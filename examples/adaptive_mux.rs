//! Adaptive-N routing demo: the serving-side extension the paper's
//! discussion motivates. A `MuxRouter` owns one shared admission queue
//! and a work-stealing lane per N — light traffic is pulled by the
//! small-N lane (low latency, little padding waste), bursts engage the
//! large-N lanes (throughput), decided at *pull* time by the adaptive
//! gate rather than per arrival.
//!
//! The demo drives three phases (idle → burst → idle) and prints which
//! lanes pulled each phase's traffic plus the latency cost. The router
//! implements the same `Submit` trait as a single coordinator, so it is
//! also network-servable: `datamux --cmd serve --adaptive true`.
//!
//! ```sh
//! cargo run --release --example adaptive_mux
//! ```

use std::sync::Arc;
use std::time::Duration;

use datamux::coordinator::{EngineBuilder, InferenceRequest, LaneStatus, MuxRouter, Submit};
use datamux::runtime::{default_artifacts_dir, ArtifactManifest, ModelRuntime};
use datamux::util::bench::Table;
use datamux::util::cli::Args;
use datamux::util::rng::Rng;
use datamux::workload::RandomWorkload;

fn main() -> anyhow::Result<()> {
    let args = Args::parse_env()
        .describe("profile", "<auto>", "artifact profile for the lanes")
        .describe("per-phase", "120", "requests per phase");
    let manifest = ArtifactManifest::load(default_artifacts_dir())?;
    // pick the smallest profile that has multiple N variants
    let profile = match args.str("profile", "") {
        p if !p.is_empty() => p,
        _ => {
            let mut profiles: Vec<&str> = manifest
                .artifacts
                .iter()
                .filter(|a| !a.trained)
                .map(|a| a.profile.as_str())
                .collect();
            profiles.sort();
            profiles.dedup();
            profiles
                .into_iter()
                .max_by_key(|p| {
                    manifest
                        .artifacts
                        .iter()
                        .filter(|a| !a.trained && a.profile == *p)
                        .map(|a| a.n_mux)
                        .collect::<std::collections::HashSet<_>>()
                        .len()
                })
                .unwrap()
                .to_string()
        }
    };

    let rt = ModelRuntime::cpu()?;
    let mut ns: Vec<usize> = manifest
        .artifacts
        .iter()
        .filter(|a| !a.trained && a.profile == profile)
        .map(|a| a.n_mux)
        .collect::<std::collections::HashSet<_>>()
        .into_iter()
        .collect();
    ns.sort_unstable();
    println!("profile {profile}: lanes at N = {ns:?}");
    let mut models = Vec::new();
    for n in &ns {
        let meta = manifest
            .artifacts
            .iter()
            .filter(|a| !a.trained && a.profile == profile && a.n_mux == *n)
            .min_by_key(|a| a.batch)
            .unwrap();
        models.push(rt.load(meta)?);
    }
    let builder = EngineBuilder::new().max_wait_ms(3).exec_time_us(20_000.0);
    let router: Arc<MuxRouter> = Arc::new(builder.build_router(models)?);
    let seq_len = router.seq_len();
    let tok = router.tokenizer().clone();

    let mut w = RandomWorkload::new(3, 200, seq_len - 4);
    let rows: Vec<Vec<i32>> = (0..256).map(|_| w.framed_row(&tok, seq_len)).collect();

    // lanes are identified by pull-time completion deltas: with
    // work-stealing dispatch the serving lane is decided when a lane
    // pulls from the shared queue, not when the request is submitted
    let per_lane_completed = |status: &[LaneStatus]| -> std::collections::BTreeMap<usize, u64> {
        status.iter().map(|l| (l.n_mux, l.completed)).collect()
    };

    let mut table = Table::new("adaptive_mux: which lanes pull at each offered load",
                               &["phase", "rate r/s", "completed per lane N", "mean latency"]);
    let per_phase = args.usize("per-phase", 120);
    for (phase, gap_us) in [("idle", 20_000u64), ("burst", 200u64), ("cooldown", 20_000u64)] {
        let mut rng = Rng::new(7);
        let before = per_lane_completed(&router.lane_status());
        let mut handles = Vec::new();
        let t0 = std::time::Instant::now();
        for i in 0..per_phase {
            let req = InferenceRequest::classify_framed(rows[i % rows.len()].clone());
            handles.push(router.submit(req)?);
            let jitter = (rng.f64() * gap_us as f64) as u64;
            std::thread::sleep(Duration::from_micros(gap_us / 2 + jitter / 2));
        }
        let mut total_lat = Duration::ZERO;
        for h in &handles {
            total_lat += h.wait()?.latency;
        }
        let rate = per_phase as f64 / t0.elapsed().as_secs_f64();
        let after = per_lane_completed(&router.lane_status());
        let served: Vec<String> = after
            .iter()
            .map(|(n, c)| format!("N={n}:{}", c - before.get(n).copied().unwrap_or(0)))
            .collect();
        table.row(&[
            phase.to_string(),
            format!("{rate:.0}"),
            served.join(" "),
            format!("{:?}", total_lat / per_phase as u32),
        ]);
    }
    table.print();
    println!("burst traffic is pulled by deeper-mux lanes; idle traffic stays at small N.");
    Ok(())
}
