//! Adaptive-N routing demo: the serving-side extension the paper's
//! discussion motivates. A `MuxRouter` owns coordinators at several N and
//! routes each arrival by observed rate — light traffic goes to small N
//! (low latency, little padding waste), bursts go to large N (throughput).
//!
//! The demo drives three phases (idle → burst → idle) and prints which
//! lane served each phase plus the latency cost. The router implements
//! the same `Submit` trait as a single coordinator, so it is also
//! network-servable: `datamux --cmd serve --adaptive true`.
//!
//! ```sh
//! cargo run --release --example adaptive_mux
//! ```

use std::sync::Arc;
use std::time::Duration;

use datamux::coordinator::{EngineBuilder, InferenceRequest, MuxRouter};
use datamux::runtime::{default_artifacts_dir, ArtifactManifest, ModelRuntime};
use datamux::util::bench::Table;
use datamux::util::cli::Args;
use datamux::util::rng::Rng;
use datamux::workload::RandomWorkload;

fn main() -> anyhow::Result<()> {
    let args = Args::parse_env()
        .describe("profile", "<auto>", "artifact profile for the lanes")
        .describe("per-phase", "120", "requests per phase");
    let manifest = ArtifactManifest::load(default_artifacts_dir())?;
    // pick the smallest profile that has multiple N variants
    let profile = match args.str("profile", "") {
        p if !p.is_empty() => p,
        _ => {
            let mut profiles: Vec<&str> = manifest
                .artifacts
                .iter()
                .filter(|a| !a.trained)
                .map(|a| a.profile.as_str())
                .collect();
            profiles.sort();
            profiles.dedup();
            profiles
                .into_iter()
                .max_by_key(|p| {
                    manifest
                        .artifacts
                        .iter()
                        .filter(|a| !a.trained && a.profile == *p)
                        .map(|a| a.n_mux)
                        .collect::<std::collections::HashSet<_>>()
                        .len()
                })
                .unwrap()
                .to_string()
        }
    };

    let rt = ModelRuntime::cpu()?;
    let mut ns: Vec<usize> = manifest
        .artifacts
        .iter()
        .filter(|a| !a.trained && a.profile == profile)
        .map(|a| a.n_mux)
        .collect::<std::collections::HashSet<_>>()
        .into_iter()
        .collect();
    ns.sort_unstable();
    println!("profile {profile}: lanes at N = {ns:?}");
    let mut models = Vec::new();
    for n in &ns {
        let meta = manifest
            .artifacts
            .iter()
            .filter(|a| !a.trained && a.profile == profile && a.n_mux == *n)
            .min_by_key(|a| a.batch)
            .unwrap();
        models.push(rt.load(meta)?);
    }
    let builder = EngineBuilder::new().max_wait_ms(3).exec_time_us(20_000.0);
    let router: Arc<MuxRouter> = Arc::new(builder.build_router(models)?);
    let seq_len = router.lanes[0].seq_len;
    let tok = router.lanes[0].tokenizer.clone();

    let mut w = RandomWorkload::new(3, 200, seq_len - 4);
    let rows: Vec<Vec<i32>> = (0..256).map(|_| w.framed_row(&tok, seq_len)).collect();

    let mut table = Table::new("adaptive_mux: lane selection by offered load",
                               &["phase", "rate r/s", "lane N (mode)", "mean latency"]);
    let per_phase = args.usize("per-phase", 120);
    for (phase, gap_us) in [("idle", 20_000u64), ("burst", 200u64), ("cooldown", 20_000u64)] {
        let mut rng = Rng::new(7);
        let mut lane_hits: std::collections::BTreeMap<usize, usize> = Default::default();
        let mut handles = Vec::new();
        let t0 = std::time::Instant::now();
        for i in 0..per_phase {
            let req = InferenceRequest::classify_framed(rows[i % rows.len()].clone());
            let (n, h) = router.submit_routed(req)?;
            *lane_hits.entry(n).or_default() += 1;
            handles.push(h);
            let jitter = (rng.f64() * gap_us as f64) as u64;
            std::thread::sleep(Duration::from_micros(gap_us / 2 + jitter / 2));
        }
        let mut total_lat = Duration::ZERO;
        for h in &handles {
            total_lat += h.wait()?.latency;
        }
        let rate = per_phase as f64 / t0.elapsed().as_secs_f64();
        let mode = lane_hits.iter().max_by_key(|(_, c)| **c).map(|(n, _)| *n).unwrap_or(0);
        table.row(&[
            phase.to_string(),
            format!("{rate:.0}"),
            format!("{mode} {lane_hits:?}"),
            format!("{:?}", total_lat / per_phase as u32),
        ]);
    }
    table.print();
    println!("burst traffic is routed to deeper-mux lanes; idle traffic stays at small N.");
    Ok(())
}
