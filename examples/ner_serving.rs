//! Token-level serving: the CoNLL-NER-style task through the full stack.
//!
//! Demonstrates per-position demultiplexing — each response carries
//! seq_len x n_tags logits, and accuracy is measured tag-by-tag on
//! non-padding positions (mirroring python/compile/train.py::eval_task).
//!
//! ```sh
//! cargo run --release --example ner_serving -- --requests 2000
//! ```

use std::sync::Arc;
use std::time::Duration;

use datamux::coordinator::{EngineBuilder, Submit};
use datamux::runtime::{default_artifacts_dir, ArtifactManifest, ModelRuntime};
use datamux::util::bench::Table;
use datamux::util::cli::Args;
use datamux::util::json::{num, obj, s};

const TAGS: [&str; 5] = ["O", "B-PER", "I-PER", "B-LOC", "I-LOC"];

fn main() -> anyhow::Result<()> {
    let args = Args::parse_env()
        .describe("requests", "2000", "requests to serve")
        .describe("show", "3", "how many tagged samples to print");
    let n_requests = args.usize("requests", 2000);

    let dir = default_artifacts_dir();
    let manifest = ArtifactManifest::load(&dir)?;
    let eval = datamux::workload::EvalSet::load(dir.join("eval_ner.json"))?;
    let mut metas: Vec<_> = manifest
        .artifacts
        .iter()
        .filter(|a| a.trained && a.train_task.as_deref() == Some("ner"))
        .collect();
    metas.sort_by_key(|a| a.n_mux);
    anyhow::ensure!(!metas.is_empty(), "no trained ner artifacts — run `make artifacts`");

    let rt = ModelRuntime::cpu()?;
    let mut table = Table::new("ner_serving: token-level accuracy through rust",
                               &["N", "token acc", "throughput r/s"]);
    let mut rows_out = Vec::new();

    let builder = EngineBuilder::new().max_wait(Duration::from_millis(4));
    for meta in metas {
        let model = rt.load(meta)?;
        let coord = Arc::new(builder.build(model)?);
        let framed = eval.framed_rows(&coord.tokenizer, coord.seq_len)?;
        let vocab = coord.tokenizer.vocab.clone();

        let t0 = std::time::Instant::now();
        let mut handles = Vec::new();
        for i in 0..n_requests {
            handles.push((i % framed.len(), coord.submit_framed(framed[i % framed.len()].clone())?));
        }
        let mut hits = 0usize;
        let mut total = 0usize;
        let mut shown = 0usize;
        for (k, h) in handles {
            let r = h.wait()?;
            let preds = r.pred_tokens();
            let sample = &eval.samples[k];
            let row = &framed[k];
            for (j, (&tok, pred)) in row.iter().zip(&preds).enumerate() {
                if tok == vocab.pad || tok == vocab.cls || tok == vocab.sep {
                    continue;
                }
                if let Some(&want) = sample.tags.get(j) {
                    total += 1;
                    if *pred as i64 == want {
                        hits += 1;
                    }
                }
            }
            if shown < args.usize("show", 3) {
                shown += 1;
                let words: Vec<String> = row
                    .iter()
                    .zip(&preds)
                    .filter(|(&t, _)| t >= vocab.content_base)
                    .map(|(&t, &p)| {
                        format!("t{}/{}", t - vocab.content_base, TAGS[p.min(TAGS.len() - 1)])
                    })
                    .collect();
                println!("  [N={}] {}", meta.n_mux, words.join(" "));
            }
        }
        let wall = t0.elapsed();
        let acc = hits as f64 / total.max(1) as f64;
        let tput = n_requests as f64 / wall.as_secs_f64();
        table.row(&[meta.n_mux.to_string(), format!("{acc:.3}"), format!("{tput:.1}")]);
        rows_out.push(obj(vec![
            ("n_mux", num(meta.n_mux as f64)),
            ("token_accuracy", num(acc)),
            ("throughput_rps", num(tput)),
        ]));
    }
    table.print();
    datamux::util::bench::write_results(
        "ner_serving.json",
        obj(vec![("task", s("ner")), ("lanes", datamux::util::json::arr(rows_out))]),
    )?;
    Ok(())
}
