//! Quickstart: load a DataMUX artifact and serve a few multiplexed
//! requests through the unified `Submit` API. This is the README
//! copy-paste example.
//!
//! ```sh
//! make artifacts            # once (python, build path)
//! cargo run --release --example quickstart
//! ```

use std::sync::Arc;

use datamux::coordinator::{EngineBuilder, InferenceRequest, Submit};
use datamux::runtime::{default_artifacts_dir, ArtifactManifest, ModelRuntime};

fn main() -> anyhow::Result<()> {
    // 1. discover artifacts (built once by `make artifacts`)
    let manifest = ArtifactManifest::load(default_artifacts_dir())?;
    let meta = manifest
        .artifacts
        .iter()
        .filter(|a| a.n_mux > 1 && a.task == "cls")
        .min_by_key(|a| (a.d_model, std::cmp::Reverse(a.trained)))
        .expect("run `make artifacts` first");
    println!(
        "artifact: {} (N={} batch={} d={} trained={})",
        meta.name, meta.n_mux, meta.batch, meta.d_model, meta.trained
    );

    // 2. one PJRT client per process; compile + upload weights once
    let rt = ModelRuntime::cpu()?;
    let model = rt.load(meta)?;
    println!(
        "loaded on {}: compile {:.0?}, weights {:.1} MB uploaded in {:.0?}",
        rt.platform(),
        model.compile_time,
        model.weight_bytes as f64 / 1e6,
        model.upload_time,
    );

    // 3. build the mux engine: requests are packed N-at-a-time into a
    //    single model execution and demultiplexed back (paper Fig 1)
    let coord = Arc::new(EngineBuilder::new().max_wait_ms(5).build(model)?);

    // 4. submit typed requests concurrently (vocabulary: t0..tN words,
    //    '[SEP]'-joined sentence pairs — see python/compile/data.py)
    let texts = [
        "t64 t65 t120 t7",
        "t100 t101 [SEP] t100",
        "t80 t81 t82",
        "t90 t9 t12 t13 t14",
        "t20 t21 [SEP] t22 t23",
        "t55 t66 t77",
    ];
    let handles: Vec<_> = texts
        .iter()
        .map(|t| coord.submit(InferenceRequest::classify_text(*t)).unwrap())
        .collect();

    for (text, h) in texts.iter().zip(handles) {
        let r = h.wait()?;
        println!(
            "  {:28} -> class {}  (mux slot {}, group {}, {:?})",
            text,
            r.pred_class(),
            r.slot,
            r.group,
            r.latency
        );
    }

    // 5. serving stats: note requests-per-execution = N * batch
    let c = coord.counters();
    println!(
        "\nstats: {} requests in {} model executions ({} group slots padded)",
        c.completed,
        c.groups_executed as usize / meta.batch.max(1),
        c.slots_padded
    );
    println!("{}", coord.latency().render("e2e latency"));
    Ok(())
}
