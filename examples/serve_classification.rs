//! **End-to-end driver** (DESIGN.md §Examples): serve a real labelled
//! workload through the full stack — tokenizer → mux batcher → PJRT
//! executable (trained T-MUX weights) → demux → predictions — and report
//! accuracy, throughput vs the N=1 baseline, and latency percentiles.
//!
//! This is the serving realization of the paper's headline experiment
//! (Fig 4c: throughput on ~20k MNLI instances) with accuracy measured
//! *through the rust path*, not in python. Results land in
//! results/serve_classification.json and EXPERIMENTS.md.
//!
//! ```sh
//! cargo run --release --example serve_classification -- --requests 20000
//! ```

use std::sync::Arc;
use std::time::{Duration, Instant};

use datamux::coordinator::{EngineBuilder, Submit};
use datamux::runtime::{default_artifacts_dir, ArtifactManifest, ModelRuntime};
use datamux::util::bench::Table;
use datamux::util::cli::Args;
use datamux::util::json::{arr, num, obj, s};
use datamux::util::metrics::fmt_ns;
use datamux::workload::EvalSet;

fn main() -> anyhow::Result<()> {
    let args = Args::parse_env()
        .describe("requests", "20000", "total requests to serve")
        .describe("clients", "8", "closed-loop client threads")
        .describe("task", "mnli", "eval task (mnli)")
        .describe("max-wait-ms", "4", "batcher deadline");
    let n_requests = args.usize("requests", 20_000);
    let clients = args.usize("clients", 8);
    let task = args.str("task", "mnli");

    let dir = default_artifacts_dir();
    let manifest = ArtifactManifest::load(&dir)?;
    let eval = EvalSet::load(dir.join(format!("eval_{task}.json")))?;
    println!(
        "workload: {} ({} labelled samples, {} classes)",
        task,
        eval.samples.len(),
        eval.n_classes
    );

    // trained artifacts at every available N (N=1 is the vanilla baseline B1)
    let mut metas: Vec<_> = manifest
        .artifacts
        .iter()
        .filter(|a| a.trained && a.train_task.as_deref() == Some(task.as_str()))
        .collect();
    metas.sort_by_key(|a| a.n_mux);
    anyhow::ensure!(
        !metas.is_empty(),
        "no trained {task} artifacts — run `make artifacts` (with training)"
    );

    let rt = ModelRuntime::cpu()?;
    let mut table = Table::new(
        &format!("serve_classification: {task} over {n_requests} requests"),
        &["N", "acc(py)", "acc(rust)", "thruput r/s", "speedup", "p50", "p95", "p99"],
    );
    let mut results = Vec::new();
    let mut base_tput = None;

    let builder = EngineBuilder::new()
        .max_wait(Duration::from_millis(args.u64("max-wait-ms", 4)));
    for meta in metas {
        let model = rt.load(meta)?;
        let coord = Arc::new(builder.build(model)?);
        let rows = Arc::new(eval.framed_rows(&coord.tokenizer, coord.seq_len)?);
        let labels: Vec<i64> = eval.samples.iter().map(|s| s.label).collect();

        // closed-loop: `clients` threads, submit→wait→repeat over the eval set
        let hits = Arc::new(std::sync::atomic::AtomicUsize::new(0));
        let served = Arc::new(std::sync::atomic::AtomicUsize::new(0));
        let t0 = Instant::now();
        let mut handles = Vec::new();
        let per_client = n_requests / clients;
        for c in 0..clients {
            let coord = coord.clone();
            let rows = rows.clone();
            let labels = labels.clone();
            let hits = hits.clone();
            let served = served.clone();
            handles.push(std::thread::spawn(move || {
                for i in 0..per_client {
                    let k = (c * per_client + i) % rows.len();
                    let h = match coord.submit_framed(rows[k].clone()) {
                        Ok(h) => h,
                        Err(_) => return,
                    };
                    let r = match h.wait() {
                        Ok(r) => r,
                        Err(_) => return,
                    };
                    served.fetch_add(1, std::sync::atomic::Ordering::Relaxed);
                    if r.pred_class() as i64 == labels[k] {
                        hits.fetch_add(1, std::sync::atomic::Ordering::Relaxed);
                    }
                }
            }));
        }
        for h in handles {
            let _ = h.join();
        }
        let wall = t0.elapsed();
        let served = served.load(std::sync::atomic::Ordering::Relaxed);
        let acc = hits.load(std::sync::atomic::Ordering::Relaxed) as f64 / served as f64;
        let tput = served as f64 / wall.as_secs_f64();
        let speedup = match base_tput {
            None => {
                base_tput = Some(tput);
                1.0
            }
            Some(b) => tput / b,
        };
        let lat = coord.stats.e2e_latency.summary();
        table.row(&[
            meta.n_mux.to_string(),
            meta.train_accuracy.map(|a| format!("{a:.3}")).unwrap_or_default(),
            format!("{acc:.3}"),
            format!("{tput:.1}"),
            format!("{speedup:.2}x"),
            fmt_ns(lat.p50_ns),
            fmt_ns(lat.p95_ns),
            fmt_ns(lat.p99_ns),
        ]);
        results.push(obj(vec![
            ("n_mux", num(meta.n_mux as f64)),
            ("accuracy_rust", num(acc)),
            ("accuracy_python", num(meta.train_accuracy.unwrap_or(f64::NAN))),
            ("throughput_rps", num(tput)),
            ("speedup", num(speedup)),
            ("p50_ns", num(lat.p50_ns as f64)),
            ("p95_ns", num(lat.p95_ns as f64)),
            ("p99_ns", num(lat.p99_ns as f64)),
            ("served", num(served as f64)),
        ]));
        println!("N={} done in {wall:?}", meta.n_mux);
    }

    table.print();
    datamux::util::bench::write_results(
        "serve_classification.json",
        obj(vec![
            ("task", s(&task)),
            ("requests", num(n_requests as f64)),
            ("clients", num(clients as f64)),
            ("lanes", arr(results)),
        ]),
    )?;
    println!("\nwrote results/serve_classification.json");
    Ok(())
}
