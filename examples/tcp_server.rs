//! Network front end demo: start the TCP server on a free port, then act
//! as its own client fleet — each client opens a connection and sends
//! requests, so tokenization, batching, model execution and demux all
//! happen server-side.
//!
//! Phase 1 drives the legacy v1 line protocol (`CLS ...`, lockstep);
//! phase 2 drives wire protocol v2 (line JSON, pipelined: all requests
//! ship before the first reply is read, correlated by client id).
//!
//! ```sh
//! cargo run --release --example tcp_server -- --clients 8 --per-client 40
//! ```

use std::io::{BufRead, BufReader, Write};
use std::net::TcpStream;
use std::sync::Arc;
use std::time::Instant;

use datamux::coordinator::{EngineBuilder, Submit};
use datamux::runtime::{default_artifacts_dir, ArtifactManifest, ModelRuntime};
use datamux::util::cli::Args;
use datamux::util::metrics::Histogram;
use datamux::workload::RandomWorkload;

fn main() -> anyhow::Result<()> {
    let args = Args::parse_env()
        .describe("clients", "8", "client connections")
        .describe("per-client", "40", "requests per connection");
    let clients = args.usize("clients", 8);
    let per_client = args.usize("per-client", 40);

    let manifest = ArtifactManifest::load(default_artifacts_dir())?;
    let meta = manifest
        .artifacts
        .iter()
        .filter(|a| a.n_mux > 1 && a.task == "cls")
        .min_by_key(|a| a.d_model)
        .expect("run `make artifacts`");
    println!("serving {} (N={})", meta.name, meta.n_mux);
    let rt = ModelRuntime::cpu()?;
    let builder = EngineBuilder::new()
        .max_wait_ms(3)
        .addr("127.0.0.1:0")
        .max_connections(clients + 2);
    let coord = Arc::new(builder.build(rt.load(meta)?)?);
    let server = builder.serve(coord.clone())?;
    println!("listening on {}", server.local_addr);

    // ---- phase 1: v1 lockstep clients -----------------------------------
    let addr = server.local_addr;
    let rtt = Arc::new(Histogram::new());
    let t0 = Instant::now();
    let mut joins = Vec::new();
    for c in 0..clients {
        let rtt = rtt.clone();
        joins.push(std::thread::spawn(move || -> anyhow::Result<usize> {
            let mut w = RandomWorkload::new(100 + c as u64, 200, 10);
            let stream = TcpStream::connect(addr)?;
            stream.set_nodelay(true)?;
            let mut writer = stream.try_clone()?;
            let mut reader = BufReader::new(stream);
            let mut ok = 0;
            for _ in 0..per_client {
                let line = format!("CLS {}\n", w.text());
                let t = Instant::now();
                writer.write_all(line.as_bytes())?;
                let mut reply = String::new();
                reader.read_line(&mut reply)?;
                rtt.record_duration(t.elapsed());
                if reply.starts_with("OK") {
                    ok += 1;
                }
            }
            writer.write_all(b"QUIT\n")?;
            Ok(ok)
        }));
    }
    let mut total_ok = 0;
    for j in joins {
        total_ok += j.join().unwrap()?;
    }
    let wall = t0.elapsed();
    println!(
        "v1: {total_ok}/{} requests OK in {wall:?} ({:.1} req/s over TCP, lockstep)",
        clients * per_client,
        total_ok as f64 / wall.as_secs_f64()
    );
    println!("{}", rtt.summary().render("v1 client RTT"));

    // ---- phase 2: one v2 connection, fully pipelined --------------------
    // window the in-flight count well below the server's per-connection
    // completion buffer (4096): a client that writes everything without
    // ever reading replies would eventually have completions shed
    let window = 1024usize;
    let mut w = RandomWorkload::new(7, 200, 10);
    let n_pipelined = clients * per_client;
    let stream = TcpStream::connect(addr)?;
    stream.set_nodelay(true)?;
    let mut writer = stream.try_clone()?;
    let mut reader = BufReader::new(stream);
    let t0 = Instant::now();
    let mut ok = 0;
    let mut sent = 0usize;
    let mut received = 0usize;
    while received < n_pipelined {
        while sent < n_pipelined && sent - received < window {
            let line =
                format!("{{\"id\":{sent},\"op\":\"classify\",\"text\":\"{}\"}}\n", w.text());
            writer.write_all(line.as_bytes())?;
            sent += 1;
        }
        let mut reply = String::new();
        reader.read_line(&mut reply)?;
        received += 1;
        if reply.contains("\"ok\":true") {
            ok += 1;
        }
    }
    let wall = t0.elapsed();
    writer.write_all(b"{\"op\":\"quit\"}\n")?;
    println!(
        "v2: {ok}/{n_pipelined} requests OK in {wall:?} ({:.1} req/s over TCP, \
         pipelined on one connection)",
        ok as f64 / wall.as_secs_f64()
    );

    let c = coord.counters();
    println!(
        "server: {} executions, {} slots padded",
        c.groups_executed as usize / meta.batch.max(1),
        c.slots_padded
    );
    server.stop();
    Ok(())
}
