//! Network front end demo: start the TCP line-protocol server on a free
//! port, then act as its own client fleet — each client opens a
//! connection and sends CLS requests, so tokenization, batching, PJRT
//! execution and demux all happen server-side.
//!
//! ```sh
//! cargo run --release --example tcp_server -- --clients 8 --per-client 40
//! ```

use std::io::{BufRead, BufReader, Write};
use std::net::TcpStream;
use std::sync::Arc;
use std::time::{Duration, Instant};

use datamux::coordinator::server::{Server, ServerConfig};
use datamux::coordinator::{CoordinatorConfig, MuxCoordinator};
use datamux::runtime::{default_artifacts_dir, ArtifactManifest, ModelRuntime};
use datamux::util::cli::Args;
use datamux::util::metrics::Histogram;
use datamux::workload::RandomWorkload;

fn main() -> anyhow::Result<()> {
    let args = Args::parse_env()
        .describe("clients", "8", "client connections")
        .describe("per-client", "40", "requests per connection");
    let clients = args.usize("clients", 8);
    let per_client = args.usize("per-client", 40);

    let manifest = ArtifactManifest::load(default_artifacts_dir())?;
    let meta = manifest
        .artifacts
        .iter()
        .filter(|a| a.n_mux > 1 && a.task == "cls")
        .min_by_key(|a| a.d_model)
        .expect("run `make artifacts`");
    println!("serving {} (N={})", meta.name, meta.n_mux);
    let rt = ModelRuntime::cpu()?;
    let coord = Arc::new(MuxCoordinator::start(
        rt.load(meta)?,
        CoordinatorConfig { max_wait: Duration::from_millis(3), ..Default::default() },
    )?);
    let server = Server::start(
        coord.clone(),
        ServerConfig { addr: "127.0.0.1:0".into(), max_connections: clients + 2 },
    )?;
    println!("listening on {}", server.local_addr);

    let addr = server.local_addr;
    let rtt = Arc::new(Histogram::new());
    let t0 = Instant::now();
    let mut joins = Vec::new();
    for c in 0..clients {
        let rtt = rtt.clone();
        joins.push(std::thread::spawn(move || -> anyhow::Result<usize> {
            let mut w = RandomWorkload::new(100 + c as u64, 200, 10);
            let stream = TcpStream::connect(addr)?;
            stream.set_nodelay(true)?;
            let mut writer = stream.try_clone()?;
            let mut reader = BufReader::new(stream);
            let mut ok = 0;
            for _ in 0..per_client {
                let line = format!("CLS {}\n", w.text());
                let t = Instant::now();
                writer.write_all(line.as_bytes())?;
                let mut reply = String::new();
                reader.read_line(&mut reply)?;
                rtt.record_duration(t.elapsed());
                if reply.starts_with("OK") {
                    ok += 1;
                }
            }
            writer.write_all(b"QUIT\n")?;
            Ok(ok)
        }));
    }
    let mut total_ok = 0;
    for j in joins {
        total_ok += j.join().unwrap()?;
    }
    let wall = t0.elapsed();
    println!(
        "{total_ok}/{} requests OK in {wall:?} ({:.1} req/s over TCP)",
        clients * per_client,
        total_ok as f64 / wall.as_secs_f64()
    );
    println!("{}", rtt.summary().render("client RTT"));
    let c = coord.stats.counters.snapshot();
    println!(
        "server: {} executions, {} slots padded",
        c.groups_executed as usize / meta.batch.max(1),
        c.slots_padded
    );
    server.stop();
    Ok(())
}
