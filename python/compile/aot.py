"""AOT lowering: JAX/Pallas models -> HLO text artifacts + weight blobs.

This is the compile-path half of the three-layer architecture. For every
artifact in the matrix we emit:

  artifacts/<name>.hlo.txt      HLO *text* of the jitted forward graph
                                (text, NOT serialized proto: jax >= 0.5
                                emits 64-bit instruction ids that
                                xla_extension 0.5.1 rejects; the text
                                parser reassigns ids — see
                                /opt/xla-example/README.md)
  artifacts/<wkey>.weights.bin  flat little-endian tensor blob, shared by
                                all batch-size variants of a config
  artifacts/manifest.json       the registry rust loads: shapes, vocab
                                layout, parameter order, parity vectors

Weights are *runtime parameters*, not baked constants: the text format
would balloon to tens of MB per artifact otherwise, and keeping them as
parameters lets the rust runtime upload them to the PJRT device once and
reuse the buffers across every request (`execute_b`).

Parameter order is the jax pytree flatten order of the params dict —
recorded tensor-by-tensor in the manifest so the rust side never guesses.

Pallas path: artifacts are lowered with ``use_pallas=True`` so the
shipped HLO is the L1 kernels' lowering (interpret=True -> plain HLO ops
executable on the CPU PJRT client).

Usage:
  python -m compile.aot --out ../artifacts              # timing matrix
  python -m compile.aot --out ../artifacts --trained    # + trained models
  python -m compile.aot --out ../artifacts --quick      # tiny dev subset
"""
import argparse
import dataclasses
import json
import os
import struct
import sys
import time

import jax
import jax.numpy as jnp
import numpy as np
from jax._src.lib import xla_client as xc

from . import config as C
from . import data as D
from . import model as M

MAGIC = b"DMUXW1\n"


# ---------------------------------------------------------------------------
# HLO text lowering (interchange gotcha: text, not .serialize())
# ---------------------------------------------------------------------------

def to_hlo_text(lowered) -> str:
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def prune_params(params, cfg: C.ModelConfig):
    """Drop the task heads the artifact's task doesn't use.

    jax.jit DCEs unused parameters out of the lowered module, so the HLO
    would expect fewer arguments than the full pytree provides — prune
    *before* lowering so the weights file and the HLO agree exactly.
    """
    used_head = {"cls": "head_cls", "token": "head_token",
                 "retrieval": "head_retrieval"}[cfg.task]
    return {k: v for k, v in params.items()
            if not k.startswith("head_") or k == used_head}


def lower_model(params, cfg: C.ModelConfig, batch: int) -> str:
    """Lower forward_task(params, ids) with params as runtime arguments.
    `params` must already be pruned (prune_params)."""
    cfg = dataclasses.replace(cfg, use_pallas=True)

    def fn(p, ids):
        return M.forward_task(p, cfg, ids)

    ids_spec = jax.ShapeDtypeStruct((batch, cfg.n_mux, cfg.input_len), jnp.int32)
    params_spec = jax.tree_util.tree_map(
        lambda a: jax.ShapeDtypeStruct(a.shape, a.dtype), params)
    return to_hlo_text(jax.jit(fn).lower(params_spec, ids_spec))


# ---------------------------------------------------------------------------
# weight blobs
# ---------------------------------------------------------------------------

def flatten_named(params):
    """(name, leaf) pairs in the exact order jax flattens the pytree —
    the order the lowered HLO expects its leading parameters in."""
    flat, _ = jax.tree_util.tree_flatten_with_path(params)
    out = []
    for path, leaf in flat:
        name = "/".join(
            str(getattr(k, "key", getattr(k, "idx", k))) for k in path)
        out.append((name, np.asarray(leaf)))
    return out


def write_weights(path, named):
    """MAGIC + u32 header_len + json header + raw tensor bytes."""
    tensors = []
    offset = 0
    blobs = []
    for name, arr in named:
        arr = np.ascontiguousarray(arr, dtype=np.float32)
        blobs.append(arr.tobytes())
        tensors.append({
            "name": name,
            "shape": list(arr.shape),
            "dtype": "f32",
            "offset": offset,
            "nbytes": len(blobs[-1]),
        })
        offset += len(blobs[-1])
    header = json.dumps({"tensors": tensors}).encode()
    with open(path, "wb") as f:
        f.write(MAGIC)
        f.write(struct.pack("<I", len(header)))
        f.write(header)
        for b in blobs:
            f.write(b)
    return tensors


# ---------------------------------------------------------------------------
# parity vectors (bit-level contract between python and rust)
# ---------------------------------------------------------------------------

def parity_blob(params, cfg: C.ModelConfig, batch: int, seed=77):
    """Deterministic input + expected output for the integration test.
    Computed through the pallas path — exactly what rust must reproduce."""
    pcfg = dataclasses.replace(cfg, use_pallas=True)
    rng = np.random.RandomState(seed)
    task_gen = {"cls": D.make_mnli if cfg.n_classes == 3 else D.make_sst2,
                "token": D.make_ner}.get(cfg.task, D.make_retrieval)
    ds = task_gen(seed, batch * cfg.n_mux, cfg.seq_len)
    content = ds.ids[: batch * cfg.n_mux].reshape(batch, cfg.n_mux, cfg.seq_len)
    ids = np.asarray(M.assemble_input(pcfg, content), np.int32)
    out = np.asarray(M.forward_task(params, pcfg, jnp.asarray(ids))[0], np.float32)
    flat = out.reshape(-1)
    k = min(64, flat.size)
    idx = rng.choice(flat.size, k, replace=False)
    return {
        "ids": ids.reshape(-1).tolist(),
        "check_indices": idx.tolist(),
        "check_values": [float(flat[i]) for i in idx],
        "output_shape": list(out.shape),
        "tol": 2e-4,
    }


# ---------------------------------------------------------------------------
# artifact matrix
# ---------------------------------------------------------------------------

def timing_matrix(quick=False):
    """(profile, n_mux, batch) combos for the serving/throughput benches."""
    if quick:
        return [("tiny", n, b) for n in (1, 4) for b in (1, 2)]
    combos = []
    for n in (1, 2, 5, 10, 20, 40):
        for b in (1, 4, 8):
            combos.append(("base", n, b))
    for prof in ("small_wide", "small_deep"):
        for n in (1, 2, 5, 10, 20):
            combos.append((prof, n, 4))
    return combos


def make_timing_cfg(prof: str, n_mux: int) -> C.ModelConfig:
    seq = 16 if prof == "tiny" else 32
    return C.profile(prof, n_mux=n_mux, seq_len=seq, task="cls", n_classes=3)


def emit_artifact(outdir, name, params, cfg, batch, wkey, meta, manifest,
                  written_weights, parity=True):
    params = prune_params(params, cfg)
    hlo_path = os.path.join(outdir, f"{name}.hlo.txt")
    t0 = time.time()
    hlo = lower_model(params, cfg, batch)
    with open(hlo_path, "w") as f:
        f.write(hlo)
    wfile = f"{wkey}.weights.bin"
    if wkey not in written_weights:
        tensors = write_weights(os.path.join(outdir, wfile), flatten_named(params))
        written_weights[wkey] = tensors
    entry = {
        "name": name,
        "hlo": f"{name}.hlo.txt",
        "weights": wfile,
        "profile": meta.get("profile", ""),
        "n_mux": cfg.n_mux,
        "seq_len": cfg.seq_len,
        "input_len": cfg.input_len,
        "batch": batch,
        "d_model": cfg.d_model,
        "n_layers": cfg.n_layers,
        "n_heads": cfg.n_heads,
        "task": cfg.task,
        "n_classes": cfg.n_classes,
        "mux": cfg.mux_strategy,
        "demux": cfg.demux_strategy,
        "vocab_size": cfg.vocab_size,
        "n_weight_tensors": len(written_weights[wkey]),
        **meta,
    }
    if parity:
        entry["parity"] = parity_blob(params, cfg, batch)
    manifest["artifacts"].append(entry)
    print(f"  {name}: {len(hlo) / 1e6:.2f} MB hlo, {time.time() - t0:.1f}s",
          flush=True)


def build_timing(outdir, manifest, written_weights, quick=False):
    print("== timing artifacts (random weights, pallas path) ==", flush=True)
    param_cache = {}
    for prof, n, b in timing_matrix(quick):
        cfg = make_timing_cfg(prof, n)
        wkey = f"{prof}_n{n}"
        if wkey not in param_cache:
            param_cache[wkey] = M.init_params(jax.random.PRNGKey(hash(wkey) % 2**31), cfg)
        name = f"timing_{prof}_n{n}_b{b}"
        # parity only on the smallest batch variant (keeps manifest compact)
        emit_artifact(outdir, name, param_cache[wkey], cfg, b, wkey,
                      {"profile": prof, "trained": False}, manifest,
                      written_weights, parity=(b == timing_matrix(quick)[0][2] or b == 1))


def build_trained(outdir, manifest, written_weights, quick=False):
    """Train tiny T-MUX models (paper recipe) and export them for the
    accuracy-through-rust examples."""
    from . import train as T
    print("== trained artifacts (warm-up + fine-tune) ==", flush=True)
    jobs = [("mnli", "cls", 3, (1, 4) if quick else (1, 2, 5, 10)),
            ("ner", "token", 5, (4,) if quick else (2, 5))]
    for task, task_kind, ncls, ns in jobs:
        for n in ns:
            cfg = C.profile("tiny", n_mux=n, seq_len=16, task=task_kind,
                            n_classes=ncls)
            # the paper notes convergence time grows ~linearly with N —
            # scale both phases accordingly
            wsteps = 150 if quick else min(300 + 170 * n, 2500)
            tsteps = 150 if quick else min(400 + 60 * n, 1300)
            t0 = time.time()
            params, wacc, acc, per_index = T.train_tmux(
                cfg, task, warmup_steps=wsteps, task_steps=tsteps,
                batch=8, seed=13)
            name = f"trained_{task}_n{n}"
            print(f"  {name}: warmup_retrieval={wacc:.3f} task_acc={acc:.3f} "
                  f"({time.time() - t0:.0f}s)", flush=True)
            emit_artifact(outdir, name, params, cfg, 4, f"{name}",
                          {"profile": "tiny", "trained": True,
                           "train_task": task,
                           "train_accuracy": round(acc, 4),
                           "warmup_retrieval_accuracy": round(wacc, 4),
                           "per_index_accuracy": [round(float(a), 4) for a in per_index]},
                          manifest, written_weights)


def build_eval_sets(outdir, quick=False):
    """Export labelled eval sets (text form) for the accuracy-through-rust
    examples — same generators as training, held-out seeds."""
    n = 200 if quick else 2000
    for task in ("mnli", "ner", "sst2"):
        ds = D.TASKS[task](987, n, 16)
        samples = []
        for i in range(n):
            entry = {"text": D.ids_to_text(ds.ids[i])}
            if ds.token_level:
                entry["label"] = int(ds.labels[i][0])
                # align tags with the non-pad prefix of the text tokens
                n_tok = int((ds.ids[i] != C.PAD_ID).sum())
                entry["tags"] = [int(t) for t in ds.labels[i][:n_tok]]
            else:
                entry["label"] = int(ds.labels[i])
            samples.append(entry)
        blob = {
            "task": task,
            "seq_len": 16,
            "n_classes": ds.n_classes,
            "token_level": ds.token_level,
            "samples": samples,
        }
        path = os.path.join(outdir, f"eval_{task}.json")
        with open(path, "w") as f:
            json.dump(blob, f)
        print(f"  eval_{task}.json: {n} samples", flush=True)


def main():
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out", default="../artifacts")
    ap.add_argument("--quick", action="store_true", help="tiny dev subset")
    ap.add_argument("--trained", action="store_true", help="also train+export models")
    ap.add_argument("--timing", dest="timing", action="store_true", default=True)
    ap.add_argument("--no-timing", dest="timing", action="store_false")
    args = ap.parse_args()

    outdir = os.path.abspath(args.out)
    os.makedirs(outdir, exist_ok=True)
    manifest = {
        "version": 1,
        "vocab": {
            "pad": C.PAD_ID, "cls": C.CLS_ID, "sep": C.SEP_ID,
            "eps_pad": C.EPS_PAD_ID, "idx_base": C.IDX_BASE,
            "max_mux": C.MAX_MUX, "content_base": C.CONTENT_BASE,
        },
        "artifacts": [],
    }
    written_weights = {}
    t0 = time.time()
    if args.timing:
        build_timing(outdir, manifest, written_weights, quick=args.quick)
    if args.trained:
        build_trained(outdir, manifest, written_weights, quick=args.quick)
    build_eval_sets(outdir, quick=args.quick)
    # merge: keep previously-built artifacts we didn't regenerate (e.g.
    # retrained models when only the timing matrix is rebuilt)
    prev_path = os.path.join(outdir, "manifest.json")
    if os.path.exists(prev_path):
        with open(prev_path) as f:
            prev = json.load(f)
        new_names = {a["name"] for a in manifest["artifacts"]}
        for a in prev.get("artifacts", []):
            if (a["name"] not in new_names
                    and os.path.exists(os.path.join(outdir, a["hlo"]))
                    and os.path.exists(os.path.join(outdir, a["weights"]))):
                manifest["artifacts"].append(a)
    with open(os.path.join(outdir, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=1)
    print(f"wrote {len(manifest['artifacts'])} artifacts to {outdir} "
          f"in {time.time() - t0:.0f}s", flush=True)


if __name__ == "__main__":
    main()
