"""Shared configuration for the DataMUX stack.

Everything that must agree between the python compile path (L1/L2) and the
rust request path (L3) lives here: special-token ids, sequence layout, and
the model-size profiles used by artifacts and experiments.

The rust mirror of the vocabulary layout is rust/src/tokenizer/mod.rs —
keep the two in sync (tests on both sides pin the constants).
"""
from dataclasses import dataclass, field, asdict
from typing import Optional

# ---------------------------------------------------------------------------
# Vocabulary layout (mirrored in rust/src/tokenizer).
#
#   0            [PAD]    sequence padding
#   1            [CLS]    sentence-classification anchor
#   2            [SEP]    pair separator
#   3            [EPS]    prefix pad token  (paper's epsilon^pad)
#   4 .. 4+39    [IDX_i]  prefix index tokens (paper's epsilon^i), i < 40
#   44 ..        t0, t1, ...  content tokens
# ---------------------------------------------------------------------------
PAD_ID = 0
CLS_ID = 1
SEP_ID = 2
EPS_PAD_ID = 3
IDX_BASE = 4
MAX_MUX = 40          # largest N supported by the vocab layout (paper's max)
CONTENT_BASE = IDX_BASE + MAX_MUX  # == 44


def idx_token(i: int) -> int:
    """Prefix index token epsilon^i."""
    assert 0 <= i < MAX_MUX
    return IDX_BASE + i


@dataclass
class ModelConfig:
    """T-MUX transformer configuration (L2).

    ``seq_len`` is the *content* length (including [CLS]/[SEP]); the model
    input length is ``n_mux + seq_len`` because an N-token prefix is
    prepended for index-embedding demultiplexing (paper §3.2).
    """
    vocab_size: int = 256 + CONTENT_BASE
    d_model: int = 128
    n_layers: int = 2
    n_heads: int = 4
    d_ff: int = 256
    seq_len: int = 16
    n_mux: int = 1                     # N — number of multiplexed instances
    mux_strategy: str = "hadamard"     # hadamard | ortho | binary | learned_hadamard | identity
    demux_strategy: str = "index_embed"  # index_embed | mlp
    task: str = "cls"                  # cls | token | retrieval
    n_classes: int = 3
    use_pallas: bool = False           # pallas kernels (AOT path) vs jnp ref (train path)
    dropout: float = 0.0               # kept 0; paper does not rely on dropout

    @property
    def prefix_len(self) -> int:
        # Index-embedding demux requires the N-token prefix; other demux
        # strategies do not consume prefix positions, but we keep the input
        # layout identical across strategies so artifacts are interchangeable.
        return self.n_mux if self.demux_strategy == "index_embed" else 0

    @property
    def input_len(self) -> int:
        return self.prefix_len + self.seq_len

    @property
    def d_head(self) -> int:
        assert self.d_model % self.n_heads == 0
        return self.d_model // self.n_heads

    def to_dict(self):
        return asdict(self)


@dataclass
class ImageModelConfig:
    """MLP / CNN image-model configuration (paper §5, Figs 7/11).

    Images are 20x20 crops (paper A.10); MLP flattens to 400, CNN keeps 2D.
    """
    arch: str = "mlp"                # mlp | cnn
    image_hw: int = 20
    n_mux: int = 1
    mux_strategy: str = "ortho"      # identity | ortho | lowrank | rotation
                                     # | random_kernel | learned_kernel | nonlinear
    mux_width: int = 1               # activation-map multiplier for nonlinear (1|4|8)
    hidden: int = 100                # MLP hidden width
    cnn_hidden: int = 84             # CNN penultimate width
    n_classes: int = 10

    @property
    def d_input(self) -> int:
        return self.image_hw * self.image_hw

    def to_dict(self):
        return asdict(self)


# ---------------------------------------------------------------------------
# Size profiles. "base"/"small_*" are the throughput-bench backbones
# (scaled stand-ins for the paper's 12L/768H, 12L/384H, 4L/768H — see
# DESIGN.md §Hardware-Adaptation); "tiny" is the accuracy-experiment model.
# ---------------------------------------------------------------------------
PROFILES = {
    "tiny":       dict(d_model=128, n_layers=2, n_heads=4, d_ff=256),
    "base":       dict(d_model=256, n_layers=4, n_heads=8, d_ff=1024),
    "small_wide": dict(d_model=256, n_layers=2, n_heads=8, d_ff=1024),
    "small_deep": dict(d_model=128, n_layers=4, n_heads=4, d_ff=512),
}


def profile(name: str, **overrides) -> ModelConfig:
    cfg = ModelConfig(**{**PROFILES[name], **overrides})
    return cfg
