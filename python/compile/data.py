"""Synthetic task family standing in for the paper's datasets.

The environment has no network access, so GLUE / CoNLL / Wikitext / MNIST
are replaced by procedurally generated tasks with the same *type
signatures* and a matched difficulty ordering (DESIGN.md §Substitutions):

  sst2-syn  binary sentence cls, unigram-decidable            (easy)
  qqp-syn   binary pair cls, bag-of-words comparable          (easy)
  qnli-syn  binary pair cls, needs one lookup                 (medium)
  mnli-syn  3-class pair cls, needs subset/antonym reasoning  (hard)
  ner-syn   5-tag token cls, local-context rules              (hard, token-level)
  retrieval zipfian token stream (warm-up corpus)             (wikitext stand-in)
  digits    procedural 20x20 digit glyphs                     (MNIST stand-in)

All generators are deterministic in their seed, emit *content token ids*
(>= config.CONTENT_BASE) of exactly ``seq_len`` positions laid out as
``[CLS] tokens... [SEP] ... [PAD]...``, and expose the same text form the
rust tokenizer produces (`t{k}` words) so the serving path does real
tokenization work.
"""
from dataclasses import dataclass

import numpy as np

from . import config as C

# content vocabulary: token ids CONTENT_BASE .. CONTENT_BASE+V-1
V_CONTENT = 256

# sentiment lexicon for sst2-syn
_POS = np.arange(0, 24)            # content-relative ids
_NEG = np.arange(24, 48)
_NEUTRAL = np.arange(48, V_CONTENT)

# qnli-syn: question tokens ids 0..31 map to answer tokens 32..63
_N_Q = 32

# mnli-syn: antonym pairs (2k, 2k+1) among ids 64..127
_ANTO_BASE = 64

# ner-syn: trigger/entity structure
_TRIG_PER, _TRIG_LOC = 0, 1        # trigger tokens (content-relative)
_ENTITY = np.arange(8, 72)         # entity-capable tokens
NER_TAGS = ["O", "B-PER", "I-PER", "B-LOC", "I-LOC"]


def ct(rel):
    """content-relative id -> absolute vocab id"""
    return np.asarray(rel) + C.CONTENT_BASE


@dataclass
class Batchset:
    """A generated dataset: fixed-length id rows + labels.

    ids:    (n, seq_len) int32, already [CLS] ... [SEP]-framed and padded
    labels: (n,) int32 for sentence tasks, (n, seq_len) for token tasks
    """
    ids: np.ndarray
    labels: np.ndarray
    n_classes: int
    token_level: bool = False


def _frame(rng, parts, seq_len):
    """[CLS] p0... [SEP] p1... [SEP]... -> pad/truncate to seq_len."""
    row = [C.CLS_ID]
    for p in parts:
        row.extend(int(t) for t in p)
        row.append(C.SEP_ID)
    row = row[:seq_len]
    row += [C.PAD_ID] * (seq_len - len(row))
    return row


def _zipf_tokens(rng, n, vocab=V_CONTENT, a=1.3):
    """Zipfian content tokens (wikitext-ish marginal distribution)."""
    z = rng.zipf(a, size=n * 4)
    z = z[z <= vocab][:n]
    while len(z) < n:
        more = rng.zipf(a, size=n * 4)
        more = more[more <= vocab]
        z = np.concatenate([z, more])[:n]
    return ct(z - 1)


# ---------------------------------------------------------------------------
# retrieval warm-up stream (wikitext-103 stand-in)
# ---------------------------------------------------------------------------

def make_retrieval(seed, n, seq_len):
    rng = np.random.RandomState(seed)
    body = seq_len - 1
    ids = np.empty((n, seq_len), np.int32)
    for i in range(n):
        ids[i] = _frame(rng, [_zipf_tokens(rng, body - 1)], seq_len)
    # labels are the inputs themselves; trainer reads ids directly
    return Batchset(ids=ids, labels=ids.copy(), n_classes=0)


# ---------------------------------------------------------------------------
# sentence-classification tasks
# ---------------------------------------------------------------------------

def make_sst2(seed, n, seq_len):
    """Binary sentiment: label = which lexicon dominates (unigram task)."""
    rng = np.random.RandomState(seed)
    ids = np.empty((n, seq_len), np.int32)
    labels = np.empty((n,), np.int32)
    body = seq_len - 2
    for i in range(n):
        y = rng.randint(2)
        lex = _POS if y == 1 else _NEG
        n_sent = rng.randint(2, max(3, body // 2))
        sent = rng.choice(lex, n_sent)
        fill = rng.choice(_NEUTRAL, body - n_sent)
        toks = np.concatenate([sent, fill])
        rng.shuffle(toks)
        ids[i] = _frame(rng, [ct(toks)], seq_len)
        labels[i] = y
    return Batchset(ids, labels, 2)


def make_qqp(seed, n, seq_len):
    """Paraphrase detection: s2 is a shuffled copy of s1 (y=1) or an
    independently sampled sentence with some overlap (y=0)."""
    rng = np.random.RandomState(seed)
    ids = np.empty((n, seq_len), np.int32)
    labels = np.empty((n,), np.int32)
    half = (seq_len - 3) // 2
    for i in range(n):
        y = rng.randint(2)
        s1 = rng.choice(V_CONTENT, half)
        if y == 1:
            s2 = s1.copy()
            rng.shuffle(s2)
        else:
            s2 = rng.choice(V_CONTENT, half)
            keep = rng.randint(0, half // 2 + 1)   # partial overlap distractor
            s2[:keep] = s1[:keep]
        ids[i] = _frame(rng, [ct(s1), ct(s2)], seq_len)
        labels[i] = y
    return Batchset(ids, labels, 2)


def make_qnli(seed, n, seq_len):
    """Answerability: question token q (in s2) has a fixed answer token
    a(q) = q + 32; y=1 iff a(q) occurs in the context s1."""
    rng = np.random.RandomState(seed)
    ids = np.empty((n, seq_len), np.int32)
    labels = np.empty((n,), np.int32)
    ctx_len = seq_len - 5
    for i in range(n):
        y = rng.randint(2)
        q = rng.randint(_N_Q)
        ans = q + _N_Q
        ctx = rng.choice(_NEUTRAL, ctx_len)
        if y == 1:
            ctx[rng.randint(ctx_len)] = ans
        else:
            ctx = np.where(ctx == ans, ans + 1, ctx)  # scrub accidental answers
        ids[i] = _frame(rng, [ct(ctx), ct([q])], seq_len)
        labels[i] = y
    return Batchset(ids, labels, 2)


def make_mnli(seed, n, seq_len):
    """3-class inference. premise p, hypothesis h:
       entail (0):    h tokens are a subsequence of p
       contradict(2): h contains the antonym partner of a p token
       neutral (1):   h tokens disjoint from p and its antonyms
    """
    rng = np.random.RandomState(seed)
    ids = np.empty((n, seq_len), np.int32)
    labels = np.empty((n,), np.int32)
    p_len = (seq_len - 3) * 2 // 3
    h_len = (seq_len - 3) - p_len
    n_pairs = (V_CONTENT - _ANTO_BASE) // 2
    for i in range(n):
        y = rng.randint(3)
        # premise drawn from antonym-pair region so contradictions exist
        pair_idx = rng.choice(n_pairs, p_len, replace=False)
        side = rng.randint(0, 2, p_len)
        prem = _ANTO_BASE + 2 * pair_idx + side
        if y == 0:      # entail: subsequence of premise
            take = np.sort(rng.choice(p_len, min(h_len, p_len), replace=False))
            hyp = prem[take][:h_len]
            if len(hyp) < h_len:
                hyp = np.concatenate([hyp, rng.choice(_NEUTRAL, h_len - len(hyp))])
        elif y == 2:    # contradict: flip one premise token to its antonym
            j = rng.randint(p_len)
            anto = _ANTO_BASE + 2 * pair_idx[j] + (1 - side[j])
            hyp = rng.choice(_NEUTRAL, h_len)
            hyp[rng.randint(h_len)] = anto
        else:           # neutral: tokens from pairs not in the premise
            unused = np.setdiff1d(np.arange(n_pairs), pair_idx)
            pick = rng.choice(unused, h_len)
            hyp = _ANTO_BASE + 2 * pick + rng.randint(0, 2, h_len)
        ids[i] = _frame(rng, [ct(prem), ct(hyp)], seq_len)
        labels[i] = y
    return Batchset(ids, labels, 3)


# ---------------------------------------------------------------------------
# token-level task (CoNLL NER stand-in)
# ---------------------------------------------------------------------------

def make_ner(seed, n, seq_len):
    """Tags decided by local context: an entity-capable token is PER/LOC if
    (and only if) preceded by the corresponding trigger; entities may span
    two tokens (B-/I- structure). Everything else is O."""
    rng = np.random.RandomState(seed)
    ids = np.empty((n, seq_len), np.int32)
    labels = np.zeros((n, seq_len), np.int32)
    body = seq_len - 2
    for i in range(n):
        toks = rng.choice(_NEUTRAL, body).astype(np.int64)
        tags = np.zeros(body, np.int64)
        n_ent = rng.randint(1, 4)
        pos = 0
        for _ in range(n_ent):
            start = rng.randint(pos, max(pos + 1, body - 4))
            if start + 2 >= body:
                break
            kind = rng.randint(2)                 # 0=PER 1=LOC
            span = rng.randint(1, 3)
            toks[start] = _TRIG_PER if kind == 0 else _TRIG_LOC
            tags[start] = 0
            for s in range(span):
                if start + 1 + s >= body:
                    break
                toks[start + 1 + s] = rng.choice(_ENTITY)
                tags[start + 1 + s] = (1 + 2 * kind) if s == 0 else (2 + 2 * kind)
            pos = start + span + 2
        row = _frame(rng, [ct(toks)], seq_len)
        ids[i] = row
        # align tags with frame: [CLS] toks... [SEP]; CLS/SEP/PAD tagged O
        labels[i, 1:1 + body] = tags
    return Batchset(ids, labels, len(NER_TAGS), token_level=True)


# ---------------------------------------------------------------------------
# image task (MNIST stand-in): procedural 20x20 digit glyphs
# ---------------------------------------------------------------------------

# 7-segment style geometry on a 20x20 canvas, with per-sample jitter/noise.
_SEGS = {           # (row0, col0, row1, col1) in a 0..1 unit box
    "top":    (0.08, 0.2, 0.08, 0.8),
    "mid":    (0.5, 0.2, 0.5, 0.8),
    "bot":    (0.9, 0.2, 0.9, 0.8),
    "tl":     (0.08, 0.2, 0.5, 0.2),
    "tr":     (0.08, 0.8, 0.5, 0.8),
    "bl":     (0.5, 0.2, 0.9, 0.2),
    "br":     (0.5, 0.8, 0.9, 0.8),
}
_DIGIT_SEGS = {
    0: ["top", "bot", "tl", "tr", "bl", "br"],
    1: ["tr", "br"],
    2: ["top", "mid", "bot", "tr", "bl"],
    3: ["top", "mid", "bot", "tr", "br"],
    4: ["mid", "tl", "tr", "br"],
    5: ["top", "mid", "bot", "tl", "br"],
    6: ["top", "mid", "bot", "tl", "bl", "br"],
    7: ["top", "tr", "br"],
    8: ["top", "mid", "bot", "tl", "tr", "bl", "br"],
    9: ["top", "mid", "bot", "tl", "tr", "br"],
}


def _draw_seg(img, seg, hw, thick=1.6):
    r0, c0, r1, c1 = seg
    n = 64
    rr = np.linspace(r0, r1, n) * (hw - 1)
    cc = np.linspace(c0, c1, n) * (hw - 1)
    ys, xs = np.mgrid[0:hw, 0:hw]
    for r, c in zip(rr[::4], cc[::4]):
        img += np.exp(-(((ys - r) ** 2 + (xs - c) ** 2) / (2 * (thick / 2) ** 2)))
    return img


_GLYPH_CACHE = {}


def _glyph(digit, hw):
    key = (digit, hw)
    if key not in _GLYPH_CACHE:
        img = np.zeros((hw, hw))
        for name in _DIGIT_SEGS[digit]:
            img = _draw_seg(img, _SEGS[name], hw)
        _GLYPH_CACHE[key] = np.clip(img, 0, 1)
    return _GLYPH_CACHE[key]


def make_digits(seed, n, hw=20, noise=0.15, max_shift=2):
    """(n, hw, hw) float32 in [0,1] + (n,) labels. Shift-jittered, noisy
    seven-segment glyphs; by construction low-rank like MNIST's top-50 PCs."""
    rng = np.random.RandomState(seed)
    xs = np.empty((n, hw, hw), np.float32)
    ys = rng.randint(0, 10, n).astype(np.int32)
    for i in range(n):
        g = _glyph(int(ys[i]), hw)
        dy, dx = rng.randint(-max_shift, max_shift + 1, 2)
        img = np.roll(np.roll(g, dy, axis=0), dx, axis=1)
        img = img * rng.uniform(0.8, 1.2) + rng.randn(hw, hw) * noise
        xs[i] = np.clip(img, 0, 1)
    return xs, ys


# ---------------------------------------------------------------------------
# registry + text form (for the rust serving path)
# ---------------------------------------------------------------------------

TASKS = {
    "sst2": make_sst2,
    "qqp": make_qqp,
    "qnli": make_qnli,
    "mnli": make_mnli,
    "ner": make_ner,
}

TASK_CLASSES = {"sst2": 2, "qqp": 2, "qnli": 2, "mnli": 3, "ner": len(NER_TAGS)}
TASK_TOKEN_LEVEL = {"sst2": False, "qqp": False, "qnli": False, "mnli": False, "ner": True}


def ids_to_text(row) -> str:
    """Mirror of the rust tokenizer's detokenizer: content ids -> t{k},
    specials -> bracketed names. Used to exercise the rust tokenize path."""
    words = []
    for t in row:
        t = int(t)
        if t == C.PAD_ID:
            continue
        if t == C.CLS_ID:
            words.append("[CLS]")
        elif t == C.SEP_ID:
            words.append("[SEP]")
        elif t == C.EPS_PAD_ID:
            words.append("[EPS]")
        elif C.IDX_BASE <= t < C.CONTENT_BASE:
            words.append(f"[IDX{t - C.IDX_BASE}]")
        else:
            words.append(f"t{t - C.CONTENT_BASE}")
    return " ".join(words)
