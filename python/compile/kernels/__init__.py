"""L1 Pallas kernels + pure-jnp reference oracles.

``mux`` / ``demux`` / ``attention`` are the interpret-mode Pallas kernels
used by the AOT artifact path; ``ref`` holds the jnp oracles used by the
training path and by the pytest equivalence sweeps.
"""
from . import attention, demux, mux, ref  # noqa: F401
