"""Pallas multi-head self-attention kernel (L1).

The backbone hot-spot: softmax(QK^T / sqrt(dh)) V, computed per
(batch, head) grid step with the full (L, L) score tile resident in VMEM.
Sequence lengths in this system are small (input_len = N + seq_len <= 104
even at N=40), so a flash-style streaming softmax is unnecessary: at
L=104, the score tile is 104*104*4 ≈ 43 KiB and q/k/v slabs are
3*104*64*4 ≈ 80 KiB — the whole step fits in VMEM with >100x headroom,
and the two MXU matmuls dominate.

Numerically-stable softmax (max-subtraction) matches kernels/ref.py
bit-for-bit under f32 (test_kernels.py pins allclose at 1e-5).

interpret=True — see package docstring.
"""
import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _mha_kernel(q_ref, k_ref, v_ref, o_ref):
    # q/k/v_ref: (1, 1, L, dh)  o_ref: (1, 1, L, dh)
    q = q_ref[0, 0]
    k = k_ref[0, 0]
    v = v_ref[0, 0]
    dh = q.shape[-1]
    scores = jax.lax.dot_general(            # (L, L) MXU matmul
        q, k,
        dimension_numbers=(((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32,
    ) / jnp.sqrt(jnp.asarray(dh, jnp.float32))
    m = scores.max(axis=-1, keepdims=True)
    e = jnp.exp(scores - m)
    probs = e / e.sum(axis=-1, keepdims=True)
    out = jax.lax.dot_general(               # (L, dh)
        probs.astype(v.dtype), v,
        dimension_numbers=(((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32,
    )
    o_ref[0, 0] = out.astype(o_ref.dtype)


def mha_attention(q: jax.Array, k: jax.Array, v: jax.Array) -> jax.Array:
    """Batched multi-head attention. q/k/v: (B, H, L, dh) -> (B, H, L, dh)."""
    B, H, L, dh = q.shape
    grid = (B, H)
    spec = pl.BlockSpec((1, 1, L, dh), lambda b, h: (b, h, 0, 0))
    return pl.pallas_call(
        _mha_kernel,
        grid=grid,
        in_specs=[spec, spec, spec],
        out_specs=spec,
        out_shape=jax.ShapeDtypeStruct((B, H, L, dh), q.dtype),
        interpret=True,
    )(q, k, v)
