"""Pallas demultiplexing kernels (L1).

Two strategies from paper §3.2:

  - index_embed: h^i_j = MLP_shared([h_j ; p_i]) where p_i is the hidden
    state of the i-th prefix token. The concat is algebraically split into
    two matmul halves (W1 [h;p] = W1h h + W1p p) so the kernel never
    materializes the concatenated (L, 2d) tensor — the p_i half is computed
    once per index and broadcast over positions. This fusion is the L1 perf
    win recorded in EXPERIMENTS.md §Perf.

  - mlp: N independent 2-layer MLPs over the same combined hidden state
    (adds parameters proportional to N; unstable per paper A.6 but needed
    for the Fig 4b / Fig 9 reproductions).

TPU mapping: grid = (batch, index); each step holds the (L, d) hidden slab,
one (d,) index embedding, and the shared (d,f)/(f,d) weights in VMEM and
issues two MXU matmuls with a GELU between. For d=256, f=1024, L=72:
weights 2*256*1024*4 ≈ 2 MiB, activations < 0.5 MiB — comfortably VMEM
resident, so each (b, i) step is a single fused pipeline stage.

interpret=True everywhere; oracles in kernels/ref.py.
"""
import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _gelu(x):
    return jax.nn.gelu(x)


# ---------------------------------------------------------------------------
# index-embedding demux (shared MLP conditioned on prefix hidden state)
# ---------------------------------------------------------------------------

def _demux_index_kernel(h_ref, p_ref, w1h_ref, w1p_ref, b1_ref, w2_ref, b2_ref, o_ref):
    # h_ref: (1, L, d)  p_ref: (1, 1, d)  o_ref: (1, 1, L, d)
    h = h_ref[0]                                  # (L, d)
    p = p_ref[0, 0]                               # (d,)
    hh = jax.lax.dot_general(                     # (L, f) MXU matmul
        h, w1h_ref[...],
        dimension_numbers=(((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32,
    )
    ph = p @ w1p_ref[...]                         # (f,) — once per index
    z = _gelu(hh + ph[None, :] + b1_ref[...][None, :])
    out = jax.lax.dot_general(                    # (L, d)
        z, w2_ref[...],
        dimension_numbers=(((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32,
    ) + b2_ref[...][None, :]
    o_ref[0, 0] = out.astype(o_ref.dtype)


def demux_index_mlp(h: jax.Array, p: jax.Array, w1h, w1p, b1, w2, b2) -> jax.Array:
    """Batched index-embedding demux.

    h: (B, L, d) combined hidden states
    p: (B, N, d) per-index embeddings (prefix hidden states)
    w1h: (d, f), w1p: (d, f), b1: (f,), w2: (f, d), b2: (d,)
    returns: (B, N, L, d)
    """
    B, L, d = h.shape
    N = p.shape[1]
    f = w1h.shape[1]
    grid = (B, N)
    return pl.pallas_call(
        _demux_index_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, L, d), lambda b, i: (b, 0, 0)),
            pl.BlockSpec((1, 1, d), lambda b, i: (b, i, 0)),
            pl.BlockSpec((d, f), lambda b, i: (0, 0)),
            pl.BlockSpec((d, f), lambda b, i: (0, 0)),
            pl.BlockSpec((f,), lambda b, i: (0,)),
            pl.BlockSpec((f, d), lambda b, i: (0, 0)),
            pl.BlockSpec((d,), lambda b, i: (0,)),
        ],
        out_specs=pl.BlockSpec((1, 1, L, d), lambda b, i: (b, i, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((B, N, L, d), h.dtype),
        interpret=True,
    )(h, p, w1h, w1p, b1, w2, b2)


# ---------------------------------------------------------------------------
# per-index MLP demux (N independent MLPs)
# ---------------------------------------------------------------------------

def _demux_mlp_kernel(h_ref, w1_ref, b1_ref, w2_ref, b2_ref, o_ref):
    # h_ref: (1, L, d)  w1_ref: (1, d, f)  w2_ref: (1, f, d)  o_ref: (1, 1, L, d)
    h = h_ref[0]
    z = _gelu(
        jax.lax.dot_general(
            h, w1_ref[0],
            dimension_numbers=(((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32,
        )
        + b1_ref[0][None, :]
    )
    out = jax.lax.dot_general(
        z, w2_ref[0],
        dimension_numbers=(((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32,
    ) + b2_ref[0][None, :]
    o_ref[0, 0] = out.astype(o_ref.dtype)


def demux_mlp(h: jax.Array, w1, b1, w2, b2) -> jax.Array:
    """Batched per-index MLP demux.

    h: (B, L, d); w1: (N, d, f), b1: (N, f), w2: (N, f, d), b2: (N, d)
    returns: (B, N, L, d)
    """
    B, L, d = h.shape
    N, _, f = w1.shape
    grid = (B, N)
    return pl.pallas_call(
        _demux_mlp_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, L, d), lambda b, i: (b, 0, 0)),
            pl.BlockSpec((1, d, f), lambda b, i: (i, 0, 0)),
            pl.BlockSpec((1, f), lambda b, i: (i, 0)),
            pl.BlockSpec((1, f, d), lambda b, i: (i, 0, 0)),
            pl.BlockSpec((1, d), lambda b, i: (i, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, L, d), lambda b, i: (b, i, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((B, N, L, d), h.dtype),
        interpret=True,
    )(h, w1, b1, w2, b2)
