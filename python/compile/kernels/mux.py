"""Pallas multiplexing kernels (L1).

The multiplexer (paper eq. 1) computes  x^{1:N} = (1/N) sum_i phi^i(x^i)
tokenwise. Three transform families are implemented:

  - hadamard: phi^i(x) = x * v_i          (fixed Gaussian vector, diag map)
  - ortho:    phi^i(x) = W_i x            (fixed random orthogonal matrix)
  - binary:   phi^i(x) = x * m_i          (0/1 chunk-select mask, paper A.5)

TPU mapping (DESIGN.md §Hardware-Adaptation): the grid tiles (batch,
token-block); each step keeps an (N, L_BLK, d) slab of embeddings plus the
(N, d) / (N, d, d) transform resident in VMEM and writes one (L_BLK, d)
output tile. For hadamard the inner op is a VPU elementwise multiply-
accumulate; for ortho it is N (L_BLK, d)x(d, d) MXU matmuls accumulated in
f32. L_BLK is chosen so the slab stays within the VMEM budget:
N*L_BLK*d*4 + N*d*d*4 + L_BLK*d*4 bytes <= ~12 MiB.

Kernels are lowered with ``interpret=True`` (CPU PJRT cannot execute Mosaic
custom-calls); numerics are pinned to kernels/ref.py by
python/tests/test_kernels.py.
"""
import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# Token-block size. 8-sublane aligned; at N=40, d=256:
# 40*16*256*4 B (slab) + 40*256*4 B (vecs) + 16*256*4 B (out) ≈ 0.7 MiB VMEM.
L_BLK = 16


def _pick_lblk(L: int) -> int:
    # largest divisor of L that is <= L_BLK keeps the BlockSpec exact
    for cand in (L_BLK, 8, 4, 2, 1):
        if L % cand == 0:
            return cand
    return 1


# ---------------------------------------------------------------------------
# hadamard / binary (both are elementwise-vector transforms)
# ---------------------------------------------------------------------------

def _mux_vec_kernel(xs_ref, vec_ref, o_ref, *, n_mux: int):
    # xs_ref: (1, N, L_BLK, d)  vec_ref: (N, d)  o_ref: (1, L_BLK, d)
    xs = xs_ref[0]                       # (N, L_BLK, d)
    v = vec_ref[...]                     # (N, d)
    acc = (xs * v[:, None, :]).sum(axis=0) * (1.0 / n_mux)
    o_ref[0] = acc.astype(o_ref.dtype)


def mux_hadamard(xs: jax.Array, vecs: jax.Array) -> jax.Array:
    """Batched Hadamard mux. xs: (B, N, L, d), vecs: (N, d) -> (B, L, d)."""
    B, N, L, d = xs.shape
    lblk = _pick_lblk(L)
    grid = (B, L // lblk)
    return pl.pallas_call(
        functools.partial(_mux_vec_kernel, n_mux=N),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, N, lblk, d), lambda b, l: (b, 0, l, 0)),
            pl.BlockSpec((N, d), lambda b, l: (0, 0)),
        ],
        out_specs=pl.BlockSpec((1, lblk, d), lambda b, l: (b, l, 0)),
        out_shape=jax.ShapeDtypeStruct((B, L, d), xs.dtype),
        interpret=True,
    )(xs, vecs)


# Binary masks are numerically identical machinery to hadamard.
mux_binary = mux_hadamard


# ---------------------------------------------------------------------------
# ortho (dense per-index linear transform)
# ---------------------------------------------------------------------------

def _mux_ortho_kernel(xs_ref, mat_ref, o_ref, *, n_mux: int):
    # xs_ref: (1, N, L_BLK, d)  mat_ref: (N, d, d)  o_ref: (1, L_BLK, d)
    xs = xs_ref[0]
    m = mat_ref[...]
    # N MXU matmuls accumulated in f32: out = (1/N) sum_i xs[i] @ m[i]
    acc = jax.lax.dot_general(
        xs, m,
        dimension_numbers=(((2,), (1,)), ((0,), (0,))),
        preferred_element_type=jnp.float32,
    ).sum(axis=0) * (1.0 / n_mux)
    o_ref[0] = acc.astype(o_ref.dtype)


def mux_ortho(xs: jax.Array, mats: jax.Array) -> jax.Array:
    """Batched orthogonal mux. xs: (B, N, L, d), mats: (N, d, d) -> (B, L, d)."""
    B, N, L, d = xs.shape
    lblk = _pick_lblk(L)
    grid = (B, L // lblk)
    return pl.pallas_call(
        functools.partial(_mux_ortho_kernel, n_mux=N),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, N, lblk, d), lambda b, l: (b, 0, l, 0)),
            pl.BlockSpec((N, d, d), lambda b, l: (0, 0, 0)),
        ],
        out_specs=pl.BlockSpec((1, lblk, d), lambda b, l: (b, l, 0)),
        out_shape=jax.ShapeDtypeStruct((B, L, d), xs.dtype),
        interpret=True,
    )(xs, mats)
