"""Pure-jnp reference oracles for the Pallas kernels (L1).

Every kernel in this package has an exact functional twin here; pytest
asserts allclose between the two across shape/dtype/N sweeps
(python/tests/test_kernels.py). The training path (L2) also uses these
reference implementations directly — interpret-mode Pallas is functionally
identical but slower to trace, so we reserve the Pallas path for the AOT
artifacts and verify equality in tests.

Shapes follow the paper's notation: N = number of multiplexed instances,
L = sequence length, d = model width.
"""
import jax
import jax.numpy as jnp


# ---------------------------------------------------------------------------
# Multiplexing  (paper eq. 1):  x^{1:N} = (1/N) sum_i phi^i(x^i)
# ---------------------------------------------------------------------------

def mux_hadamard(xs: jax.Array, vecs: jax.Array) -> jax.Array:
    """Hadamard multiplexing: phi^i(x) = x * v_i (elementwise).

    xs: (N, L, d) stacked per-instance embeddings
    vecs: (N, d) fixed Gaussian vectors
    returns: (L, d) combined representation
    """
    return jnp.mean(xs * vecs[:, None, :], axis=0)


def mux_ortho(xs: jax.Array, mats: jax.Array) -> jax.Array:
    """Orthogonal multiplexing: phi^i(x) = W_i x for orthogonal W_i.

    xs: (N, L, d), mats: (N, d, d) -> (L, d)
    """
    # out[l, e] = mean_i sum_d xs[i, l, d] mats[i, d, e]
    return jnp.mean(jnp.einsum("nld,nde->nle", xs, mats), axis=0)


def mux_binary(xs: jax.Array, masks: jax.Array) -> jax.Array:
    """Binary-mask multiplexing (paper A.5): mask_i selects the i-th d/N
    chunk. Equivalent to Hadamard with 0/1 vectors; masks: (N, d)."""
    return jnp.mean(xs * masks[:, None, :], axis=0)


def demux_index_mlp(h: jax.Array, p: jax.Array, w1h, w1p, b1, w2, b2) -> jax.Array:
    """Index-embedding demultiplexing (paper §3.2, strategy 2).

    h^i_j = MLP_shared([h_j ; p_i]); the concat is folded into two matmul
    halves: W1 [h;p] = W1h h + W1p p.

    h: (L, d) combined hidden states
    p: (N, d) index embeddings (hidden states at the prefix positions)
    w1h: (d, f), w1p: (d, f), b1: (f,), w2: (f, d), b2: (d,)
    returns: (N, L, d) demultiplexed hidden states
    """
    ph = p @ w1p                                             # (N, f)
    hh = h @ w1h                                             # (L, f)
    z = jax.nn.gelu(hh[None, :, :] + ph[:, None, :] + b1)    # (N, L, f)
    return z @ w2 + b2                                       # (N, L, d)


def demux_mlp(h: jax.Array, w1, b1, w2, b2) -> jax.Array:
    """Per-index MLP demultiplexing (paper §3.2, strategy 1).

    N independent 2-layer MLPs applied to the same combined hidden state.

    h: (L, d); w1: (N, d, f), b1: (N, f), w2: (N, f, d), b2: (N, d)
    returns: (N, L, d)
    """
    z = jax.nn.gelu(jnp.einsum("ld,ndf->nlf", h, w1) + b1[:, None, :])
    return jnp.einsum("nlf,nfd->nld", z, w2) + b2[:, None, :]


# ---------------------------------------------------------------------------
# Multi-head self-attention (the backbone hot-spot)
# ---------------------------------------------------------------------------

def mha_attention(q: jax.Array, k: jax.Array, v: jax.Array,
                  mask: jax.Array | None = None) -> jax.Array:
    """Scaled dot-product attention per head.

    q, k, v: (H, L, dh); mask: optional (L, L) additive mask.
    returns: (H, L, dh)
    """
    dh = q.shape[-1]
    scores = jnp.einsum("hld,hmd->hlm", q, k) / jnp.sqrt(jnp.asarray(dh, q.dtype))
    if mask is not None:
        scores = scores + mask
    probs = jax.nn.softmax(scores, axis=-1)
    return jnp.einsum("hlm,hmd->hld", probs, v)
