"""L2: the T-MUX model (paper §3/§4) plus the MLP/CNN image models (§5).

Pure-jax (no flax in this image): parameters are nested dicts, forward
functions are pure. Two execution paths share one parameterization:

  - ``use_pallas=False`` — jnp reference ops (kernels/ref.py); used for
    training (fast to trace on CPU).
  - ``use_pallas=True``  — interpret-mode Pallas kernels (kernels/*.py);
    used when lowering AOT inference artifacts so the shipped HLO runs
    through the L1 kernels.

test_model.py pins the two paths to identical outputs.

Input layout for T-MUX (must match rust/src/coordinator — see config.py):

    ids: (B, N, input_len) int32
    input_len = prefix_len + seq_len
    ids[b, i] = prefix^i ++ [CLS] content... [SEP] [PAD]...
    prefix^i  = [EPS]*i ++ [IDX_i] ++ [EPS]*(N-1-i)        (paper §3.2)
"""
import math
from functools import partial

import jax
import jax.numpy as jnp

from . import config as C
from .kernels import attention as kattn
from .kernels import demux as kdemux
from .kernels import mux as kmux
from .kernels import ref


# ---------------------------------------------------------------------------
# init helpers
# ---------------------------------------------------------------------------

def _dense(key, d_in, d_out, scale=None):
    scale = scale if scale is not None else (2.0 / (d_in + d_out)) ** 0.5
    return {
        "w": jax.random.normal(key, (d_in, d_out)) * scale,
        "b": jnp.zeros((d_out,)),
    }


def _layer_norm_params(d):
    return {"g": jnp.ones((d,)), "b": jnp.zeros((d,))}


def _random_orthogonal(key, d):
    a = jax.random.normal(key, (d, d))
    q, r = jnp.linalg.qr(a)
    # sign-fix for a haar-uniform orthogonal matrix
    return q * jnp.sign(jnp.diag(r))[None, :]


def init_mux_params(key, cfg: C.ModelConfig):
    """Fixed (or learned) multiplexing transforms phi^i.

    hadamard/binary: (N, d) vectors; ortho: (N, d, d) matrices.
    All are *frozen* except for the ``learned_hadamard`` strategy — the
    trainer masks updates via `trainable_mask` below.
    """
    N, d = cfg.n_mux, cfg.d_model
    s = cfg.mux_strategy
    if s in ("hadamard", "learned_hadamard"):
        return {"vecs": jax.random.normal(key, (N, d))}
    if s == "ortho":
        keys = jax.random.split(key, N)
        return {"mats": jnp.stack([_random_orthogonal(k, d) for k in keys])}
    if s == "binary":
        chunk = max(d // N, 1)
        m = jnp.zeros((N, d))
        for i in range(N):
            lo = (i * chunk) % d
            m = m.at[i, lo:lo + chunk].set(1.0)
        return {"vecs": m}
    if s == "identity":
        return {"vecs": jnp.ones((N, d))}
    raise ValueError(f"unknown mux strategy {s}")


def init_params(key, cfg: C.ModelConfig):
    """Full T-MUX parameter pytree."""
    keys = jax.random.split(key, 16 + cfg.n_layers)
    d, f = cfg.d_model, cfg.d_ff
    params = {
        "tok_emb": jax.random.normal(keys[0], (cfg.vocab_size, d)) * 0.02,
        "pos_emb": jax.random.normal(keys[1], (cfg.input_len, d)) * 0.02,
        "mux": init_mux_params(keys[2], cfg),
        "layers": [],
        "ln_f": _layer_norm_params(d),
    }
    for li in range(cfg.n_layers):
        k = jax.random.split(keys[3 + li], 8)
        params["layers"].append({
            "ln1": _layer_norm_params(d),
            "wq": _dense(k[0], d, d), "wk": _dense(k[1], d, d),
            "wv": _dense(k[2], d, d), "wo": _dense(k[3], d, d),
            "ln2": _layer_norm_params(d),
            "ff1": _dense(k[4], d, f), "ff2": _dense(k[5], f, d),
        })
    kd = jax.random.split(keys[15], 6)
    fd = 2 * d   # demux MLP hidden width
    if cfg.demux_strategy == "index_embed":
        params["demux"] = {
            "w1h": jax.random.normal(kd[0], (d, fd)) * (1.0 / math.sqrt(d)),
            "w1p": jax.random.normal(kd[1], (d, fd)) * (1.0 / math.sqrt(d)),
            "b1": jnp.zeros((fd,)),
            "w2": jax.random.normal(kd[2], (fd, d)) * (1.0 / math.sqrt(fd)),
            "b2": jnp.zeros((d,)),
        }
    elif cfg.demux_strategy == "mlp":
        params["demux"] = {
            "w1": jax.random.normal(kd[0], (cfg.n_mux, d, fd)) * (1.0 / math.sqrt(d)),
            "b1": jnp.zeros((cfg.n_mux, fd)),
            "w2": jax.random.normal(kd[1], (cfg.n_mux, fd, d)) * (1.0 / math.sqrt(fd)),
            "b2": jnp.zeros((cfg.n_mux, d)),
        }
    else:
        raise ValueError(f"unknown demux strategy {cfg.demux_strategy}")
    params["head_cls"] = _dense(kd[3], d, cfg.n_classes)
    params["head_token"] = _dense(kd[4], d, cfg.n_classes)
    params["head_retrieval"] = _dense(kd[5], d, cfg.vocab_size)
    return params


def trainable_mask(params, cfg: C.ModelConfig):
    """1/0 pytree: which leaves the optimizer may update.

    The mux transforms are fixed random (paper §3.1) except for the
    ``learned_hadamard`` ablation (paper A.5).
    """
    mask = jax.tree_util.tree_map(lambda _: 1.0, params)
    if cfg.mux_strategy != "learned_hadamard":
        mask["mux"] = jax.tree_util.tree_map(lambda _: 0.0, params["mux"])
    return mask


# ---------------------------------------------------------------------------
# forward
# ---------------------------------------------------------------------------

def _layer_norm(x, p, eps=1e-5):
    mu = x.mean(-1, keepdims=True)
    var = ((x - mu) ** 2).mean(-1, keepdims=True)
    return (x - mu) * jax.lax.rsqrt(var + eps) * p["g"] + p["b"]


def _apply_dense(x, p):
    return x @ p["w"] + p["b"]


def _mux(params, cfg: C.ModelConfig, emb):
    """emb: (B, N, Lin, d) -> (B, Lin, d)."""
    mp = params["mux"]
    if cfg.mux_strategy == "ortho":
        if cfg.use_pallas:
            return kmux.mux_ortho(emb, mp["mats"])
        return jax.vmap(lambda x: ref.mux_ortho(x, mp["mats"]))(emb)
    vecs = mp["vecs"]
    if cfg.use_pallas:
        return kmux.mux_hadamard(emb, vecs)
    return jax.vmap(lambda x: ref.mux_hadamard(x, vecs))(emb)


def _attention(cfg: C.ModelConfig, lp, x):
    """x: (B, L, d) -> (B, L, d) multi-head self-attention."""
    B, L, d = x.shape
    H, dh = cfg.n_heads, cfg.d_head

    def split(t):  # (B, L, d) -> (B, H, L, dh)
        return t.reshape(B, L, H, dh).transpose(0, 2, 1, 3)

    q = split(_apply_dense(x, lp["wq"]))
    k = split(_apply_dense(x, lp["wk"]))
    v = split(_apply_dense(x, lp["wv"]))
    if cfg.use_pallas:
        o = kattn.mha_attention(q, k, v)
    else:
        o = jax.vmap(ref.mha_attention)(q, k, v)
    o = o.transpose(0, 2, 1, 3).reshape(B, L, d)
    return _apply_dense(o, lp["wo"])


def _encoder(params, cfg: C.ModelConfig, x):
    """Pre-LN transformer encoder. x: (B, L, d)."""
    for lp in params["layers"]:
        x = x + _attention(cfg, lp, _layer_norm(x, lp["ln1"]))
        h = _apply_dense(jax.nn.gelu(_apply_dense(_layer_norm(x, lp["ln2"]), lp["ff1"])), lp["ff2"])
        x = x + h
    return _layer_norm(x, params["ln_f"])


def _demux(params, cfg: C.ModelConfig, h, demux_len=None):
    """h: (B, Lin, d) encoder output -> (B, N, L', d) per-instance states.

    ``demux_len`` restricts demultiplexing to the first L' content
    positions. The demux MLP is position-wise, so this changes cost, not
    values. Sentence-classification inference only needs the [CLS]
    position (demux_len=1) — demuxing all L positions costs O(N*L*d^2)
    per execution, which erases the multiplexing throughput win at large
    N (EXPERIMENTS.md §Perf, L2 optimization #1).
    """
    dp = params["demux"]
    P = cfg.prefix_len
    content = h[:, P:, :]                        # (B, L, d)
    if demux_len is not None:
        content = content[:, :demux_len, :]
    if cfg.demux_strategy == "index_embed":
        p = h[:, :cfg.n_mux, :]                  # (B, N, d) prefix hidden states
        if cfg.use_pallas:
            return kdemux.demux_index_mlp(content, p, dp["w1h"], dp["w1p"],
                                          dp["b1"], dp["w2"], dp["b2"])
        return jax.vmap(lambda hh, pp: ref.demux_index_mlp(
            hh, pp, dp["w1h"], dp["w1p"], dp["b1"], dp["w2"], dp["b2"]))(content, p)
    # per-index MLP demux
    if cfg.use_pallas:
        return kdemux.demux_mlp(content, dp["w1"], dp["b1"], dp["w2"], dp["b2"])
    return jax.vmap(lambda hh: ref.demux_mlp(
        hh, dp["w1"], dp["b1"], dp["w2"], dp["b2"]))(content)


def forward(params, cfg: C.ModelConfig, ids, demux_len=None):
    """Full T-MUX forward.

    ids: (B, N, input_len) int32 -> dict of per-task outputs:
      hidden:    (B, N, L', d)   demultiplexed hidden states
      cls:       (B, N, n_classes)    sentence-classification logits ([CLS])
      token:     (B, N, L', n_classes) token-classification logits
      retrieval: (B, N, L', vocab)     retrieval logits
    where L' = demux_len or seq_len (see _demux).
    """
    B, N, Lin = ids.shape
    assert N == cfg.n_mux and Lin == cfg.input_len, (ids.shape, cfg)
    emb = params["tok_emb"][ids] + params["pos_emb"][None, None, :, :]
    x = _mux(params, cfg, emb)                   # (B, Lin, d)
    h = _encoder(params, cfg, x)                 # (B, Lin, d)
    dem = _demux(params, cfg, h, demux_len)      # (B, N, L', d)
    out = {"hidden": dem}
    # heads may be pruned for AOT export (aot.prune_params): compute only
    # the ones present in the pytree
    if "head_cls" in params:
        out["cls"] = _apply_dense(dem[:, :, 0, :], params["head_cls"])
    if "head_token" in params:
        out["token"] = _apply_dense(dem, params["head_token"])
    if "head_retrieval" in params:
        out["retrieval"] = _apply_dense(dem, params["head_retrieval"])
    return out


def forward_task(params, cfg: C.ModelConfig, ids):
    """Inference entry point lowered by aot.py: returns only the logits the
    configured task needs (keeps artifacts small and XLA DCE effective).
    For sentence classification, only the [CLS] position is demultiplexed
    (identical logits, O(L) less demux work — §Perf L2 #1)."""
    out = forward(params, cfg, ids, demux_len=1 if cfg.task == "cls" else None)
    if cfg.task == "cls":
        return (out["cls"],)
    if cfg.task == "token":
        return (out["token"],)
    if cfg.task == "retrieval":
        return (out["retrieval"],)
    raise ValueError(cfg.task)


def build_prefix(n_mux: int) -> list[list[int]]:
    """prefix^i = [EPS]*i + [IDX_i] + [EPS]*(N-1-i) (paper §3.2)."""
    out = []
    for i in range(n_mux):
        row = [C.EPS_PAD_ID] * n_mux
        row[i] = C.idx_token(i)
        out.append(row)
    return out


def assemble_input(cfg: C.ModelConfig, content_ids) -> jnp.ndarray:
    """content_ids: (B, N, seq_len) -> (B, N, input_len) with prefixes."""
    content_ids = jnp.asarray(content_ids, jnp.int32)
    B, N, L = content_ids.shape
    assert N == cfg.n_mux and L == cfg.seq_len
    if cfg.prefix_len == 0:
        return content_ids
    pref = jnp.asarray(build_prefix(N), jnp.int32)          # (N, N)
    pref = jnp.broadcast_to(pref[None], (B, N, N))
    return jnp.concatenate([pref, content_ids], axis=2)


# ===========================================================================
# Image models (paper §5): MLP and CNN with mux variants
# ===========================================================================

def _conv(x, w, b, stride=1):
    """x: (B, H, W, Cin), w: (kh, kw, Cin, Cout)."""
    y = jax.lax.conv_general_dilated(
        x, w, window_strides=(stride, stride), padding="VALID",
        dimension_numbers=("NHWC", "HWIO", "NHWC"))
    return y + b


def _maxpool2(x):
    return jax.lax.reduce_window(x, -jnp.inf, jax.lax.max,
                                 (1, 2, 2, 1), (1, 2, 2, 1), "VALID")


def _rotation_matrix(d, theta):
    """Block-diagonal 2D rotations acting on pixel pairs — the SO(2)
    separation function of paper A.11, lifted to the flattened image."""
    c, s = math.cos(theta), math.sin(theta)
    m = jnp.eye(d)
    idx = jnp.arange(0, d - 1, 2)
    m = m.at[idx, idx].set(c)
    m = m.at[idx, idx + 1].set(-s)
    m = m.at[idx + 1, idx].set(s)
    m = m.at[idx + 1, idx + 1].set(c)
    return m


def init_image_mux(key, cfg: C.ImageModelConfig):
    N, d = cfg.n_mux, cfg.d_input
    s = cfg.mux_strategy
    if s == "identity":
        return {}
    if s == "ortho":
        keys = jax.random.split(key, N)
        return {"mats": jnp.stack([_random_orthogonal(k, d) for k in keys])}
    if s == "lowrank":
        # paper A.10: d random orthogonal rows split into N groups, then
        # rotated by another orthogonal matrix -> N rank-(d/N) transforms
        k1, k2 = jax.random.split(key)
        q = _random_orthogonal(k1, d)
        r = _random_orthogonal(k2, d)
        rank = d // N
        mats = []
        for i in range(N):
            rows = q[i * rank:(i + 1) * rank, :]            # (rank, d)
            mats.append(rows.T @ rows @ r)                  # (d, d) rank-deficient
        return {"mats": jnp.stack(mats)}
    if s == "rotation":
        return {"mats": jnp.stack([_rotation_matrix(d, 2 * math.pi * i / max(N, 1))
                                   for i in range(N)])}
    if s in ("random_kernel", "learned_kernel"):
        # slide a 3x3 kernel over each input image before summing (A.11)
        return {"kernels": jax.random.normal(key, (N, 3, 3, 1, 1))}
    if s == "nonlinear":
        # N small 2-layer convnets, 16 3x3 kernels, tanh (A.11); `mux_width`
        # is the activation-map multiplier for the 4x/8x variants
        k1, k2 = jax.random.split(key)
        return {
            "c1": jax.random.normal(k1, (N, 3, 3, 1, 16)) * 0.3,
            "b1": jnp.zeros((N, 16)),
            "c2": jax.random.normal(k2, (N, 3, 3, 16, cfg.mux_width)) * 0.3,
            "b2": jnp.zeros((N, cfg.mux_width)),
        }
    raise ValueError(s)


def image_mux_trainable(cfg: C.ImageModelConfig) -> bool:
    return cfg.mux_strategy in ("learned_kernel", "nonlinear")


def apply_image_mux(mux_params, cfg: C.ImageModelConfig, xs):
    """xs: (B, N, H, W) -> combined representation.

    Linear strategies return (B, d_input); conv strategies return
    (B, H, W, mux_width) keeping spatial structure.
    """
    B, N, Hh, Ww = xs.shape
    s = cfg.mux_strategy
    if s in ("identity", "ortho", "lowrank", "rotation"):
        flat = xs.reshape(B, N, -1)
        if s == "identity":
            return flat.mean(axis=1)
        return jnp.einsum("bnd,nde->be", flat, mux_params["mats"]) / N
    if s in ("random_kernel", "learned_kernel"):
        img = xs.reshape(B * N, Hh, Ww, 1)
        w = mux_params["kernels"]                           # (N,3,3,1,1)
        # same-padding conv per index, then mean over N
        y = jax.lax.conv_general_dilated(
            img, w.reshape(N * 1, 3, 3, 1).transpose(1, 2, 3, 0),
            (1, 1), "SAME", dimension_numbers=("NHWC", "HWIO", "NHWC"))
        # y: (B*N, H, W, N) — take the diagonal (instance i convolved with kernel i)
        y = y.reshape(B, N, Hh, Ww, N)
        y = jnp.einsum("bnhwn->bnhw", y)  # diag over the two N axes
        return y.mean(axis=1)[..., None]                    # (B, H, W, 1)
    if s == "nonlinear":
        def per_index(x_i, c1, b1, c2, b2):
            h = jnp.tanh(jax.lax.conv_general_dilated(
                x_i[..., None], c1, (1, 1), "SAME",
                dimension_numbers=("NHWC", "HWIO", "NHWC")) + b1)
            return jnp.tanh(jax.lax.conv_general_dilated(
                h, c2, (1, 1), "SAME",
                dimension_numbers=("NHWC", "HWIO", "NHWC")) + b2)
        ys = jax.vmap(per_index, in_axes=(1, 0, 0, 0, 0), out_axes=1)(
            xs, mux_params["c1"], mux_params["b1"], mux_params["c2"], mux_params["b2"])
        return ys.sum(axis=1)                               # (B, H, W, width)
    raise ValueError(s)


def init_image_params(key, cfg: C.ImageModelConfig):
    """MLP (A.10): 400 -> 100 -> demux 20*N -> shared readout 20->10.
    CNN (A.10): LeNet-ish convs -> 84 -> demux 84*N -> shared readout 84->10."""
    keys = jax.random.split(key, 10)
    params = {"mux": init_image_mux(keys[0], cfg)}
    if cfg.arch == "mlp":
        d_in = cfg.d_input
        params["fc1"] = _dense(keys[1], d_in, cfg.hidden)
        params["demux"] = _dense(keys[2], cfg.hidden, 20 * cfg.n_mux)
        params["readout"] = _dense(keys[3], 20, cfg.n_classes)
    else:
        cin = cfg.mux_width if cfg.mux_strategy == "nonlinear" else 1
        params["c1"] = {"w": jax.random.normal(keys[1], (3, 3, cin, 10)) * 0.3,
                        "b": jnp.zeros((10,))}
        params["c2"] = {"w": jax.random.normal(keys[2], (4, 4, 10, 16)) * 0.2,
                        "b": jnp.zeros((16,))}
        params["c3"] = {"w": jax.random.normal(keys[3], (3, 3, 16, 120)) * 0.1,
                        "b": jnp.zeros((120,))}
        params["fc"] = _dense(keys[4], 120, cfg.cnn_hidden)
        params["demux"] = _dense(keys[5], cfg.cnn_hidden, cfg.cnn_hidden * cfg.n_mux)
        params["readout"] = _dense(keys[6], cfg.cnn_hidden, cfg.n_classes)
    return params


def image_forward(params, cfg: C.ImageModelConfig, xs):
    """xs: (B, N, H, W) -> (B, N, n_classes) tanh outputs (paper A.10 uses
    tanh targets + MSE)."""
    B, N = xs.shape[:2]
    mixed = apply_image_mux(params["mux"], cfg, xs)
    if cfg.arch == "mlp":
        if mixed.ndim > 2:                       # conv mux output -> flatten
            mixed = mixed.reshape(B, -1)
        h = jnp.tanh(_apply_dense(mixed, params["fc1"]))
        dem = jnp.tanh(_apply_dense(h, params["demux"])).reshape(B, N, 20)
    else:
        img = mixed if mixed.ndim == 4 else mixed.reshape(B, cfg.image_hw, cfg.image_hw, 1)
        h = jnp.tanh(_conv(img, params["c1"]["w"], params["c1"]["b"]))
        h = _maxpool2(h)
        h = jnp.tanh(_conv(h, params["c2"]["w"], params["c2"]["b"]))
        h = _maxpool2(h)
        h = jnp.tanh(_conv(h, params["c3"]["w"], params["c3"]["b"]))
        h = h.reshape(B, -1)
        h = jnp.tanh(_apply_dense(h, params["fc"]))
        dem = jnp.tanh(_apply_dense(h, params["demux"])).reshape(B, N, cfg.cnn_hidden)
    return jnp.tanh(_apply_dense(dem, params["readout"]))   # (B, N, 10)
