"""Training for T-MUX and the image models (build path only).

Implements the paper's recipe end to end:

  1. *Retrieval warm-up* (§3.3, eq. 3): self-supervised pre-training on a
     token stream; the model must recover the token at every position of
     one randomly chosen instance per position (index I ~ U[1, N]).
  2. *Task fine-tuning* (§4.1, eq. 4): L = (1-a) L_task + a L_retrieval
     with a = 0.1, starting from the warm-up checkpoint.

No optax in this image, so Adam is implemented here (bias-corrected,
global-norm clipped) with a trainable-mask so the fixed mux transforms
stay frozen (§3.1).
"""
import time
from dataclasses import dataclass
from functools import partial

import jax
import jax.numpy as jnp
import numpy as np

from . import config as C
from . import data as D
from . import model as M


# ---------------------------------------------------------------------------
# Adam (optax stand-in)
# ---------------------------------------------------------------------------

def adam_init(params):
    """Optimizer state pytree: {step, m, v}."""
    return {
        "step": jnp.zeros((), jnp.int32),
        "m": jax.tree_util.tree_map(jnp.zeros_like, params),
        "v": jax.tree_util.tree_map(jnp.zeros_like, params),
    }


def adam_update(state, grads, params, mask, lr, b1=0.9, b2=0.999,
                eps=1e-8, clip=1.0):
    # global-norm clip
    leaves = jax.tree_util.tree_leaves(grads)
    gnorm = jnp.sqrt(sum(jnp.sum(g * g) for g in leaves) + 1e-12)
    scale = jnp.minimum(1.0, clip / gnorm)
    grads = jax.tree_util.tree_map(lambda g: g * scale, grads)

    step = state["step"] + 1
    m = jax.tree_util.tree_map(lambda m_, g: b1 * m_ + (1 - b1) * g, state["m"], grads)
    v = jax.tree_util.tree_map(lambda v_, g: b2 * v_ + (1 - b2) * g * g, state["v"], grads)
    t = step.astype(jnp.float32)
    mhat = jax.tree_util.tree_map(lambda m_: m_ / (1 - b1 ** t), m)
    vhat = jax.tree_util.tree_map(lambda v_: v_ / (1 - b2 ** t), v)
    new = jax.tree_util.tree_map(
        lambda p, mh, vh, msk: p - msk * lr * mh / (jnp.sqrt(vh) + eps),
        params, mhat, vhat, mask)
    return {"step": step, "m": m, "v": v}, new


# ---------------------------------------------------------------------------
# losses
# ---------------------------------------------------------------------------

def _xent(logits, labels):
    logp = jax.nn.log_softmax(logits, axis=-1)
    return -jnp.take_along_axis(logp, labels[..., None], axis=-1).squeeze(-1)


def retrieval_loss(out, ids_content, key):
    """Paper eq. 3: for each position j, retrieve token w_j^I of one random
    instance I (memory-saving trick from §3.3).

    out["retrieval"]: (B, N, L, V); ids_content: (B, N, L).
    """
    B, N, L, _ = out["retrieval"].shape
    I = jax.random.randint(key, (B, L), 0, N)               # noqa: E741
    sel = jnp.take_along_axis(out["retrieval"], I[:, None, :, None], axis=1)[:, 0]
    tgt = jnp.take_along_axis(ids_content, I[:, None, :], axis=1)[:, 0]
    mask = (tgt != C.PAD_ID).astype(jnp.float32)
    per = _xent(sel, tgt) * mask
    return per.sum() / jnp.maximum(mask.sum(), 1.0)


def retrieval_accuracy(out, ids_content):
    """Full-retrieval accuracy over *all* instances (the Fig 4b metric)."""
    pred = out["retrieval"].argmax(-1)
    mask = ids_content != C.PAD_ID
    return (jnp.where(mask, pred == ids_content, False).sum()
            / jnp.maximum(mask.sum(), 1))


def cls_loss(out, labels):
    """labels: (B, N) -> scalar."""
    return _xent(out["cls"], labels).mean()


def token_loss(out, labels, ids_content):
    """labels: (B, N, L); positions past [SEP]/[PAD] are ignored."""
    mask = (ids_content != C.PAD_ID) & (ids_content != C.CLS_ID) & (ids_content != C.SEP_ID)
    per = _xent(out["token"], labels) * mask
    return per.sum() / jnp.maximum(mask.sum(), 1.0)


# ---------------------------------------------------------------------------
# batching: pack instances into (B, N, L) mux groups
# ---------------------------------------------------------------------------

def pack_groups(rng: np.random.RandomState, ids, labels, batch, n_mux,
                token_level=None):
    n = ids.shape[0]
    take = batch * n_mux
    idx = rng.randint(0, n, take)
    gids = ids[idx].reshape(batch, n_mux, -1)
    # token-level labels are (n, L); sentence labels are (n,)
    if token_level is None:
        token_level = labels.ndim == 2
    if token_level:
        glab = labels[idx].reshape(batch, n_mux, -1)
    else:
        glab = labels[idx].reshape(batch, n_mux)
    return gids, glab


# ---------------------------------------------------------------------------
# T-MUX training
# ---------------------------------------------------------------------------

def make_step_fns(cfg: C.ModelConfig, alpha=0.1):
    """jitted (loss, grads) steps for warm-up and task phases."""

    def warmup_loss_fn(params, content_ids, key):
        ids = M.assemble_input(cfg, content_ids)
        out = M.forward(params, cfg, ids)
        return retrieval_loss(out, content_ids, key)

    def task_loss_fn(params, content_ids, labels, key):
        ids = M.assemble_input(cfg, content_ids)
        out = M.forward(params, cfg, ids)
        if cfg.task == "token":
            lt = token_loss(out, labels, content_ids)
        else:
            lt = cls_loss(out, labels)
        lr_ = retrieval_loss(out, content_ids, key)
        return (1 - alpha) * lt + alpha * lr_

    wgrad = jax.jit(jax.value_and_grad(warmup_loss_fn))
    tgrad = jax.jit(jax.value_and_grad(task_loss_fn))
    return wgrad, tgrad


@dataclass
class TrainResult:
    params: dict
    warmup_acc: float
    history: list
    cfg: object = None   # effective config (heads may be resized per task)


def warmup(cfg: C.ModelConfig, params=None, steps=400, batch=8, lr=5e-4,
           seed=0, corpus_size=4096, log_every=0):
    """Retrieval warm-up pre-training. Returns params + final retrieval acc."""
    rng = np.random.RandomState(seed)
    key = jax.random.PRNGKey(seed)
    if params is None:
        params = M.init_params(key, cfg)
    mask = M.trainable_mask(params, cfg)
    stream = D.make_retrieval(seed + 1, corpus_size, cfg.seq_len)
    wgrad, _ = make_step_fns(cfg)
    opt = adam_init(params)
    hist = []
    upd = jax.jit(partial(adam_update, lr=lr))
    for step in range(steps):
        gids, _ = pack_groups(rng, stream.ids, stream.labels, batch, cfg.n_mux)
        key, sub = jax.random.split(key)
        loss, grads = wgrad(params, jnp.asarray(gids), sub)
        opt, params = upd(opt, grads, params, mask)
        if log_every and step % log_every == 0:
            hist.append((step, float(loss)))
    # measure full retrieval accuracy on held-out stream
    test = D.make_retrieval(seed + 7, 256, cfg.seq_len)
    acc = eval_retrieval(params, cfg, test, batch=batch, seed=seed + 9)
    return TrainResult(params, acc, hist, cfg)


def finetune(cfg: C.ModelConfig, params, task: str, steps=400, batch=8,
             lr=5e-4, alpha=0.1, seed=0, train_size=8192, log_every=0):
    """Task fine-tuning with the auxiliary retrieval objective (eq. 4)."""
    rng = np.random.RandomState(seed + 100)
    key = jax.random.PRNGKey(seed + 100)
    ds = D.TASKS[task](seed + 3, train_size, cfg.seq_len)
    if ds.n_classes != cfg.n_classes:
        # warm-up checkpoints are task-agnostic; resize the task heads here
        import dataclasses
        cfg = dataclasses.replace(cfg, n_classes=ds.n_classes)
        kh = jax.random.PRNGKey(seed + 55)
        d = cfg.d_model
        scale = (2.0 / (d + ds.n_classes)) ** 0.5
        params = dict(params)
        params["head_cls"] = {"w": jax.random.normal(kh, (d, ds.n_classes)) * scale,
                              "b": jnp.zeros((ds.n_classes,))}
        params["head_token"] = {"w": jax.random.normal(kh, (d, ds.n_classes)) * scale,
                                "b": jnp.zeros((ds.n_classes,))}
    mask = M.trainable_mask(params, cfg)
    _, tgrad = make_step_fns(cfg, alpha=alpha)
    opt = adam_init(params)
    hist = []
    upd = jax.jit(partial(adam_update, lr=lr))
    for step in range(steps):
        gids, glab = pack_groups(rng, ds.ids, ds.labels, batch, cfg.n_mux,
                                 ds.token_level)
        key, sub = jax.random.split(key)
        loss, grads = tgrad(params, jnp.asarray(gids), jnp.asarray(glab), sub)
        opt, params = upd(opt, grads, params, mask)
        if log_every and step % log_every == 0:
            hist.append((step, float(loss)))
    return TrainResult(params, float("nan"), hist, cfg)


# ---------------------------------------------------------------------------
# evaluation
# ---------------------------------------------------------------------------

def eval_retrieval(params, cfg, ds: D.Batchset, batch=8, seed=0):
    rng = np.random.RandomState(seed)
    fwd = jax.jit(lambda p, ids: M.forward(p, cfg, ids))
    accs = []
    for _ in range(8):
        gids, _ = pack_groups(rng, ds.ids, ds.labels, batch, cfg.n_mux)
        out = fwd(params, M.assemble_input(cfg, jnp.asarray(gids)))
        accs.append(float(retrieval_accuracy(out, jnp.asarray(gids))))
    return float(np.mean(accs))


def eval_task(params, cfg, task: str, n_eval=1024, batch=8, seed=1234):
    """Returns (overall_acc, per_index_acc[N])."""
    ds = D.TASKS[task](seed, n_eval, cfg.seq_len)
    rng = np.random.RandomState(seed + 1)
    fwd = jax.jit(lambda p, ids: M.forward(p, cfg, ids))
    hits = np.zeros(cfg.n_mux)
    tot = np.zeros(cfg.n_mux)
    iters = max(1, n_eval // (batch * cfg.n_mux))
    for _ in range(iters):
        gids, glab = pack_groups(rng, ds.ids, ds.labels, batch, cfg.n_mux,
                                 ds.token_level)
        out = fwd(params, M.assemble_input(cfg, jnp.asarray(gids)))
        if ds.token_level:
            pred = np.asarray(out["token"].argmax(-1))       # (B, N, L)
            mask = (gids != C.PAD_ID) & (gids != C.CLS_ID) & (gids != C.SEP_ID)
            for i in range(cfg.n_mux):
                m = mask[:, i]
                hits[i] += (pred[:, i][m] == glab[:, i][m]).sum()
                tot[i] += m.sum()
        else:
            pred = np.asarray(out["cls"].argmax(-1))         # (B, N)
            hits += (pred == glab).sum(axis=0)
            tot += pred.shape[0]
    per_index = hits / np.maximum(tot, 1)
    return float(hits.sum() / tot.sum()), per_index


def train_tmux(cfg: C.ModelConfig, task: str, warmup_steps=400, task_steps=400,
               batch=8, seed=0, log_every=0):
    """Full paper recipe: warm-up then fine-tune. Returns
    (params, warmup_acc, task_acc, per_index_acc)."""
    w = warmup(cfg, steps=warmup_steps, batch=batch, seed=seed, log_every=log_every)
    t = finetune(cfg, w.params, task, steps=task_steps, batch=batch, seed=seed,
                 log_every=log_every)
    acc, per_index = eval_task(t.params, t.cfg, task, seed=seed + 4321)
    return t.params, w.warmup_acc, acc, per_index


# ---------------------------------------------------------------------------
# image-model training (paper A.10: SGD, MSE on tanh targets)
# ---------------------------------------------------------------------------

def train_image(cfg: C.ImageModelConfig, steps=1500, batch=32, lr=0.05,
                seed=0, train_size=12000, n_eval=2000):
    """Returns (params, overall_acc, per_index_acc)."""
    rng = np.random.RandomState(seed)
    key = jax.random.PRNGKey(seed)
    params = M.init_image_params(key, cfg)
    xs, ys = D.make_digits(seed + 1, train_size, cfg.image_hw)
    mux_trainable = M.image_mux_trainable(cfg)

    def loss_fn(p, xb, yb):
        out = M.image_forward(p, cfg, xb)                    # (B, N, 10)
        tgt = jax.nn.one_hot(yb, cfg.n_classes) * 2.0 - 1.0  # tanh targets
        tgt = jnp.tanh(tgt)
        return ((out - tgt) ** 2).mean()

    grad_fn = jax.jit(jax.value_and_grad(loss_fn))

    @jax.jit
    def sgd(p, g):
        def upd(path_is_mux, pp, gg):
            return pp - lr * gg
        new = jax.tree_util.tree_map(lambda pp, gg: pp - lr * gg, p, g)
        if not mux_trainable and "mux" in p:
            new["mux"] = p["mux"]                            # frozen transforms
        return new

    for _ in range(steps):
        idx = rng.randint(0, train_size, batch * cfg.n_mux)
        xb = jnp.asarray(xs[idx].reshape(batch, cfg.n_mux, cfg.image_hw, cfg.image_hw))
        yb = jnp.asarray(ys[idx].reshape(batch, cfg.n_mux))
        _, grads = grad_fn(params, xb, yb)
        params = sgd(params, grads)

    # eval
    xe, ye = D.make_digits(seed + 5, n_eval, cfg.image_hw)
    fwd = jax.jit(lambda p, xb: M.image_forward(p, cfg, xb))
    hits = np.zeros(cfg.n_mux)
    tot = 0
    bs = 64
    iters = n_eval // (bs * cfg.n_mux)
    for it in range(max(iters, 1)):
        lo = it * bs * cfg.n_mux
        hi = lo + bs * cfg.n_mux
        if hi > n_eval:
            break
        xb = jnp.asarray(xe[lo:hi].reshape(bs, cfg.n_mux, cfg.image_hw, cfg.image_hw))
        yb = ye[lo:hi].reshape(bs, cfg.n_mux)
        pred = np.asarray(fwd(params, xb).argmax(-1))
        hits += (pred == yb).sum(axis=0)
        tot += bs
    per_index = hits / max(tot, 1)
    return params, float(per_index.mean()), per_index
