"""Figure-regeneration experiments (paper evaluation section).

Each module regenerates one paper figure's data on the synthetic task
family (DESIGN.md §Substitutions) and writes results/<fig>.json plus an
ascii table. `run_all` executes them in priority order under a wall-clock
budget. Retrieval warm-up checkpoints are cached per
(strategy, demux, N, arch) in results/warmup_cache/ and shared across
figures — the same trick the paper uses (§4.1: one warm-up, many tasks).
"""
