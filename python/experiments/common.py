"""Shared experiment infrastructure.

- adaptive retrieval warm-up (train until retrieval accuracy plateaus or
  a step cap scaled by N — the paper notes convergence time grows ~linearly
  with N)
- warm-up checkpoint cache (pickled param pytrees keyed by config)
- result writing (results/<name>.json) + ascii tables
"""
import json
import os
import pickle
import time

import jax
import numpy as np

import sys
sys.path.insert(0, os.path.join(os.path.dirname(__file__), ".."))

from compile import config as C          # noqa: E402
from compile import data as D            # noqa: E402
from compile import model as M           # noqa: E402
from compile import train as T           # noqa: E402

RESULTS_DIR = os.environ.get(
    "DATAMUX_RESULTS", os.path.join(os.path.dirname(__file__), "..", "..", "results"))
CACHE_DIR = os.path.join(RESULTS_DIR, "warmup_cache")

# accuracy-experiment N grid (paper uses up to 40 at d=768; our d=128 tiny
# model has 6.4 dims/instance at N=20, already beyond the paper's 19 at
# N=40 — see DESIGN.md §Substitutions)
N_GRID = [1, 2, 5, 10, 20]
N_GRID_SHORT = [1, 2, 5, 10]


def ensure_dirs():
    os.makedirs(RESULTS_DIR, exist_ok=True)
    os.makedirs(CACHE_DIR, exist_ok=True)


def tiny_cfg(n_mux, task="cls", n_classes=3, **over):
    return C.profile("tiny", n_mux=n_mux, seq_len=16, task=task,
                     n_classes=n_classes, **over)


def warmup_schedule(n_mux: int) -> int:
    """Step cap for the retrieval warm-up, scaled ~linearly with N."""
    return min(300 + 170 * n_mux, 3800)


def task_steps(n_mux: int) -> int:
    return min(400 + 45 * n_mux, 1300)


def adaptive_warmup(cfg, seed=0, batch=8, lr=1e-3, target=0.985, check_every=250):
    """Warm up in chunks, stopping early once retrieval accuracy passes
    `target`. Returns (params, retrieval_acc, steps_used)."""
    cap = warmup_schedule(cfg.n_mux)
    params = None
    steps_used = 0
    acc = 0.0
    while steps_used < cap:
        chunk = min(check_every, cap - steps_used)
        res = T.warmup(cfg, params=params, steps=chunk, batch=batch, lr=lr,
                       seed=seed + steps_used)
        params, acc = res.params, res.warmup_acc
        steps_used += chunk
        if acc >= target:
            break
    return params, acc, steps_used


def cached_warmup(cfg, seed=0, tag=""):
    """Warm-up with an on-disk checkpoint cache (shared across figures)."""
    ensure_dirs()
    key = (f"{cfg.mux_strategy}_{cfg.demux_strategy}_n{cfg.n_mux}"
           f"_d{cfg.d_model}_l{cfg.n_layers}_h{cfg.n_heads}_s{seed}{tag}")
    path = os.path.join(CACHE_DIR, key + ".pkl")
    if os.path.exists(path):
        with open(path, "rb") as f:
            blob = pickle.load(f)
        return blob["params"], blob["acc"], blob["steps"]
    t0 = time.time()
    params, acc, steps = adaptive_warmup(cfg, seed=seed)
    print(f"    [warmup {key}: acc={acc:.3f} in {steps} steps, "
          f"{time.time() - t0:.0f}s]", flush=True)
    with open(path, "wb") as f:
        pickle.dump({"params": jax.device_get(params), "acc": acc, "steps": steps}, f)
    return params, acc, steps


def finetune_eval(cfg, params, task, seed=0, steps=None, lr=1e-3, alpha=0.1):
    """Fine-tune from a warm-up checkpoint and evaluate.
    Returns (acc, per_index, params, effective_cfg)."""
    steps = steps or task_steps(cfg.n_mux)
    t = T.finetune(cfg, params, task, steps=steps, batch=8, lr=lr,
                   alpha=alpha, seed=seed)
    acc, per_index = T.eval_task(t.params, t.cfg, task, seed=seed + 4321)
    return acc, per_index, t.params, t.cfg


def write_result(name: str, payload: dict):
    ensure_dirs()
    path = os.path.join(RESULTS_DIR, f"{name}.json")
    payload = dict(payload)
    payload["generated_unix"] = int(time.time())
    with open(path, "w") as f:
        json.dump(payload, f, indent=1)
    print(f"wrote {path}", flush=True)


def table(title, headers, rows):
    widths = [max(len(str(h)), *(len(str(r[i])) for r in rows)) if rows else len(str(h))
              for i, h in enumerate(headers)]
    out = [f"\n== {title} =="]
    out.append("  ".join(str(h).rjust(w) for h, w in zip(headers, widths)))
    out.append("-" * (sum(widths) + 2 * len(widths)))
    for r in rows:
        out.append("  ".join(str(c).rjust(w) for c, w in zip(r, widths)))
    print("\n".join(out), flush=True)
