"""Fig 10 / A.7: backbone size sweep (layers x hidden) without mux.

Paper claims: much smaller models than 12L/768H stay competitive on
MNLI/NER — the over-parameterization slack that multiplexing exploits.

  python -m experiments.fig10_model_size [--quick]
"""
import sys

import jax

from . import common as X
from compile import model as M
from compile import train as T


def main(quick=False):
    layer_grid = [1, 2] if quick else [1, 2, 4]
    width_grid = [64, 128] if quick else [64, 128, 256]
    results = {}
    rows = []
    for nl in layer_grid:
        for d in width_grid:
            label = f"{nl}L/{d}H"
            accs = {}
            for task, ncls, kind in [("mnli", 3, "cls"), ("ner", 5, "token")]:
                cfg = X.tiny_cfg(1, task=kind, n_classes=ncls,
                                 n_layers=nl, d_model=d, d_ff=2 * d)
                params = M.init_params(jax.random.PRNGKey(0), cfg)
                t = T.finetune(cfg, params, task, steps=600 if not quick else 200,
                               batch=16, lr=1e-3, alpha=0.0, seed=0)
                acc, _ = T.eval_task(t.params, t.cfg, task)
                accs[task] = acc
            results[label] = accs
            rows.append([label, f"{accs['mnli']:.3f}", f"{accs['ner']:.3f}"])
            print(f"  {label}: mnli={accs['mnli']:.3f} ner={accs['ner']:.3f}", flush=True)
    X.table("Fig 10: model size sweep (N=1)", ["model", "mnli", "ner"], rows)
    X.write_result("fig10_model_size", {
        "results": results,
        "paper_claim": "small models competitive -> capacity slack for multiplexing",
    })


if __name__ == "__main__":
    main(quick="--quick" in sys.argv)
