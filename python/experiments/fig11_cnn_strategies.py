"""Fig 11 / A.11: CNN multiplexing strategy zoo.

Paper claims: rotation (SO(2)) beats SO(d) at N<=2; random vs learned 3x3
kernels are similar and capped (~2 correct inputs); nonlinear conv
separation is best and 4x/8x activation maps keep improving larger N.

  python -m experiments.fig11_cnn_strategies [--quick]
"""
import sys
import time

from . import common as X
from compile import config as C
from compile import train as T

VARIANTS = [
    ("rotation", 1),
    ("random_kernel", 1),
    ("learned_kernel", 1),
    ("nonlinear", 1),
    ("nonlinear", 4),
]


def main(quick=False):
    ns = [1, 2, 4] if quick else [1, 2, 4, 8, 16]
    steps = 400 if quick else 1500
    results = {}
    rows = []
    for mux, width in VARIANTS:
        label = mux if width == 1 else f"{mux}{width}x"
        results[label] = {}
        for n in ns:
            cfg = C.ImageModelConfig(arch="cnn", n_mux=n, mux_strategy=mux,
                                     mux_width=width)
            t0 = time.time()
            _, acc, _ = T.train_image(cfg, steps=steps, seed=0)
            results[label][n] = acc
            print(f"  {label} N={n}: acc={acc:.3f} ({time.time()-t0:.0f}s)", flush=True)
        rows.append([label] + [f"{results[label][n]:.3f}" for n in ns])
    X.table("Fig 11: CNN mux strategies", ["variant"] + [f"N={n}" for n in ns], rows)
    X.write_result("fig11_cnn_strategies", {
        "ns": ns,
        "accuracy": results,
        "paper_claim": "nonlinear separation best; wider activation maps extend usable N",
    })


if __name__ == "__main__":
    main(quick="--quick" in sys.argv)
