"""Fig 3: task accuracy vs N across the five task analogues
(mnli/qnli/qqp/sst2 sentence-level, ner token-level), T-MUX with
Hadamard mux + index-embedding demux.

Paper claims (R1): easy tasks (qqp/sst2/qnli) barely drop with N; hard
tasks (mnli, ner) trade 10-15% at the largest N; small N can even help
(mixup-like regularization).

  python -m experiments.fig3_tasks [--quick]
"""
import sys
import time

import numpy as np

from . import common as X
from compile import data as D


TASKS = [("sst2", 2, "cls"), ("qqp", 2, "cls"), ("qnli", 2, "cls"),
         ("mnli", 3, "cls"), ("ner", 5, "token")]


def main(quick=False):
    ns = [1, 2, 5] if quick else X.N_GRID
    results = {t: {} for t, _, _ in TASKS}
    per_index_store = {}
    rows = []
    for n in ns:
        cfg0 = X.tiny_cfg(n)
        params, wacc, wsteps = X.cached_warmup(cfg0, seed=0)
        for task, ncls, kind in TASKS:
            cfg = X.tiny_cfg(n, task=kind, n_classes=3)
            t0 = time.time()
            acc, per_index, _, _ = X.finetune_eval(cfg, params, task, seed=0)
            results[task][n] = acc
            per_index_store[f"{task}_n{n}"] = [float(a) for a in per_index]
            print(f"  N={n} {task}: acc={acc:.3f} ({time.time()-t0:.0f}s)", flush=True)
    for task, _, _ in TASKS:
        rows.append([task] + [f"{results[task].get(n, float('nan')):.3f}" for n in ns])
    X.table("Fig 3: accuracy vs N (hadamard + index embed)", ["task"] + [f"N={n}" for n in ns], rows)
    X.write_result("fig3_tasks", {
        "ns": ns,
        "accuracy": results,
        "per_index": per_index_store,
        "paper_claim": "easy tasks flat in N; mnli/ner trade 10-15% at max N",
    })


if __name__ == "__main__":
    main(quick="--quick" in sys.argv)
