"""Fig 4b: retrieval warm-up accuracy vs N across multiplexing /
demultiplexing strategies.

Paper claims (R2): ~100% retrieval up to N=20 for most strategy pairs —
the soft upper bound on usable N; binary masking fails at large N (A.5);
unfreezing the Hadamard vectors ("Learned") doesn't change much.

  python -m experiments.fig4b_retrieval [--quick]
"""
import sys

from . import common as X

STRATEGIES = [
    ("hadamard", "index_embed"),
    ("ortho", "index_embed"),
    ("binary", "index_embed"),
    ("learned_hadamard", "index_embed"),
    ("hadamard", "mlp"),
]


def main(quick=False):
    ns = [1, 2, 5] if quick else X.N_GRID
    results = {}
    rows = []
    for mux, demux in STRATEGIES:
        label = f"{mux}+{demux}"
        results[label] = {}
        for n in ns:
            cfg = X.tiny_cfg(n, mux_strategy=mux, demux_strategy=demux)
            _, acc, steps = X.cached_warmup(cfg, seed=0)
            results[label][n] = acc
            print(f"  {label} N={n}: retrieval={acc:.3f} ({steps} steps)", flush=True)
        rows.append([label] + [f"{results[label][n]:.3f}" for n in ns])
    X.table("Fig 4b: retrieval accuracy vs N", ["strategy"] + [f"N={n}" for n in ns], rows)
    X.write_result("fig4b_retrieval", {
        "ns": ns,
        "retrieval_accuracy": results,
        "paper_claim": "~100% up to N=20 for most pairs; binary fails at large N",
    })


if __name__ == "__main__":
    main(quick="--quick" in sys.argv)
