"""Fig 5a: effect of attention-head count on multiplexing.

Paper claims (A1): cutting 12 heads to 2 barely changes retrieval or task
accuracy — heads are not the mechanism of multiplexing. Ours compares
2 vs 8 heads on the tiny backbone (4 is its default).

  python -m experiments.fig5a_heads [--quick]
"""
import sys

from . import common as X


def main(quick=False):
    ns = [1, 2, 5] if quick else X.N_GRID_SHORT + [20]
    results = {}
    rows = []
    for heads in (2, 8):
        label = f"{heads}h"
        results[label] = {"retrieval": {}, "mnli": {}}
        for n in ns:
            cfg = X.tiny_cfg(n, n_heads=heads)
            params, wacc, _ = X.cached_warmup(cfg, seed=0)
            acc, _, _, _ = X.finetune_eval(cfg, params, "mnli", seed=0)
            results[label]["retrieval"][n] = wacc
            results[label]["mnli"][n] = acc
            print(f"  {label} N={n}: retrieval={wacc:.3f} mnli={acc:.3f}", flush=True)
        rows.append([label] +
                    [f"{results[label]['retrieval'][n]:.2f}/{results[label]['mnli'][n]:.2f}"
                     for n in ns])
    X.table("Fig 5a: heads ablation (retrieval/mnli)", ["heads"] + [f"N={n}" for n in ns], rows)
    X.write_result("fig5a_heads", {
        "ns": ns,
        "results": results,
        "paper_claim": "2 heads ~= 12 heads for multiplexing",
    })


if __name__ == "__main__":
    main(quick="--quick" in sys.argv)
