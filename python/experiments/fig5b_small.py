"""Fig 5b: multiplexing with smaller backbone capacities.

Paper claims (A2): 12L/384H and 4L/768H still multiplex to N=20 with
competitive accuracy. Ours scales the pair down: half-width (2L/64H) and
half-depth (1L/128H) against the tiny default (2L/128H).

  python -m experiments.fig5b_small [--quick]
"""
import sys

from . import common as X

VARIANTS = [
    ("tiny 2L/128H", dict()),
    ("half-width 2L/64H", dict(d_model=64, d_ff=128)),
    ("half-depth 1L/128H", dict(n_layers=1)),
]


def main(quick=False):
    ns = [1, 2, 5] if quick else X.N_GRID_SHORT + [20]
    results = {}
    rows = []
    for label, over in VARIANTS:
        results[label] = {}
        for n in ns:
            cfg = X.tiny_cfg(n, **over)
            params, wacc, _ = X.cached_warmup(cfg, seed=0)
            acc, _, _, _ = X.finetune_eval(cfg, params, "mnli", seed=0)
            results[label][n] = {"retrieval": wacc, "mnli": acc}
            print(f"  {label} N={n}: retrieval={wacc:.3f} mnli={acc:.3f}", flush=True)
        rows.append([label] + [f"{results[label][n]['mnli']:.3f}" for n in ns])
    X.table("Fig 5b: smaller backbones, mnli accuracy", ["model"] + [f"N={n}" for n in ns], rows)
    X.write_result("fig5b_small", {
        "ns": ns,
        "results": results,
        "paper_claim": "smaller models multiplex to N=20 with competitive accuracy",
    })


if __name__ == "__main__":
    main(quick="--quick" in sys.argv)
