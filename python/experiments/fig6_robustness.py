"""Fig 6: is the demultiplexed representation of an instance robust to
the other instances it is multiplexed with?

Paper method: 10 anchor instances, each multiplexed with 30 different
random context sets; t-SNE of the demuxed representations clusters by
anchor. Ours replaces the visual with the quantitative versions of the
same claim:
  * intra/inter distance ratio (mean distance between representations of
    the same anchor / different anchors) — small means tight clusters;
  * 1-NN purity: fraction of representations whose nearest neighbour is
    the same anchor (t-SNE clusters <=> purity ~1.0).

  python -m experiments.fig6_robustness [--quick]
"""
import sys

import jax
import numpy as np

from . import common as X
from compile import data as D
from compile import model as M


def main(quick=False):
    ns = [2, 5] if quick else [2, 5, 10, 20]
    n_anchors, n_contexts = (5, 10) if quick else (10, 30)
    results = {}
    rows = []
    rng = np.random.RandomState(0)
    for n in ns:
        cfg = X.tiny_cfg(n)
        params, _, _ = X.cached_warmup(cfg, seed=0)
        # fine-tune briefly on mnli so representations are task-shaped
        _, _, params, cfg_eff = X.finetune_eval(cfg, params, "mnli", seed=0,
                                                steps=min(X.task_steps(n), 500))
        ds = D.make_mnli(321, 4096, cfg.seq_len)
        anchors = ds.ids[:n_anchors]
        fwd = jax.jit(lambda p, ids: M.forward(p, cfg_eff, ids))
        reps = np.zeros((n_anchors, n_contexts, cfg.d_model), np.float32)
        for a in range(n_anchors):
            for c in range(n_contexts):
                ctx_idx = rng.randint(n_anchors, 4096, n - 1)
                group = np.stack([anchors[a]] + [ds.ids[i] for i in ctx_idx])[None]
                out = fwd(params, M.assemble_input(cfg_eff, group))
                reps[a, c] = np.asarray(out["hidden"][0, 0, 0, :])  # CLS of slot 0
        flat = reps.reshape(n_anchors * n_contexts, -1)
        labels = np.repeat(np.arange(n_anchors), n_contexts)
        d2 = ((flat[:, None, :] - flat[None, :, :]) ** 2).sum(-1) ** 0.5
        same = labels[:, None] == labels[None, :]
        eye = np.eye(len(flat), dtype=bool)
        intra = d2[same & ~eye].mean()
        inter = d2[~same].mean()
        np.fill_diagonal(d2, np.inf)
        nn_purity = float((labels[d2.argmin(1)] == labels).mean())
        results[n] = {"intra": float(intra), "inter": float(inter),
                      "ratio": float(intra / inter), "nn_purity": nn_purity}
        rows.append([n, f"{intra:.3f}", f"{inter:.3f}", f"{intra/inter:.3f}", f"{nn_purity:.3f}"])
        print(f"  N={n}: intra={intra:.3f} inter={inter:.3f} purity={nn_purity:.3f}", flush=True)
    X.table("Fig 6: demux representation robustness",
            ["N", "intra-dist", "inter-dist", "ratio", "1-NN purity"], rows)
    X.write_result("fig6_robustness", {
        "results": {str(k): v for k, v in results.items()},
        "paper_claim": "representations cluster by instance regardless of co-muxed context "
                       "(ratio << 1, purity ~1)",
    })


if __name__ == "__main__":
    main(quick="--quick" in sys.argv)
