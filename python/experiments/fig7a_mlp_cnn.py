"""Fig 7a: multiplexing MLPs and CNNs on the digit-classification task.

Paper claims (§5): MLP+Ortho holds ~78% at N=8 (vs ~95% base); LowRank
helps ~5% at N=8; identity collapses ~1/N; CNN+Ortho is poor (locality
destroyed); CNN+Nonlinear >80% to N=4 then drops.

  python -m experiments.fig7a_mlp_cnn [--quick]
"""
import sys
import time

from . import common as X
from compile import config as C
from compile import train as T

VARIANTS = [
    ("mlp", "identity"),
    ("mlp", "ortho"),
    ("mlp", "lowrank"),
    ("cnn", "ortho"),
    ("cnn", "nonlinear"),
]


def main(quick=False):
    ns = [1, 2, 4] if quick else [1, 2, 4, 8, 16]
    steps = 400 if quick else 1500
    results = {}
    rows = []
    for arch, mux in VARIANTS:
        label = f"{arch}+{mux}"
        results[label] = {}
        for n in ns:
            if mux == "lowrank" and n > 16:
                continue
            cfg = C.ImageModelConfig(arch=arch, n_mux=n, mux_strategy=mux)
            t0 = time.time()
            _, acc, per_index = T.train_image(cfg, steps=steps, seed=0)
            results[label][n] = acc
            print(f"  {label} N={n}: acc={acc:.3f} ({time.time()-t0:.0f}s)", flush=True)
        rows.append([label] + [f"{results[label].get(n, float('nan')):.3f}" for n in ns])
    X.table("Fig 7a: MLP/CNN digit accuracy vs N", ["variant"] + [f"N={n}" for n in ns], rows)
    X.write_result("fig7a_mlp_cnn", {
        "ns": ns,
        "accuracy": results,
        "paper_claim": "MLP+Ortho usable to N=8; LowRank helps; identity ~1/N; "
                       "CNN+Ortho poor; CNN+Nonlinear >80% to N=4",
    })


if __name__ == "__main__":
    main(quick="--quick" in sys.argv)
