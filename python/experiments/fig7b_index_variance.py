"""Fig 7b: per-index accuracy variance grows with N.

Paper claims (A3): at N=40 mnli accuracy varies ~10 points across mux
indices. Reads the per-index accuracies stored by fig3 (or recomputes
mnli if fig3 hasn't run).

  python -m experiments.fig7b_index_variance [--quick]
"""
import json
import os
import sys

import numpy as np

from . import common as X


def main(quick=False):
    ns = [1, 2, 5] if quick else X.N_GRID
    fig3_path = os.path.join(X.RESULTS_DIR, "fig3_tasks.json")
    per_index = {}
    if os.path.exists(fig3_path):
        with open(fig3_path) as f:
            per_index = json.load(f).get("per_index", {})
    rows = []
    results = {}
    for n in ns:
        key = f"mnli_n{n}"
        if key in per_index:
            accs = np.asarray(per_index[key])
        else:
            cfg = X.tiny_cfg(n)
            params, _, _ = X.cached_warmup(cfg, seed=0)
            _, accs, _, _ = X.finetune_eval(cfg, params, "mnli", seed=0)
            accs = np.asarray(accs)
        results[n] = {"mean": float(accs.mean()), "std": float(accs.std()),
                      "spread": float(accs.max() - accs.min()),
                      "per_index": [float(a) for a in accs]}
        rows.append([n, f"{accs.mean():.3f}", f"{accs.std():.3f}",
                     f"{accs.max()-accs.min():.3f}"])
        print(f"  N={n}: mean={accs.mean():.3f} spread={accs.max()-accs.min():.3f}", flush=True)
    X.table("Fig 7b: per-index mnli accuracy variance",
            ["N", "mean", "std", "max-min"], rows)
    X.write_result("fig7b_index_variance", {
        "results": {str(k): v for k, v in results.items()},
        "paper_claim": "per-index spread grows with N (~10 points at the paper's N=40)",
    })


if __name__ == "__main__":
    main(quick="--quick" in sys.argv)
