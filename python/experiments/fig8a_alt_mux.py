"""Fig 8a / A.5: alternative multiplexing strategies on MNLI + NER.

Paper claims: unfreezing the Hadamard vectors ("Learned") changes little;
binary chunk-select masking fails to multiplex for large N (mux is more
than concatenating d/N-dim downsampled inputs).

  python -m experiments.fig8a_alt_mux [--quick]
"""
import sys

from . import common as X

STRATS = ["hadamard", "learned_hadamard", "binary"]


def main(quick=False):
    ns = [1, 2, 5] if quick else X.N_GRID
    results = {}
    rows = []
    for strat in STRATS:
        results[strat] = {}
        for n in ns:
            cfg = X.tiny_cfg(n, mux_strategy=strat)
            params, wacc, _ = X.cached_warmup(cfg, seed=0)
            acc, _, _, _ = X.finetune_eval(cfg, params, "mnli", seed=0)
            results[strat][n] = {"retrieval": wacc, "mnli": acc}
            print(f"  {strat} N={n}: retrieval={wacc:.3f} mnli={acc:.3f}", flush=True)
        rows.append([strat] + [f"{results[strat][n]['mnli']:.3f}" for n in ns])
    X.table("Fig 8a: alternative mux strategies (mnli)", ["strategy"] + [f"N={n}" for n in ns], rows)
    X.write_result("fig8a_alt_mux", {
        "ns": ns,
        "results": results,
        "paper_claim": "learned ~= frozen hadamard; binary fails at large N",
    })


if __name__ == "__main__":
    main(quick="--quick" in sys.argv)
