"""Fig 8b / A.4: seed variance of multiplexed fine-tuning.

Paper claims: with the warm-up checkpoint shared, fine-tuning variance
across 3 seeds is minimal at every N (the seed only affects demux/head
initialization and data order).

  python -m experiments.fig8b_seeds [--quick]
"""
import sys

import numpy as np

from . import common as X


def main(quick=False):
    ns = [2] if quick else [2, 5, 10]
    seeds = [0, 1, 2]
    results = {}
    rows = []
    for n in ns:
        cfg = X.tiny_cfg(n)
        params, _, _ = X.cached_warmup(cfg, seed=0)  # shared warm-up (paper A.4)
        accs = []
        for s in seeds:
            acc, _, _, _ = X.finetune_eval(cfg, params, "mnli", seed=1000 + s)
            accs.append(acc)
            print(f"  N={n} seed={s}: mnli={acc:.3f}", flush=True)
        accs = np.asarray(accs)
        results[n] = {"accs": [float(a) for a in accs], "mean": float(accs.mean()),
                      "std": float(accs.std())}
        rows.append([n, f"{accs.mean():.3f}", f"{accs.std():.4f}"])
    X.table("Fig 8b: mnli accuracy across 3 seeds", ["N", "mean", "std"], rows)
    X.write_result("fig8b_seeds", {
        "results": {str(k): v for k, v in results.items()},
        "paper_claim": "variance across seeds minimal at every N",
    })


if __name__ == "__main__":
    main(quick="--quick" in sys.argv)
