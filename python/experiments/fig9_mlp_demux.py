"""Fig 9 / A.6: MLP demultiplexing vs index embeddings.

Paper claims: MLP demux works for retrieval but fine-tunes slightly worse
and is *optimization-unstable* — some N fail to converge at apparently
arbitrary points (their N=10 failed while N=20 trained). We run 2 seeds
per N and report best/worst to surface instability.

  python -m experiments.fig9_mlp_demux [--quick]
"""
import sys

import numpy as np

from . import common as X


def main(quick=False):
    ns = [1, 2, 5] if quick else X.N_GRID_SHORT
    results = {}
    rows = []
    for demux in ["index_embed", "mlp"]:
        results[demux] = {}
        for n in ns:
            accs = []
            for seed in (0, 1):
                cfg = X.tiny_cfg(n, demux_strategy=demux)
                params, wacc, _ = X.cached_warmup(cfg, seed=seed,
                                                  tag="" if seed == 0 else f"_s{seed}")
                acc, _, _, _ = X.finetune_eval(cfg, params, "mnli", seed=seed)
                accs.append(acc)
            accs = np.asarray(accs)
            results[demux][n] = {"best": float(accs.max()), "worst": float(accs.min())}
            print(f"  {demux} N={n}: best={accs.max():.3f} worst={accs.min():.3f}", flush=True)
        rows.append([demux] + [f"{results[demux][n]['best']:.2f}/{results[demux][n]['worst']:.2f}"
                               for n in ns])
    X.table("Fig 9: demux strategy, mnli best/worst of 2 seeds",
            ["demux"] + [f"N={n}" for n in ns], rows)
    X.write_result("fig9_mlp_demux", {
        "ns": ns,
        "results": results,
        "paper_claim": "MLP demux slightly worse + unstable (best/worst gap) vs index embed",
    })


if __name__ == "__main__":
    main(quick="--quick" in sys.argv)
