"""Run all figure experiments in priority order under a wall-clock budget.

  python -m experiments.run_all [--quick] [--budget-min 90]

Priority: warm-up-cache builders first (fig4b seeds the cache for every
strategy; fig3 the task grid), then the cheaper analyses. If the budget
expires the remaining figures are listed as skipped in
results/run_all_status.json — rerun individually.
"""
import json
import os
import sys
import time
import traceback

from . import common as X
from . import (fig3_tasks, fig4b_retrieval, fig5a_heads, fig5b_small,
               fig6_robustness, fig7a_mlp_cnn, fig7b_index_variance,
               fig8a_alt_mux, fig8b_seeds, fig9_mlp_demux,
               fig10_model_size, fig11_cnn_strategies)

ORDER = [
    ("fig4b_retrieval", fig4b_retrieval.main),
    ("fig3_tasks", fig3_tasks.main),
    ("fig7b_index_variance", fig7b_index_variance.main),
    ("fig7a_mlp_cnn", fig7a_mlp_cnn.main),
    ("fig10_model_size", fig10_model_size.main),
    ("fig8a_alt_mux", fig8a_alt_mux.main),
    ("fig8b_seeds", fig8b_seeds.main),
    ("fig9_mlp_demux", fig9_mlp_demux.main),
    ("fig11_cnn_strategies", fig11_cnn_strategies.main),
    ("fig5a_heads", fig5a_heads.main),
    ("fig5b_small", fig5b_small.main),
    ("fig6_robustness", fig6_robustness.main),
]


def main():
    quick = "--quick" in sys.argv
    budget_min = 90.0
    for i, a in enumerate(sys.argv):
        if a == "--budget-min" and i + 1 < len(sys.argv):
            budget_min = float(sys.argv[i + 1])
    deadline = time.time() + budget_min * 60
    status = {}
    for name, fn in ORDER:
        if time.time() > deadline:
            status[name] = "skipped (budget)"
            print(f"== {name}: skipped (budget) ==", flush=True)
            continue
        print(f"\n==== {name} (budget left {int(deadline - time.time())}s) ====", flush=True)
        t0 = time.time()
        try:
            fn(quick=quick)
            status[name] = f"ok ({int(time.time() - t0)}s)"
        except Exception as e:  # keep the suite going
            traceback.print_exc()
            status[name] = f"error: {e}"
    X.ensure_dirs()
    with open(os.path.join(X.RESULTS_DIR, "run_all_status.json"), "w") as f:
        json.dump(status, f, indent=1)
    print("\n== run_all status ==")
    for k, v in status.items():
        print(f"  {k}: {v}")


if __name__ == "__main__":
    main()
