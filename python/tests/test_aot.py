"""AOT pipeline: pruning, weight files, HLO lowering, manifest contract."""
import json
import os
import struct
import tempfile

import jax
import numpy as np
import pytest

from compile import aot
from compile import config as C
from compile import model as M


def tiny():
    return C.profile("tiny", n_mux=2, seq_len=12, task="cls", n_classes=3,
                     d_model=64, d_ff=128)


def test_prune_params_drops_unused_heads():
    cfg = tiny()
    params = M.init_params(jax.random.PRNGKey(0), cfg)
    pruned = aot.prune_params(params, cfg)
    assert "head_cls" in pruned
    assert "head_token" not in pruned
    assert "head_retrieval" not in pruned
    import dataclasses
    cfg_tok = dataclasses.replace(cfg, task="token")
    pruned_tok = aot.prune_params(params, cfg_tok)
    assert "head_token" in pruned_tok and "head_cls" not in pruned_tok


def test_flatten_order_is_deterministic():
    cfg = tiny()
    params = aot.prune_params(M.init_params(jax.random.PRNGKey(0), cfg), cfg)
    a = [n for n, _ in aot.flatten_named(params)]
    b = [n for n, _ in aot.flatten_named(params)]
    assert a == b
    assert len(a) == len(set(a)), "names unique"
    assert any("tok_emb" in n for n in a)


def test_weights_file_roundtrip():
    cfg = tiny()
    params = aot.prune_params(M.init_params(jax.random.PRNGKey(1), cfg), cfg)
    named = aot.flatten_named(params)
    with tempfile.TemporaryDirectory() as d:
        path = os.path.join(d, "w.bin")
        tensors = aot.write_weights(path, named)
        blob = open(path, "rb").read()
        assert blob[:7] == aot.MAGIC
        hlen = struct.unpack("<I", blob[7:11])[0]
        header = json.loads(blob[11:11 + hlen])
        assert len(header["tensors"]) == len(named)
        # first tensor data round-trips bit-exactly
        t0 = header["tensors"][0]
        start = 11 + hlen + t0["offset"]
        data = np.frombuffer(blob[start:start + t0["nbytes"]], np.float32)
        np.testing.assert_array_equal(
            data, np.asarray(named[0][1], np.float32).reshape(-1))
        assert tensors == header["tensors"]


def test_lower_model_emits_hlo_text():
    cfg = tiny()
    params = aot.prune_params(M.init_params(jax.random.PRNGKey(2), cfg), cfg)
    hlo = aot.lower_model(params, cfg, batch=1)
    assert "HloModule" in hlo
    assert "ENTRY" in hlo
    # parameter count = weight leaves + ids
    n_leaves = len(aot.flatten_named(params))
    assert hlo.count("parameter(") >= n_leaves + 1


def test_parity_blob_is_self_consistent():
    cfg = tiny()
    params = aot.prune_params(M.init_params(jax.random.PRNGKey(3), cfg), cfg)
    blob = aot.parity_blob(params, cfg, batch=1)
    assert len(blob["ids"]) == 1 * cfg.n_mux * cfg.input_len
    assert len(blob["check_indices"]) == len(blob["check_values"])
    assert np.prod(blob["output_shape"]) >= max(blob["check_indices"]) + 1
    # values finite
    assert all(np.isfinite(v) for v in blob["check_values"])


def test_manifest_exists_and_matches_schema():
    """Integration-level: the real artifacts dir written by `make artifacts`."""
    art = os.path.join(os.path.dirname(__file__), "..", "..", "artifacts")
    manifest_path = os.path.join(art, "manifest.json")
    if not os.path.exists(manifest_path):
        pytest.skip("artifacts not built")
    with open(manifest_path) as f:
        m = json.load(f)
    assert m["version"] == 1
    assert m["vocab"]["content_base"] == C.CONTENT_BASE
    for a in m["artifacts"]:
        assert os.path.exists(os.path.join(art, a["hlo"])), a["name"]
        assert os.path.exists(os.path.join(art, a["weights"])), a["name"]
        assert a["input_len"] == a["n_mux"] + a["seq_len"]
