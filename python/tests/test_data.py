"""Synthetic task generators: determinism, label semantics, balance."""
import numpy as np
import pytest

from compile import config as C
from compile import data as D


@pytest.mark.parametrize("task", list(D.TASKS))
def test_deterministic_in_seed(task):
    a = D.TASKS[task](7, 64, 16)
    b = D.TASKS[task](7, 64, 16)
    np.testing.assert_array_equal(a.ids, b.ids)
    np.testing.assert_array_equal(a.labels, b.labels)
    c = D.TASKS[task](8, 64, 16)
    assert not np.array_equal(a.ids, c.ids)


@pytest.mark.parametrize("task", list(D.TASKS))
def test_frame_layout(task):
    ds = D.TASKS[task](0, 128, 16)
    assert ds.ids.shape == (128, 16)
    assert (ds.ids[:, 0] == C.CLS_ID).all()
    # ids are valid vocab entries
    assert ds.ids.min() >= 0
    assert ds.ids.max() < D.V_CONTENT + C.CONTENT_BASE
    # no prefix tokens in content
    assert not ((ds.ids >= C.IDX_BASE) & (ds.ids < C.CONTENT_BASE) & (ds.ids != C.EPS_PAD_ID)).any()


@pytest.mark.parametrize("task,ncls", [("sst2", 2), ("qqp", 2), ("qnli", 2), ("mnli", 3)])
def test_labels_balanced(task, ncls):
    ds = D.TASKS[task](3, 3000, 16)
    counts = np.bincount(ds.labels, minlength=ncls)
    assert ds.n_classes == ncls
    assert counts.min() > 0.8 * 3000 / ncls, counts


def test_sst2_label_semantics():
    """Label must equal which lexicon the sentiment tokens came from."""
    ds = D.make_sst2(11, 200, 16)
    for i in range(200):
        toks = ds.ids[i] - C.CONTENT_BASE
        pos = ((toks >= 0) & (toks < 24)).sum()
        neg = ((toks >= 24) & (toks < 48)).sum()
        want = 1 if pos > neg else 0
        assert want == ds.labels[i], (i, pos, neg, ds.labels[i])


def test_qnli_label_semantics():
    """y=1 iff the answer token a(q)=q+32 appears in the context."""
    ds = D.make_qnli(13, 300, 16)
    for i in range(300):
        row = ds.ids[i]
        sep_positions = np.where(row == C.SEP_ID)[0]
        ctx = row[1:sep_positions[0]] - C.CONTENT_BASE
        q = row[sep_positions[0] + 1] - C.CONTENT_BASE
        has_answer = (ctx == q + 32).any()
        assert bool(has_answer) == bool(ds.labels[i])


def test_ner_tags_follow_triggers():
    ds = D.make_ner(17, 200, 16)
    assert ds.token_level
    for i in range(200):
        row = ds.ids[i] - C.CONTENT_BASE
        tags = ds.labels[i]
        for j in range(1, 15):
            if tags[j] in (1, 3):  # B-PER / B-LOC
                trig = row[j - 1]
                assert trig in (0, 1), f"B tag without trigger at {i},{j}"
                assert tags[j] == (1 if trig == 0 else 3)


def test_retrieval_stream_zipfian():
    ds = D.make_retrieval(19, 512, 16)
    toks = ds.ids[ds.ids >= C.CONTENT_BASE] - C.CONTENT_BASE
    counts = np.bincount(toks, minlength=D.V_CONTENT)
    assert counts[0] > counts[10] > counts[100], "zipf head heavier than tail"


def test_digits_shapes_and_distinguishability():
    xs, ys = D.make_digits(0, 500)
    assert xs.shape == (500, 20, 20)
    assert xs.min() >= 0 and xs.max() <= 1
    assert set(np.unique(ys)) == set(range(10))
    # prototype separation: mean image per class differs between classes
    means = np.stack([xs[ys == d].mean(0) for d in range(10)])
    d01 = np.abs(means[0] - means[1]).sum()
    assert d01 > 5.0, "digit glyphs must be distinguishable"


def test_digits_low_rank_like_mnist():
    """Paper A.10: top-50 PCs of MNIST explain ~87% variance; our
    generator must be comparably low-rank for the d/50 mux argument."""
    xs, _ = D.make_digits(1, 2000)
    flat = xs.reshape(2000, -1) - xs.reshape(2000, -1).mean(0)
    s = np.linalg.svd(flat, compute_uv=False)
    var = s ** 2
    explained = var[:50].sum() / var.sum()
    assert explained > 0.80, f"top-50 PCs explain only {explained:.2f}"


def test_ids_to_text_roundtrip_tokens():
    ds = D.make_mnli(2, 4, 16)
    text = D.ids_to_text(ds.ids[0])
    assert text.startswith("[CLS]")
    assert "[SEP]" in text
    # every non-special word is t{k}
    for w in text.split():
        assert w.startswith("[") or w.startswith("t")
