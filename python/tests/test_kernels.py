"""L1 correctness: Pallas kernels vs pure-jnp oracles.

These sweeps are the core correctness signal for the compile path: every
(kernel, shape, dtype, N) combination must match ref.py within f32
tolerance. hypothesis is unavailable in this image, so the sweep space is
enumerated with parametrize (DESIGN.md §Substitutions).
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile.kernels import attention, demux, mux, ref

TOL = dict(rtol=1e-5, atol=1e-5)


def rand(key, shape, dtype=jnp.float32, scale=1.0):
    return (jax.random.normal(jax.random.PRNGKey(key), shape) * scale).astype(dtype)


# ---------------------------------------------------------------------------
# mux kernels
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("n_mux", [1, 2, 5, 8, 20, 40])
@pytest.mark.parametrize("batch,seq,d", [(1, 8, 32), (2, 16, 64), (3, 24, 128)])
def test_mux_hadamard_matches_ref(n_mux, batch, seq, d):
    xs = rand(0, (batch, n_mux, seq, d))
    vecs = rand(1, (n_mux, d))
    got = mux.mux_hadamard(xs, vecs)
    want = jax.vmap(lambda x: ref.mux_hadamard(x, vecs))(xs)
    np.testing.assert_allclose(got, want, **TOL)


@pytest.mark.parametrize("n_mux", [1, 2, 5, 10, 20])
@pytest.mark.parametrize("batch,seq,d", [(1, 8, 32), (2, 16, 64)])
def test_mux_ortho_matches_ref(n_mux, batch, seq, d):
    xs = rand(2, (batch, n_mux, seq, d))
    mats = rand(3, (n_mux, d, d), scale=d ** -0.5)
    got = mux.mux_ortho(xs, mats)
    want = jax.vmap(lambda x: ref.mux_ortho(x, mats))(xs)
    np.testing.assert_allclose(got, want, **TOL)


@pytest.mark.parametrize("n_mux", [2, 4, 8])
def test_mux_binary_matches_ref(n_mux):
    d = 64
    xs = rand(4, (2, n_mux, 8, d))
    chunk = d // n_mux
    masks = np.zeros((n_mux, d), np.float32)
    for i in range(n_mux):
        masks[i, i * chunk:(i + 1) * chunk] = 1.0
    masks = jnp.asarray(masks)
    got = mux.mux_binary(xs, masks)
    want = jax.vmap(lambda x: ref.mux_binary(x, masks))(xs)
    np.testing.assert_allclose(got, want, **TOL)


def test_mux_identity_single_instance():
    """N=1 hadamard with unit vector must be the identity."""
    xs = rand(5, (2, 1, 16, 64))
    vecs = jnp.ones((1, 64))
    np.testing.assert_allclose(mux.mux_hadamard(xs, vecs), xs[:, 0], **TOL)


def test_mux_order_dependence():
    """Permuting instances must change the combined representation
    (the property that separates DataMUX from mixup)."""
    xs = rand(6, (1, 4, 8, 32))
    vecs = rand(7, (4, 32))
    a = mux.mux_hadamard(xs, vecs)
    b = mux.mux_hadamard(xs[:, ::-1], vecs)
    assert not np.allclose(a, b, atol=1e-3)


def test_mux_ortho_preserves_norm_per_instance():
    """Orthogonal phi_i preserve per-instance norms before averaging."""
    d = 64
    q, _ = np.linalg.qr(np.random.RandomState(0).randn(d, d))
    mats = jnp.asarray(q[None], jnp.float32)
    xs = rand(8, (1, 1, 8, d))
    out = mux.mux_ortho(xs, mats)
    np.testing.assert_allclose(
        np.linalg.norm(np.asarray(out[0]), axis=-1),
        np.linalg.norm(np.asarray(xs[0, 0]), axis=-1),
        rtol=1e-4,
    )


@pytest.mark.parametrize("seq", [3, 5, 7, 12, 30])
def test_mux_ragged_seq_lengths(seq):
    """Block picker must handle L not divisible by the preferred block."""
    xs = rand(9, (2, 3, seq, 32))
    vecs = rand(10, (3, 32))
    got = mux.mux_hadamard(xs, vecs)
    want = jax.vmap(lambda x: ref.mux_hadamard(x, vecs))(xs)
    np.testing.assert_allclose(got, want, **TOL)


# ---------------------------------------------------------------------------
# demux kernels
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("n_mux", [1, 2, 5, 10, 20, 40])
@pytest.mark.parametrize("batch,seq,d,f", [(1, 8, 32, 64), (2, 16, 64, 128)])
def test_demux_index_mlp_matches_ref(n_mux, batch, seq, d, f):
    h = rand(11, (batch, seq, d))
    p = rand(12, (batch, n_mux, d))
    w1h, w1p = rand(13, (d, f), scale=0.1), rand(14, (d, f), scale=0.1)
    b1 = rand(15, (f,), scale=0.01)
    w2, b2 = rand(16, (f, d), scale=0.1), rand(17, (d,), scale=0.01)
    got = demux.demux_index_mlp(h, p, w1h, w1p, b1, w2, b2)
    want = jax.vmap(lambda hh, pp: ref.demux_index_mlp(hh, pp, w1h, w1p, b1, w2, b2))(h, p)
    np.testing.assert_allclose(got, want, **TOL)


@pytest.mark.parametrize("n_mux", [1, 2, 5, 10])
@pytest.mark.parametrize("batch,seq,d,f", [(2, 8, 32, 64)])
def test_demux_mlp_matches_ref(n_mux, batch, seq, d, f):
    h = rand(18, (batch, seq, d))
    w1, b1 = rand(19, (n_mux, d, f), scale=0.1), rand(20, (n_mux, f), scale=0.01)
    w2, b2 = rand(21, (n_mux, f, d), scale=0.1), rand(22, (n_mux, d), scale=0.01)
    got = demux.demux_mlp(h, w1, b1, w2, b2)
    want = jax.vmap(lambda hh: ref.demux_mlp(hh, w1, b1, w2, b2))(h)
    np.testing.assert_allclose(got, want, **TOL)


def test_demux_index_distinct_indices_give_distinct_outputs():
    h = rand(23, (1, 8, 64))
    p = rand(24, (1, 4, 64))
    w1h, w1p = rand(25, (64, 128), scale=0.2), rand(26, (64, 128), scale=0.2)
    out = demux.demux_index_mlp(h, p, w1h, w1p, jnp.zeros(128),
                                rand(27, (128, 64), scale=0.2), jnp.zeros(64))
    assert not np.allclose(out[0, 0], out[0, 1], atol=1e-3)


def test_demux_concat_split_equivalence():
    """The two-matmul-halves trick equals a literal concat MLP."""
    d, f, L, N = 32, 64, 8, 3
    h = rand(28, (L, d))
    p = rand(29, (N, d))
    w1h, w1p = rand(30, (d, f), scale=0.1), rand(31, (d, f), scale=0.1)
    b1, w2, b2 = rand(32, (f,)), rand(33, (f, d), scale=0.1), rand(34, (d,))
    w1_full = jnp.concatenate([w1h, w1p], axis=0)          # (2d, f)
    want = []
    for i in range(N):
        cat = jnp.concatenate([h, jnp.broadcast_to(p[i], (L, d))], axis=-1)
        want.append(jax.nn.gelu(cat @ w1_full + b1) @ w2 + b2)
    want = jnp.stack(want)
    got = ref.demux_index_mlp(h, p, w1h, w1p, b1, w2, b2)
    np.testing.assert_allclose(got, want, **TOL)


# ---------------------------------------------------------------------------
# attention kernel
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("batch,heads,seq,dh", [
    (1, 1, 8, 16), (2, 4, 16, 16), (1, 8, 24, 32), (2, 2, 56, 64),
])
def test_mha_matches_ref(batch, heads, seq, dh):
    q = rand(35, (batch, heads, seq, dh))
    k = rand(36, (batch, heads, seq, dh))
    v = rand(37, (batch, heads, seq, dh))
    got = attention.mha_attention(q, k, v)
    want = jax.vmap(lambda a, b, c: ref.mha_attention(a, b, c))(q, k, v)
    np.testing.assert_allclose(got, want, **TOL)


def test_mha_rows_are_convex_combinations():
    """Attention outputs are convex combos of V rows: bounded by V extremes."""
    q = rand(38, (1, 2, 8, 16))
    k = rand(39, (1, 2, 8, 16))
    v = rand(40, (1, 2, 8, 16))
    out = np.asarray(attention.mha_attention(q, k, v))
    vmin = np.asarray(v).min(axis=2, keepdims=True) - 1e-5
    vmax = np.asarray(v).max(axis=2, keepdims=True) + 1e-5
    assert (out >= vmin).all() and (out <= vmax).all()


def test_mha_softmax_stability_large_logits():
    """Max-subtraction must keep huge logits finite."""
    q = rand(41, (1, 1, 8, 16), scale=100.0)
    k = rand(42, (1, 1, 8, 16), scale=100.0)
    v = rand(43, (1, 1, 8, 16))
    out = np.asarray(attention.mha_attention(q, k, v))
    assert np.isfinite(out).all()


def test_kernels_jit_compatible():
    """All kernels must trace under jit (the AOT path requirement)."""
    xs = rand(44, (1, 2, 8, 32))
    vecs = rand(45, (2, 32))
    out = jax.jit(mux.mux_hadamard)(xs, vecs)
    assert out.shape == (1, 8, 32)
    h = rand(46, (1, 8, 32))
    p = rand(47, (1, 2, 32))
    args = (rand(48, (32, 64)), rand(49, (32, 64)), jnp.zeros(64),
            rand(50, (64, 32)), jnp.zeros(32))
    out = jax.jit(demux.demux_index_mlp)(h, p, *args)
    assert out.shape == (1, 2, 8, 32)
    q = rand(51, (1, 2, 8, 16))
    out = jax.jit(attention.mha_attention)(q, q, q)
    assert out.shape == (1, 2, 8, 16)
