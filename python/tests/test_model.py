"""L2 model invariants: shapes, pallas/ref parity, mux semantics."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import config as C
from compile import data as D
from compile import model as M

TOL = dict(rtol=2e-5, atol=2e-5)


def make(n_mux=2, **over):
    return C.profile("tiny", n_mux=n_mux, seq_len=16, task="cls", n_classes=3, **over)


def inputs(cfg, batch=2, seed=0):
    ds = D.make_mnli(seed, batch * cfg.n_mux, cfg.seq_len)
    content = ds.ids.reshape(batch, cfg.n_mux, cfg.seq_len)
    return M.assemble_input(cfg, content), content


@pytest.mark.parametrize("n_mux", [1, 2, 5, 10])
def test_forward_shapes(n_mux):
    cfg = make(n_mux)
    params = M.init_params(jax.random.PRNGKey(0), cfg)
    ids, _ = inputs(cfg)
    out = M.forward(params, cfg, ids)
    B = 2
    assert out["hidden"].shape == (B, n_mux, cfg.seq_len, cfg.d_model)
    assert out["cls"].shape == (B, n_mux, cfg.n_classes)
    assert out["token"].shape == (B, n_mux, cfg.seq_len, cfg.n_classes)
    assert out["retrieval"].shape == (B, n_mux, cfg.seq_len, cfg.vocab_size)


@pytest.mark.parametrize("mux", ["hadamard", "ortho", "binary"])
@pytest.mark.parametrize("demux", ["index_embed", "mlp"])
def test_pallas_matches_ref_path(mux, demux):
    cfg = make(4, mux_strategy=mux, demux_strategy=demux)
    params = M.init_params(jax.random.PRNGKey(1), cfg)
    ids, _ = inputs(cfg)
    ref_out = M.forward(params, cfg, ids)
    pal_out = M.forward(params, dataclasses.replace(cfg, use_pallas=True), ids)
    for k in ("cls", "token"):
        np.testing.assert_allclose(ref_out[k], pal_out[k], rtol=1e-4, atol=1e-4)


def test_input_layout_prefix_tokens():
    cfg = make(3)
    ids, content = inputs(cfg)
    # prefix region: [EPS]*i [IDX_i] [EPS]* then content
    assert ids.shape[-1] == cfg.n_mux + cfg.seq_len
    for i in range(3):
        row = np.asarray(ids[0, i])
        assert row[i] == C.idx_token(i)
        for j in range(3):
            if j != i:
                assert row[j] == C.EPS_PAD_ID
        np.testing.assert_array_equal(row[3:], np.asarray(content[0, i]))


def test_n1_identity_mux_recovers_single_model():
    """N=1 with identity mux == unmuxed transformer on the same tokens."""
    cfg = make(1, mux_strategy="identity")
    params = M.init_params(jax.random.PRNGKey(2), cfg)
    ids, _ = inputs(cfg, batch=1)
    out = M.forward(params, cfg, ids)
    assert np.isfinite(np.asarray(out["cls"])).all()


def test_mux_order_sensitivity_end_to_end():
    """Swapping two instances changes their (slot-indexed) outputs —
    the model is order-dependent, unlike mixup."""
    cfg = make(2)
    params = M.init_params(jax.random.PRNGKey(3), cfg)
    ids, content = inputs(cfg, batch=1)
    out_a = M.forward(params, cfg, ids)
    swapped = content[:, ::-1, :]
    out_b = M.forward(params, cfg, M.assemble_input(cfg, swapped))
    # instance 0's logits should move to slot 1
    a0 = np.asarray(out_a["cls"][0, 0])
    b1 = np.asarray(out_b["cls"][0, 1])
    # not exactly equal (different mux vector), but correlated with itself
    # more than with the other instance's logits
    a1 = np.asarray(out_a["cls"][0, 1])
    assert not np.allclose(a0, a1, atol=1e-3)


def test_trainable_mask_freezes_mux():
    cfg = make(2)
    params = M.init_params(jax.random.PRNGKey(4), cfg)
    mask = M.trainable_mask(params, cfg)
    assert all(float(l) == 0.0 for l in jax.tree_util.tree_leaves(mask["mux"]))
    cfg2 = make(2, mux_strategy="learned_hadamard")
    params2 = M.init_params(jax.random.PRNGKey(4), cfg2)
    mask2 = M.trainable_mask(params2, cfg2)
    assert all(float(l) == 1.0 for l in jax.tree_util.tree_leaves(mask2["mux"]))


def test_ortho_mux_matrices_are_orthogonal():
    cfg = make(3, mux_strategy="ortho")
    params = M.init_params(jax.random.PRNGKey(5), cfg)
    mats = np.asarray(params["mux"]["mats"])
    for m in mats:
        np.testing.assert_allclose(m @ m.T, np.eye(cfg.d_model), atol=1e-4)


def test_prefix_builder_matches_rust_contract():
    """Pinned layout shared with rust/src/tokenizer (prefix_shape test)."""
    pref = M.build_prefix(4)
    assert pref[0] == [C.idx_token(0), C.EPS_PAD_ID, C.EPS_PAD_ID, C.EPS_PAD_ID]
    assert pref[2] == [C.EPS_PAD_ID, C.EPS_PAD_ID, C.idx_token(2), C.EPS_PAD_ID]


@pytest.mark.parametrize("arch,mux", [("mlp", "identity"), ("mlp", "ortho"),
                                      ("mlp", "lowrank"), ("cnn", "ortho"),
                                      ("cnn", "rotation"), ("cnn", "random_kernel"),
                                      ("cnn", "nonlinear")])
def test_image_models_forward(arch, mux):
    cfg = C.ImageModelConfig(arch=arch, n_mux=2, mux_strategy=mux)
    params = M.init_image_params(jax.random.PRNGKey(0), cfg)
    xs = jnp.asarray(np.random.RandomState(0).rand(3, 2, 20, 20), jnp.float32)
    out = M.image_forward(params, cfg, xs)
    assert out.shape == (3, 2, 10)
    assert np.isfinite(np.asarray(out)).all()
    assert (np.abs(np.asarray(out)) <= 1.0 + 1e-6).all(), "tanh outputs"


def test_image_nonlinear_width_multiplier():
    cfg = C.ImageModelConfig(arch="cnn", n_mux=2, mux_strategy="nonlinear", mux_width=4)
    params = M.init_image_params(jax.random.PRNGKey(0), cfg)
    xs = jnp.asarray(np.random.RandomState(1).rand(2, 2, 20, 20), jnp.float32)
    out = M.image_forward(params, cfg, xs)
    assert out.shape == (2, 2, 10)
