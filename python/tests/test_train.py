"""Trainer: Adam semantics, loss masking, short-training smoke."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import config as C
from compile import data as D
from compile import model as M
from compile import train as T


def test_adam_converges_on_quadratic():
    params = {"x": jnp.asarray([5.0, -3.0])}
    mask = {"x": jnp.ones(2)}
    opt = T.adam_init(params)
    loss = lambda p: (p["x"] ** 2).sum()
    g = jax.grad(loss)
    for _ in range(300):
        opt, params = T.adam_update(opt, g(params), params, mask, lr=0.1)
    assert float(loss(params)) < 1e-3


def test_adam_respects_mask():
    params = {"a": jnp.asarray(4.0), "b": jnp.asarray(4.0)}
    mask = {"a": jnp.asarray(1.0), "b": jnp.asarray(0.0)}
    opt = T.adam_init(params)
    g = jax.grad(lambda p: (p["a"] ** 2 + p["b"] ** 2))
    for _ in range(50):
        opt, params = T.adam_update(opt, g(params), params, mask, lr=0.1)
    assert float(params["a"]) != pytest.approx(4.0)
    assert float(params["b"]) == pytest.approx(4.0), "masked leaf frozen"


def test_adam_clips_global_norm():
    params = {"x": jnp.asarray([0.0])}
    mask = {"x": jnp.ones(1)}
    opt = T.adam_init(params)
    huge = {"x": jnp.asarray([1e9])}
    opt, new = T.adam_update(opt, huge, params, mask, lr=1.0, clip=1.0)
    assert np.isfinite(float(new["x"][0]))
    assert abs(float(new["x"][0])) < 10.0


def test_token_loss_ignores_specials():
    cfg = C.profile("tiny", n_mux=1, seq_len=8, task="token", n_classes=5)
    B, N, L = 2, 1, 8
    logits = jnp.zeros((B, N, L, 5))
    labels = jnp.zeros((B, N, L), jnp.int32)
    ids = jnp.full((B, N, L), C.PAD_ID, jnp.int32)
    out = {"token": logits}
    # all padding -> denominator guard, loss finite
    loss = T.token_loss(out, labels, ids)
    assert np.isfinite(float(loss))


def test_retrieval_loss_decreases_with_training():
    cfg = C.profile("tiny", n_mux=2, seq_len=12, d_model=64, d_ff=128)
    res0 = T.warmup(cfg, steps=5, batch=4, seed=0, log_every=1)
    res1 = T.warmup(cfg, steps=120, batch=4, seed=0, log_every=119)
    # accuracy after 120 steps must beat 5 steps
    assert res1.warmup_acc > res0.warmup_acc


def test_finetune_resizes_heads_for_task():
    cfg = C.profile("tiny", n_mux=1, seq_len=12, n_classes=3, d_model=64, d_ff=128)
    params = M.init_params(jax.random.PRNGKey(0), cfg)
    t = T.finetune(cfg, params, "sst2", steps=3, batch=4, seed=0)
    assert t.cfg.n_classes == 2
    assert t.params["head_cls"]["w"].shape[-1] == 2


def test_eval_task_returns_per_index():
    cfg = C.profile("tiny", n_mux=3, seq_len=12, d_model=64, d_ff=128)
    params = M.init_params(jax.random.PRNGKey(0), cfg)
    acc, per_index = T.eval_task(params, cfg, "mnli", n_eval=96, batch=4)
    assert per_index.shape == (3,)
    assert 0.0 <= acc <= 1.0


def test_image_training_beats_chance_quickly():
    cfg = C.ImageModelConfig(arch="mlp", n_mux=1, mux_strategy="identity")
    _, acc, per_index = T.train_image(cfg, steps=300, batch=32, seed=0,
                                      train_size=2000, n_eval=640)
    assert acc > 0.5, f"MLP N=1 should beat 10% chance easily, got {acc}"
    assert per_index.shape == (1,)


def test_pack_groups_shapes():
    rng = np.random.RandomState(0)
    ids = np.arange(40 * 8).reshape(40, 8).astype(np.int32)
    labels = np.arange(40).astype(np.int32)
    gids, glab = T.pack_groups(rng, ids, labels, batch=3, n_mux=4)
    assert gids.shape == (3, 4, 8)
    assert glab.shape == (3, 4)
    tok_labels = np.zeros((40, 8), np.int32)
    _, glab2 = T.pack_groups(rng, ids, tok_labels, batch=3, n_mux=4)
    assert glab2.shape == (3, 4, 8)
