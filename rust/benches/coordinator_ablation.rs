//! Coordinator ablations (DESIGN.md per-experiment index, last rows):
//! the design choices the paper's serving deployment would tune.
//!
//!   1. batcher deadline (max_wait) vs throughput and padding waste
//!   2. slot policy: Fill vs RotateOffset (paper A3: per-index accuracy
//!      varies, so spreading load across slots costs nothing here and
//!      equalizes exposure)
//!   3. coordinator overhead: group formation + demux routing time with
//!      the model execution subtracted (target: <5% of execute time)
//!
//!   cargo bench --bench coordinator_ablation

use std::sync::Arc;
use std::time::Duration;

use datamux::coordinator::{CoordinatorConfig, MuxCoordinator, SlotPolicy, Submit};
use datamux::runtime::{default_artifacts_dir, ArtifactManifest, ModelRuntime};
use datamux::util::bench::{write_results, Table};
use datamux::util::json::{arr, num, obj, s};
use datamux::workload::{closed_loop, RandomWorkload};

fn main() -> anyhow::Result<()> {
    let manifest = ArtifactManifest::load(default_artifacts_dir())?;
    let rt = ModelRuntime::cpu()?;
    // smallest N>1 artifact: fast executions isolate coordinator costs
    let meta = manifest
        .artifacts
        .iter()
        .filter(|a| !a.trained && a.n_mux >= 4)
        .min_by_key(|a| (a.d_model, a.n_mux, a.batch))
        .expect("run `make artifacts`");
    println!("artifact: {} (N={}, B={})", meta.name, meta.n_mux, meta.batch);
    let mut results = Vec::new();

    // ----- 1. deadline sweep -------------------------------------------
    let mut t1 = Table::new(
        "ablation: batcher deadline (8 clients closed loop)",
        &["max_wait ms", "throughput r/s", "p95 latency", "padded slots/exec"],
    );
    for wait_ms in [0u64, 1, 2, 5, 10, 25] {
        let model = rt.load(meta)?;
        let coord = Arc::new(MuxCoordinator::start(
            model,
            CoordinatorConfig {
                max_wait: Duration::from_millis(wait_ms),
                ..Default::default()
            },
        )?);
        let mut w = RandomWorkload::new(5, 200, meta.seq_len - 4);
        let rows: Vec<Vec<i32>> =
            (0..64).map(|_| w.framed_row(&coord.tokenizer, meta.seq_len)).collect();
        let report = closed_loop(&coord, &Arc::new(rows), 8, 40);
        let c = coord.stats.counters.snapshot();
        let execs = (c.groups_executed / meta.batch as u64).max(1);
        let lat = coord.stats.e2e_latency.summary();
        t1.row(&[
            wait_ms.to_string(),
            format!("{:.1}", report.throughput_rps),
            datamux::util::metrics::fmt_ns(lat.p95_ns),
            format!("{:.1}", c.slots_padded as f64 / execs as f64),
        ]);
        results.push(obj(vec![
            ("ablation", s("deadline")),
            ("max_wait_ms", num(wait_ms as f64)),
            ("throughput_rps", num(report.throughput_rps)),
            ("p95_ns", num(lat.p95_ns as f64)),
            ("padded_per_exec", num(c.slots_padded as f64 / execs as f64)),
        ]));
    }
    t1.print();

    // ----- 2. slot policy ------------------------------------------------
    let mut t2 = Table::new(
        "ablation: slot assignment policy",
        &["policy", "throughput r/s", "distinct slots used"],
    );
    for (name, policy) in [("Fill", SlotPolicy::Fill), ("RotateOffset", SlotPolicy::RotateOffset)] {
        let model = rt.load(meta)?;
        let coord = Arc::new(MuxCoordinator::start(
            model,
            CoordinatorConfig {
                max_wait: Duration::from_millis(2),
                slot_policy: policy,
                ..Default::default()
            },
        )?);
        let mut w = RandomWorkload::new(6, 200, meta.seq_len - 4);
        let rows: Vec<Vec<i32>> =
            (0..64).map(|_| w.framed_row(&coord.tokenizer, meta.seq_len)).collect();
        // serial lone submissions expose slot placement
        let mut slots = std::collections::HashSet::new();
        let t0 = std::time::Instant::now();
        for i in 0..48 {
            let h = coord.submit_framed(rows[i % rows.len()].clone())?;
            slots.insert(h.wait()?.slot);
        }
        let tput = 48.0 / t0.elapsed().as_secs_f64();
        t2.row(&[name.to_string(), format!("{tput:.1}"), slots.len().to_string()]);
        results.push(obj(vec![
            ("ablation", s("slot_policy")),
            ("policy", s(name)),
            ("throughput_rps", num(tput)),
            ("distinct_slots", num(slots.len() as f64)),
        ]));
    }
    t2.print();

    // ----- 3. coordinator overhead ---------------------------------------
    // exec-only time (direct run_ids) vs end-to-end through the coordinator
    let model = rt.load(meta)?;
    let direct = {
        let ids = vec![1i32; meta.ids_len()];
        let stats = datamux::util::bench::bench("direct", 3, 20, || {
            model.run_ids(&ids).unwrap();
        });
        stats.mean
    };
    let coord = Arc::new(MuxCoordinator::start(
        model,
        CoordinatorConfig { max_wait: Duration::from_millis(0), ..Default::default() },
    )?);
    let mut w = RandomWorkload::new(8, 200, meta.seq_len - 4);
    let rows: Vec<Vec<i32>> =
        (0..64).map(|_| w.framed_row(&coord.tokenizer, meta.seq_len)).collect();
    let rows = Arc::new(rows);
    let capacity = meta.batch * meta.n_mux;
    let e2e = datamux::util::bench::bench("through-coordinator", 2, 10, || {
        // saturate one full execution's worth of requests
        let handles: Vec<_> = (0..capacity)
            .map(|i| coord.submit_framed(rows[i % rows.len()].clone()).unwrap())
            .collect();
        for h in handles {
            h.wait().expect("response");
        }
    });
    let overhead = (e2e.mean.as_secs_f64() - direct.as_secs_f64()).max(0.0);
    let pct = 100.0 * overhead / direct.as_secs_f64();
    let mut t3 = Table::new("ablation: coordinator overhead per execution",
                            &["exec only", "through coordinator", "overhead", "% of exec"]);
    t3.row(&[
        format!("{direct:?}"),
        format!("{:?}", e2e.mean),
        format!("{:.2?}", Duration::from_secs_f64(overhead)),
        format!("{pct:.1}%"),
    ]);
    t3.print();
    results.push(obj(vec![
        ("ablation", s("overhead")),
        ("direct_s", num(direct.as_secs_f64())),
        ("e2e_s", num(e2e.mean.as_secs_f64())),
        ("overhead_pct", num(pct)),
    ]));

    write_results("coordinator_ablation.json", obj(vec![("rows", arr(results))]))?;
    Ok(())
}
