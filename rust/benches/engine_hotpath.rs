//! Engine hot-path bench: coordinator overhead, measured with **zero
//! artifacts** (FakeBackend) so it runs anywhere — laptops, CI — and the
//! repo finally has a PR-over-PR perf trajectory.
//!
//! Two layers of measurement:
//!
//! 1. **legacy vs hot micro-benches** — the pre-change request path is
//!    reimplemented inline (one mutex round-trip per intake item, full
//!    pad/prefix tensor re-derivation per execution, per-request logits
//!    `to_vec`) and raced against the shipped path
//!    (`Channel::recv_up_to` wave drains, `MuxTemplate::stamp`, shared
//!    `LogitsView` demux). This keeps the pre-refactor baseline a live,
//!    machine-local number instead of a stale constant.
//! 2. **engine end-to-end** — a full batch pass through the real
//!    coordinator over FakeBackend, reporting non-execute ns/request
//!    (wall minus measured backend time), batcher wave sizes, scratch
//!    reallocations, and the queue-wait histogram.
//!
//! Results are printed as a table and written to `BENCH_engine.json` at
//! the repo root. The bench exits non-zero if it produces no results.
//!
//!   cargo bench --bench engine_hotpath            # full
//!   cargo bench --bench engine_hotpath -- --quick # CI-sized

use std::hint::black_box;
use std::sync::Arc;
use std::time::Instant;

use datamux::coordinator::scheduler::MuxTemplate;
use datamux::coordinator::{EngineBuilder, LogitsView, SlotPolicy, Submit};
use datamux::runtime::{FakeBackend, InferenceBackend};
use datamux::tokenizer::{default_vocab, Tokenizer};
use datamux::util::bench::Table;
use datamux::util::json::{num, obj, s, Json};
use datamux::util::threadpool::Channel;
use datamux::workload::{batch_pass, RandomWorkload};

const N_MUX: usize = 8;
const BATCH: usize = 4;
const SEQ_LEN: usize = 32;
const N_CLASSES: usize = 4;

fn median_ns(samples: &mut [f64]) -> f64 {
    samples.sort_by(|a, b| a.partial_cmp(b).unwrap());
    samples[samples.len() / 2]
}

/// ns/item to drain `n_items` preloaded items one `recv()` at a time
/// (the pre-change batcher: one lock + wakeup bookkeeping per request).
fn bench_intake_legacy(n_items: usize, samples: usize) -> f64 {
    let mut out = Vec::with_capacity(samples);
    for _ in 0..samples {
        let c: Channel<u64> = Channel::bounded(n_items);
        for i in 0..n_items {
            c.send(i as u64).unwrap();
        }
        let t0 = Instant::now();
        for _ in 0..n_items {
            black_box(c.recv().unwrap());
        }
        out.push(t0.elapsed().as_nanos() as f64 / n_items as f64);
    }
    median_ns(&mut out)
}

/// ns/item to drain the same backlog in capacity-sized waves
/// (`recv_up_to`: one lock acquisition per wave).
fn bench_intake_hot(n_items: usize, wave: usize, samples: usize) -> f64 {
    let mut out = Vec::with_capacity(samples);
    for _ in 0..samples {
        let c: Channel<u64> = Channel::bounded(n_items);
        for i in 0..n_items {
            c.send(i as u64).unwrap();
        }
        let mut buf: Vec<u64> = Vec::with_capacity(wave);
        let t0 = Instant::now();
        let mut got = 0usize;
        while got < n_items {
            buf.clear();
            got += c.try_recv_up_to(&mut buf, wave);
            black_box(buf.last());
        }
        out.push(t0.elapsed().as_nanos() as f64 / n_items as f64);
    }
    median_ns(&mut out)
}

/// ns/request to assemble one execution's ids tensor the pre-change way:
/// re-derive every pad row and slot prefix from the tokenizer, then
/// place the requests.
fn bench_assembly_legacy(
    tok: &Tokenizer,
    rows: &[Vec<i32>],
    input_len: usize,
    iters: usize,
) -> f64 {
    let prefix_len = N_MUX;
    let capacity = BATCH * N_MUX;
    let mut scratch: Vec<i32> = Vec::new();
    let mut samples = Vec::with_capacity(iters);
    for _ in 0..iters {
        let t0 = Instant::now();
        scratch.clear();
        scratch.resize(capacity * input_len, tok.vocab.pad);
        let pad_row = tok.pad_row(SEQ_LEN);
        for g in 0..BATCH {
            for slot in 0..N_MUX {
                let start = ((g * N_MUX) + slot) * input_len;
                let row = &mut scratch[start..start + input_len];
                for (j, p) in row[..prefix_len].iter_mut().enumerate() {
                    *p = if j == slot {
                        tok.vocab.idx_base + slot as i32
                    } else {
                        tok.vocab.eps_pad
                    };
                }
                row[prefix_len..].copy_from_slice(&pad_row);
            }
        }
        for (pos, content) in rows.iter().enumerate() {
            let start = pos * input_len + prefix_len;
            scratch[start..start + SEQ_LEN].copy_from_slice(content);
        }
        samples.push(t0.elapsed().as_nanos() as f64 / capacity as f64);
        black_box(&scratch);
    }
    median_ns(&mut samples)
}

/// ns/request with the precomputed template: one bulk stamp + placement.
fn bench_assembly_hot(
    template: &MuxTemplate,
    rows: &[Vec<i32>],
    input_len: usize,
    iters: usize,
) -> f64 {
    let capacity = template.capacity();
    let mut scratch: Vec<i32> = Vec::with_capacity(template.ids_len());
    let mut samples = Vec::with_capacity(iters);
    for _ in 0..iters {
        let t0 = Instant::now();
        template.stamp(&mut scratch);
        for (pos, content) in rows.iter().enumerate() {
            let start = pos * input_len + template.prefix_len;
            scratch[start..start + SEQ_LEN].copy_from_slice(content);
        }
        samples.push(t0.elapsed().as_nanos() as f64 / capacity as f64);
        black_box(&scratch);
    }
    median_ns(&mut samples)
}

/// ns/request to demux one execution's output the pre-change way: one
/// `to_vec` allocation + copy per request.
fn bench_demux_legacy(out: &[f32], slot_len: usize, iters: usize) -> f64 {
    let capacity = BATCH * N_MUX;
    let mut samples = Vec::with_capacity(iters);
    for _ in 0..iters {
        let t0 = Instant::now();
        for r in 0..capacity {
            let off = r * slot_len;
            black_box(out[off..off + slot_len].to_vec());
        }
        samples.push(t0.elapsed().as_nanos() as f64 / capacity as f64);
    }
    median_ns(&mut samples)
}

/// ns/request with shared views: one per-batch buffer conversion, then a
/// refcount bump + offset per request.
fn bench_demux_hot(out: &[f32], slot_len: usize, iters: usize) -> f64 {
    let capacity = BATCH * N_MUX;
    let mut samples = Vec::with_capacity(iters);
    for _ in 0..iters {
        let t0 = Instant::now();
        // the per-batch bulk copy is charged to the hot path (the real
        // scheduler does `Vec -> Arc<[f32]>` once per execution)
        let shared: Arc<[f32]> = out.to_vec().into();
        for r in 0..capacity {
            black_box(LogitsView::shared(shared.clone(), r * slot_len, slot_len));
        }
        samples.push(t0.elapsed().as_nanos() as f64 / capacity as f64);
    }
    median_ns(&mut samples)
}

fn main() -> anyhow::Result<()> {
    let quick = std::env::args().any(|a| a == "--quick");
    let (intake_items, micro_iters, e2e_batches) =
        if quick { (512, 60, 16) } else { (4096, 400, 64) };
    let capacity = BATCH * N_MUX;

    let backend = FakeBackend::new("cls", N_MUX, BATCH, SEQ_LEN, N_CLASSES);
    let meta = backend.meta().clone();
    let input_len = meta.input_len;
    let slot_len = N_CLASSES;
    let tok = Tokenizer::new(default_vocab(), meta.vocab_size);
    let template = MuxTemplate::new(&meta, &tok);
    let mut w = RandomWorkload::new(13, 200, SEQ_LEN - 4);
    let rows: Vec<Vec<i32>> = (0..capacity).map(|_| w.framed_row(&tok, SEQ_LEN)).collect();

    // ----- legacy vs hot micro-benches ---------------------------------
    let intake_legacy = bench_intake_legacy(intake_items, 7);
    let intake_hot = bench_intake_hot(intake_items, capacity, 7);
    let asm_legacy = bench_assembly_legacy(&tok, &rows, input_len, micro_iters);
    let asm_hot = bench_assembly_hot(&template, &rows, input_len, micro_iters);
    let exec_out = vec![0.25f32; capacity * slot_len];
    let demux_legacy = bench_demux_legacy(&exec_out, slot_len, micro_iters);
    let demux_hot = bench_demux_hot(&exec_out, slot_len, micro_iters);
    let coord_legacy = intake_legacy + asm_legacy + demux_legacy;
    let coord_hot = intake_hot + asm_hot + demux_hot;

    let mut t = Table::new(
        "engine hot path: coordinator ns/request (legacy = pre-change path)",
        &["stage", "legacy ns/req", "hot ns/req", "speedup"],
    );
    let speedup = |l: f64, h: f64| if h > 0.0 { l / h } else { f64::INFINITY };
    for (name, l, h) in [
        ("intake", intake_legacy, intake_hot),
        ("assembly", asm_legacy, asm_hot),
        ("demux", demux_legacy, demux_hot),
        ("total", coord_legacy, coord_hot),
    ] {
        t.row(&[
            name.to_string(),
            format!("{l:.0}"),
            format!("{h:.0}"),
            format!("{:.2}x", speedup(l, h)),
        ]);
    }
    t.print();

    // ----- engine end-to-end over FakeBackend --------------------------
    // measured backend time, to subtract from the e2e wall clock
    let ids = vec![1i32; meta.ids_len()];
    let mut exec_samples: Vec<f64> = (0..micro_iters.max(20))
        .map(|_| {
            let t0 = Instant::now();
            black_box(backend.run_ids(&ids).unwrap());
            t0.elapsed().as_nanos() as f64
        })
        .collect();
    let exec_ns_per_batch = median_ns(&mut exec_samples);

    let total = capacity * e2e_batches;
    let engine = Arc::new(
        EngineBuilder::new()
            .max_wait_ms(2)
            .queue_cap(total + 8)
            .slot_policy(SlotPolicy::Fill)
            .build_backend(Arc::new(FakeBackend::new(
                "cls", N_MUX, BATCH, SEQ_LEN, N_CLASSES,
            )))?,
    );
    let report = batch_pass(&engine, &rows, total);
    anyhow::ensure!(
        report.completed == total,
        "e2e pass lost requests: {} of {total}",
        report.completed
    );
    let c = engine.counters();
    let qw = engine.queue_wait();
    let execs = (c.groups_executed / BATCH as u64).max(1);
    let e2e_ns_per_req = report.wall.as_nanos() as f64 / total as f64;
    let exec_ns_per_req = exec_ns_per_batch * execs as f64 / total as f64;
    let overhead_ns_per_req = (e2e_ns_per_req - exec_ns_per_req).max(0.0);
    let avg_wave = c.submitted as f64 / c.intake_waves.max(1) as f64;

    let mut t2 = Table::new(
        "engine e2e over FakeBackend (no artifacts)",
        &["metric", "value"],
    );
    for (k, v) in [
        ("requests", format!("{total}")),
        ("throughput r/s", format!("{:.0}", report.throughput_rps)),
        ("e2e ns/req", format!("{e2e_ns_per_req:.0}")),
        ("exec ns/req (measured direct)", format!("{exec_ns_per_req:.0}")),
        ("coordinator overhead ns/req", format!("{overhead_ns_per_req:.0}")),
        ("intake waves", format!("{}", c.intake_waves)),
        ("avg requests/wave", format!("{avg_wave:.1}")),
        ("scratch reallocs", format!("{}", c.scratch_reallocs)),
        ("queue-wait p50", datamux::util::metrics::fmt_ns(qw.p50_ns)),
        ("queue-wait p99", datamux::util::metrics::fmt_ns(qw.p99_ns)),
    ] {
        t2.row(&[k.to_string(), v]);
    }
    t2.print();

    // ----- BENCH_engine.json at the repo root --------------------------
    let result = obj(vec![
        ("schema", s("engine_hotpath/v1")),
        ("quick", Json::Bool(quick)),
        (
            "config",
            obj(vec![
                ("n_mux", num(N_MUX as f64)),
                ("batch", num(BATCH as f64)),
                ("seq_len", num(SEQ_LEN as f64)),
                ("n_classes", num(N_CLASSES as f64)),
                ("requests", num(total as f64)),
            ]),
        ),
        (
            "legacy_ns_per_request",
            obj(vec![
                ("intake", num(intake_legacy)),
                ("assembly", num(asm_legacy)),
                ("demux", num(demux_legacy)),
                ("coordinator", num(coord_legacy)),
            ]),
        ),
        (
            "hot_ns_per_request",
            obj(vec![
                ("intake", num(intake_hot)),
                ("assembly", num(asm_hot)),
                ("demux", num(demux_hot)),
                ("coordinator", num(coord_hot)),
            ]),
        ),
        ("speedup_vs_legacy", num(speedup(coord_legacy, coord_hot))),
        (
            "engine",
            obj(vec![
                ("throughput_rps", num(report.throughput_rps)),
                ("e2e_ns_per_request", num(e2e_ns_per_req)),
                ("exec_ns_per_request", num(exec_ns_per_req)),
                ("overhead_ns_per_request", num(overhead_ns_per_req)),
                ("intake_waves", num(c.intake_waves as f64)),
                ("avg_requests_per_wave", num(avg_wave)),
                ("scratch_reallocs", num(c.scratch_reallocs as f64)),
                ("queue_wait_p50_ns", num(qw.p50_ns as f64)),
                ("queue_wait_p99_ns", num(qw.p99_ns as f64)),
            ]),
        ),
    ]);
    let root = std::path::Path::new(env!("CARGO_MANIFEST_DIR"))
        .parent()
        .expect("crate sits one level below the repo root");
    let path = root.join("BENCH_engine.json");
    std::fs::write(&path, result.to_pretty())?;

    // self-check: the file must exist, parse, and carry results —
    // CI fails the job otherwise
    let written = std::fs::read_to_string(&path)?;
    let parsed = Json::parse(&written).map_err(|e| anyhow::anyhow!("reparse: {e}"))?;
    anyhow::ensure!(
        parsed.get("engine").and_then(|e| e.get("e2e_ns_per_request")).is_some()
            && parsed.get("speedup_vs_legacy").and_then(Json::as_f64).is_some(),
        "BENCH_engine.json is missing results"
    );
    println!(
        "\nwrote {} (coordinator speedup vs pre-change path: {:.2}x)",
        path.display(),
        speedup(coord_legacy, coord_hot)
    );
    // the acceptance gate: the hot path must stay >=2x cheaper than the
    // pre-change path, or this bench (and the CI job) fails
    anyhow::ensure!(
        speedup(coord_legacy, coord_hot) >= 2.0,
        "hot-path regression: coordinator speedup vs legacy is {:.2}x (< 2x gate); \
         legacy={coord_legacy:.0}ns/req hot={coord_hot:.0}ns/req",
        speedup(coord_legacy, coord_hot)
    );
    Ok(())
}
