//! Fig 12 reproduction: inference memory overhead vs N.
//!
//! Paper: with a fixed minibatch of 60 inputs, GPU memory grows linearly
//! in N but with a very gentle slope — ~4x at N=40 vs N=1 — because only
//! the demultiplexing inputs grow with N while the backbone activation
//! footprint is fixed.
//!
//! Ours, on the CPU plugin, two measurements per N:
//!   * analytic: weights + model I/O bytes from the artifact metadata
//!     (the component the paper attributes the growth to), and
//!   * RSS delta: process resident-set growth across load + execute
//!     (captures XLA temp buffers).
//!
//!   cargo bench --bench fig12_memory

use datamux::runtime::{default_artifacts_dir, ArtifactManifest, ModelRuntime};
use datamux::util::bench::{write_results, Table};
use datamux::util::json::{arr, num, obj, s};

fn rss_bytes() -> usize {
    // /proc/self/statm: pages; field 1 = resident
    let statm = std::fs::read_to_string("/proc/self/statm").unwrap_or_default();
    let resident_pages: usize = statm
        .split_whitespace()
        .nth(1)
        .and_then(|s| s.parse().ok())
        .unwrap_or(0);
    resident_pages * 4096
}

fn main() -> anyhow::Result<()> {
    let manifest = ArtifactManifest::load(default_artifacts_dir())?;
    let rt = ModelRuntime::cpu()?;
    let profile = std::env::var("BENCH_PROFILE").unwrap_or_else(|_| "base".into());
    // paper fixes the minibatch at 60 sequences; our closest fixed lane is
    // batch=8 mux-rows per execution for every N
    let batch = 8;

    let mut table = Table::new(
        &format!("Fig 12: memory vs N ({profile}, fixed batch {batch})"),
        &["N", "weights MB", "io KB", "analytic ratio", "rss delta MB", "rss ratio"],
    );
    let mut rows_json = Vec::new();
    let mut base_analytic: Option<f64> = None;
    let mut base_rss: Option<f64> = None;

    for n in [1usize, 2, 5, 10, 20, 40] {
        let Some(meta) = manifest.timing(&profile, n, batch) else { continue };
        let rss0 = rss_bytes();
        let model = rt.load(meta)?;
        // run a few times so XLA temp allocations are materialized
        let ids = vec![1i32; meta.ids_len()];
        for _ in 0..3 {
            model.run_ids(&ids)?;
        }
        let rss_delta = rss_bytes().saturating_sub(rss0) as f64;
        let analytic = model.approx_device_bytes() as f64;
        let aratio = match base_analytic {
            None => {
                base_analytic = Some(analytic);
                1.0
            }
            Some(b) => analytic / b,
        };
        let rratio = match base_rss {
            None => {
                base_rss = Some(rss_delta.max(1.0));
                1.0
            }
            Some(b) => rss_delta / b,
        };
        table.row(&[
            n.to_string(),
            format!("{:.1}", model.weight_bytes as f64 / 1e6),
            format!("{:.1}", (meta.ids_len() * 4 + meta.output_len() * 4) as f64 / 1e3),
            format!("{aratio:.2}x"),
            format!("{:.1}", rss_delta / 1e6),
            format!("{rratio:.2}x"),
        ]);
        rows_json.push(obj(vec![
            ("n_mux", num(n as f64)),
            ("weights_bytes", num(model.weight_bytes as f64)),
            ("analytic_ratio", num(aratio)),
            ("rss_delta_bytes", num(rss_delta)),
            ("rss_ratio", num(rratio)),
        ]));
        drop(model); // keep the sequence comparable (allocator reuse noted)
    }
    table.print();
    println!("paper: memory at N=40 is ~4x N=1 (gentle linear growth)");
    write_results(
        "fig12_memory.json",
        obj(vec![("profile", s(&profile)), ("rows", arr(rows_json))]),
    )?;
    Ok(())
}
