//! Fig 4c reproduction: inference throughput vs number of multiplexed
//! instances N, normalized to the N=1 baseline.
//!
//! Paper setup: 20k MNLI instances, 4 batch sizes, max throughput taken
//! per N (A.8); 12L/768H T-MUX reaches 11x at N=20 and 18x at N=40 (the
//! shortfall from Nx is the prefix overhead: input_len = N + L).
//!
//! Ours: the `base` profile (4L/256H — DESIGN.md §Hardware-Adaptation) on
//! the PJRT CPU client, batch sizes {1,4,8}, closed-loop saturation. The
//! claim under test is the *shape*: monotone speedup with N, sublinear in
//! N with the gap tracking (N + L) / L.
//!
//!   cargo bench --bench fig4c_throughput
//!   BENCH_REQUESTS=4000 cargo bench --bench fig4c_throughput   # longer run

use std::sync::Arc;
use std::time::Duration;

use datamux::coordinator::{CoordinatorConfig, MuxCoordinator};
use datamux::runtime::{default_artifacts_dir, ArtifactManifest, ModelRuntime};
use datamux::util::bench::{write_results, Table};
use datamux::util::json::{arr, num, obj, s};
use datamux::workload::{batch_pass, RandomWorkload};

fn main() -> anyhow::Result<()> {
    let manifest = ArtifactManifest::load(default_artifacts_dir())?;
    let rt = ModelRuntime::cpu()?;
    let profile = std::env::var("BENCH_PROFILE").unwrap_or_else(|_| "base".into());
    let base_requests: usize = std::env::var("BENCH_REQUESTS")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(480);

    let ns = [1usize, 2, 5, 10, 20, 40];
    let batches = [1usize, 4, 8];
    let mut table = Table::new(
        &format!("Fig 4c: throughput vs N ({profile} profile, max over batch sizes)"),
        &["N", "input_len", "best B", "throughput r/s", "speedup", "ideal Nx", "prefix penalty"],
    );
    let mut rows_json = Vec::new();
    let mut base_tput: Option<f64> = None;

    for &n in &ns {
        let mut best: Option<(usize, f64)> = None;
        let mut input_len = 0;
        for &b in &batches {
            let Some(meta) = manifest.timing(&profile, n, b) else { continue };
            input_len = meta.input_len;
            let model = rt.load(meta)?;
            let coord = Arc::new(MuxCoordinator::start(
                model,
                CoordinatorConfig {
                    max_wait: Duration::from_millis(2),
                    queue_cap: 1 << 16,
                    ..Default::default()
                },
            )?);
            let mut w = RandomWorkload::new(5, 200, meta.seq_len - 4);
            let rows: Vec<Vec<i32>> =
                (0..128).map(|_| w.framed_row(&coord.tokenizer, meta.seq_len)).collect();
                        // enough requests to fill several executions at this capacity
            // offline dataset pass (paper A.8): all requests queued up
            // front so every mux group is full
            let requests = base_requests.max(meta.batch * meta.n_mux * 4);
            let report = batch_pass(&coord, &rows, requests);
            if best.map(|(_, t)| report.throughput_rps > t).unwrap_or(true) {
                best = Some((b, report.throughput_rps));
            }
        }
        let Some((b, tput)) = best else { continue };
        let speedup = match base_tput {
            None => {
                base_tput = Some(tput);
                1.0
            }
            Some(base) => tput / base,
        };
        // prefix penalty: the paper's explanation for sublinear speedup —
        // sequence grows from L to N + L
        let seq = input_len - n.min(input_len);
        let penalty = (n + seq) as f64 / seq as f64;
        table.row(&[
            n.to_string(),
            input_len.to_string(),
            b.to_string(),
            format!("{tput:.1}"),
            format!("{speedup:.2}x"),
            format!("{n}.00x"),
            format!("{penalty:.2}x"),
        ]);
        rows_json.push(obj(vec![
            ("n_mux", num(n as f64)),
            ("best_batch", num(b as f64)),
            ("throughput_rps", num(tput)),
            ("speedup", num(speedup)),
        ]));
    }
    table.print();
    println!("paper (12L/768H, RTX 2080): 11x @ N=20, 18x @ N=40 — shape: monotone, sublinear in N");
    write_results(
        "fig4c_throughput.json",
        obj(vec![("profile", s(&profile)), ("rows", arr(rows_json))]),
    )?;
    Ok(())
}
