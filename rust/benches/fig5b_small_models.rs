//! Fig 5b / Fig 4c-inset reproduction: smaller backbones multiplexed to
//! N=20 give *higher* relative speedup than the full model at N=40.
//!
//! Paper: 12L/384H and 4L/768H reach ~25x at N=20 vs 18x for 12L/768H at
//! N=40. Ours: `small_wide` (2L/256H) and `small_deep` (4L/128H) vs the
//! `base` profile — the claim under test is the crossover: small models
//! at N=20 beat base at N=20 in absolute throughput, and their speedup
//! curves sit above base's.
//!
//!   cargo bench --bench fig5b_small_models

use std::sync::Arc;
use std::time::Duration;

use datamux::coordinator::{CoordinatorConfig, MuxCoordinator};
use datamux::runtime::{default_artifacts_dir, ArtifactManifest, ModelRuntime};
use datamux::util::bench::{write_results, Table};
use datamux::util::json::{arr, num, obj, s};
use datamux::workload::{batch_pass, RandomWorkload};

fn measure(
    rt: &ModelRuntime,
    manifest: &ArtifactManifest,
    profile: &str,
    n: usize,
    batch: usize,
    base_requests: usize,
) -> anyhow::Result<Option<f64>> {
    let Some(meta) = manifest.timing(profile, n, batch) else {
        return Ok(None);
    };
    let model = rt.load(meta)?;
    let coord = Arc::new(MuxCoordinator::start(
        model,
        CoordinatorConfig {
            max_wait: Duration::from_millis(2),
            queue_cap: 1 << 16,
            ..Default::default()
        },
    )?);
    let mut w = RandomWorkload::new(5, 200, meta.seq_len - 4);
    let rows: Vec<Vec<i32>> =
        (0..128).map(|_| w.framed_row(&coord.tokenizer, meta.seq_len)).collect();
        // offline dataset pass (paper A.8): full mux groups
    let requests = base_requests.max(meta.batch * meta.n_mux * 4);
    let report = batch_pass(&coord, &rows, requests);
    Ok(Some(report.throughput_rps))
}

fn main() -> anyhow::Result<()> {
    let manifest = ArtifactManifest::load(default_artifacts_dir())?;
    let rt = ModelRuntime::cpu()?;
    let base_requests: usize = std::env::var("BENCH_REQUESTS")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(320);

    let ns = [1usize, 2, 5, 10, 20];
    let mut table = Table::new(
        "Fig 5b: small-backbone throughput (speedup vs own N=1 baseline)",
        &["profile", "N", "throughput r/s", "speedup", "vs base N=1"],
    );
    let mut rows_json = Vec::new();
    // base profile's N=1 as the cross-profile reference (batch 4 lane)
    let base_ref = measure(&rt, &manifest, "base", 1, 4, base_requests)?.unwrap_or(f64::NAN);

    for profile in ["base", "small_wide", "small_deep"] {
        let mut own_base: Option<f64> = None;
        for &n in &ns {
            let batch = 4;
            let Some(tput) = measure(&rt, &manifest, profile, n, batch, base_requests)? else {
                continue;
            };
            let speedup = match own_base {
                None => {
                    own_base = Some(tput);
                    1.0
                }
                Some(b) => tput / b,
            };
            table.row(&[
                profile.to_string(),
                n.to_string(),
                format!("{tput:.1}"),
                format!("{speedup:.2}x"),
                format!("{:.2}x", tput / base_ref),
            ]);
            rows_json.push(obj(vec![
                ("profile", s(profile)),
                ("n_mux", num(n as f64)),
                ("throughput_rps", num(tput)),
                ("speedup", num(speedup)),
                ("vs_base_n1", num(tput / base_ref)),
            ]));
        }
    }
    table.print();
    println!("paper: smaller T-MUX at N=20 reaches ~25x vs base N=1 (> base's 18x at N=40)");
    write_results("fig5b_small_models.json", obj(vec![("rows", arr(rows_json))]))?;
    Ok(())
}
