//! Latency under offered load: mux lane (large N) vs vanilla baseline
//! (N=1), open-loop Poisson arrivals.
//!
//! Not a paper figure per se — it is the serving consequence of Fig 4c
//! that a deployment actually cares about: the mux lane sustains rates
//! far beyond the baseline's saturation point while keeping tail latency
//! bounded, at the cost of a small queueing delay at low rates (waiting
//! for co-muxed peers).
//!
//!   cargo bench --bench latency_under_load

use std::sync::Arc;
use std::time::Duration;

use datamux::coordinator::{CoordinatorConfig, MuxCoordinator};
use datamux::runtime::{default_artifacts_dir, ArtifactManifest, ModelRuntime};
use datamux::util::bench::{write_results, Table};
use datamux::util::json::{arr, num, obj, s};
use datamux::util::metrics::fmt_ns;
use datamux::workload::{open_loop, RandomWorkload};

fn main() -> anyhow::Result<()> {
    let manifest = ArtifactManifest::load(default_artifacts_dir())?;
    let rt = ModelRuntime::cpu()?;
    let profile = std::env::var("BENCH_PROFILE").unwrap_or_else(|_| "base".into());
    let duration = Duration::from_secs_f64(
        std::env::var("BENCH_SECONDS").ok().and_then(|s| s.parse().ok()).unwrap_or(6.0),
    );

    // capacity estimate from one direct execution of the baseline
    let base_meta = manifest.timing(&profile, 1, 4).expect("N=1 B=4 artifact");
    let base_model = rt.load(base_meta)?;
    let ids = vec![1i32; base_meta.ids_len()];
    let t = datamux::util::bench::bench("probe", 2, 8, || {
        base_model.run_ids(&ids).unwrap();
    });
    let base_cap = base_meta.batch as f64 / t.mean.as_secs_f64();
    println!("baseline capacity ≈ {base_cap:.1} r/s (direct)");
    drop(base_model);

    let mut table = Table::new(
        &format!("latency under load ({profile}): N=1 baseline vs N=10 mux lane"),
        &["lane", "offered r/s", "completed", "rejected", "p50", "p95", "p99"],
    );
    let mut rows_json = Vec::new();

    for (lane, n) in [("baseline", 1usize), ("mux", 10)] {
        let meta = manifest.timing(&profile, n, 4).expect("artifact");
        for mult in [0.4, 0.8, 1.2, 2.0, 4.0] {
            let rate = base_cap * mult;
            let model = rt.load(meta)?;
            let coord = Arc::new(MuxCoordinator::start(
                model,
                CoordinatorConfig {
                    max_wait: Duration::from_millis(5),
                    queue_cap: 256,
                    ..Default::default()
                },
            )?);
            let mut w = RandomWorkload::new(17, 200, meta.seq_len - 4);
            let rows: Vec<Vec<i32>> =
                (0..128).map(|_| w.framed_row(&coord.tokenizer, meta.seq_len)).collect();
            let report = open_loop(&coord, &Arc::new(rows), rate, duration, 3);
            let lat = coord.stats.e2e_latency.summary();
            table.row(&[
                format!("{lane} N={n}"),
                format!("{rate:.0}"),
                report.completed.to_string(),
                report.rejected.to_string(),
                fmt_ns(lat.p50_ns),
                fmt_ns(lat.p95_ns),
                fmt_ns(lat.p99_ns),
            ]);
            rows_json.push(obj(vec![
                ("lane", s(lane)),
                ("n_mux", num(n as f64)),
                ("offered_rps", num(rate)),
                ("completed", num(report.completed as f64)),
                ("rejected", num(report.rejected as f64)),
                ("p50_ns", num(lat.p50_ns as f64)),
                ("p95_ns", num(lat.p95_ns as f64)),
                ("p99_ns", num(lat.p99_ns as f64)),
            ]));
        }
    }
    table.print();
    println!("expected shape: baseline saturates (rejections, unbounded tail) past ~1x;");
    println!("the N=10 lane absorbs 4x the baseline capacity with bounded p99.");
    write_results("latency_under_load.json", obj(vec![("rows", arr(rows_json))]))?;
    Ok(())
}
