//! Native forward bench: the repo's first *real* end-to-end throughput
//! number — actual T-MUX math (embedding + fused mux, attention, FFN,
//! demux, head) executed by `runtime/native` with zero artifacts and no
//! PJRT, swept over `n_mux ∈ {1,2,4,8,16,32}` in the shape of the
//! paper's Fig 4c throughput-vs-N curve. Every N is measured at both
//! weight precisions (f32 and int8) against the same random model.
//!
//! Three gates, all enforced wherever the bench runs (CI included):
//!
//! 1. **fused f32 ≥ 3x naive on AVX2+FMA hosts (≥ 2x scalar)** — at
//!    every N, the optimized forward (vectorized microkernel, fused mux,
//!    arena reuse, thread banding) must beat the naive unfused scalar
//!    reference (`native::reference`, the live in-bench baseline: same
//!    weights, same machine, measured in the same run — never a stale
//!    constant). The floor is 3x when the AVX2 microkernel is active and
//!    stays at the historical 2x for the scalar fallback
//!    (`DATAMUX_FORCE_SCALAR=1` or a non-AVX2 host).
//! 2. **int8 ≥ 1.5x f32 at equal N** on AVX2+FMA hosts (the scalar int8
//!    arm exists for parity, not speed, and is not gated).
//! 3. **arena_reallocs == 0 in steady state** — after warmup, timed
//!    forwards must not materialize new tensor arenas (both precisions).
//!
//! Each row also reports `gflops_peak_frac`: achieved GFLOP/s over a
//! theoretical machine peak derived from a measured clock estimate
//! (serialized-LCG timing loop) times the kernel's FLOPs/cycle/core.
//! The fraction is observability, not a gate — it tells you how far the
//! microkernel sits from the roofline on the host that ran CI.
//!
//! Results are printed as a table and written to `BENCH_native.json` at
//! the repo root (uploaded as a CI artifact next to `BENCH_engine.json`).
//!
//!   cargo bench --bench native_forward            # full
//!   cargo bench --bench native_forward -- --quick # CI-sized

use std::hint::black_box;
use std::time::Instant;

use datamux::runtime::native::{
    active_kernel, reference, synthetic_meta, Kernel, Precision, RawWeights,
};
use datamux::runtime::{InferenceBackend, NativeBackend, WeightsFile};
use datamux::util::bench::Table;
use datamux::util::json::{arr, num, obj, s, Json};

const NS: [usize; 6] = [1, 2, 4, 8, 16, 32];
const BATCH: usize = 2;
const SEQ_LEN: usize = 16;
const D_MODEL: usize = 128;
const N_LAYERS: usize = 2;
const N_HEADS: usize = 4;
const N_CLASSES: usize = 3;

fn median(samples: &mut [f64]) -> f64 {
    samples.sort_by(|a, b| a.partial_cmp(b).unwrap());
    samples[samples.len() / 2]
}

/// Clock estimate from a fully serialized LCG chain: each iteration is
/// one 64-bit multiply (3 cycles on every recent x86) feeding one add
/// (1 cycle), with no instruction-level parallelism to hide either, so
/// iterations/sec ≈ clock / 4. Good to ~10-20% across turbo states —
/// plenty for a reported roofline fraction.
fn estimate_ghz() -> f64 {
    const ITERS: u64 = 50_000_000;
    let mut x: u64 = 0x243F_6A88_85A3_08D3;
    let t0 = Instant::now();
    for _ in 0..ITERS {
        x = x.wrapping_mul(6364136223846793005).wrapping_add(1442695040888963407);
    }
    let dt = t0.elapsed().as_secs_f64();
    black_box(x);
    ITERS as f64 * 4.0 / dt / 1e9
}

/// Peak f32 FLOPs per cycle per core for the active kernel arm: AVX2+FMA
/// retires two 8-lane FMAs per cycle (2 * 8 * 2 = 32); the scalar arm is
/// credited one multiply + one add per cycle.
fn flops_per_cycle(kernel: Kernel) -> f64 {
    match kernel {
        Kernel::Avx2Fma => 32.0,
        Kernel::Scalar => 2.0,
    }
}

struct Measured {
    rps: f64,
    gflops: f64,
    ns_per_req: f64,
    fused_ns: f64,
    arena_delta: u64,
}

fn measure(
    backend: &NativeBackend,
    ids: &[i32],
    warmup: usize,
    iters: usize,
) -> anyhow::Result<Measured> {
    // warmup settles the tensor arena; the timed loop must not grow it
    for _ in 0..warmup {
        black_box(backend.run_ids(ids)?);
    }
    let arena_before = backend.arena_reallocs();
    let mut samples = Vec::with_capacity(iters);
    let t0 = Instant::now();
    for _ in 0..iters {
        let t1 = Instant::now();
        black_box(backend.run_ids(ids)?);
        samples.push(t1.elapsed().as_nanos() as f64);
    }
    let wall = t0.elapsed().as_secs_f64();
    let arena_delta = backend.arena_reallocs() - arena_before;
    let fused_ns = median(&mut samples);
    let requests_per_exec = (backend.dims().batch * backend.dims().n_mux) as f64;
    Ok(Measured {
        rps: requests_per_exec * iters as f64 / wall,
        gflops: backend.dims().flops() / fused_ns, // FLOP/ns == GFLOP/s
        ns_per_req: fused_ns / requests_per_exec,
        fused_ns,
        arena_delta,
    })
}

fn main() -> anyhow::Result<()> {
    let quick = std::env::args().any(|a| a == "--quick");
    let (warmup, iters, naive_iters): (usize, usize, usize) =
        if quick { (2, 5, 2) } else { (5, 30, 5) };

    let kernel = active_kernel();
    let ghz = estimate_ghz();

    let mut table = Table::new(
        "native T-MUX forward: throughput vs N (paper Fig 4c shape)",
        &[
            "N",
            "prec",
            "req/s",
            "vs N=1",
            "GFLOP/s",
            "peak frac",
            "ns/req",
            "naive ns/req",
            "fused speedup",
            "int8 vs f32",
            "arena reallocs",
        ],
    );
    let mut sweep = Vec::new();
    let mut base_rps = 0.0f64;
    let mut min_speedup = f64::INFINITY;
    let mut min_q8_speedup = f64::INFINITY;
    let mut steady_arena = 0u64;
    let mut peak_gflops = 0.0f64;

    for &n in &NS {
        let meta = synthetic_meta("cls", n, BATCH, SEQ_LEN, D_MODEL, N_LAYERS, N_HEADS, N_CLASSES);
        let raw = RawWeights::random(&meta, 2 * D_MODEL, 40 + n as u64);
        let backend =
            NativeBackend::from_weights(meta.clone(), WeightsFile::parse(raw.to_blob())?)?;
        // same model, int8 projection weights quantized online at pack
        let q8 = NativeBackend::from_weights_prec(
            meta.clone(),
            WeightsFile::parse(raw.to_blob())?,
            Precision::Int8,
        )?;
        let ids: Vec<i32> = (0..meta.ids_len())
            .map(|i| ((i * 131 + 7) % meta.vocab_size) as i32)
            .collect();

        // the machine peak is clock * flops/cycle * GEMM worker threads;
        // computed once per run (thread count is fixed across Ns)
        peak_gflops = ghz * flops_per_cycle(kernel) * backend.n_threads() as f64;

        let mf = measure(&backend, &ids, warmup, iters)?;
        let mq = measure(&q8, &ids, warmup, iters)?;

        // the live naive unfused baseline: identical weights and inputs,
        // scalar reference implementation, measured in this same run
        let mut nsamples = Vec::with_capacity(naive_iters);
        for _ in 0..naive_iters {
            let t1 = Instant::now();
            black_box(reference::forward(&raw, &meta, &ids)?);
            nsamples.push(t1.elapsed().as_nanos() as f64);
        }
        let naive_ns = median(&mut nsamples);
        let naive_ns_per_req = naive_ns / (BATCH * n) as f64;
        let speedup = naive_ns / mf.fused_ns;
        let q8_speedup = mf.fused_ns / mq.fused_ns;

        if n == NS[0] {
            base_rps = mf.rps;
        }
        min_speedup = min_speedup.min(speedup);
        min_q8_speedup = min_q8_speedup.min(q8_speedup);
        steady_arena += mf.arena_delta + mq.arena_delta;

        for (prec, m, fused_speedup, q8_vs_f32) in [
            ("f32", &mf, Some(speedup), None),
            ("int8", &mq, None, Some(q8_speedup)),
        ] {
            let frac = m.gflops / peak_gflops;
            table.row(&[
                format!("{n}"),
                prec.to_string(),
                format!("{:.0}", m.rps),
                format!("{:.2}x", m.rps / base_rps),
                format!("{:.2}", m.gflops),
                format!("{frac:.3}"),
                format!("{:.0}", m.ns_per_req),
                fused_speedup.map_or("-".into(), |_| format!("{naive_ns_per_req:.0}")),
                fused_speedup.map_or("-".into(), |x| format!("{x:.2}x")),
                q8_vs_f32.map_or("-".into(), |x| format!("{x:.2}x")),
                format!("{}", m.arena_delta),
            ]);
            let mut fields = vec![
                ("n_mux", num(n as f64)),
                ("precision", s(prec)),
                ("throughput_rps", num(m.rps)),
                ("speedup_vs_n1", num(m.rps / base_rps)),
                ("gflops", num(m.gflops)),
                ("gflops_peak_frac", num(frac)),
                ("ns_per_request", num(m.ns_per_req)),
                ("arena_reallocs", num(m.arena_delta as f64)),
            ];
            if fused_speedup.is_some() {
                fields.push(("naive_ns_per_request", num(naive_ns_per_req)));
                fields.push(("fused_speedup", num(speedup)));
            }
            if let Some(x) = q8_vs_f32 {
                fields.push(("int8_speedup_vs_f32", num(x)));
            }
            sweep.push(obj(fields));
        }
    }
    table.print();

    let result = obj(vec![
        ("schema", s("native_forward/v2")),
        ("quick", Json::Bool(quick)),
        ("kernel", s(kernel.name())),
        ("estimated_ghz", num(ghz)),
        ("peak_gflops", num(peak_gflops)),
        (
            "config",
            obj(vec![
                ("batch", num(BATCH as f64)),
                ("seq_len", num(SEQ_LEN as f64)),
                ("d_model", num(D_MODEL as f64)),
                ("n_layers", num(N_LAYERS as f64)),
                ("n_heads", num(N_HEADS as f64)),
                ("n_classes", num(N_CLASSES as f64)),
                ("iters", num(iters as f64)),
            ]),
        ),
        ("sweep", arr(sweep)),
        ("min_fused_speedup", num(min_speedup)),
        ("min_int8_speedup_vs_f32", num(min_q8_speedup)),
        ("steady_state_arena_reallocs", num(steady_arena as f64)),
    ]);
    let root = std::path::Path::new(env!("CARGO_MANIFEST_DIR"))
        .parent()
        .expect("crate sits one level below the repo root");
    let path = root.join("BENCH_native.json");
    std::fs::write(&path, result.to_pretty())?;

    // self-check: the file must exist, parse, and carry the sweep —
    // CI fails the job otherwise
    let written = std::fs::read_to_string(&path)?;
    let parsed = Json::parse(&written).map_err(|e| anyhow::anyhow!("reparse: {e}"))?;
    anyhow::ensure!(
        parsed.get("sweep").and_then(Json::as_arr).map_or(0, |a| a.len()) == 2 * NS.len()
            && parsed.get("min_fused_speedup").and_then(Json::as_f64).is_some(),
        "BENCH_native.json is missing results"
    );
    println!(
        "\nwrote {} (kernel {}, min fused speedup vs naive: {min_speedup:.2}x, \
         min int8 vs f32: {min_q8_speedup:.2}x)",
        path.display(),
        kernel.name()
    );
    // acceptance gates — the fused floor is raised to 3x where the AVX2
    // microkernel runs; the scalar fallback keeps the historical 2x
    let fused_floor = match kernel {
        Kernel::Avx2Fma => 3.0,
        Kernel::Scalar => 2.0,
    };
    anyhow::ensure!(
        min_speedup >= fused_floor,
        "fused forward regression: {min_speedup:.2}x < {fused_floor}x vs the naive unfused \
         in-bench baseline (kernel {})",
        kernel.name()
    );
    if kernel == Kernel::Avx2Fma {
        anyhow::ensure!(
            min_q8_speedup >= 1.5,
            "int8 path regression: {min_q8_speedup:.2}x < 1.5x vs f32 at equal N"
        );
    }
    anyhow::ensure!(
        steady_arena == 0,
        "tensor arena materialized {steady_arena} new workspaces in steady state (must be 0)"
    );
    Ok(())
}
