//! Native forward bench: the repo's first *real* end-to-end throughput
//! number — actual T-MUX math (embedding + fused mux, attention, FFN,
//! demux, head) executed by `runtime/native` with zero artifacts and no
//! PJRT, swept over `n_mux ∈ {1,2,4,8,16,32}` in the shape of the
//! paper's Fig 4c throughput-vs-N curve.
//!
//! Two gates, both enforced wherever the bench runs (CI included):
//!
//! 1. **fused ≥ 2x naive** — at every N, the optimized forward (blocked
//!    pre-transposed GEMM, fused mux, arena reuse, thread banding) must
//!    beat the naive unfused scalar reference (`native::reference`, the
//!    live in-bench baseline: same weights, same machine, measured in
//!    the same run — never a stale constant).
//! 2. **arena_reallocs == 0 in steady state** — after warmup, timed
//!    forwards must not materialize new tensor arenas.
//!
//! Results are printed as a table and written to `BENCH_native.json` at
//! the repo root (uploaded as a CI artifact next to `BENCH_engine.json`).
//!
//!   cargo bench --bench native_forward            # full
//!   cargo bench --bench native_forward -- --quick # CI-sized

use std::hint::black_box;
use std::time::Instant;

use datamux::runtime::native::{reference, synthetic_meta, RawWeights};
use datamux::runtime::{InferenceBackend, NativeBackend, WeightsFile};
use datamux::util::bench::Table;
use datamux::util::json::{arr, num, obj, s, Json};

const NS: [usize; 6] = [1, 2, 4, 8, 16, 32];
const BATCH: usize = 2;
const SEQ_LEN: usize = 16;
const D_MODEL: usize = 128;
const N_LAYERS: usize = 2;
const N_HEADS: usize = 4;
const N_CLASSES: usize = 3;

fn median(samples: &mut [f64]) -> f64 {
    samples.sort_by(|a, b| a.partial_cmp(b).unwrap());
    samples[samples.len() / 2]
}

fn main() -> anyhow::Result<()> {
    let quick = std::env::args().any(|a| a == "--quick");
    let (warmup, iters, naive_iters): (usize, usize, usize) =
        if quick { (2, 5, 2) } else { (5, 30, 5) };

    let mut table = Table::new(
        "native T-MUX forward: throughput vs N (paper Fig 4c shape)",
        &[
            "N",
            "req/s",
            "vs N=1",
            "GFLOP/s",
            "ns/req",
            "naive ns/req",
            "fused speedup",
            "arena reallocs",
        ],
    );
    let mut sweep = Vec::new();
    let mut base_rps = 0.0f64;
    let mut min_speedup = f64::INFINITY;
    let mut steady_arena = 0u64;

    for &n in &NS {
        let meta = synthetic_meta("cls", n, BATCH, SEQ_LEN, D_MODEL, N_LAYERS, N_HEADS, N_CLASSES);
        let raw = RawWeights::random(&meta, 2 * D_MODEL, 40 + n as u64);
        let wf = WeightsFile::parse(raw.to_blob())?;
        let backend = NativeBackend::from_weights(meta.clone(), wf)?;
        let ids: Vec<i32> = (0..meta.ids_len())
            .map(|i| ((i * 131 + 7) % meta.vocab_size) as i32)
            .collect();

        // warmup settles the tensor arena; the timed loop must not grow it
        for _ in 0..warmup {
            black_box(backend.run_ids(&ids)?);
        }
        let arena_before = backend.arena_reallocs();
        let mut samples = Vec::with_capacity(iters);
        let t0 = Instant::now();
        for _ in 0..iters {
            let t1 = Instant::now();
            black_box(backend.run_ids(&ids)?);
            samples.push(t1.elapsed().as_nanos() as f64);
        }
        let wall = t0.elapsed().as_secs_f64();
        let arena_delta = backend.arena_reallocs() - arena_before;
        let fused_ns = median(&mut samples);
        let requests_per_exec = (BATCH * n) as f64;
        let rps = requests_per_exec * iters as f64 / wall;
        let ns_per_req = fused_ns / requests_per_exec;
        let gflops = backend.dims().flops() / fused_ns; // FLOP/ns == GFLOP/s

        // the live naive unfused baseline: identical weights and inputs,
        // scalar reference implementation, measured in this same run
        let mut nsamples = Vec::with_capacity(naive_iters);
        for _ in 0..naive_iters {
            let t1 = Instant::now();
            black_box(reference::forward(&raw, &meta, &ids)?);
            nsamples.push(t1.elapsed().as_nanos() as f64);
        }
        let naive_ns = median(&mut nsamples);
        let naive_ns_per_req = naive_ns / requests_per_exec;
        let speedup = naive_ns / fused_ns;

        if n == NS[0] {
            base_rps = rps;
        }
        min_speedup = min_speedup.min(speedup);
        steady_arena += arena_delta;

        table.row(&[
            format!("{n}"),
            format!("{rps:.0}"),
            format!("{:.2}x", rps / base_rps),
            format!("{gflops:.2}"),
            format!("{ns_per_req:.0}"),
            format!("{naive_ns_per_req:.0}"),
            format!("{speedup:.2}x"),
            format!("{arena_delta}"),
        ]);
        sweep.push(obj(vec![
            ("n_mux", num(n as f64)),
            ("throughput_rps", num(rps)),
            ("speedup_vs_n1", num(rps / base_rps)),
            ("gflops", num(gflops)),
            ("ns_per_request", num(ns_per_req)),
            ("naive_ns_per_request", num(naive_ns_per_req)),
            ("fused_speedup", num(speedup)),
            ("arena_reallocs", num(arena_delta as f64)),
        ]));
    }
    table.print();

    let result = obj(vec![
        ("schema", s("native_forward/v1")),
        ("quick", Json::Bool(quick)),
        (
            "config",
            obj(vec![
                ("batch", num(BATCH as f64)),
                ("seq_len", num(SEQ_LEN as f64)),
                ("d_model", num(D_MODEL as f64)),
                ("n_layers", num(N_LAYERS as f64)),
                ("n_heads", num(N_HEADS as f64)),
                ("n_classes", num(N_CLASSES as f64)),
                ("iters", num(iters as f64)),
            ]),
        ),
        ("sweep", arr(sweep)),
        ("min_fused_speedup", num(min_speedup)),
        ("steady_state_arena_reallocs", num(steady_arena as f64)),
    ]);
    let root = std::path::Path::new(env!("CARGO_MANIFEST_DIR"))
        .parent()
        .expect("crate sits one level below the repo root");
    let path = root.join("BENCH_native.json");
    std::fs::write(&path, result.to_pretty())?;

    // self-check: the file must exist, parse, and carry the sweep —
    // CI fails the job otherwise
    let written = std::fs::read_to_string(&path)?;
    let parsed = Json::parse(&written).map_err(|e| anyhow::anyhow!("reparse: {e}"))?;
    anyhow::ensure!(
        parsed.get("sweep").and_then(Json::as_arr).map_or(0, |a| a.len()) == NS.len()
            && parsed.get("min_fused_speedup").and_then(Json::as_f64).is_some(),
        "BENCH_native.json is missing results"
    );
    println!(
        "\nwrote {} (min fused speedup vs naive reference: {min_speedup:.2}x)",
        path.display()
    );
    // acceptance gates
    anyhow::ensure!(
        min_speedup >= 2.0,
        "fused forward regression: {min_speedup:.2}x < 2x vs the naive unfused in-bench baseline"
    );
    anyhow::ensure!(
        steady_arena == 0,
        "tensor arena materialized {steady_arena} new workspaces in steady state (must be 0)"
    );
    Ok(())
}
