//! Native forward bench: the repo's first *real* end-to-end throughput
//! number — actual T-MUX math (embedding + fused mux, attention, FFN,
//! demux, head) executed by `runtime/native` with zero artifacts and no
//! PJRT, swept over `n_mux ∈ {1,2,4,8,16,32}` in the shape of the
//! paper's Fig 4c throughput-vs-N curve. Every N is measured at both
//! weight precisions (f32 and int8) against the same random model.
//!
//! Six gates, all enforced wherever the bench runs (CI included):
//!
//! 1. **fused f32 ≥ 3x naive on AVX2+FMA hosts (≥ 2x scalar)** — at
//!    every N, the optimized forward (vectorized microkernel, fused mux,
//!    arena reuse, thread banding) must beat the naive unfused scalar
//!    reference (`native::reference`, the live in-bench baseline: same
//!    weights, same machine, measured in the same run — never a stale
//!    constant). The floor is 3x when the AVX2 microkernel is active and
//!    stays at the historical 2x for the scalar fallback
//!    (`DATAMUX_FORCE_SCALAR=1` or a non-AVX2 host).
//! 2. **int8 ≥ 1.5x f32 at equal N** on AVX2+FMA hosts (the scalar int8
//!    arm exists for parity, not speed, and is not gated).
//! 3. **arena_reallocs == 0 in steady state** — after warmup, timed
//!    forwards must not materialize new tensor arenas (both precisions).
//! 4. **flash attention ≥ 1.5x the PR 7 attention path (≥ 1.15x
//!    scalar-vs-scalar)** — the per-layer `attention` stage time of a
//!    single-threaded forward at the largest N, against a live in-bench
//!    reproduction of the pre-flash path (materialized `li×li` scores,
//!    sequential scalar dots, two-pass libm softmax, scalar PV).
//! 5. **one projection GEMM per layer** — the process-wide GEMM dispatch
//!    delta across one forward must be exactly `4L + 2b + 2` (qkv, wo,
//!    ff1, ff2 per layer; w1p + w1h per batch row; w2; head), pinning
//!    the QKV fusion (three projections would make it `6L + 2b + 2`).
//! 6. **workspace bytes linear in `li`** — three equally spaced buckets
//!    must give exactly collinear workspace byte counts (the quadratic
//!    scores block is gone; flash tile scratch is constant in `li`).
//!
//! Per-stage wall time (mux / qkv / attention / ffn / head, cumulative
//! ns per forward) is reported for every row as `stage_ns` — the Amdahl
//! breakdown future perf work reads from the artifact instead of
//! guessing.
//!
//! Each row also reports `gflops_peak_frac`: achieved GFLOP/s over a
//! theoretical machine peak derived from a measured clock estimate
//! (serialized-LCG timing loop) times the kernel's FLOPs/cycle/core.
//! The fraction is observability, not a gate — it tells you how far the
//! microkernel sits from the roofline on the host that ran CI.
//!
//! Results are printed as a table and written to `BENCH_native.json` at
//! the repo root (uploaded as a CI artifact next to `BENCH_engine.json`).
//!
//!   cargo bench --bench native_forward            # full
//!   cargo bench --bench native_forward -- --quick # CI-sized

use std::hint::black_box;
use std::time::Instant;

use datamux::runtime::native::{
    active_kernel, gemm_dispatches, reference, synthetic_meta, Kernel, Precision, RawWeights,
};
use datamux::runtime::{InferenceBackend, NativeBackend, WeightsFile};
use datamux::util::bench::Table;
use datamux::util::json::{arr, num, obj, s, Json};

const NS: [usize; 6] = [1, 2, 4, 8, 16, 32];
const BATCH: usize = 2;
const SEQ_LEN: usize = 16;
const D_MODEL: usize = 128;
const N_LAYERS: usize = 2;
const N_HEADS: usize = 4;
const N_CLASSES: usize = 3;

fn median(samples: &mut [f64]) -> f64 {
    samples.sort_by(|a, b| a.partial_cmp(b).unwrap());
    samples[samples.len() / 2]
}

/// Clock estimate from a fully serialized LCG chain: each iteration is
/// one 64-bit multiply (3 cycles on every recent x86) feeding one add
/// (1 cycle), with no instruction-level parallelism to hide either, so
/// iterations/sec ≈ clock / 4. Good to ~10-20% across turbo states —
/// plenty for a reported roofline fraction.
fn estimate_ghz() -> f64 {
    const ITERS: u64 = 50_000_000;
    let mut x: u64 = 0x243F_6A88_85A3_08D3;
    let t0 = Instant::now();
    for _ in 0..ITERS {
        x = x.wrapping_mul(6364136223846793005).wrapping_add(1442695040888963407);
    }
    let dt = t0.elapsed().as_secs_f64();
    black_box(x);
    ITERS as f64 * 4.0 / dt / 1e9
}

/// Peak f32 FLOPs per cycle per core for the active kernel arm: AVX2+FMA
/// retires two 8-lane FMAs per cycle (2 * 8 * 2 = 32); the scalar arm is
/// credited one multiply + one add per cycle.
fn flops_per_cycle(kernel: Kernel) -> f64 {
    match kernel {
        Kernel::Avx2Fma => 32.0,
        Kernel::Scalar => 2.0,
    }
}

struct Measured {
    rps: f64,
    gflops: f64,
    ns_per_req: f64,
    fused_ns: f64,
    arena_delta: u64,
    /// average ns per forward spent in each stage over the timed loop,
    /// in pipeline order (mux, qkv, attention, ffn, head)
    stage_ns: Vec<(&'static str, f64)>,
}

fn measure(
    backend: &NativeBackend,
    ids: &[i32],
    warmup: usize,
    iters: usize,
) -> anyhow::Result<Measured> {
    // warmup settles the tensor arena; the timed loop must not grow it
    for _ in 0..warmup {
        black_box(backend.run_ids(ids)?);
    }
    let arena_before = backend.arena_reallocs();
    let stages_before = backend.stage_ns();
    let mut samples = Vec::with_capacity(iters);
    let t0 = Instant::now();
    for _ in 0..iters {
        let t1 = Instant::now();
        black_box(backend.run_ids(ids)?);
        samples.push(t1.elapsed().as_nanos() as f64);
    }
    let wall = t0.elapsed().as_secs_f64();
    let arena_delta = backend.arena_reallocs() - arena_before;
    let stage_ns: Vec<(&'static str, f64)> = backend
        .stage_ns()
        .iter()
        .zip(&stages_before)
        .map(|(&(k, after), &(_, before))| (k, (after - before) as f64 / iters as f64))
        .collect();
    let fused_ns = median(&mut samples);
    let requests_per_exec = (backend.dims().batch * backend.dims().n_mux) as f64;
    Ok(Measured {
        rps: requests_per_exec * iters as f64 / wall,
        gflops: backend.dims().flops() / fused_ns, // FLOP/ns == GFLOP/s
        ns_per_req: fused_ns / requests_per_exec,
        fused_ns,
        arena_delta,
        stage_ns,
    })
}

/// Fill a buffer from a deterministic LCG stream, roughly uniform in
/// [-0.5, 0.5) — activation-scale inputs for the attention baseline.
fn lcg_fill(buf: &mut [f32], seed: &mut u64) {
    for x in buf.iter_mut() {
        *seed = seed.wrapping_mul(6364136223846793005).wrapping_add(1442695040888963407);
        *x = (*seed >> 40) as f32 / (1u64 << 24) as f32 - 0.5;
    }
}

/// The PR 7 attention path, reproduced as a live in-bench baseline: a
/// materialized `li×li` scores block per (batch, head), sequential
/// scalar QK^T dots, two-pass softmax through libm `exp`, and a scalar
/// PV accumulate. One call does exactly one layer's worth of attention
/// for the given shape — the unit the flash kernel's `attention` stage
/// counter is compared against.
#[allow(clippy::too_many_arguments)]
fn pr7_attention(
    q: &[f32],
    k: &[f32],
    v: &[f32],
    scores: &mut [f32],
    ctx: &mut [f32],
    b: usize,
    heads: usize,
    li: usize,
    d: usize,
    dh: usize,
    scale: f32,
) {
    for bh in 0..b * heads {
        let (bb, hh) = (bh / heads, bh % heads);
        for i in 0..li {
            let qrow = &q[(bb * li + i) * d + hh * dh..][..dh];
            for j in 0..li {
                let krow = &k[(bb * li + j) * d + hh * dh..][..dh];
                let mut sdot = 0.0f32;
                for t in 0..dh {
                    sdot += qrow[t] * krow[t];
                }
                scores[i * li + j] = sdot * scale;
            }
            let row = &mut scores[i * li..(i + 1) * li];
            let mut max = f32::NEG_INFINITY;
            for &sv in row.iter() {
                if sv > max {
                    max = sv;
                }
            }
            let mut sum = 0.0f32;
            for sv in row.iter_mut() {
                *sv = (*sv - max).exp();
                sum += *sv;
            }
            let inv = 1.0 / sum;
            for sv in row.iter_mut() {
                *sv *= inv;
            }
            let crow = &mut ctx[(bb * li + i) * d + hh * dh..][..dh];
            crow.fill(0.0);
            for j in 0..li {
                let p = scores[i * li + j];
                let vrow = &v[(bb * li + j) * d + hh * dh..][..dh];
                for t in 0..dh {
                    crow[t] += p * vrow[t];
                }
            }
        }
    }
}

/// Gate 4: per-layer flash-attention stage time vs the PR 7 path at the
/// largest N, both single-threaded so the comparison is kernel-vs-kernel
/// rather than kernel-vs-fan-out. Returns (pr7_ns, flash_ns, speedup).
fn attention_gate_measurement(
    n: usize,
    warmup: usize,
    iters: usize,
) -> anyhow::Result<(f64, f64, f64)> {
    let meta = synthetic_meta("cls", n, BATCH, SEQ_LEN, D_MODEL, N_LAYERS, N_HEADS, N_CLASSES);
    let raw = RawWeights::random(&meta, 2 * D_MODEL, 99);
    let backend = NativeBackend::from_weights(meta.clone(), WeightsFile::parse(raw.to_blob())?)?
        .with_threads(1);
    let ids: Vec<i32> = (0..meta.ids_len())
        .map(|i| ((i * 131 + 7) % meta.vocab_size) as i32)
        .collect();
    for _ in 0..warmup {
        black_box(backend.run_ids(&ids)?);
    }
    let attn_before = stage_of(&backend, "attention");
    for _ in 0..iters {
        black_box(backend.run_ids(&ids)?);
    }
    let flash_ns =
        (stage_of(&backend, "attention") - attn_before) as f64 / (iters * N_LAYERS) as f64;

    // the baseline runs over synthetic activations of the same shape —
    // identical op count and memory traffic to the pre-flash path
    let li = n + SEQ_LEN;
    let (d, dh) = (D_MODEL, D_MODEL / N_HEADS);
    let scale = 1.0 / (dh as f32).sqrt();
    let mut seed = 0x9E37_79B9_7F4A_7C15u64;
    let mut q = vec![0.0f32; BATCH * li * d];
    let mut k = vec![0.0f32; BATCH * li * d];
    let mut v = vec![0.0f32; BATCH * li * d];
    lcg_fill(&mut q, &mut seed);
    lcg_fill(&mut k, &mut seed);
    lcg_fill(&mut v, &mut seed);
    let mut scores = vec![0.0f32; li * li];
    let mut ctx = vec![0.0f32; BATCH * li * d];
    for _ in 0..warmup {
        pr7_attention(&q, &k, &v, &mut scores, &mut ctx, BATCH, N_HEADS, li, d, dh, scale);
        black_box(&mut ctx);
    }
    let mut samples = Vec::with_capacity(iters);
    for _ in 0..iters {
        let t1 = Instant::now();
        pr7_attention(&q, &k, &v, &mut scores, &mut ctx, BATCH, N_HEADS, li, d, dh, scale);
        black_box(&mut ctx);
        samples.push(t1.elapsed().as_nanos() as f64);
    }
    let pr7_ns = median(&mut samples);
    Ok((pr7_ns, flash_ns, pr7_ns / flash_ns))
}

fn stage_of(backend: &NativeBackend, name: &str) -> u64 {
    backend.stage_ns().iter().find(|(k, _)| *k == name).map_or(0, |&(_, ns)| ns)
}

fn main() -> anyhow::Result<()> {
    let quick = std::env::args().any(|a| a == "--quick");
    let (warmup, iters, naive_iters): (usize, usize, usize) =
        if quick { (2, 5, 2) } else { (5, 30, 5) };

    let kernel = active_kernel();
    let ghz = estimate_ghz();

    let mut table = Table::new(
        "native T-MUX forward: throughput vs N (paper Fig 4c shape)",
        &[
            "N",
            "prec",
            "req/s",
            "vs N=1",
            "GFLOP/s",
            "peak frac",
            "ns/req",
            "naive ns/req",
            "fused speedup",
            "int8 vs f32",
            "arena reallocs",
        ],
    );
    let mut sweep = Vec::new();
    let mut base_rps = 0.0f64;
    let mut min_speedup = f64::INFINITY;
    let mut min_q8_speedup = f64::INFINITY;
    let mut steady_arena = 0u64;
    let mut peak_gflops = 0.0f64;

    for &n in &NS {
        let meta = synthetic_meta("cls", n, BATCH, SEQ_LEN, D_MODEL, N_LAYERS, N_HEADS, N_CLASSES);
        let raw = RawWeights::random(&meta, 2 * D_MODEL, 40 + n as u64);
        let backend =
            NativeBackend::from_weights(meta.clone(), WeightsFile::parse(raw.to_blob())?)?;
        // same model, int8 projection weights quantized online at pack
        let q8 = NativeBackend::from_weights_prec(
            meta.clone(),
            WeightsFile::parse(raw.to_blob())?,
            Precision::Int8,
        )?;
        let ids: Vec<i32> = (0..meta.ids_len())
            .map(|i| ((i * 131 + 7) % meta.vocab_size) as i32)
            .collect();

        // the machine peak is clock * flops/cycle * GEMM worker threads;
        // computed once per run (thread count is fixed across Ns)
        peak_gflops = ghz * flops_per_cycle(kernel) * backend.n_threads() as f64;

        let mf = measure(&backend, &ids, warmup, iters)?;
        let mq = measure(&q8, &ids, warmup, iters)?;

        // the live naive unfused baseline: identical weights and inputs,
        // scalar reference implementation, measured in this same run
        let mut nsamples = Vec::with_capacity(naive_iters);
        for _ in 0..naive_iters {
            let t1 = Instant::now();
            black_box(reference::forward(&raw, &meta, &ids)?);
            nsamples.push(t1.elapsed().as_nanos() as f64);
        }
        let naive_ns = median(&mut nsamples);
        let naive_ns_per_req = naive_ns / (BATCH * n) as f64;
        let speedup = naive_ns / mf.fused_ns;
        let q8_speedup = mf.fused_ns / mq.fused_ns;

        if n == NS[0] {
            base_rps = mf.rps;
        }
        min_speedup = min_speedup.min(speedup);
        min_q8_speedup = min_q8_speedup.min(q8_speedup);
        steady_arena += mf.arena_delta + mq.arena_delta;

        for (prec, m, fused_speedup, q8_vs_f32) in [
            ("f32", &mf, Some(speedup), None),
            ("int8", &mq, None, Some(q8_speedup)),
        ] {
            let frac = m.gflops / peak_gflops;
            table.row(&[
                format!("{n}"),
                prec.to_string(),
                format!("{:.0}", m.rps),
                format!("{:.2}x", m.rps / base_rps),
                format!("{:.2}", m.gflops),
                format!("{frac:.3}"),
                format!("{:.0}", m.ns_per_req),
                fused_speedup.map_or("-".into(), |_| format!("{naive_ns_per_req:.0}")),
                fused_speedup.map_or("-".into(), |x| format!("{x:.2}x")),
                q8_vs_f32.map_or("-".into(), |x| format!("{x:.2}x")),
                format!("{}", m.arena_delta),
            ]);
            let mut fields = vec![
                ("n_mux", num(n as f64)),
                ("precision", s(prec)),
                ("throughput_rps", num(m.rps)),
                ("speedup_vs_n1", num(m.rps / base_rps)),
                ("gflops", num(m.gflops)),
                ("gflops_peak_frac", num(frac)),
                ("ns_per_request", num(m.ns_per_req)),
                ("arena_reallocs", num(m.arena_delta as f64)),
                (
                    "stage_ns",
                    obj(m.stage_ns.iter().map(|&(k, ns)| (k, num(ns))).collect()),
                ),
            ];
            if fused_speedup.is_some() {
                fields.push(("naive_ns_per_request", num(naive_ns_per_req)));
                fields.push(("fused_speedup", num(speedup)));
            }
            if let Some(x) = q8_vs_f32 {
                fields.push(("int8_speedup_vs_f32", num(x)));
            }
            sweep.push(obj(fields));
        }
    }
    table.print();

    // gate 4: flash attention vs the PR 7 attention path at the largest N
    let n_big = NS[NS.len() - 1];
    let (pr7_attn_ns, flash_attn_ns, attn_speedup) =
        attention_gate_measurement(n_big, warmup, iters)?;

    // gate 5: QKV fusion means exactly one projection GEMM per layer —
    // the dispatch delta across one forward is 4L + 2b + 2, not 6L + 2b + 2
    let gemm_expected = (4 * N_LAYERS + 2 * BATCH + 2) as u64;
    let gemm_per_forward = {
        let meta =
            synthetic_meta("cls", n_big, BATCH, SEQ_LEN, D_MODEL, N_LAYERS, N_HEADS, N_CLASSES);
        let raw = RawWeights::random(&meta, 2 * D_MODEL, 7);
        let backend =
            NativeBackend::from_weights(meta.clone(), WeightsFile::parse(raw.to_blob())?)?;
        let ids: Vec<i32> = (0..meta.ids_len())
            .map(|i| ((i * 131 + 7) % meta.vocab_size) as i32)
            .collect();
        black_box(backend.run_ids(&ids)?); // settle the arena outside the count
        let before = gemm_dispatches();
        black_box(backend.run_ids(&ids)?);
        gemm_dispatches() - before
    };

    // gate 6: workspace bytes must be exactly collinear across equally
    // spaced buckets — a quadratic scores block would break the equality
    let (ws_a, ws_b, ws_c) = {
        let meta =
            synthetic_meta("cls", n_big, BATCH, SEQ_LEN, D_MODEL, N_LAYERS, N_HEADS, N_CLASSES);
        let raw = RawWeights::random(&meta, 2 * D_MODEL, 7);
        let backend = NativeBackend::from_weights(meta, WeightsFile::parse(raw.to_blob())?)?;
        (
            backend.workspace_bytes_at(4)?,
            backend.workspace_bytes_at(10)?,
            backend.workspace_bytes_at(16)?,
        )
    };
    let ws_linear = ws_b > ws_a && ws_c > ws_b && ws_b - ws_a == ws_c - ws_b;

    let result = obj(vec![
        ("schema", s("native_forward/v3")),
        ("quick", Json::Bool(quick)),
        ("kernel", s(kernel.name())),
        ("estimated_ghz", num(ghz)),
        ("peak_gflops", num(peak_gflops)),
        (
            "config",
            obj(vec![
                ("batch", num(BATCH as f64)),
                ("seq_len", num(SEQ_LEN as f64)),
                ("d_model", num(D_MODEL as f64)),
                ("n_layers", num(N_LAYERS as f64)),
                ("n_heads", num(N_HEADS as f64)),
                ("n_classes", num(N_CLASSES as f64)),
                ("iters", num(iters as f64)),
            ]),
        ),
        ("sweep", arr(sweep)),
        ("min_fused_speedup", num(min_speedup)),
        ("min_int8_speedup_vs_f32", num(min_q8_speedup)),
        ("steady_state_arena_reallocs", num(steady_arena as f64)),
        (
            "attention",
            obj(vec![
                ("n_mux", num(n_big as f64)),
                ("pr7_ns_per_layer", num(pr7_attn_ns)),
                ("flash_ns_per_layer", num(flash_attn_ns)),
            ]),
        ),
        ("attention_speedup", num(attn_speedup)),
        ("gemm_dispatches_per_forward", num(gemm_per_forward as f64)),
        ("workspace_linear_in_li", Json::Bool(ws_linear)),
    ]);
    let root = std::path::Path::new(env!("CARGO_MANIFEST_DIR"))
        .parent()
        .expect("crate sits one level below the repo root");
    let path = root.join("BENCH_native.json");
    std::fs::write(&path, result.to_pretty())?;

    // self-check: the file must exist, parse, and carry the sweep —
    // CI fails the job otherwise
    let written = std::fs::read_to_string(&path)?;
    let parsed = Json::parse(&written).map_err(|e| anyhow::anyhow!("reparse: {e}"))?;
    anyhow::ensure!(
        parsed.get("sweep").and_then(Json::as_arr).map_or(0, |a| a.len()) == 2 * NS.len()
            && parsed.get("min_fused_speedup").and_then(Json::as_f64).is_some()
            && parsed.get("attention_speedup").and_then(Json::as_f64).is_some()
            && parsed
                .get("sweep")
                .and_then(Json::as_arr)
                .and_then(|a| a.first())
                .and_then(|row| row.get("stage_ns"))
                .is_some(),
        "BENCH_native.json is missing results"
    );
    println!(
        "\nwrote {} (kernel {}, min fused speedup vs naive: {min_speedup:.2}x, \
         min int8 vs f32: {min_q8_speedup:.2}x, flash attention vs PR 7 path: \
         {attn_speedup:.2}x)",
        path.display(),
        kernel.name()
    );
    // acceptance gates — the fused floor is raised to 3x where the AVX2
    // microkernel runs; the scalar fallback keeps the historical 2x
    let fused_floor = match kernel {
        Kernel::Avx2Fma => 3.0,
        Kernel::Scalar => 2.0,
    };
    anyhow::ensure!(
        min_speedup >= fused_floor,
        "fused forward regression: {min_speedup:.2}x < {fused_floor}x vs the naive unfused \
         in-bench baseline (kernel {})",
        kernel.name()
    );
    if kernel == Kernel::Avx2Fma {
        anyhow::ensure!(
            min_q8_speedup >= 1.5,
            "int8 path regression: {min_q8_speedup:.2}x < 1.5x vs f32 at equal N"
        );
    }
    anyhow::ensure!(
        steady_arena == 0,
        "tensor arena materialized {steady_arena} new workspaces in steady state (must be 0)"
    );
    // the flash kernel must beat the PR 7 attention path at the largest
    // bucket — vectorized floor where AVX2 runs, scalar-vs-scalar floor
    // under DATAMUX_FORCE_SCALAR / non-AVX2 hosts
    let attn_floor = match kernel {
        Kernel::Avx2Fma => 1.5,
        Kernel::Scalar => 1.15,
    };
    anyhow::ensure!(
        attn_speedup >= attn_floor,
        "flash attention regression: {attn_speedup:.2}x < {attn_floor}x vs the PR 7 \
         attention path at N={n_big} (kernel {})",
        kernel.name()
    );
    anyhow::ensure!(
        gemm_per_forward == gemm_expected,
        "QKV fusion broken: {gemm_per_forward} GEMM dispatches per forward, expected \
         {gemm_expected} (one fused projection GEMM per layer)"
    );
    anyhow::ensure!(
        ws_linear,
        "workspace bytes are not linear in li: {ws_a} / {ws_b} / {ws_c} at equally \
         spaced seq lens (quadratic scores block reintroduced?)"
    );
    Ok(())
}
