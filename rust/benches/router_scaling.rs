//! Router scaling bench: offered-load sweep over adaptive-N lane sets
//! of **native** backends (`runtime/native`: the real T-MUX forward on
//! random weights — zero artifacts, zero PJRT, runs anywhere, CI
//! included), then a mid-run lane kill.
//!
//! Per lane set (small-N-only vs. small+large), lane capacity is
//! *measured* (a direct `run_ids` probe per backend) and an open-loop
//! Poisson driver offers fractions of the aggregate. Two gates make the
//! bench (and the CI job) **exit non-zero**:
//!
//! 1. **Zero rejects with spare capacity** — any sweep point offered
//!    below aggregate capacity must finish with zero `QueueFull`
//!    rejects: the shared admission queue + pull-gate engage the
//!    large-N lane as backlog grows, so capacity anywhere means no
//!    rejects. (This was the herding bug: the per-arrival router
//!    rejected on one lane's full queue while a sibling idled.)
//! 2. **Failover loses nothing** — mid-run, the large native lane's
//!    backend starts failing (a delegating fail-after-k wrapper;
//!    `NativeBackend` itself has no failure knob). The lane must die
//!    and hand its unexecuted waves back; the survivor completes
//!    everything else: zero `Shutdown` answers, every request
//!    answered, at most one failed batch.
//!
//! Results are printed as tables and written to `BENCH_router.json` at
//! the repo root (uploaded by CI next to `BENCH_engine.json` /
//! `BENCH_native.json`).
//!
//!   cargo bench --bench router_scaling            # full
//!   cargo bench --bench router_scaling -- --quick # CI-sized

use std::hint::black_box;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Arc;
use std::time::Duration;

use datamux::coordinator::EngineBuilder;
use datamux::runtime::{ArtifactMeta, InferenceBackend, NativeBackend};
use datamux::util::bench::{bench, Table};
use datamux::util::json::{num, obj, s, Json};
use datamux::workload::{open_loop, RandomWorkload};
use datamux::{EngineError, Submit};

const SEQ_LEN: usize = 24;
const BATCH: usize = 1;
const D_MODEL: usize = 128;
const N_LAYERS: usize = 2;
const N_HEADS: usize = 4;
const N_CLASSES: usize = 3;
const SMALL_N: usize = 2;
const LARGE_N: usize = 8;

fn native_lane(n_mux: usize, seed: u64) -> anyhow::Result<Arc<dyn InferenceBackend>> {
    let b = NativeBackend::random(
        "cls",
        n_mux,
        BATCH,
        SEQ_LEN,
        D_MODEL,
        N_LAYERS,
        N_HEADS,
        N_CLASSES,
        seed,
    )?;
    Ok(Arc::new(b))
}

/// Measured sustained-lane estimate: requests per second one lane can
/// serve with full waves (`batch * n_mux` per execution).
fn probe(backend: &Arc<dyn InferenceBackend>) -> (f64, f64) {
    let ids = vec![1i32; backend.meta().ids_len()];
    let t = bench("probe", 2, 5, || {
        black_box(backend.run_ids(&ids).unwrap());
    });
    let exec_us = t.mean.as_secs_f64() * 1e6;
    let capacity = (backend.meta().batch * backend.meta().n_mux) as f64 / t.mean.as_secs_f64();
    (capacity, exec_us)
}

struct SweepPoint {
    fraction: f64,
    target_rps: f64,
    offered_rps: f64,
    submitted: usize,
    completed: usize,
    rejected: usize,
    p99_us: f64,
    lanes: Vec<Json>,
}

fn sweep_lane_set(
    backends: &[Arc<dyn InferenceBackend>],
    capacity_rps: f64,
    exec_us: f64,
    fractions: &[f64],
    duration: Duration,
    table: &mut Table,
) -> anyhow::Result<Vec<SweepPoint>> {
    let ns: Vec<usize> = backends.iter().map(|b| b.meta().n_mux).collect();
    let mut points = Vec::new();
    for (i, &fraction) in fractions.iter().enumerate() {
        let target = capacity_rps * fraction;
        let router = Arc::new(
            EngineBuilder::new()
                .max_wait_ms(3)
                .queue_cap(1024)
                .exec_time_us(exec_us)
                .build_router_backends(backends.to_vec())?,
        );
        let mut w = RandomWorkload::new(21 + i as u64, 200, SEQ_LEN - 4);
        let rows: Vec<Vec<i32>> =
            (0..128).map(|_| w.framed_row(router.tokenizer(), SEQ_LEN)).collect();
        let report = open_loop(&router, &Arc::new(rows), target, duration, 5 + i as u64);
        let offered = report.submitted as f64 / report.wall.as_secs_f64();
        let lat = router.latency();
        let lanes: Vec<Json> = router
            .lane_status()
            .iter()
            .map(|l| {
                obj(vec![
                    ("n_mux", num(l.n_mux as f64)),
                    ("alive", Json::Bool(l.alive)),
                    ("pulls", num(l.pulls as f64)),
                    ("completed", num(l.completed as f64)),
                ])
            })
            .collect();
        table.row(&[
            format!("{ns:?}"),
            format!("{target:.0} ({fraction:.2}x)"),
            report.submitted.to_string(),
            report.completed.to_string(),
            report.rejected.to_string(),
            format!("{:.0}", lat.p99_ns as f64 / 1e3),
        ]);
        points.push(SweepPoint {
            fraction,
            target_rps: target,
            offered_rps: offered,
            submitted: report.submitted,
            completed: report.completed,
            rejected: report.rejected,
            p99_us: lat.p99_ns as f64 / 1e3,
            lanes,
        });
    }
    Ok(points)
}

/// Delegating backend that fails every `run_ids` after the first `k`
/// calls — failure injection for the mid-run lane kill (the native
/// backend itself has, deliberately, no failure knob).
struct FailAfter {
    inner: Arc<dyn InferenceBackend>,
    k: u64,
    calls: AtomicU64,
}

impl InferenceBackend for FailAfter {
    fn meta(&self) -> &ArtifactMeta {
        self.inner.meta()
    }

    fn run_ids(&self, ids: &[i32]) -> anyhow::Result<Vec<f32>> {
        if self.calls.fetch_add(1, Ordering::Relaxed) >= self.k {
            anyhow::bail!("injected lane failure (mid-run kill)");
        }
        self.inner.run_ids(ids)
    }
}

struct FailoverReport {
    requests: usize,
    completed: usize,
    worker_failed: usize,
    shutdown: usize,
    requeued: u64,
    dead_lane_is_dead: bool,
    survivor_alive: bool,
}

/// Kill the large native lane after 3 executions; the surviving native
/// lane must finish the remaining work with zero `Shutdown` answers and
/// no stranded waiters.
fn failover_run(
    small: &Arc<dyn InferenceBackend>,
    large: &Arc<dyn InferenceBackend>,
    exec_us: f64,
    requests: usize,
) -> anyhow::Result<FailoverReport> {
    let failing: Arc<dyn InferenceBackend> =
        Arc::new(FailAfter { inner: large.clone(), k: 3, calls: AtomicU64::new(0) });
    let router = Arc::new(
        EngineBuilder::new()
            .max_wait_ms(3)
            .queue_cap(requests + 8)
            .exec_time_us(exec_us)
            .build_router_backends(vec![small.clone(), failing])?,
    );
    let mut w = RandomWorkload::new(77, 200, SEQ_LEN - 4);
    let rows: Vec<Vec<i32>> =
        (0..128).map(|_| w.framed_row(router.tokenizer(), SEQ_LEN)).collect();
    let mut handles = Vec::with_capacity(requests);
    for i in 0..requests {
        handles.push(router.submit_framed(rows[i % rows.len()].clone())?);
    }
    let (mut completed, mut worker_failed, mut shutdown) = (0usize, 0usize, 0usize);
    for h in &handles {
        match h.wait_timeout(Duration::from_secs(300)).expect("stranded waiter") {
            Ok(_) => completed += 1,
            Err(EngineError::WorkerFailed(_)) => worker_failed += 1,
            Err(EngineError::Shutdown) => shutdown += 1,
            Err(EngineError::DeadlineExceeded) => unreachable!("no deadlines set"),
        }
    }
    // the dead flag lands just after the failed batch is answered; give
    // the worker thread a moment before reading lane health
    let t0 = std::time::Instant::now();
    while router.live_lanes() > 1 && t0.elapsed() < Duration::from_secs(5) {
        std::thread::sleep(Duration::from_millis(5));
    }
    let status = router.lane_status();
    let dead = status.iter().find(|l| l.n_mux == LARGE_N).expect("large lane");
    let survivor = status.iter().find(|l| l.n_mux == SMALL_N).expect("small lane");
    Ok(FailoverReport {
        requests,
        completed,
        worker_failed,
        shutdown,
        requeued: dead.requeued,
        dead_lane_is_dead: !dead.alive,
        survivor_alive: survivor.alive,
    })
}

fn main() -> anyhow::Result<()> {
    let quick = std::env::args().any(|a| a == "--quick");
    let (duration, fractions, failover_requests): (Duration, &[f64], usize) = if quick {
        (Duration::from_millis(500), &[0.3, 1.5], 120)
    } else {
        (Duration::from_millis(1200), &[0.2, 0.35, 0.5, 1.5], 400)
    };

    // two native lanes (small N, large N) — the paper's adaptive-N
    // serving shape, executed as real T-MUX math on random weights
    let small = native_lane(SMALL_N, 11)?;
    let large = native_lane(LARGE_N, 12)?;
    let (cap_small, exec_small_us) = probe(&small);
    let (cap_large, exec_large_us) = probe(&large);
    println!(
        "native lanes: N={SMALL_N} ≈ {cap_small:.0} r/s ({exec_small_us:.0}us/exec), \
         N={LARGE_N} ≈ {cap_large:.0} r/s ({exec_large_us:.0}us/exec)"
    );

    // ----- offered-load sweep per lane set ------------------------------
    let mut table = Table::new(
        "router scaling (native lanes): offered load vs completed/rejected",
        &["lanes", "target r/s", "submitted", "completed", "rejected", "p99 us"],
    );
    let sets: [(Vec<Arc<dyn InferenceBackend>>, f64); 2] = [
        (vec![small.clone()], cap_small),
        (vec![small.clone(), large.clone()], cap_small + cap_large),
    ];
    let mut sets_json = Vec::new();
    let mut spare_capacity_rejects = 0usize;
    for (backends, capacity) in &sets {
        let points =
            sweep_lane_set(backends, *capacity, exec_large_us, fractions, duration, &mut table)?;
        spare_capacity_rejects += points
            .iter()
            .filter(|p| p.fraction < 1.0)
            .map(|p| p.rejected)
            .sum::<usize>();
        let ns: Vec<Json> = backends.iter().map(|b| num(b.meta().n_mux as f64)).collect();
        sets_json.push(obj(vec![
            ("lanes", Json::Arr(ns)),
            ("capacity_rps", num(*capacity)),
            (
                "sweep",
                Json::Arr(
                    points
                        .into_iter()
                        .map(|p| {
                            obj(vec![
                                ("fraction", num(p.fraction)),
                                ("target_rps", num(p.target_rps)),
                                ("offered_rps", num(p.offered_rps)),
                                ("submitted", num(p.submitted as f64)),
                                ("completed", num(p.completed as f64)),
                                ("rejected", num(p.rejected as f64)),
                                ("p99_us", num(p.p99_us)),
                                ("lanes", Json::Arr(p.lanes)),
                            ])
                        })
                        .collect(),
                ),
            ),
        ]));
    }
    table.print();

    // ----- failover: kill the large lane mid-run ------------------------
    let f = failover_run(&small, &large, exec_large_us, failover_requests)?;
    let mut t2 =
        Table::new("router failover: large native lane dies mid-run", &["metric", "value"]);
    for (k, v) in [
        ("requests", f.requests.to_string()),
        ("completed", f.completed.to_string()),
        ("worker_failed (one batch max)", f.worker_failed.to_string()),
        ("shutdown answers (must be 0)", f.shutdown.to_string()),
        ("requeued to survivor", f.requeued.to_string()),
        (
            "large lane dead / small lane alive",
            format!("{} / {}", f.dead_lane_is_dead, f.survivor_alive),
        ),
    ] {
        t2.row(&[k.to_string(), v]);
    }
    t2.print();

    // ----- BENCH_router.json at the repo root ---------------------------
    let zero_rejects_gate = spare_capacity_rejects == 0;
    let failover_gate = f.shutdown == 0
        && f.completed + f.worker_failed == f.requests
        && f.worker_failed <= LARGE_N * BATCH
        && f.dead_lane_is_dead
        && f.survivor_alive;
    let result = obj(vec![
        ("schema", s("router_scaling/v1")),
        ("quick", Json::Bool(quick)),
        (
            "config",
            obj(vec![
                ("seq_len", num(SEQ_LEN as f64)),
                ("batch", num(BATCH as f64)),
                ("d_model", num(D_MODEL as f64)),
                ("n_layers", num(N_LAYERS as f64)),
                ("small_n", num(SMALL_N as f64)),
                ("large_n", num(LARGE_N as f64)),
                ("probe_capacity_small_rps", num(cap_small)),
                ("probe_capacity_large_rps", num(cap_large)),
                ("duration_ms", num(duration.as_millis() as f64)),
            ]),
        ),
        ("lane_sets", Json::Arr(sets_json)),
        (
            "failover",
            obj(vec![
                ("requests", num(f.requests as f64)),
                ("completed", num(f.completed as f64)),
                ("worker_failed", num(f.worker_failed as f64)),
                ("shutdown", num(f.shutdown as f64)),
                ("requeued", num(f.requeued as f64)),
                ("dead_lane_is_dead", Json::Bool(f.dead_lane_is_dead)),
                ("survivor_alive", Json::Bool(f.survivor_alive)),
            ]),
        ),
        (
            "gates",
            obj(vec![
                ("zero_rejects_with_spare_capacity", Json::Bool(zero_rejects_gate)),
                ("failover_no_shutdown_no_loss", Json::Bool(failover_gate)),
            ]),
        ),
    ]);
    let root = std::path::Path::new(env!("CARGO_MANIFEST_DIR"))
        .parent()
        .expect("crate sits one level below the repo root");
    let path = root.join("BENCH_router.json");
    std::fs::write(&path, result.to_pretty())?;

    // self-check: the file must exist, parse, and carry results
    let written = std::fs::read_to_string(&path)?;
    let parsed = Json::parse(&written).map_err(|e| anyhow::anyhow!("reparse: {e}"))?;
    anyhow::ensure!(
        parsed.get("lane_sets").and_then(Json::as_arr).is_some_and(|a| a.len() == 2)
            && parsed.get("failover").and_then(|x| x.get("completed")).is_some(),
        "BENCH_router.json is missing results"
    );
    println!("\nwrote {}", path.display());

    // the acceptance gates: fail the bench (and the CI job) loudly
    anyhow::ensure!(
        zero_rejects_gate,
        "router rejected {spare_capacity_rejects} request(s) at sub-capacity offered load — \
         QueueFull with spare lane capacity is the herding bug this redesign removes"
    );
    anyhow::ensure!(
        failover_gate,
        "failover gate failed: completed={} worker_failed={} shutdown={} of {} \
         (dead_lane_is_dead={} survivor_alive={})",
        f.completed,
        f.worker_failed,
        f.shutdown,
        f.requests,
        f.dead_lane_is_dead,
        f.survivor_alive
    );
    println!("gates OK: zero sub-capacity rejects; lane death lost nothing");
    Ok(())
}
