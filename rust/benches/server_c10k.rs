//! C10K server bench: one epoll reactor thread vs. thousands of
//! concurrent sockets, then SLO-tiered admission under overload.
//!
//! The server front end is the event-driven reactor (`coordinator/
//! reactor`): every socket lives on a single event-loop thread, so
//! concurrent connections cost buffers, not threads. The engine behind
//! it is a `FakeBackend` with a fixed per-wave delay — deterministic
//! capacity, no artifacts, runs in CI.
//!
//! Three phases, each with a gate that makes the bench (and the CI job)
//! **exit non-zero**:
//!
//! 1. **C10K hold** — 5,000 clients connect concurrently, each sends
//!    one v2 classify, and every one gets its answer back through one
//!    reactor thread (`/proc` is checked: exactly one
//!    `datamux-reactor`). The bench side drives its own nonblocking
//!    sockets through the same `Poller` the reactor uses.
//! 2. **SLO tiers** — an open-loop driver offers a 20% `high` (250 ms
//!    deadline) / 80% `bulk` (50 ms deadline) mix. At sub-capacity
//!    load nothing is shed: zero high-priority rejects. At 3x
//!    capacity, bulk is shed fast with typed `overloaded`/`deadline`
//!    errors while the high tier's client-observed p99 stays inside
//!    its SLO — strict-priority drain plus deadline-aware admission.
//! 3. **Pre-expired work** — requests with `deadline_ms: 0` are all
//!    answered with the typed `expired` error and the engine's
//!    per-class `completed` counters do not move: expired work is
//!    never executed.
//!
//! Results are printed as tables and written to `BENCH_server.json` at
//! the repo root (uploaded by CI next to the other BENCH artifacts).
//!
//!   cargo bench --bench server_c10k            # full
//!   cargo bench --bench server_c10k -- --quick # CI-sized

use std::collections::HashMap;
use std::io::{BufRead, BufReader, ErrorKind, Read, Write};
use std::net::{Shutdown, SocketAddr, TcpStream};
use std::os::fd::AsRawFd;
use std::sync::{Arc, Mutex};
use std::thread;
use std::time::{Duration, Instant};

use datamux::coordinator::reactor::{raise_nofile_limit, Poller};
use datamux::coordinator::server::{Server, ServerConfig};
use datamux::coordinator::EngineBuilder;
use datamux::util::bench::Table;
use datamux::util::json::{num, obj, s, Json};
use datamux::{FakeBackend, Submit};

const N_MUX: usize = 8;
const BATCH: usize = 4;
const SEQ_LEN: usize = 16;
const N_CLASSES: usize = 3;
/// Per-wave execution delay: capacity = BATCH * N_MUX / EXEC_DELAY.
const EXEC_DELAY: Duration = Duration::from_millis(4);
const QUEUE_CAP: usize = 8192;

const C10K_TARGET: usize = 5000;
const SLO_CONNS: usize = 32;
const HIGH_DEADLINE_MS: u64 = 250;
const BULK_DEADLINE_MS: u64 = 50;
/// Client-observed p99 budget for the high tier under overload.
const HIGH_SLO_MS: f64 = 150.0;

// ---------------------------------------------------------------- phase 1

struct C10kReport {
    attempted: usize,
    connected: usize,
    answered: usize,
    errors: usize,
    wall: Duration,
}

/// Connect `conns` sockets (all concurrently live), send one classify
/// per socket, and drain every reply through a bench-side `Poller`.
fn c10k_hold(addr: SocketAddr, conns: usize) -> anyhow::Result<C10kReport> {
    let t0 = Instant::now();
    let mut streams = Vec::with_capacity(conns);
    for i in 0..conns {
        streams.push(TcpStream::connect(addr)?);
        // give the single accept loop air so the listen backlog (128)
        // never overflows into SYN retransmits
        if i % 512 == 511 {
            thread::sleep(Duration::from_millis(1));
        }
    }
    let connected = streams.len();
    for (i, st) in streams.iter_mut().enumerate() {
        let line = format!("{{\"id\":{i},\"op\":\"classify\",\"ids\":[1,2,3,4]}}\n");
        st.write_all(line.as_bytes())?;
    }
    let mut poller = Poller::new()?;
    for (i, st) in streams.iter().enumerate() {
        st.set_nonblocking(true)?;
        poller.add(st.as_raw_fd(), i as u64, true, false)?;
    }
    let mut bufs: Vec<Vec<u8>> = vec![Vec::new(); conns];
    let mut done = vec![false; conns];
    let (mut answered, mut errors) = (0usize, 0usize);
    let mut evs = Vec::new();
    let give_up = Instant::now() + Duration::from_secs(60);
    while answered + errors < conns && Instant::now() < give_up {
        evs.clear();
        poller.wait(&mut evs, Some(Duration::from_millis(200)))?;
        for ev in &evs {
            let i = ev.token as usize;
            if done[i] {
                continue;
            }
            let mut chunk = [0u8; 4096];
            loop {
                match (&streams[i]).read(&mut chunk) {
                    Ok(0) => break,
                    Ok(n) => bufs[i].extend_from_slice(&chunk[..n]),
                    Err(e) if e.kind() == ErrorKind::WouldBlock => break,
                    Err(_) => break,
                }
            }
            if let Some(pos) = bufs[i].iter().position(|&b| b == b'\n') {
                done[i] = true;
                let line = String::from_utf8_lossy(&bufs[i][..pos]);
                let ok = Json::parse(&line)
                    .ok()
                    .and_then(|v| v.get("ok").and_then(Json::as_bool))
                    == Some(true);
                if ok {
                    answered += 1;
                } else {
                    errors += 1;
                }
                poller.remove(streams[i].as_raw_fd()).ok();
            }
        }
    }
    Ok(C10kReport { attempted: conns, connected, answered, errors, wall: t0.elapsed() })
}

// ---------------------------------------------------------------- phase 2

struct Reply {
    id: String,
    ok: bool,
    code: String,
    at: Instant,
}

fn spawn_reader(stream: TcpStream, sink: Arc<Mutex<Vec<Reply>>>) -> thread::JoinHandle<()> {
    thread::spawn(move || {
        stream.set_read_timeout(Some(Duration::from_secs(30))).ok();
        let mut r = BufReader::new(stream);
        let mut line = String::new();
        loop {
            line.clear();
            match r.read_line(&mut line) {
                Ok(0) | Err(_) => break,
                Ok(_) => {
                    let Ok(v) = Json::parse(line.trim()) else { continue };
                    sink.lock().unwrap().push(Reply {
                        id: v.get("id").and_then(Json::as_str).unwrap_or("").to_string(),
                        ok: v.get("ok").and_then(Json::as_bool) == Some(true),
                        code: v.get("error").and_then(Json::as_str).unwrap_or("").to_string(),
                        at: Instant::now(),
                    });
                }
            }
        }
    })
}

struct SloOutcome {
    target_rps: f64,
    offered_rps: f64,
    sent_high: usize,
    sent_bulk: usize,
    ok_high: usize,
    ok_bulk: usize,
    rej_high: usize,
    rej_bulk: usize,
    unanswered: usize,
    high_p99_ms: f64,
    bulk_rej_codes: HashMap<String, usize>,
}

/// Open-loop paced mix over `SLO_CONNS` pipelined connections: 20%
/// `high` (generous deadline), 80% `bulk` (tight deadline). Every
/// request gets exactly one reply — a prediction or a typed error.
fn slo_run(addr: SocketAddr, target_rps: f64, duration: Duration) -> anyhow::Result<SloOutcome> {
    let mut streams = Vec::with_capacity(SLO_CONNS);
    let replies: Arc<Mutex<Vec<Reply>>> = Arc::default();
    let mut readers = Vec::with_capacity(SLO_CONNS);
    for _ in 0..SLO_CONNS {
        let st = TcpStream::connect(addr)?;
        readers.push(spawn_reader(st.try_clone()?, replies.clone()));
        streams.push(st);
    }
    let total = (target_rps * duration.as_secs_f64()) as usize;
    let mut sent: HashMap<String, (Instant, bool)> = HashMap::with_capacity(total);
    let t0 = Instant::now();
    for i in 0..total {
        let due = t0 + Duration::from_secs_f64(i as f64 / target_rps);
        let now = Instant::now();
        if due > now {
            thread::sleep(due - now);
        }
        let high = i % 5 == 0;
        let (id, prio, dl) = if high {
            (format!("h{i}"), "high", HIGH_DEADLINE_MS)
        } else {
            (format!("b{i}"), "bulk", BULK_DEADLINE_MS)
        };
        let line = format!(
            "{{\"id\":\"{id}\",\"op\":\"classify\",\"ids\":[1,2,3,4],\
             \"priority\":\"{prio}\",\"deadline_ms\":{dl}}}\n"
        );
        streams[i % SLO_CONNS].write_all(line.as_bytes())?;
        sent.insert(id, (Instant::now(), high));
    }
    let offered_rps = total as f64 / t0.elapsed().as_secs_f64();
    // every request is answered (prediction or typed shed); wait it out
    let give_up = Instant::now() + Duration::from_secs(30);
    while replies.lock().unwrap().len() < total && Instant::now() < give_up {
        thread::sleep(Duration::from_millis(10));
    }
    for st in &streams {
        st.shutdown(Shutdown::Both).ok();
    }
    for h in readers {
        h.join().ok();
    }

    let replies = replies.lock().unwrap();
    let mut out = SloOutcome {
        target_rps,
        offered_rps,
        sent_high: sent.values().filter(|(_, h)| *h).count(),
        sent_bulk: sent.values().filter(|(_, h)| !*h).count(),
        ok_high: 0,
        ok_bulk: 0,
        rej_high: 0,
        rej_bulk: 0,
        unanswered: 0,
        high_p99_ms: 0.0,
        bulk_rej_codes: HashMap::new(),
    };
    let mut high_lat_ms: Vec<f64> = Vec::new();
    let mut matched = 0usize;
    for r in replies.iter() {
        let Some(&(sent_at, high)) = sent.get(&r.id) else { continue };
        matched += 1;
        match (high, r.ok) {
            (true, true) => {
                out.ok_high += 1;
                high_lat_ms.push(r.at.duration_since(sent_at).as_secs_f64() * 1e3);
            }
            (true, false) => out.rej_high += 1,
            (false, true) => out.ok_bulk += 1,
            (false, false) => {
                out.rej_bulk += 1;
                *out.bulk_rej_codes.entry(r.code.clone()).or_insert(0) += 1;
            }
        }
    }
    out.unanswered = total - matched;
    high_lat_ms.sort_by(f64::total_cmp);
    if !high_lat_ms.is_empty() {
        let idx = ((high_lat_ms.len() as f64 * 0.99) as usize).min(high_lat_ms.len() - 1);
        out.high_p99_ms = high_lat_ms[idx];
    }
    Ok(out)
}

// ---------------------------------------------------------------- phase 3

/// Send `n` requests whose deadline already passed (`deadline_ms: 0`)
/// across all three priority classes; count typed `expired` replies.
fn expired_run(addr: SocketAddr, n: usize) -> anyhow::Result<(usize, usize)> {
    let mut c = TcpStream::connect(addr)?;
    c.set_read_timeout(Some(Duration::from_secs(10)))?;
    let prios = ["high", "normal", "bulk"];
    for i in 0..n {
        let p = prios[i % prios.len()];
        c.write_all(
            format!(
                "{{\"id\":\"x{i}\",\"op\":\"classify\",\"ids\":[1,2,3,4],\
                 \"priority\":\"{p}\",\"deadline_ms\":0}}\n"
            )
            .as_bytes(),
        )?;
    }
    let mut r = BufReader::new(c);
    let mut expired = 0usize;
    let mut line = String::new();
    for _ in 0..n {
        line.clear();
        if r.read_line(&mut line)? == 0 {
            break;
        }
        let v = Json::parse(line.trim()).map_err(|e| anyhow::anyhow!("reply parse: {e}"))?;
        if v.get("error").and_then(Json::as_str) == Some("expired") {
            expired += 1;
        }
    }
    Ok((n, expired))
}

// ------------------------------------------------------------------ stats

struct ClassSnap {
    priority: String,
    completed: f64,
    shed_expired: f64,
    shed_overloaded: f64,
    queue_wait_p99_us: f64,
}

/// One-shot v2 STATS: the per-priority-class admission/queue accounting
/// this PR adds to the protocol.
fn fetch_classes(addr: SocketAddr) -> anyhow::Result<Vec<ClassSnap>> {
    let mut c = TcpStream::connect(addr)?;
    c.set_read_timeout(Some(Duration::from_secs(10)))?;
    c.write_all(b"{\"id\":0,\"op\":\"stats\"}\n")?;
    let mut line = String::new();
    BufReader::new(c).read_line(&mut line)?;
    let v = Json::parse(line.trim()).map_err(|e| anyhow::anyhow!("stats parse: {e}"))?;
    let classes = v
        .get("stats")
        .and_then(|st| st.get("classes"))
        .and_then(Json::as_arr)
        .ok_or_else(|| anyhow::anyhow!("no per-class stats in STATS reply"))?;
    Ok(classes
        .iter()
        .map(|cl| {
            let f = |k: &str| cl.get(k).and_then(Json::as_f64).unwrap_or(0.0);
            ClassSnap {
                priority: cl.get("priority").and_then(Json::as_str).unwrap_or("").to_string(),
                completed: f("completed"),
                shed_expired: f("shed_expired"),
                shed_overloaded: f("shed_overloaded"),
                queue_wait_p99_us: f("queue_wait_p99_us"),
            }
        })
        .collect())
}

fn class<'a>(snaps: &'a [ClassSnap], name: &str) -> &'a ClassSnap {
    snaps.iter().find(|c| c.priority == name).expect("priority class in STATS")
}

fn reactor_threads() -> usize {
    let mut n = 0;
    if let Ok(dir) = std::fs::read_dir("/proc/self/task") {
        for t in dir.flatten() {
            let comm = std::fs::read_to_string(t.path().join("comm")).unwrap_or_default();
            if comm.trim() == "datamux-reactor" {
                n += 1;
            }
        }
    }
    n
}

fn slo_json(o: &SloOutcome) -> Json {
    let codes: Vec<Json> = {
        let mut pairs: Vec<(&String, &usize)> = o.bulk_rej_codes.iter().collect();
        pairs.sort();
        pairs
            .into_iter()
            .map(|(k, v)| obj(vec![("code", s(k)), ("count", num(*v as f64))]))
            .collect()
    };
    obj(vec![
        ("target_rps", num(o.target_rps)),
        ("offered_rps", num(o.offered_rps)),
        ("sent_high", num(o.sent_high as f64)),
        ("sent_bulk", num(o.sent_bulk as f64)),
        ("ok_high", num(o.ok_high as f64)),
        ("ok_bulk", num(o.ok_bulk as f64)),
        ("rej_high", num(o.rej_high as f64)),
        ("rej_bulk", num(o.rej_bulk as f64)),
        ("unanswered", num(o.unanswered as f64)),
        ("high_p99_ms", num(o.high_p99_ms)),
        ("bulk_reject_codes", Json::Arr(codes)),
    ])
}

fn main() -> anyhow::Result<()> {
    let quick = std::env::args().any(|a| a == "--quick");
    let (sub_dur, over_dur, expired_n) = if quick {
        (Duration::from_millis(700), Duration::from_millis(900), 48)
    } else {
        (Duration::from_millis(2500), Duration::from_millis(2500), 96)
    };
    let capacity_rps = (BATCH * N_MUX) as f64 / EXEC_DELAY.as_secs_f64();

    // the bench process holds both ends of every socket: ~2 fds per conn
    let want = (C10K_TARGET * 2 + 1024) as u64;
    let nofile = raise_nofile_limit(want);
    let conns = if (nofile as usize) < C10K_TARGET * 2 + 256 {
        let fit = (nofile as usize).saturating_sub(256) / 2;
        println!("NOFILE limit {nofile} < {want}: holding {fit} conns instead of {C10K_TARGET}");
        fit
    } else {
        C10K_TARGET
    };

    let backend = FakeBackend::new("cls", N_MUX, BATCH, SEQ_LEN, N_CLASSES).with_delay(EXEC_DELAY);
    let engine: Arc<dyn Submit> = Arc::new(
        EngineBuilder::new().max_wait_ms(2).queue_cap(QUEUE_CAP).build_backend(Arc::new(backend))?,
    );
    let server = Server::start(
        engine,
        ServerConfig {
            addr: "127.0.0.1:0".to_string(),
            max_connections: conns + 64,
            ..ServerConfig::default()
        },
    )?;
    let addr = server.local_addr;
    println!(
        "server on {addr}: wave {} x {EXEC_DELAY:?} => capacity {capacity_rps:.0} r/s",
        BATCH * N_MUX
    );

    // ----- phase 1: C10K hold -------------------------------------------
    let hold = c10k_hold(addr, conns)?;
    let one_reactor = reactor_threads() == 1;
    let mut t1 =
        Table::new("C10K: concurrent conns through one reactor thread", &["metric", "value"]);
    for (k, v) in [
        ("connections attempted", hold.attempted.to_string()),
        ("connections held", hold.connected.to_string()),
        ("replies ok", hold.answered.to_string()),
        ("replies error", hold.errors.to_string()),
        ("reactor threads", reactor_threads().to_string()),
        ("wall", format!("{:.2}s", hold.wall.as_secs_f64())),
    ] {
        t1.row(&[k.to_string(), v]);
    }
    t1.print();

    // ----- phase 2: SLO tiers at sub-capacity, then 3x overload ---------
    let sub = slo_run(addr, capacity_rps * 0.35, sub_dur)?;
    let before_over = fetch_classes(addr)?;
    let over = slo_run(addr, capacity_rps * 3.0, over_dur)?;
    let after_over = fetch_classes(addr)?;
    let bulk_shed_server = (class(&after_over, "bulk").shed_expired
        + class(&after_over, "bulk").shed_overloaded)
        - (class(&before_over, "bulk").shed_expired
            + class(&before_over, "bulk").shed_overloaded);
    let mut t2 = Table::new(
        "SLO tiers: 20% high(250ms) / 80% bulk(50ms)",
        &["run", "target r/s", "high ok/rej", "bulk ok/rej", "high p99 ms", "unanswered"],
    );
    for (name, o) in [("0.35x", &sub), ("3.0x", &over)] {
        t2.row(&[
            name.to_string(),
            format!("{:.0}", o.target_rps),
            format!("{}/{}", o.ok_high, o.rej_high),
            format!("{}/{}", o.ok_bulk, o.rej_bulk),
            format!("{:.1}", o.high_p99_ms),
            o.unanswered.to_string(),
        ]);
    }
    t2.print();
    println!(
        "server-side: bulk shed {bulk_shed_server:.0} during overload; \
         high queue_wait p99 {:.0}us cumulative",
        class(&after_over, "high").queue_wait_p99_us
    );

    // ----- phase 3: pre-expired work is shed, never executed ------------
    let done_before = fetch_classes(addr)?;
    let (expired_sent, expired_replies) = expired_run(addr, expired_n)?;
    let done_after = fetch_classes(addr)?;
    let executed_delta: f64 = done_after.iter().map(|c| c.completed).sum::<f64>()
        - done_before.iter().map(|c| c.completed).sum::<f64>();
    println!(
        "pre-expired: {expired_replies}/{expired_sent} typed 'expired' replies, \
         completed delta {executed_delta:.0}"
    );

    server.stop();

    // ----- BENCH_server.json at the repo root ---------------------------
    let c10k_gate = hold.connected >= C10K_TARGET
        && hold.answered == hold.attempted
        && hold.errors == 0
        && one_reactor;
    let subcap_gate = sub.rej_high == 0 && sub.unanswered == 0;
    let slo_gate = over.high_p99_ms <= HIGH_SLO_MS && over.rej_high == 0;
    let shed_gate = over.rej_bulk > 0 && bulk_shed_server > 0.0 && over.unanswered == 0;
    let expired_gate = expired_replies == expired_sent && executed_delta == 0.0;
    let result = obj(vec![
        ("schema", s("server_c10k/v1")),
        ("quick", Json::Bool(quick)),
        (
            "config",
            obj(vec![
                ("n_mux", num(N_MUX as f64)),
                ("batch", num(BATCH as f64)),
                ("exec_delay_ms", num(EXEC_DELAY.as_secs_f64() * 1e3)),
                ("capacity_rps", num(capacity_rps)),
                ("c10k_target", num(C10K_TARGET as f64)),
                ("slo_conns", num(SLO_CONNS as f64)),
                ("high_deadline_ms", num(HIGH_DEADLINE_MS as f64)),
                ("bulk_deadline_ms", num(BULK_DEADLINE_MS as f64)),
                ("high_slo_ms", num(HIGH_SLO_MS)),
                ("nofile_limit", num(nofile as f64)),
            ]),
        ),
        (
            "c10k",
            obj(vec![
                ("attempted", num(hold.attempted as f64)),
                ("connected", num(hold.connected as f64)),
                ("answered", num(hold.answered as f64)),
                ("errors", num(hold.errors as f64)),
                ("wall_s", num(hold.wall.as_secs_f64())),
                ("one_reactor_thread", Json::Bool(one_reactor)),
            ]),
        ),
        ("subcapacity", slo_json(&sub)),
        ("overload", slo_json(&over)),
        (
            "overload_server_classes",
            Json::Arr(
                after_over
                    .iter()
                    .map(|c| {
                        obj(vec![
                            ("priority", s(&c.priority)),
                            ("completed", num(c.completed)),
                            ("shed_expired", num(c.shed_expired)),
                            ("shed_overloaded", num(c.shed_overloaded)),
                            ("queue_wait_p99_us", num(c.queue_wait_p99_us)),
                        ])
                    })
                    .collect(),
            ),
        ),
        (
            "expired",
            obj(vec![
                ("sent", num(expired_sent as f64)),
                ("typed_expired_replies", num(expired_replies as f64)),
                ("executed_delta", num(executed_delta)),
            ]),
        ),
        (
            "gates",
            obj(vec![
                ("c10k_held_and_answered", Json::Bool(c10k_gate)),
                ("zero_high_rejects_subcapacity", Json::Bool(subcap_gate)),
                ("high_p99_within_slo_under_overload", Json::Bool(slo_gate)),
                ("bulk_shed_with_typed_errors", Json::Bool(shed_gate)),
                ("expired_never_executed", Json::Bool(expired_gate)),
            ]),
        ),
    ]);
    let root = std::path::Path::new(env!("CARGO_MANIFEST_DIR"))
        .parent()
        .expect("crate sits one level below the repo root");
    let path = root.join("BENCH_server.json");
    std::fs::write(&path, result.to_pretty())?;

    // self-check: the file must exist, parse, and carry results
    let written = std::fs::read_to_string(&path)?;
    let parsed = Json::parse(&written).map_err(|e| anyhow::anyhow!("reparse: {e}"))?;
    anyhow::ensure!(
        parsed.get("c10k").and_then(|x| x.get("answered")).is_some()
            && parsed.get("overload").and_then(|x| x.get("high_p99_ms")).is_some(),
        "BENCH_server.json is missing results"
    );
    println!("\nwrote {}", path.display());

    // the acceptance gates: fail the bench (and the CI job) loudly
    anyhow::ensure!(
        c10k_gate,
        "C10K gate failed: connected={} answered={} errors={} of {} (one_reactor={one_reactor})",
        hold.connected,
        hold.answered,
        hold.errors,
        hold.attempted
    );
    anyhow::ensure!(
        subcap_gate,
        "sub-capacity gate failed: {} high rejects, {} unanswered — admission must not shed \
         high-priority work when there is spare capacity",
        sub.rej_high,
        sub.unanswered
    );
    anyhow::ensure!(
        slo_gate,
        "overload SLO gate failed: high p99 {:.1}ms (budget {HIGH_SLO_MS}ms), {} high rejects",
        over.high_p99_ms,
        over.rej_high
    );
    anyhow::ensure!(
        shed_gate,
        "overload shed gate failed: rej_bulk={} server_shed={bulk_shed_server:.0} unanswered={} \
         — bulk must be shed fast with typed errors, not left to time out",
        over.rej_bulk,
        over.unanswered
    );
    anyhow::ensure!(
        expired_gate,
        "expired gate failed: {expired_replies}/{expired_sent} typed replies, \
         completed delta {executed_delta:.0} — pre-expired work must never execute"
    );
    println!(
        "gates OK: {} conns on one reactor thread; high p99 {:.1}ms under 3x overload; \
         bulk shed fast; expired never executed",
        hold.connected, over.high_p99_ms
    );
    Ok(())
}
