//! Shape-bucket bench: throughput vs request-length distribution, real
//! T-MUX math (`NativeBackend`), zero artifacts.
//!
//! Two engines over the **same weights** run in the same process:
//!
//! * **bucketed** — sequence-length buckets `{SHORT, MID, MAX}`; a
//!   request only pays attention/GEMM for its own bucket's shape;
//! * **pad-to-max** — the live baseline: the identical engine with the
//!   single terminal bucket, i.e. exactly the pre-bucketing behavior,
//!   measured in the same run on the same machine (never a stale
//!   constant).
//!
//! Both are driven with the same unpadded rows across three length
//! distributions (uniform-short, bimodal, all-max). Attention is
//! O(input_len²), so short requests in a pad-to-max engine pay a
//! quadratic tax — the uniform-short sweep is where bucketing must win.
//!
//! Gates (enforced wherever the bench runs, CI included):
//!
//! 1. **uniform-short ≥ 2x** — bucketed throughput at least doubles the
//!    live pad-to-max baseline on the short-request distribution.
//! 2. **scratch_reallocs == 0** on every engine in steady state (the
//!    per-bucket worker scratches are pre-sized).
//! 3. **arena_reallocs flat** after per-bucket warmup on the measured
//!    passes (the native workspace pool is keyed on the bucket).
//!
//! Results are printed as a table and written to `BENCH_shapes.json` at
//! the repo root (uploaded as a CI artifact next to the other benches).
//!
//!   cargo bench --bench shape_buckets            # full
//!   cargo bench --bench shape_buckets -- --quick # CI-sized

use std::sync::Arc;

use datamux::runtime::NativeBackend;
use datamux::util::bench::Table;
use datamux::util::json::{arr, num, obj, s, Json};
use datamux::util::rng::Rng;
use datamux::workload::batch_pass;
use datamux::{EngineBuilder, MuxCoordinator, Submit};

const N_MUX: usize = 4;
const BATCH: usize = 2;
const SEQ_MAX: usize = 96;
const BUCKETS: [usize; 2] = [24, 48]; // + SEQ_MAX terminal
const D_MODEL: usize = 32;
const N_LAYERS: usize = 1;
const N_HEADS: usize = 4;
const N_CLASSES: usize = 3;
const SEED: u64 = 424242;

/// One framed unpadded row of `content_len` total tokens.
fn row(rng: &mut Rng, content_len: usize) -> Vec<i32> {
    assert!((2..=SEQ_MAX).contains(&content_len));
    let mut r = Vec::with_capacity(content_len);
    r.push(1); // [CLS]
    for _ in 0..content_len - 2 {
        r.push(44 + rng.below(200) as i32);
    }
    r.push(2); // [SEP]
    r
}

/// A request-length distribution: framed row lengths for one sweep.
struct Dist {
    name: &'static str,
    lens: fn(&mut Rng) -> usize,
}

const DISTS: [Dist; 3] = [
    // everything fits the smallest bucket: the quadratic-win case
    Dist { name: "uniform_short", lens: |r| 4 + r.below(17) }, // 4..=20
    // half short, half near-max: realistic mixed traffic
    Dist {
        name: "bimodal",
        lens: |r| if r.below(2) == 0 { 4 + r.below(17) } else { 80 + r.below(15) },
    },
    // worst case for bucketing: everything lands in the terminal bucket
    Dist { name: "all_max", lens: |r| 88 + r.below(7) }, // 88..=94
];

fn backend() -> anyhow::Result<NativeBackend> {
    NativeBackend::random(
        "cls", N_MUX, BATCH, SEQ_MAX, D_MODEL, N_LAYERS, N_HEADS, N_CLASSES, SEED,
    )
}

fn engine(
    buckets: Vec<usize>,
    queue_cap: usize,
) -> anyhow::Result<(Arc<MuxCoordinator>, Arc<NativeBackend>)> {
    let be = Arc::new(backend()?);
    let coord = Arc::new(
        EngineBuilder::new()
            .max_wait_ms(1)
            .queue_cap(queue_cap)
            .buckets(buckets)
            .build_backend(be.clone())?,
    );
    Ok((coord, be))
}

fn main() -> anyhow::Result<()> {
    let quick = std::env::args().any(|a| a == "--quick");
    let requests: usize = if quick { 64 } else { 512 };

    // warmup rows touch every bucket so the measured pass materializes
    // no new arenas (the steady-state gate)
    let warmup_rows: Vec<Vec<i32>> = {
        let mut rng = Rng::new(SEED ^ 1);
        [4usize, 8, 30, 40, 90, 94].iter().map(|&l| row(&mut rng, l)).collect()
    };

    let mut table = Table::new(
        "shape buckets: throughput vs request-length distribution (native math)",
        &[
            "distribution",
            "bucketed r/s",
            "pad-to-max r/s",
            "speedup",
            "bucketed pad-toks",
            "pad-to-max pad-toks",
        ],
    );
    let mut sweep = Vec::new();
    let mut short_speedup = 0.0f64;
    let mut total_scratch = 0u64;
    let mut total_arena_growth = 0u64;

    for dist in &DISTS {
        // fresh engines per distribution so counters and queues are clean;
        // identical weights via the shared seed
        let (bucketed, be_b) = engine(BUCKETS.to_vec(), requests + 16)?;
        let (padmax, be_p) = engine(Vec::new(), requests + 16)?;
        let mut rng = Rng::new(SEED ^ 0xd15b);
        let rows: Vec<Vec<i32>> =
            (0..requests).map(|_| row(&mut rng, (dist.lens)(&mut rng))).collect();

        let mut results = Vec::new();
        for (eng, be) in [(&bucketed, &be_b), (&padmax, &be_p)] {
            let w = batch_pass(eng, &warmup_rows, warmup_rows.len());
            anyhow::ensure!(w.completed == warmup_rows.len(), "warmup lost requests");
            // measure the timed pass only: counters are deltas past the
            // warmup, so the reported padding waste (and the realloc
            // gates) reflect the distribution, not the warmup waves
            let arena_before = be.arena_reallocs();
            let before = eng.counters();
            let report = batch_pass(eng, &rows, requests);
            anyhow::ensure!(
                report.completed == requests,
                "{}: lost requests: {} of {requests}",
                dist.name,
                report.completed
            );
            let arena_growth = be.arena_reallocs() - arena_before;
            let c = eng.counters();
            total_scratch += c.scratch_reallocs - before.scratch_reallocs;
            total_arena_growth += arena_growth;
            results.push((
                report.throughput_rps,
                c.tokens_padded - before.tokens_padded,
                arena_growth,
            ));
        }
        let (b_rps, b_pad, _) = results[0];
        let (p_rps, p_pad, _) = results[1];
        let speedup = b_rps / p_rps;
        if dist.name == "uniform_short" {
            short_speedup = speedup;
        }
        table.row(&[
            dist.name.to_string(),
            format!("{b_rps:.0}"),
            format!("{p_rps:.0}"),
            format!("{speedup:.2}x"),
            format!("{b_pad}"),
            format!("{p_pad}"),
        ]);
        sweep.push(obj(vec![
            ("distribution", s(dist.name)),
            ("requests", num(requests as f64)),
            ("bucketed_rps", num(b_rps)),
            ("padmax_rps", num(p_rps)),
            ("speedup_vs_padmax", num(speedup)),
            ("bucketed_tokens_padded", num(b_pad as f64)),
            ("padmax_tokens_padded", num(p_pad as f64)),
        ]));
    }
    table.print();

    let result = obj(vec![
        ("schema", s("shape_buckets/v1")),
        ("quick", Json::Bool(quick)),
        (
            "config",
            obj(vec![
                ("n_mux", num(N_MUX as f64)),
                ("batch", num(BATCH as f64)),
                ("seq_len_max", num(SEQ_MAX as f64)),
                ("buckets", arr(BUCKETS.iter().map(|&b| num(b as f64)))),
                ("d_model", num(D_MODEL as f64)),
                ("n_layers", num(N_LAYERS as f64)),
                ("n_heads", num(N_HEADS as f64)),
                ("requests", num(requests as f64)),
            ]),
        ),
        ("sweep", arr(sweep)),
        ("uniform_short_speedup", num(short_speedup)),
        ("steady_state_scratch_reallocs", num(total_scratch as f64)),
        ("steady_state_arena_reallocs", num(total_arena_growth as f64)),
    ]);
    let root = std::path::Path::new(env!("CARGO_MANIFEST_DIR"))
        .parent()
        .expect("crate sits one level below the repo root");
    let path = root.join("BENCH_shapes.json");
    std::fs::write(&path, result.to_pretty())?;

    // self-check: the file must exist, parse, and carry the sweep —
    // CI fails the job otherwise
    let written = std::fs::read_to_string(&path)?;
    let parsed = Json::parse(&written).map_err(|e| anyhow::anyhow!("reparse: {e}"))?;
    anyhow::ensure!(
        parsed.get("sweep").and_then(Json::as_arr).map_or(0, |a| a.len()) == DISTS.len()
            && parsed.get("uniform_short_speedup").and_then(Json::as_f64).is_some(),
        "BENCH_shapes.json is missing results"
    );
    println!(
        "\nwrote {} (uniform-short speedup vs live pad-to-max baseline: {short_speedup:.2}x)",
        path.display()
    );
    // acceptance gates
    anyhow::ensure!(
        short_speedup >= 2.0,
        "bucketing regression: uniform-short throughput is only {short_speedup:.2}x the live \
         pad-to-max baseline (gate: >= 2x)"
    );
    anyhow::ensure!(
        total_scratch == 0,
        "worker scratch grew mid-serving ({total_scratch} reallocs; must be 0 per bucket)"
    );
    anyhow::ensure!(
        total_arena_growth == 0,
        "native arenas materialized {total_arena_growth} new workspaces after warmup \
         (must be 0 per bucket)"
    );
    Ok(())
}
