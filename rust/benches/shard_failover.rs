//! Shard-failover soak: a [`ShardRouter`] over three real `datamux
//! serve` **child processes**, driven by a trace replay with a mid-run
//! SIGKILL and a later restart of one shard.
//!
//! The trace models an MNLI-like classification stream: bimodal lengths
//! (~70% short rows in the 16-token bucket, ~30% long rows in the
//! 64-token bucket), bursty arrivals (fixed-size bursts on a fixed
//! period), and a 20% high-priority slice carrying a 250 ms deadline.
//!
//! Timeline: warm -> SIGKILL shard 1 -> soak through the outage
//! (closed-loop high-tier probes measure client-observed latency while
//! the pool is degraded) -> restart shard 1 on the same port -> the
//! half-open probe re-adopts it.
//!
//! Three gates make the bench (and the CI job) **exit non-zero**:
//!
//! 1. **zero_lost_across_kill** — every request the router admitted
//!    resolves to exactly one typed answer, and every successful answer
//!    carries the class the fake model assigns to that exact row (no
//!    crossed wires through failover).
//! 2. **high_p99_within_slo_during_failover** — closed-loop high-tier
//!    probes stay under the SLO budget while a third of the pool is
//!    dead.
//! 3. **killed_shard_readopted** — after the restart the breaker closes
//!    again and the returned shard serves traffic.
//!
//! Results go to `BENCH_shards.json` at the repo root.
//!
//!   cargo bench --bench shard_failover            # full
//!   cargo bench --bench shard_failover -- --quick # CI-sized

use std::net::{TcpListener, TcpStream};
use std::process::{Child, Command, Stdio};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{Arc, Mutex};
use std::thread;
use std::time::{Duration, Instant};

use datamux::coordinator::{
    InferenceRequest, Placement, Priority, ShardConfig, ShardRouter, ShardState,
};
use datamux::util::bench::Table;
use datamux::util::json::{num, obj, s, Json};
use datamux::util::rng::Rng;
use datamux::{FakeBackend, RequestHandle, Submit};

const N_SHARDS: usize = 3;
const KILLED: usize = 1;
const SEQ_LEN: usize = 64;
const N_CLASSES: usize = 3;
const EXEC_DELAY_MS: u64 = 2;
const HIGH_DEADLINE_MS: u64 = 250;
/// Client-observed p99 budget for high-tier probes during the outage.
const HIGH_SLO_MS: f64 = 150.0;
const BURST: usize = 8;
const BURST_PERIOD_MS: f64 = 25.0;
const PROBE_THREADS: usize = 2;

// ------------------------------------------------------------- shard procs

/// One backend shard as a real child process (`datamux serve --backend
/// fake`), killable with SIGKILL and restartable on the same port.
struct ShardProc {
    child: Option<Child>,
}

impl ShardProc {
    fn spawn(addr: &str) -> anyhow::Result<ShardProc> {
        let child = Command::new(env!("CARGO_BIN_EXE_datamux"))
            .args([
                "--cmd",
                "serve",
                "--backend",
                "fake",
                "--addr",
                addr,
                "--fake-seq-len",
                "64",
                "--fake-classes",
                "3",
                "--fake-n",
                "2",
                "--fake-delay-ms",
                "2",
                "--buckets",
                "16,64",
                "--max-wait-ms",
                "1",
                "--queue-cap",
                "4096",
                "--max-connections",
                "16",
            ])
            .stdout(Stdio::null())
            .stderr(Stdio::null())
            .spawn()?;
        let t0 = Instant::now();
        while TcpStream::connect(addr).is_err() {
            anyhow::ensure!(
                t0.elapsed() < Duration::from_secs(15),
                "shard {addr} did not start listening"
            );
            thread::sleep(Duration::from_millis(25));
        }
        Ok(ShardProc { child: Some(child) })
    }

    /// SIGKILL: no drain, no goodbye — the crash the failover path is for.
    fn kill(&mut self) {
        if let Some(mut c) = self.child.take() {
            c.kill().ok();
            c.wait().ok();
        }
    }
}

impl Drop for ShardProc {
    fn drop(&mut self) {
        self.kill();
    }
}

/// Pick `n` distinct free ports (bind, read, release).
fn free_addrs(n: usize) -> Vec<String> {
    let listeners: Vec<TcpListener> =
        (0..n).map(|_| TcpListener::bind("127.0.0.1:0").expect("bind :0")).collect();
    listeners.iter().map(|l| l.local_addr().unwrap().to_string()).collect()
}

// ------------------------------------------------------------------ trace

struct TraceEvent {
    due: Duration,
    row: Vec<i32>,
    high: bool,
}

/// Bimodal bursty trace: bursts of [`BURST`] requests every
/// [`BURST_PERIOD_MS`], rows ~70% short (16-token bucket) / ~30% long
/// (64-token bucket), 20% high priority. Seeded — the same trace
/// replays identically run to run.
fn build_trace(seed: u64, duration: Duration) -> Vec<TraceEvent> {
    let mut rng = Rng::new(seed);
    let bursts = (duration.as_secs_f64() * 1e3 / BURST_PERIOD_MS) as usize;
    let mut trace = Vec::with_capacity(bursts * BURST);
    for b in 0..bursts {
        let due = Duration::from_secs_f64(b as f64 * BURST_PERIOD_MS / 1e3);
        for _ in 0..BURST {
            let content_len =
                if rng.bool(0.7) { rng.range(3, 13) } else { rng.range(20, SEQ_LEN - 2) };
            let mut row = Vec::with_capacity(content_len + 2);
            row.push(1); // [CLS]
            for _ in 0..content_len {
                row.push(44 + rng.below(200) as i32);
            }
            row.push(2); // [SEP]
            trace.push(TraceEvent { due, row, high: rng.bool(0.2) });
        }
    }
    trace
}

struct Admitted {
    expected: usize,
    handle: RequestHandle,
}

/// Open-loop replay on its own thread: pace by the trace clock, submit
/// everything, hand the handles back for the zero-lost audit.
fn replay(
    router: Arc<ShardRouter>,
    trace: Vec<TraceEvent>,
    t0: Instant,
) -> (Vec<Admitted>, usize) {
    let mut admitted = Vec::with_capacity(trace.len());
    let mut refused = 0usize;
    for ev in trace {
        let due = t0 + ev.due;
        let now = Instant::now();
        if due > now {
            thread::sleep(due - now);
        }
        let expected = FakeBackend::expected_class(&ev.row, N_CLASSES);
        let mut req = InferenceRequest::classify_framed(ev.row);
        if ev.high {
            req = req
                .with_priority(Priority::High)
                .with_deadline(Duration::from_millis(HIGH_DEADLINE_MS));
        }
        match router.submit(req) {
            Ok(handle) => admitted.push(Admitted { expected, handle }),
            Err(_) => refused += 1,
        }
    }
    (admitted, refused)
}

// ------------------------------------------------------------- SLO probes

struct ProbeReport {
    samples: Vec<f64>,
    failures: usize,
}

/// Closed-loop high-tier probe: submit one request, wait for its own
/// answer, record the client-observed wall time. Runs only while the
/// pool is degraded — this *is* the "p99 during failover" measurement.
fn probe_loop(router: Arc<ShardRouter>, stop: Arc<AtomicBool>, out: Arc<Mutex<ProbeReport>>) {
    let row = vec![1, 50, 60, 70, 2];
    while !stop.load(Ordering::Acquire) {
        let req = InferenceRequest::classify_framed(row.clone())
            .with_priority(Priority::High)
            .with_deadline(Duration::from_millis(HIGH_DEADLINE_MS));
        let t = Instant::now();
        let outcome = router.submit(req).ok().and_then(|h| h.wait_timeout(Duration::from_secs(2)));
        let ms = t.elapsed().as_secs_f64() * 1e3;
        let mut r = out.lock().unwrap();
        match outcome {
            Some(Ok(_)) => r.samples.push(ms),
            _ => r.failures += 1,
        }
        drop(r);
        thread::sleep(Duration::from_millis(2));
    }
}

fn p99(samples: &mut [f64]) -> f64 {
    if samples.is_empty() {
        return 0.0;
    }
    samples.sort_by(f64::total_cmp);
    samples[((samples.len() as f64 * 0.99) as usize).min(samples.len() - 1)]
}

// ------------------------------------------------------------------- main

fn main() -> anyhow::Result<()> {
    let quick = std::env::args().any(|a| a == "--quick");
    let (warm, down, post) = if quick {
        (Duration::from_millis(1000), Duration::from_millis(1500), Duration::from_millis(1000))
    } else {
        (Duration::from_secs(3), Duration::from_secs(4), Duration::from_secs(3))
    };
    let total = warm + down + post;

    let addrs = free_addrs(N_SHARDS);
    let mut shards: Vec<ShardProc> = Vec::with_capacity(N_SHARDS);
    for a in &addrs {
        shards.push(ShardProc::spawn(a)?);
    }
    println!("{N_SHARDS} shard processes up: {addrs:?}");

    let router = Arc::new(ShardRouter::connect(
        ShardConfig::new(addrs.clone())
            .placement(Placement::RoundRobin)
            .probe_interval(Duration::from_millis(50))
            .probe_timeout(Duration::from_millis(250))
            .backoff(Duration::from_millis(50), Duration::from_millis(400))
            .connect_timeout(Duration::from_millis(500))
            .hop_timeout(Duration::from_secs(5)),
    )?);

    let trace = build_trace(7, total);
    let offered = trace.len();
    println!(
        "trace: {offered} requests over {:.1}s (bursts of {BURST} / {BURST_PERIOD_MS}ms, \
         70/30 short/long, 20% high@{HIGH_DEADLINE_MS}ms)",
        total.as_secs_f64()
    );

    // replay the whole timeline on a driver thread; orchestrate the
    // kill and restart from here on the same clock
    let t0 = Instant::now();
    let driver = {
        let router = router.clone();
        thread::spawn(move || replay(router, trace, t0))
    };

    // --- warm, then SIGKILL one shard mid-stream ------------------------
    thread::sleep(warm.saturating_sub(t0.elapsed()));
    shards[KILLED].kill();
    let killed_at = Instant::now();
    println!("killed shard {KILLED} ({}) at t={:.2}s", addrs[KILLED], t0.elapsed().as_secs_f64());

    // closed-loop high-tier probes across the outage window
    let stop = Arc::new(AtomicBool::new(false));
    let report = Arc::new(Mutex::new(ProbeReport { samples: Vec::new(), failures: 0 }));
    let probes: Vec<_> = (0..PROBE_THREADS)
        .map(|_| {
            let (r, st, rep) = (router.clone(), stop.clone(), report.clone());
            thread::spawn(move || probe_loop(r, st, rep))
        })
        .collect();

    thread::sleep((warm + down).saturating_sub(t0.elapsed()));
    stop.store(true, Ordering::Release);
    for p in probes {
        p.join().ok();
    }

    // --- restart the shard on the same port; wait for re-adoption -------
    shards[KILLED] = ShardProc::spawn(&addrs[KILLED])?;
    let restarted_at = Instant::now();
    println!("restarted shard {KILLED} at t={:.2}s", t0.elapsed().as_secs_f64());
    let mut readopt_ms = -1.0;
    let give_up = Instant::now() + Duration::from_secs(10);
    while Instant::now() < give_up {
        if router.shard_status()[KILLED].state == ShardState::Closed {
            readopt_ms = restarted_at.elapsed().as_secs_f64() * 1e3;
            break;
        }
        thread::sleep(Duration::from_millis(20));
    }

    let (admitted, refused) = driver.join().expect("driver thread");

    // the returned shard must serve again: push a burst and watch its
    // completed counter move
    let completed_before = router.shard_status()[KILLED].completed;
    let mut tail = Vec::new();
    for i in 0..50 {
        let row = vec![1, 44 + (i % 100), 2];
        tail.push(router.submit(InferenceRequest::classify_framed(row))?);
    }
    for h in &tail {
        let _ = h.wait_timeout(Duration::from_secs(5));
    }
    let served_after_return = router.shard_status()[KILLED].completed - completed_before;

    // --- audit: nothing admitted is lost, nothing crossed wires ---------
    let (mut ok, mut failed_typed, mut wrong, mut unresolved) = (0usize, 0usize, 0usize, 0usize);
    for a in &admitted {
        match a.handle.wait_timeout(Duration::from_secs(15)) {
            Some(Ok(resp)) => {
                if resp.pred_class() == a.expected {
                    ok += 1;
                } else {
                    wrong += 1;
                }
            }
            Some(Err(_)) => failed_typed += 1,
            None => unresolved += 1,
        }
    }
    let status = router.shard_status();
    let failovers: u64 = status.iter().map(|sh| sh.failovers).sum();
    let mut rep = Arc::try_unwrap(report).ok().expect("probes joined").into_inner().unwrap();
    let probe_p99_ms = p99(&mut rep.samples);

    let mut t = Table::new("shard failover soak", &["metric", "value"]);
    for (k, v) in [
        ("offered", offered.to_string()),
        ("admitted", admitted.len().to_string()),
        ("refused at admission", refused.to_string()),
        ("ok (correct class)", ok.to_string()),
        ("failed typed", failed_typed.to_string()),
        ("wrong class", wrong.to_string()),
        ("unresolved", unresolved.to_string()),
        ("failovers", failovers.to_string()),
        ("outage probes", rep.samples.len().to_string()),
        ("outage probe failures", rep.failures.to_string()),
        ("outage high p99 ms", format!("{probe_p99_ms:.1}")),
        ("readopt ms after restart", format!("{readopt_ms:.0}")),
        ("served after return", served_after_return.to_string()),
    ] {
        t.row(&[k.to_string(), v]);
    }
    t.print();

    drop(router); // shut the pool down before the children die

    // ----- gates --------------------------------------------------------
    let zero_lost = unresolved == 0 && wrong == 0 && !admitted.is_empty();
    let slo_gate = rep.failures == 0 && !rep.samples.is_empty() && probe_p99_ms <= HIGH_SLO_MS;
    let readopted = readopt_ms >= 0.0 && served_after_return > 0;

    let result = obj(vec![
        ("schema", s("shard_failover/v1")),
        ("quick", Json::Bool(quick)),
        (
            "config",
            obj(vec![
                ("n_shards", num(N_SHARDS as f64)),
                ("seq_len", num(SEQ_LEN as f64)),
                ("n_classes", num(N_CLASSES as f64)),
                ("exec_delay_ms", num(EXEC_DELAY_MS as f64)),
                ("burst", num(BURST as f64)),
                ("burst_period_ms", num(BURST_PERIOD_MS)),
                ("high_deadline_ms", num(HIGH_DEADLINE_MS as f64)),
                ("high_slo_ms", num(HIGH_SLO_MS)),
                ("warm_s", num(warm.as_secs_f64())),
                ("down_s", num(down.as_secs_f64())),
                ("post_s", num(post.as_secs_f64())),
            ]),
        ),
        (
            "soak",
            obj(vec![
                ("offered", num(offered as f64)),
                ("admitted", num(admitted.len() as f64)),
                ("refused", num(refused as f64)),
                ("ok", num(ok as f64)),
                ("failed_typed", num(failed_typed as f64)),
                ("wrong_class", num(wrong as f64)),
                ("unresolved", num(unresolved as f64)),
                ("failovers", num(failovers as f64)),
            ]),
        ),
        (
            "outage",
            obj(vec![
                ("probe_samples", num(rep.samples.len() as f64)),
                ("probe_failures", num(rep.failures as f64)),
                ("high_p99_ms", num(probe_p99_ms)),
                ("window_s", num(restarted_at.duration_since(killed_at).as_secs_f64())),
            ]),
        ),
        (
            "recovery",
            obj(vec![
                ("readopt_ms", num(readopt_ms)),
                ("served_after_return", num(served_after_return as f64)),
            ]),
        ),
        (
            "shards",
            Json::Arr(
                status
                    .iter()
                    .map(|sh| {
                        obj(vec![
                            ("addr", s(&sh.addr)),
                            ("state", s(sh.state.as_str())),
                            ("probes", num(sh.probes as f64)),
                            ("probe_failures", num(sh.probe_failures as f64)),
                            ("failovers", num(sh.failovers as f64)),
                            ("completed", num(sh.completed as f64)),
                            ("ewma_rtt_us", num(sh.ewma_rtt_us)),
                        ])
                    })
                    .collect(),
            ),
        ),
        (
            "gates",
            obj(vec![
                ("zero_lost_across_kill", Json::Bool(zero_lost)),
                ("high_p99_within_slo_during_failover", Json::Bool(slo_gate)),
                ("killed_shard_readopted", Json::Bool(readopted)),
            ]),
        ),
    ]);
    let root = std::path::Path::new(env!("CARGO_MANIFEST_DIR"))
        .parent()
        .expect("crate sits one level below the repo root");
    let path = root.join("BENCH_shards.json");
    std::fs::write(&path, result.to_pretty())?;

    // self-check: the file must exist, parse, and carry results
    let written = std::fs::read_to_string(&path)?;
    let parsed = Json::parse(&written).map_err(|e| anyhow::anyhow!("reparse: {e}"))?;
    anyhow::ensure!(
        parsed.get("soak").and_then(|x| x.get("unresolved")).is_some()
            && parsed.get("outage").and_then(|x| x.get("high_p99_ms")).is_some(),
        "BENCH_shards.json is missing results"
    );
    println!("\nwrote {}", path.display());

    anyhow::ensure!(
        zero_lost,
        "zero-lost gate failed: {unresolved} unresolved, {wrong} wrong-class of {} admitted \
         — every admitted request must resolve to exactly one correct typed answer",
        admitted.len()
    );
    anyhow::ensure!(
        slo_gate,
        "failover SLO gate failed: high p99 {probe_p99_ms:.1}ms (budget {HIGH_SLO_MS}ms), \
         {} probe failures of {} samples while a shard was down",
        rep.failures,
        rep.samples.len()
    );
    anyhow::ensure!(
        readopted,
        "re-adoption gate failed: readopt_ms={readopt_ms:.0} served_after_return=\
         {served_after_return} — the restarted shard must be probed back into rotation"
    );
    println!(
        "gates OK: {}/{} admitted answered correctly across a SIGKILL; outage high p99 \
         {probe_p99_ms:.1}ms; shard re-adopted in {readopt_ms:.0}ms",
        ok,
        admitted.len()
    );
    Ok(())
}
