//! Line-preserving scrubber behind the `datamux lint` pass.
//!
//! Not a parser: a character state machine that splits a Rust source
//! file into a *code channel* (string/char-literal contents and
//! comments blanked to spaces) and a *comment channel* (the comment
//! text each line carries). Rules run cheap token searches over the
//! code channel — a banned token inside a string or comment can never
//! fire — and read justifications (SAFETY notes, markers) from the
//! comment channel.
//!
//! Handled: line and nested block comments, plain / byte / raw strings
//! (any `#` depth), char literals vs lifetimes, escapes. Both channels
//! keep the file's exact line structure, so every finding maps back to
//! a real source line.

/// One source file split into per-line code and comment channels.
pub struct Scrubbed {
    /// Original source lines, for allowlist matching and messages.
    pub raw: Vec<String>,
    /// Code with literal contents and comments blanked to spaces.
    pub code: Vec<String>,
    /// Comment text carried by each line (line, doc and block).
    pub comments: Vec<String>,
}

#[derive(Clone, Copy)]
enum State {
    Code,
    LineComment,
    BlockComment { depth: u32 },
    Str,
    RawStr { hashes: usize },
}

/// Split `src` into its code and comment channels.
pub fn scrub(src: &str) -> Scrubbed {
    let chars: Vec<char> = src.chars().collect();
    let n = chars.len();
    let mut code_lines = Vec::new();
    let mut comment_lines = Vec::new();
    let mut code = String::new();
    let mut comment = String::new();
    let mut state = State::Code;
    let mut i = 0;
    while i < n {
        let c = chars[i];
        if c == '\n' {
            if matches!(state, State::LineComment) {
                state = State::Code;
            }
            code_lines.push(std::mem::take(&mut code));
            comment_lines.push(std::mem::take(&mut comment));
            i += 1;
            continue;
        }
        let nxt = chars.get(i + 1).copied();
        match state {
            State::Code => {
                if c == '/' && nxt == Some('/') {
                    state = State::LineComment;
                    i += 2;
                } else if c == '/' && nxt == Some('*') {
                    state = State::BlockComment { depth: 1 };
                    i += 2;
                } else if c == '"' {
                    code.push('"');
                    state = State::Str;
                    i += 1;
                } else if (c == 'r' || c == 'b') && !(i > 0 && is_word(chars[i - 1])) {
                    if let Some((quote, hashes)) = raw_string_open(&chars, i) {
                        for _ in i..quote {
                            code.push(' ');
                        }
                        code.push('"');
                        state = State::RawStr { hashes };
                        i = quote + 1;
                    } else if c == 'b' && nxt == Some('"') {
                        code.push(' ');
                        code.push('"');
                        state = State::Str;
                        i += 2;
                    } else {
                        code.push(c);
                        i += 1;
                    }
                } else if c == '\'' {
                    // char literal iff escaped or exactly one char
                    // wide; otherwise a lifetime, which stays code
                    let is_char = nxt == Some('\\')
                        || (chars.get(i + 2) == Some(&'\'') && nxt != Some('\''));
                    match char_literal_end(&chars, i).filter(|_| is_char) {
                        Some(end) => {
                            code.push('\'');
                            for _ in i + 1..end {
                                code.push(' ');
                            }
                            code.push('\'');
                            i = end + 1;
                        }
                        None => {
                            code.push('\'');
                            i += 1;
                        }
                    }
                } else {
                    code.push(c);
                    i += 1;
                }
            }
            State::LineComment => {
                comment.push(c);
                i += 1;
            }
            State::BlockComment { depth } => {
                if c == '/' && nxt == Some('*') {
                    state = State::BlockComment { depth: depth + 1 };
                    i += 2;
                } else if c == '*' && nxt == Some('/') {
                    state = if depth == 1 {
                        State::Code
                    } else {
                        State::BlockComment { depth: depth - 1 }
                    };
                    i += 2;
                } else {
                    comment.push(c);
                    i += 1;
                }
            }
            State::Str => {
                if c == '\\' {
                    code.push(' ');
                    // consume the escaped char, but never a newline:
                    // the line push above must still run for it
                    if nxt.is_some() && nxt != Some('\n') {
                        code.push(' ');
                        i += 2;
                    } else {
                        i += 1;
                    }
                } else if c == '"' {
                    code.push('"');
                    state = State::Code;
                    i += 1;
                } else {
                    code.push(' ');
                    i += 1;
                }
            }
            State::RawStr { hashes } => {
                if c == '"' && (1..=hashes).all(|h| chars.get(i + h) == Some(&'#')) {
                    code.push('"');
                    for _ in 0..hashes {
                        code.push(' ');
                    }
                    state = State::Code;
                    i += 1 + hashes;
                } else {
                    code.push(' ');
                    i += 1;
                }
            }
        }
    }
    code_lines.push(code);
    comment_lines.push(comment);
    let raw: Vec<String> = src.split('\n').map(str::to_string).collect();
    debug_assert_eq!(raw.len(), code_lines.len());
    debug_assert_eq!(raw.len(), comment_lines.len());
    Scrubbed { raw, code: code_lines, comments: comment_lines }
}

/// If a raw (or raw byte) string opens at `i`, the index of its opening
/// quote and its `#` count.
fn raw_string_open(chars: &[char], i: usize) -> Option<(usize, usize)> {
    let mut j = i;
    if chars[j] == 'b' {
        j += 1;
    }
    if chars.get(j) != Some(&'r') {
        return None;
    }
    j += 1;
    let mut hashes = 0;
    while chars.get(j) == Some(&'#') {
        hashes += 1;
        j += 1;
    }
    (chars.get(j) == Some(&'"')).then_some((j, hashes))
}

/// Index of the closing quote of a char literal opening at `i`, if it
/// closes on the same line.
fn char_literal_end(chars: &[char], i: usize) -> Option<usize> {
    let mut j = i + 1;
    while j < chars.len() && chars[j] != '\n' {
        match chars[j] {
            '\\' => j += 2,
            '\'' => return Some(j),
            _ => j += 1,
        }
    }
    None
}

/// Identifier-forming character (the token boundary test).
pub fn is_word(c: char) -> bool {
    c.is_alphanumeric() || c == '_'
}

/// Count occurrences of `word` in `line` with word boundaries on both
/// sides — `Mutex` does not match inside `TrackedMutex`.
pub fn count_word(line: &str, word: &str) -> usize {
    let mut n = 0;
    let mut start = 0;
    while let Some(pos) = line[start..].find(word) {
        let at = start + pos;
        let end = at + word.len();
        let before = line[..at].chars().next_back().is_none_or(|c| !is_word(c));
        let after = line[end..].chars().next().is_none_or(|c| !is_word(c));
        if before && after {
            n += 1;
        }
        start = end;
    }
    n
}

/// `count_word(..) > 0`.
pub fn has_word(line: &str, word: &str) -> bool {
    count_word(line, word) > 0
}

/// Does `line` invoke macro `needle` (word boundary on the left only —
/// the `!` already terminates the token on the right)?
pub fn has_macro(line: &str, needle: &str) -> bool {
    let mut start = 0;
    while let Some(pos) = line[start..].find(needle) {
        let at = start + pos;
        if line[..at].chars().next_back().is_none_or(|c| !is_word(c)) {
            return true;
        }
        start = at + needle.len();
    }
    false
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn comments_leave_the_code_channel() {
        let s = scrub("let x = 1; // trailing .unwrap()\n/* block\npanic! */ let y = 2;\n");
        assert!(!s.code[0].contains(".unwrap()"));
        assert!(s.comments[0].contains(".unwrap()"));
        assert!(!s.code[1].contains("panic!"));
        assert!(s.comments[1].contains("panic!"));
        assert!(s.code[2].contains("let y = 2;"));
    }

    #[test]
    fn strings_are_blanked_but_quotes_remain() {
        let s = scrub("let s = \"panic! // no comment\";\nlet t = 1;\n");
        assert!(!s.code[0].contains("panic!"));
        assert!(!s.code[0].contains("//"));
        assert!(s.comments[0].is_empty());
        assert_eq!(s.code[0].matches('"').count(), 2);
        // string escapes cannot hide the closing quote
        let s = scrub("let q = \"a\\\"b\"; q.unwrap();\n");
        assert!(s.code[0].contains(".unwrap()"));
    }

    #[test]
    fn raw_strings_close_only_on_matching_hashes() {
        let s = scrub("let r = r#\"inner \" quote panic!\"#; x.unwrap();\n");
        assert!(!s.code[0].contains("panic!"));
        assert!(s.code[0].contains(".unwrap()"));
    }

    #[test]
    fn char_literals_blank_but_lifetimes_survive() {
        let s = scrub("fn f<'a>(x: &'a str) -> char { '{' }\n");
        assert!(s.code[0].contains("<'a>"));
        assert!(s.code[0].contains("&'a str"));
        // the brace inside the char literal must not skew brace depth
        assert!(!s.code[0].contains("'{'"));
        let s = scrub("let c = '\\n'; let d = b'\\t';\n");
        assert!(!s.code[0].contains('n'), "escape contents blanked: {}", s.code[0]);
    }

    #[test]
    fn nested_block_comments_terminate_correctly() {
        let s = scrub("/* outer /* inner */ still comment */ code();\n");
        assert!(s.code[0].contains("code();"));
        assert!(s.comments[0].contains("still comment"));
    }

    #[test]
    fn multiline_strings_keep_line_structure() {
        let s = scrub("let s = \"line one\n  line two .unwrap()\";\nnext();\n");
        assert_eq!(s.code.len(), 4);
        assert!(!s.code[1].contains(".unwrap()"));
        assert!(s.code[2].contains("next();"));
    }

    #[test]
    fn word_boundaries_reject_identifier_substrings() {
        assert!(has_word("let m: Mutex<u32>;", "Mutex"));
        assert!(!has_word("let m: TrackedMutex<u32>;", "Mutex"));
        assert!(!has_word("let g: MutexGuard<u32>;", "Mutex"));
        assert_eq!(count_word("unsafe impl Send {} unsafe impl Sync {}", "unsafe"), 2);
        assert!(has_macro("    panic!(\"boom\")", "panic!"));
        assert!(!has_macro("    dont_panic!(1)", "panic!"));
    }
}
