//! `datamux lint` — repo-native static analysis over the crate's own
//! sources.
//!
//! `cargo run -- --cmd lint` (or the `lint` CI step) scans `src/` with
//! a lightweight lexer ([`lexer::scrub`]) and enforces four invariants
//! that ordinary rustc/clippy cannot see (documented in DESIGN.md,
//! "Concurrency invariants"):
//!
//! 1. **unsafe-safety** — every `unsafe` outside test code carries a
//!    `SAFETY:` (or `# Safety` doc) justification in the comment block
//!    attached to it.
//! 2. **unsafe-inventory** — the per-file count of non-test `unsafe`
//!    tokens matches the pin in [`UNSAFE_INVENTORY`]. Growing the
//!    unsafe surface fails the lint until the pin is updated in the
//!    same change, which makes it a reviewed, deliberate act.
//! 3. **serving-panic** — no `.unwrap()` / `.expect(` / `panic!` in
//!    non-test serving code (`coordinator/`, `runtime/`, `main.rs`)
//!    outside the justified [`PANIC_ALLOWLIST`].
//! 4. **hot-path-alloc** — a function armed by the marker comment
//!    [`HOT_PATH_MARKER`] must not contain an allocating construct
//!    ([`HOT_PATH_BANNED`]).
//! 5. **raw-lock** — `coordinator/` non-test code must not name the
//!    raw `Mutex` / `Condvar` / `RwLock` primitives: every coordinator
//!    lock goes through the instrumented wrappers in `util::sync`, so
//!    the runtime lock-order/leak detector sees every acquisition.
//!
//! Test code (any `#[cfg(test)]`-attributed item) is exempt from all
//! rules. The pass is deliberately token-based, not a full parser: it
//! understands strings, comments and char literals well enough that a
//! banned token inside either can never misfire, and nothing else.

mod lexer;

use std::fmt;
use std::fs;
use std::io;
use std::path::{Path, PathBuf};

pub use lexer::{scrub, Scrubbed};

use lexer::{count_word, has_macro, has_word, is_word};

/// Marker comment (this constant's exact text) that arms the
/// allocation ban on the next `fn`. Written directly above the
/// function, after any doc comments.
pub const HOT_PATH_MARKER: &str = "lint: hot-path";

/// Allocating constructs banned inside marker-armed functions.
pub const HOT_PATH_BANNED: &[&str] = &["Vec::new(", ".to_vec(", ".clone(", "format!", "Box::new("];

/// Pinned per-file count of non-test `unsafe` tokens, relative to the
/// scanned root. A file whose count drifts from its pin — including a
/// first `unsafe` in an unlisted file — fails the lint until the pin
/// is updated in the same change. Pinned files absent from the scanned
/// tree are skipped, so fixture trees can be linted with the same
/// driver.
pub const UNSAFE_INVENTORY: &[(&str, usize)] = &[
    ("coordinator/reactor.rs", 5),
    ("coordinator/scheduler.rs", 2),
    ("runtime/native/forward.rs", 8),
    ("runtime/native/gemm.rs", 7),
    ("runtime/native/quant.rs", 1),
    ("runtime/native/simd.rs", 16),
    ("runtime/weights.rs", 3),
];

/// One reviewed exception to the serving-panic rule.
pub struct PanicAllow {
    /// `/`-separated path suffix the entry applies to.
    pub file: &'static str,
    /// Substring of the raw offending line (matched against the
    /// original source, so string contents count).
    pub needle: &'static str,
    /// Why the panic cannot fire — or is the correct response — on the
    /// serving path.
    pub why: &'static str,
}

/// The serving-panic exceptions. Keep this list short and each `why`
/// honest: an entry is a claim that the panic is unreachable from the
/// request path, reviewed like any other invariant.
pub const PANIC_ALLOWLIST: &[PanicAllow] = &[
    PanicAllow {
        file: "coordinator/scheduler.rs",
        needle: "unsupported serving task",
        why: "task strings are validated at backend load; mux templates are \
              built at startup, not per request",
    },
    PanicAllow {
        file: "runtime/weights.rs",
        needle: ".try_into().unwrap()",
        why: "infallible: the slice is statically four bytes",
    },
    PanicAllow {
        file: "runtime/manifest.rs",
        needle: "unknown task",
        why: "manifest task fields are checked when artifacts load; \
              output_len runs at backend construction, not per request",
    },
    PanicAllow {
        file: "runtime/native/gemm.rs",
        needle: "a pool job panicked",
        why: "deliberate re-raise of a worker panic after the join — the \
              caller must never observe partial output as success",
    },
];

/// Which rule a [`Violation`] came from.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Rule {
    UnsafeSafety,
    UnsafeInventory,
    ServingPanic,
    HotPathAlloc,
    RawLock,
}

impl Rule {
    pub fn name(self) -> &'static str {
        match self {
            Rule::UnsafeSafety => "unsafe-safety",
            Rule::UnsafeInventory => "unsafe-inventory",
            Rule::ServingPanic => "serving-panic",
            Rule::HotPathAlloc => "hot-path-alloc",
            Rule::RawLock => "raw-lock",
        }
    }
}

/// One finding: file, 1-based line, rule, human-readable detail.
#[derive(Debug, Clone)]
pub struct Violation {
    /// Path relative to the scanned root, `/`-separated.
    pub file: String,
    /// 1-based line number.
    pub line: usize,
    pub rule: Rule,
    pub message: String,
}

impl fmt::Display for Violation {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}:{}: [{}] {}", self.file, self.line, self.rule.name(), self.message)
    }
}

/// Outcome of a [`lint_dir`] run.
#[derive(Debug, Default)]
pub struct LintReport {
    pub violations: Vec<Violation>,
    pub files_scanned: usize,
}

/// Lint every `.rs` file under `src_root` (recursively, sorted, so
/// output order is deterministic).
pub fn lint_dir(src_root: &Path) -> io::Result<LintReport> {
    let mut files = Vec::new();
    collect_rs(src_root, &mut files)?;
    files.sort();
    let mut report = LintReport::default();
    for path in &files {
        let rel: String = path
            .strip_prefix(src_root)
            .unwrap_or(path)
            .components()
            .map(|c| c.as_os_str().to_string_lossy())
            .collect::<Vec<_>>()
            .join("/");
        let src = fs::read_to_string(path)?;
        report.violations.extend(lint_source(&rel, &src));
        report.files_scanned += 1;
    }
    Ok(report)
}

fn collect_rs(dir: &Path, out: &mut Vec<PathBuf>) -> io::Result<()> {
    for entry in fs::read_dir(dir)? {
        let path = entry?.path();
        if path.is_dir() {
            collect_rs(&path, out)?;
        } else if path.extension().is_some_and(|e| e == "rs") {
            out.push(path);
        }
    }
    Ok(())
}

/// Lint one file's source. `rel` is the `/`-separated path relative to
/// the source root; it drives the per-directory rule scopes.
pub fn lint_source(rel: &str, src: &str) -> Vec<Violation> {
    let s = scrub(src);
    let mask = test_mask(&s.code);
    let mut out = Vec::new();
    let serving = in_serving_scope(rel);
    let coordinator = rel.starts_with("coordinator/");
    let mut unsafe_count = 0usize;
    for (i, code) in s.code.iter().enumerate() {
        if mask[i] {
            continue;
        }
        let hits = count_word(code, "unsafe");
        if hits > 0 {
            unsafe_count += hits;
            if !safety_justified(&s, i) {
                out.push(Violation {
                    file: rel.to_string(),
                    line: i + 1,
                    rule: Rule::UnsafeSafety,
                    message: "`unsafe` without an attached SAFETY justification".to_string(),
                });
            }
        }
        if serving {
            serving_panic_check(rel, &s, i, &mut out);
        }
        if coordinator {
            for tok in ["Mutex", "Condvar", "RwLock"] {
                if has_word(code, tok) {
                    out.push(Violation {
                        file: rel.to_string(),
                        line: i + 1,
                        rule: Rule::RawLock,
                        message: format!(
                            "raw `{tok}` in coordinator code — use the tracked \
                             wrappers in `util::sync`"
                        ),
                    });
                }
            }
        }
    }
    check_inventory(rel, unsafe_count, &mut out);
    check_hot_paths(rel, &s, &mask, &mut out);
    out
}

/// Serving scope for the panic rule: the request path lives under
/// `coordinator/` and `runtime/`, plus the binary entrypoint. `util/`
/// (scaffolding), `workload/`, `baseline/` and `tokenizer/` (offline
/// tooling) may unwrap.
fn in_serving_scope(rel: &str) -> bool {
    rel.starts_with("coordinator/") || rel.starts_with("runtime/") || rel == "main.rs"
}

fn serving_panic_check(rel: &str, s: &Scrubbed, i: usize, out: &mut Vec<Violation>) {
    let code = &s.code[i];
    let tok = if code.contains(".unwrap()") {
        ".unwrap()"
    } else if code.contains(".expect(") {
        ".expect("
    } else if has_macro(code, "panic!") {
        "panic!"
    } else {
        return;
    };
    let allowed =
        PANIC_ALLOWLIST.iter().any(|a| rel.ends_with(a.file) && s.raw[i].contains(a.needle));
    if !allowed {
        out.push(Violation {
            file: rel.to_string(),
            line: i + 1,
            rule: Rule::ServingPanic,
            message: format!(
                "`{tok}` on a serving path — return a typed error instead \
                 (or add a justified allowlist entry)"
            ),
        });
    }
}

fn check_inventory(rel: &str, count: usize, out: &mut Vec<Violation>) {
    let pinned = UNSAFE_INVENTORY.iter().find(|(f, _)| *f == rel).map_or(0, |&(_, c)| c);
    if count != pinned {
        out.push(Violation {
            file: rel.to_string(),
            line: 1,
            rule: Rule::UnsafeInventory,
            message: format!(
                "non-test `unsafe` count is {count} but the inventory pins \
                 {pinned} — update UNSAFE_INVENTORY in the same change"
            ),
        });
    }
}

fn check_hot_paths(rel: &str, s: &Scrubbed, mask: &[bool], out: &mut Vec<Violation>) {
    for i in 0..s.comments.len() {
        if mask[i] || !s.comments[i].contains(HOT_PATH_MARKER) {
            continue;
        }
        // the armed fn must open within the next few lines (attributes
        // between the marker and the signature are fine)
        let fn_line = (i + 1..s.code.len().min(i + 6)).find(|&j| has_word(&s.code[j], "fn"));
        let Some(fn_line) = fn_line else {
            out.push(Violation {
                file: rel.to_string(),
                line: i + 1,
                rule: Rule::HotPathAlloc,
                message: "dangling hot-path marker: no fn within 5 lines".to_string(),
            });
            continue;
        };
        let end = item_end(&s.code, fn_line);
        for l in fn_line..=end {
            for tok in HOT_PATH_BANNED {
                if banned_hit(&s.code[l], tok) {
                    out.push(Violation {
                        file: rel.to_string(),
                        line: l + 1,
                        rule: Rule::HotPathAlloc,
                        message: format!("`{tok}` inside a hot-path function"),
                    });
                }
            }
        }
    }
}

/// Does `line` contain banned construct `tok`? Needles that start with
/// a letter get a word-boundary check on the left; leading-`.` needles
/// need none.
fn banned_hit(line: &str, tok: &str) -> bool {
    let named = tok.starts_with(|c: char| c.is_alphabetic());
    let mut start = 0;
    while let Some(pos) = line[start..].find(tok) {
        let at = start + pos;
        if !named || line[..at].chars().next_back().is_none_or(|c| !is_word(c)) {
            return true;
        }
        start = at + tok.len();
    }
    false
}

const SAFETY_MARKS: [&str; 2] = ["SAFETY:", "# Safety"];

fn is_safety(comment: &str) -> bool {
    SAFETY_MARKS.iter().any(|m| comment.contains(m))
}

/// Walk up from the line holding `unsafe` through the comment /
/// attribute / continuation lines attached to it, accepting the first
/// safety mark found. One comment may cover a contiguous run of unsafe
/// items (paired `unsafe impl`s), and a mark above a multi-line
/// statement covers an `unsafe` on its continuation lines (a line not
/// ending in `;`, `{` or `}` cannot end a statement, so the walk keeps
/// climbing through it).
fn safety_justified(s: &Scrubbed, i: usize) -> bool {
    if is_safety(&s.comments[i]) {
        return true;
    }
    let mut j = i;
    while j > 0 {
        j -= 1;
        if is_safety(&s.comments[j]) {
            return true;
        }
        let t = s.code[j].trim();
        let pure_comment = t.is_empty() && !s.comments[j].trim().is_empty();
        let attr = t.starts_with("#[") || t.starts_with("#![");
        let continuation = !t.is_empty() && !t.ends_with([';', '{', '}']);
        if !(pure_comment || attr || continuation || has_word(t, "unsafe")) {
            return false;
        }
    }
    false
}

/// Mark every line covered by a `#[cfg(test)]`-attributed item —
/// module, fn, impl, or a brace-less item up to its `;`. Rules skip
/// masked lines: test code may unwrap, panic and use raw locks freely.
fn test_mask(code: &[String]) -> Vec<bool> {
    let mut mask = vec![false; code.len()];
    for start in 0..code.len() {
        if !code[start].contains("#[cfg(test)]") {
            continue;
        }
        let end = item_end(code, start);
        for m in mask.iter_mut().take(end + 1).skip(start) {
            *m = true;
        }
    }
    mask
}

/// Last line of the item starting at `start`: the line closing the
/// brace pair opened first, or the first top-level `;` on a later line
/// for brace-less items. Runs over the code channel, so braces in
/// strings, chars and comments cannot skew the depth.
fn item_end(code: &[String], start: usize) -> usize {
    let mut depth = 0i32;
    let mut opened = false;
    for (l, line) in code.iter().enumerate().skip(start) {
        for ch in line.chars() {
            match ch {
                '{' => {
                    depth += 1;
                    opened = true;
                }
                '}' => {
                    depth -= 1;
                    if opened && depth == 0 {
                        return l;
                    }
                }
                ';' if !opened && l > start => return l,
                _ => {}
            }
        }
    }
    code.len() - 1
}

#[cfg(test)]
mod tests {
    use super::*;

    fn rules(v: &[Violation]) -> Vec<Rule> {
        v.iter().map(|x| x.rule).collect()
    }

    #[test]
    fn unwrap_fires_only_in_serving_scope() {
        let src = "fn f() { x.unwrap(); }\n";
        assert!(rules(&lint_source("coordinator/a.rs", src)).contains(&Rule::ServingPanic));
        assert!(rules(&lint_source("runtime/b.rs", src)).contains(&Rule::ServingPanic));
        assert!(rules(&lint_source("main.rs", src)).contains(&Rule::ServingPanic));
        assert!(lint_source("util/c.rs", src).is_empty());
        // unwrap_or_else and friends never match the exact token
        let ok = "fn f() { x.unwrap_or_else(e); y.unwrap_or(0); }\n";
        assert!(lint_source("coordinator/a.rs", ok).is_empty());
    }

    #[test]
    fn expect_and_panic_fire_too() {
        let src = "fn f() { x.expect(\"boom\"); }\n";
        assert!(rules(&lint_source("runtime/a.rs", src)).contains(&Rule::ServingPanic));
        let src = "fn f() { panic!(\"boom\"); }\n";
        assert!(rules(&lint_source("runtime/a.rs", src)).contains(&Rule::ServingPanic));
    }

    #[test]
    fn allowlist_suppresses_by_file_and_needle() {
        let src = "fn f(b: &[u8]) -> u32 { u32::from_le_bytes(b.try_into().unwrap()) }\n";
        assert!(lint_source("runtime/weights.rs", src).is_empty());
        // same line in another file still fires
        assert!(!lint_source("runtime/other.rs", src).is_empty());
    }

    #[test]
    fn tokens_in_strings_and_comments_never_fire() {
        let src = "fn f() { log(\".unwrap() panic!\"); } // .unwrap() panic!\n";
        assert!(lint_source("coordinator/a.rs", src).is_empty());
    }

    #[test]
    fn cfg_test_items_are_exempt() {
        let src = "#[cfg(test)]\nmod tests {\n    fn f() { x.unwrap(); }\n}\n";
        assert!(lint_source("coordinator/a.rs", src).is_empty());
        // a cfg(test) fn outside a tests module is exempt too
        let src = "#[cfg(test)]\npub fn helper() -> u32 {\n    x.unwrap()\n}\n";
        assert!(lint_source("coordinator/a.rs", src).is_empty());
        // but code after the exempt item is back in scope
        let src = "#[cfg(test)]\nfn h() { x.unwrap(); }\nfn f() { y.unwrap(); }\n";
        let v = lint_source("coordinator/a.rs", src);
        assert_eq!(v.len(), 1, "{v:?}");
        assert_eq!(v[0].line, 3);
    }

    #[test]
    fn unsafe_requires_safety_comment() {
        let bad = "fn f() {\n    unsafe { g() };\n}\n";
        assert!(rules(&lint_source("util/a.rs", bad)).contains(&Rule::UnsafeSafety));
        let good = "fn f() {\n    // SAFETY: g has no preconditions\n    unsafe { g() };\n}\n";
        assert!(!rules(&lint_source("util/a.rs", good)).contains(&Rule::UnsafeSafety));
        // doc-style safety sections satisfy the rule as well
        let doc = "/// # Safety\n/// caller checks alignment\npub unsafe fn g() {}\n";
        assert!(!rules(&lint_source("util/a.rs", doc)).contains(&Rule::UnsafeSafety));
    }

    #[test]
    fn safety_comment_covers_unsafe_groups_and_continuations() {
        let pair = "// SAFETY: both impls hold for the same reason\n\
                    unsafe impl Send for X {}\nunsafe impl Sync for X {}\n";
        assert!(!rules(&lint_source("util/a.rs", pair)).contains(&Rule::UnsafeSafety));
        let cont = "fn f() {\n    // SAFETY: checked above\n    let x =\n        \
                    unsafe { g() };\n}\n";
        assert!(!rules(&lint_source("util/a.rs", cont)).contains(&Rule::UnsafeSafety));
    }

    #[test]
    fn inventory_pins_unsafe_counts() {
        // an unlisted file gains an unsafe block: count 1 vs pin 0
        let src = "fn f() {\n    // SAFETY: fine\n    unsafe { g() };\n}\n";
        let v = lint_source("util/new_file.rs", src);
        assert!(rules(&v).contains(&Rule::UnsafeInventory), "{v:?}");
        // a pinned file with the right count is clean
        let two = "// SAFETY: raw fd, closed once\nunsafe impl Send for X {}\n\
                   unsafe impl Sync for X {}\n";
        let v = lint_source("coordinator/scheduler.rs", two);
        assert!(!rules(&v).contains(&Rule::UnsafeInventory), "{v:?}");
    }

    #[test]
    fn hot_path_marker_bans_allocation() {
        let marker = format!("// {HOT_PATH_MARKER}");
        let bad = format!("{marker}\nfn f() {{\n    let v = Vec::new();\n}}\n");
        let v = lint_source("util/a.rs", &bad);
        assert!(rules(&v).contains(&Rule::HotPathAlloc), "{v:?}");
        assert_eq!(v[0].line, 3);
        for tok in ["x.to_vec()", "x.clone()", "format!(\"x\")", "Box::new(1)"] {
            let bad = format!("{marker}\nfn f() {{\n    let v = {tok};\n}}\n");
            assert!(
                rules(&lint_source("util/a.rs", &bad)).contains(&Rule::HotPathAlloc),
                "{tok} not caught"
            );
        }
        let good = format!("{marker}\nfn f(x: &mut [f32]) {{\n    x[0] = 1.0;\n}}\n");
        assert!(lint_source("util/a.rs", &good).is_empty());
        // an unmarked fn may allocate freely
        assert!(lint_source("util/a.rs", "fn f() { let v = Vec::new(); }\n").is_empty());
        // a marker with no fn is itself an error
        let dangling = format!("{marker}\nconst X: u32 = 1;\n");
        assert!(rules(&lint_source("util/a.rs", &dangling)).contains(&Rule::HotPathAlloc));
    }

    #[test]
    fn raw_locks_banned_in_coordinator_only() {
        let src = "use std::sync::Mutex;\nfn f(m: &Mutex<u32>) {}\n";
        let v = lint_source("coordinator/a.rs", src);
        assert_eq!(rules(&v), [Rule::RawLock, Rule::RawLock]);
        assert!(lint_source("runtime/native/a.rs", src).is_empty());
        for tok in ["Condvar", "RwLock"] {
            let src = format!("fn f(c: &{tok}) {{}}\n");
            assert!(
                rules(&lint_source("coordinator/a.rs", &src)).contains(&Rule::RawLock),
                "{tok} not caught"
            );
        }
        // the tracked wrappers never match the raw tokens
        let ok = "use crate::util::sync::{TrackedCondvar, TrackedMutex};\n\
                  fn f(m: &TrackedMutex<u32>, c: &TrackedCondvar) {}\n";
        assert!(lint_source("coordinator/a.rs", ok).is_empty());
    }

    #[test]
    fn allowlist_entries_carry_justifications() {
        for a in PANIC_ALLOWLIST {
            assert!(!a.why.is_empty(), "{} entry missing a why", a.file);
        }
        for (file, count) in UNSAFE_INVENTORY {
            assert!(*count > 0, "{file} pinned at zero — drop the entry instead");
        }
    }

    #[test]
    fn violation_display_is_grep_friendly() {
        let v = Violation {
            file: "coordinator/a.rs".to_string(),
            line: 7,
            rule: Rule::ServingPanic,
            message: "boom".to_string(),
        };
        assert_eq!(v.to_string(), "coordinator/a.rs:7: [serving-panic] boom");
    }
}
