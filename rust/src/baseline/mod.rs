//! Baseline serving path (paper's B1): the same coordinator machinery
//! pointed at an N=1 artifact — one request per model row, batching only
//! along the batch dimension. Every figure's "1x" reference point.
//!
//! Kept as its own module so benches compare `baseline::start` vs
//! `MuxCoordinator::start` symmetrically and so the non-multiplexed path
//! stays honest (same queues, same scheduler, same tokenizer — the only
//! difference is N).

use anyhow::{anyhow, Result};

use crate::coordinator::{CoordinatorConfig, MuxCoordinator};
use crate::runtime::{ArtifactManifest, ModelRuntime};

/// Start a vanilla (N=1) serving engine for `profile` at batch size
/// `batch` from the manifest's timing artifacts.
pub fn start(
    rt: &ModelRuntime,
    manifest: &ArtifactManifest,
    profile: &str,
    batch: usize,
    cfg: CoordinatorConfig,
) -> Result<MuxCoordinator> {
    let meta = manifest
        .timing(profile, 1, batch)
        .ok_or_else(|| anyhow!("no N=1 artifact for profile {profile} batch {batch}"))?;
    let model = rt.load(meta)?;
    MuxCoordinator::start(model, cfg)
}
