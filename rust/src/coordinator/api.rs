//! The unified submission API: typed requests, typed errors, and the
//! [`Submit`] trait every engine front end codes against.
//!
//! `Submit` is implemented by both [`super::MuxCoordinator`] (one model)
//! and [`super::MuxRouter`] (adaptive-N over several models), so the TCP
//! server, the workload drivers, the benches, and the examples are all
//! generic over the backend — the paper's A3-style adaptive-N knob is
//! servable through the exact same plumbing as a fixed-N lane.

use std::time::Duration;

use crate::tokenizer::Tokenizer;
use crate::util::metrics::{CounterSnapshot, LatencySummary};
use crate::util::threadpool::Channel;

use super::request::{EngineError, RequestHandle, Response};

/// What the caller wants back from the model.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum TaskKind {
    /// sentence-level prediction (model task `cls`)
    Classify,
    /// per-position tag prediction (model task `token`)
    TagTokens,
}

impl TaskKind {
    /// Map an artifact's task string to the kind it serves.
    pub fn from_model_task(task: &str) -> Option<TaskKind> {
        match task {
            "cls" => Some(TaskKind::Classify),
            "token" => Some(TaskKind::TagTokens),
            _ => None,
        }
    }

    pub fn as_str(&self) -> &'static str {
        match self {
            TaskKind::Classify => "classify",
            TaskKind::TagTokens => "tag",
        }
    }
}

/// SLO class of a request. Admission keeps one FIFO per class inside
/// every sequence-length bucket and batchers drain the highest class
/// first, so under overload high-priority traffic keeps its latency SLO
/// while lower classes queue behind it (and are shed first by
/// deadline-aware admission). Strict priority is deliberate: bulk
/// starvation under sustained high-class saturation is the documented
/// contract, not a bug.
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord, Hash, Default)]
pub enum Priority {
    /// latency-sensitive: drained first, shed last
    High,
    /// the default class
    #[default]
    Normal,
    /// throughput traffic: drained last, shed first under overload
    Bulk,
}

/// Number of priority classes (indexes `0..N_CLASSES` via
/// [`Priority::index`], high first).
pub const N_PRIORITY_CLASSES: usize = 3;

impl Priority {
    /// All classes, highest first — iteration order for drains/reports.
    pub const ALL: [Priority; N_PRIORITY_CLASSES] =
        [Priority::High, Priority::Normal, Priority::Bulk];

    /// Dense index, 0 = highest class.
    #[inline]
    pub fn index(self) -> usize {
        self as usize
    }

    /// Wire name (v2 `priority` field).
    pub fn as_str(self) -> &'static str {
        match self {
            Priority::High => "high",
            Priority::Normal => "normal",
            Priority::Bulk => "bulk",
        }
    }

    /// Parse a wire name; `None` for anything else (the server answers
    /// `bad_request` rather than silently defaulting a typo).
    pub fn from_str(s: &str) -> Option<Priority> {
        match s {
            "high" => Some(Priority::High),
            "normal" => Some(Priority::Normal),
            "bulk" => Some(Priority::Bulk),
            _ => None,
        }
    }
}

/// Request payload: already-framed token ids, or raw token text.
#[derive(Debug, Clone)]
pub enum Payload {
    /// One framed content row (`[CLS] .. [SEP] ..`), `1..=seq_len` ids.
    /// Padding is **not** required (nor useful): the engine assigns the
    /// row to its sequence-length bucket and pads to the bucket at
    /// batch assembly. Max-length pre-padded rows still work.
    Framed(Vec<i32>),
    /// Token text; sentence pairs are ` [SEP] `-joined. Tokenized and
    /// framed (unpadded) by the engine.
    Text(String),
}

/// A typed inference request (replaces the old
/// `submit_framed`/`submit_text`/`try_submit_framed` trio).
#[derive(Debug, Clone)]
pub struct InferenceRequest {
    pub task: TaskKind,
    pub payload: Payload,
    /// Relative deadline. A deadline that is already zero at submit time
    /// is rejected with [`SubmitError::Expired`]; one that provably
    /// cannot be met given queue depth and drain rate is rejected with
    /// [`SubmitError::Overloaded`]; requests that expire while queued
    /// are dropped at batch-assembly time with
    /// [`EngineError::DeadlineExceeded`], and
    /// [`RequestHandle::wait_deadline`] stops waiting once it passes.
    pub deadline: Option<Duration>,
    /// SLO class (default [`Priority::Normal`]).
    pub priority: Priority,
}

impl InferenceRequest {
    pub fn classify_framed(ids: Vec<i32>) -> Self {
        InferenceRequest {
            task: TaskKind::Classify,
            payload: Payload::Framed(ids),
            deadline: None,
            priority: Priority::Normal,
        }
    }

    pub fn classify_text(text: impl Into<String>) -> Self {
        InferenceRequest {
            task: TaskKind::Classify,
            payload: Payload::Text(text.into()),
            deadline: None,
            priority: Priority::Normal,
        }
    }

    pub fn tag_framed(ids: Vec<i32>) -> Self {
        InferenceRequest {
            task: TaskKind::TagTokens,
            payload: Payload::Framed(ids),
            deadline: None,
            priority: Priority::Normal,
        }
    }

    pub fn tag_text(text: impl Into<String>) -> Self {
        InferenceRequest {
            task: TaskKind::TagTokens,
            payload: Payload::Text(text.into()),
            deadline: None,
            priority: Priority::Normal,
        }
    }

    pub fn with_deadline(mut self, deadline: Duration) -> Self {
        self.deadline = Some(deadline);
        self
    }

    pub fn with_priority(mut self, priority: Priority) -> Self {
        self.priority = priority;
        self
    }
}

/// Why a submission was not accepted. Unlike the old
/// `try_submit_framed` (which conflated queue-full and bad-frame in one
/// `Err(Vec<i32>)`), every cause is distinct and machine-readable.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum SubmitError {
    /// admission queue is full (non-blocking submit only)
    QueueFull,
    /// framed payload is empty (a row needs at least its `[CLS]`)
    BadFrame { expected: usize, got: usize },
    /// content exceeds the model's maximum sequence length — returned
    /// instead of silently truncating the tail of the sentence
    TooLong { got: usize, max: usize },
    /// text payload failed to tokenize
    Tokenize(String),
    /// request task kind does not match what the model serves
    WrongTask { requested: TaskKind, served: TaskKind },
    /// the request's deadline had already expired at submit time — shed
    /// at admission instead of being silently dropped at batch assembly
    Expired,
    /// the request's deadline provably cannot be met given the queued
    /// work ahead of its class and the engine's measured drain rate —
    /// shed fast at admission instead of expiring in the queue
    Overloaded,
    /// no backend shard can take the request: every shard's breaker is
    /// open (or half-open, still probing). Returned *fast* by the shard
    /// router instead of hanging on dead connections — the caller can
    /// retry with backoff or fail over to another front
    Unavailable,
    /// the engine has stopped accepting requests
    Shutdown,
}

impl SubmitError {
    /// Stable machine-readable code (used by wire protocol v2).
    pub fn code(&self) -> &'static str {
        match self {
            SubmitError::QueueFull => "queue_full",
            SubmitError::BadFrame { .. } => "bad_frame",
            SubmitError::TooLong { .. } => "too_long",
            SubmitError::Tokenize(_) => "tokenize",
            SubmitError::WrongTask { .. } => "wrong_task",
            SubmitError::Expired => "expired",
            SubmitError::Overloaded => "overloaded",
            SubmitError::Unavailable => "unavailable",
            SubmitError::Shutdown => "shutdown",
        }
    }
}

impl std::fmt::Display for SubmitError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            SubmitError::QueueFull => write!(f, "admission queue is full"),
            SubmitError::BadFrame { expected, got } => {
                write!(f, "content must be 1..={expected} framed ids (got {got})")
            }
            SubmitError::TooLong { got, max } => {
                write!(f, "content is {got} tokens, model max is {max}")
            }
            SubmitError::Tokenize(msg) => write!(f, "tokenize: {msg}"),
            SubmitError::WrongTask { requested, served } => write!(
                f,
                "request kind '{}' but the model serves '{}'",
                requested.as_str(),
                served.as_str()
            ),
            SubmitError::Expired => write!(f, "deadline already expired at submit"),
            SubmitError::Overloaded => {
                write!(f, "deadline cannot be met at current load (shed at admission)")
            }
            SubmitError::Unavailable => {
                write!(f, "no shard available (all breakers open); retry with backoff")
            }
            SubmitError::Shutdown => write!(f, "engine is shut down"),
        }
    }
}

impl std::error::Error for SubmitError {}

/// Per-bucket execution counts of one lane: how many waves ran at this
/// sequence length and how many requests they carried. Padding waste is
/// the gap between `entries * seq_len` and the actual token counts —
/// observable before/after bucketing via the `tokens_padded` counter.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct BucketStatus {
    pub seq_len: usize,
    pub waves: u64,
    pub entries: u64,
}

/// Health and progress of one serving lane, as reported by
/// [`Submit::lane_status`]. A router reports one entry per lane; a
/// standalone coordinator reports a single entry for itself.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct LaneStatus {
    pub n_mux: usize,
    /// false once the lane's worker failed — a dead lane never takes
    /// work again, and an engine is only `Shutdown` when no lane is alive
    pub alive: bool,
    /// exec batches this lane formed (waves pulled from its queue source)
    pub pulls: u64,
    /// requests this lane handed back to the shared queue when it died
    pub requeued: u64,
    /// requests this lane answered with a response
    pub completed: u64,
    /// per-bucket waves/entries, aligned with [`Submit::buckets`]
    pub buckets: Vec<BucketStatus>,
}

/// Breaker state of one backend shard, as seen by the shard router's
/// health machinery (see `coordinator/shards.rs`).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ShardState {
    /// healthy: taking traffic, probed on the regular interval
    Closed,
    /// failed: no traffic; the next half-open probe is scheduled with
    /// seeded-jitter exponential backoff
    Open,
    /// probing: one reconnect+STATS attempt in flight; success closes
    /// the breaker, failure re-opens it with a doubled delay
    HalfOpen,
}

impl ShardState {
    /// Wire name (v2 STATS `shards[].state`).
    pub fn as_str(self) -> &'static str {
        match self {
            ShardState::Closed => "closed",
            ShardState::Open => "open",
            ShardState::HalfOpen => "half_open",
        }
    }
}

/// Health and progress of one backend shard, as reported by
/// [`Submit::shard_status`]. Engines that are not shard routers report
/// an empty list.
#[derive(Debug, Clone, PartialEq)]
pub struct ShardStatus {
    /// backend address (`host:port`)
    pub addr: String,
    pub state: ShardState,
    /// health probes sent to this shard
    pub probes: u64,
    /// probes that timed out or failed (each one trips the breaker)
    pub probe_failures: u64,
    /// in-flight requests resubmitted *off* this shard when it died
    pub failovers: u64,
    /// requests currently awaiting a reply from this shard
    pub in_flight: usize,
    /// requests this shard answered
    pub completed: u64,
    /// EWMA of probe/request round-trip time (us); 0 until first sample
    pub ewma_rtt_us: f64,
}

/// Per-priority-class serving status, as reported by
/// [`Submit::class_status`] — one entry per [`Priority`], highest
/// first. Queue-wait percentiles are the SLO-facing number: how long
/// this class's requests sat in admission before batch formation.
#[derive(Debug, Clone, PartialEq)]
pub struct ClassStatus {
    pub priority: Priority,
    /// requests of this class currently queued (all buckets)
    pub depth: usize,
    /// requests of this class answered with a response
    pub completed: u64,
    /// shed at admission: deadline already expired at submit
    pub shed_expired: u64,
    /// shed at admission: deadline provably unmeetable at current load
    pub shed_overloaded: u64,
    /// submit -> batch-formed wait for this class
    pub queue_wait: LatencySummary,
}

/// A tagged completion: the request tag plus its outcome. Delivered to a
/// [`CompletionQueue`] by [`Submit::submit_tagged`].
pub type CompletionItem = (u64, Result<Response, EngineError>);

/// Queue that receives tagged completions as they happen — the server's
/// pipelined connections drain one of these instead of blocking a thread
/// per in-flight request.
pub type CompletionQueue = Channel<CompletionItem>;

/// A multiplexing inference engine that accepts requests.
///
/// Implemented by [`super::MuxCoordinator`] and [`super::MuxRouter`];
/// object-safe so servers can hold `Arc<dyn Submit>`.
pub trait Submit: Send + Sync {
    /// Submit, blocking while the admission queue is full
    /// (backpressure). Never returns [`SubmitError::QueueFull`].
    fn submit(&self, req: InferenceRequest) -> Result<RequestHandle, SubmitError>;

    /// Non-blocking submit; [`SubmitError::QueueFull`] when the
    /// admission queue is full.
    fn try_submit(&self, req: InferenceRequest) -> Result<RequestHandle, SubmitError>;

    /// Non-blocking submit whose completion is delivered to `out` as
    /// `(tag, result)` instead of through a handle. Used for pipelined
    /// serving: one queue fans in completions for a whole connection.
    /// If `out` is full when the request completes, the completion is
    /// dropped.
    fn submit_tagged(
        &self,
        req: InferenceRequest,
        tag: u64,
        out: &CompletionQueue,
    ) -> Result<(), SubmitError>;

    /// The task kind the backing model(s) natively serve.
    fn native_task(&self) -> TaskKind;

    fn tokenizer(&self) -> &Tokenizer;

    /// The model's maximum sequence length (the terminal bucket).
    fn seq_len(&self) -> usize;

    /// Output classes of the served task head (cls: per sentence,
    /// token: per position). Surfaced in the v2 STATS `model` block so
    /// a shard router can reconstruct typed [`Response`]s client-side.
    fn n_classes(&self) -> usize;

    /// The sequence-length buckets this engine executes, ascending; the
    /// last is always [`Submit::seq_len`]. A pad-to-max engine reports
    /// the single terminal bucket.
    fn buckets(&self) -> Vec<usize> {
        vec![self.seq_len()]
    }

    /// Requests admitted but not yet handed to a worker.
    fn queue_depth(&self) -> usize;

    /// Aggregated serving counters (summed over lanes for a router).
    fn counters(&self) -> CounterSnapshot;

    /// End-to-end latency summary (merged over lanes for a router).
    fn latency(&self) -> LatencySummary;

    /// Queue-wait (submit -> batch formed) summary: the batching delay
    /// component of latency, separate from execution time (merged over
    /// lanes for a router).
    fn queue_wait(&self) -> LatencySummary;

    /// Per-lane health and progress (one entry per lane for a router, a
    /// single self-entry for a coordinator). Default: no lane detail.
    fn lane_status(&self) -> Vec<LaneStatus> {
        Vec::new()
    }

    /// Per-priority-class depth/progress/shedding (one entry per
    /// [`Priority`], highest first). Default: no class detail.
    fn class_status(&self) -> Vec<ClassStatus> {
        Vec::new()
    }

    /// Per-shard breaker/health detail (one entry per backend shard for
    /// a shard router, in configured order). Default: not sharded.
    fn shard_status(&self) -> Vec<ShardStatus> {
        Vec::new()
    }

    /// One human-readable line per serving backend (model name, mux
    /// width, and — for the native backend — the selected GEMM kernel
    /// and weight precision). Surfaced in `serve` startup output and
    /// the v2 STATS payload. Default: no backend detail.
    fn backend_info(&self) -> Vec<String> {
        Vec::new()
    }

    /// Per-stage cumulative execution nanoseconds per backend, aligned
    /// index-for-index with [`Submit::backend_info`]. Backends without
    /// stage instrumentation contribute an empty list. Surfaced in the
    /// v2 STATS `backends` block. Default: no stage detail.
    fn backend_stage_ns(&self) -> Vec<Vec<(&'static str, u64)>> {
        Vec::new()
    }

    /// Convenience: submit one framed row for whatever task the model
    /// serves. The common path for drivers and benches.
    fn submit_framed(&self, ids: Vec<i32>) -> Result<RequestHandle, SubmitError> {
        self.submit(InferenceRequest {
            task: self.native_task(),
            payload: Payload::Framed(ids),
            deadline: None,
            priority: Priority::Normal,
        })
    }

    /// Convenience: non-blocking framed submit.
    fn try_submit_framed(&self, ids: Vec<i32>) -> Result<RequestHandle, SubmitError> {
        self.try_submit(InferenceRequest {
            task: self.native_task(),
            payload: Payload::Framed(ids),
            deadline: None,
            priority: Priority::Normal,
        })
    }

    /// Convenience: submit ` [SEP] `-joined text parts.
    fn submit_text(&self, parts: &[&str]) -> Result<RequestHandle, SubmitError> {
        self.submit(InferenceRequest {
            task: self.native_task(),
            payload: Payload::Text(parts.join(" [SEP] ")),
            deadline: None,
            priority: Priority::Normal,
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn task_kind_maps_model_tasks() {
        assert_eq!(TaskKind::from_model_task("cls"), Some(TaskKind::Classify));
        assert_eq!(TaskKind::from_model_task("token"), Some(TaskKind::TagTokens));
        assert_eq!(TaskKind::from_model_task("retrieval"), None);
    }

    #[test]
    fn submit_error_codes_are_distinct() {
        let errs = [
            SubmitError::QueueFull,
            SubmitError::BadFrame { expected: 16, got: 0 },
            SubmitError::TooLong { got: 40, max: 16 },
            SubmitError::Tokenize("x".into()),
            SubmitError::WrongTask {
                requested: TaskKind::TagTokens,
                served: TaskKind::Classify,
            },
            SubmitError::Expired,
            SubmitError::Overloaded,
            SubmitError::Unavailable,
            SubmitError::Shutdown,
        ];
        let codes: std::collections::HashSet<_> = errs.iter().map(|e| e.code()).collect();
        assert_eq!(codes.len(), errs.len());
        for e in &errs {
            assert!(!format!("{e}").is_empty());
        }
    }

    #[test]
    fn request_builders() {
        let r = InferenceRequest::classify_text("t1 t2")
            .with_deadline(Duration::from_millis(5));
        assert_eq!(r.task, TaskKind::Classify);
        assert_eq!(r.deadline, Some(Duration::from_millis(5)));
        assert_eq!(r.priority, Priority::Normal);
        let r = InferenceRequest::classify_text("t").with_priority(Priority::High);
        assert_eq!(r.priority, Priority::High);
        match InferenceRequest::tag_framed(vec![1, 2]).payload {
            Payload::Framed(ids) => assert_eq!(ids, vec![1, 2]),
            _ => panic!("expected framed"),
        }
    }

    #[test]
    fn shard_state_wire_names_are_distinct() {
        let states = [ShardState::Closed, ShardState::Open, ShardState::HalfOpen];
        let names: std::collections::HashSet<_> = states.iter().map(|s| s.as_str()).collect();
        assert_eq!(names.len(), states.len());
    }

    #[test]
    fn priority_wire_names_round_trip() {
        for (i, p) in Priority::ALL.into_iter().enumerate() {
            assert_eq!(p.index(), i, "ALL is ordered highest-first by index");
            assert_eq!(Priority::from_str(p.as_str()), Some(p));
        }
        assert_eq!(Priority::from_str("urgent"), None);
        assert_eq!(Priority::default(), Priority::Normal);
        assert!(Priority::High < Priority::Bulk, "ordering follows drain order");
    }
}
