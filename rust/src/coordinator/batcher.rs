//! The mux batcher — the serving realization of the paper's contribution.
//!
//! Incoming requests are grouped into *multiplex groups* of `n_mux` slots
//! and further into a model batch of `batch` groups, i.e. one model
//! execution serves up to `batch * n_mux` requests. Group formation is
//! deadline-driven: the batch ships when full OR when the oldest queued
//! request has waited `max_wait` — the standard dynamic-batching
//! throughput/latency dial, except each "row" here is a *mixed
//! representation of N requests*, which is what multiplies throughput
//! (paper Fig 4c) instead of memory (Fig 12).
//!
//! Shape discipline: admission is a [`BucketQueues`] — one FIFO per
//! sequence-length bucket — and every formed wave drains a single
//! bucket, so an [`ExecBatch`] is **shape-homogeneous** by construction
//! (the scheduler stamps one bucket template per wave and the backend
//! executes at that runtime length). Batchers pull the *deepest*
//! non-empty bucket first, with a round-robin probe every
//! [`ANTI_STARVE_PERIOD`]-th wave so a quiet bucket is never starved by
//! a saturated sibling; when everything is empty they park on a
//! rotating bucket's condvar with a bounded tick (backing off while
//! idle), so a single-bucket engine parks exactly like the old
//! one-channel design while a multi-bucket engine notices any arrival
//! within one park tick.
//!
//! Invariants (property-tested in tests/):
//!   * no request is dropped, duplicated, or reordered within its bucket
//!   * a batch never carries more than `batch * n_mux` requests, and
//!     never mixes buckets
//!   * no request waits longer than `max_wait` before its batch ships
//!     once its bucket has been picked, and a non-empty bucket is
//!     picked within [`ANTI_STARVE_PERIOD`] waves (modulo executor
//!     time)

use std::sync::atomic::{AtomicU64, Ordering};
use std::time::{Duration, Instant};

use super::buckets::BucketQueues;
use super::dispatch::LaneControl;
use super::request::{EngineError, Request};
use crate::util::metrics::Counters;
use crate::util::threadpool::TrySendError;

/// One model execution's worth of requests (up to batch * n_mux), all
/// from one sequence-length bucket.
pub struct ExecBatch {
    pub seq: u64,
    /// index into the engine's bucket registry — selects the worker's
    /// template and scratch for this wave
    pub bucket: usize,
    pub entries: Vec<Request>,
    pub formed_at: Instant,
}

#[derive(Debug, Clone)]
pub struct BatcherConfig {
    pub n_mux: usize,
    pub batch: usize,
    pub max_wait: Duration,
}

impl BatcherConfig {
    pub fn capacity(&self) -> usize {
        self.n_mux * self.batch
    }
}

/// Every this-many formed waves, the bucket choice is a round-robin
/// probe instead of deepest-first — the anti-starvation valve: under
/// sustained saturation of one bucket, a lone request in a quiet
/// bucket is still served within a few wave times instead of losing
/// the deepest() race forever.
const ANTI_STARVE_PERIOD: u64 = 4;

/// Pick the bucket to serve next, parking when everything is empty.
///
/// `round` is the number of waves formed so far: most rounds pick the
/// deepest non-empty bucket, every [`ANTI_STARVE_PERIOD`]-th round
/// probes the buckets round-robin (see the constant).
///
/// Returns the chosen bucket (the park may already have pulled a first
/// wave into `entries`), or `None` when the queues are closed and
/// drained (shutdown) or the park tick expired empty (caller re-loops
/// to re-check health/gates, backing its tick off). The park is on a
/// rotating bucket's condvar so any single arrival wakes a sleeping
/// batcher within one tick — and immediately in the single-bucket
/// case, where the rotation always parks on the only (and therefore
/// correct) queue, with no deadline at all.
fn pick_bucket(
    input: &BucketQueues,
    entries: &mut Vec<Request>,
    capacity: usize,
    park_seq: &mut usize,
    tick: Duration,
    round: u64,
) -> Option<usize> {
    let choice = if round % ANTI_STARVE_PERIOD == ANTI_STARVE_PERIOD - 1 {
        input.nonempty_from((round / ANTI_STARVE_PERIOD) as usize % input.count())
    } else {
        input.deepest()
    };
    if let Some(b) = choice {
        return Some(b);
    }
    if input.is_closed() {
        return None;
    }
    let b = *park_seq % input.count();
    *park_seq += 1;
    // single bucket: an unbounded park is safe (close wakes the condvar)
    // and costs zero idle CPU, exactly the pre-bucket batcher behavior
    let deadline = if input.count() == 1 { None } else { Some(Instant::now() + tick) };
    if input.recv_wave(b, entries, capacity, deadline) > 0 {
        Some(b)
    } else {
        None
    }
}

/// Pull requests from `input`, form deadline-bounded shape-homogeneous
/// ExecBatches, push to `output`. Runs until `input` is closed and
/// drained; then closes `output`. Returns the number of batches formed.
///
/// Intake is wave-based: each drain grabs the chosen bucket's whole
/// backlog (capped at batch capacity) with one lock acquisition, so
/// under load a full batch costs O(1) mutex round-trips instead of one
/// per request. FIFO order per bucket, the no-loss invariant, and the
/// `max_wait` deadline are unchanged. When `counters` is given, drains
/// are tallied into `intake_waves` (requests-per-wave is the
/// amortization factor benches watch).
pub fn run_batcher(
    cfg: &BatcherConfig,
    input: &BucketQueues,
    output: &crate::util::threadpool::Channel<ExecBatch>,
    counters: Option<&Counters>,
) -> u64 {
    let capacity = cfg.capacity();
    let poll = Duration::from_millis(1);
    let max_idle = poll * 20;
    let mut idle = poll;
    let mut park_seq = 0usize;
    let mut seq = 0u64;
    let mut entries: Vec<Request> = Vec::with_capacity(capacity);
    loop {
        let bucket = match pick_bucket(input, &mut entries, capacity, &mut park_seq, idle, seq) {
            Some(b) => {
                idle = poll;
                b
            }
            None => {
                if input.is_closed() && input.is_empty() {
                    break; // closed + drained
                }
                // empty park tick: back off so an idle multi-bucket
                // batcher costs ~no CPU, then re-check
                idle = (idle * 2).min(max_idle);
                continue;
            }
        };
        // first wave of this batch (unless the park already pulled one)
        if entries.is_empty()
            && input.recv_wave(bucket, &mut entries, capacity, Some(Instant::now() + poll)) == 0
        {
            continue; // raced with close/another consumer
        }
        let mut waves = 1u64;
        let deadline = Instant::now() + cfg.max_wait;
        while entries.len() < capacity {
            if input.recv_wave(bucket, &mut entries, capacity - entries.len(), Some(deadline)) == 0
            {
                break; // deadline passed, or closed + drained
            }
            waves += 1;
        }
        seq += 1;
        if let Some(c) = counters {
            c.intake_waves.fetch_add(waves, Ordering::Relaxed);
            c.batches_formed.fetch_add(1, Ordering::Relaxed);
        }
        let batch = ExecBatch {
            seq,
            bucket,
            entries: std::mem::replace(&mut entries, Vec::with_capacity(capacity)),
            formed_at: Instant::now(),
        };
        if output.send(batch).is_err() {
            break;
        }
    }
    output.close();
    seq
}

/// Pull-gated batcher over a **shared** admission queue set (the
/// router's work-stealing dispatch). Unlike [`run_batcher`], the bucket
/// queues are not owned by this lane: every lane of a router pulls
/// waves from the same [`BucketQueues`], each sized to its own
/// `batch * n_mux` capacity, and the `gate` closure (the router's
/// [`AdaptiveN`](super::AdaptiveN) pull-gate) decides per wakeup
/// whether the current backlog/rate justifies this lane's N. Each pull
/// drains the deepest non-empty bucket, so stolen waves stay
/// shape-homogeneous. A closed shared queue bypasses the gate (drain
/// mode), so the admitted backlog always completes on shutdown.
///
/// Lane health: when `lane.dead` is set (this lane's worker failed) the
/// batcher stops pulling immediately. A wave it already holds when the
/// exec channel closes under it is handed back to the shared queues via
/// [`requeue_entries`] — re-queued (by bucket) for a sibling lane, or
/// failed loudly; never silently dropped. Returns the number of batches
/// formed and closes `output` on exit.
///
/// `poll` is the *initial* tick: while a lane finds nothing to do
/// (gated off, or gate open but the queues stay empty), consecutive
/// idle ticks back off exponentially up to `20 * poll`, so an idle
/// router costs almost no CPU; the backoff resets the moment a wave is
/// pulled.
pub fn run_pull_batcher(
    cfg: &BatcherConfig,
    shared: &BucketQueues,
    output: &crate::util::threadpool::Channel<ExecBatch>,
    lane: &LaneControl,
    gate: &dyn Fn() -> bool,
    poll: Duration,
    counters: Option<&Counters>,
) -> u64 {
    let capacity = cfg.capacity();
    let max_idle = poll * 20;
    let mut idle = poll;
    let mut park_seq = 0usize;
    let mut seq = 0u64;
    // reused across poll ticks; a replacement is only allocated when a
    // formed wave is actually handed off, so idle ticks allocate nothing
    let mut entries: Vec<Request> = Vec::with_capacity(capacity);
    'pull: loop {
        if lane.dead.load(Ordering::Acquire) {
            break;
        }
        let draining = shared.is_closed();
        if !draining && !gate() {
            // not this lane's turn: sleep one (backed-off) tick, then
            // re-check the gate (backlog may have grown) and health
            std::thread::sleep(idle);
            idle = (idle * 2).min(max_idle);
            continue;
        }
        // pick the deepest bucket (with the round-robin anti-starvation
        // probe, like run_batcher); when all are empty, park bounded on
        // a rotating bucket so arrivals (and close) wake us promptly.
        // Multi-bucket parks are capped well below the backed-off idle
        // tick: an arrival in a bucket we are NOT parked on cannot wake
        // the condvar, so the cap — not the backoff — bounds its wait.
        let park_cap = if shared.count() == 1 { idle } else { idle.min(Duration::from_millis(2)) };
        let choice = if seq % ANTI_STARVE_PERIOD == ANTI_STARVE_PERIOD - 1 {
            shared.nonempty_from((seq / ANTI_STARVE_PERIOD) as usize % shared.count())
        } else {
            shared.deepest()
        };
        let bucket = match choice {
            Some(b) => b,
            None => {
                if draining {
                    break; // closed + drained: shutdown complete
                }
                let b = park_seq % shared.count();
                park_seq += 1;
                if shared.recv_wave(b, &mut entries, capacity, Some(Instant::now() + park_cap))
                    == 0
                {
                    idle = (idle * 2).min(max_idle);
                    continue;
                }
                b
            }
        };
        if entries.is_empty()
            && shared.recv_wave(bucket, &mut entries, capacity, Some(Instant::now() + poll)) == 0
        {
            if draining && shared.is_empty() {
                break;
            }
            idle = (idle * 2).min(max_idle);
            continue;
        }
        idle = poll;
        let mut waves = 1u64;
        let deadline = Instant::now() + cfg.max_wait;
        while entries.len() < capacity {
            if shared.recv_wave(bucket, &mut entries, capacity - entries.len(), Some(deadline))
                == 0
            {
                break; // deadline passed, or closed + drained
            }
            waves += 1;
        }
        seq += 1;
        if let Some(c) = counters {
            c.intake_waves.fetch_add(waves, Ordering::Relaxed);
            c.batches_formed.fetch_add(1, Ordering::Relaxed);
        }
        let mut batch = ExecBatch {
            seq,
            bucket,
            entries: std::mem::replace(&mut entries, Vec::with_capacity(capacity)),
            formed_at: Instant::now(),
        };
        // hand off to this lane's workers. try_send (not send) so a wave
        // is never lost to a closed channel: on worker death the batch
        // comes back and is returned to the shared queues.
        loop {
            match output.try_send(batch) {
                Ok(()) => continue 'pull,
                Err(TrySendError::Closed(b)) => {
                    requeue_entries(shared, b.entries, &lane.requeued);
                    break 'pull;
                }
                Err(TrySendError::Full(b)) => {
                    if lane.dead.load(Ordering::Acquire) {
                        requeue_entries(shared, b.entries, &lane.requeued);
                        break 'pull;
                    }
                    batch = b;
                    std::thread::sleep(poll);
                }
            }
        }
    }
    output.close();
    seq
}

/// Return pulled-but-unexecuted requests to the shared queues (lane-death
/// path), each to its own bucket, preserving original submit timestamps.
/// Requests that cannot go back are failed **loudly**: `WorkerFailed`
/// when the bucket queue is full, `Shutdown` (via the completion drop
/// guard) when it is closed — never silently lost.
pub(crate) fn requeue_entries(
    shared: &BucketQueues,
    entries: Vec<Request>,
    requeued: &AtomicU64,
) {
    for req in entries {
        match shared.try_send(req) {
            Ok(()) => {
                requeued.fetch_add(1, Ordering::Relaxed);
            }
            Err(TrySendError::Full(req)) => {
                req.fulfill(Err(EngineError::WorkerFailed(
                    "lane died and the shared queue is full; request could not be re-queued"
                        .to_string(),
                )));
            }
            Err(TrySendError::Closed(req)) => {
                // router is shutting down (or every lane is dead): the
                // drop guard answers Shutdown
                drop(req);
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::request::Completion;
    use crate::util::threadpool::{Channel, OnceCellSync};

    fn req(id: u64) -> Request {
        req_in(id, 0)
    }

    fn req_in(id: u64, bucket: usize) -> Request {
        Request {
            id,
            content: vec![1, 0, 0, 0],
            bucket,
            submitted: Instant::now(),
            deadline: None,
            priority: crate::coordinator::Priority::Normal,
            done: Completion::cell(OnceCellSync::new()),
        }
    }

    fn queues(n_buckets: usize, cap: usize) -> BucketQueues {
        BucketQueues::new(n_buckets, cap)
    }

    fn cfg(n_mux: usize, batch: usize, wait_ms: u64) -> BatcherConfig {
        BatcherConfig { n_mux, batch, max_wait: Duration::from_millis(wait_ms) }
    }

    #[test]
    fn ships_full_batch_immediately() {
        let input = queues(1, 64);
        let output = Channel::bounded(64);
        for i in 0..8 {
            input.send(req(i)).unwrap();
        }
        input.close();
        let counters = Counters::default();
        let n = run_batcher(&cfg(4, 2, 1_000), &input, &output, Some(&counters));
        assert_eq!(n, 1);
        // the whole preloaded backlog is one drain: one lock round-trip
        assert_eq!(counters.intake_waves.load(std::sync::atomic::Ordering::Relaxed), 1);
        let b = output.recv().unwrap();
        assert_eq!(b.entries.len(), 8);
        assert_eq!(b.bucket, 0);
        let ids: Vec<u64> = b.entries.iter().map(|r| r.id).collect();
        assert_eq!(ids, (0..8).collect::<Vec<_>>(), "arrival order preserved");
    }

    #[test]
    fn ships_partial_batch_at_deadline() {
        let input = queues(1, 64);
        let output: Channel<ExecBatch> = Channel::bounded(64);
        input.send(req(0)).unwrap();
        input.send(req(1)).unwrap();
        let i2 = input.clone();
        let o2 = output.clone();
        let t0 = Instant::now();
        let h = std::thread::spawn(move || run_batcher(&cfg(4, 2, 30), &i2, &o2, None));
        // consumer observes the partial batch at the 30ms deadline, long
        // before the input channel closes at ~120ms
        let b = output.recv().expect("batch at deadline");
        let t_first = t0.elapsed();
        assert_eq!(b.entries.len(), 2, "partial batch shipped");
        assert!(t_first >= Duration::from_millis(25), "respected deadline: {t_first:?}");
        assert!(t_first < Duration::from_millis(110), "shipped at deadline, not at close: {t_first:?}");
        std::thread::sleep(Duration::from_millis(90).saturating_sub(t_first));
        input.close();
        assert_eq!(h.join().unwrap(), 1);
    }

    #[test]
    fn splits_across_batches_without_loss() {
        let input = queues(1, 256);
        let output = Channel::bounded(256);
        for i in 0..50 {
            input.send(req(i)).unwrap();
        }
        input.close();
        run_batcher(&cfg(4, 4, 1_000), &input, &output, None);
        let mut all = Vec::new();
        while let Some(b) = output.recv() {
            assert!(b.entries.len() <= 16);
            all.extend(b.entries.iter().map(|r| r.id));
        }
        assert_eq!(all, (0..50).collect::<Vec<_>>());
    }

    #[test]
    fn closes_output_on_exit() {
        let input = queues(1, 4);
        let output = Channel::bounded(4);
        input.close();
        run_batcher(&cfg(2, 1, 10), &input, &output, None);
        assert!(output.recv().is_none());
    }

    /// Waves never mix buckets: a mixed backlog ships as one wave per
    /// shape, deepest bucket first, FIFO within each bucket.
    #[test]
    fn waves_are_shape_homogeneous_and_deepest_first() {
        let input = queues(3, 64);
        let output = Channel::bounded(64);
        // bucket 2 is deepest (3 entries), bucket 0 has 2, bucket 1 has 1
        for (id, b) in [(0u64, 2), (1, 0), (2, 2), (3, 1), (4, 2), (5, 0)] {
            input.send(req_in(id, b)).unwrap();
        }
        input.close();
        let n = run_batcher(&cfg(4, 2, 5), &input, &output, None);
        assert_eq!(n, 3, "one wave per bucket");
        let mut seen: Vec<(usize, Vec<u64>)> = Vec::new();
        while let Some(b) = output.recv() {
            assert!(
                b.entries.iter().all(|r| r.bucket == b.bucket),
                "wave mixes buckets: {:?}",
                b.entries.iter().map(|r| r.bucket).collect::<Vec<_>>()
            );
            seen.push((b.bucket, b.entries.iter().map(|r| r.id).collect()));
        }
        assert_eq!(seen[0], (2, vec![0, 2, 4]), "deepest bucket ships first");
        // remaining buckets drain too, FIFO within each
        assert!(seen.contains(&(0, vec![1, 5])));
        assert!(seen.contains(&(1, vec![3])));
    }

    /// Anti-starvation: a lone request in a quiet bucket must be served
    /// within [`ANTI_STARVE_PERIOD`] waves even while a sibling bucket
    /// holds a deep backlog that wins deepest-first on every other round.
    #[test]
    fn starved_bucket_is_served_within_the_anti_starve_period() {
        let input = queues(2, 64);
        let output = Channel::bounded(64);
        input.send(req_in(999, 0)).unwrap(); // the lone quiet-bucket request
        for i in 0..40 {
            input.send(req_in(i, 1)).unwrap(); // deep saturated bucket
        }
        input.close();
        let n = run_batcher(&cfg(2, 2, 1), &input, &output, None); // capacity 4
        assert!(n >= 10, "backlog takes many waves: {n}");
        let mut pos_of_quiet = None;
        let mut i = 0usize;
        while let Some(b) = output.recv() {
            if b.bucket == 0 {
                assert_eq!(b.entries.len(), 1);
                assert_eq!(b.entries[0].id, 999);
                pos_of_quiet = Some(i);
            }
            i += 1;
        }
        let pos = pos_of_quiet.expect("quiet bucket served");
        assert!(
            pos < ANTI_STARVE_PERIOD as usize,
            "quiet bucket served at wave {pos}, must beat the anti-starve period"
        );
    }

    #[test]
    fn pull_batcher_drains_closed_shared_queue_ignoring_gate() {
        let shared = queues(1, 64);
        let output = Channel::bounded(64);
        for i in 0..8 {
            shared.send(req(i)).unwrap();
        }
        shared.close();
        let lane = LaneControl::default();
        // gate always says no — but a closed queue is drain mode
        let n = run_pull_batcher(
            &cfg(4, 2, 5),
            &shared,
            &output,
            &lane,
            &|| false,
            Duration::from_millis(1),
            None,
        );
        assert_eq!(n, 1);
        let b = output.recv().expect("backlog still ships on shutdown");
        assert_eq!(b.entries.len(), 8);
        assert!(output.recv().is_none(), "output closed on exit");
        assert_eq!(lane.requeued.load(Ordering::Relaxed), 0);
    }

    #[test]
    fn pull_batcher_waits_for_the_gate_to_open() {
        let shared = std::sync::Arc::new(queues(1, 64));
        let output: Channel<ExecBatch> = Channel::bounded(64);
        shared.send(req(0)).unwrap();
        shared.send(req(1)).unwrap();
        let open = std::sync::Arc::new(std::sync::atomic::AtomicBool::new(false));
        let h = {
            let shared = shared.clone();
            let output = output.clone();
            let open = open.clone();
            std::thread::spawn(move || {
                let lane = LaneControl::default();
                let gate = || open.load(Ordering::Relaxed);
                run_pull_batcher(
                    &cfg(2, 1, 1),
                    &shared,
                    &output,
                    &lane,
                    &gate,
                    Duration::from_millis(1),
                    None,
                )
            })
        };
        std::thread::sleep(Duration::from_millis(40));
        assert!(output.try_recv().is_none(), "gated lane must not pull");
        open.store(true, Ordering::Relaxed);
        let b = output.recv().expect("open gate releases the wave");
        assert_eq!(b.entries.len(), 2);
        shared.close();
        assert_eq!(h.join().unwrap(), 1);
    }

    #[test]
    fn pull_batcher_requeues_wave_when_exec_channel_is_closed() {
        let shared = queues(2, 64);
        let output: Channel<ExecBatch> = Channel::bounded(1);
        output.close(); // worker already died
        for i in 0..4 {
            shared.send(req_in(i, 1)).unwrap();
        }
        let lane = LaneControl::default();
        let n = run_pull_batcher(
            &cfg(4, 1, 1),
            &shared,
            &output,
            &lane,
            &|| true,
            Duration::from_millis(1),
            None,
        );
        assert_eq!(n, 1, "the wave was formed before the dead handoff");
        assert_eq!(lane.requeued.load(Ordering::Relaxed), 4, "whole wave handed back");
        assert_eq!(shared.len(), 4, "requests are back in the shared queue");
        assert_eq!(shared.depth(1), 4, "requeue routes to the right bucket");
        let mut back = Vec::new();
        shared.try_recv_any(&mut back, 8);
        let ids: Vec<u64> = back.iter().map(|r| r.id).collect();
        assert_eq!(ids, vec![0, 1, 2, 3], "requeue preserves wave order");
    }

    #[test]
    fn pull_batcher_stops_immediately_when_marked_dead() {
        let shared = queues(1, 8);
        let output: Channel<ExecBatch> = Channel::bounded(8);
        shared.send(req(0)).unwrap();
        let lane = LaneControl::default();
        lane.dead.store(true, Ordering::Release);
        let n = run_pull_batcher(
            &cfg(2, 1, 1),
            &shared,
            &output,
            &lane,
            &|| true,
            Duration::from_millis(1),
            None,
        );
        assert_eq!(n, 0);
        assert_eq!(shared.len(), 1, "a dead lane never pulls");
        assert!(output.recv().is_none(), "output closed on exit");
    }

    #[test]
    fn requeue_fails_loudly_when_queue_full_or_closed() {
        // full queue -> WorkerFailed
        let shared = queues(1, 1);
        shared.send(req(99)).unwrap();
        let cell = OnceCellSync::new();
        let r = Request {
            id: 1,
            content: vec![0; 4],
            bucket: 0,
            submitted: Instant::now(),
            deadline: None,
            priority: crate::coordinator::Priority::Normal,
            done: Completion::cell(cell.clone()),
        };
        let requeued = AtomicU64::new(0);
        requeue_entries(&shared, vec![r], &requeued);
        assert_eq!(requeued.load(Ordering::Relaxed), 0);
        match cell.wait_timeout(Duration::from_secs(1)).expect("answered") {
            Err(EngineError::WorkerFailed(msg)) => assert!(msg.contains("re-queued"), "{msg}"),
            other => panic!("expected WorkerFailed, got {other:?}"),
        }
        // closed queue -> Shutdown via the drop guard
        shared.close();
        let cell2 = OnceCellSync::new();
        let r2 = Request {
            id: 2,
            content: vec![0; 4],
            bucket: 0,
            submitted: Instant::now(),
            deadline: None,
            priority: crate::coordinator::Priority::Normal,
            done: Completion::cell(cell2.clone()),
        };
        requeue_entries(&shared, vec![r2], &requeued);
        match cell2.wait_timeout(Duration::from_secs(1)).expect("answered") {
            Err(EngineError::Shutdown) => {}
            other => panic!("expected Shutdown, got {other:?}"),
        }
    }
}
