//! The mux batcher — the serving realization of the paper's contribution.
//!
//! Incoming requests are grouped into *multiplex groups* of `n_mux` slots
//! and further into a model batch of `batch` groups, i.e. one PJRT
//! execution serves up to `batch * n_mux` requests. Group formation is
//! deadline-driven: the batch ships when full OR when the oldest queued
//! request has waited `max_wait` — the standard dynamic-batching
//! throughput/latency dial, except each "row" here is a *mixed
//! representation of N requests*, which is what multiplies throughput
//! (paper Fig 4c) instead of memory (Fig 12).
//!
//! Invariants (property-tested in tests/):
//!   * no request is dropped, duplicated, or reordered across groups
//!   * a batch never carries more than `batch * n_mux` requests
//!   * no request waits longer than `max_wait` before its batch ships
//!     (modulo executor time)

use std::sync::atomic::{AtomicU64, Ordering};
use std::time::{Duration, Instant};

use super::dispatch::LaneControl;
use super::request::{EngineError, Request};
use crate::util::metrics::Counters;
use crate::util::threadpool::{Channel, TrySendError};

/// One model execution's worth of requests (up to batch * n_mux).
pub struct ExecBatch {
    pub seq: u64,
    pub entries: Vec<Request>,
    pub formed_at: Instant,
}

#[derive(Debug, Clone)]
pub struct BatcherConfig {
    pub n_mux: usize,
    pub batch: usize,
    pub max_wait: Duration,
}

impl BatcherConfig {
    pub fn capacity(&self) -> usize {
        self.n_mux * self.batch
    }
}

/// Pull requests from `input`, form deadline-bounded ExecBatches, push to
/// `output`. Runs until `input` is closed and drained; then closes
/// `output`. Returns the number of batches formed.
///
/// Intake is wave-based: each [`Channel::recv_up_to`] drain grabs the
/// whole queued backlog (capped at batch capacity) with one lock
/// acquisition, so under load a full batch costs O(1) mutex round-trips
/// instead of one per request. FIFO order, the no-loss invariant, and
/// the `max_wait` deadline are unchanged. When `counters` is given,
/// drains are tallied into `intake_waves` (requests-per-wave is the
/// amortization factor benches watch).
pub fn run_batcher(
    cfg: &BatcherConfig,
    input: &Channel<Request>,
    output: &Channel<ExecBatch>,
    counters: Option<&Counters>,
) -> u64 {
    let capacity = cfg.capacity();
    let mut seq = 0u64;
    loop {
        let mut entries: Vec<Request> = Vec::with_capacity(capacity);
        // block for the first wave of the next batch
        let mut waves = 1u64;
        if input.recv_up_to(&mut entries, capacity, None) == 0 {
            break; // closed + drained
        }
        let deadline = Instant::now() + cfg.max_wait;
        while entries.len() < capacity {
            if input.recv_up_to(&mut entries, capacity - entries.len(), Some(deadline)) == 0 {
                break; // deadline passed, or closed + drained
            }
            waves += 1;
        }
        seq += 1;
        if let Some(c) = counters {
            c.intake_waves.fetch_add(waves, Ordering::Relaxed);
            c.batches_formed.fetch_add(1, Ordering::Relaxed);
        }
        let batch = ExecBatch { seq, entries, formed_at: Instant::now() };
        if output.send(batch).is_err() {
            break;
        }
    }
    output.close();
    seq
}

/// Pull-gated batcher over a **shared** admission queue (the router's
/// work-stealing dispatch). Unlike [`run_batcher`], the input channel is
/// not owned by this lane: every lane of a router pulls waves from the
/// same queue, each sized to its own `batch * n_mux` capacity, and the
/// `gate` closure (the router's [`AdaptiveN`](super::AdaptiveN)
/// pull-gate) decides per wakeup whether the current backlog/rate
/// justifies this lane's N. A closed shared queue bypasses the gate
/// (drain mode), so the admitted backlog always completes on shutdown.
///
/// Lane health: when `lane.dead` is set (this lane's worker failed) the
/// batcher stops pulling immediately. A wave it already holds when the
/// exec channel closes under it is handed back to the shared queue via
/// [`requeue_entries`] — re-queued for a sibling lane, or failed loudly;
/// never silently dropped. Returns the number of batches formed and
/// closes `output` on exit.
///
/// `poll` is the *initial* tick: while a lane finds nothing to do
/// (gated off, or gate open but the queue stays empty), consecutive
/// idle ticks back off exponentially up to `20 * poll`, so an idle
/// router costs almost no CPU; the backoff resets the moment a wave is
/// pulled. A lane that passes the gate parks *inside* `recv_up_to` on
/// the queue's condvar, so arrival latency is unaffected by backoff —
/// only how fast a gated-off lane notices it is newly justified (and
/// how fast shutdown/death is noticed) is bounded by the backed-off
/// tick.
pub fn run_pull_batcher(
    cfg: &BatcherConfig,
    shared: &Channel<Request>,
    output: &Channel<ExecBatch>,
    lane: &LaneControl,
    gate: &dyn Fn() -> bool,
    poll: Duration,
    counters: Option<&Counters>,
) -> u64 {
    let capacity = cfg.capacity();
    let max_idle = poll * 20;
    let mut idle = poll;
    let mut seq = 0u64;
    // reused across poll ticks; a replacement is only allocated when a
    // formed wave is actually handed off, so idle ticks allocate nothing
    let mut entries: Vec<Request> = Vec::with_capacity(capacity);
    'pull: loop {
        if lane.dead.load(Ordering::Acquire) {
            break;
        }
        let draining = shared.is_closed();
        if !draining && !gate() {
            // not this lane's turn: sleep one (backed-off) tick, then
            // re-check the gate (backlog may have grown) and health
            std::thread::sleep(idle);
            idle = (idle * 2).min(max_idle);
            continue;
        }
        // bounded block: wake at most one tick later to re-check
        // gate/health (arrivals wake the condvar immediately)
        if shared.recv_up_to(&mut entries, capacity, Some(Instant::now() + idle)) == 0 {
            if draining && shared.is_empty() {
                break; // closed + drained: shutdown complete
            }
            idle = (idle * 2).min(max_idle);
            continue;
        }
        idle = poll;
        let mut waves = 1u64;
        let deadline = Instant::now() + cfg.max_wait;
        while entries.len() < capacity {
            if shared.recv_up_to(&mut entries, capacity - entries.len(), Some(deadline)) == 0 {
                break; // deadline passed, or closed + drained
            }
            waves += 1;
        }
        seq += 1;
        if let Some(c) = counters {
            c.intake_waves.fetch_add(waves, Ordering::Relaxed);
            c.batches_formed.fetch_add(1, Ordering::Relaxed);
        }
        let mut batch = ExecBatch {
            seq,
            entries: std::mem::replace(&mut entries, Vec::with_capacity(capacity)),
            formed_at: Instant::now(),
        };
        // hand off to this lane's workers. try_send (not send) so a wave
        // is never lost to a closed channel: on worker death the batch
        // comes back and is returned to the shared queue.
        loop {
            match output.try_send(batch) {
                Ok(()) => continue 'pull,
                Err(TrySendError::Closed(b)) => {
                    requeue_entries(shared, b.entries, &lane.requeued);
                    break 'pull;
                }
                Err(TrySendError::Full(b)) => {
                    if lane.dead.load(Ordering::Acquire) {
                        requeue_entries(shared, b.entries, &lane.requeued);
                        break 'pull;
                    }
                    batch = b;
                    std::thread::sleep(poll);
                }
            }
        }
    }
    output.close();
    seq
}

/// Return pulled-but-unexecuted requests to the shared queue (lane-death
/// path), preserving their original submit timestamps. Requests that
/// cannot go back are failed **loudly**: `WorkerFailed` when the queue
/// is full, `Shutdown` (via the completion drop guard) when it is
/// closed — never silently lost.
pub(crate) fn requeue_entries(
    shared: &Channel<Request>,
    entries: Vec<Request>,
    requeued: &AtomicU64,
) {
    for req in entries {
        match shared.try_send(req) {
            Ok(()) => {
                requeued.fetch_add(1, Ordering::Relaxed);
            }
            Err(TrySendError::Full(req)) => {
                req.fulfill(Err(EngineError::WorkerFailed(
                    "lane died and the shared queue is full; request could not be re-queued"
                        .to_string(),
                )));
            }
            Err(TrySendError::Closed(req)) => {
                // router is shutting down (or every lane is dead): the
                // drop guard answers Shutdown
                drop(req);
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::request::Completion;
    use crate::util::threadpool::OnceCellSync;

    fn req(id: u64) -> Request {
        Request {
            id,
            content: vec![1, 0, 0, 0],
            submitted: Instant::now(),
            deadline: None,
            done: Completion::cell(OnceCellSync::new()),
        }
    }

    fn cfg(n_mux: usize, batch: usize, wait_ms: u64) -> BatcherConfig {
        BatcherConfig { n_mux, batch, max_wait: Duration::from_millis(wait_ms) }
    }

    #[test]
    fn ships_full_batch_immediately() {
        let input = Channel::bounded(64);
        let output = Channel::bounded(64);
        for i in 0..8 {
            input.send(req(i)).unwrap();
        }
        input.close();
        let counters = Counters::default();
        let n = run_batcher(&cfg(4, 2, 1_000), &input, &output, Some(&counters));
        assert_eq!(n, 1);
        // the whole preloaded backlog is one drain: one lock round-trip
        assert_eq!(counters.intake_waves.load(std::sync::atomic::Ordering::Relaxed), 1);
        let b = output.recv().unwrap();
        assert_eq!(b.entries.len(), 8);
        let ids: Vec<u64> = b.entries.iter().map(|r| r.id).collect();
        assert_eq!(ids, (0..8).collect::<Vec<_>>(), "arrival order preserved");
    }

    #[test]
    fn ships_partial_batch_at_deadline() {
        let input = Channel::bounded(64);
        let output: Channel<ExecBatch> = Channel::bounded(64);
        input.send(req(0)).unwrap();
        input.send(req(1)).unwrap();
        let i2 = input.clone();
        let o2 = output.clone();
        let t0 = Instant::now();
        let h = std::thread::spawn(move || run_batcher(&cfg(4, 2, 30), &i2, &o2, None));
        // consumer observes the partial batch at the 30ms deadline, long
        // before the input channel closes at ~120ms
        let b = output.recv().expect("batch at deadline");
        let t_first = t0.elapsed();
        assert_eq!(b.entries.len(), 2, "partial batch shipped");
        assert!(t_first >= Duration::from_millis(25), "respected deadline: {t_first:?}");
        assert!(t_first < Duration::from_millis(110), "shipped at deadline, not at close: {t_first:?}");
        std::thread::sleep(Duration::from_millis(90).saturating_sub(t_first));
        input.close();
        assert_eq!(h.join().unwrap(), 1);
    }

    #[test]
    fn splits_across_batches_without_loss() {
        let input = Channel::bounded(256);
        let output = Channel::bounded(256);
        for i in 0..50 {
            input.send(req(i)).unwrap();
        }
        input.close();
        run_batcher(&cfg(4, 4, 1_000), &input, &output, None);
        let mut all = Vec::new();
        while let Some(b) = output.recv() {
            assert!(b.entries.len() <= 16);
            all.extend(b.entries.iter().map(|r| r.id));
        }
        assert_eq!(all, (0..50).collect::<Vec<_>>());
    }

    #[test]
    fn closes_output_on_exit() {
        let input: Channel<Request> = Channel::bounded(4);
        let output = Channel::bounded(4);
        input.close();
        run_batcher(&cfg(2, 1, 10), &input, &output, None);
        assert!(output.recv().is_none());
    }

    #[test]
    fn pull_batcher_drains_closed_shared_queue_ignoring_gate() {
        let shared = Channel::bounded(64);
        let output = Channel::bounded(64);
        for i in 0..8 {
            shared.send(req(i)).unwrap();
        }
        shared.close();
        let lane = LaneControl::default();
        // gate always says no — but a closed queue is drain mode
        let n = run_pull_batcher(
            &cfg(4, 2, 5),
            &shared,
            &output,
            &lane,
            &|| false,
            Duration::from_millis(1),
            None,
        );
        assert_eq!(n, 1);
        let b = output.recv().expect("backlog still ships on shutdown");
        assert_eq!(b.entries.len(), 8);
        assert!(output.recv().is_none(), "output closed on exit");
        assert_eq!(lane.requeued.load(Ordering::Relaxed), 0);
    }

    #[test]
    fn pull_batcher_waits_for_the_gate_to_open() {
        let shared = Channel::bounded(64);
        let output: Channel<ExecBatch> = Channel::bounded(64);
        shared.send(req(0)).unwrap();
        shared.send(req(1)).unwrap();
        let open = std::sync::Arc::new(std::sync::atomic::AtomicBool::new(false));
        let h = {
            let shared = shared.clone();
            let output = output.clone();
            let open = open.clone();
            std::thread::spawn(move || {
                let lane = LaneControl::default();
                let gate = || open.load(Ordering::Relaxed);
                run_pull_batcher(
                    &cfg(2, 1, 1),
                    &shared,
                    &output,
                    &lane,
                    &gate,
                    Duration::from_millis(1),
                    None,
                )
            })
        };
        std::thread::sleep(Duration::from_millis(40));
        assert!(output.try_recv().is_none(), "gated lane must not pull");
        open.store(true, Ordering::Relaxed);
        let b = output.recv().expect("open gate releases the wave");
        assert_eq!(b.entries.len(), 2);
        shared.close();
        assert_eq!(h.join().unwrap(), 1);
    }

    #[test]
    fn pull_batcher_requeues_wave_when_exec_channel_is_closed() {
        let shared = Channel::bounded(64);
        let output: Channel<ExecBatch> = Channel::bounded(1);
        output.close(); // worker already died
        for i in 0..4 {
            shared.send(req(i)).unwrap();
        }
        let lane = LaneControl::default();
        let n = run_pull_batcher(
            &cfg(4, 1, 1),
            &shared,
            &output,
            &lane,
            &|| true,
            Duration::from_millis(1),
            None,
        );
        assert_eq!(n, 1, "the wave was formed before the dead handoff");
        assert_eq!(lane.requeued.load(Ordering::Relaxed), 4, "whole wave handed back");
        assert_eq!(shared.len(), 4, "requests are back in the shared queue");
        let mut back = Vec::new();
        shared.try_recv_up_to(&mut back, 8);
        let ids: Vec<u64> = back.iter().map(|r| r.id).collect();
        assert_eq!(ids, vec![0, 1, 2, 3], "requeue preserves wave order");
    }

    #[test]
    fn pull_batcher_stops_immediately_when_marked_dead() {
        let shared = Channel::bounded(8);
        let output: Channel<ExecBatch> = Channel::bounded(8);
        shared.send(req(0)).unwrap();
        let lane = LaneControl::default();
        lane.dead.store(true, Ordering::Release);
        let n = run_pull_batcher(
            &cfg(2, 1, 1),
            &shared,
            &output,
            &lane,
            &|| true,
            Duration::from_millis(1),
            None,
        );
        assert_eq!(n, 0);
        assert_eq!(shared.len(), 1, "a dead lane never pulls");
        assert!(output.recv().is_none(), "output closed on exit");
    }

    #[test]
    fn requeue_fails_loudly_when_queue_full_or_closed() {
        // full queue -> WorkerFailed
        let shared: Channel<Request> = Channel::bounded(1);
        shared.send(req(99)).unwrap();
        let cell = OnceCellSync::new();
        let r = Request {
            id: 1,
            content: vec![0; 4],
            submitted: Instant::now(),
            deadline: None,
            done: Completion::cell(cell.clone()),
        };
        let requeued = AtomicU64::new(0);
        requeue_entries(&shared, vec![r], &requeued);
        assert_eq!(requeued.load(Ordering::Relaxed), 0);
        match cell.wait_timeout(Duration::from_secs(1)).expect("answered") {
            Err(EngineError::WorkerFailed(msg)) => assert!(msg.contains("re-queued"), "{msg}"),
            other => panic!("expected WorkerFailed, got {other:?}"),
        }
        // closed queue -> Shutdown via the drop guard
        shared.close();
        let cell2 = OnceCellSync::new();
        let r2 = Request {
            id: 2,
            content: vec![0; 4],
            submitted: Instant::now(),
            deadline: None,
            done: Completion::cell(cell2.clone()),
        };
        requeue_entries(&shared, vec![r2], &requeued);
        match cell2.wait_timeout(Duration::from_secs(1)).expect("answered") {
            Err(EngineError::Shutdown) => {}
            other => panic!("expected Shutdown, got {other:?}"),
        }
    }
}
