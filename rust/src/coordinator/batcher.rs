//! The mux batcher — the serving realization of the paper's contribution.
//!
//! Incoming requests are grouped into *multiplex groups* of `n_mux` slots
//! and further into a model batch of `batch` groups, i.e. one PJRT
//! execution serves up to `batch * n_mux` requests. Group formation is
//! deadline-driven: the batch ships when full OR when the oldest queued
//! request has waited `max_wait` — the standard dynamic-batching
//! throughput/latency dial, except each "row" here is a *mixed
//! representation of N requests*, which is what multiplies throughput
//! (paper Fig 4c) instead of memory (Fig 12).
//!
//! Invariants (property-tested in tests/):
//!   * no request is dropped, duplicated, or reordered across groups
//!   * a batch never carries more than `batch * n_mux` requests
//!   * no request waits longer than `max_wait` before its batch ships
//!     (modulo executor time)

use std::sync::atomic::Ordering;
use std::time::{Duration, Instant};

use super::request::Request;
use crate::util::metrics::Counters;
use crate::util::threadpool::Channel;

/// One model execution's worth of requests (up to batch * n_mux).
pub struct ExecBatch {
    pub seq: u64,
    pub entries: Vec<Request>,
    pub formed_at: Instant,
}

#[derive(Debug, Clone)]
pub struct BatcherConfig {
    pub n_mux: usize,
    pub batch: usize,
    pub max_wait: Duration,
}

impl BatcherConfig {
    pub fn capacity(&self) -> usize {
        self.n_mux * self.batch
    }
}

/// Pull requests from `input`, form deadline-bounded ExecBatches, push to
/// `output`. Runs until `input` is closed and drained; then closes
/// `output`. Returns the number of batches formed.
///
/// Intake is wave-based: each [`Channel::recv_up_to`] drain grabs the
/// whole queued backlog (capped at batch capacity) with one lock
/// acquisition, so under load a full batch costs O(1) mutex round-trips
/// instead of one per request. FIFO order, the no-loss invariant, and
/// the `max_wait` deadline are unchanged. When `counters` is given,
/// drains are tallied into `intake_waves` (requests-per-wave is the
/// amortization factor benches watch).
pub fn run_batcher(
    cfg: &BatcherConfig,
    input: &Channel<Request>,
    output: &Channel<ExecBatch>,
    counters: Option<&Counters>,
) -> u64 {
    let capacity = cfg.capacity();
    let mut seq = 0u64;
    loop {
        let mut entries: Vec<Request> = Vec::with_capacity(capacity);
        // block for the first wave of the next batch
        let mut waves = 1u64;
        if input.recv_up_to(&mut entries, capacity, None) == 0 {
            break; // closed + drained
        }
        let deadline = Instant::now() + cfg.max_wait;
        while entries.len() < capacity {
            if input.recv_up_to(&mut entries, capacity - entries.len(), Some(deadline)) == 0 {
                break; // deadline passed, or closed + drained
            }
            waves += 1;
        }
        seq += 1;
        if let Some(c) = counters {
            c.intake_waves.fetch_add(waves, Ordering::Relaxed);
        }
        let batch = ExecBatch { seq, entries, formed_at: Instant::now() };
        if output.send(batch).is_err() {
            break;
        }
    }
    output.close();
    seq
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::request::Completion;
    use crate::util::threadpool::OnceCellSync;

    fn req(id: u64) -> Request {
        Request {
            id,
            content: vec![1, 0, 0, 0],
            submitted: Instant::now(),
            deadline: None,
            done: Completion::cell(OnceCellSync::new()),
        }
    }

    fn cfg(n_mux: usize, batch: usize, wait_ms: u64) -> BatcherConfig {
        BatcherConfig { n_mux, batch, max_wait: Duration::from_millis(wait_ms) }
    }

    #[test]
    fn ships_full_batch_immediately() {
        let input = Channel::bounded(64);
        let output = Channel::bounded(64);
        for i in 0..8 {
            input.send(req(i)).unwrap();
        }
        input.close();
        let counters = Counters::default();
        let n = run_batcher(&cfg(4, 2, 1_000), &input, &output, Some(&counters));
        assert_eq!(n, 1);
        // the whole preloaded backlog is one drain: one lock round-trip
        assert_eq!(counters.intake_waves.load(std::sync::atomic::Ordering::Relaxed), 1);
        let b = output.recv().unwrap();
        assert_eq!(b.entries.len(), 8);
        let ids: Vec<u64> = b.entries.iter().map(|r| r.id).collect();
        assert_eq!(ids, (0..8).collect::<Vec<_>>(), "arrival order preserved");
    }

    #[test]
    fn ships_partial_batch_at_deadline() {
        let input = Channel::bounded(64);
        let output: Channel<ExecBatch> = Channel::bounded(64);
        input.send(req(0)).unwrap();
        input.send(req(1)).unwrap();
        let i2 = input.clone();
        let o2 = output.clone();
        let t0 = Instant::now();
        let h = std::thread::spawn(move || run_batcher(&cfg(4, 2, 30), &i2, &o2, None));
        // consumer observes the partial batch at the 30ms deadline, long
        // before the input channel closes at ~120ms
        let b = output.recv().expect("batch at deadline");
        let t_first = t0.elapsed();
        assert_eq!(b.entries.len(), 2, "partial batch shipped");
        assert!(t_first >= Duration::from_millis(25), "respected deadline: {t_first:?}");
        assert!(t_first < Duration::from_millis(110), "shipped at deadline, not at close: {t_first:?}");
        std::thread::sleep(Duration::from_millis(90).saturating_sub(t_first));
        input.close();
        assert_eq!(h.join().unwrap(), 1);
    }

    #[test]
    fn splits_across_batches_without_loss() {
        let input = Channel::bounded(256);
        let output = Channel::bounded(256);
        for i in 0..50 {
            input.send(req(i)).unwrap();
        }
        input.close();
        run_batcher(&cfg(4, 4, 1_000), &input, &output, None);
        let mut all = Vec::new();
        while let Some(b) = output.recv() {
            assert!(b.entries.len() <= 16);
            all.extend(b.entries.iter().map(|r| r.id));
        }
        assert_eq!(all, (0..50).collect::<Vec<_>>());
    }

    #[test]
    fn closes_output_on_exit() {
        let input: Channel<Request> = Channel::bounded(4);
        let output = Channel::bounded(4);
        input.close();
        run_batcher(&cfg(2, 1, 10), &input, &output, None);
        assert!(output.recv().is_none());
    }
}
