//! Sequence-length buckets: the shape registry of the request path.
//!
//! The engine used to bake ONE `seq_len` end-to-end: every request was
//! padded to the model max at submission, every template stamped the max
//! shape, and the native forward paid O(seq_len²) attention on `[PAD]`
//! tokens. [`Buckets`] is the small sorted registry of sequence lengths
//! the engine executes instead (e.g. `{32, 64, 128}` with 128 the model
//! max): a request is admitted **unpadded**, assigned the smallest
//! bucket that fits it, and only ever padded to *that bucket's* length
//! at batch assembly.
//!
//! [`BucketQueues`] is the admission structure that keeps waves
//! shape-homogeneous: one bounded, class-prioritized FIFO per bucket
//! ([`PrioChannel`] — one entry per [`Priority`] class), requests
//! routed by their `(bucket, priority)` at admission, and batchers
//! pulling whole waves from the **deepest** non-empty bucket, highest
//! class first within the wave — so one model execution only ever
//! carries rows of a single shape, high-priority rows board first, and
//! arrival order is preserved within each `(shape, class)` pair.

use std::time::Instant;

use crate::util::threadpool::{PrioChannel, SendError, TrySendError};

use super::api::N_PRIORITY_CLASSES;
use super::request::Request;

/// Sorted registry of the sequence lengths the engine executes. The
/// largest bucket is always the model's `seq_len` (the compiled /
/// trained maximum), so every admissible request has a home.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct Buckets {
    /// ascending, deduplicated, last == seq_len_max
    lens: Vec<usize>,
}

impl Buckets {
    /// Build from requested bucket lengths plus the mandatory
    /// `seq_len_max` terminal bucket. Requested lengths outside
    /// `1..=seq_len_max` are ignored; duplicates collapse.
    pub fn new(requested: &[usize], seq_len_max: usize) -> Buckets {
        assert!(seq_len_max >= 1, "model seq_len must be positive");
        let mut lens: Vec<usize> = requested
            .iter()
            .copied()
            .filter(|&l| (1..seq_len_max).contains(&l))
            .collect();
        lens.push(seq_len_max);
        lens.sort_unstable();
        lens.dedup();
        Buckets { lens }
    }

    /// The degenerate single-bucket registry: pad-to-max, the pre-bucket
    /// behavior (and the only option for shape-baked PJRT backends).
    pub fn single(seq_len_max: usize) -> Buckets {
        Buckets::new(&[], seq_len_max)
    }

    pub fn lens(&self) -> &[usize] {
        &self.lens
    }

    pub fn count(&self) -> usize {
        self.lens.len()
    }

    pub fn max_len(&self) -> usize {
        // `new` always appends the terminal `seq_len_max` bucket, so the
        // registry is never empty; read an (impossible) empty registry
        // as 0 rather than panicking on the serving path
        self.lens.last().copied().unwrap_or(0)
    }

    pub fn len_of(&self, idx: usize) -> usize {
        self.lens[idx]
    }

    /// Index of the smallest bucket that fits a `content_len`-token row;
    /// `None` when the row exceeds the model max (reject at admission).
    // lint: hot-path
    pub fn index_for(&self, content_len: usize) -> Option<usize> {
        if content_len == 0 {
            return None;
        }
        self.lens.iter().position(|&l| l >= content_len)
    }
}

/// One bounded, class-prioritized admission FIFO per bucket, closed and
/// drained as a unit.
///
/// `queue_cap` applies **per bucket per priority class**: a burst of
/// one shape cannot starve admission of another (per-shape head-of-line
/// isolation), and a flood of bulk traffic cannot consume a higher
/// class's admission slots. The single-bucket, all-normal default
/// behaves exactly like the old one-channel admission queue.
#[derive(Clone)]
pub struct BucketQueues {
    qs: Vec<PrioChannel<Request>>,
}

impl BucketQueues {
    pub fn new(n_buckets: usize, cap_per_bucket: usize) -> BucketQueues {
        assert!(n_buckets >= 1);
        BucketQueues {
            qs: (0..n_buckets)
                .map(|_| PrioChannel::bounded(N_PRIORITY_CLASSES, cap_per_bucket))
                .collect(),
        }
    }

    pub fn count(&self) -> usize {
        self.qs.len()
    }

    /// The channel backing bucket `idx` (batchers pull waves off it).
    pub fn queue(&self, idx: usize) -> &PrioChannel<Request> {
        &self.qs[idx]
    }

    /// Blocking admission, routed by the request's own
    /// `(bucket, priority)` (backpressure per bucket+class). Err when
    /// closed.
    pub fn send(&self, req: Request) -> Result<(), SendError> {
        let class = req.priority.index();
        self.qs[req.bucket].send(req, class)
    }

    /// Non-blocking admission; `Full`/`Closed` hand the request back.
    pub fn try_send(&self, req: Request) -> Result<(), TrySendError<Request>> {
        let class = req.priority.index();
        self.qs[req.bucket].try_send(req, class)
    }

    /// Total queued across buckets (lock-free mirror reads).
    pub fn len(&self) -> usize {
        self.qs.iter().map(PrioChannel::len).sum()
    }

    pub fn is_empty(&self) -> bool {
        self.qs.iter().all(PrioChannel::is_empty)
    }

    pub fn depth(&self, idx: usize) -> usize {
        self.qs[idx].len()
    }

    /// Queued work at `class` or higher across all buckets — the depth
    /// a new arrival of `class` queues behind, whichever bucket a
    /// batcher drains next (feeds the admission overload check).
    pub fn depth_at_or_above(&self, class: usize) -> usize {
        self.qs.iter().map(|q| q.depth_at_or_above(class)).sum()
    }

    /// Queued work of exactly `class` across all buckets (STATS depth).
    pub fn depth_class(&self, class: usize) -> usize {
        self.qs.iter().map(|q| q.depth_class(class)).sum()
    }

    /// The deepest non-empty bucket — the "deepest eligible bucket" rule
    /// batchers pull by. Ties break toward the *larger* bucket (its
    /// waves amortize more padding headroom).
    pub fn deepest(&self) -> Option<usize> {
        let mut best: Option<(usize, usize)> = None;
        for (i, q) in self.qs.iter().enumerate() {
            let d = q.len();
            if d > 0 && best.map_or(true, |(_, bd)| d >= bd) {
                best = Some((i, d));
            }
        }
        best.map(|(i, _)| i)
    }

    /// First non-empty bucket scanning cyclically from `start` — the
    /// batchers' round-robin anti-starvation probe (a quiet bucket must
    /// not wait forever behind a saturated sibling that always wins the
    /// deepest-first rule).
    pub fn nonempty_from(&self, start: usize) -> Option<usize> {
        let n = self.qs.len();
        (0..n).map(|k| (start + k) % n).find(|&i| !self.qs[i].is_empty())
    }

    /// Close every bucket: senders fail, receivers drain then stop.
    pub fn close(&self) {
        for q in &self.qs {
            q.close();
        }
    }

    pub fn is_closed(&self) -> bool {
        // buckets are closed as a unit; the first one answers for all
        self.qs[0].is_closed()
    }

    /// Drain up to `max` requests from any bucket into `out`
    /// (non-blocking). Used by teardown paths that fail the backlog.
    pub fn try_recv_any(&self, out: &mut Vec<Request>, max: usize) -> usize {
        let mut got = 0;
        for q in &self.qs {
            if got >= max {
                break;
            }
            got += q.try_recv_up_to(out, max - got);
        }
        got
    }

    /// Bounded park on one bucket's condvar: wait for a wave on bucket
    /// `idx` until `deadline` (`None` = until close). Returns the number
    /// of requests appended to `out`.
    pub fn recv_wave(
        &self,
        idx: usize,
        out: &mut Vec<Request>,
        max: usize,
        deadline: Option<Instant>,
    ) -> usize {
        self.qs[idx].recv_up_to(out, max, deadline)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::request::Completion;
    use crate::util::threadpool::OnceCellSync;
    use std::time::Instant;

    use crate::coordinator::Priority;

    fn req(id: u64, bucket: usize) -> Request {
        req_at(id, bucket, Priority::Normal)
    }

    fn req_at(id: u64, bucket: usize, priority: Priority) -> Request {
        Request {
            id,
            content: vec![1],
            bucket,
            submitted: Instant::now(),
            deadline: None,
            priority,
            done: Completion::cell(OnceCellSync::new()),
        }
    }

    #[test]
    fn buckets_sort_dedup_and_pin_the_max() {
        let b = Buckets::new(&[64, 16, 16, 200, 0, 32], 128);
        assert_eq!(b.lens(), &[16, 32, 64, 128], "oversize and zero dropped, max appended");
        assert_eq!(b.max_len(), 128);
        assert_eq!(Buckets::single(16).lens(), &[16]);
        assert_eq!(Buckets::new(&[16], 16).lens(), &[16], "max-dup collapses");
    }

    #[test]
    fn index_for_picks_smallest_fitting_bucket() {
        let b = Buckets::new(&[16, 32, 64], 128);
        assert_eq!(b.index_for(1), Some(0));
        assert_eq!(b.index_for(16), Some(0));
        assert_eq!(b.index_for(17), Some(1));
        assert_eq!(b.index_for(64), Some(2));
        assert_eq!(b.index_for(65), Some(3));
        assert_eq!(b.index_for(128), Some(3));
        assert_eq!(b.index_for(129), None, "over the model max");
        assert_eq!(b.index_for(0), None, "empty rows have no bucket");
    }

    #[test]
    fn queues_route_by_bucket_and_report_the_deepest() {
        let q = BucketQueues::new(3, 8);
        assert!(q.deepest().is_none());
        q.send(req(1, 0)).unwrap();
        q.send(req(2, 2)).unwrap();
        q.send(req(3, 2)).unwrap();
        assert_eq!(q.len(), 3);
        assert_eq!((q.depth(0), q.depth(1), q.depth(2)), (1, 0, 2));
        assert_eq!(q.deepest(), Some(2));
        let mut out = Vec::new();
        assert_eq!(q.recv_wave(2, &mut out, 8, None), 2);
        assert_eq!(out.iter().map(|r| r.id).collect::<Vec<_>>(), vec![2, 3]);
        assert_eq!(q.deepest(), Some(0));
    }

    #[test]
    fn deepest_ties_break_toward_the_larger_bucket() {
        let q = BucketQueues::new(3, 8);
        q.send(req(1, 0)).unwrap();
        q.send(req(2, 1)).unwrap();
        assert_eq!(q.deepest(), Some(1), "equal depths pick the larger shape");
    }

    #[test]
    fn nonempty_from_scans_cyclically() {
        let q = BucketQueues::new(3, 8);
        assert_eq!(q.nonempty_from(0), None);
        q.send(req(1, 1)).unwrap();
        assert_eq!(q.nonempty_from(0), Some(1));
        assert_eq!(q.nonempty_from(1), Some(1));
        assert_eq!(q.nonempty_from(2), Some(1), "wraps past the end");
        q.send(req(2, 2)).unwrap();
        assert_eq!(q.nonempty_from(2), Some(2), "starts at the probe index");
    }

    #[test]
    fn waves_board_high_class_first_within_a_bucket() {
        let q = BucketQueues::new(2, 8);
        q.send(req_at(1, 1, Priority::Bulk)).unwrap();
        q.send(req_at(2, 1, Priority::High)).unwrap();
        q.send(req_at(3, 1, Priority::Normal)).unwrap();
        q.send(req_at(4, 1, Priority::High)).unwrap();
        assert_eq!(q.depth_at_or_above(Priority::High.index()), 2);
        assert_eq!(q.depth_at_or_above(Priority::Bulk.index()), 4);
        let mut out = Vec::new();
        assert_eq!(q.recv_wave(1, &mut out, 8, None), 4);
        assert_eq!(
            out.iter().map(|r| r.id).collect::<Vec<_>>(),
            vec![2, 4, 3, 1],
            "high first, then normal, then bulk; FIFO within a class"
        );
    }

    #[test]
    fn class_caps_isolate_admission_per_priority() {
        let q = BucketQueues::new(1, 1);
        q.send(req_at(1, 0, Priority::Bulk)).unwrap();
        assert!(
            matches!(q.try_send(req_at(2, 0, Priority::Bulk)), Err(TrySendError::Full(_))),
            "bulk is at its cap"
        );
        q.try_send(req_at(3, 0, Priority::High))
            .expect("a saturated bulk class must not consume high slots");
    }

    #[test]
    fn close_is_unit_wide_and_drain_any_sweeps_all_buckets() {
        let q = BucketQueues::new(2, 4);
        q.send(req(1, 0)).unwrap();
        q.send(req(2, 1)).unwrap();
        q.close();
        assert!(q.is_closed());
        assert!(q.send(req(3, 0)).is_err());
        let mut out = Vec::new();
        assert_eq!(q.try_recv_any(&mut out, 10), 2);
        assert_eq!(q.try_recv_any(&mut out, 10), 0);
    }
}
