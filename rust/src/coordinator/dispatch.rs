//! Work-stealing dispatch for the adaptive-N router.
//!
//! The pre-redesign `MuxRouter` pushed every arrival into one of several
//! fully independent coordinator lanes, which realized the paper's
//! adapt-N-to-load knob (§A3 / Fig 4c) as a *per-arrival* decision with
//! three bug classes: a full small-N lane rejected `QueueFull` while a
//! large-N sibling sat idle, a lane whose worker died kept receiving
//! traffic forever and answered `Shutdown`, and the all-lane depth sum
//! herded bursts onto the already-backlogged lane.
//!
//! This module inverts the data flow: **all submits enter one bounded
//! queue owned by the router** ([`DispatchState::queue`]), and each lane
//! *pulls* waves sized to its own `batch * n_mux` capacity
//! ([`run_pull_batcher`](super::batcher::run_pull_batcher)). `AdaptiveN`
//! is demoted from per-arrival chooser to a pull-gate: a lane only pulls
//! when the current backlog/rate justifies its N — small-N lanes serve
//! idle traffic, large-N lanes engage as the backlog grows, and any lane
//! may steal any request, so capacity anywhere means no rejects.
//!
//! Lane health: a lane whose worker fails is marked dead, stops pulling,
//! and its formed-but-unexecuted waves are returned to the shared queue
//! (or failed loudly) — never silently routed to again. Only when the
//! *last* lane dies is the shared queue closed and its backlog failed
//! with `Shutdown`.

use std::sync::atomic::{AtomicBool, AtomicU64, AtomicUsize, Ordering};
use std::sync::Arc;
use std::time::{Duration, Instant};

use anyhow::Result;

use crate::runtime::InferenceBackend;
use crate::tokenizer::Tokenizer;
use crate::util::sync::{rank, TrackedMutex};
use crate::util::threadpool::Channel;

use super::api::{BucketStatus, LaneStatus};
use super::batcher::{self, BatcherConfig, ExecBatch};
use super::buckets::{BucketQueues, Buckets};
use super::policy::AdaptiveN;
use super::request::Request;
use super::scheduler::{self, MuxTemplate, Stats};
use super::CoordinatorConfig;

/// How often a gated-off (or idle) lane re-checks the pull-gate and its
/// health flags. Bounds both gate responsiveness and shutdown latency;
/// well under any realistic model execution time.
pub(crate) const PULL_POLL: Duration = Duration::from_micros(500);

/// State shared by the router's admission path and every lane: the
/// single bounded admission queue, the adaptive-N pull-gate, and the
/// live-lane count that decides when `Shutdown` becomes the truth.
pub struct DispatchState {
    /// the one admission queue set all lanes pull from: one bounded
    /// FIFO per sequence-length bucket, requests routed by shape at
    /// admission so every stolen wave is shape-homogeneous
    pub queue: BucketQueues,
    gate: TrackedMutex<AdaptiveN>,
    epoch: Instant,
    live: AtomicUsize,
}

impl DispatchState {
    pub fn new(
        candidates: Vec<usize>,
        exec_time_us: f64,
        queue_cap: usize,
        n_buckets: usize,
    ) -> Self {
        let n_lanes = candidates.len();
        DispatchState {
            queue: BucketQueues::new(n_buckets, queue_cap),
            gate: TrackedMutex::new(
                "dispatch.gate",
                rank::DISPATCH_GATE,
                AdaptiveN::new(candidates, exec_time_us),
            ),
            epoch: Instant::now(),
            live: AtomicUsize::new(n_lanes),
        }
    }

    fn now_us(&self) -> u64 {
        self.epoch.elapsed().as_micros() as u64
    }

    /// Record one admission into the rate estimate.
    pub fn on_arrival(&self) {
        self.gate.lock().on_arrival(self.now_us());
    }

    /// Pull-gate decision for a lane multiplexing `lane_n` requests.
    /// Applies rate decay first, so a stale burst estimate cannot keep
    /// large lanes engaged on idle traffic.
    pub fn should_pull(&self, lane_n: usize) -> bool {
        let depth = self.queue.len();
        let mut g = self.gate.lock();
        g.decay(self.now_us());
        g.should_pull(lane_n, depth)
    }

    /// A lane died: retire its N from the candidate grid so the gate
    /// never targets it again. When the *last* lane dies, close the
    /// admission queue and fail its backlog — from here on submissions
    /// (and only from here on) answer `Shutdown`.
    pub fn lane_died(&self, lane_n: usize) {
        self.gate.lock().remove_candidate(lane_n);
        if self.live.fetch_sub(1, Ordering::AcqRel) == 1 {
            self.queue.close();
            // nobody will pull again: drain what was admitted (every
            // bucket), dropping each request so its completion guard
            // answers Shutdown
            let mut orphans: Vec<Request> = Vec::new();
            while self.queue.try_recv_any(&mut orphans, 64) > 0 {
                orphans.clear();
            }
        }
    }

    pub fn live_lanes(&self) -> usize {
        self.live.load(Ordering::Acquire)
    }
}

/// Per-lane health and dispatch counters.
#[derive(Default)]
pub struct LaneControl {
    /// set on the first worker failure; the puller stops immediately
    pub dead: AtomicBool,
    /// requests this lane returned to the shared queue when it died
    pub requeued: AtomicU64,
}

/// One serving lane of the work-stealing router: a pull-gated batcher
/// plus worker thread(s) over one `(N, batch)` backend. Unlike a
/// standalone [`MuxCoordinator`](super::MuxCoordinator), a lane owns no
/// admission queue — it pulls from [`DispatchState::queue`].
///
/// Failure bound: when the backend starts failing, each worker that is
/// *mid-execution* answers its batch `WorkerFailed` — so with
/// `n_workers` workers up to `n_workers` batches can fail before the
/// dead flag stops the lane (exactly one with the default single
/// worker, which is what the router-scaling bench and the engine tests
/// gate on). Batches still queued when the flag lands are re-queued,
/// never failed.
pub struct Lane {
    pub n_mux: usize,
    pub stats: Arc<Stats>,
    control: Arc<LaneControl>,
    puller: Option<std::thread::JoinHandle<u64>>,
    workers: Vec<std::thread::JoinHandle<()>>,
}

impl Lane {
    /// Spawn the lane's puller and workers against the shared dispatch
    /// state. `tokenizer` must agree with the router's (validated by the
    /// caller along with seq_len/task), and `buckets` is the router's
    /// shared bucket registry — the lane derives one template (and one
    /// worker scratch) per bucket, since any stolen wave arrives tagged
    /// with its bucket index.
    pub fn start(
        backend: Arc<dyn InferenceBackend>,
        cfg: &CoordinatorConfig,
        state: &Arc<DispatchState>,
        tokenizer: &Tokenizer,
        buckets: &Buckets,
    ) -> Result<Lane> {
        let meta = backend.meta().clone();
        let n_mux = meta.n_mux;
        let batch = meta.batch;
        let templates: Arc<Vec<MuxTemplate>> = Arc::new(
            buckets
                .lens()
                .iter()
                .map(|&l| MuxTemplate::for_bucket(&meta, tokenizer, l))
                .collect(),
        );
        let stats = Arc::new(Stats::for_buckets(buckets.lens()));
        let control = Arc::new(LaneControl::default());
        let n_workers = cfg.n_workers.max(1);
        // keep the exec buffer shallow: batches parked here cannot be
        // stolen by sibling lanes, only re-queued on death
        let exec: Channel<ExecBatch> = Channel::bounded(n_workers);
        let bcfg = BatcherConfig { n_mux, batch, max_wait: cfg.max_wait };

        let puller = {
            let state = state.clone();
            let exec = exec.clone();
            let control = control.clone();
            let stats = stats.clone();
            std::thread::Builder::new()
                .name(format!("datamux-lane{n_mux}-pull"))
                .spawn(move || {
                    let gate = || state.should_pull(n_mux);
                    batcher::run_pull_batcher(
                        &bcfg,
                        &state.queue,
                        &exec,
                        &control,
                        &gate,
                        PULL_POLL,
                        Some(&stats.counters),
                    )
                })?
        };

        let mut workers = Vec::with_capacity(n_workers);
        for w in 0..n_workers {
            let backend = backend.clone();
            let exec = exec.clone();
            let state = state.clone();
            let control = control.clone();
            let stats = stats.clone();
            let templates = templates.clone();
            let policy = cfg.slot_policy;
            workers.push(
                std::thread::Builder::new()
                    .name(format!("datamux-lane{n_mux}-exec-{w}"))
                    .spawn(move || {
                        // one pre-sized scratch per bucket: the
                        // scratch_reallocs == 0 invariant holds per shape
                        let mut scratch: Vec<Vec<i32>> = templates
                            .iter()
                            .map(|t| Vec::with_capacity(t.ids_len()))
                            .collect();
                        while let Some(b) = exec.recv() {
                            if control.dead.load(Ordering::Acquire) {
                                // a sibling worker failed while this
                                // batch sat queued: hand it back rather
                                // than executing against a dead backend
                                batcher::requeue_entries(
                                    &state.queue,
                                    b.entries,
                                    &control.requeued,
                                );
                                continue;
                            }
                            let bucket = b.bucket;
                            if let Err(e) = scheduler::execute_batch(
                                backend.as_ref(),
                                &templates[bucket],
                                policy,
                                &stats,
                                b,
                                &mut scratch[bucket],
                            ) {
                                // the failed batch's waiters were already
                                // answered WorkerFailed inside
                                // execute_batch. Mark the lane dead so it
                                // is never pulled for again, hand its
                                // formed-but-unexecuted waves back to the
                                // shared queue, and let siblings carry on.
                                eprintln!(
                                    "router lane N={n_mux} worker {w}: execution failed: \
                                     {e:#}; lane marked dead"
                                );
                                let first = !control.dead.swap(true, Ordering::AcqRel);
                                exec.close();
                                while let Some(stranded) = exec.try_recv() {
                                    batcher::requeue_entries(
                                        &state.queue,
                                        stranded.entries,
                                        &control.requeued,
                                    );
                                }
                                if first {
                                    state.lane_died(n_mux);
                                }
                                break;
                            }
                        }
                    })?,
            );
        }

        Ok(Lane { n_mux, stats, control, puller: Some(puller), workers })
    }

    pub fn alive(&self) -> bool {
        !self.control.dead.load(Ordering::Acquire)
    }

    pub fn status(&self) -> LaneStatus {
        let c = self.stats.counters.snapshot();
        LaneStatus {
            n_mux: self.n_mux,
            alive: self.alive(),
            pulls: c.batches_formed,
            requeued: self.control.requeued.load(Ordering::Relaxed),
            completed: c.completed,
            buckets: self
                .stats
                .bucket_snapshot()
                .into_iter()
                .map(|(seq_len, waves, entries)| BucketStatus { seq_len, waves, entries })
                .collect(),
        }
    }

    /// Join the lane's threads; returns the number of batches it formed.
    /// The caller must have closed (or drained) the shared queue first.
    pub(crate) fn join(&mut self) -> u64 {
        let batches = self.puller.take().map(|p| p.join().unwrap_or(0)).unwrap_or(0);
        for w in self.workers.drain(..) {
            let _ = w.join();
        }
        batches
    }
}

impl Drop for Lane {
    fn drop(&mut self) {
        self.join();
    }
}
