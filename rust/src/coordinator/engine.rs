//! [`EngineBuilder`]: one place to configure the whole serving stack.
//!
//! Unifies the knobs that used to be scattered across
//! `CoordinatorConfig`, `BatcherConfig` (derived from the model) and
//! `ServerConfig`, then builds whichever engine shape is wanted: a
//! single-model [`MuxCoordinator`], an adaptive-N [`MuxRouter`], or a
//! TCP [`Server`] over either.
//!
//! ```no_run
//! # use datamux::coordinator::EngineBuilder;
//! # use datamux::runtime::{ArtifactManifest, ModelRuntime, default_artifacts_dir};
//! # fn main() -> anyhow::Result<()> {
//! let manifest = ArtifactManifest::load(default_artifacts_dir())?;
//! let rt = ModelRuntime::cpu()?;
//! let engine = std::sync::Arc::new(
//!     EngineBuilder::new()
//!         .max_wait_ms(3)
//!         .queue_cap(4096)
//!         .build(rt.load(&manifest.artifacts[0])?)?,
//! );
//! let server = EngineBuilder::new().addr("127.0.0.1:7071").serve(engine)?;
//! # drop(server); Ok(()) }
//! ```

use std::sync::Arc;
use std::time::Duration;

use anyhow::Result;

use crate::runtime::native::Precision;
use crate::runtime::{ArtifactMeta, InferenceBackend, LoadedModel, NativeBackend};

use super::api::Submit;
use super::scheduler::SharedModel;
use super::server::{Server, ServerConfig};
use super::{CoordinatorConfig, MuxCoordinator, MuxRouter, SlotPolicy};

#[derive(Debug, Clone)]
pub struct EngineBuilder {
    coordinator: CoordinatorConfig,
    addr: String,
    max_connections: usize,
    read_timeout: Duration,
    max_line: usize,
    write_buf_cap: usize,
    /// model execute-time estimate driving adaptive-N routing (us)
    exec_time_us: f64,
    /// weight precision for native backends built via `build_native`
    precision: Precision,
}

impl Default for EngineBuilder {
    fn default() -> Self {
        let server = ServerConfig::default();
        EngineBuilder {
            coordinator: CoordinatorConfig::default(),
            addr: server.addr,
            max_connections: server.max_connections,
            read_timeout: server.read_timeout,
            max_line: server.max_line,
            write_buf_cap: server.write_buf_cap,
            exec_time_us: 20_000.0,
            precision: Precision::F32,
        }
    }
}

impl EngineBuilder {
    pub fn new() -> Self {
        Self::default()
    }

    /// Batcher deadline: how long the first request of a group waits for
    /// co-muxed peers.
    pub fn max_wait(mut self, d: Duration) -> Self {
        self.coordinator.max_wait = d;
        self
    }

    pub fn max_wait_ms(self, ms: u64) -> Self {
        self.max_wait(Duration::from_millis(ms))
    }

    /// Admission queue capacity (blocking senders beyond this).
    pub fn queue_cap(mut self, cap: usize) -> Self {
        self.coordinator.queue_cap = cap;
        self
    }

    /// Backend worker threads per coordinator.
    pub fn n_workers(mut self, n: usize) -> Self {
        self.coordinator.n_workers = n;
        self
    }

    /// Sequence-length buckets (e.g. `[32, 64]`). The model's seq_len is
    /// always the terminal bucket; lengths a backend cannot execute are
    /// dropped at engine start. Empty (the default) = pad-to-max.
    pub fn buckets(mut self, lens: Vec<usize>) -> Self {
        self.coordinator.buckets = lens;
        self
    }

    pub fn slot_policy(mut self, p: SlotPolicy) -> Self {
        self.coordinator.slot_policy = p;
        self
    }

    /// TCP bind address for `serve` (port 0 picks a free port).
    pub fn addr(mut self, addr: impl Into<String>) -> Self {
        self.addr = addr.into();
        self
    }

    pub fn max_connections(mut self, n: usize) -> Self {
        self.max_connections = n;
        self
    }

    /// Drain grace for `Server::stop()` and flush-closes.
    pub fn read_timeout(mut self, d: Duration) -> Self {
        self.read_timeout = d;
        self
    }

    /// Longest accepted request line (bytes); beyond it the client gets
    /// a typed `oversized_line` error and a disconnect.
    pub fn max_line(mut self, bytes: usize) -> Self {
        self.max_line = bytes;
        self
    }

    /// Per-connection write backlog allowed before a slow consumer is
    /// disconnected.
    pub fn write_buf_cap(mut self, bytes: usize) -> Self {
        self.write_buf_cap = bytes;
        self
    }

    /// Execute-time estimate (us) used by adaptive-N routing.
    pub fn exec_time_us(mut self, us: f64) -> Self {
        self.exec_time_us = us;
        self
    }

    /// Weight precision for native backends built through
    /// [`build_native`](Self::build_native): `F32` (default) or `Int8`
    /// (per-output-channel symmetric weights, dynamic per-row activation
    /// quantization).
    pub fn precision(mut self, p: Precision) -> Self {
        self.precision = p;
        self
    }

    pub fn coordinator_config(&self) -> &CoordinatorConfig {
        &self.coordinator
    }

    pub fn server_config(&self) -> ServerConfig {
        ServerConfig {
            addr: self.addr.clone(),
            max_connections: self.max_connections,
            read_timeout: self.read_timeout,
            max_line: self.max_line,
            write_buf_cap: self.write_buf_cap,
        }
    }

    /// One serving lane over a PJRT-loaded artifact.
    pub fn build(&self, model: LoadedModel) -> Result<MuxCoordinator> {
        MuxCoordinator::start(model, self.coordinator.clone())
    }

    /// One serving lane over any backend (e.g.
    /// [`FakeBackend`](crate::runtime::FakeBackend)).
    pub fn build_backend(&self, backend: Arc<dyn InferenceBackend>) -> Result<MuxCoordinator> {
        MuxCoordinator::start_backend(backend, self.coordinator.clone())
    }

    /// One serving lane over the pure-rust native forward
    /// ([`NativeBackend`]): real T-MUX math executed straight from the
    /// artifact's weights blob — no PJRT anywhere in the process.
    pub fn build_native(&self, meta: &ArtifactMeta) -> Result<MuxCoordinator> {
        self.build_backend(Arc::new(NativeBackend::from_artifact_prec(meta, self.precision)?))
    }

    /// Adaptive-N router: one work-stealing lane per model (paper's
    /// A3-style knob) pulling from a single shared admission queue of
    /// `queue_cap` requests.
    pub fn build_router(&self, models: Vec<LoadedModel>) -> Result<MuxRouter> {
        let mut backends: Vec<Arc<dyn InferenceBackend>> = Vec::with_capacity(models.len());
        for m in models {
            backends.push(Arc::new(SharedModel(Arc::new(m))));
        }
        self.build_router_backends(backends)
    }

    /// Adaptive-N router over arbitrary backends (PJRT, native, fake).
    pub fn build_router_backends(
        &self,
        backends: Vec<Arc<dyn InferenceBackend>>,
    ) -> Result<MuxRouter> {
        MuxRouter::start_backends(backends, self.coordinator.clone(), self.exec_time_us)
    }

    /// TCP front end over any engine (coordinator or router).
    pub fn serve(&self, engine: Arc<dyn Submit>) -> Result<Server> {
        Server::start(engine, self.server_config())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::FakeBackend;

    #[test]
    fn builder_knobs_land_in_configs() {
        let b = EngineBuilder::new()
            .max_wait_ms(7)
            .queue_cap(32)
            .n_workers(2)
            .slot_policy(SlotPolicy::RotateOffset)
            .buckets(vec![8, 16])
            .addr("127.0.0.1:0")
            .max_connections(3)
            .read_timeout(Duration::from_millis(50))
            .max_line(512)
            .write_buf_cap(4096)
            .exec_time_us(123.0);
        assert_eq!(b.coordinator_config().max_wait, Duration::from_millis(7));
        assert_eq!(b.coordinator_config().queue_cap, 32);
        assert_eq!(b.coordinator_config().n_workers, 2);
        assert_eq!(b.coordinator_config().slot_policy, SlotPolicy::RotateOffset);
        assert_eq!(b.coordinator_config().buckets, vec![8, 16]);
        let s = b.server_config();
        assert_eq!(s.addr, "127.0.0.1:0");
        assert_eq!(s.max_connections, 3);
        assert_eq!(s.read_timeout, Duration::from_millis(50));
        assert_eq!(s.max_line, 512);
        assert_eq!(s.write_buf_cap, 4096);
    }

    #[test]
    fn builds_coordinator_and_router_over_fake_backends() {
        let b = EngineBuilder::new().max_wait_ms(0);
        let coord = b
            .build_backend(Arc::new(FakeBackend::new("cls", 2, 1, 8, 3)))
            .expect("coordinator over fake backend");
        assert_eq!(coord.n_mux, 2);
        drop(coord);
        let router = b
            .build_router_backends(vec![
                Arc::new(FakeBackend::new("cls", 2, 1, 8, 3)),
                Arc::new(FakeBackend::new("cls", 8, 1, 8, 3)),
            ])
            .expect("router over fake backends");
        let lanes = router.lane_status();
        assert_eq!(lanes.len(), 2);
        assert_eq!(lanes[0].n_mux, 2, "lanes sorted ascending by N");
        assert!(lanes.iter().all(|l| l.alive), "all lanes start alive");
        assert_eq!(router.live_lanes(), 2);
    }

    #[test]
    fn builds_coordinator_over_native_backend() {
        let native = NativeBackend::random("cls", 2, 1, 8, 16, 1, 2, 3, 3).unwrap();
        let coord = EngineBuilder::new()
            .max_wait_ms(0)
            .build_backend(Arc::new(native))
            .expect("coordinator over native backend");
        assert_eq!(coord.n_mux, 2);
        let mut row = vec![0i32; 8];
        row[0] = 1; // [CLS]
        row[1] = 44; // t0
        let h = coord.submit_framed(row).expect("submit");
        let r = h.wait().expect("real math round-trips the coordinator");
        assert!(r.pred_class() < 3);
        assert_eq!(r.logits.len(), 3);
    }

    #[test]
    fn bucketed_coordinator_serves_short_rows_and_reports_buckets() {
        let coord = EngineBuilder::new()
            .max_wait_ms(0)
            .buckets(vec![4, 2])
            .build_backend(Arc::new(FakeBackend::new("cls", 2, 1, 8, 3)))
            .unwrap();
        assert_eq!(coord.buckets(), vec![2, 4, 8], "sorted + terminal max bucket");
        // a 3-token unpadded row lands in the 4-bucket
        let h = coord.submit_framed(vec![1, 45, 2]).expect("short rows are admissible");
        let r = h.wait().expect("served");
        assert_eq!(r.pred_class(), (1 + 45 + 2) % 3, "unpadded row predicts like padded");
        let lanes = coord.lane_status();
        let b = &lanes[0].buckets;
        assert_eq!(b.iter().map(|x| x.seq_len).collect::<Vec<_>>(), vec![2, 4, 8]);
        assert_eq!(b[1].waves, 1, "the 4-bucket executed the wave");
        assert_eq!(b[1].entries, 1);
        assert_eq!(b[0].waves + b[2].waves, 0, "other buckets untouched");
        // bucketed tokens_padded: capacity 2 * bucket 4 - 3 carried = 5
        assert_eq!(coord.counters().tokens_padded, 5);
        // over-length and empty rows are typed errors
        use crate::coordinator::api::SubmitError;
        match coord.submit_framed(vec![1; 9]).err() {
            Some(SubmitError::TooLong { got: 9, max: 8 }) => {}
            other => panic!("expected TooLong, got {other:?}"),
        }
        match coord.submit_framed(Vec::new()).err() {
            Some(SubmitError::BadFrame { got: 0, .. }) => {}
            other => panic!("expected BadFrame, got {other:?}"),
        }
    }

    #[test]
    fn pjrt_style_backend_degrades_to_pad_to_max() {
        /// A backend that (like PJRT) only executes its baked shape.
        struct BakedShape(FakeBackend);
        impl crate::runtime::InferenceBackend for BakedShape {
            fn meta(&self) -> &crate::runtime::ArtifactMeta {
                self.0.meta()
            }
            fn run_ids(&self, ids: &[i32]) -> anyhow::Result<Vec<f32>> {
                self.0.run_ids(ids)
            }
            // default supports_seq_len / run_ids_at: baked shape only
        }
        let coord = EngineBuilder::new()
            .max_wait_ms(0)
            .buckets(vec![2, 4])
            .build_backend(Arc::new(BakedShape(FakeBackend::new("cls", 2, 1, 8, 3))))
            .unwrap();
        assert_eq!(coord.buckets(), vec![8], "requested buckets dropped, terminal kept");
        let h = coord.submit_framed(vec![1, 45, 2]).expect("short rows still admissible");
        assert_eq!(h.wait().expect("served").pred_class(), (1 + 45 + 2) % 3);
    }

    #[test]
    fn router_rejects_mismatched_lanes() {
        let b = EngineBuilder::new().max_wait_ms(0);
        let r = b.build_router_backends(vec![
            Arc::new(FakeBackend::new("cls", 2, 1, 8, 3)),
            Arc::new(FakeBackend::new("cls", 4, 1, 16, 3)), // different seq_len
        ]);
        assert!(r.is_err(), "construct-time validation must reject");
        let r = b.build_router_backends(vec![]);
        assert!(r.is_err(), "empty router must be rejected");
    }
}
