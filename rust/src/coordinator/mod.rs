//! L3 coordinator — the DataMUX serving engine.
//!
//! ```text
//!  MuxCoordinator (one model):
//!  Submit::submit() ──▶ [bucket queues] ──▶ batcher thread ──▶ [exec queue]
//!                                                                 │
//!                                              worker thread(s) ◀─┘
//!                                                assemble ids → backend execute
//!                                                → demux → fulfill completions
//!
//!  MuxRouter (adaptive N, work-stealing):
//!  Submit::submit() ──▶ [shared bucket queues] ◀── pull ── lane N=2  ──▶ exec
//!                                              ◀── pull ── lane N=20 ──▶ exec
//!                        (AdaptiveN pull-gate: a lane pulls only when
//!                         backlog/rate justifies its N; dead lanes stop
//!                         pulling and hand their waves back)
//! ```
//!
//! Admission is sequence-length-bucketed ([`buckets`]): a request is
//! admitted unpadded, routed to the queue of the smallest bucket that
//! fits it, and every formed wave is shape-homogeneous — the backend
//! executes at the bucket's runtime shape, not the compile-time max.
//!
//! The coordinator owns one [`InferenceBackend`] (usually an
//! AOT-compiled `(profile, N, batch)` artifact behind PJRT) plus the
//! batcher/worker threads. [`MuxRouter`] owns one shared admission
//! queue and a set of lanes (one per N candidate) that *pull* work from
//! it (see [`dispatch`]). Both implement the [`Submit`] trait, so every
//! consumer — the TCP server, the workload drivers, benches and
//! examples — is generic over which one it talks to.

pub mod api;
pub mod batcher;
pub mod buckets;
pub mod dispatch;
pub mod engine;
pub mod policy;
pub mod pool;
pub mod reactor;
pub mod request;
pub mod scheduler;
pub mod server;
pub mod shards;

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Arc;
use std::time::{Duration, Instant};

use anyhow::Result;

use crate::runtime::{InferenceBackend, LoadedModel};
use crate::tokenizer::{TokenizeError, Tokenizer};
use crate::util::metrics::{CounterSnapshot, LatencySummary};
use crate::util::sync::{rank, TrackedMutex};
use crate::util::threadpool::{Channel, OnceCellSync, TrySendError};

pub use api::{
    BucketStatus, ClassStatus, CompletionItem, CompletionQueue, InferenceRequest, LaneStatus,
    Payload, Priority, ShardState, ShardStatus, Submit, SubmitError, TaskKind,
    N_PRIORITY_CLASSES,
};
pub use batcher::{BatcherConfig, ExecBatch};
pub use buckets::{BucketQueues, Buckets};
pub use dispatch::{DispatchState, Lane};
pub use engine::EngineBuilder;
pub use policy::{AdaptiveN, SlotPolicy};
pub use pool::{FaultInjector, FaultPlan};
pub use request::{EngineError, LogitsView, Request, RequestHandle, Response};
pub use scheduler::{ClassTally, MuxTemplate, SharedModel, Stats};
pub use shards::{Placement, ShardConfig, ShardRouter};

#[derive(Debug, Clone)]
pub struct CoordinatorConfig {
    /// max time the first request of a batch waits for co-muxed peers
    pub max_wait: Duration,
    /// admission queue capacity **per bucket** (senders block beyond
    /// this — backpressure; per-shape head-of-line isolation)
    pub queue_cap: usize,
    /// backend worker threads (CPU plugin: 1 is usually right on 1 core)
    pub n_workers: usize,
    pub slot_policy: SlotPolicy,
    /// requested sequence-length buckets (e.g. `[32, 64]`); the model's
    /// seq_len is always appended as the terminal bucket, and lengths
    /// the backend cannot execute (shape-baked PJRT) are dropped with a
    /// notice. Empty = pad-to-max, the pre-bucket behavior.
    pub buckets: Vec<usize>,
}

impl Default for CoordinatorConfig {
    fn default() -> Self {
        CoordinatorConfig {
            max_wait: Duration::from_millis(5),
            queue_cap: 1024,
            n_workers: 1,
            slot_policy: SlotPolicy::Fill,
            buckets: Vec::new(),
        }
    }
}

/// Resolve the effective bucket registry for a set of backends: the
/// requested lengths each backend can execute, plus the mandatory
/// terminal `seq_len` bucket. Unsupported requests are dropped loudly
/// (stderr), not errors — a PJRT artifact simply serves pad-to-max.
fn resolve_buckets(
    requested: &[usize],
    seq_len_max: usize,
    backends: &[Arc<dyn InferenceBackend>],
) -> Buckets {
    let supported: Vec<usize> = requested
        .iter()
        .copied()
        .filter(|&l| {
            let ok = (1..=seq_len_max).contains(&l)
                && backends.iter().all(|b| b.supports_seq_len(l));
            if !ok {
                eprintln!(
                    "datamux: dropping requested bucket {l} (backend only executes \
                     1..={seq_len_max} or is shape-baked)"
                );
            }
            ok
        })
        .collect();
    Buckets::new(&supported, seq_len_max)
}

/// Validate a typed request against an engine's (task, buckets) and
/// frame its payload — the shared admission front half of both
/// [`MuxCoordinator`] and [`MuxRouter`]. Returns the **unpadded**
/// content row, its bucket index, the absolute deadline, and the
/// request's priority class. A deadline that has already expired
/// (relative duration zero) is rejected here with
/// [`SubmitError::Expired`] — the engine never queues provably-dead
/// work only to sweep it at batch assembly.
fn prepare_request(
    tokenizer: &Tokenizer,
    buckets: &Buckets,
    task: TaskKind,
    req: InferenceRequest,
) -> Result<(Vec<i32>, usize, Option<Instant>, Priority), SubmitError> {
    if req.task != task {
        return Err(SubmitError::WrongTask { requested: req.task, served: task });
    }
    if req.deadline.is_some_and(|d| d.is_zero()) {
        return Err(SubmitError::Expired);
    }
    let max = buckets.max_len();
    let content = match req.payload {
        Payload::Framed(ids) => {
            if ids.is_empty() {
                return Err(SubmitError::BadFrame { expected: max, got: 0 });
            }
            if ids.len() > max {
                return Err(SubmitError::TooLong { got: ids.len(), max });
            }
            ids
        }
        Payload::Text(text) => tokenizer
            .encode_framed_unpadded(&text.split(" [SEP] ").collect::<Vec<_>>(), max)
            .map_err(|e| match e {
                TokenizeError::TooLong { got, max } => SubmitError::TooLong { got, max },
                other => SubmitError::Tokenize(other.to_string()),
            })?,
    };
    // the length was validated against the terminal bucket above, so a
    // miss here means the registry itself is inconsistent — surface it as
    // a typed reject rather than a panic on the serving path
    let bucket = match buckets.index_for(content.len()) {
        Some(b) => b,
        None => return Err(SubmitError::TooLong { got: content.len(), max }),
    };
    let deadline = req.deadline.map(|d| Instant::now() + d);
    Ok((content, bucket, deadline, req.priority))
}

/// Below this completions/sec estimate the [`DrainMeter`] is considered
/// cold (engine idle or just started) and the overload check admits
/// everything — shedding must never fire on a warming engine, or
/// sub-capacity traffic would see spurious rejects.
const MIN_DRAIN_RATE: f64 = 1.0;

/// A deadline is only declared unmeetable when the estimated queue wait
/// exceeds the remaining budget by this factor. >1 keeps the check
/// conservative: "provably cannot be met", not "might be tight".
const OVERLOAD_MARGIN: f64 = 2.0;

/// Completion-rate estimator feeding deadline-aware admission shedding.
/// Sampled at submit time from the engine's cumulative `completed`
/// counter; windows shorter than 50ms are ignored so per-request calls
/// stay cheap and the EWMA is not dominated by timer noise.
struct DrainMeter {
    inner: TrackedMutex<DrainWindow>,
}

struct DrainWindow {
    last_completed: u64,
    last_at: Instant,
    /// completions/sec EWMA; 0.0 until the first window closes
    rate: f64,
}

impl DrainMeter {
    fn new() -> Self {
        DrainMeter {
            inner: TrackedMutex::new(
                "engine.drain_meter",
                rank::DISPATCH_GATE,
                DrainWindow { last_completed: 0, last_at: Instant::now(), rate: 0.0 },
            ),
        }
    }

    /// Update with the cumulative completion count; returns the current
    /// completions/sec estimate (0.0 while cold).
    fn observe(&self, completed: u64) -> f64 {
        let mut w = self.inner.lock();
        let dt = w.last_at.elapsed();
        if dt >= Duration::from_millis(50) {
            let inst = completed.saturating_sub(w.last_completed) as f64 / dt.as_secs_f64();
            w.rate = if w.rate == 0.0 { inst } else { 0.7 * w.rate + 0.3 * inst };
            w.last_completed = completed;
            w.last_at = Instant::now();
        }
        w.rate
    }
}

/// Deadline-aware admission check (requests without a deadline always
/// pass). `Expired` when the absolute deadline has already passed;
/// `Overloaded` when the queue depth at or above the request's class,
/// divided by the measured drain rate, provably exceeds the remaining
/// budget (with [`OVERLOAD_MARGIN`] headroom). The caller records the
/// shed in its per-class tallies.
fn admission_check(
    meter: &DrainMeter,
    completed: u64,
    depth_ahead: usize,
    deadline: Option<Instant>,
) -> Result<(), SubmitError> {
    let Some(dl) = deadline else { return Ok(()) };
    let remaining = dl.saturating_duration_since(Instant::now());
    if remaining.is_zero() {
        return Err(SubmitError::Expired);
    }
    let rate = meter.observe(completed);
    if rate >= MIN_DRAIN_RATE && depth_ahead as f64 / rate > remaining.as_secs_f64() * OVERLOAD_MARGIN
    {
        return Err(SubmitError::Overloaded);
    }
    Ok(())
}

/// Record a shed admission (`Expired` / `Overloaded`) in the right
/// per-class tally, passing the error through. Other submit errors
/// (validation failures) pass through untallied.
fn note_shed(stats: &Stats, priority: Priority, err: SubmitError) -> SubmitError {
    let t = &stats.per_class[priority.index()];
    match err {
        SubmitError::Expired => {
            t.shed_expired.fetch_add(1, Ordering::Relaxed);
        }
        SubmitError::Overloaded => {
            t.shed_overloaded.fetch_add(1, Ordering::Relaxed);
        }
        _ => {}
    }
    err
}

/// The serving engine for one loaded model.
pub struct MuxCoordinator {
    input: BucketQueues,
    pub stats: Arc<Stats>,
    pub tokenizer: Tokenizer,
    pub n_mux: usize,
    pub seq_len: usize,
    n_classes: usize,
    buckets: Buckets,
    task: TaskKind,
    /// captured at start: the backend's one-line self-description
    /// (surfaced by [`Submit::backend_info`])
    backend_desc: String,
    /// retained handle to the running backend so live execution detail
    /// (per-stage timers) can be snapshotted by [`Submit::backend_stage_ns`]
    backend: Arc<dyn InferenceBackend>,
    next_id: AtomicU64,
    drain: DrainMeter,
    batcher: Option<std::thread::JoinHandle<u64>>,
    workers: Vec<std::thread::JoinHandle<()>>,
}

impl MuxCoordinator {
    /// Start over a PJRT-loaded artifact (the production path).
    pub fn start(model: LoadedModel, cfg: CoordinatorConfig) -> Result<Self> {
        Self::start_backend(Arc::new(SharedModel(Arc::new(model))), cfg)
    }

    /// Start over any [`InferenceBackend`] (PJRT model, native, fake...).
    pub fn start_backend(
        backend: Arc<dyn InferenceBackend>,
        cfg: CoordinatorConfig,
    ) -> Result<Self> {
        let meta = backend.meta().clone();
        let backend_desc = backend.describe();
        let task = TaskKind::from_model_task(&meta.task)
            .ok_or_else(|| anyhow::anyhow!("unsupported serving task '{}'", meta.task))?;
        let tokenizer =
            Tokenizer::new(crate::tokenizer::default_vocab(), meta.vocab_size);
        let n_mux = meta.n_mux;
        let seq_len = meta.seq_len;
        let buckets =
            resolve_buckets(&cfg.buckets, seq_len, std::slice::from_ref(&backend));
        let stats = Arc::new(Stats::for_buckets(buckets.lens()));
        let input = BucketQueues::new(buckets.count(), cfg.queue_cap);
        let exec: Channel<ExecBatch> = Channel::bounded(cfg.n_workers * 2 + 2);

        // derive each bucket's empty-slot ids tensor once; workers
        // bulk-copy the right one per batch instead of re-deriving pad
        // rows and prefixes
        let templates: Arc<Vec<MuxTemplate>> = Arc::new(
            buckets
                .lens()
                .iter()
                .map(|&l| scheduler::MuxTemplate::for_bucket(&meta, &tokenizer, l))
                .collect(),
        );

        let bcfg = BatcherConfig { n_mux, batch: meta.batch, max_wait: cfg.max_wait };
        let b_in = input.clone();
        let b_out = exec.clone();
        let b_stats = stats.clone();
        let batcher = std::thread::Builder::new()
            .name("datamux-batcher".into())
            .spawn(move || {
                batcher::run_batcher(&bcfg, &b_in, &b_out, Some(&b_stats.counters))
            })?;

        let mut workers = Vec::new();
        for w in 0..cfg.n_workers.max(1) {
            let backend = backend.clone();
            let exec = exec.clone();
            let input = input.clone();
            let stats = stats.clone();
            let templates = templates.clone();
            let policy = cfg.slot_policy;
            workers.push(
                std::thread::Builder::new()
                    .name(format!("datamux-exec-{w}"))
                    .spawn(move || {
                        // worker-owned scratch, one per bucket, reused
                        // across batches; pre-sized so steady state never
                        // reallocates (the invariant holds per shape)
                        let mut scratch: Vec<Vec<i32>> = templates
                            .iter()
                            .map(|t| Vec::with_capacity(t.ids_len()))
                            .collect();
                        while let Some(batch) = exec.recv() {
                            let bucket = batch.bucket;
                            if let Err(e) = scheduler::execute_batch(
                                backend.as_ref(),
                                &templates[bucket],
                                policy,
                                &stats,
                                batch,
                                &mut scratch[bucket],
                            ) {
                                // the failed batch's waiters were already
                                // fulfilled with WorkerFailed inside
                                // execute_batch; poison the intake so new
                                // submissions fail fast with Shutdown, then
                                // keep draining so queued waiters are
                                // answered (not stranded) too.
                                eprintln!("worker {w}: execution failed: {e:#}");
                                input.close();
                            }
                        }
                    })?,
            );
        }

        Ok(MuxCoordinator {
            input,
            stats,
            tokenizer,
            n_mux,
            seq_len,
            n_classes: meta.n_classes,
            buckets,
            task,
            backend_desc,
            backend,
            next_id: AtomicU64::new(1),
            drain: DrainMeter::new(),
            batcher: Some(batcher),
            workers,
        })
    }

    /// Validate a typed request and frame its payload (unpadded) into
    /// its sequence-length bucket, then run the deadline-aware admission
    /// check (expired / unmeetable deadlines are shed here with a typed
    /// error, tallied per class).
    fn prepare(
        &self,
        req: InferenceRequest,
    ) -> Result<(Vec<i32>, usize, Option<Instant>, Priority), SubmitError> {
        let priority = req.priority;
        let parts = prepare_request(&self.tokenizer, &self.buckets, self.task, req)
            .map_err(|e| note_shed(&self.stats, priority, e))?;
        let completed = self.stats.counters.completed.load(Ordering::Relaxed);
        let ahead = self.input.depth_at_or_above(priority.index());
        admission_check(&self.drain, completed, ahead, parts.2)
            .map_err(|e| note_shed(&self.stats, priority, e))?;
        Ok(parts)
    }

    fn make_request(
        &self,
        content: Vec<i32>,
        bucket: usize,
        deadline: Option<Instant>,
        priority: Priority,
        done: request::Completion,
    ) -> Request {
        let id = self.next_id.fetch_add(1, Ordering::Relaxed);
        Request { id, content, bucket, submitted: Instant::now(), deadline, priority, done }
    }

    /// Blocking admission (backpressure); `Shutdown` when the intake is
    /// closed. Shared counter discipline for every submit flavor.
    fn admit_blocking(&self, req: Request) -> Result<(), SubmitError> {
        if self.input.send(req).is_err() {
            self.stats.counters.rejected.fetch_add(1, Ordering::Relaxed);
            // the dropped request already fulfilled its completion with
            // Shutdown; the caller also gets the error synchronously
            return Err(SubmitError::Shutdown);
        }
        self.stats.counters.submitted.fetch_add(1, Ordering::Relaxed);
        Ok(())
    }

    /// Non-blocking admission; distinguishes `QueueFull` from `Shutdown`
    /// and defuses the handed-back request's completion (the failure is
    /// reported synchronously instead).
    fn admit_nonblocking(&self, req: Request) -> Result<(), SubmitError> {
        match self.input.try_send(req) {
            Ok(()) => {
                self.stats.counters.submitted.fetch_add(1, Ordering::Relaxed);
                Ok(())
            }
            Err(err) => {
                self.stats.counters.rejected.fetch_add(1, Ordering::Relaxed);
                let submit_err = match &err {
                    TrySendError::Full(_) => SubmitError::QueueFull,
                    TrySendError::Closed(_) => SubmitError::Shutdown,
                };
                let mut req = err.into_inner();
                req.done.defuse();
                Err(submit_err)
            }
        }
    }

    /// Stop accepting new requests; everything already admitted still
    /// completes. Submissions return [`SubmitError::Shutdown`] from now
    /// on.
    pub fn close_intake(&self) {
        self.input.close();
    }

    pub fn queue_depth(&self) -> usize {
        self.input.len()
    }

    /// Drain and stop. All in-flight requests are completed first.
    pub fn shutdown(mut self) -> u64 {
        self.input.close();
        let batches = self.batcher.take().map(|b| b.join().unwrap_or(0)).unwrap_or(0);
        for w in self.workers.drain(..) {
            let _ = w.join();
        }
        batches
    }
}

impl Submit for MuxCoordinator {
    fn submit(&self, req: InferenceRequest) -> Result<RequestHandle, SubmitError> {
        let (content, bucket, deadline, priority) = self.prepare(req)?;
        let cell = OnceCellSync::new();
        let req = self.make_request(
            content,
            bucket,
            deadline,
            priority,
            request::Completion::cell(cell.clone()),
        );
        let handle = RequestHandle { id: req.id, deadline, done: cell };
        self.admit_blocking(req)?;
        Ok(handle)
    }

    fn try_submit(&self, req: InferenceRequest) -> Result<RequestHandle, SubmitError> {
        let (content, bucket, deadline, priority) = self.prepare(req)?;
        let cell = OnceCellSync::new();
        let req = self.make_request(
            content,
            bucket,
            deadline,
            priority,
            request::Completion::cell(cell.clone()),
        );
        let handle = RequestHandle { id: req.id, deadline, done: cell };
        self.admit_nonblocking(req)?;
        Ok(handle)
    }

    fn submit_tagged(
        &self,
        req: InferenceRequest,
        tag: u64,
        out: &CompletionQueue,
    ) -> Result<(), SubmitError> {
        let (content, bucket, deadline, priority) = self.prepare(req)?;
        let req = self.make_request(
            content,
            bucket,
            deadline,
            priority,
            request::Completion::queue(tag, out.clone()),
        );
        self.admit_nonblocking(req)
    }

    fn native_task(&self) -> TaskKind {
        self.task
    }

    fn tokenizer(&self) -> &Tokenizer {
        &self.tokenizer
    }

    fn seq_len(&self) -> usize {
        self.seq_len
    }

    fn n_classes(&self) -> usize {
        self.n_classes
    }

    fn buckets(&self) -> Vec<usize> {
        self.buckets.lens().to_vec()
    }

    fn queue_depth(&self) -> usize {
        self.input.len()
    }

    fn counters(&self) -> CounterSnapshot {
        self.stats.counters.snapshot()
    }

    fn latency(&self) -> LatencySummary {
        self.stats.e2e_latency.summary()
    }

    fn queue_wait(&self) -> LatencySummary {
        self.stats.queue_wait.summary()
    }

    fn lane_status(&self) -> Vec<LaneStatus> {
        let c = self.stats.counters.snapshot();
        vec![LaneStatus {
            n_mux: self.n_mux,
            // worker death poisons the intake, so a closed input channel
            // is exactly "this lane no longer takes work"
            alive: !self.input.is_closed(),
            pulls: c.batches_formed,
            requeued: 0,
            completed: c.completed,
            buckets: self
                .stats
                .bucket_snapshot()
                .into_iter()
                .map(|(seq_len, waves, entries)| BucketStatus { seq_len, waves, entries })
                .collect(),
        }]
    }

    fn class_status(&self) -> Vec<ClassStatus> {
        let mut classes = self.stats.class_snapshot();
        for c in &mut classes {
            c.depth = self.input.depth_class(c.priority.index());
        }
        classes
    }

    fn backend_info(&self) -> Vec<String> {
        vec![self.backend_desc.clone()]
    }

    fn backend_stage_ns(&self) -> Vec<Vec<(&'static str, u64)>> {
        vec![self.backend.stage_ns()]
    }
}

impl Drop for MuxCoordinator {
    fn drop(&mut self) {
        self.input.close();
        if let Some(b) = self.batcher.take() {
            let _ = b.join();
        }
        for w in self.workers.drain(..) {
            let _ = w.join();
        }
    }
}

/// Adaptive-N router: one **shared bounded admission queue** feeding a
/// set of work-stealing lanes (one per N candidate).
///
/// Every submit enters the shared queue; each lane pulls waves sized to
/// its own `batch * n_mux` capacity, gated by [`AdaptiveN`] (see
/// [`dispatch`]). Consequences the per-arrival design could not offer:
///
/// * `try_submit` only reports `QueueFull` when the *router* is full —
///   a burst can never be rejected while any lane has spare capacity.
/// * A lane whose worker dies stops pulling and hands its unexecuted
///   waves back to the shared queue for the surviving lanes; it is
///   never routed to again.
/// * `Shutdown` is only reported once **all** lanes are dead (or the
///   intake was explicitly closed).
pub struct MuxRouter {
    state: Arc<DispatchState>,
    /// ascending by n_mux; all lanes share seq_len, task and vocabulary
    lanes: Vec<Lane>,
    /// admission-side counters (submitted / rejected); execution-side
    /// counters accumulate in each lane's stats
    pub stats: Arc<Stats>,
    tokenizer: Tokenizer,
    seq_len: usize,
    n_classes: usize,
    buckets: Buckets,
    task: TaskKind,
    /// one description per lane backend, captured at start and ascending
    /// by n_mux (surfaced by [`Submit::backend_info`])
    backend_descs: Vec<String>,
    /// retained lane backend handles, same order as `backend_descs`, so
    /// live per-stage timers flow out via [`Submit::backend_stage_ns`]
    backend_handles: Vec<Arc<dyn InferenceBackend>>,
    next_id: AtomicU64,
    drain: DrainMeter,
}

impl MuxRouter {
    /// Start a router over one backend per lane.
    ///
    /// Construct-time validation pins the dispatch invariant: the
    /// adaptive-N candidate grid is exactly the set of lane Ns, and all
    /// lanes agree on seq_len, task and vocabulary, so any admitted
    /// request is valid on whichever lane steals it.
    pub fn start_backends(
        backends: Vec<Arc<dyn InferenceBackend>>,
        cfg: CoordinatorConfig,
        exec_time_us: f64,
    ) -> Result<Self> {
        anyhow::ensure!(!backends.is_empty(), "MuxRouter needs at least one lane");
        let mut backends = backends;
        backends.sort_by_key(|b| b.meta().n_mux);
        let m0 = backends[0].meta().clone();
        let task = TaskKind::from_model_task(&m0.task)
            .ok_or_else(|| anyhow::anyhow!("unsupported serving task '{}'", m0.task))?;
        for b in &backends {
            let m = b.meta();
            anyhow::ensure!(
                m.seq_len == m0.seq_len && m.task == m0.task && m.vocab_size == m0.vocab_size,
                "router lanes must agree on seq_len/task/vocab: lane N={} has (seq_len={}, \
                 task={}, vocab={}), expected (seq_len={}, task={}, vocab={})",
                m.n_mux,
                m.seq_len,
                m.task,
                m.vocab_size,
                m0.seq_len,
                m0.task,
                m0.vocab_size
            );
        }
        let tokenizer = Tokenizer::new(crate::tokenizer::default_vocab(), m0.vocab_size);
        // a bucket is only usable if EVERY lane can execute it (any lane
        // may steal any wave); the terminal max bucket always is
        let buckets = resolve_buckets(&cfg.buckets, m0.seq_len, &backends);
        let candidates: Vec<usize> = backends.iter().map(|b| b.meta().n_mux).collect();
        let state = Arc::new(DispatchState::new(
            candidates,
            exec_time_us,
            cfg.queue_cap,
            buckets.count(),
        ));
        let backend_descs: Vec<String> = backends.iter().map(|b| b.describe()).collect();
        let backend_handles: Vec<Arc<dyn InferenceBackend>> = backends.to_vec();
        let lanes = backends
            .into_iter()
            .map(|b| Lane::start(b, &cfg, &state, &tokenizer, &buckets))
            .collect::<Result<Vec<_>>>()?;
        Ok(MuxRouter {
            state,
            lanes,
            stats: Arc::new(Stats::default()),
            tokenizer,
            seq_len: m0.seq_len,
            n_classes: m0.n_classes,
            buckets,
            task,
            backend_descs,
            backend_handles,
            next_id: AtomicU64::new(1),
            drain: DrainMeter::new(),
        })
    }

    /// Lanes still pulling work.
    pub fn live_lanes(&self) -> usize {
        self.state.live_lanes()
    }

    /// Stop accepting new requests; everything already admitted still
    /// completes on whatever lanes remain.
    pub fn close_intake(&self) {
        self.state.queue.close();
    }

    /// Shared admission into the one queue; counter discipline matches
    /// the coordinator's (`submitted` on accept, `rejected` otherwise).
    fn admit(&self, req: Request, blocking: bool) -> Result<(), SubmitError> {
        self.state.on_arrival();
        let outcome = if blocking {
            // the dropped request already fulfilled its completion with
            // Shutdown; the caller also gets the error synchronously
            self.state.queue.send(req).map_err(|_| SubmitError::Shutdown)
        } else {
            match self.state.queue.try_send(req) {
                Ok(()) => Ok(()),
                Err(err) => {
                    let submit_err = match &err {
                        TrySendError::Full(_) => SubmitError::QueueFull,
                        TrySendError::Closed(_) => SubmitError::Shutdown,
                    };
                    let mut req = err.into_inner();
                    req.done.defuse();
                    Err(submit_err)
                }
            }
        };
        match outcome {
            Ok(()) => {
                self.stats.counters.submitted.fetch_add(1, Ordering::Relaxed);
                Ok(())
            }
            Err(e) => {
                self.stats.counters.rejected.fetch_add(1, Ordering::Relaxed);
                Err(e)
            }
        }
    }

    fn make_request(
        &self,
        content: Vec<i32>,
        bucket: usize,
        deadline: Option<Instant>,
        priority: Priority,
        done: request::Completion,
    ) -> Request {
        let id = self.next_id.fetch_add(1, Ordering::Relaxed);
        Request { id, content, bucket, submitted: Instant::now(), deadline, priority, done }
    }

    /// Router-side admission front half: validate + frame, then the
    /// deadline-aware check against the shared queue depth and the
    /// lanes' merged completion rate. Sheds are tallied in the router's
    /// admission-side per-class stats.
    fn prepare(
        &self,
        req: InferenceRequest,
    ) -> Result<(Vec<i32>, usize, Option<Instant>, Priority), SubmitError> {
        let priority = req.priority;
        let parts = prepare_request(&self.tokenizer, &self.buckets, self.task, req)
            .map_err(|e| note_shed(&self.stats, priority, e))?;
        let completed: u64 = self
            .lanes
            .iter()
            .map(|l| l.stats.counters.completed.load(Ordering::Relaxed))
            .sum();
        let ahead = self.state.queue.depth_at_or_above(priority.index());
        admission_check(&self.drain, completed, ahead, parts.2)
            .map_err(|e| note_shed(&self.stats, priority, e))?;
        Ok(parts)
    }

    /// Shared body of `submit` / `try_submit` (cell-completion flavor).
    fn submit_with(
        &self,
        req: InferenceRequest,
        blocking: bool,
    ) -> Result<RequestHandle, SubmitError> {
        let (content, bucket, deadline, priority) = self.prepare(req)?;
        let cell = OnceCellSync::new();
        let req = self.make_request(
            content,
            bucket,
            deadline,
            priority,
            request::Completion::cell(cell.clone()),
        );
        let handle = RequestHandle { id: req.id, deadline, done: cell };
        self.admit(req, blocking)?;
        Ok(handle)
    }

    /// Drain and stop every lane; returns the total batches formed.
    pub fn shutdown(mut self) -> u64 {
        self.state.queue.close();
        self.lanes.iter_mut().map(Lane::join).sum()
    }
}

impl Drop for MuxRouter {
    fn drop(&mut self) {
        // close the shared queue before the lanes drop-join, or their
        // pullers would wait for work forever
        self.state.queue.close();
    }
}

impl Submit for MuxRouter {
    fn submit(&self, req: InferenceRequest) -> Result<RequestHandle, SubmitError> {
        self.submit_with(req, true)
    }

    fn try_submit(&self, req: InferenceRequest) -> Result<RequestHandle, SubmitError> {
        self.submit_with(req, false)
    }

    fn submit_tagged(
        &self,
        req: InferenceRequest,
        tag: u64,
        out: &CompletionQueue,
    ) -> Result<(), SubmitError> {
        let (content, bucket, deadline, priority) = self.prepare(req)?;
        let req = self.make_request(
            content,
            bucket,
            deadline,
            priority,
            request::Completion::queue(tag, out.clone()),
        );
        self.admit(req, false)
    }

    fn native_task(&self) -> TaskKind {
        self.task
    }

    fn tokenizer(&self) -> &Tokenizer {
        &self.tokenizer
    }

    fn seq_len(&self) -> usize {
        self.seq_len
    }

    fn n_classes(&self) -> usize {
        self.n_classes
    }

    fn buckets(&self) -> Vec<usize> {
        self.buckets.lens().to_vec()
    }

    fn queue_depth(&self) -> usize {
        self.state.queue.len()
    }

    fn counters(&self) -> CounterSnapshot {
        // admission counters live router-side, execution counters
        // lane-side; merged they read like one engine
        self.lanes
            .iter()
            .map(|l| l.stats.counters.snapshot())
            .fold(self.stats.counters.snapshot(), CounterSnapshot::merge)
    }

    fn latency(&self) -> LatencySummary {
        let mut it = self.lanes.iter().map(|l| l.stats.e2e_latency.summary());
        let first = it.next().unwrap_or_default();
        it.fold(first, LatencySummary::merge)
    }

    fn queue_wait(&self) -> LatencySummary {
        let mut it = self.lanes.iter().map(|l| l.stats.queue_wait.summary());
        let first = it.next().unwrap_or_default();
        it.fold(first, LatencySummary::merge)
    }

    fn lane_status(&self) -> Vec<LaneStatus> {
        self.lanes.iter().map(Lane::status).collect()
    }

    fn class_status(&self) -> Vec<ClassStatus> {
        // sheds are tallied admission-side (router stats); queue-wait and
        // completions accumulate in whichever lane executed the request
        let mut classes = self.stats.class_snapshot();
        for lane in &self.lanes {
            for (acc, lc) in classes.iter_mut().zip(lane.stats.class_snapshot()) {
                acc.completed += lc.completed;
                acc.shed_expired += lc.shed_expired;
                acc.shed_overloaded += lc.shed_overloaded;
                acc.queue_wait = LatencySummary::merge(acc.queue_wait.clone(), lc.queue_wait);
            }
        }
        for c in &mut classes {
            c.depth = self.state.queue.depth_class(c.priority.index());
        }
        classes
    }

    fn backend_info(&self) -> Vec<String> {
        self.backend_descs.clone()
    }

    fn backend_stage_ns(&self) -> Vec<Vec<(&'static str, u64)>> {
        self.backend_handles.iter().map(|b| b.stage_ns()).collect()
    }
}
