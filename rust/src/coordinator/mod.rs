//! L3 coordinator — the DataMUX serving engine.
//!
//! ```text
//!  Submit::submit() ──▶ [bounded queue] ──▶ batcher thread ──▶ [exec queue]
//!                                                                 │
//!                                              worker thread(s) ◀─┘
//!                                                assemble ids → backend execute
//!                                                → demux → fulfill completions
//! ```
//!
//! The coordinator owns one [`InferenceBackend`] (usually an
//! AOT-compiled `(profile, N, batch)` artifact behind PJRT) plus the
//! batcher/worker threads. [`MuxRouter`] composes several coordinators
//! and routes by arrival rate (adaptive N). Both implement the
//! [`Submit`] trait, so every consumer — the TCP server, the workload
//! drivers, benches and examples — is generic over which one it talks
//! to.

pub mod api;
pub mod batcher;
pub mod engine;
pub mod policy;
pub mod request;
pub mod scheduler;
pub mod server;

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Arc;
use std::time::{Duration, Instant};

use anyhow::Result;

use crate::runtime::{InferenceBackend, LoadedModel};
use crate::tokenizer::Tokenizer;
use crate::util::metrics::{CounterSnapshot, LatencySummary};
use crate::util::threadpool::{Channel, OnceCellSync, TrySendError};

pub use api::{
    CompletionItem, CompletionQueue, InferenceRequest, Payload, Submit, SubmitError, TaskKind,
};
pub use batcher::{BatcherConfig, ExecBatch};
pub use engine::EngineBuilder;
pub use policy::{AdaptiveN, SlotPolicy};
pub use request::{EngineError, LogitsView, Request, RequestHandle, Response};
pub use scheduler::{MuxTemplate, SharedModel, Stats};

#[derive(Debug, Clone)]
pub struct CoordinatorConfig {
    /// max time the first request of a batch waits for co-muxed peers
    pub max_wait: Duration,
    /// admission queue capacity (senders block beyond this — backpressure)
    pub queue_cap: usize,
    /// backend worker threads (CPU plugin: 1 is usually right on 1 core)
    pub n_workers: usize,
    pub slot_policy: SlotPolicy,
}

impl Default for CoordinatorConfig {
    fn default() -> Self {
        CoordinatorConfig {
            max_wait: Duration::from_millis(5),
            queue_cap: 1024,
            n_workers: 1,
            slot_policy: SlotPolicy::Fill,
        }
    }
}

/// The serving engine for one loaded model.
pub struct MuxCoordinator {
    input: Channel<Request>,
    pub stats: Arc<Stats>,
    pub tokenizer: Tokenizer,
    pub n_mux: usize,
    pub seq_len: usize,
    task: TaskKind,
    next_id: AtomicU64,
    batcher: Option<std::thread::JoinHandle<u64>>,
    workers: Vec<std::thread::JoinHandle<()>>,
}

impl MuxCoordinator {
    /// Start over a PJRT-loaded artifact (the production path).
    pub fn start(model: LoadedModel, cfg: CoordinatorConfig) -> Result<Self> {
        Self::start_backend(Arc::new(SharedModel(Arc::new(model))), cfg)
    }

    /// Start over any [`InferenceBackend`] (PJRT model, fake, ...).
    pub fn start_backend(
        backend: Arc<dyn InferenceBackend>,
        cfg: CoordinatorConfig,
    ) -> Result<Self> {
        let meta = backend.meta().clone();
        let task = TaskKind::from_model_task(&meta.task)
            .ok_or_else(|| anyhow::anyhow!("unsupported serving task '{}'", meta.task))?;
        let tokenizer =
            Tokenizer::new(crate::tokenizer::default_vocab(), meta.vocab_size);
        let n_mux = meta.n_mux;
        let seq_len = meta.seq_len;
        let stats = Arc::new(Stats::default());
        let input: Channel<Request> = Channel::bounded(cfg.queue_cap);
        let exec: Channel<ExecBatch> = Channel::bounded(cfg.n_workers * 2 + 2);

        // derive the empty-slot ids tensor once; workers bulk-copy it
        // per batch instead of re-deriving pad rows and prefixes
        let template = Arc::new(scheduler::MuxTemplate::new(&meta, &tokenizer));

        let bcfg = BatcherConfig { n_mux, batch: meta.batch, max_wait: cfg.max_wait };
        let b_in = input.clone();
        let b_out = exec.clone();
        let b_stats = stats.clone();
        let batcher = std::thread::Builder::new()
            .name("datamux-batcher".into())
            .spawn(move || {
                batcher::run_batcher(&bcfg, &b_in, &b_out, Some(&b_stats.counters))
            })?;

        let mut workers = Vec::new();
        for w in 0..cfg.n_workers.max(1) {
            let backend = backend.clone();
            let exec = exec.clone();
            let input = input.clone();
            let stats = stats.clone();
            let template = template.clone();
            let policy = cfg.slot_policy;
            workers.push(
                std::thread::Builder::new()
                    .name(format!("datamux-exec-{w}"))
                    .spawn(move || {
                        // worker-owned scratch, reused across batches;
                        // pre-sized so steady state never reallocates
                        let mut scratch = Vec::with_capacity(template.ids_len());
                        while let Some(batch) = exec.recv() {
                            if let Err(e) = scheduler::execute_batch(
                                backend.as_ref(),
                                &template,
                                policy,
                                &stats,
                                batch,
                                &mut scratch,
                            ) {
                                // the failed batch's waiters were already
                                // fulfilled with WorkerFailed inside
                                // execute_batch; poison the intake so new
                                // submissions fail fast with Shutdown, then
                                // keep draining so queued waiters are
                                // answered (not stranded) too.
                                eprintln!("worker {w}: execution failed: {e:#}");
                                input.close();
                            }
                        }
                    })?,
            );
        }

        Ok(MuxCoordinator {
            input,
            stats,
            tokenizer,
            n_mux,
            seq_len,
            task,
            next_id: AtomicU64::new(1),
            batcher: Some(batcher),
            workers,
        })
    }

    /// Validate a typed request and frame its payload.
    fn prepare(&self, req: InferenceRequest) -> Result<(Vec<i32>, Option<Instant>), SubmitError> {
        if req.task != self.task {
            return Err(SubmitError::WrongTask { requested: req.task, served: self.task });
        }
        let content = match req.payload {
            Payload::Framed(ids) => {
                if ids.len() != self.seq_len {
                    return Err(SubmitError::BadFrame {
                        expected: self.seq_len,
                        got: ids.len(),
                    });
                }
                ids
            }
            Payload::Text(text) => self
                .tokenizer
                .encode_framed(&text.split(" [SEP] ").collect::<Vec<_>>(), self.seq_len)
                .map_err(|e| SubmitError::Tokenize(e.to_string()))?,
        };
        let deadline = req.deadline.map(|d| Instant::now() + d);
        Ok((content, deadline))
    }

    fn make_request(
        &self,
        content: Vec<i32>,
        deadline: Option<Instant>,
        done: request::Completion,
    ) -> Request {
        let id = self.next_id.fetch_add(1, Ordering::Relaxed);
        Request { id, content, submitted: Instant::now(), deadline, done }
    }

    /// Blocking admission (backpressure); `Shutdown` when the intake is
    /// closed. Shared counter discipline for every submit flavor.
    fn admit_blocking(&self, req: Request) -> Result<(), SubmitError> {
        if self.input.send(req).is_err() {
            self.stats.counters.rejected.fetch_add(1, Ordering::Relaxed);
            // the dropped request already fulfilled its completion with
            // Shutdown; the caller also gets the error synchronously
            return Err(SubmitError::Shutdown);
        }
        self.stats.counters.submitted.fetch_add(1, Ordering::Relaxed);
        Ok(())
    }

    /// Non-blocking admission; distinguishes `QueueFull` from `Shutdown`
    /// and defuses the handed-back request's completion (the failure is
    /// reported synchronously instead).
    fn admit_nonblocking(&self, req: Request) -> Result<(), SubmitError> {
        match self.input.try_send(req) {
            Ok(()) => {
                self.stats.counters.submitted.fetch_add(1, Ordering::Relaxed);
                Ok(())
            }
            Err(err) => {
                self.stats.counters.rejected.fetch_add(1, Ordering::Relaxed);
                let submit_err = match &err {
                    TrySendError::Full(_) => SubmitError::QueueFull,
                    TrySendError::Closed(_) => SubmitError::Shutdown,
                };
                let mut req = err.into_inner();
                req.done.defuse();
                Err(submit_err)
            }
        }
    }

    /// Stop accepting new requests; everything already admitted still
    /// completes. Submissions return [`SubmitError::Shutdown`] from now
    /// on.
    pub fn close_intake(&self) {
        self.input.close();
    }

    pub fn queue_depth(&self) -> usize {
        self.input.len()
    }

    /// Drain and stop. All in-flight requests are completed first.
    pub fn shutdown(mut self) -> u64 {
        self.input.close();
        let batches = self.batcher.take().map(|b| b.join().unwrap_or(0)).unwrap_or(0);
        for w in self.workers.drain(..) {
            let _ = w.join();
        }
        batches
    }
}

impl Submit for MuxCoordinator {
    fn submit(&self, req: InferenceRequest) -> Result<RequestHandle, SubmitError> {
        let (content, deadline) = self.prepare(req)?;
        let cell = OnceCellSync::new();
        let req =
            self.make_request(content, deadline, request::Completion::cell(cell.clone()));
        let handle = RequestHandle { id: req.id, deadline, done: cell };
        self.admit_blocking(req)?;
        Ok(handle)
    }

    fn try_submit(&self, req: InferenceRequest) -> Result<RequestHandle, SubmitError> {
        let (content, deadline) = self.prepare(req)?;
        let cell = OnceCellSync::new();
        let req =
            self.make_request(content, deadline, request::Completion::cell(cell.clone()));
        let handle = RequestHandle { id: req.id, deadline, done: cell };
        self.admit_nonblocking(req)?;
        Ok(handle)
    }

    fn submit_tagged(
        &self,
        req: InferenceRequest,
        tag: u64,
        out: &CompletionQueue,
    ) -> Result<(), SubmitError> {
        let (content, deadline) = self.prepare(req)?;
        let req =
            self.make_request(content, deadline, request::Completion::queue(tag, out.clone()));
        self.admit_nonblocking(req)
    }

    fn native_task(&self) -> TaskKind {
        self.task
    }

    fn tokenizer(&self) -> &Tokenizer {
        &self.tokenizer
    }

    fn seq_len(&self) -> usize {
        self.seq_len
    }

    fn queue_depth(&self) -> usize {
        self.input.len()
    }

    fn counters(&self) -> CounterSnapshot {
        self.stats.counters.snapshot()
    }

    fn latency(&self) -> LatencySummary {
        self.stats.e2e_latency.summary()
    }

    fn queue_wait(&self) -> LatencySummary {
        self.stats.queue_wait.summary()
    }
}

impl Drop for MuxCoordinator {
    fn drop(&mut self) {
        self.input.close();
        if let Some(b) = self.batcher.take() {
            let _ = b.join();
        }
        for w in self.workers.drain(..) {
            let _ = w.join();
        }
    }
}

/// Adaptive-N router over several coordinators (one per N candidate).
pub struct MuxRouter {
    /// ascending by n_mux; all lanes share seq_len, task and vocabulary
    pub lanes: Vec<MuxCoordinator>,
    adaptive: std::sync::Mutex<AdaptiveN>,
    epoch: Instant,
}

impl MuxRouter {
    /// Compose lanes into an adaptive-N engine.
    ///
    /// Construct-time validation pins the routing invariant: the
    /// adaptive-N candidate set is exactly the set of lane Ns, so
    /// `AdaptiveN::choose` can never name an N without a lane. Lanes
    /// must also agree on seq_len and task, since one typed request must
    /// be valid on whichever lane routing picks.
    pub fn new(mut lanes: Vec<MuxCoordinator>, exec_time_us: f64) -> Result<Self> {
        anyhow::ensure!(!lanes.is_empty(), "MuxRouter needs at least one lane");
        lanes.sort_by_key(|c| c.n_mux);
        let (seq_len, task) = (lanes[0].seq_len, lanes[0].task);
        for lane in &lanes {
            anyhow::ensure!(
                lane.seq_len == seq_len && lane.task == task,
                "router lanes must agree on seq_len/task: lane N={} has (seq_len={}, \
                 task={:?}), expected (seq_len={}, task={:?})",
                lane.n_mux,
                lane.seq_len,
                lane.task,
                seq_len,
                task
            );
        }
        let candidates = lanes.iter().map(|c| c.n_mux).collect();
        Ok(MuxRouter {
            lanes,
            adaptive: std::sync::Mutex::new(AdaptiveN::new(candidates, exec_time_us)),
            epoch: Instant::now(),
        })
    }

    /// Pick the lane adaptive-N selects for one arrival.
    fn route(&self) -> &MuxCoordinator {
        let depth: usize = self.lanes.iter().map(|l| l.queue_depth()).sum();
        let n = {
            let mut a = self.adaptive.lock().unwrap();
            a.on_arrival(self.epoch.elapsed().as_micros() as u64);
            a.choose(depth)
        };
        // `new()` pins candidates == lane Ns, so this lookup always hits;
        // the debug_assert keeps the invariant loud if that ever drifts.
        let lane = self.lanes.iter().find(|l| l.n_mux == n);
        debug_assert!(lane.is_some(), "AdaptiveN chose N={n} but no lane serves it");
        lane.unwrap_or_else(|| self.lanes.last().unwrap())
    }

    /// Route one typed request, reporting which lane (by N) took it.
    pub fn submit_routed(
        &self,
        req: InferenceRequest,
    ) -> Result<(usize, RequestHandle), SubmitError> {
        let lane = self.route();
        Ok((lane.n_mux, lane.submit(req)?))
    }

    /// Drain and stop every lane.
    pub fn shutdown(self) -> u64 {
        self.lanes.into_iter().map(|l| l.shutdown()).sum()
    }
}

impl Submit for MuxRouter {
    fn submit(&self, req: InferenceRequest) -> Result<RequestHandle, SubmitError> {
        self.submit_routed(req).map(|(_, h)| h)
    }

    fn try_submit(&self, req: InferenceRequest) -> Result<RequestHandle, SubmitError> {
        self.route().try_submit(req)
    }

    fn submit_tagged(
        &self,
        req: InferenceRequest,
        tag: u64,
        out: &CompletionQueue,
    ) -> Result<(), SubmitError> {
        self.route().submit_tagged(req, tag, out)
    }

    fn native_task(&self) -> TaskKind {
        self.lanes[0].task
    }

    fn tokenizer(&self) -> &Tokenizer {
        &self.lanes[0].tokenizer
    }

    fn seq_len(&self) -> usize {
        self.lanes[0].seq_len
    }

    fn queue_depth(&self) -> usize {
        self.lanes.iter().map(|l| l.queue_depth()).sum()
    }

    fn counters(&self) -> CounterSnapshot {
        self.lanes
            .iter()
            .map(|l| l.stats.counters.snapshot())
            .fold(CounterSnapshot::default(), CounterSnapshot::merge)
    }

    fn latency(&self) -> LatencySummary {
        let mut it = self.lanes.iter().map(|l| l.stats.e2e_latency.summary());
        let first = it.next().expect("router has at least one lane");
        it.fold(first, LatencySummary::merge)
    }

    fn queue_wait(&self) -> LatencySummary {
        let mut it = self.lanes.iter().map(|l| l.stats.queue_wait.summary());
        let first = it.next().expect("router has at least one lane");
        it.fold(first, LatencySummary::merge)
    }
}
