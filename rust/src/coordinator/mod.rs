//! L3 coordinator — the DataMUX serving engine.
//!
//! ```text
//!  submit() ──▶ [bounded queue] ──▶ batcher thread ──▶ [exec queue]
//!                                                        │
//!                                     worker thread(s) ◀─┘
//!                                       assemble ids → PJRT execute
//!                                       → demux → fulfill handles
//! ```
//!
//! The coordinator owns one AOT-compiled model (one `(profile, N, batch)`
//! artifact) plus the batcher/worker threads. `MuxRouter` composes
//! several coordinators and routes by arrival rate (adaptive N).

pub mod batcher;
pub mod policy;
pub mod request;
pub mod scheduler;
pub mod server;

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Arc;
use std::time::{Duration, Instant};

use anyhow::Result;

use crate::runtime::LoadedModel;
use crate::tokenizer::Tokenizer;
use crate::util::threadpool::{Channel, OnceCellSync};

pub use batcher::{BatcherConfig, ExecBatch};
pub use policy::{AdaptiveN, SlotPolicy};
pub use request::{Request, RequestHandle, Response};
pub use scheduler::{SharedModel, Stats};

#[derive(Debug, Clone)]
pub struct CoordinatorConfig {
    /// max time the first request of a batch waits for co-muxed peers
    pub max_wait: Duration,
    /// admission queue capacity (senders block beyond this — backpressure)
    pub queue_cap: usize,
    /// PJRT worker threads (CPU plugin: 1 is usually right on 1 core)
    pub n_workers: usize,
    pub slot_policy: SlotPolicy,
}

impl Default for CoordinatorConfig {
    fn default() -> Self {
        CoordinatorConfig {
            max_wait: Duration::from_millis(5),
            queue_cap: 1024,
            n_workers: 1,
            slot_policy: SlotPolicy::Fill,
        }
    }
}

/// The serving engine for one loaded model.
pub struct MuxCoordinator {
    input: Channel<Request>,
    pub stats: Arc<Stats>,
    pub tokenizer: Tokenizer,
    pub n_mux: usize,
    pub seq_len: usize,
    next_id: AtomicU64,
    batcher: Option<std::thread::JoinHandle<u64>>,
    workers: Vec<std::thread::JoinHandle<()>>,
}

impl MuxCoordinator {
    pub fn start(model: LoadedModel, cfg: CoordinatorConfig) -> Result<Self> {
        let tokenizer = Tokenizer::new(
            crate::tokenizer::default_vocab(),
            model.meta.vocab_size,
        );
        let n_mux = model.meta.n_mux;
        let seq_len = model.meta.seq_len;
        let stats = Arc::new(Stats::default());
        let input: Channel<Request> = Channel::bounded(cfg.queue_cap);
        let exec: Channel<ExecBatch> = Channel::bounded(cfg.n_workers * 2 + 2);

        let bcfg = BatcherConfig {
            n_mux,
            batch: model.meta.batch,
            max_wait: cfg.max_wait,
        };
        let b_in = input.clone();
        let b_out = exec.clone();
        let batcher = std::thread::Builder::new()
            .name("datamux-batcher".into())
            .spawn(move || batcher::run_batcher(&bcfg, &b_in, &b_out))?;

        let shared = SharedModel(Arc::new(model));
        let mut workers = Vec::new();
        for w in 0..cfg.n_workers.max(1) {
            let model = shared.clone();
            let exec = exec.clone();
            let stats = stats.clone();
            let tok = tokenizer.clone();
            let policy = cfg.slot_policy;
            workers.push(
                std::thread::Builder::new()
                    .name(format!("datamux-exec-{w}"))
                    .spawn(move || {
                        let mut scratch = Vec::new();
                        while let Some(batch) = exec.recv() {
                            if let Err(e) = scheduler::execute_batch(
                                &model, &tok, policy, &stats, batch, &mut scratch,
                            ) {
                                eprintln!("worker {w}: execution failed: {e:#}");
                                return;
                            }
                        }
                    })?,
            );
        }

        Ok(MuxCoordinator {
            input,
            stats,
            tokenizer,
            n_mux,
            seq_len,
            next_id: AtomicU64::new(1),
            batcher: Some(batcher),
            workers,
        })
    }

    /// Submit a framed content row (seq_len ids). Blocks on backpressure.
    pub fn submit_framed(&self, content: Vec<i32>) -> Result<RequestHandle> {
        anyhow::ensure!(
            content.len() == self.seq_len,
            "content must be framed to seq_len={} (got {})",
            self.seq_len,
            content.len()
        );
        let id = self.next_id.fetch_add(1, Ordering::Relaxed);
        let done = OnceCellSync::new();
        let handle = RequestHandle { id, done: done.clone() };
        self.stats.counters.submitted.fetch_add(1, Ordering::Relaxed);
        let req = Request { id, content, submitted: Instant::now(), done };
        if self.input.send(req).is_err() {
            self.stats.counters.rejected.fetch_add(1, Ordering::Relaxed);
            anyhow::bail!("coordinator is shut down");
        }
        Ok(handle)
    }

    /// Submit text (`t5 t12 ...` or multiple [SEP]-joined parts).
    pub fn submit_text(&self, parts: &[&str]) -> Result<RequestHandle> {
        let framed = self
            .tokenizer
            .encode_framed(parts, self.seq_len)
            .map_err(|e| anyhow::anyhow!("tokenize: {e}"))?;
        self.submit_framed(framed)
    }

    /// Non-blocking submit; Err(content) when the queue is full.
    pub fn try_submit_framed(&self, content: Vec<i32>) -> std::result::Result<RequestHandle, Vec<i32>> {
        if content.len() != self.seq_len {
            return Err(content);
        }
        let id = self.next_id.fetch_add(1, Ordering::Relaxed);
        let done = OnceCellSync::new();
        let handle = RequestHandle { id, done: done.clone() };
        let req = Request { id, content, submitted: Instant::now(), done };
        match self.input.try_send(req) {
            Ok(()) => {
                self.stats.counters.submitted.fetch_add(1, Ordering::Relaxed);
                Ok(handle)
            }
            Err(req) => {
                self.stats.counters.rejected.fetch_add(1, Ordering::Relaxed);
                Err(req.content)
            }
        }
    }

    pub fn queue_depth(&self) -> usize {
        self.input.len()
    }

    /// Drain and stop. All in-flight requests are completed first.
    pub fn shutdown(mut self) -> u64 {
        self.input.close();
        let batches = self.batcher.take().map(|b| b.join().unwrap_or(0)).unwrap_or(0);
        for w in self.workers.drain(..) {
            let _ = w.join();
        }
        batches
    }
}

impl Drop for MuxCoordinator {
    fn drop(&mut self) {
        self.input.close();
        if let Some(b) = self.batcher.take() {
            let _ = b.join();
        }
        for w in self.workers.drain(..) {
            let _ = w.join();
        }
    }
}

/// Adaptive-N router over several coordinators (one per N candidate).
pub struct MuxRouter {
    /// ascending by n_mux
    pub lanes: Vec<MuxCoordinator>,
    adaptive: std::sync::Mutex<AdaptiveN>,
    epoch: Instant,
}

impl MuxRouter {
    pub fn new(mut lanes: Vec<MuxCoordinator>, exec_time_us: f64) -> Self {
        lanes.sort_by_key(|c| c.n_mux);
        let candidates = lanes.iter().map(|c| c.n_mux).collect();
        MuxRouter {
            lanes,
            adaptive: std::sync::Mutex::new(AdaptiveN::new(candidates, exec_time_us)),
            epoch: Instant::now(),
        }
    }

    /// Route one framed request to the lane adaptive-N selects.
    pub fn submit_framed(&self, content: Vec<i32>) -> Result<(usize, RequestHandle)> {
        let depth: usize = self.lanes.iter().map(|l| l.queue_depth()).sum();
        let n = {
            let mut a = self.adaptive.lock().unwrap();
            a.on_arrival(self.epoch.elapsed().as_micros() as u64);
            a.choose(depth)
        };
        let lane = self
            .lanes
            .iter()
            .find(|l| l.n_mux == n)
            .unwrap_or_else(|| self.lanes.last().unwrap());
        Ok((lane.n_mux, lane.submit_framed(content)?))
    }
}
