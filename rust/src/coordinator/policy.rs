//! Slot-assignment and executable-selection policies.
//!
//! Paper A3 / Fig 7b: accuracy varies across mux indices, so *which slot*
//! a request lands in matters. `SlotPolicy` controls the group-local
//! starting offset so long-run per-slot load (and thus exposure to the
//! weaker indices) can be equalized.
//!
//! `AdaptiveN` picks which executable (which N) to route to from the
//! observed arrival rate — the serving-side extension the paper's
//! discussion motivates (multiplex more when the queue is deep, keep
//! latency low when traffic is light).

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum SlotPolicy {
    /// Always assign slots 0..k in order. Simple; index-0 bias.
    Fill,
    /// Rotate the starting slot every group so each index sees the same
    /// long-run request share.
    RotateOffset,
}

impl SlotPolicy {
    /// Map entry position -> slot index for a group with `n_mux` slots.
    pub fn slot_of(&self, group_seq: u64, position: usize, n_mux: usize) -> usize {
        match self {
            SlotPolicy::Fill => position,
            SlotPolicy::RotateOffset => (position + (group_seq as usize % n_mux)) % n_mux,
        }
    }
}

/// EWMA arrival-rate estimator driving adaptive-N selection.
#[derive(Debug)]
pub struct AdaptiveN {
    /// candidate N values, ascending (each must have a loaded model)
    pub candidates: Vec<usize>,
    ewma_interarrival_us: f64,
    alpha: f64,
    last_arrival_us: Option<u64>,
    /// model execute time estimate (us) — amortization target
    pub exec_time_us: f64,
}

impl AdaptiveN {
    pub fn new(mut candidates: Vec<usize>, exec_time_us: f64) -> Self {
        candidates.sort_unstable();
        assert!(!candidates.is_empty());
        AdaptiveN {
            candidates,
            ewma_interarrival_us: 1e6,
            alpha: 0.2,
            last_arrival_us: None,
            exec_time_us,
        }
    }

    /// Record an arrival (monotonic microsecond timestamp).
    pub fn on_arrival(&mut self, now_us: u64) {
        if let Some(prev) = self.last_arrival_us {
            let delta = (now_us.saturating_sub(prev)) as f64;
            self.ewma_interarrival_us =
                self.alpha * delta + (1.0 - self.alpha) * self.ewma_interarrival_us;
        }
        self.last_arrival_us = Some(now_us);
    }

    pub fn arrival_rate_per_s(&self) -> f64 {
        if self.ewma_interarrival_us <= 0.0 {
            return 0.0;
        }
        1e6 / self.ewma_interarrival_us
    }

    /// Choose N: the number of requests expected to arrive within one
    /// model execution, clamped to the candidate grid. Deep queues ->
    /// large N (throughput mode); light traffic -> small N (latency mode).
    pub fn choose(&self, queue_depth: usize) -> usize {
        let expected = self.arrival_rate_per_s() * self.exec_time_us / 1e6;
        let want = expected.max(queue_depth as f64).max(1.0);
        *self
            .candidates
            .iter()
            .find(|&&n| (n as f64) >= want)
            .unwrap_or(self.candidates.last().unwrap())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fill_is_identity() {
        let p = SlotPolicy::Fill;
        for pos in 0..8 {
            assert_eq!(p.slot_of(3, pos, 8), pos);
        }
    }

    #[test]
    fn rotate_covers_all_slots_evenly() {
        let p = SlotPolicy::RotateOffset;
        let n = 4;
        let mut hits = [0usize; 4];
        for group in 0..100u64 {
            // one request per group at position 0
            hits[p.slot_of(group, 0, n)] += 1;
        }
        assert!(hits.iter().all(|&h| h == 25), "{hits:?}");
    }

    #[test]
    fn rotate_is_bijective_within_group() {
        let p = SlotPolicy::RotateOffset;
        let n = 5;
        for group in 0..7u64 {
            let mut seen = [false; 5];
            for pos in 0..n {
                let s = p.slot_of(group, pos, n);
                assert!(!seen[s]);
                seen[s] = true;
            }
        }
    }

    #[test]
    fn adaptive_prefers_small_n_when_idle() {
        let a = AdaptiveN::new(vec![1, 2, 5, 10, 20, 40], 10_000.0);
        assert_eq!(a.choose(0), 1);
        assert_eq!(a.choose(1), 1);
    }

    #[test]
    fn adaptive_scales_with_queue_depth() {
        let a = AdaptiveN::new(vec![1, 2, 5, 10, 20, 40], 10_000.0);
        assert_eq!(a.choose(4), 5);
        assert_eq!(a.choose(12), 20);
        assert_eq!(a.choose(100), 40); // clamped to max
    }

    #[test]
    fn adaptive_tracks_arrival_rate() {
        let mut a = AdaptiveN::new(vec![1, 5, 20], 100_000.0); // 100ms exec
        // 1 arrival every 10ms -> ~10 arrivals per execution -> N=20
        let mut t = 0u64;
        for _ in 0..50 {
            a.on_arrival(t);
            t += 10_000;
        }
        assert!(a.arrival_rate_per_s() > 50.0);
        assert_eq!(a.choose(0), 20);
    }
}
