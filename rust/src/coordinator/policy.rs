//! Slot-assignment and executable-selection policies.
//!
//! Paper A3 / Fig 7b: accuracy varies across mux indices, so *which slot*
//! a request lands in matters. `SlotPolicy` controls the group-local
//! starting offset so long-run per-slot load (and thus exposure to the
//! weaker indices) can be equalized.
//!
//! `AdaptiveN` estimates the arrival rate and maps it (plus the current
//! backlog) onto the candidate N grid — the serving-side extension the
//! paper's discussion motivates (multiplex more when the queue is deep,
//! keep latency low when traffic is light). Since the shared-queue
//! router redesign it is a **pull gate**, not a per-arrival chooser:
//! every lane asks `should_pull(its_n, depth)` before taking work from
//! the shared admission queue, so small-N lanes serve light traffic and
//! large-N lanes engage as the backlog (or rate) grows. Dead lanes are
//! retired from the candidate grid with [`AdaptiveN::remove_candidate`].

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum SlotPolicy {
    /// Always assign slots 0..k in order. Simple; index-0 bias.
    Fill,
    /// Rotate the starting slot every group so each index sees the same
    /// long-run request share.
    RotateOffset,
}

impl SlotPolicy {
    /// Map entry position -> slot index for a group with `n_mux` slots.
    pub fn slot_of(&self, group_seq: u64, position: usize, n_mux: usize) -> usize {
        match self {
            SlotPolicy::Fill => position,
            SlotPolicy::RotateOffset => (position + (group_seq as usize % n_mux)) % n_mux,
        }
    }
}

/// EWMA arrival-rate estimator driving adaptive-N selection.
#[derive(Debug)]
pub struct AdaptiveN {
    /// candidate N values, ascending (each must have a loaded model)
    pub candidates: Vec<usize>,
    ewma_interarrival_us: f64,
    alpha: f64,
    last_arrival_us: Option<u64>,
    /// model execute time estimate (us) — amortization target
    pub exec_time_us: f64,
}

impl AdaptiveN {
    pub fn new(mut candidates: Vec<usize>, exec_time_us: f64) -> Self {
        candidates.sort_unstable();
        assert!(!candidates.is_empty());
        AdaptiveN {
            candidates,
            ewma_interarrival_us: 1e6,
            alpha: 0.2,
            last_arrival_us: None,
            exec_time_us,
        }
    }

    /// Record an arrival (monotonic microsecond timestamp).
    pub fn on_arrival(&mut self, now_us: u64) {
        if let Some(prev) = self.last_arrival_us {
            let delta = (now_us.saturating_sub(prev)) as f64;
            self.ewma_interarrival_us =
                self.alpha * delta + (1.0 - self.alpha) * self.ewma_interarrival_us;
        }
        self.last_arrival_us = Some(now_us);
    }

    /// Fold an observed quiet gap into the rate estimate.
    ///
    /// `on_arrival` only updates the EWMA *when requests arrive*, so
    /// after a burst stops the estimate froze at burst rate forever and
    /// kept large-N lanes engaged on idle traffic. Called at
    /// choose/pull time, this blends the elapsed silence (`now -
    /// last_arrival`) into the EWMA whenever it exceeds the current
    /// estimate — one-sided, so in-burst calls (tiny gaps) are no-ops
    /// and repeated idle calls converge the estimate onto the quiet
    /// gap. `last_arrival_us` is deliberately untouched: the next real
    /// arrival still sees the full gap.
    pub fn decay(&mut self, now_us: u64) {
        if let Some(prev) = self.last_arrival_us {
            let gap = (now_us.saturating_sub(prev)) as f64;
            if gap > self.ewma_interarrival_us {
                self.ewma_interarrival_us =
                    self.alpha * gap + (1.0 - self.alpha) * self.ewma_interarrival_us;
            }
        }
    }

    /// Retire one candidate (a lane died). The grid may become empty —
    /// `choose_checked` then reports `None` and no lane pulls.
    pub fn remove_candidate(&mut self, n: usize) {
        if let Some(i) = self.candidates.iter().position(|&c| c == n) {
            self.candidates.remove(i);
        }
    }

    pub fn arrival_rate_per_s(&self) -> f64 {
        if self.ewma_interarrival_us <= 0.0 {
            return 0.0;
        }
        1e6 / self.ewma_interarrival_us
    }

    /// Choose N: the number of requests expected to arrive within one
    /// model execution, clamped to the candidate grid. Deep queues ->
    /// large N (throughput mode); light traffic -> small N (latency mode).
    /// `None` when every candidate has been retired.
    pub fn choose_checked(&self, queue_depth: usize) -> Option<usize> {
        let expected = self.arrival_rate_per_s() * self.exec_time_us / 1e6;
        let want = expected.max(queue_depth as f64).max(1.0);
        self.candidates
            .iter()
            .copied()
            .find(|&n| (n as f64) >= want)
            .or_else(|| self.candidates.last().copied())
    }

    /// `choose_checked`, for callers that know candidates remain.
    /// Test-only: production callers go through `should_pull`, which
    /// handles the no-candidates-left case without panicking.
    #[cfg(test)]
    pub fn choose(&self, queue_depth: usize) -> usize {
        self.choose_checked(queue_depth).expect("AdaptiveN has no candidates left")
    }

    /// Pull-gate: may a lane multiplexing `lane_n` requests take work
    /// from the shared queue right now? True for every live lane whose N
    /// does not exceed the chosen target — when idle only the smallest
    /// lane pulls; as backlog/rate grows, progressively larger lanes
    /// engage (the smallest live lane always qualifies, so admitted work
    /// can never sit unpulled while any lane is alive).
    pub fn should_pull(&self, lane_n: usize, queue_depth: usize) -> bool {
        match self.choose_checked(queue_depth) {
            Some(n) => lane_n <= n,
            None => false,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fill_is_identity() {
        let p = SlotPolicy::Fill;
        for pos in 0..8 {
            assert_eq!(p.slot_of(3, pos, 8), pos);
        }
    }

    #[test]
    fn rotate_covers_all_slots_evenly() {
        let p = SlotPolicy::RotateOffset;
        let n = 4;
        let mut hits = [0usize; 4];
        for group in 0..100u64 {
            // one request per group at position 0
            hits[p.slot_of(group, 0, n)] += 1;
        }
        assert!(hits.iter().all(|&h| h == 25), "{hits:?}");
    }

    #[test]
    fn rotate_is_bijective_within_group() {
        let p = SlotPolicy::RotateOffset;
        let n = 5;
        for group in 0..7u64 {
            let mut seen = [false; 5];
            for pos in 0..n {
                let s = p.slot_of(group, pos, n);
                assert!(!seen[s]);
                seen[s] = true;
            }
        }
    }

    #[test]
    fn adaptive_prefers_small_n_when_idle() {
        let a = AdaptiveN::new(vec![1, 2, 5, 10, 20, 40], 10_000.0);
        assert_eq!(a.choose(0), 1);
        assert_eq!(a.choose(1), 1);
    }

    #[test]
    fn adaptive_scales_with_queue_depth() {
        let a = AdaptiveN::new(vec![1, 2, 5, 10, 20, 40], 10_000.0);
        assert_eq!(a.choose(4), 5);
        assert_eq!(a.choose(12), 20);
        assert_eq!(a.choose(100), 40); // clamped to max
    }

    #[test]
    fn adaptive_tracks_arrival_rate() {
        let mut a = AdaptiveN::new(vec![1, 5, 20], 100_000.0); // 100ms exec
        // 1 arrival every 10ms -> ~10 arrivals per execution -> N=20
        let mut t = 0u64;
        for _ in 0..50 {
            a.on_arrival(t);
            t += 10_000;
        }
        assert!(a.arrival_rate_per_s() > 50.0);
        assert_eq!(a.choose(0), 20);
    }

    #[test]
    fn rate_decays_after_burst_stops() {
        let mut a = AdaptiveN::new(vec![1, 5, 20], 100_000.0); // 100ms exec
        let mut t = 0u64;
        for _ in 0..50 {
            a.on_arrival(t);
            t += 10_000;
        }
        assert_eq!(a.choose(0), 20, "mid-burst the rate estimate wants large N");
        // the burst stops; pull-time decay observes 5s of silence and
        // the stale burst-rate estimate must come down to the idle choice
        let quiet = t + 5_000_000;
        for _ in 0..40 {
            a.decay(quiet);
        }
        assert!(a.arrival_rate_per_s() < 5.0, "rate={}", a.arrival_rate_per_s());
        assert_eq!(a.choose(0), 1, "after silence the smallest N serves");
        // a fresh burst still re-engages large N (depth path is intact)
        assert_eq!(a.choose(50), 20);
    }

    #[test]
    fn decay_is_a_noop_during_active_traffic() {
        let mut a = AdaptiveN::new(vec![1, 5, 20], 100_000.0);
        let mut t = 0u64;
        for _ in 0..20 {
            a.on_arrival(t);
            t += 10_000;
        }
        let before = a.arrival_rate_per_s();
        a.decay(t + 1_000); // 1ms since last arrival: shorter than the EWMA
        assert_eq!(a.arrival_rate_per_s(), before);
    }

    #[test]
    fn pull_gate_prefers_small_lanes_idle_and_opens_up_under_backlog() {
        let a = AdaptiveN::new(vec![2, 8, 20], 10_000.0);
        // idle: only the smallest lane pulls
        assert!(a.should_pull(2, 0));
        assert!(!a.should_pull(8, 0));
        assert!(!a.should_pull(20, 0));
        // moderate backlog: mid lane engages, the largest stays gated
        assert!(a.should_pull(2, 6));
        assert!(a.should_pull(8, 6));
        assert!(!a.should_pull(20, 6));
        // deep backlog: everyone pulls
        assert!(a.should_pull(2, 50));
        assert!(a.should_pull(8, 50));
        assert!(a.should_pull(20, 50));
    }

    #[test]
    fn retired_candidates_stop_pulling_and_empty_grid_gates_everyone() {
        let mut a = AdaptiveN::new(vec![2, 8], 10_000.0);
        a.remove_candidate(2);
        // with the small lane dead, the idle choice falls to N=8
        assert_eq!(a.choose_checked(0), Some(8));
        assert!(a.should_pull(8, 0));
        a.remove_candidate(8);
        assert_eq!(a.choose_checked(0), None);
        assert!(!a.should_pull(8, 0));
        assert!(!a.should_pull(2, 0));
    }
}
