//! Protocol-v2 client connection pool: the transport under
//! [`super::shards::ShardRouter`].
//!
//! One [`ShardConn`] per backend shard: a blocking `TcpStream` writer
//! (line-JSON v2 requests with pool-chosen numeric ids) plus one reader
//! thread that reassembles reply lines ([`LineAssembler`]) and routes
//! each reply to its waiter **exactly once** through a shared in-flight
//! map — removal from the map is the only door to a completion, so a
//! reply, a failover drain, and a shutdown can race without ever
//! double-fulfilling or stranding a request.
//!
//! A [`FaultInjector`] can be layered into every pool I/O operation
//! (env- or builder-configured, seeded LCG) for deterministic chaos
//! testing: refuse connects, delay writes, split frames across writes,
//! garble a frame byte, or drop the connection mid-frame. Every fault
//! collapses into one of two recoverable outcomes — a typed error reply
//! or a dead connection — both of which the router's failover machinery
//! already handles, which is exactly the property CI asserts.

use std::collections::HashMap;
use std::io::{Read, Write};
use std::net::{Shutdown, TcpStream, ToSocketAddrs};
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::Arc;
use std::time::{Duration, Instant};

use anyhow::{anyhow, Context, Result};

use crate::util::framed::LineAssembler;
use crate::util::json::{arr, num, obj, s, Json};
use crate::util::metrics::Histogram;
use crate::util::sync::{rank, TrackedMutex};
use crate::util::threadpool::Channel;

use super::api::{Priority, TaskKind};
use super::request::{Completion, EngineError, LogitsView, Response};

// ---------------------------------------------------------------------------
// fault injection
// ---------------------------------------------------------------------------

/// Chaos configuration for the pool's I/O layer. All probabilities are
/// per-operation in `[0, 1]`; the stream of decisions is drawn from a
/// seeded LCG, so a fixed seed reproduces the exact same fault schedule.
#[derive(Debug, Clone)]
pub struct FaultPlan {
    pub seed: u64,
    /// refuse a connect attempt (the client-side mirror of a backend
    /// refusing accepts)
    pub refuse_connect: f64,
    /// drop the connection mid-frame: write half the request bytes,
    /// then shut the socket down
    pub drop_conn: f64,
    /// sleep up to `max_delay` before a write
    pub delay_write: f64,
    pub max_delay: Duration,
    /// split a request frame across two writes with a pause between
    pub split_write: f64,
    /// overwrite one request byte with `0x01` — depending on where it
    /// lands the server answers a typed error or an uncorrelatable
    /// `bad_json`, which poisons the connection (failover path)
    pub garble: f64,
}

impl FaultPlan {
    /// No faults (the production default).
    pub fn disabled() -> FaultPlan {
        FaultPlan {
            seed: 0,
            refuse_connect: 0.0,
            drop_conn: 0.0,
            delay_write: 0.0,
            max_delay: Duration::ZERO,
            split_write: 0.0,
            garble: 0.0,
        }
    }

    /// Mild-but-mean defaults for a given seed: every fault class fires,
    /// none so often that the system cannot make progress.
    pub fn chaos(seed: u64) -> FaultPlan {
        FaultPlan {
            seed,
            refuse_connect: 0.10,
            drop_conn: 0.02,
            delay_write: 0.05,
            max_delay: Duration::from_millis(5),
            split_write: 0.20,
            garble: 0.01,
        }
    }

    /// `DATAMUX_FAULT_SEED=<n>` enables [`FaultPlan::chaos`] with that
    /// seed; unset or unparsable means no faults.
    pub fn from_env() -> FaultPlan {
        FaultPlan::from_env_value(std::env::var("DATAMUX_FAULT_SEED").ok().as_deref())
    }

    /// Parse an already-read `DATAMUX_FAULT_SEED` value. Pure — tests
    /// inject the value here instead of mutating the process-global
    /// environment under a multithreaded harness.
    pub fn from_env_value(value: Option<&str>) -> FaultPlan {
        match value.and_then(|v| v.parse::<u64>().ok()) {
            Some(seed) => FaultPlan::chaos(seed),
            None => FaultPlan::disabled(),
        }
    }

    pub fn enabled(&self) -> bool {
        self.refuse_connect > 0.0
            || self.drop_conn > 0.0
            || self.delay_write > 0.0
            || self.split_write > 0.0
            || self.garble > 0.0
    }
}

/// What the injector decided for one write.
struct WriteFx {
    delay: Option<Duration>,
    split_at: Option<usize>,
    garble_at: Option<usize>,
    drop_mid_frame: bool,
}

/// Deterministic fault source shared by every connection of one router.
pub struct FaultInjector {
    plan: FaultPlan,
    /// LCG state (Knuth MMIX constants)
    state: TrackedMutex<u64>,
}

impl FaultInjector {
    pub fn new(plan: FaultPlan) -> FaultInjector {
        let seed = plan.seed;
        FaultInjector {
            plan,
            state: TrackedMutex::new(
                "pool.fault",
                rank::FAULT_STATE,
                seed.wrapping_mul(6364136223846793005).wrapping_add(1442695040888963407),
            ),
        }
    }

    pub fn enabled(&self) -> bool {
        self.plan.enabled()
    }

    fn next_f64(&self) -> f64 {
        let mut st = self.state.lock();
        *st = st.wrapping_mul(6364136223846793005).wrapping_add(1442695040888963407);
        (*st >> 11) as f64 / (1u64 << 53) as f64
    }

    fn roll(&self, p: f64) -> bool {
        p > 0.0 && self.next_f64() < p
    }

    /// Should this connect attempt be refused?
    pub fn refuse_connect(&self) -> bool {
        self.roll(self.plan.refuse_connect)
    }

    fn write_fx(&self, frame_len: usize) -> WriteFx {
        if !self.enabled() {
            return WriteFx { delay: None, split_at: None, garble_at: None, drop_mid_frame: false };
        }
        let delay = self
            .roll(self.plan.delay_write)
            .then(|| self.plan.max_delay.mul_f64(self.next_f64()));
        // never split at 0 or len (that would be a plain write), and
        // never garble the trailing newline (framing must survive)
        let split_at = (frame_len > 2 && self.roll(self.plan.split_write))
            .then(|| 1 + (self.next_f64() * (frame_len - 2) as f64) as usize);
        let garble_at = (frame_len > 1 && self.roll(self.plan.garble))
            .then(|| (self.next_f64() * (frame_len - 1) as f64) as usize);
        let drop_mid_frame = self.roll(self.plan.drop_conn);
        WriteFx { delay, split_at, garble_at, drop_mid_frame }
    }
}

// ---------------------------------------------------------------------------
// in-flight tracking
// ---------------------------------------------------------------------------

/// A request the pool has written to a shard and not yet answered.
/// Carries everything needed to resubmit it to a surviving shard with
/// its *remaining* deadline budget on failover.
pub(crate) struct PoolRequest {
    pub content: Vec<i32>,
    pub task: TaskKind,
    pub priority: Priority,
    pub bucket: usize,
    /// absolute deadline (the client's total budget — never extended)
    pub deadline: Option<Instant>,
    /// when the request was admitted (feeds e2e latency — never reset)
    pub submitted: Instant,
    /// when the current hop was written to the wire; restamped by every
    /// send, so hop-staleness sweeps judge the *current* shard, not the
    /// request's whole lifetime (a failed-over or long-parked request
    /// must not condemn the healthy connection it lands on)
    pub sent_at: Instant,
    pub resubmits: u32,
    pub done: Completion,
}

/// One slot in a connection's in-flight map.
pub(crate) enum Entry {
    /// a health probe (v2 STATS); answered by updating shard RTT/liveness
    Probe { sent: Instant },
    Req(Box<PoolRequest>),
}

pub(crate) type InFlightMap = Arc<TrackedMutex<HashMap<u64, Entry>>>;

/// Fresh in-flight map for one connection (named + ranked for the
/// runtime lock-order detector).
pub(crate) fn new_in_flight_map() -> InFlightMap {
    Arc::new(TrackedMutex::new("pool.in_flight", rank::POOL_IN_FLIGHT, HashMap::new()))
}

/// Liveness/progress counters for one shard, shared between its
/// connection reader, the router's submit path, and the monitor thread.
#[derive(Default)]
pub(crate) struct ShardShared {
    pub probes: AtomicU64,
    pub probe_failures: AtomicU64,
    pub failovers: AtomicU64,
    pub completed: AtomicU64,
    /// requests that ended in `DeadlineExceeded` on this shard
    pub expired: AtomicU64,
    pub in_flight: AtomicU64,
    /// front-observed end-to-end latency of requests answered here
    pub e2e: Histogram,
    /// f64 bits of the RTT EWMA in microseconds (0 until first sample)
    ewma_rtt_us_bits: AtomicU64,
}

impl ShardShared {
    pub fn note_rtt(&self, rtt: Duration) {
        let us = rtt.as_secs_f64() * 1e6;
        let old = f64::from_bits(self.ewma_rtt_us_bits.load(Ordering::Relaxed));
        let new = if old == 0.0 { us } else { 0.8 * old + 0.2 * us };
        self.ewma_rtt_us_bits.store(new.to_bits(), Ordering::Relaxed);
    }

    pub fn ewma_rtt_us(&self) -> f64 {
        f64::from_bits(self.ewma_rtt_us_bits.load(Ordering::Relaxed))
    }
}

/// Events the connection readers push to the router's monitor thread.
pub(crate) enum PoolEvent {
    /// the shard's connection died; `orphans` are its unanswered
    /// requests, to be resubmitted to surviving shards
    ConnDown { shard: usize, generation: u64, orphans: Vec<PoolRequest> },
    /// the shard answered with a retryable error (its queue was full /
    /// it is shutting down): place the request on another shard
    Retry { shard: usize, req: Box<PoolRequest> },
}

// ---------------------------------------------------------------------------
// wire formatting / parsing
// ---------------------------------------------------------------------------

/// Serialize a pool request into a v2 line (no trailing newline).
/// `deadline_ms` is the *remaining* budget the shard is given — the
/// caller computes it from the absolute deadline minus the RTT margin.
pub(crate) fn request_json(id: u64, req: &PoolRequest, deadline_ms: Option<f64>) -> Json {
    let mut fields = vec![
        ("id", num(id as f64)),
        ("op", s(req.task.as_str())),
        ("ids", arr(req.content.iter().map(|&t| num(t as f64)))),
        ("priority", s(req.priority.as_str())),
        // always fetch logits: the front fabricates a full typed
        // Response (pred_class/pred_tokens/logits) from the reply
        ("logits", Json::Bool(true)),
    ];
    if let Some(ms) = deadline_ms {
        fields.push(("deadline_ms", num(ms)));
    }
    obj(fields)
}

pub(crate) fn probe_json(id: u64) -> Json {
    obj(vec![("id", num(id as f64)), ("op", s("stats"))])
}

/// Model shape learned from a shard's v2 STATS handshake.
#[derive(Debug, Clone, PartialEq, Eq)]
pub(crate) struct ModelInfo {
    pub task: TaskKind,
    pub seq_len: usize,
    pub n_classes: usize,
    pub vocab_size: usize,
    pub buckets: Vec<usize>,
}

impl ModelInfo {
    pub fn parse(stats_reply: &Json) -> Result<ModelInfo> {
        let m = stats_reply
            .get("stats")
            .and_then(|st| st.get("model"))
            .ok_or_else(|| anyhow!("shard STATS reply has no stats.model block"))?;
        let task_str = m.get("task").and_then(Json::as_str).unwrap_or("");
        let task = match task_str {
            "classify" => TaskKind::Classify,
            "tag" => TaskKind::TagTokens,
            other => return Err(anyhow!("shard serves unknown task '{other}'")),
        };
        Ok(ModelInfo {
            task,
            seq_len: m
                .get("seq_len")
                .and_then(Json::as_usize)
                .ok_or_else(|| anyhow!("model block missing seq_len"))?,
            n_classes: m
                .get("n_classes")
                .and_then(Json::as_usize)
                .ok_or_else(|| anyhow!("model block missing n_classes"))?,
            vocab_size: m
                .get("vocab_size")
                .and_then(Json::as_usize)
                .ok_or_else(|| anyhow!("model block missing vocab_size"))?,
            buckets: m
                .get("buckets")
                .and_then(Json::as_arr)
                .map(|a| a.iter().filter_map(Json::as_usize).collect())
                .unwrap_or_default(),
        })
    }
}

/// Build a typed [`Response`] from a successful v2 reply. Falls back to
/// one-hot logits synthesized from `pred`/`tags` if the shard did not
/// return logits (it always should — the pool asks for them).
fn response_from_reply(id: u64, v: &Json, req: &PoolRequest, n_classes: usize) -> Response {
    let slot = v.get("slot").and_then(Json::as_usize).unwrap_or(0);
    let group = v.get("group").and_then(Json::as_i64).unwrap_or(0) as u64;
    let logits: Vec<f32> = match v.get("logits").and_then(Json::as_arr) {
        Some(a) if !a.is_empty() => {
            a.iter().map(|x| x.as_f64().unwrap_or(0.0) as f32).collect()
        }
        _ => {
            let mut one_hot = |class: usize, out: &mut Vec<f32>| {
                let mut row = vec![0.0f32; n_classes];
                if class < n_classes {
                    row[class] = 1.0;
                }
                out.extend_from_slice(&row);
            };
            let mut out = Vec::new();
            if let Some(tags) = v.get("tags").and_then(Json::as_arr) {
                for t in tags {
                    one_hot(t.as_usize().unwrap_or(0), &mut out);
                }
            } else {
                one_hot(v.get("pred").and_then(Json::as_usize).unwrap_or(0), &mut out);
            }
            out
        }
    };
    Response {
        id,
        slot,
        group,
        logits: LogitsView::from_vec(logits),
        n_classes,
        // front-observed end-to-end latency (includes the shard hop)
        latency: req.submitted.elapsed(),
    }
}

/// Route one reply line to its waiter. Returns `false` when the line
/// poisons the connection (unparsable, or an uncorrelatable reply — the
/// caller must kill the connection so its in-flight requests fail over).
///
/// Factored free of sockets so the frame-reassembly proptest can drive
/// it directly with arbitrarily split/merged/interleaved reply streams.
pub(crate) fn route_reply(
    line: &str,
    shard: usize,
    map: &InFlightMap,
    shared: &ShardShared,
    events: &Channel<PoolEvent>,
    n_classes: usize,
) -> bool {
    let v = match Json::parse(line) {
        Ok(v) => v,
        Err(_) => return false,
    };
    let Some(id) = v.get("id").and_then(Json::as_f64).filter(|f| *f >= 0.0).map(|f| f as u64)
    else {
        // a null/absent id cannot be correlated (e.g. the server's
        // bad_json answer to a garbled frame): the only safe move is to
        // drop the connection and resubmit everything in flight on it
        return false;
    };
    let entry = map.lock().remove(&id);
    let Some(entry) = entry else {
        return true; // late reply for a request already failed over
    };
    match entry {
        Entry::Probe { sent } => {
            shared.note_rtt(sent.elapsed());
            true
        }
        Entry::Req(req) => {
            shared.in_flight.fetch_sub(1, Ordering::Relaxed);
            if v.get("ok").and_then(Json::as_bool) == Some(true) {
                let elapsed = req.submitted.elapsed();
                shared.note_rtt(elapsed);
                shared.e2e.record_duration(elapsed);
                shared.completed.fetch_add(1, Ordering::Relaxed);
                let resp = response_from_reply(id, &v, &req, n_classes);
                req.done.fulfill(Ok(resp));
                return true;
            }
            let code = v.get("error").and_then(Json::as_str).unwrap_or("").to_string();
            let msg = v.get("message").and_then(Json::as_str).unwrap_or("").to_string();
            match code.as_str() {
                "expired" | "deadline" => {
                    shared.expired.fetch_add(1, Ordering::Relaxed);
                    req.done.fulfill(Err(EngineError::DeadlineExceeded));
                }
                // transient shard-side conditions: place elsewhere. The
                // send blocks rather than dropping — losing the event
                // would mis-answer an admitted request as Shutdown while
                // the engine is still up. The monitor is the sole
                // consumer and never blocks behind this channel, so a
                // full buffer only delays the retry. A closed channel
                // means real router shutdown, and the dropped
                // completion's guard answers typed Shutdown.
                "queue_full" | "overloaded" | "shutdown" | "unavailable" => {
                    let _ = events.send(PoolEvent::Retry { shard, req });
                }
                _ => req
                    .done
                    .fulfill(Err(EngineError::WorkerFailed(format!("shard error {code}: {msg}")))),
            }
            true
        }
    }
}

/// Drain every in-flight entry of a dying connection: probes are
/// dropped, requests become failover orphans.
pub(crate) fn drain_orphans(map: &InFlightMap, shared: &ShardShared) -> Vec<PoolRequest> {
    let entries: Vec<Entry> = {
        let mut m = map.lock();
        m.drain().map(|(_, e)| e).collect()
    };
    let mut orphans = Vec::new();
    for e in entries {
        if let Entry::Req(r) = e {
            shared.in_flight.fetch_sub(1, Ordering::Relaxed);
            orphans.push(*r);
        }
    }
    orphans
}

// ---------------------------------------------------------------------------
// one live connection
// ---------------------------------------------------------------------------

/// A live v2 connection to one shard: locked writer + reader thread.
pub(crate) struct ShardConn {
    pub generation: u64,
    /// writer half (the reader thread owns a separate clone)
    writer: TrackedMutex<TcpStream>,
    /// handle for shutdown (same underlying socket as `writer`)
    sock: TcpStream,
    pub map: InFlightMap,
    dead: AtomicBool,
    reader: TrackedMutex<Option<std::thread::JoinHandle<()>>>,
}

impl ShardConn {
    /// Wrap an already-handshaken stream and start its reader thread.
    pub fn start(
        shard: usize,
        generation: u64,
        stream: TcpStream,
        shared: Arc<ShardShared>,
        events: Channel<PoolEvent>,
        n_classes: usize,
    ) -> Result<Arc<ShardConn>> {
        let reader_stream = stream.try_clone().context("cloning shard stream")?;
        let conn = Arc::new(ShardConn {
            generation,
            writer: TrackedMutex::new(
                "pool.conn_writer",
                rank::CONN_WRITER,
                stream.try_clone().context("cloning shard stream")?,
            ),
            sock: stream,
            map: new_in_flight_map(),
            dead: AtomicBool::new(false),
            reader: TrackedMutex::new("pool.conn_reader", rank::THREAD_HANDLE, None),
        });
        let c = conn.clone();
        let handle = std::thread::Builder::new()
            .name(format!("datamux-shard-{shard}-rx"))
            .spawn(move || {
                c.read_loop(reader_stream, shard, &shared, &events, n_classes);
                c.dead.store(true, Ordering::Release);
                let orphans = drain_orphans(&c.map, &shared);
                // blocking send: orphans must reach the monitor or the
                // failover guarantee is void (a full channel delays,
                // never drops; closed means shutdown, where the dropped
                // completions answer typed Shutdown)
                let _ = events.send(PoolEvent::ConnDown {
                    shard,
                    generation: c.generation,
                    orphans,
                });
            })?;
        *conn.reader.lock() = Some(handle);
        Ok(conn)
    }

    fn read_loop(
        &self,
        mut stream: TcpStream,
        shard: usize,
        shared: &ShardShared,
        events: &Channel<PoolEvent>,
        n_classes: usize,
    ) {
        let mut asm = LineAssembler::new(1 << 22); // replies can carry logits
        let mut buf = [0u8; 16 * 1024];
        let mut lines: Vec<String> = Vec::new();
        loop {
            let n = match stream.read(&mut buf) {
                Ok(0) | Err(_) => return,
                Ok(n) => n,
            };
            if asm.feed(&buf[..n], &mut lines).is_err() {
                return; // oversized reply: framing no longer trusted
            }
            for line in lines.drain(..) {
                if line.is_empty() {
                    continue;
                }
                if !route_reply(&line, shard, &self.map, shared, events, n_classes) {
                    self.shutdown_now();
                    return;
                }
            }
        }
    }

    pub fn is_dead(&self) -> bool {
        self.dead.load(Ordering::Acquire)
    }

    /// Force the connection down; the reader exits and drains orphans.
    pub fn shutdown_now(&self) {
        self.dead.store(true, Ordering::Release);
        let _ = self.sock.shutdown(Shutdown::Both);
    }

    /// Write one request/probe line, with fault injection. An `Err`
    /// means the connection is unusable (the caller fails over).
    pub fn send_line(&self, json: &Json, fault: &FaultInjector) -> std::io::Result<()> {
        let mut frame = json.to_string().into_bytes();
        frame.push(b'\n');
        let fx = fault.write_fx(frame.len());
        if let Some(d) = fx.delay {
            std::thread::sleep(d);
        }
        if let Some(i) = fx.garble_at {
            frame[i] = 0x01;
        }
        let mut w = self.writer.lock();
        if fx.drop_mid_frame {
            // write half a frame, then kill the socket: the server sees
            // a truncated line, the reader exits, failover resubmits
            let _ = w.write_all(&frame[..frame.len() / 2]);
            drop(w);
            self.shutdown_now();
            return Err(std::io::Error::new(
                std::io::ErrorKind::ConnectionAborted,
                "fault injection dropped the connection mid-frame",
            ));
        }
        match fx.split_at {
            Some(i) => {
                w.write_all(&frame[..i])?;
                w.flush()?;
                std::thread::sleep(Duration::from_micros(50));
                w.write_all(&frame[i..])?;
            }
            None => w.write_all(&frame)?,
        }
        w.flush()
    }

    pub fn join(&self) {
        if let Some(h) = self.reader.lock().take() {
            let _ = h.join();
        }
    }
}

/// Connect to a shard and learn its model shape via a STATS handshake.
/// Fault injection can refuse the connect (chaos "refused accept").
pub(crate) fn connect_handshake(
    addr: &str,
    timeout: Duration,
    fault: &FaultInjector,
) -> Result<(TcpStream, ModelInfo)> {
    if fault.refuse_connect() {
        return Err(anyhow!("fault injection refused connect to {addr}"));
    }
    let sock_addr = addr
        .to_socket_addrs()
        .with_context(|| format!("resolving {addr}"))?
        .next()
        .ok_or_else(|| anyhow!("no address for {addr}"))?;
    let stream = TcpStream::connect_timeout(&sock_addr, timeout)
        .with_context(|| format!("connecting {addr}"))?;
    stream.set_nodelay(true).ok();
    stream.set_read_timeout(Some(timeout)).context("set handshake timeout")?;
    let mut w = stream.try_clone().context("cloning handshake stream")?;
    w.write_all(b"{\"id\":0,\"op\":\"stats\"}\n").context("handshake write")?;
    w.flush().ok();
    // read exactly one reply line under the handshake timeout
    let mut line = Vec::new();
    let mut byte = [0u8; 1];
    let mut r = stream.try_clone().context("cloning handshake stream")?;
    loop {
        match r.read(&mut byte) {
            Ok(0) => return Err(anyhow!("{addr} closed during handshake")),
            Ok(_) if byte[0] == b'\n' => break,
            Ok(_) => {
                line.push(byte[0]);
                if line.len() > 1 << 20 {
                    return Err(anyhow!("{addr} handshake reply too large"));
                }
            }
            Err(e) => return Err(anyhow!("{addr} handshake read: {e}")),
        }
    }
    let text = String::from_utf8_lossy(&line);
    let v = Json::parse(&text).map_err(|e| anyhow!("{addr} handshake parse: {e}"))?;
    let info = ModelInfo::parse(&v).with_context(|| format!("handshaking {addr}"))?;
    stream.set_read_timeout(None).ok();
    Ok((stream, info))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::request::RequestHandle;
    use crate::util::proptest::check;
    use crate::util::threadpool::OnceCellSync;

    fn mk_req(done: Completion) -> Box<PoolRequest> {
        Box::new(PoolRequest {
            content: vec![1, 45, 2],
            task: TaskKind::Classify,
            priority: Priority::Normal,
            bucket: 0,
            deadline: None,
            submitted: Instant::now(),
            sent_at: Instant::now(),
            resubmits: 0,
            done,
        })
    }

    fn register(map: &InFlightMap, shared: &ShardShared, id: u64) -> RequestHandle {
        let cell = OnceCellSync::new();
        let handle = RequestHandle { id, deadline: None, done: cell.clone() };
        map.lock().insert(id, Entry::Req(mk_req(Completion::cell(cell))));
        shared.in_flight.fetch_add(1, Ordering::Relaxed);
        handle
    }

    fn ok_reply(id: u64, pred: usize) -> String {
        let logits: Vec<&str> =
            (0..3).map(|i| if i == pred { "9.0" } else { "0.0" }).collect();
        format!(
            "{{\"id\":{id},\"ok\":true,\"pred\":{pred},\"slot\":1,\"group\":9,\
             \"us\":12,\"logits\":[{}]}}",
            logits.join(",")
        )
    }

    #[test]
    fn reply_routes_to_the_right_waiter_with_typed_payload() {
        let map: InFlightMap = new_in_flight_map();
        let shared = ShardShared::default();
        let events: Channel<PoolEvent> = Channel::bounded(8);
        let h7 = register(&map, &shared, 7);
        let h8 = register(&map, &shared, 8);
        assert!(route_reply(&ok_reply(8, 1), 0, &map, &shared, &events, 3));
        let r = h8.wait().expect("id 8 answered");
        assert_eq!(r.pred_class(), 1);
        assert_eq!(r.slot, 1);
        assert_eq!(r.n_classes, 3);
        assert!(h7.wait_timeout(Duration::from_millis(10)).is_none(), "id 7 still waiting");
        assert!(route_reply(&ok_reply(7, 0), 0, &map, &shared, &events, 3));
        assert_eq!(h7.wait().expect("id 7 answered").pred_class(), 0);
        assert_eq!(shared.completed.load(Ordering::Relaxed), 2);
        assert_eq!(shared.in_flight.load(Ordering::Relaxed), 0);
        assert!(map.lock().is_empty());
    }

    #[test]
    fn error_replies_map_to_typed_outcomes() {
        let map: InFlightMap = new_in_flight_map();
        let shared = ShardShared::default();
        let events: Channel<PoolEvent> = Channel::bounded(8);
        // deadline error -> DeadlineExceeded
        let h = register(&map, &shared, 1);
        assert!(route_reply(
            r#"{"id":1,"ok":false,"error":"deadline","message":"m"}"#,
            0,
            &map,
            &shared,
            &events,
            3
        ));
        assert_eq!(h.wait(), Err(EngineError::DeadlineExceeded));
        // queue_full -> retry event, not a completion
        let h = register(&map, &shared, 2);
        assert!(route_reply(
            r#"{"id":2,"ok":false,"error":"queue_full","message":"m"}"#,
            4,
            &map,
            &shared,
            &events,
            3
        ));
        match events.try_recv() {
            Some(PoolEvent::Retry { shard: 4, .. }) => {}
            _ => panic!("expected a Retry event"),
        }
        assert!(h.wait_timeout(Duration::from_millis(10)).is_none(), "not answered yet");
        // unknown code -> WorkerFailed
        let h = register(&map, &shared, 3);
        assert!(route_reply(
            r#"{"id":3,"ok":false,"error":"worker_failed","message":"boom"}"#,
            0,
            &map,
            &shared,
            &events,
            3
        ));
        match h.wait() {
            Err(EngineError::WorkerFailed(m)) => assert!(m.contains("boom"), "{m}"),
            other => panic!("expected WorkerFailed, got {other:?}"),
        }
    }

    #[test]
    fn uncorrelatable_replies_poison_the_connection() {
        let map: InFlightMap = new_in_flight_map();
        let shared = ShardShared::default();
        let events: Channel<PoolEvent> = Channel::bounded(8);
        let _h = register(&map, &shared, 1);
        assert!(!route_reply("{not json", 0, &map, &shared, &events, 3));
        assert!(
            !route_reply(r#"{"id":null,"ok":false,"error":"bad_json"}"#, 0, &map, &shared, &events, 3),
            "a null id cannot be correlated"
        );
        // an unknown-but-valid id is a late reply after failover: ignored
        assert!(route_reply(&ok_reply(999, 0), 0, &map, &shared, &events, 3));
        assert_eq!(map.lock().len(), 1, "the waiter is untouched");
    }

    #[test]
    fn drained_orphans_preserve_their_requests() {
        let map: InFlightMap = new_in_flight_map();
        let shared = ShardShared::default();
        let _h1 = register(&map, &shared, 1);
        let _h2 = register(&map, &shared, 2);
        map.lock().insert(3, Entry::Probe { sent: Instant::now() });
        let orphans = drain_orphans(&map, &shared);
        assert_eq!(orphans.len(), 2, "probes are not orphans");
        assert_eq!(shared.in_flight.load(Ordering::Relaxed), 0);
        assert!(map.lock().is_empty());
    }

    #[test]
    fn request_json_carries_remaining_budget_and_logits() {
        let req = mk_req(Completion::cell(OnceCellSync::new()));
        let j = request_json(42, &req, Some(123.5));
        let text = j.to_string();
        assert!(text.contains("\"id\":42"), "{text}");
        assert!(text.contains("\"deadline_ms\":123.5"), "{text}");
        assert!(text.contains("\"logits\":true"), "{text}");
        assert!(text.contains("\"op\":\"classify\""), "{text}");
        let j = request_json(1, &req, None);
        assert!(!j.to_string().contains("deadline_ms"), "no budget -> no field");
        // defuse the test requests' completions (synchronous error path)
        let mut r = req;
        r.done.defuse();
    }

    #[test]
    fn fault_injector_is_deterministic_per_seed() {
        let a = FaultInjector::new(FaultPlan::chaos(99));
        let b = FaultInjector::new(FaultPlan::chaos(99));
        let seq_a: Vec<bool> = (0..64).map(|_| a.refuse_connect()).collect();
        let seq_b: Vec<bool> = (0..64).map(|_| b.refuse_connect()).collect();
        assert_eq!(seq_a, seq_b);
        assert!(seq_a.iter().any(|&x| x), "10% over 64 draws should fire");
        assert!(!seq_a.iter().all(|&x| x));
        let off = FaultInjector::new(FaultPlan::disabled());
        assert!((0..256).all(|_| !off.refuse_connect()));
        assert!(!off.enabled());
    }

    #[test]
    fn fault_plan_from_env_value_parses_seed() {
        // the pure injected form: no process-global env mutation (other
        // tests constructing ShardConfig::new run concurrently and read
        // the real environment)
        let p = FaultPlan::from_env_value(Some("1234"));
        assert!(p.enabled());
        assert_eq!(p.seed, 1234);
        assert!(!FaultPlan::from_env_value(None).enabled());
        assert!(!FaultPlan::from_env_value(Some("not-a-number")).enabled());
        assert!(!FaultPlan::from_env_value(Some("-3")).enabled());
    }

    /// Satellite: client-side v2 frame reassembly. Replies arrive
    /// arbitrarily split/merged across reads and interleaved out of
    /// order; every reply must reach the right waiter exactly once, and
    /// an oversized line must poison the stream, not truncate-and-parse.
    #[test]
    fn proptest_reply_reassembly_routes_exactly_once() {
        check("pool_frame_reassembly", 60, |g| {
            let n = g.sized(24);
            let map: InFlightMap = new_in_flight_map();
            let shared = ShardShared::default();
            let events: Channel<PoolEvent> = Channel::bounded(64);
            let handles: Vec<RequestHandle> =
                (0..n as u64).map(|id| register(&map, &shared, id)).collect();
            // out-of-order replies, each predicting its own id % 3
            let mut order: Vec<u64> = (0..n as u64).collect();
            let mut rng = g.rng.split();
            rng.shuffle(&mut order);
            let mut stream = String::new();
            for id in &order {
                stream.push_str(&ok_reply(*id, (*id % 3) as usize));
                stream.push('\n');
            }
            // feed in arbitrary fragments
            let bytes = stream.as_bytes();
            let mut asm = LineAssembler::new(1 << 16);
            let mut lines = Vec::new();
            let mut at = 0usize;
            while at < bytes.len() {
                let step = 1 + rng.below(40.min(bytes.len() - at)).min(bytes.len() - at - 1);
                let mut got = Vec::new();
                asm.feed(&bytes[at..at + step], &mut got)
                    .map_err(|e| format!("unexpected oversize: {e:?}"))?;
                lines.extend(got);
                at += step;
            }
            for line in &lines {
                if !route_reply(line, 0, &map, &shared, &events, 3) {
                    return Err(format!("reply poisoned the stream: {line}"));
                }
            }
            // every waiter answered exactly once, with its own payload
            for (id, h) in handles.iter().enumerate() {
                let r = h
                    .wait_timeout(Duration::from_millis(50))
                    .ok_or_else(|| format!("waiter {id} never answered"))?
                    .map_err(|e| format!("waiter {id} failed: {e}"))?;
                if r.pred_class() != id % 3 {
                    return Err(format!(
                        "waiter {id} got pred {} (crossed wires)",
                        r.pred_class()
                    ));
                }
            }
            if !map.lock().is_empty() {
                return Err("in-flight map not drained".into());
            }
            if shared.completed.load(Ordering::Relaxed) != n as u64 {
                return Err("completed counter mismatch".into());
            }
            // oversized reply line: poison, never a truncated parse
            let mut asm = LineAssembler::new(64);
            let huge = format!("{{\"id\":1,\"ok\":true,\"logits\":[{}]}}", "1,".repeat(200));
            let mut got = Vec::new();
            if asm.feed(huge.as_bytes(), &mut got).is_ok() {
                return Err("oversized line must be rejected".into());
            }
            Ok(())
        });
    }
}
