//! Nonblocking connection reactor: one epoll event loop owning every
//! client socket.
//!
//! The pre-reactor server spawned one detached OS thread per accepted
//! connection — a few hundred clients exhausted the box while the
//! engine underneath can multiplex 40 requests per forward pass. This
//! module replaces that with the classic single-threaded reactor shape:
//!
//! ```text
//!   [listener] ─┐
//!   [waker]    ─┤ epoll ──▶ per-conn rbuf ──▶ complete lines ──▶ Handler
//!   [conn fds] ─┘    ▲                                             │
//!                    └── per-conn wbuf ◀── Outbox ops (send/close/…)┘
//! ```
//!
//! * **Poller** is a minimal epoll wrapper over raw `extern "C"`
//!   bindings — the workspace builds offline with no `libc` crate, and
//!   `std` already links the platform C library, so the four syscall
//!   symbols resolve at link time.
//! * **Reactor** runs the loop on one named thread. Connections live in
//!   a slab; tokens are `(generation << 32) | slot` so a stale event for
//!   a recycled slot can never be misrouted to a new connection.
//! * **Read path**: incremental line framing into a bounded per-conn
//!   `rbuf`. A line longer than `max_line` triggers
//!   [`Handler::on_oversize`] (stage a typed goodbye) and a flush-close
//!   — the buffer is bounded, a hostile client cannot balloon memory.
//! * **Write path**: replies append to a per-conn `wbuf` and flush
//!   opportunistically; `EPOLLOUT` interest exists only while bytes are
//!   buffered. A consumer whose backlog exceeds `write_buf_cap` after a
//!   flush attempt is **disconnected** — backpressure by eviction, so a
//!   slow reader can never block the loop or other connections.
//! * **Handlers** never touch sockets: they stage [`Outbox`] ops
//!   (send / close / pause / resume), applied by the loop after each
//!   callback. `pause`/`resume` drop and restore read interest — the
//!   v1 lockstep protocol parks a connection while its one in-flight
//!   request executes, without blocking a thread.
//! * **Stop** drains: live connections get `drain_grace` to flush their
//!   write buffers, then everything is force-closed and the loop thread
//!   joins — no orphaned threads, no leaked sockets.
//!
//! Cross-thread completion delivery (the engine finishing a request on
//! a worker thread) pokes the [`Waker`] — a nonblocking socketpair the
//! loop polls like any other fd — and the loop calls
//! [`Handler::on_wake`] to drain staged results.

use std::io::{self, Read, Write};
use std::net::{SocketAddr, TcpListener, TcpStream};
use std::os::unix::io::{AsRawFd, RawFd};
use std::os::unix::net::UnixStream;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;
use std::time::{Duration, Instant};

// ---------------------------------------------------------------------------
// raw epoll / rlimit FFI (no libc crate; std links the platform libc)
// ---------------------------------------------------------------------------

/// Mirrors `struct epoll_event`. x86-64 Linux declares it packed; other
/// architectures use natural alignment.
#[cfg_attr(target_arch = "x86_64", repr(C, packed))]
#[cfg_attr(not(target_arch = "x86_64"), repr(C))]
#[derive(Clone, Copy)]
struct EpollEvent {
    events: u32,
    data: u64,
}

#[repr(C)]
struct RLimit {
    rlim_cur: u64,
    rlim_max: u64,
}

extern "C" {
    fn epoll_create1(flags: i32) -> i32;
    fn epoll_ctl(epfd: i32, op: i32, fd: i32, event: *mut EpollEvent) -> i32;
    fn epoll_wait(epfd: i32, events: *mut EpollEvent, maxevents: i32, timeout_ms: i32) -> i32;
    fn close(fd: i32) -> i32;
    fn getrlimit(resource: i32, rlim: *mut RLimit) -> i32;
    fn setrlimit(resource: i32, rlim: *const RLimit) -> i32;
}

const EPOLL_CLOEXEC: i32 = 0x80000;
const EPOLL_CTL_ADD: i32 = 1;
const EPOLL_CTL_DEL: i32 = 2;
const EPOLL_CTL_MOD: i32 = 3;
const EPOLLIN: u32 = 0x1;
const EPOLLOUT: u32 = 0x4;
const EPOLLERR: u32 = 0x8;
const EPOLLHUP: u32 = 0x10;
const EPOLLRDHUP: u32 = 0x2000;
const RLIMIT_NOFILE: i32 = 7;

/// Raise the process's open-file soft limit toward `want` (capped at
/// the hard limit) and return the resulting soft limit. Best-effort:
/// C10K-scale benches call this so 5000 sockets don't hit the default
/// 1024-fd ceiling; failure just leaves the current limit in place.
pub fn raise_nofile_limit(want: u64) -> u64 {
    // SAFETY: get/setrlimit only read/write the RLimit struct we pass by
    // valid pointer; both live on this stack frame for the whole call.
    unsafe {
        let mut lim = RLimit { rlim_cur: 0, rlim_max: 0 };
        if getrlimit(RLIMIT_NOFILE, &mut lim) != 0 {
            return 0;
        }
        if lim.rlim_cur < want {
            let new = RLimit { rlim_cur: want.min(lim.rlim_max), rlim_max: lim.rlim_max };
            let _ = setrlimit(RLIMIT_NOFILE, &new);
            if getrlimit(RLIMIT_NOFILE, &mut lim) != 0 {
                return new.rlim_cur;
            }
        }
        lim.rlim_cur
    }
}

// ---------------------------------------------------------------------------
// Poller: minimal epoll wrapper
// ---------------------------------------------------------------------------

/// One readiness event out of [`Poller::wait`].
#[derive(Debug, Clone, Copy)]
pub struct PollEvent {
    pub token: u64,
    pub readable: bool,
    pub writable: bool,
    /// peer hung up or the fd errored — treat the connection as gone
    pub hangup: bool,
}

/// Level-triggered epoll instance. `token` is an opaque u64 returned
/// with each event; interest is (readable, writable) per fd.
pub struct Poller {
    epfd: i32,
    raw: Vec<EpollEvent>,
}

impl Poller {
    pub fn new() -> io::Result<Poller> {
        // SAFETY: epoll_create1 takes no pointers; a negative return is
        // checked below before the fd is used.
        let epfd = unsafe { epoll_create1(EPOLL_CLOEXEC) };
        if epfd < 0 {
            return Err(io::Error::last_os_error());
        }
        Ok(Poller { epfd, raw: vec![EpollEvent { events: 0, data: 0 }; 1024] })
    }

    fn mask(readable: bool, writable: bool) -> u32 {
        let mut m = EPOLLRDHUP;
        if readable {
            m |= EPOLLIN;
        }
        if writable {
            m |= EPOLLOUT;
        }
        m
    }

    fn ctl(&self, op: i32, fd: RawFd, events: u32, token: u64) -> io::Result<()> {
        let mut ev = EpollEvent { events, data: token };
        // SAFETY: `ev` is a live, properly laid out (repr C) epoll_event;
        // the kernel only reads it during the call. epfd/fd validity is
        // the kernel's to check — errors surface as the -1 handled below.
        if unsafe { epoll_ctl(self.epfd, op, fd, &mut ev) } != 0 {
            return Err(io::Error::last_os_error());
        }
        Ok(())
    }

    pub fn add(&self, fd: RawFd, token: u64, readable: bool, writable: bool) -> io::Result<()> {
        self.ctl(EPOLL_CTL_ADD, fd, Self::mask(readable, writable), token)
    }

    pub fn modify(&self, fd: RawFd, token: u64, readable: bool, writable: bool) -> io::Result<()> {
        self.ctl(EPOLL_CTL_MOD, fd, Self::mask(readable, writable), token)
    }

    pub fn remove(&self, fd: RawFd) -> io::Result<()> {
        // a non-null event pointer keeps pre-2.6.9 kernels happy
        self.ctl(EPOLL_CTL_DEL, fd, 0, 0)
    }

    /// Wait for readiness, appending into `out`. `None` blocks
    /// indefinitely. Returns the number of events delivered; EINTR is
    /// retried internally.
    pub fn wait(
        &mut self,
        out: &mut Vec<PollEvent>,
        timeout: Option<Duration>,
    ) -> io::Result<usize> {
        let ms: i32 = match timeout {
            None => -1,
            Some(t) => t.as_millis().min(i32::MAX as u128) as i32,
        };
        let n = loop {
            // SAFETY: `raw` is a live Vec of repr(C) epoll_event with
            // exactly `raw.len()` writable slots; the kernel writes at
            // most `maxevents` entries, and we only read the first
            // `n <= raw.len()` below.
            let n = unsafe {
                epoll_wait(self.epfd, self.raw.as_mut_ptr(), self.raw.len() as i32, ms)
            };
            if n >= 0 {
                break n as usize;
            }
            let err = io::Error::last_os_error();
            if err.kind() != io::ErrorKind::Interrupted {
                return Err(err);
            }
        };
        for ev in &self.raw[..n] {
            // copy out of the (possibly packed) struct before testing bits
            let bits = ev.events;
            let token = ev.data;
            out.push(PollEvent {
                token,
                readable: bits & EPOLLIN != 0,
                writable: bits & EPOLLOUT != 0,
                hangup: bits & (EPOLLERR | EPOLLHUP | EPOLLRDHUP) != 0,
            });
        }
        Ok(n)
    }
}

impl Drop for Poller {
    fn drop(&mut self) {
        // SAFETY: epfd was returned by epoll_create1 and is closed exactly
        // once, here; no other code path closes it.
        unsafe {
            close(self.epfd);
        }
    }
}

// ---------------------------------------------------------------------------
// Handler contract
// ---------------------------------------------------------------------------

/// Identifies one live connection: `(generation << 32) | slab slot`.
/// After the connection closes the id is never reused (the slot is, the
/// generation is not), so late ops targeting it are dropped harmlessly.
pub type ConnId = u64;

enum Op {
    Send(Vec<u8>),
    Close,
    Pause,
    Resume,
}

/// Staged connection operations. Handlers never touch sockets directly;
/// they stage ops here and the loop applies them after the callback
/// returns — so a handler can reply to any connection (completion
/// fan-out), disconnect, or toggle read interest, all race-free.
#[derive(Default)]
pub struct Outbox {
    ops: Vec<(ConnId, Op)>,
}

impl Outbox {
    /// Queue bytes for `conn`. Flushed opportunistically; if the
    /// conn's backlog exceeds the reactor's `write_buf_cap` after a
    /// flush attempt, the conn is disconnected as a slow consumer.
    pub fn send(&mut self, conn: ConnId, bytes: Vec<u8>) {
        self.ops.push((conn, Op::Send(bytes)));
    }

    /// Flush what is queued for `conn`, then disconnect it.
    pub fn close(&mut self, conn: ConnId) {
        self.ops.push((conn, Op::Close));
    }

    /// Stop reading from `conn` (v1 lockstep: park until the in-flight
    /// request completes). Already-buffered bytes are kept.
    pub fn pause(&mut self, conn: ConnId) {
        self.ops.push((conn, Op::Pause));
    }

    /// Restore read interest on `conn` and re-scan its buffered input
    /// for complete lines.
    pub fn resume(&mut self, conn: ConnId) {
        self.ops.push((conn, Op::Resume));
    }
}

/// Protocol logic plugged into the reactor. Runs on the reactor thread;
/// `Send` so the loop thread can own it.
pub trait Handler: Send + 'static {
    /// One complete line arrived on `conn` (newline and any trailing
    /// `\r` stripped).
    fn on_line(&mut self, conn: ConnId, line: &str, out: &mut Outbox);

    /// The [`Waker`] was poked from another thread: drain staged work
    /// (e.g. engine completions) and reply via `out`.
    fn on_wake(&mut self, out: &mut Outbox);

    /// `conn` exceeded `max_line` without a newline. Stage a goodbye;
    /// the reactor flush-closes the connection right after.
    fn on_oversize(&mut self, conn: ConnId, out: &mut Outbox) {
        let _ = (conn, out);
    }

    /// `conn` is gone (peer EOF, hangup, backpressure eviction, or
    /// stop). Drop any per-conn state; replies staged for it are
    /// discarded.
    fn on_close(&mut self, conn: ConnId) {
        let _ = conn;
    }
}

/// Cross-thread wakeup handle: poke it and the reactor loop calls
/// [`Handler::on_wake`]. Cloneable, nonblocking, coalescing (multiple
/// pokes before the loop runs collapse into one wake).
#[derive(Clone)]
pub struct Waker {
    pipe: Arc<UnixStream>,
}

impl Waker {
    pub fn wake(&self) {
        // WouldBlock means the pipe already holds unread pokes — the
        // loop is waking anyway, dropping this byte is correct
        let _ = (&*self.pipe).write(&[1u8]);
    }
}

#[derive(Debug, Clone)]
pub struct ReactorConfig {
    /// accepts beyond this are turned away with a best-effort error line
    pub max_connections: usize,
    /// read-buffer bound: a line longer than this is an oversize close
    pub max_line: usize,
    /// write-backlog bound: a conn buffering more than this after a
    /// flush attempt is disconnected as a slow consumer
    pub write_buf_cap: usize,
    /// on stop (and per-conn flush-close), how long a connection gets
    /// to drain its write buffer before being force-closed
    pub drain_grace: Duration,
}

impl Default for ReactorConfig {
    fn default() -> Self {
        ReactorConfig {
            max_connections: 64,
            max_line: 64 * 1024,
            write_buf_cap: 256 * 1024,
            drain_grace: Duration::from_millis(250),
        }
    }
}

// ---------------------------------------------------------------------------
// the reactor proper
// ---------------------------------------------------------------------------

const TOKEN_LISTENER: u64 = u64::MAX;
const TOKEN_WAKER: u64 = u64::MAX - 1;
/// poll tick while idle: bounds how stale a `closing` deadline sweep
/// can get; all real work is event-driven
const POLL_TICK: Duration = Duration::from_millis(100);

struct Conn {
    stream: TcpStream,
    token: ConnId,
    rbuf: Vec<u8>,
    wbuf: Vec<u8>,
    /// already-written prefix of `wbuf`
    wpos: usize,
    paused: bool,
    /// flush-then-close mode: no more reads, close once `wbuf` drains
    /// or `close_by` passes
    closing: bool,
    close_by: Option<Instant>,
    /// interest currently registered with the poller
    want_read: bool,
    want_write: bool,
}

impl Conn {
    fn pending_write(&self) -> usize {
        self.wbuf.len() - self.wpos
    }
}

/// Owns the event loop thread. Dropping (or [`Reactor::stop`]) drains
/// and joins — the no-orphaned-threads guarantee `Server::stop` builds
/// on.
pub struct Reactor {
    local_addr: SocketAddr,
    waker: Waker,
    stop: Arc<AtomicBool>,
    thread: Option<std::thread::JoinHandle<()>>,
}

impl Reactor {
    /// Take ownership of a bound listener and start the loop thread.
    pub fn start<H: Handler>(
        listener: TcpListener,
        cfg: ReactorConfig,
        handler: H,
    ) -> io::Result<Reactor> {
        let local_addr = listener.local_addr()?;
        listener.set_nonblocking(true)?;
        let (wake_tx, wake_rx) = UnixStream::pair()?;
        wake_tx.set_nonblocking(true)?;
        wake_rx.set_nonblocking(true)?;
        let poller = Poller::new()?;
        poller.add(listener.as_raw_fd(), TOKEN_LISTENER, true, false)?;
        poller.add(wake_rx.as_raw_fd(), TOKEN_WAKER, true, false)?;
        let stop = Arc::new(AtomicBool::new(false));
        let mut lp = EventLoop {
            poller,
            listener,
            wake_rx,
            cfg,
            handler,
            stop: stop.clone(),
            conns: Vec::new(),
            gens: Vec::new(),
            free: Vec::new(),
            n_live: 0,
            outbox: Outbox::default(),
        };
        let thread = std::thread::Builder::new()
            .name("datamux-reactor".into())
            .spawn(move || lp.run())?;
        let waker = Waker { pipe: Arc::new(wake_tx) };
        Ok(Reactor { local_addr, waker, stop, thread: Some(thread) })
    }

    pub fn local_addr(&self) -> SocketAddr {
        self.local_addr
    }

    pub fn waker(&self) -> Waker {
        self.waker.clone()
    }

    /// Stop the loop: live connections get `drain_grace` to flush, then
    /// everything closes and the thread joins. Idempotent.
    pub fn stop(&mut self) {
        self.stop.store(true, Ordering::Release);
        self.waker.wake();
        if let Some(t) = self.thread.take() {
            let _ = t.join();
        }
    }
}

impl Drop for Reactor {
    fn drop(&mut self) {
        self.stop();
    }
}

struct EventLoop<H: Handler> {
    poller: Poller,
    listener: TcpListener,
    wake_rx: UnixStream,
    cfg: ReactorConfig,
    handler: H,
    stop: Arc<AtomicBool>,
    /// slab; `None` slots are free (their indices live in `free`)
    conns: Vec<Option<Conn>>,
    /// per-slot generation, bumped on close so stale tokens never match
    gens: Vec<u32>,
    free: Vec<usize>,
    n_live: usize,
    outbox: Outbox,
}

impl<H: Handler> EventLoop<H> {
    fn run(&mut self) {
        let mut events: Vec<PollEvent> = Vec::with_capacity(1024);
        let mut stopping = false;
        let mut stop_deadline = Instant::now();
        loop {
            if !stopping && self.stop.load(Ordering::Acquire) {
                stopping = true;
                stop_deadline = Instant::now() + self.cfg.drain_grace;
                let _ = self.poller.remove(self.listener.as_raw_fd());
                for idx in 0..self.conns.len() {
                    self.begin_close(idx);
                }
            }
            if stopping && (self.n_live == 0 || Instant::now() >= stop_deadline) {
                for idx in 0..self.conns.len() {
                    self.close_conn(idx, true);
                }
                return;
            }
            events.clear();
            if self.poller.wait(&mut events, Some(POLL_TICK)).is_err() {
                return; // epoll fd itself failed; nothing sane left to do
            }
            for i in 0..events.len() {
                let ev = events[i];
                match ev.token {
                    TOKEN_LISTENER => {
                        if !stopping {
                            self.accept_ready();
                        }
                    }
                    TOKEN_WAKER => {
                        let mut sink = [0u8; 64];
                        while matches!((&self.wake_rx).read(&mut sink), Ok(n) if n > 0) {}
                        self.handler.on_wake(&mut self.outbox);
                        self.apply_outbox();
                    }
                    token => self.conn_event(token, ev),
                }
            }
            self.sweep_closing();
        }
    }

    // -- slab ------------------------------------------------------------

    fn slot_of(&self, token: ConnId) -> Option<usize> {
        let idx = (token & 0xffff_ffff) as usize;
        let generation = (token >> 32) as u32;
        match self.conns.get(idx) {
            Some(Some(_)) if self.gens[idx] == generation => Some(idx),
            _ => None,
        }
    }

    fn accept_ready(&mut self) {
        loop {
            match self.listener.accept() {
                Ok((stream, _)) => {
                    if self.n_live >= self.cfg.max_connections {
                        // best effort; the accepted fd is blocking but the
                        // message is one small write
                        let mut s = stream;
                        let _ = s.write_all(b"ERR too many connections\n");
                        continue;
                    }
                    if stream.set_nonblocking(true).is_err() {
                        continue;
                    }
                    let _ = stream.set_nodelay(true);
                    let idx = match self.free.pop() {
                        Some(i) => i,
                        None => {
                            self.conns.push(None);
                            self.gens.push(0);
                            self.conns.len() - 1
                        }
                    };
                    let token = ((self.gens[idx] as u64) << 32) | idx as u64;
                    if self.poller.add(stream.as_raw_fd(), token, true, false).is_err() {
                        self.free.push(idx);
                        continue;
                    }
                    self.conns[idx] = Some(Conn {
                        stream,
                        token,
                        rbuf: Vec::new(),
                        wbuf: Vec::new(),
                        wpos: 0,
                        paused: false,
                        closing: false,
                        close_by: None,
                        want_read: true,
                        want_write: false,
                    });
                    self.n_live += 1;
                }
                Err(e) if e.kind() == io::ErrorKind::WouldBlock => break,
                Err(_) => break,
            }
        }
    }

    // -- per-connection event handling -----------------------------------

    fn conn_event(&mut self, token: ConnId, ev: PollEvent) {
        let Some(idx) = self.slot_of(token) else { return };
        if ev.writable {
            if !self.flush(idx) {
                return;
            }
            // a closing conn that just drained is done
            if let Some(Some(c)) = self.conns.get(idx) {
                if c.closing && c.pending_write() == 0 {
                    self.close_conn(idx, true);
                    return;
                }
            }
        }
        if ev.readable {
            if !self.read_ready(idx) {
                return;
            }
        }
        if ev.hangup {
            // only after read: a FIN with final data still delivers it
            self.close_conn(idx, true);
            return;
        }
        self.update_interest(idx);
    }

    /// Pull everything currently readable into rbuf and dispatch
    /// complete lines. Returns false if the conn was closed.
    fn read_ready(&mut self, idx: usize) -> bool {
        let mut chunk = [0u8; 4096];
        loop {
            let conn = match &mut self.conns[idx] {
                Some(c) => c,
                None => return false,
            };
            if conn.paused || conn.closing {
                return true;
            }
            match conn.stream.read(&mut chunk) {
                Ok(0) => {
                    self.close_conn(idx, true);
                    return false;
                }
                Ok(n) => {
                    conn.rbuf.extend_from_slice(&chunk[..n]);
                    if !self.drain_lines(idx) {
                        return false;
                    }
                }
                Err(e) if e.kind() == io::ErrorKind::WouldBlock => return true,
                Err(e) if e.kind() == io::ErrorKind::Interrupted => continue,
                Err(_) => {
                    self.close_conn(idx, true);
                    return false;
                }
            }
        }
    }

    /// Dispatch every complete buffered line on `idx` (stopping early if
    /// a handler pauses or closes it). Returns false if the conn closed.
    fn drain_lines(&mut self, idx: usize) -> bool {
        loop {
            let (token, raw) = {
                let conn = match &mut self.conns[idx] {
                    Some(c) => c,
                    None => return false,
                };
                if conn.paused || conn.closing {
                    return true;
                }
                match conn.rbuf.iter().position(|&b| b == b'\n') {
                    None => {
                        if conn.rbuf.len() > self.cfg.max_line {
                            let token = conn.token;
                            self.handler.on_oversize(token, &mut self.outbox);
                            self.apply_outbox();
                            self.begin_close(idx);
                            return false;
                        }
                        return true;
                    }
                    Some(pos) => {
                        let mut raw: Vec<u8> = conn.rbuf.drain(..=pos).collect();
                        raw.pop(); // the newline
                        if raw.last() == Some(&b'\r') {
                            raw.pop();
                        }
                        (conn.token, raw)
                    }
                }
            };
            let line = String::from_utf8_lossy(&raw);
            self.handler.on_line(token, &line, &mut self.outbox);
            self.apply_outbox();
        }
    }

    /// Apply staged handler ops. Runs after every handler callback, so
    /// a `pause` staged by `on_line` takes effect before the next
    /// buffered line is dispatched.
    fn apply_outbox(&mut self) {
        while !self.outbox.ops.is_empty() {
            let ops = std::mem::take(&mut self.outbox.ops);
            for (token, op) in ops {
                let Some(idx) = self.slot_of(token) else { continue };
                match op {
                    Op::Send(bytes) => {
                        // slot_of validated the generation, so the slot
                        // is occupied; stay defensive rather than panic
                        // the reactor thread on a bookkeeping bug
                        if let Some(conn) = self.conns[idx].as_mut() {
                            conn.wbuf.extend_from_slice(&bytes);
                        }
                        if !self.flush(idx) {
                            continue;
                        }
                        let evict = self.conns[idx]
                            .as_ref()
                            .is_some_and(|c| c.pending_write() > self.cfg.write_buf_cap);
                        if evict {
                            // slow consumer: evict rather than let one
                            // unread backlog grow without bound
                            self.close_conn(idx, true);
                            continue;
                        }
                        self.update_interest(idx);
                    }
                    Op::Close => self.begin_close(idx),
                    Op::Pause => {
                        if let Some(conn) = self.conns[idx].as_mut() {
                            conn.paused = true;
                        }
                        self.update_interest(idx);
                    }
                    Op::Resume => {
                        if let Some(conn) = self.conns[idx].as_mut() {
                            conn.paused = false;
                        }
                        self.update_interest(idx);
                        // lines may already be buffered from before the
                        // pause; dispatch them now (may stage more ops,
                        // picked up by the outer while)
                        self.drain_lines(idx);
                    }
                }
            }
        }
    }

    /// Write as much buffered output as the socket accepts. Returns
    /// false if the conn was closed by a write error.
    fn flush(&mut self, idx: usize) -> bool {
        loop {
            let conn = match &mut self.conns[idx] {
                Some(c) => c,
                None => return false,
            };
            if conn.pending_write() == 0 {
                conn.wbuf.clear();
                conn.wpos = 0;
                return true;
            }
            match conn.stream.write(&conn.wbuf[conn.wpos..]) {
                Ok(0) => {
                    self.close_conn(idx, true);
                    return false;
                }
                Ok(n) => {
                    conn.wpos += n;
                    // compact once fully drained (cheap; keeps the buffer
                    // reusable without unbounded growth of the dead prefix)
                    if conn.wpos == conn.wbuf.len() {
                        conn.wbuf.clear();
                        conn.wpos = 0;
                        return true;
                    }
                }
                Err(e) if e.kind() == io::ErrorKind::WouldBlock => return true,
                Err(e) if e.kind() == io::ErrorKind::Interrupted => continue,
                Err(_) => {
                    self.close_conn(idx, true);
                    return false;
                }
            }
        }
    }

    /// Reconcile the poller's interest with the conn's state: read while
    /// not paused/closing, write while output is buffered.
    fn update_interest(&mut self, idx: usize) {
        let Some(Some(conn)) = self.conns.get_mut(idx) else { return };
        let want_read = !conn.paused && !conn.closing;
        let want_write = conn.pending_write() > 0;
        if want_read != conn.want_read || want_write != conn.want_write {
            conn.want_read = want_read;
            conn.want_write = want_write;
            let fd = conn.stream.as_raw_fd();
            let token = conn.token;
            let _ = self.poller.modify(fd, token, want_read, want_write);
        }
    }

    /// Flush-then-close: drain what we can now; if output remains, keep
    /// the conn write-only until it drains or `drain_grace` passes.
    fn begin_close(&mut self, idx: usize) {
        {
            let Some(Some(conn)) = self.conns.get_mut(idx) else { return };
            if conn.closing {
                return;
            }
            conn.closing = true;
            conn.close_by = Some(Instant::now() + self.cfg.drain_grace);
        }
        if !self.flush(idx) {
            return; // write error already closed it
        }
        let drained = self.conns[idx].as_ref().is_some_and(|c| c.pending_write() == 0);
        if drained {
            self.close_conn(idx, true);
        } else {
            self.update_interest(idx);
        }
    }

    /// Force-close `closing` conns whose drain grace has passed.
    fn sweep_closing(&mut self) {
        let now = Instant::now();
        for idx in 0..self.conns.len() {
            let overdue = self.conns[idx]
                .as_ref()
                .is_some_and(|c| c.closing && c.close_by.is_some_and(|t| now >= t));
            if overdue {
                self.close_conn(idx, true);
            }
        }
    }

    fn close_conn(&mut self, idx: usize, notify: bool) {
        let Some(conn) = self.conns.get_mut(idx).and_then(Option::take) else { return };
        let token = conn.token;
        let _ = self.poller.remove(conn.stream.as_raw_fd());
        self.gens[idx] = self.gens[idx].wrapping_add(1);
        self.free.push(idx);
        self.n_live -= 1;
        drop(conn); // closes the fd
        if notify {
            self.handler.on_close(token);
            self.apply_outbox();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::Mutex;

    fn connect(addr: SocketAddr) -> TcpStream {
        let s = TcpStream::connect(addr).expect("connect");
        s.set_read_timeout(Some(Duration::from_secs(5))).unwrap();
        s
    }

    fn read_line(s: &mut TcpStream) -> String {
        let mut out = Vec::new();
        let mut b = [0u8; 1];
        loop {
            match s.read(&mut b) {
                Ok(0) => break,
                Ok(_) if b[0] == b'\n' => break,
                Ok(_) => out.push(b[0]),
                Err(e) => panic!("read: {e}"),
            }
        }
        String::from_utf8(out).unwrap()
    }

    /// Echoes each line back; records closes for assertions.
    struct Echo {
        closed: Arc<Mutex<Vec<ConnId>>>,
    }

    impl Handler for Echo {
        fn on_line(&mut self, conn: ConnId, line: &str, out: &mut Outbox) {
            out.send(conn, format!("echo {line}\n").into_bytes());
        }

        fn on_wake(&mut self, _out: &mut Outbox) {}

        fn on_oversize(&mut self, conn: ConnId, out: &mut Outbox) {
            out.send(conn, b"ERR line too long\n".to_vec());
        }

        fn on_close(&mut self, conn: ConnId) {
            self.closed.lock().unwrap().push(conn);
        }
    }

    fn echo_reactor(cfg: ReactorConfig) -> (Reactor, Arc<Mutex<Vec<ConnId>>>) {
        let closed: Arc<Mutex<Vec<ConnId>>> = Arc::default();
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let r = Reactor::start(listener, cfg, Echo { closed: closed.clone() }).unwrap();
        (r, closed)
    }

    #[test]
    fn poller_reports_readiness() {
        let (a, b) = UnixStream::pair().unwrap();
        a.set_nonblocking(true).unwrap();
        b.set_nonblocking(true).unwrap();
        let mut p = Poller::new().unwrap();
        p.add(b.as_raw_fd(), 7, true, false).unwrap();
        let mut evs = Vec::new();
        assert_eq!(p.wait(&mut evs, Some(Duration::from_millis(10))).unwrap(), 0, "quiet fd");
        (&a).write_all(b"x").unwrap();
        evs.clear();
        assert_eq!(p.wait(&mut evs, Some(Duration::from_secs(2))).unwrap(), 1);
        assert_eq!(evs[0].token, 7);
        assert!(evs[0].readable);
        p.remove(b.as_raw_fd()).unwrap();
    }

    #[test]
    fn lines_split_across_writes_reassemble() {
        let (mut r, _closed) = echo_reactor(ReactorConfig::default());
        let mut c = connect(r.local_addr());
        c.write_all(b"hel").unwrap();
        std::thread::sleep(Duration::from_millis(30));
        c.write_all(b"lo\nwor").unwrap();
        assert_eq!(read_line(&mut c), "echo hello");
        c.write_all(b"ld\n").unwrap();
        assert_eq!(read_line(&mut c), "echo world");
        r.stop();
    }

    #[test]
    fn oversized_line_gets_typed_error_then_disconnect() {
        let cfg = ReactorConfig { max_line: 64, ..ReactorConfig::default() };
        let (mut r, closed) = echo_reactor(cfg);
        let mut c = connect(r.local_addr());
        c.write_all(&vec![b'x'; 400]).unwrap(); // no newline, over the cap
        assert_eq!(read_line(&mut c), "ERR line too long");
        let mut rest = Vec::new();
        c.read_to_end(&mut rest).expect("server closes after the error");
        assert!(rest.is_empty());
        r.stop();
        assert_eq!(closed.lock().unwrap().len(), 1, "handler told about the close");
    }

    #[test]
    fn stop_closes_live_connections_and_joins_the_thread() {
        let (mut r, closed) = echo_reactor(ReactorConfig::default());
        let mut c1 = connect(r.local_addr());
        let mut c2 = connect(r.local_addr());
        c1.write_all(b"ping\n").unwrap();
        assert_eq!(read_line(&mut c1), "echo ping");
        r.stop(); // joins: after this the loop thread is gone
        let mut rest = Vec::new();
        c1.read_to_end(&mut rest).expect("clean EOF");
        c2.read_to_end(&mut rest).expect("clean EOF");
        assert_eq!(closed.lock().unwrap().len(), 2, "both conns saw on_close");
        // no datamux-reactor thread survives
        let mut names = String::new();
        for t in std::fs::read_dir("/proc/self/task").unwrap() {
            let p = t.unwrap().path().join("comm");
            names.push_str(&std::fs::read_to_string(p).unwrap_or_default());
        }
        assert!(!names.contains("datamux-reactor"), "orphaned reactor thread: {names}");
    }

    #[test]
    fn over_capacity_accept_is_turned_away() {
        let cfg = ReactorConfig { max_connections: 1, ..ReactorConfig::default() };
        let (mut r, _closed) = echo_reactor(cfg);
        let mut keep = connect(r.local_addr());
        keep.write_all(b"a\n").unwrap();
        assert_eq!(read_line(&mut keep), "echo a");
        let mut extra = connect(r.local_addr());
        assert_eq!(read_line(&mut extra), "ERR too many connections");
        let mut rest = Vec::new();
        extra.read_to_end(&mut rest).expect("refused conn is closed");
        // the original connection still works
        keep.write_all(b"b\n").unwrap();
        assert_eq!(read_line(&mut keep), "echo b");
        r.stop();
    }

    #[test]
    fn slow_reader_is_evicted_without_stalling_others() {
        /// Answers "blast" with a 256 KiB payload — amplification that
        /// outruns kernel socket buffering once the client stops reading.
        struct Blast {
            closed: Arc<Mutex<Vec<ConnId>>>,
        }
        impl Handler for Blast {
            fn on_line(&mut self, conn: ConnId, line: &str, out: &mut Outbox) {
                if line == "ping" {
                    out.send(conn, b"pong\n".to_vec());
                } else {
                    let mut big = vec![b'z'; 256 * 1024];
                    big.push(b'\n');
                    out.send(conn, big);
                }
            }

            fn on_wake(&mut self, _out: &mut Outbox) {}

            fn on_close(&mut self, conn: ConnId) {
                self.closed.lock().unwrap().push(conn);
            }
        }

        let closed: Arc<Mutex<Vec<ConnId>>> = Arc::default();
        let cfg = ReactorConfig { write_buf_cap: 8 * 1024, ..ReactorConfig::default() };
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let mut r = Reactor::start(listener, cfg, Blast { closed: closed.clone() }).unwrap();

        let mut slow = connect(r.local_addr());
        let mut fast = connect(r.local_addr());
        // 128 requests x 256 KiB replies = 32 MiB aimed at a client that
        // never reads: far past socket buffers plus the 8 KiB wbuf cap
        for _ in 0..128 {
            slow.write_all(b"blast\n").unwrap();
        }
        // the healthy connection keeps getting prompt answers meanwhile
        for _ in 0..3 {
            fast.write_all(b"ping\n").unwrap();
            assert_eq!(read_line(&mut fast), "pong");
        }
        // the reactor evicts the slow reader instead of buffering forever
        let t0 = Instant::now();
        while closed.lock().unwrap().is_empty() && t0.elapsed() < Duration::from_secs(10) {
            std::thread::sleep(Duration::from_millis(10));
        }
        assert_eq!(closed.lock().unwrap().len(), 1, "slow reader evicted");
        // the evicted socket terminates (EOF after draining what the
        // kernel already buffered, or a reset — either ends the conn)
        let _ = slow.read_to_end(&mut Vec::new());
        // and the fast connection is still live afterwards
        fast.write_all(b"ping\n").unwrap();
        assert_eq!(read_line(&mut fast), "pong");
        r.stop();
    }

    #[test]
    fn raise_nofile_limit_reports_a_positive_limit() {
        let lim = raise_nofile_limit(1024);
        assert!(lim >= 256, "soft NOFILE limit unreasonably low: {lim}");
    }
}
