//! Request / response types and the completion plumbing.
//!
//! Every in-flight request carries a [`Completion`]: either a one-shot
//! cell behind a [`RequestHandle`], or a tagged entry on a shared
//! [`CompletionQueue`](super::CompletionQueue) (the pipelined-server
//! path). `Completion` fulfills **exactly once** — and if a `Request` is
//! dropped unfulfilled anywhere in the engine (queue teardown, worker
//! death, batcher exit), the drop guard fails it with
//! [`EngineError::Shutdown`] so callers can never hang on `wait()`.

use std::sync::Arc;
use std::time::{Duration, Instant};

use crate::util::threadpool::OnceCellSync;

use super::api::CompletionQueue;

/// Why a request that was accepted did not produce a response.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum EngineError {
    /// the executing worker failed; the message carries the cause chain
    WorkerFailed(String),
    /// the request's deadline passed before it reached a model execution
    DeadlineExceeded,
    /// the engine shut down (or dropped the request) before executing it
    Shutdown,
}

impl EngineError {
    /// Stable machine-readable code (used by wire protocol v2).
    pub fn code(&self) -> &'static str {
        match self {
            EngineError::WorkerFailed(_) => "worker_failed",
            EngineError::DeadlineExceeded => "deadline",
            EngineError::Shutdown => "shutdown",
        }
    }
}

impl std::fmt::Display for EngineError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            EngineError::WorkerFailed(msg) => write!(f, "worker failed: {msg}"),
            EngineError::DeadlineExceeded => write!(f, "deadline exceeded"),
            EngineError::Shutdown => write!(f, "engine shut down before execution"),
        }
    }
}

impl std::error::Error for EngineError {}

pub(crate) enum CompletionInner {
    Cell(OnceCellSync<Result<Response, EngineError>>),
    Queue { tag: u64, queue: CompletionQueue },
}

/// Exactly-once completion slot with a fail-on-drop guard.
pub struct Completion {
    inner: Option<CompletionInner>,
}

impl Completion {
    pub(crate) fn cell(cell: OnceCellSync<Result<Response, EngineError>>) -> Self {
        Completion { inner: Some(CompletionInner::Cell(cell)) }
    }

    pub(crate) fn queue(tag: u64, queue: CompletionQueue) -> Self {
        Completion { inner: Some(CompletionInner::Queue { tag, queue }) }
    }

    pub(crate) fn fulfill(mut self, result: Result<Response, EngineError>) {
        Self::deliver(self.inner.take(), result);
    }

    /// Disarm the drop guard without fulfilling (the caller is reporting
    /// the failure synchronously instead).
    pub(crate) fn defuse(&mut self) {
        self.inner = None;
    }

    fn deliver(inner: Option<CompletionInner>, result: Result<Response, EngineError>) {
        match inner {
            None => {}
            Some(CompletionInner::Cell(cell)) => cell.set(result),
            Some(CompletionInner::Queue { tag, queue }) => {
                // never block an engine thread on a slow consumer; a full
                // queue drops the completion (consumer gone or stalled)
                let _ = queue.try_send((tag, result));
            }
        }
    }
}

impl Drop for Completion {
    fn drop(&mut self) {
        Self::deliver(self.inner.take(), Err(EngineError::Shutdown));
    }
}

/// A single admitted request: one framed content row
/// (`[CLS] ... [SEP] ...`), **unpadded** — padding to the request's
/// sequence-length bucket happens at batch assembly, against the
/// bucket's precomputed template.
pub struct Request {
    pub id: u64,
    /// framed ids, `1..=seq_len_max` tokens, no trailing `[PAD]`s needed
    pub content: Vec<i32>,
    /// index into the engine's [`Buckets`](super::Buckets) registry —
    /// the smallest bucket whose length fits `content`; assigned at
    /// admission so queues and batchers can route by shape without
    /// re-deriving it
    pub bucket: usize,
    pub submitted: Instant,
    /// absolute deadline; expired requests are failed at batch assembly
    pub deadline: Option<Instant>,
    /// SLO class — routes the request into its class FIFO within the
    /// bucket queue and keys per-class queue-wait accounting
    pub priority: super::Priority,
    pub(crate) done: Completion,
}

impl Request {
    pub(crate) fn fulfill(self, result: Result<Response, EngineError>) {
        self.done.fulfill(result);
    }

    pub(crate) fn expired(&self, now: Instant) -> bool {
        self.deadline.map_or(false, |d| d <= now)
    }
}

/// Zero-copy view into one request's slice of a batch's logits.
///
/// The scheduler executes one model call per batch and hands every
/// response a *view* of the shared flat output (`Arc<[f32]>` plus
/// offset/len) instead of copying `per_slot_len` floats per request —
/// steady-state demux performs no per-request copy. Derefs to `[f32]`,
/// so callers index, slice and iterate it exactly like the `Vec<f32>`
/// it replaced; use [`LogitsView::to_vec`] only when an owned buffer is
/// genuinely needed.
#[derive(Clone)]
pub struct LogitsView {
    data: Arc<[f32]>,
    offset: usize,
    len: usize,
}

impl LogitsView {
    /// View `data[offset..offset + len]` without copying.
    pub fn shared(data: Arc<[f32]>, offset: usize, len: usize) -> Self {
        assert!(
            offset.checked_add(len).is_some_and(|end| end <= data.len()),
            "logits view [{offset}, {offset}+{len}) out of range for buffer of {}",
            data.len()
        );
        LogitsView { data, offset, len }
    }

    /// Wrap an owned vector (single-response paths and tests).
    pub fn from_vec(v: Vec<f32>) -> Self {
        let len = v.len();
        LogitsView { data: v.into(), offset: 0, len }
    }

    pub fn as_slice(&self) -> &[f32] {
        &self.data[self.offset..self.offset + self.len]
    }

    /// Copy the view into an owned vector.
    pub fn to_vec(&self) -> Vec<f32> {
        self.as_slice().to_vec()
    }

    /// True when both views share the same underlying batch buffer —
    /// the zero-copy invariant tests assert on this.
    pub fn same_buffer(&self, other: &Self) -> bool {
        Arc::ptr_eq(&self.data, &other.data)
    }

    /// How many views are currently alive on the underlying buffer.
    pub fn shared_count(&self) -> usize {
        Arc::strong_count(&self.data)
    }
}

impl std::ops::Deref for LogitsView {
    type Target = [f32];
    fn deref(&self) -> &[f32] {
        self.as_slice()
    }
}

impl From<Vec<f32>> for LogitsView {
    fn from(v: Vec<f32>) -> Self {
        Self::from_vec(v)
    }
}

impl std::fmt::Debug for LogitsView {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_list().entries(self.as_slice().iter()).finish()
    }
}

impl PartialEq for LogitsView {
    fn eq(&self, other: &Self) -> bool {
        self.as_slice() == other.as_slice()
    }
}

/// The demultiplexed result for one request.
#[derive(Debug, Clone, PartialEq)]
pub struct Response {
    pub id: u64,
    /// which mux slot (paper's index i) served this request — exposed
    /// because per-index accuracy varies (paper A3 / Fig 7b)
    pub slot: usize,
    /// group sequence number (diagnostics)
    pub group: u64,
    /// task logits for this request (cls -> n_classes, token ->
    /// seq_len * n_classes): a shared view of the batch output, not an
    /// owned copy
    pub logits: LogitsView,
    pub n_classes: usize,
    pub latency: Duration,
}

impl Response {
    /// Sentence-level prediction (argmax over class logits).
    pub fn pred_class(&self) -> usize {
        argmax(&self.logits[..self.n_classes])
    }

    /// Token-level predictions (argmax per position).
    pub fn pred_tokens(&self) -> Vec<usize> {
        self.logits.chunks_exact(self.n_classes).map(argmax).collect()
    }
}

pub fn argmax(xs: &[f32]) -> usize {
    let mut best = 0;
    for (i, &x) in xs.iter().enumerate() {
        if x > xs[best] {
            best = i;
        }
    }
    best
}

/// Caller-side handle; waits until the engine fulfills the request.
#[derive(Clone)]
pub struct RequestHandle {
    pub id: u64,
    /// absolute deadline mirrored from the request (drives `wait_deadline`)
    pub deadline: Option<Instant>,
    pub(crate) done: OnceCellSync<Result<Response, EngineError>>,
}

impl RequestHandle {
    /// Block until the engine fulfills the request. Cannot hang: every
    /// accepted request is fulfilled with a `Response` or an
    /// [`EngineError`], even across worker death and shutdown.
    pub fn wait(&self) -> Result<Response, EngineError> {
        self.done.wait()
    }

    /// Wait with a caller-chosen timeout; `None` when it elapses first.
    pub fn wait_timeout(&self, d: Duration) -> Option<Result<Response, EngineError>> {
        self.done.wait_timeout(d)
    }

    /// Deadline-aware wait: block until the request's own deadline, then
    /// give up with [`EngineError::DeadlineExceeded`]. Without a
    /// deadline this is `wait()`.
    pub fn wait_deadline(&self) -> Result<Response, EngineError> {
        match self.deadline {
            None => self.wait(),
            Some(dl) => {
                let now = Instant::now();
                let left = dl.saturating_duration_since(now);
                match self.done.wait_timeout(left) {
                    Some(r) => r,
                    None => Err(EngineError::DeadlineExceeded),
                }
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::threadpool::Channel;

    #[test]
    fn argmax_picks_first_max() {
        assert_eq!(argmax(&[0.1, 0.9, 0.9]), 1);
        assert_eq!(argmax(&[3.0]), 0);
        assert_eq!(argmax(&[-5.0, -1.0, -3.0]), 1);
    }

    #[test]
    fn response_predictions() {
        let r = Response {
            id: 1,
            slot: 0,
            group: 0,
            logits: vec![0.0, 1.0, /* pos2 */ 2.0, 0.5].into(),
            n_classes: 2,
            latency: Duration::ZERO,
        };
        assert_eq!(r.pred_class(), 1);
        assert_eq!(r.pred_tokens(), vec![1, 0]);
    }

    #[test]
    fn logits_view_slices_shared_buffer_without_copy() {
        let batch: Arc<[f32]> = vec![0.0, 1.0, 2.0, 3.0, 4.0, 5.0].into();
        let a = LogitsView::shared(batch.clone(), 0, 3);
        let b = LogitsView::shared(batch.clone(), 3, 3);
        assert_eq!(&a[..], &[0.0, 1.0, 2.0]);
        assert_eq!(&b[..], &[3.0, 4.0, 5.0]);
        assert_eq!(b.len(), 3);
        assert!(a.same_buffer(&b), "views share one allocation");
        assert!(a.shared_count() >= 3); // batch + a + b
        let c = a.clone();
        assert!(c.same_buffer(&a));
        // equality is by contents, not identity
        assert_eq!(a, LogitsView::from_vec(vec![0.0, 1.0, 2.0]));
        assert!(!a.same_buffer(&LogitsView::from_vec(vec![0.0, 1.0, 2.0])));
        assert_eq!(format!("{a:?}"), "[0.0, 1.0, 2.0]");
    }

    #[test]
    #[should_panic(expected = "out of range")]
    fn logits_view_rejects_oob() {
        let batch: Arc<[f32]> = vec![0.0; 4].into();
        let _ = LogitsView::shared(batch, 2, 3);
    }

    #[test]
    fn dropped_completion_fails_with_shutdown() {
        let cell = OnceCellSync::new();
        let handle = RequestHandle { id: 1, deadline: None, done: cell.clone() };
        drop(Completion::cell(cell));
        assert_eq!(handle.wait(), Err(EngineError::Shutdown));
    }

    #[test]
    fn defused_completion_stays_silent() {
        let cell: OnceCellSync<Result<Response, EngineError>> = OnceCellSync::new();
        let mut c = Completion::cell(cell.clone());
        c.defuse();
        drop(c);
        assert!(cell.wait_timeout(Duration::from_millis(20)).is_none());
    }

    #[test]
    fn queue_completion_delivers_tagged() {
        let q: CompletionQueue = Channel::bounded(4);
        Completion::queue(7, q.clone()).fulfill(Err(EngineError::DeadlineExceeded));
        let (tag, result) = q.try_recv().expect("tagged completion");
        assert_eq!(tag, 7);
        assert_eq!(result, Err(EngineError::DeadlineExceeded));
    }

    #[test]
    fn wait_deadline_times_out() {
        let cell = OnceCellSync::new();
        let h = RequestHandle {
            id: 1,
            deadline: Some(Instant::now() + Duration::from_millis(20)),
            done: cell,
        };
        assert_eq!(h.wait_deadline(), Err(EngineError::DeadlineExceeded));
    }
}
