//! Request / response types and the completion handle.

use std::time::{Duration, Instant};

use crate::util::threadpool::OnceCellSync;

/// A single inference request: one framed content row (already
/// `[CLS] ... [SEP] ... [PAD]`-laid-out to the model's seq_len).
pub struct Request {
    pub id: u64,
    pub content: Vec<i32>,
    pub submitted: Instant,
    pub(crate) done: OnceCellSync<Response>,
}

/// The demultiplexed result for one request.
#[derive(Debug, Clone)]
pub struct Response {
    pub id: u64,
    /// which mux slot (paper's index i) served this request — exposed
    /// because per-index accuracy varies (paper A3 / Fig 7b)
    pub slot: usize,
    /// group sequence number (diagnostics)
    pub group: u64,
    /// task logits for this request: cls -> n_classes, token -> seq_len * n_classes
    pub logits: Vec<f32>,
    pub n_classes: usize,
    pub latency: Duration,
}

impl Response {
    /// Sentence-level prediction (argmax over class logits).
    pub fn pred_class(&self) -> usize {
        argmax(&self.logits[..self.n_classes])
    }

    /// Token-level predictions (argmax per position).
    pub fn pred_tokens(&self) -> Vec<usize> {
        self.logits.chunks_exact(self.n_classes).map(argmax).collect()
    }
}

pub fn argmax(xs: &[f32]) -> usize {
    let mut best = 0;
    for (i, &x) in xs.iter().enumerate() {
        if x > xs[best] {
            best = i;
        }
    }
    best
}

/// Caller-side handle; `wait()` blocks until the scheduler fulfills it.
#[derive(Clone)]
pub struct RequestHandle {
    pub id: u64,
    pub(crate) done: OnceCellSync<Response>,
}

impl RequestHandle {
    pub fn wait(&self) -> Response {
        self.done.wait()
    }

    pub fn wait_timeout(&self, d: Duration) -> Option<Response> {
        self.done.wait_timeout(d)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn argmax_picks_first_max() {
        assert_eq!(argmax(&[0.1, 0.9, 0.9]), 1);
        assert_eq!(argmax(&[3.0]), 0);
        assert_eq!(argmax(&[-5.0, -1.0, -3.0]), 1);
    }

    #[test]
    fn response_predictions() {
        let r = Response {
            id: 1,
            slot: 0,
            group: 0,
            logits: vec![0.0, 1.0, /* pos2 */ 2.0, 0.5],
            n_classes: 2,
            latency: Duration::ZERO,
        };
        assert_eq!(r.pred_class(), 1);
        assert_eq!(r.pred_tokens(), vec![1, 0]);
    }
}
