//! Request / response types and the completion plumbing.
//!
//! Every in-flight request carries a [`Completion`]: either a one-shot
//! cell behind a [`RequestHandle`], or a tagged entry on a shared
//! [`CompletionQueue`](super::CompletionQueue) (the pipelined-server
//! path). `Completion` fulfills **exactly once** — and if a `Request` is
//! dropped unfulfilled anywhere in the engine (queue teardown, worker
//! death, batcher exit), the drop guard fails it with
//! [`EngineError::Shutdown`] so callers can never hang on `wait()`.

use std::time::{Duration, Instant};

use crate::util::threadpool::OnceCellSync;

use super::api::CompletionQueue;

/// Why a request that was accepted did not produce a response.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum EngineError {
    /// the executing worker failed; the message carries the cause chain
    WorkerFailed(String),
    /// the request's deadline passed before it reached a model execution
    DeadlineExceeded,
    /// the engine shut down (or dropped the request) before executing it
    Shutdown,
}

impl EngineError {
    /// Stable machine-readable code (used by wire protocol v2).
    pub fn code(&self) -> &'static str {
        match self {
            EngineError::WorkerFailed(_) => "worker_failed",
            EngineError::DeadlineExceeded => "deadline",
            EngineError::Shutdown => "shutdown",
        }
    }
}

impl std::fmt::Display for EngineError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            EngineError::WorkerFailed(msg) => write!(f, "worker failed: {msg}"),
            EngineError::DeadlineExceeded => write!(f, "deadline exceeded"),
            EngineError::Shutdown => write!(f, "engine shut down before execution"),
        }
    }
}

impl std::error::Error for EngineError {}

pub(crate) enum CompletionInner {
    Cell(OnceCellSync<Result<Response, EngineError>>),
    Queue { tag: u64, queue: CompletionQueue },
}

/// Exactly-once completion slot with a fail-on-drop guard.
pub struct Completion {
    inner: Option<CompletionInner>,
}

impl Completion {
    pub(crate) fn cell(cell: OnceCellSync<Result<Response, EngineError>>) -> Self {
        Completion { inner: Some(CompletionInner::Cell(cell)) }
    }

    pub(crate) fn queue(tag: u64, queue: CompletionQueue) -> Self {
        Completion { inner: Some(CompletionInner::Queue { tag, queue }) }
    }

    pub(crate) fn fulfill(mut self, result: Result<Response, EngineError>) {
        Self::deliver(self.inner.take(), result);
    }

    /// Disarm the drop guard without fulfilling (the caller is reporting
    /// the failure synchronously instead).
    pub(crate) fn defuse(&mut self) {
        self.inner = None;
    }

    fn deliver(inner: Option<CompletionInner>, result: Result<Response, EngineError>) {
        match inner {
            None => {}
            Some(CompletionInner::Cell(cell)) => cell.set(result),
            Some(CompletionInner::Queue { tag, queue }) => {
                // never block an engine thread on a slow consumer; a full
                // queue drops the completion (consumer gone or stalled)
                let _ = queue.try_send((tag, result));
            }
        }
    }
}

impl Drop for Completion {
    fn drop(&mut self) {
        Self::deliver(self.inner.take(), Err(EngineError::Shutdown));
    }
}

/// A single admitted request: one framed content row (already
/// `[CLS] ... [SEP] ... [PAD]`-laid-out to the model's seq_len).
pub struct Request {
    pub id: u64,
    pub content: Vec<i32>,
    pub submitted: Instant,
    /// absolute deadline; expired requests are failed at batch assembly
    pub deadline: Option<Instant>,
    pub(crate) done: Completion,
}

impl Request {
    pub(crate) fn fulfill(self, result: Result<Response, EngineError>) {
        self.done.fulfill(result);
    }

    pub(crate) fn expired(&self, now: Instant) -> bool {
        self.deadline.map_or(false, |d| d <= now)
    }
}

/// The demultiplexed result for one request.
#[derive(Debug, Clone, PartialEq)]
pub struct Response {
    pub id: u64,
    /// which mux slot (paper's index i) served this request — exposed
    /// because per-index accuracy varies (paper A3 / Fig 7b)
    pub slot: usize,
    /// group sequence number (diagnostics)
    pub group: u64,
    /// task logits for this request: cls -> n_classes, token -> seq_len * n_classes
    pub logits: Vec<f32>,
    pub n_classes: usize,
    pub latency: Duration,
}

impl Response {
    /// Sentence-level prediction (argmax over class logits).
    pub fn pred_class(&self) -> usize {
        argmax(&self.logits[..self.n_classes])
    }

    /// Token-level predictions (argmax per position).
    pub fn pred_tokens(&self) -> Vec<usize> {
        self.logits.chunks_exact(self.n_classes).map(argmax).collect()
    }
}

pub fn argmax(xs: &[f32]) -> usize {
    let mut best = 0;
    for (i, &x) in xs.iter().enumerate() {
        if x > xs[best] {
            best = i;
        }
    }
    best
}

/// Caller-side handle; waits until the engine fulfills the request.
#[derive(Clone)]
pub struct RequestHandle {
    pub id: u64,
    /// absolute deadline mirrored from the request (drives `wait_deadline`)
    pub deadline: Option<Instant>,
    pub(crate) done: OnceCellSync<Result<Response, EngineError>>,
}

impl RequestHandle {
    /// Block until the engine fulfills the request. Cannot hang: every
    /// accepted request is fulfilled with a `Response` or an
    /// [`EngineError`], even across worker death and shutdown.
    pub fn wait(&self) -> Result<Response, EngineError> {
        self.done.wait()
    }

    /// Wait with a caller-chosen timeout; `None` when it elapses first.
    pub fn wait_timeout(&self, d: Duration) -> Option<Result<Response, EngineError>> {
        self.done.wait_timeout(d)
    }

    /// Deadline-aware wait: block until the request's own deadline, then
    /// give up with [`EngineError::DeadlineExceeded`]. Without a
    /// deadline this is `wait()`.
    pub fn wait_deadline(&self) -> Result<Response, EngineError> {
        match self.deadline {
            None => self.wait(),
            Some(dl) => {
                let now = Instant::now();
                let left = dl.saturating_duration_since(now);
                match self.done.wait_timeout(left) {
                    Some(r) => r,
                    None => Err(EngineError::DeadlineExceeded),
                }
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::threadpool::Channel;

    #[test]
    fn argmax_picks_first_max() {
        assert_eq!(argmax(&[0.1, 0.9, 0.9]), 1);
        assert_eq!(argmax(&[3.0]), 0);
        assert_eq!(argmax(&[-5.0, -1.0, -3.0]), 1);
    }

    #[test]
    fn response_predictions() {
        let r = Response {
            id: 1,
            slot: 0,
            group: 0,
            logits: vec![0.0, 1.0, /* pos2 */ 2.0, 0.5],
            n_classes: 2,
            latency: Duration::ZERO,
        };
        assert_eq!(r.pred_class(), 1);
        assert_eq!(r.pred_tokens(), vec![1, 0]);
    }

    #[test]
    fn dropped_completion_fails_with_shutdown() {
        let cell = OnceCellSync::new();
        let handle = RequestHandle { id: 1, deadline: None, done: cell.clone() };
        drop(Completion::cell(cell));
        assert_eq!(handle.wait(), Err(EngineError::Shutdown));
    }

    #[test]
    fn defused_completion_stays_silent() {
        let cell: OnceCellSync<Result<Response, EngineError>> = OnceCellSync::new();
        let mut c = Completion::cell(cell.clone());
        c.defuse();
        drop(c);
        assert!(cell.wait_timeout(Duration::from_millis(20)).is_none());
    }

    #[test]
    fn queue_completion_delivers_tagged() {
        let q: CompletionQueue = Channel::bounded(4);
        Completion::queue(7, q.clone()).fulfill(Err(EngineError::DeadlineExceeded));
        let (tag, result) = q.try_recv().expect("tagged completion");
        assert_eq!(tag, 7);
        assert_eq!(result, Err(EngineError::DeadlineExceeded));
    }

    #[test]
    fn wait_deadline_times_out() {
        let cell = OnceCellSync::new();
        let h = RequestHandle {
            id: 1,
            deadline: Some(Instant::now() + Duration::from_millis(20)),
            done: cell,
        };
        assert_eq!(h.wait_deadline(), Err(EngineError::DeadlineExceeded));
    }
}
