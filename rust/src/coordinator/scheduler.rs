//! Scheduler: turn ExecBatches into model executions and route the
//! demultiplexed outputs back to their requests.
//!
//! Input assembly mirrors the compile-path layout exactly (pinned by the
//! parity integration test): for group `g`, slot `i`, the model row is
//! `prefix^i ++ content`, and the output logits for that request live at
//! flat offset `(g * n_mux + i) * per_slot_len`.
//!
//! Failure discipline: `execute_batch` never strands a caller. Expired
//! requests are failed with `DeadlineExceeded` before assembly, and if
//! the backend errors, every request in the batch is failed with
//! `WorkerFailed` before the error propagates to the worker loop.

use std::sync::atomic::Ordering;
use std::sync::Arc;
use std::time::Instant;

use super::batcher::ExecBatch;
use super::policy::SlotPolicy;
use super::request::{EngineError, Response};
use crate::runtime::{ArtifactMeta, InferenceBackend, LoadedModel};
use crate::tokenizer::Tokenizer;
use crate::util::metrics::{Counters, Histogram};

/// `LoadedModel` wraps PJRT FFI handles (raw pointers), which the xla
/// crate does not mark Send/Sync. The PJRT C API is thread-safe for
/// compilation-free usage (execute / buffer upload), and every model here
/// is used behind an `Arc` without interior mutation, so sharing across
/// the scheduler threads is sound.
pub struct SharedModel(pub Arc<LoadedModel>);

// SAFETY: see type-level comment — PJRT execution and host-to-device
// transfer are thread-safe in the CPU plugin; we never mutate LoadedModel
// after construction.
unsafe impl Send for SharedModel {}
unsafe impl Sync for SharedModel {}

impl Clone for SharedModel {
    fn clone(&self) -> Self {
        SharedModel(self.0.clone())
    }
}

impl std::ops::Deref for SharedModel {
    type Target = LoadedModel;
    fn deref(&self) -> &LoadedModel {
        &self.0
    }
}

impl InferenceBackend for SharedModel {
    fn meta(&self) -> &ArtifactMeta {
        &self.0.meta
    }

    fn run_ids(&self, ids: &[i32]) -> anyhow::Result<Vec<f32>> {
        self.0.run_ids(ids)
    }
}

/// Shared serving statistics.
#[derive(Default)]
pub struct Stats {
    pub counters: Counters,
    /// submit -> response fulfilled
    pub e2e_latency: Histogram,
    /// batch formed -> execution done
    pub exec_latency: Histogram,
}

/// Per-slot output length (flattened logits) for the model's task.
pub fn per_slot_len(meta: &ArtifactMeta) -> usize {
    match meta.task.as_str() {
        "cls" => meta.n_classes,
        "token" => meta.seq_len * meta.n_classes,
        other => panic!("unsupported serving task {other}"),
    }
}

/// Execute one batch and fulfill its requests. Returns Err only on
/// backend failure — and by then every request in the batch has already
/// been fulfilled with [`EngineError::WorkerFailed`], so callers cannot
/// hang on the error path.
pub fn execute_batch(
    model: &dyn InferenceBackend,
    tok: &Tokenizer,
    policy: SlotPolicy,
    stats: &Stats,
    batch: ExecBatch,
    ids_scratch: &mut Vec<i32>,
) -> anyhow::Result<()> {
    let meta = model.meta();
    let n_mux = meta.n_mux;
    let b = meta.batch;
    let input_len = meta.input_len;
    let seq_len = meta.seq_len;
    let prefix_len = input_len - seq_len;
    debug_assert!(prefix_len == 0 || prefix_len == n_mux);
    let capacity = b * n_mux;
    assert!(batch.entries.len() <= capacity, "batcher produced oversized batch");

    // --- drop requests whose deadline already passed ---------------------
    let now = Instant::now();
    let mut entries = Vec::with_capacity(batch.entries.len());
    for req in batch.entries {
        if req.expired(now) {
            stats.counters.expired.fetch_add(1, Ordering::Relaxed);
            req.fulfill(Err(EngineError::DeadlineExceeded));
        } else {
            entries.push(req);
        }
    }
    if entries.is_empty() {
        return Ok(());
    }

    // --- assemble the (b, n_mux, input_len) ids tensor -------------------
    ids_scratch.clear();
    ids_scratch.resize(capacity * input_len, tok.vocab.pad);
    // fill every slot with the pad row first (empty slots stay in-distribution)
    let pad_row = tok.pad_row(seq_len);
    for g in 0..b {
        for slot in 0..n_mux {
            let row = &mut ids_scratch
                [((g * n_mux) + slot) * input_len..((g * n_mux) + slot + 1) * input_len];
            if prefix_len > 0 {
                for (j, p) in row[..prefix_len].iter_mut().enumerate() {
                    *p = if j == slot {
                        tok.vocab.idx_base + slot as i32
                    } else {
                        tok.vocab.eps_pad
                    };
                }
            }
            row[prefix_len..].copy_from_slice(&pad_row);
        }
    }
    // place the real requests
    let mut placement: Vec<(usize, usize)> = Vec::with_capacity(entries.len());
    for (pos, req) in entries.iter().enumerate() {
        let g = pos / n_mux;
        let slot = policy.slot_of(batch.seq.wrapping_add(g as u64), pos % n_mux, n_mux);
        debug_assert_eq!(req.content.len(), seq_len, "request content must be framed");
        let row = &mut ids_scratch
            [((g * n_mux) + slot) * input_len..((g * n_mux) + slot + 1) * input_len];
        row[prefix_len..].copy_from_slice(&req.content);
        placement.push((g, slot));
    }
    let padded = capacity - entries.len();

    // --- execute ----------------------------------------------------------
    let t_exec = Instant::now();
    let out = match model.run_ids(ids_scratch) {
        Ok(out) => out,
        Err(e) => {
            // fail every waiter before surfacing the error: wait() must
            // never hang on worker death
            let msg = format!("{e:#}");
            for req in entries {
                req.fulfill(Err(EngineError::WorkerFailed(msg.clone())));
            }
            return Err(e);
        }
    };
    stats.exec_latency.record_duration(t_exec.elapsed());
    stats.counters.groups_executed.fetch_add(b as u64, Ordering::Relaxed);
    stats.counters.slots_padded.fetch_add(padded as u64, Ordering::Relaxed);

    // --- demux dispatch ----------------------------------------------------
    let slot_len = per_slot_len(meta);
    let now = Instant::now();
    for (req, (g, slot)) in entries.into_iter().zip(placement) {
        let off = ((g * n_mux) + slot) * slot_len;
        let logits = out[off..off + slot_len].to_vec();
        let latency = now.duration_since(req.submitted);
        stats.e2e_latency.record_duration(latency);
        stats.counters.completed.fetch_add(1, Ordering::Relaxed);
        let response = Response {
            id: req.id,
            slot,
            group: batch.seq,
            logits,
            n_classes: meta.n_classes,
            latency,
        };
        req.fulfill(Ok(response));
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn shared_model_is_send_sync() {
        fn assert_send_sync<T: Send + Sync>() {}
        assert_send_sync::<SharedModel>();
    }
}
