//! Scheduler: turn ExecBatches into model executions and route the
//! demultiplexed outputs back to their requests.
//!
//! Input assembly mirrors the compile-path layout exactly (pinned by the
//! parity integration test): for group `g`, slot `i`, the model row is
//! `prefix^i ++ content`, and the output logits for that request live at
//! flat offset `(g * n_mux + i) * per_slot_len`.
//!
//! Hot-path memory discipline: the empty-slot ids tensor (pad rows plus
//! per-slot index prefixes) is derived **once** into a [`MuxTemplate`]
//! at coordinator startup; per batch it is bulk-copied into a reused
//! scratch buffer and only the live requests' content regions are
//! overwritten. Demux hands each response a shared [`LogitsView`] of
//! the batch output instead of copying per request. Steady state does
//! no allocation in assembly and no per-request copy in demux.
//!
//! Failure discipline: `execute_batch` never strands a caller. Expired
//! requests are failed with `DeadlineExceeded` before assembly, and if
//! the backend errors, every request in the batch is failed with
//! `WorkerFailed` before the error propagates to the worker loop.

use std::sync::atomic::Ordering;
use std::sync::Arc;
use std::time::Instant;

use super::api::{ClassStatus, Priority, N_PRIORITY_CLASSES};
use super::batcher::ExecBatch;
use super::policy::SlotPolicy;
use super::request::{EngineError, LogitsView, Response};
use crate::runtime::{ArtifactMeta, InferenceBackend, LoadedModel};
use crate::tokenizer::Tokenizer;
use crate::util::metrics::{Counters, Histogram};

/// `LoadedModel` wraps PJRT FFI handles (raw pointers), which the xla
/// crate does not mark Send/Sync. The PJRT C API is thread-safe for
/// compilation-free usage (execute / buffer upload), and every model here
/// is used behind an `Arc` without interior mutation, so sharing across
/// the scheduler threads is sound.
pub struct SharedModel(pub Arc<LoadedModel>);

// SAFETY: see type-level comment — PJRT execution and host-to-device
// transfer are thread-safe in the CPU plugin; we never mutate LoadedModel
// after construction.
unsafe impl Send for SharedModel {}
unsafe impl Sync for SharedModel {}

impl Clone for SharedModel {
    fn clone(&self) -> Self {
        SharedModel(self.0.clone())
    }
}

impl std::ops::Deref for SharedModel {
    type Target = LoadedModel;
    fn deref(&self) -> &LoadedModel {
        &self.0
    }
}

impl InferenceBackend for SharedModel {
    fn meta(&self) -> &ArtifactMeta {
        &self.0.meta
    }

    fn run_ids(&self, ids: &[i32]) -> anyhow::Result<Vec<f32>> {
        self.0.run_ids(ids)
    }
}

/// Per-bucket execution tallies (one per registered sequence-length
/// bucket): how many waves executed at this shape and how many requests
/// they carried. Exposed through `Submit::lane_status()` and v2 STATS
/// so padding waste is observable per shape.
pub struct BucketTally {
    pub seq_len: usize,
    pub waves: std::sync::atomic::AtomicU64,
    pub entries: std::sync::atomic::AtomicU64,
}

/// Per-priority-class serving tallies, indexed by [`Priority::index`].
/// `queue_wait` is the per-class view of [`Stats::queue_wait`]; the shed
/// counters are bumped at admission (not here) so STATS can report how
/// much work each class lost to deadline-aware shedding.
#[derive(Default)]
pub struct ClassTally {
    /// submit -> batch formed, for requests of this class
    pub queue_wait: Histogram,
    pub completed: std::sync::atomic::AtomicU64,
    /// rejected at submit: deadline already expired
    pub shed_expired: std::sync::atomic::AtomicU64,
    /// rejected at submit: deadline provably unmeetable at current load
    pub shed_overloaded: std::sync::atomic::AtomicU64,
}

/// Shared serving statistics.
#[derive(Default)]
pub struct Stats {
    pub counters: Counters,
    /// submit -> response fulfilled
    pub e2e_latency: Histogram,
    /// batch formed -> execution done: worker pickup (exec-queue wait
    /// when all workers are busy) + expiry sweep + assembly + model
    pub exec_latency: Histogram,
    /// submit -> batch formed: admission queueing plus group-formation
    /// delay, the batching cost invisible to `exec_latency`
    pub queue_wait: Histogram,
    /// one tally per bucket, aligned with the engine's bucket registry;
    /// empty when the consumer doesn't track buckets (unit tests)
    pub per_bucket: Vec<BucketTally>,
    /// one tally per SLO priority class, indexed by `Priority::index()`
    pub per_class: [ClassTally; N_PRIORITY_CLASSES],
}

impl Stats {
    /// Stats with one tally slot per bucket length.
    pub fn for_buckets(lens: &[usize]) -> Stats {
        Stats {
            per_bucket: lens
                .iter()
                .map(|&seq_len| BucketTally {
                    seq_len,
                    waves: Default::default(),
                    entries: Default::default(),
                })
                .collect(),
            ..Stats::default()
        }
    }

    /// Snapshot the per-bucket tallies as `(seq_len, waves, entries)`.
    pub fn bucket_snapshot(&self) -> Vec<(usize, u64, u64)> {
        self.per_bucket
            .iter()
            .map(|t| {
                (t.seq_len, t.waves.load(Ordering::Relaxed), t.entries.load(Ordering::Relaxed))
            })
            .collect()
    }

    /// Snapshot the per-class tallies as [`ClassStatus`] entries.
    /// `depth` is left at zero — queue depth lives with whoever owns the
    /// queues, so `Submit::class_status` implementations fill it in.
    pub fn class_snapshot(&self) -> Vec<ClassStatus> {
        Priority::ALL
            .iter()
            .map(|&p| {
                let t = &self.per_class[p.index()];
                ClassStatus {
                    priority: p,
                    depth: 0,
                    completed: t.completed.load(Ordering::Relaxed),
                    shed_expired: t.shed_expired.load(Ordering::Relaxed),
                    shed_overloaded: t.shed_overloaded.load(Ordering::Relaxed),
                    queue_wait: t.queue_wait.summary(),
                }
            })
            .collect()
    }
}

/// Per-slot output length (flattened logits) for the model's task at
/// the model's full sequence length.
pub fn per_slot_len(meta: &ArtifactMeta) -> usize {
    per_slot_len_at(meta, meta.seq_len)
}

/// Per-slot output length at a runtime bucket length (`token` logits
/// scale with the executed shape; `cls` is shape-independent).
pub fn per_slot_len_at(meta: &ArtifactMeta, seq_len: usize) -> usize {
    match meta.task.as_str() {
        "cls" => meta.n_classes,
        "token" => seq_len * meta.n_classes,
        other => panic!("unsupported serving task {other}"),
    }
}

/// Precomputed `(batch, n_mux, input_len)` ids tensor with every slot
/// empty: pad rows plus the per-slot index prefix (paper §3.2), derived
/// once per **bucket** at engine startup (`seq_len` here is the bucket
/// length, `input_len = prefix + bucket`). Per batch,
/// [`MuxTemplate::stamp`] resets the scratch buffer with one bulk copy,
/// so steady-state assembly never re-derives pad rows or prefixes and
/// never allocates — including the bucket's pad row, which lives in
/// [`MuxTemplate::pad_row`] instead of being rebuilt by
/// `Tokenizer::pad_row` per call.
pub struct MuxTemplate {
    ids: Vec<i32>,
    /// the bucket's empty content row (`[CLS]` anchor + `[PAD]`s),
    /// computed once — serving paths and tests read it from here
    pad_row: Vec<i32>,
    pub n_mux: usize,
    pub batch: usize,
    pub input_len: usize,
    pub seq_len: usize,
    pub prefix_len: usize,
    pub per_slot_len: usize,
}

impl MuxTemplate {
    /// Template at the model's full sequence length (the terminal
    /// bucket / pad-to-max behavior).
    pub fn new(meta: &ArtifactMeta, tok: &Tokenizer) -> Self {
        Self::for_bucket(meta, tok, meta.seq_len)
    }

    /// Template for one sequence-length bucket: the stamped tensor is
    /// `(batch, n_mux, prefix + bucket_len)` — everything downstream
    /// (assembly, backend call, demux offsets) uses these runtime
    /// shapes, never the compile-time maximum.
    pub fn for_bucket(meta: &ArtifactMeta, tok: &Tokenizer, bucket_len: usize) -> Self {
        let n_mux = meta.n_mux;
        let b = meta.batch;
        let max_prefix = meta.input_len - meta.seq_len;
        assert!(
            max_prefix == 0 || max_prefix == n_mux,
            "unexpected prefix layout: input_len={} seq_len={} n_mux={n_mux}",
            meta.input_len,
            meta.seq_len
        );
        assert!(
            (1..=meta.seq_len).contains(&bucket_len),
            "bucket {bucket_len} outside 1..={}",
            meta.seq_len
        );
        let seq_len = bucket_len;
        let prefix_len = max_prefix;
        let input_len = prefix_len + seq_len;
        // the one pad row this bucket will ever build ([CLS] anchor kept
        // so empty slots stay in-distribution)
        let mut pad_row = vec![tok.vocab.pad; seq_len];
        pad_row[0] = tok.vocab.cls;
        let mut ids = vec![tok.vocab.pad; b * n_mux * input_len];
        for g in 0..b {
            for slot in 0..n_mux {
                let start = ((g * n_mux) + slot) * input_len;
                let row = &mut ids[start..start + input_len];
                if prefix_len > 0 {
                    for (j, p) in row[..prefix_len].iter_mut().enumerate() {
                        *p = if j == slot {
                            tok.vocab.idx_base + slot as i32
                        } else {
                            tok.vocab.eps_pad
                        };
                    }
                }
                row[prefix_len..].copy_from_slice(&pad_row);
            }
        }
        MuxTemplate {
            ids,
            pad_row,
            n_mux,
            batch: b,
            input_len,
            seq_len,
            prefix_len,
            per_slot_len: per_slot_len_at(meta, seq_len),
        }
    }

    /// The bucket's precomputed empty content row.
    pub fn pad_row(&self) -> &[i32] {
        &self.pad_row
    }

    /// Requests one execution can carry (`batch * n_mux`).
    pub fn capacity(&self) -> usize {
        self.batch * self.n_mux
    }

    /// Total ids per execution (`capacity * input_len`).
    pub fn ids_len(&self) -> usize {
        self.ids.len()
    }

    /// Reset `scratch` to the empty-slot tensor with one bulk copy;
    /// allocation-free once `scratch` has reached full capacity.
    // lint: hot-path
    pub fn stamp(&self, scratch: &mut Vec<i32>) {
        scratch.clear();
        scratch.extend_from_slice(&self.ids);
    }

    /// Index range of the content region of row `(g, slot)` in the
    /// flattened ids tensor (reuse-safety tests inspect these).
    pub fn content_range(&self, g: usize, slot: usize) -> std::ops::Range<usize> {
        let start = ((g * self.n_mux) + slot) * self.input_len + self.prefix_len;
        start..start + self.seq_len
    }
}

/// Execute one batch and fulfill its requests. Returns Err only on
/// backend failure — and by then every request in the batch has already
/// been fulfilled with [`EngineError::WorkerFailed`], so callers cannot
/// hang on the error path.
///
/// `template` must be the one built for `batch.bucket` (same
/// `ArtifactMeta` as `model`, bucket sequence length): the wave is
/// shape-homogeneous by construction, the backend executes at
/// `template.seq_len`, and request contents — unpadded, any length up
/// to the bucket — land over the template's pre-stamped pad rows.
/// `ids_scratch` is a worker-owned per-bucket buffer reused across
/// batches (its contents are fully overwritten by
/// [`MuxTemplate::stamp`] plus the per-request content writes, so
/// nothing from a previous batch can leak into this one —
/// property-tested by poisoning it between calls).
pub fn execute_batch(
    model: &dyn InferenceBackend,
    template: &MuxTemplate,
    policy: SlotPolicy,
    stats: &Stats,
    batch: ExecBatch,
    ids_scratch: &mut Vec<i32>,
) -> anyhow::Result<()> {
    let meta = model.meta();
    let n_mux = template.n_mux;
    let input_len = template.input_len;
    let seq_len = template.seq_len;
    let prefix_len = template.prefix_len;
    let capacity = template.capacity();
    assert!(batch.entries.len() <= capacity, "batcher produced oversized batch");

    // --- drop requests whose deadline already passed ---------------------
    let now = Instant::now();
    let mut entries = Vec::with_capacity(batch.entries.len());
    for req in batch.entries {
        let waited = batch.formed_at.saturating_duration_since(req.submitted);
        stats.queue_wait.record_duration(waited);
        stats.per_class[req.priority.index()].queue_wait.record_duration(waited);
        if req.expired(now) {
            stats.counters.expired.fetch_add(1, Ordering::Relaxed);
            req.fulfill(Err(EngineError::DeadlineExceeded));
        } else {
            entries.push(req);
        }
    }
    if entries.is_empty() {
        return Ok(());
    }

    // --- assemble the (b, n_mux, input_len) ids tensor -------------------
    // one bulk copy of the precomputed empty-slot tensor (pad rows +
    // prefixes), then overwrite only the live requests' content regions
    if ids_scratch.capacity() < template.ids_len() {
        stats.counters.scratch_reallocs.fetch_add(1, Ordering::Relaxed);
    }
    template.stamp(ids_scratch);
    let mut placement: Vec<(usize, usize)> = Vec::with_capacity(entries.len());
    let mut content_tokens = 0usize;
    for (pos, req) in entries.iter().enumerate() {
        let g = pos / n_mux;
        let slot = policy.slot_of(batch.seq.wrapping_add(g as u64), pos % n_mux, n_mux);
        debug_assert!(
            !req.content.is_empty() && req.content.len() <= seq_len,
            "request content ({}) must fit its bucket ({seq_len})",
            req.content.len()
        );
        // unpadded content lands over the template's pre-stamped pad
        // row; the tail beyond content.len() is already [PAD]
        let start = ((g * n_mux) + slot) * input_len + prefix_len;
        ids_scratch[start..start + req.content.len()].copy_from_slice(&req.content);
        content_tokens += req.content.len();
        placement.push((g, slot));
    }
    let padded = capacity - entries.len();

    // --- execute ----------------------------------------------------------
    // Re-check the demux contract for *every* backend before slicing:
    // `LoadedModel` validates its own output length, but `FakeBackend`
    // (and any future backend) is only trusted here. A short or oversized
    // buffer must fail the batch loudly, not index out of range below.
    let expected_len = capacity * template.per_slot_len;
    let run = model.run_ids_at(ids_scratch, seq_len).and_then(|out| {
        anyhow::ensure!(
            out.len() == expected_len,
            "backend returned {} logits, expected {} (capacity {} x per_slot {})",
            out.len(),
            expected_len,
            capacity,
            template.per_slot_len
        );
        Ok(out)
    });
    let out = match run {
        Ok(out) => out,
        Err(e) => {
            // fail every waiter before surfacing the error: wait() must
            // never hang on worker death
            let msg = format!("{e:#}");
            for req in entries {
                req.fulfill(Err(EngineError::WorkerFailed(msg.clone())));
            }
            return Err(e);
        }
    };
    // "batch formed -> execution done", as documented: includes worker
    // pickup, the expiry sweep and assembly, not just the backend call
    stats.exec_latency.record_duration(batch.formed_at.elapsed());
    // count only the groups actually occupied by requests: a partial
    // batch of k entries fills ceil(k / n_mux) groups (entry `pos` lands
    // in group `pos / n_mux` under every slot policy), not the template's
    // full `batch` — the fixed counter makes padded-group waste visible
    // as `slots_padded` rather than inflating throughput accounting
    let occupied_groups = entries.len().div_ceil(n_mux) as u64;
    stats.counters.groups_executed.fetch_add(occupied_groups, Ordering::Relaxed);
    stats.counters.slots_padded.fetch_add(padded as u64, Ordering::Relaxed);
    // wasted token-positions in the executed content tensor: empty-slot
    // rows plus each live row's pad tail — the number bucketing drives
    // down (a pad-to-max engine wastes (max - len) per request)
    let wasted = capacity * seq_len - content_tokens;
    stats.counters.tokens_padded.fetch_add(wasted as u64, Ordering::Relaxed);
    if let Some(tally) = stats.per_bucket.get(batch.bucket) {
        tally.waves.fetch_add(1, Ordering::Relaxed);
        tally.entries.fetch_add(entries.len() as u64, Ordering::Relaxed);
    }

    // --- demux dispatch ----------------------------------------------------
    // share the flat batch output across all responses; each gets an
    // offset view, not a copy
    let slot_len = template.per_slot_len;
    let shared: Arc<[f32]> = out.into();
    let now = Instant::now();
    for (req, (g, slot)) in entries.into_iter().zip(placement) {
        let off = ((g * n_mux) + slot) * slot_len;
        let logits = LogitsView::shared(shared.clone(), off, slot_len);
        let latency = now.duration_since(req.submitted);
        stats.e2e_latency.record_duration(latency);
        stats.counters.completed.fetch_add(1, Ordering::Relaxed);
        stats.per_class[req.priority.index()].completed.fetch_add(1, Ordering::Relaxed);
        let response = Response {
            id: req.id,
            slot,
            group: batch.seq,
            logits,
            n_classes: meta.n_classes,
            latency,
        };
        req.fulfill(Ok(response));
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::time::Duration;

    use crate::coordinator::request::{Completion, Request};
    use crate::runtime::FakeBackend;
    use crate::tokenizer::{default_vocab, Tokenizer};
    use crate::util::threadpool::OnceCellSync;

    #[test]
    fn shared_model_is_send_sync() {
        fn assert_send_sync<T: Send + Sync>() {}
        assert_send_sync::<SharedModel>();
    }

    /// The pre-template per-execution derivation, kept as the oracle the
    /// precomputed tensor must match exactly.
    fn legacy_empty_tensor(meta: &ArtifactMeta, tok: &Tokenizer) -> Vec<i32> {
        let prefix_len = meta.input_len - meta.seq_len;
        let pad_row = tok.pad_row(meta.seq_len);
        let mut ids = vec![tok.vocab.pad; meta.batch * meta.n_mux * meta.input_len];
        for g in 0..meta.batch {
            for slot in 0..meta.n_mux {
                let start = ((g * meta.n_mux) + slot) * meta.input_len;
                let row = &mut ids[start..start + meta.input_len];
                if prefix_len > 0 {
                    for (j, p) in row[..prefix_len].iter_mut().enumerate() {
                        *p = if j == slot {
                            tok.vocab.idx_base + slot as i32
                        } else {
                            tok.vocab.eps_pad
                        };
                    }
                }
                row[prefix_len..].copy_from_slice(&pad_row);
            }
        }
        ids
    }

    #[test]
    fn template_matches_legacy_derivation() {
        for (task, n_mux, batch, seq_len, n_classes) in
            [("cls", 4, 2, 8, 3), ("cls", 1, 1, 4, 2), ("token", 2, 3, 6, 5)]
        {
            let b = FakeBackend::new(task, n_mux, batch, seq_len, n_classes);
            let tok = Tokenizer::new(default_vocab(), b.meta().vocab_size);
            let t = MuxTemplate::new(b.meta(), &tok);
            // wrong size + poison: stamp must fix both
            let mut scratch = vec![-1; 3];
            t.stamp(&mut scratch);
            assert_eq!(scratch, legacy_empty_tensor(b.meta(), &tok));
            assert_eq!(t.ids_len(), b.meta().ids_len());
        }
    }

    fn make_req(
        id: u64,
        content: Vec<i32>,
        cell: OnceCellSync<Result<Response, EngineError>>,
    ) -> Request {
        Request {
            id,
            content,
            bucket: 0,
            submitted: Instant::now(),
            deadline: None,
            priority: Priority::Normal,
            done: Completion::cell(cell),
        }
    }

    /// A backend that violates the output-length contract.
    struct ShortBackend(ArtifactMeta);

    impl InferenceBackend for ShortBackend {
        fn meta(&self) -> &ArtifactMeta {
            &self.0
        }

        fn run_ids(&self, _ids: &[i32]) -> anyhow::Result<Vec<f32>> {
            Ok(vec![0.0; 1])
        }
    }

    #[test]
    fn misbehaving_backend_output_fails_batch_loudly() {
        let meta = FakeBackend::new("cls", 2, 1, 4, 3).meta().clone();
        let backend = ShortBackend(meta.clone());
        let tok = Tokenizer::new(default_vocab(), meta.vocab_size);
        let template = MuxTemplate::new(&meta, &tok);
        let stats = Stats::default();
        let mut scratch = Vec::new();
        let cell = OnceCellSync::new();
        let req = make_req(1, vec![tok.vocab.pad; 4], cell.clone());
        let eb = ExecBatch { seq: 0, bucket: 0, entries: vec![req], formed_at: Instant::now() };
        let res = execute_batch(&backend, &template, SlotPolicy::Fill, &stats, eb, &mut scratch);
        assert!(res.is_err(), "short output must surface as a batch failure");
        match cell.wait_timeout(Duration::from_secs(1)).expect("fulfilled, never stranded") {
            Err(EngineError::WorkerFailed(msg)) => {
                assert!(msg.contains("logits"), "{msg}")
            }
            other => panic!("expected WorkerFailed, got {other:?}"),
        }
    }

    /// Pins the partial-batch counter semantics: `groups_executed`
    /// counts occupied groups (`ceil(entries / n_mux)`), not the
    /// template's full `batch` per execution.
    #[test]
    fn groups_executed_counts_occupied_groups_only() {
        // n_mux=4, batch=3: capacity 12, template would claim 3 groups
        for (n_entries, want_groups) in [(1usize, 1u64), (4, 1), (5, 2), (9, 3), (12, 3)] {
            let backend = FakeBackend::new("cls", 4, 3, 6, 3);
            let tok = Tokenizer::new(default_vocab(), backend.meta().vocab_size);
            let template = MuxTemplate::new(backend.meta(), &tok);
            let stats = Stats::default();
            let mut scratch = Vec::new();
            let mut cells = Vec::new();
            let mut entries = Vec::new();
            for pos in 0..n_entries {
                let mut c = vec![tok.vocab.pad; 6];
                c[0] = tok.vocab.cls;
                let cell = OnceCellSync::new();
                cells.push(cell.clone());
                entries.push(make_req(pos as u64, c, cell));
            }
            let eb = ExecBatch { seq: 1, bucket: 0, entries, formed_at: Instant::now() };
            execute_batch(&backend, &template, SlotPolicy::Fill, &stats, eb, &mut scratch)
                .expect("fake backend executes");
            let c = stats.counters.snapshot();
            assert_eq!(
                c.groups_executed, want_groups,
                "{n_entries} entries must occupy {want_groups} groups"
            );
            assert_eq!(c.slots_padded, (12 - n_entries) as u64);
            for cell in cells {
                assert!(cell.wait_timeout(Duration::from_secs(1)).is_some());
            }
        }
    }

    /// Per-class tallies: completions and queue-wait samples land in the
    /// request's priority class, not a global bucket.
    #[test]
    fn per_class_tallies_track_completions_by_priority() {
        let backend = FakeBackend::new("cls", 4, 1, 6, 3);
        let tok = Tokenizer::new(default_vocab(), backend.meta().vocab_size);
        let template = MuxTemplate::new(backend.meta(), &tok);
        let stats = Stats::default();
        let mut scratch = Vec::new();
        let mut cells = Vec::new();
        let mut entries = Vec::new();
        for (pos, prio) in
            [(0u64, Priority::High), (1, Priority::Normal), (2, Priority::Bulk), (3, Priority::High)]
        {
            let mut c = vec![tok.vocab.pad; 6];
            c[0] = tok.vocab.cls;
            let cell = OnceCellSync::new();
            cells.push(cell.clone());
            let mut req = make_req(pos, c, cell);
            req.priority = prio;
            entries.push(req);
        }
        let eb = ExecBatch { seq: 0, bucket: 0, entries, formed_at: Instant::now() };
        execute_batch(&backend, &template, SlotPolicy::Fill, &stats, eb, &mut scratch)
            .expect("fake backend executes");
        for cell in cells {
            assert!(cell.wait_timeout(Duration::from_secs(1)).unwrap().is_ok());
        }
        let classes = stats.class_snapshot();
        assert_eq!(classes.len(), N_PRIORITY_CLASSES);
        assert_eq!(classes[Priority::High.index()].completed, 2);
        assert_eq!(classes[Priority::Normal.index()].completed, 1);
        assert_eq!(classes[Priority::Bulk.index()].completed, 1);
        for (c, want) in classes.iter().zip([2u64, 1, 1]) {
            assert_eq!(c.queue_wait.count, want, "{:?} queue-wait samples", c.priority);
            assert_eq!(c.shed_expired, 0);
            assert_eq!(c.shed_overloaded, 0);
        }
    }

    /// Bucketed templates: shapes shrink with the bucket, the pad row is
    /// precomputed per bucket, and the stamped tensor matches what the
    /// full-shape derivation would produce at that length.
    #[test]
    fn bucket_template_shrinks_shapes_and_precomputes_the_pad_row() {
        let b = FakeBackend::new("token", 4, 2, 8, 5);
        let tok = Tokenizer::new(default_vocab(), b.meta().vocab_size);
        for bucket_len in [1usize, 3, 8] {
            let t = MuxTemplate::for_bucket(b.meta(), &tok, bucket_len);
            assert_eq!(t.seq_len, bucket_len);
            assert_eq!(t.prefix_len, 4);
            assert_eq!(t.input_len, 4 + bucket_len);
            assert_eq!(t.per_slot_len, bucket_len * 5, "token logits scale with the bucket");
            assert_eq!(t.ids_len(), 2 * 4 * (4 + bucket_len));
            assert_eq!(t.pad_row(), &tok.pad_row(bucket_len)[..], "one pad row per bucket");
            let mut scratch = Vec::new();
            t.stamp(&mut scratch);
            // every content region is exactly the bucket's pad row
            for g in 0..2 {
                for slot in 0..4 {
                    assert_eq!(&scratch[t.content_range(g, slot)], t.pad_row());
                }
            }
        }
        // cls per-slot output is bucket-independent
        let c = FakeBackend::new("cls", 2, 1, 8, 3);
        let t = MuxTemplate::for_bucket(c.meta(), &tok, 4);
        assert_eq!(t.per_slot_len, 3);
    }

    /// `tokens_padded` counts wasted token-positions: empty-slot rows
    /// plus each live row's pad tail, at the executed bucket length.
    #[test]
    fn tokens_padded_counts_wasted_positions_at_the_bucket_length() {
        let backend = FakeBackend::new("cls", 2, 2, 8, 3); // capacity 4
        let tok = Tokenizer::new(default_vocab(), backend.meta().vocab_size);
        let template = MuxTemplate::for_bucket(backend.meta(), &tok, 4);
        let stats = Stats::for_buckets(&[4, 8]);
        let mut scratch = Vec::new();
        // two live requests of 2 and 3 tokens in the 4-bucket
        let mut cells = Vec::new();
        let mut entries = Vec::new();
        for (pos, len) in [(0u64, 2usize), (1, 3)] {
            let mut c = vec![tok.vocab.pad; len];
            c[0] = tok.vocab.cls;
            let cell = OnceCellSync::new();
            cells.push(cell.clone());
            entries.push(make_req(pos, c, cell));
        }
        let eb = ExecBatch { seq: 0, bucket: 0, entries, formed_at: Instant::now() };
        execute_batch(&backend, &template, SlotPolicy::Fill, &stats, eb, &mut scratch)
            .expect("fake backend executes");
        for cell in cells {
            assert!(cell.wait_timeout(Duration::from_secs(1)).unwrap().is_ok());
        }
        let c = stats.counters.snapshot();
        // capacity 4 * bucket 4 = 16 positions, 5 carried content tokens
        assert_eq!(c.tokens_padded, 16 - 5);
        assert_eq!(c.slots_padded, 2);
        let buckets = stats.bucket_snapshot();
        assert_eq!(buckets[0], (4, 1, 2), "bucket 4: one wave, two entries");
        assert_eq!(buckets[1], (8, 0, 0), "bucket 8 untouched");
    }

    /// Property: poison the reused ids scratch between batches; after
    /// `execute_batch`, (a) every response decodes to *its own* content
    /// (no cross-request or cross-batch leak), (b) all responses of one
    /// batch share a single logits buffer (zero-copy demux), (c) every
    /// assembled row carries exactly its request's content or the
    /// template pad row, and (d) no poisoned cell survives anywhere.
    #[test]
    fn prop_poisoned_scratch_never_leaks_between_batches() {
        const POISON: i32 = 7777;
        crate::util::proptest::check("scratch poison leak", 25, |g| {
            let n_mux = g.rng.range(1, 5);
            let batch = g.rng.range(1, 4);
            let seq_len = 6;
            let n_classes = 7;
            let backend = FakeBackend::new("cls", n_mux, batch, seq_len, n_classes);
            let tok = Tokenizer::new(default_vocab(), backend.meta().vocab_size);
            let template = MuxTemplate::new(backend.meta(), &tok);
            let stats = Stats::default();
            let mut scratch = Vec::new();
            let capacity = template.capacity();
            let pad_row = tok.pad_row(seq_len);
            for round in 0..4u64 {
                scratch.clear();
                scratch.resize(template.ids_len(), POISON);
                let n_entries = g.rng.range(1, capacity + 1);
                let mut cells = Vec::new();
                let mut contents = Vec::new();
                let mut entries = Vec::new();
                for pos in 0..n_entries {
                    // content distinct per (round, pos) so any stale or
                    // crossed row changes the fake model's prediction
                    let mut c = vec![tok.vocab.pad; seq_len];
                    c[0] = tok.vocab.cls;
                    c[1] = tok.vocab.content_base
                        + ((round as usize * capacity + pos) % 200) as i32;
                    let cell = OnceCellSync::new();
                    cells.push(cell.clone());
                    contents.push(c.clone());
                    entries.push(make_req(pos as u64, c, cell));
                }
                let eb =
                    ExecBatch { seq: round, bucket: 0, entries, formed_at: Instant::now() };
                execute_batch(&backend, &template, SlotPolicy::Fill, &stats, eb, &mut scratch)
                    .map_err(|e| e.to_string())?;
                let mut first: Option<Response> = None;
                for (pos, cell) in cells.iter().enumerate() {
                    let r = cell
                        .wait_timeout(Duration::from_secs(5))
                        .ok_or_else(|| "request left unfulfilled".to_string())?
                        .map_err(|e| e.to_string())?;
                    let want = FakeBackend::expected_class(&contents[pos], n_classes);
                    if r.pred_class() != want {
                        return Err(format!(
                            "round {round} pos {pos}: leaked tokens (pred {}, want {want})",
                            r.pred_class()
                        ));
                    }
                    match &first {
                        None => first = Some(r),
                        Some(f) => {
                            if !f.logits.same_buffer(&r.logits) {
                                return Err(format!(
                                    "round {round} pos {pos}: demux copied instead of sharing"
                                ));
                            }
                        }
                    }
                }
                // assembled tensor: placed rows carry their own content,
                // every other slot carries the template pad row
                for pos in 0..capacity {
                    let range = template.content_range(pos / n_mux, pos % n_mux);
                    let row = &scratch[range];
                    let want: &[i32] =
                        if pos < n_entries { &contents[pos] } else { &pad_row };
                    if row != want {
                        return Err(format!(
                            "round {round} slot {pos}: assembled row {row:?} != {want:?}"
                        ));
                    }
                }
                if let Some(i) = scratch.iter().position(|&x| x == POISON) {
                    return Err(format!("round {round}: poison survived at index {i}"));
                }
            }
            Ok(())
        });
    }
}
