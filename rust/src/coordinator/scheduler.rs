//! Scheduler: turn ExecBatches into PJRT executions and route the
//! demultiplexed outputs back to their requests.
//!
//! Input assembly mirrors the compile-path layout exactly (pinned by the
//! parity integration test): for group `g`, slot `i`, the model row is
//! `prefix^i ++ content`, and the output logits for that request live at
//! flat offset `(g * n_mux + i) * per_slot_len`.

use std::sync::atomic::Ordering;
use std::sync::Arc;
use std::time::Instant;

use super::batcher::ExecBatch;
use super::policy::SlotPolicy;
use super::request::Response;
use crate::runtime::LoadedModel;
use crate::tokenizer::Tokenizer;
use crate::util::metrics::{Counters, Histogram};

/// `LoadedModel` wraps PJRT FFI handles (raw pointers), which the xla
/// crate does not mark Send/Sync. The PJRT C API is thread-safe for
/// compilation-free usage (execute / buffer upload), and every model here
/// is used behind an `Arc` without interior mutation, so sharing across
/// the scheduler threads is sound.
pub struct SharedModel(pub Arc<LoadedModel>);

// SAFETY: see type-level comment — PJRT execution and host-to-device
// transfer are thread-safe in the CPU plugin; we never mutate LoadedModel
// after construction.
unsafe impl Send for SharedModel {}
unsafe impl Sync for SharedModel {}

impl Clone for SharedModel {
    fn clone(&self) -> Self {
        SharedModel(self.0.clone())
    }
}

impl std::ops::Deref for SharedModel {
    type Target = LoadedModel;
    fn deref(&self) -> &LoadedModel {
        &self.0
    }
}

/// Shared serving statistics.
#[derive(Default)]
pub struct Stats {
    pub counters: Counters,
    /// submit -> response fulfilled
    pub e2e_latency: Histogram,
    /// batch formed -> execution done
    pub exec_latency: Histogram,
}

/// Per-slot output length (flattened logits) for the model's task.
pub fn per_slot_len(model: &LoadedModel) -> usize {
    match model.meta.task.as_str() {
        "cls" => model.meta.n_classes,
        "token" => model.meta.seq_len * model.meta.n_classes,
        other => panic!("unsupported serving task {other}"),
    }
}

/// Execute one batch and fulfill its requests. Returns Err only on
/// runtime failure (callers treat that as fatal for the worker).
pub fn execute_batch(
    model: &LoadedModel,
    tok: &Tokenizer,
    policy: SlotPolicy,
    stats: &Stats,
    batch: ExecBatch,
    ids_scratch: &mut Vec<i32>,
) -> anyhow::Result<()> {
    let n_mux = model.meta.n_mux;
    let b = model.meta.batch;
    let input_len = model.meta.input_len;
    let seq_len = model.meta.seq_len;
    let prefix_len = input_len - seq_len;
    debug_assert!(prefix_len == 0 || prefix_len == n_mux);
    let capacity = b * n_mux;
    assert!(batch.entries.len() <= capacity, "batcher produced oversized batch");

    // --- assemble the (b, n_mux, input_len) ids tensor -------------------
    ids_scratch.clear();
    ids_scratch.resize(capacity * input_len, tok.vocab.pad);
    // fill every slot with the pad row first (empty slots stay in-distribution)
    let pad_row = tok.pad_row(seq_len);
    for g in 0..b {
        for slot in 0..n_mux {
            let row = &mut ids_scratch
                [((g * n_mux) + slot) * input_len..((g * n_mux) + slot + 1) * input_len];
            if prefix_len > 0 {
                for (j, p) in row[..prefix_len].iter_mut().enumerate() {
                    *p = if j == slot {
                        tok.vocab.idx_base + slot as i32
                    } else {
                        tok.vocab.eps_pad
                    };
                }
            }
            row[prefix_len..].copy_from_slice(&pad_row);
        }
    }
    // place the real requests
    let mut placement: Vec<(usize, usize)> = Vec::with_capacity(batch.entries.len());
    for (pos, req) in batch.entries.iter().enumerate() {
        let g = pos / n_mux;
        let slot = policy.slot_of(batch.seq.wrapping_add(g as u64), pos % n_mux, n_mux);
        debug_assert_eq!(req.content.len(), seq_len, "request content must be framed");
        let row = &mut ids_scratch
            [((g * n_mux) + slot) * input_len..((g * n_mux) + slot + 1) * input_len];
        row[prefix_len..].copy_from_slice(&req.content);
        placement.push((g, slot));
    }
    let padded = capacity - batch.entries.len();

    // --- execute ----------------------------------------------------------
    let t_exec = Instant::now();
    let out = model.run_ids(ids_scratch)?;
    stats.exec_latency.record_duration(t_exec.elapsed());
    stats.counters.groups_executed.fetch_add(b as u64, Ordering::Relaxed);
    stats.counters.slots_padded.fetch_add(padded as u64, Ordering::Relaxed);

    // --- demux dispatch ----------------------------------------------------
    let slot_len = per_slot_len(model);
    let now = Instant::now();
    for (req, (g, slot)) in batch.entries.into_iter().zip(placement) {
        let off = ((g * n_mux) + slot) * slot_len;
        let logits = out[off..off + slot_len].to_vec();
        let latency = now.duration_since(req.submitted);
        stats.e2e_latency.record_duration(latency);
        stats.counters.completed.fetch_add(1, Ordering::Relaxed);
        req.done.set(Response {
            id: req.id,
            slot,
            group: batch.seq,
            logits,
            n_classes: model.meta.n_classes,
            latency,
        });
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn shared_model_is_send_sync() {
        fn assert_send_sync<T: Send + Sync>() {}
        assert_send_sync::<SharedModel>();
    }
}
