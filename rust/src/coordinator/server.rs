//! TCP front end over any [`Submit`] engine (single coordinator or
//! adaptive-N router).
//!
//! Two wire protocols share every connection, dispatched per line:
//!
//! **v1 (legacy, lockstep)** — one request per line, one reply per line,
//! in order:
//! ```text
//!   CLS <token text>   -> OK <pred> slot=<i> us=<latency>
//!   TOK <token text>   -> OK <tag,tag,..> slot=<i> us=<latency>
//!   STATS              -> one-line counters snapshot
//!   QUIT               -> closes the connection
//!   errors             -> ERR <message>
//! ```
//!
//! **v2 (pipelined, typed)** — any line starting with `{` is a
//! line-delimited JSON request with a *client-chosen id*. Many requests
//! may be in flight per connection; replies are correlated by id and
//! written in completion order (not submission order):
//! ```text
//!   {"id":..,"op":"classify"|"tag","text":"t1 t2"|"ids":[..],
//!    "deadline_ms":N?,"logits":bool?}
//!   {"id":..,"op":"batch","items":[<op objects without id>..]}
//!   {"id":..,"op":"stats"} / {"op":"quit"}
//! -> {"id":..,"ok":true,"pred":N|"tags":[..],"slot":N,"group":N,"us":N}
//! -> {"id":..,"ok":true,"results":[..]}          (batch, one line)
//! -> {"id":..,"ok":false,"error":"<code>","message":".."}
//! ```
//! Error codes are the stable [`SubmitError::code`] /
//! [`EngineError::code`] strings plus `bad_json` and `bad_request`.
//!
//! One OS reader thread plus one completion-pump thread per connection,
//! capped by a semaphore-ish counter — the heavy lifting (batching,
//! model execution) happens on the engine's threads. Completions are
//! delivered to a per-connection [`CompletionQueue`], so a pipelined
//! connection never blocks a thread per in-flight request. Reads use a
//! timeout so `Server::stop()` terminates idle connections promptly.

use std::collections::HashMap;
use std::io::{BufRead, BufReader, Write};
use std::net::{TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, AtomicUsize, Ordering};
use std::sync::{Arc, Mutex};
use std::time::Duration;

use anyhow::Result;

use crate::util::json::{num, obj, s, Json};
use crate::util::threadpool::Channel;

use super::api::{CompletionQueue, InferenceRequest, Payload, Submit, TaskKind};
use super::request::Response;

/// Completions buffered per connection before the pump writes them out.
///
/// Slow-consumer shedding: if a client keeps >CAP requests in flight
/// while not reading replies (the pump is stuck on TCP backpressure),
/// further completions for that connection are dropped rather than
/// blocking the engine's shared scheduler threads — those ids simply
/// never get a reply line (and a batch containing one never completes).
/// Well-behaved clients that read replies never get near the cap.
const PIPELINE_COMPLETION_CAP: usize = 4096;

pub struct ServerConfig {
    pub addr: String,
    pub max_connections: usize,
    /// Poll interval at which blocked reads re-check the stop flag; also
    /// bounds how long `Server::stop()` waits on idle connections.
    pub read_timeout: Duration,
}

impl Default for ServerConfig {
    fn default() -> Self {
        ServerConfig {
            addr: "127.0.0.1:7071".into(),
            max_connections: 64,
            read_timeout: Duration::from_millis(250),
        }
    }
}

pub struct Server {
    pub local_addr: std::net::SocketAddr,
    stop: Arc<AtomicBool>,
    accept_thread: Option<std::thread::JoinHandle<()>>,
}

impl Server {
    /// Start serving `engine` on `cfg.addr`. Non-blocking; returns the
    /// bound address (use port 0 to pick a free port).
    pub fn start(engine: Arc<dyn Submit>, cfg: ServerConfig) -> Result<Server> {
        let listener = TcpListener::bind(&cfg.addr)?;
        let local_addr = listener.local_addr()?;
        listener.set_nonblocking(true)?;
        let stop = Arc::new(AtomicBool::new(false));
        let stop2 = stop.clone();
        let live = Arc::new(AtomicUsize::new(0));
        let accept_thread = std::thread::Builder::new()
            .name("datamux-accept".into())
            .spawn(move || {
                while !stop2.load(Ordering::Relaxed) {
                    match listener.accept() {
                        Ok((stream, _)) => {
                            if live.load(Ordering::Relaxed) >= cfg.max_connections {
                                let mut s = stream;
                                let _ = s.write_all(b"ERR too many connections\n");
                                continue;
                            }
                            live.fetch_add(1, Ordering::Relaxed);
                            let engine = engine.clone();
                            let live = live.clone();
                            let stop = stop2.clone();
                            let read_timeout = cfg.read_timeout;
                            std::thread::spawn(move || {
                                // decrement on drop so a panicking handler
                                // can't leak a max_connections slot
                                struct LiveGuard(Arc<AtomicUsize>);
                                impl Drop for LiveGuard {
                                    fn drop(&mut self) {
                                        self.0.fetch_sub(1, Ordering::Relaxed);
                                    }
                                }
                                let _guard = LiveGuard(live);
                                let _ = handle_conn(stream, &engine, &stop, read_timeout);
                            });
                        }
                        Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => {
                            std::thread::sleep(Duration::from_millis(2));
                        }
                        Err(_) => break,
                    }
                }
            })?;
        Ok(Server { local_addr, stop, accept_thread: Some(accept_thread) })
    }

    pub fn stop(mut self) {
        self.stop.store(true, Ordering::Relaxed);
        if let Some(t) = self.accept_thread.take() {
            let _ = t.join();
        }
    }
}

impl Drop for Server {
    fn drop(&mut self) {
        self.stop.store(true, Ordering::Relaxed);
        if let Some(t) = self.accept_thread.take() {
            let _ = t.join();
        }
    }
}

fn handle_conn(
    stream: TcpStream,
    engine: &Arc<dyn Submit>,
    stop: &AtomicBool,
    read_timeout: Duration,
) -> Result<()> {
    stream.set_nodelay(true).ok();
    if !read_timeout.is_zero() {
        // without this, an idle connection parked in read_line() only
        // notices `stop` after its *next* line arrives
        stream.set_read_timeout(Some(read_timeout)).ok();
    }
    let writer = Arc::new(Mutex::new(stream.try_clone()?));
    let mut reader = BufReader::new(stream);
    // created lazily on the first v2 line: pure-v1 connections never pay
    // for the pump thread or the completion queue
    let mut conn: Option<PipelinedConn<TcpStream>> = None;
    // accumulate raw bytes, not a String: read_line() would discard
    // partially-read bytes when a read timeout splits a multibyte UTF-8
    // character, silently corrupting the request line
    let mut line_buf: Vec<u8> = Vec::new();
    loop {
        if stop.load(Ordering::Relaxed) {
            break;
        }
        match reader.read_until(b'\n', &mut line_buf) {
            Ok(0) => break, // EOF
            Ok(_) => {
                let text = String::from_utf8_lossy(&line_buf).into_owned();
                let l = text.trim();
                let keep_open = if l.is_empty() {
                    true
                } else if l.starts_with('{') {
                    conn.get_or_insert_with(|| PipelinedConn::new(engine.clone(), writer.clone()))
                        .handle_line(l)
                } else {
                    match handle_line(l, engine.as_ref()) {
                        Some(reply) => {
                            write_line(&writer, &reply)?;
                            true
                        }
                        None => false, // QUIT
                    }
                };
                line_buf.clear();
                if !keep_open {
                    break;
                }
            }
            // timeout: partial bytes stay in `line_buf`; loop to re-check
            // `stop` and keep reading
            Err(e)
                if matches!(
                    e.kind(),
                    std::io::ErrorKind::WouldBlock
                        | std::io::ErrorKind::TimedOut
                        | std::io::ErrorKind::Interrupted
                ) =>
            {
                continue
            }
            Err(_) => break,
        }
    }
    Ok(())
}

fn write_line<W: Write>(writer: &Mutex<W>, line: &str) -> std::io::Result<()> {
    let mut w = writer.lock().unwrap();
    w.write_all(line.as_bytes())?;
    w.write_all(b"\n")?;
    w.flush()
}

// ---------------------------------------------------------------------------
// protocol v1 (legacy, lockstep)
// ---------------------------------------------------------------------------

/// v1 protocol logic, factored for unit testing without sockets.
pub fn handle_line(line: &str, engine: &dyn Submit) -> Option<String> {
    let (cmd, rest) = match line.split_once(' ') {
        Some((c, r)) => (c, r),
        None => (line, ""),
    };
    match cmd {
        "QUIT" => None,
        "STATS" => {
            let c = engine.counters();
            Some(format!(
                "OK submitted={} completed={} rejected={} groups={} padded={} \
                 tokens_padded={} expired={}",
                c.submitted,
                c.completed,
                c.rejected,
                c.groups_executed,
                c.slots_padded,
                c.tokens_padded,
                c.expired
            ))
        }
        "CLS" | "TOK" => {
            // v1 is task-agnostic on submission (back-compat): the
            // command only picks the reply formatting. CLS splits
            // sentence pairs on ' [SEP] '; TOK treats the whole line as
            // one part — both exactly as the legacy protocol did.
            let payload = if cmd == "CLS" {
                Payload::Text(rest.to_string())
            } else {
                // unpadded: the engine assigns the bucket and pads there
                match engine.tokenizer().encode_framed_unpadded(&[rest], engine.seq_len()) {
                    Ok(ids) => Payload::Framed(ids),
                    Err(e) => return Some(format!("ERR tokenize: {e}")),
                }
            };
            let req =
                InferenceRequest { task: engine.native_task(), payload, deadline: None };
            match engine.submit(req) {
                Ok(h) => match h.wait() {
                    Ok(r) if cmd == "CLS" => Some(format!(
                        "OK {} slot={} us={}",
                        r.pred_class(),
                        r.slot,
                        r.latency.as_micros()
                    )),
                    Ok(r) => {
                        let tags: Vec<String> =
                            r.pred_tokens().iter().map(|t| t.to_string()).collect();
                        Some(format!(
                            "OK {} slot={} us={}",
                            tags.join(","),
                            r.slot,
                            r.latency.as_micros()
                        ))
                    }
                    Err(e) => Some(format!("ERR {e}")),
                },
                Err(e) => Some(format!("ERR {e}")),
            }
        }
        _ => Some(format!("ERR unknown command '{cmd}'")),
    }
}

// ---------------------------------------------------------------------------
// protocol v2 (pipelined, typed)
// ---------------------------------------------------------------------------

struct Pending {
    /// client-chosen id, echoed verbatim (string, number, anything)
    id: Json,
    kind: TaskKind,
    want_logits: bool,
    /// set when this request is one item of a BATCH submit
    batch: Option<(Arc<Mutex<BatchAcc>>, usize)>,
}

struct BatchAcc {
    id: Json,
    remaining: usize,
    results: Vec<Json>,
}

/// Per-connection v2 state: a tag allocator, the pending-request table,
/// and a completion-pump thread that writes replies as results land
/// (out of submission order when lanes complete at different speeds).
struct PipelinedConn<W: Write + Send + 'static> {
    engine: Arc<dyn Submit>,
    writer: Arc<Mutex<W>>,
    cq: CompletionQueue,
    pending: Arc<Mutex<HashMap<u64, Pending>>>,
    next_tag: u64,
    pump: Option<std::thread::JoinHandle<()>>,
}

impl<W: Write + Send + 'static> PipelinedConn<W> {
    fn new(engine: Arc<dyn Submit>, writer: Arc<Mutex<W>>) -> Self {
        let cq: CompletionQueue = Channel::bounded(PIPELINE_COMPLETION_CAP);
        let pending: Arc<Mutex<HashMap<u64, Pending>>> = Arc::new(Mutex::new(HashMap::new()));
        let pump = {
            let cq = cq.clone();
            let pending = pending.clone();
            let writer = writer.clone();
            std::thread::Builder::new()
                .name("datamux-conn-pump".into())
                .spawn(move || run_completion_pump(&cq, &pending, &writer))
                .expect("spawn completion pump")
        };
        PipelinedConn { engine, writer, cq, pending, next_tag: 1, pump: Some(pump) }
    }

    /// Handle one v2 line; returns false when the connection should close.
    fn handle_line(&mut self, line: &str) -> bool {
        let v = match Json::parse(line) {
            Ok(v) => v,
            Err(e) => {
                self.write_error(&Json::Null, "bad_json", &e.to_string());
                return true;
            }
        };
        let id = v.get("id").cloned().unwrap_or(Json::Null);
        match v.get("op").and_then(Json::as_str) {
            Some("quit") => false,
            Some("stats") => {
                let line = attach_id(id, self.stats_json()).to_string();
                let _ = write_line(&self.writer, &line);
                true
            }
            Some("batch") => {
                self.handle_batch(&id, &v);
                true
            }
            Some("classify") | Some("tag") => {
                self.handle_single(&id, &v);
                true
            }
            Some(other) => {
                self.write_error(&id, "bad_request", &format!("unknown op '{other}'"));
                true
            }
            None => {
                self.write_error(&id, "bad_request", "missing 'op'");
                true
            }
        }
    }

    fn handle_single(&mut self, id: &Json, v: &Json) {
        match parse_task_item(v) {
            Err(msg) => self.write_error(id, "bad_request", &msg),
            Ok((req, kind, want_logits)) => {
                let tag = self.alloc_tag();
                // register before submitting: the completion may land
                // before submit_tagged even returns
                self.pending.lock().unwrap().insert(
                    tag,
                    Pending { id: id.clone(), kind, want_logits, batch: None },
                );
                if let Err(e) = self.engine.submit_tagged(req, tag, &self.cq) {
                    self.pending.lock().unwrap().remove(&tag);
                    self.write_error(id, e.code(), &e.to_string());
                }
            }
        }
    }

    fn handle_batch(&mut self, id: &Json, v: &Json) {
        let items = match v.get("items").and_then(Json::as_arr) {
            Some(items) => items,
            None => {
                self.write_error(id, "bad_request", "batch needs an 'items' array");
                return;
            }
        };
        if items.is_empty() {
            let line = attach_id(
                id.clone(),
                obj(vec![("ok", Json::Bool(true)), ("results", Json::Arr(Vec::new()))]),
            )
            .to_string();
            let _ = write_line(&self.writer, &line);
            return;
        }
        let acc = Arc::new(Mutex::new(BatchAcc {
            id: id.clone(),
            remaining: items.len(),
            results: vec![Json::Null; items.len()],
        }));
        for (idx, item) in items.iter().enumerate() {
            match parse_task_item(item) {
                Err(msg) => {
                    self.finish_batch_item(&acc, idx, error_json("bad_request", &msg));
                }
                Ok((req, kind, want_logits)) => {
                    let tag = self.alloc_tag();
                    self.pending.lock().unwrap().insert(
                        tag,
                        Pending {
                            id: Json::Null,
                            kind,
                            want_logits,
                            batch: Some((acc.clone(), idx)),
                        },
                    );
                    if let Err(e) = self.engine.submit_tagged(req, tag, &self.cq) {
                        self.pending.lock().unwrap().remove(&tag);
                        self.finish_batch_item(&acc, idx, error_json(e.code(), &e.to_string()));
                    }
                }
            }
        }
    }

    fn finish_batch_item(&self, acc: &Arc<Mutex<BatchAcc>>, idx: usize, result: Json) {
        if let Some(line) = batch_item_done(acc, idx, result) {
            let _ = write_line(&self.writer, &line);
        }
    }

    fn stats_json(&self) -> Json {
        let c = self.engine.counters();
        let l = self.engine.latency();
        let qw = self.engine.queue_wait();
        let status = self.engine.lane_status();
        // per-lane health: which Ns are alive, how many waves each
        // pulled, what a dead lane handed back to the shared queue, and
        // the per-bucket wave/entry split
        let lanes: Vec<Json> = status
            .iter()
            .map(|lane| {
                let lane_buckets: Vec<Json> = lane
                    .buckets
                    .iter()
                    .map(|b| {
                        obj(vec![
                            ("seq_len", num(b.seq_len as f64)),
                            ("waves", num(b.waves as f64)),
                            ("entries", num(b.entries as f64)),
                        ])
                    })
                    .collect();
                obj(vec![
                    ("n_mux", num(lane.n_mux as f64)),
                    ("alive", Json::Bool(lane.alive)),
                    ("pulls", num(lane.pulls as f64)),
                    ("requeued", num(lane.requeued as f64)),
                    ("completed", num(lane.completed as f64)),
                    ("buckets", Json::Arr(lane_buckets)),
                ])
            })
            .collect();
        // engine-wide per-bucket aggregate (lanes share one registry)
        let mut agg: Vec<(usize, u64, u64)> = Vec::new();
        for lane in &status {
            for b in &lane.buckets {
                match agg.iter_mut().find(|(l, _, _)| *l == b.seq_len) {
                    Some(slot) => {
                        slot.1 += b.waves;
                        slot.2 += b.entries;
                    }
                    None => agg.push((b.seq_len, b.waves, b.entries)),
                }
            }
        }
        agg.sort_unstable_by_key(|&(l, _, _)| l);
        let buckets: Vec<Json> = agg
            .into_iter()
            .map(|(seq_len, waves, entries)| {
                obj(vec![
                    ("seq_len", num(seq_len as f64)),
                    ("waves", num(waves as f64)),
                    ("entries", num(entries as f64)),
                ])
            })
            .collect();
        obj(vec![
            ("ok", Json::Bool(true)),
            (
                "stats",
                obj(vec![
                    ("submitted", num(c.submitted as f64)),
                    ("completed", num(c.completed as f64)),
                    ("rejected", num(c.rejected as f64)),
                    ("expired", num(c.expired as f64)),
                    ("groups", num(c.groups_executed as f64)),
                    ("padded", num(c.slots_padded as f64)),
                    ("tokens_padded", num(c.tokens_padded as f64)),
                    ("intake_waves", num(c.intake_waves as f64)),
                    ("scratch_reallocs", num(c.scratch_reallocs as f64)),
                    ("queue_depth", num(self.engine.queue_depth() as f64)),
                    ("p50_us", num(l.p50_ns as f64 / 1e3)),
                    ("p99_us", num(l.p99_ns as f64 / 1e3)),
                    ("queue_wait_p50_us", num(qw.p50_ns as f64 / 1e3)),
                    ("queue_wait_p99_us", num(qw.p99_ns as f64 / 1e3)),
                    ("buckets", Json::Arr(buckets)),
                    ("lanes", Json::Arr(lanes)),
                ]),
            ),
        ])
    }

    fn write_error(&self, id: &Json, code: &str, msg: &str) {
        let line = attach_id(id.clone(), error_json(code, msg)).to_string();
        let _ = write_line(&self.writer, &line);
    }

    fn alloc_tag(&mut self) -> u64 {
        let t = self.next_tag;
        self.next_tag += 1;
        t
    }
}

impl<W: Write + Send + 'static> Drop for PipelinedConn<W> {
    fn drop(&mut self) {
        // close the completion queue: the pump drains what already
        // landed, then exits; late completions are dropped harmlessly
        self.cq.close();
        if let Some(p) = self.pump.take() {
            let _ = p.join();
        }
    }
}

/// Drain tagged completions and write replies, in completion order.
fn run_completion_pump<W: Write>(
    cq: &CompletionQueue,
    pending: &Mutex<HashMap<u64, Pending>>,
    writer: &Mutex<W>,
) {
    while let Some((tag, result)) = cq.recv() {
        let info = match pending.lock().unwrap().remove(&tag) {
            Some(info) => info,
            None => continue, // already answered synchronously
        };
        let payload = match result {
            Ok(r) => success_json(info.kind, info.want_logits, &r),
            Err(e) => error_json(e.code(), &e.to_string()),
        };
        match info.batch {
            None => {
                let line = attach_id(info.id, payload).to_string();
                let _ = write_line(writer, &line);
            }
            Some((acc, idx)) => {
                if let Some(line) = batch_item_done(&acc, idx, payload) {
                    let _ = write_line(writer, &line);
                }
            }
        }
    }
}

/// Record one finished batch item; returns the reply line when the whole
/// batch is done.
fn batch_item_done(acc: &Mutex<BatchAcc>, idx: usize, result: Json) -> Option<String> {
    let mut a = acc.lock().unwrap();
    a.results[idx] = result;
    a.remaining -= 1;
    if a.remaining > 0 {
        return None;
    }
    let results = std::mem::take(&mut a.results);
    Some(
        attach_id(
            a.id.clone(),
            obj(vec![("ok", Json::Bool(true)), ("results", Json::Arr(results))]),
        )
        .to_string(),
    )
}

/// Parse one task object (`op`/`text`|`ids`/`deadline_ms`/`logits`) into
/// a typed request.
fn parse_task_item(v: &Json) -> Result<(InferenceRequest, TaskKind, bool), String> {
    let kind = match v.get("op").and_then(Json::as_str) {
        Some("classify") | None => TaskKind::Classify,
        Some("tag") => TaskKind::TagTokens,
        Some(other) => return Err(format!("unknown op '{other}'")),
    };
    let payload = if let Some(ids) = v.get("ids").and_then(Json::as_arr) {
        let mut parsed = Vec::with_capacity(ids.len());
        for x in ids {
            // strict: reject floats and out-of-range values instead of
            // silently truncating/wrapping them into wrong token ids
            match x.as_f64() {
                Some(f)
                    if f.fract() == 0.0
                        && (i32::MIN as f64..=i32::MAX as f64).contains(&f) =>
                {
                    parsed.push(f as i32)
                }
                _ => return Err("'ids' must be an array of i32 integers".to_string()),
            }
        }
        Payload::Framed(parsed)
    } else if let Some(text) = v.get("text").and_then(Json::as_str) {
        Payload::Text(text.to_string())
    } else {
        return Err("missing 'text' or 'ids'".to_string());
    };
    // clamp to [0, 1 day]: Duration::from_secs_f64 panics on huge or
    // non-finite input, and a panic here would kill the connection thread
    let deadline = v
        .get("deadline_ms")
        .and_then(Json::as_f64)
        .filter(|ms| ms.is_finite())
        .map(|ms| Duration::from_secs_f64(ms.clamp(0.0, 86_400_000.0) / 1e3));
    let want_logits = v.get("logits").and_then(Json::as_bool).unwrap_or(false);
    Ok((InferenceRequest { task: kind, payload, deadline }, kind, want_logits))
}

fn success_json(kind: TaskKind, want_logits: bool, r: &Response) -> Json {
    let mut fields = vec![
        ("ok", Json::Bool(true)),
        ("slot", num(r.slot as f64)),
        ("group", num(r.group as f64)),
        ("us", num(r.latency.as_micros() as f64)),
    ];
    match kind {
        TaskKind::Classify => fields.push(("pred", num(r.pred_class() as f64))),
        TaskKind::TagTokens => fields.push((
            "tags",
            Json::Arr(r.pred_tokens().into_iter().map(|t| num(t as f64)).collect()),
        )),
    }
    if want_logits {
        fields.push((
            "logits",
            Json::Arr(r.logits.iter().map(|&x| num(x as f64)).collect()),
        ));
    }
    obj(fields)
}

fn error_json(code: &str, msg: &str) -> Json {
    obj(vec![("ok", Json::Bool(false)), ("error", s(code)), ("message", s(msg))])
}

fn attach_id(id: Json, payload: Json) -> Json {
    match payload {
        Json::Obj(mut m) => {
            m.insert("id".to_string(), id);
            Json::Obj(m)
        }
        other => other,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::request::EngineError;
    use crate::coordinator::EngineBuilder;
    use crate::runtime::FakeBackend;
    use std::time::Instant;

    fn fake_cls_engine() -> Arc<dyn Submit> {
        Arc::new(
            EngineBuilder::new()
                .max_wait_ms(0)
                .build_backend(Arc::new(FakeBackend::new("cls", 2, 1, 8, 3)))
                .unwrap(),
        )
    }

    fn new_conn(engine: Arc<dyn Submit>) -> (PipelinedConn<Vec<u8>>, Arc<Mutex<Vec<u8>>>) {
        let writer = Arc::new(Mutex::new(Vec::new()));
        (PipelinedConn::new(engine, writer.clone()), writer)
    }

    fn lines(writer: &Mutex<Vec<u8>>) -> Vec<String> {
        String::from_utf8(writer.lock().unwrap().clone())
            .unwrap()
            .lines()
            .map(|l| l.to_string())
            .collect()
    }

    /// Poll until `n` reply lines landed (completions are asynchronous).
    fn wait_for_lines(writer: &Mutex<Vec<u8>>, n: usize) -> Vec<String> {
        let t0 = Instant::now();
        loop {
            let ls = lines(writer);
            if ls.len() >= n {
                return ls;
            }
            assert!(
                t0.elapsed() < Duration::from_secs(10),
                "timed out waiting for {n} reply lines; got {ls:?}"
            );
            std::thread::sleep(Duration::from_millis(5));
        }
    }

    #[test]
    fn v1_unknown_command_and_stats() {
        let engine = fake_cls_engine();
        let reply = handle_line("BOGUS x", engine.as_ref()).unwrap();
        assert!(reply.starts_with("ERR"), "{reply}");
        let stats = handle_line("STATS", engine.as_ref()).unwrap();
        assert!(stats.contains("submitted="), "{stats}");
        assert!(handle_line("QUIT", engine.as_ref()).is_none());
    }

    #[test]
    fn v1_cls_roundtrip_and_tokenize_error() {
        let engine = fake_cls_engine();
        let reply = handle_line("CLS t1 t2 t3", engine.as_ref()).unwrap();
        assert!(reply.starts_with("OK "), "{reply}");
        let reply = handle_line("CLS hello world", engine.as_ref()).unwrap();
        assert!(reply.starts_with("ERR"), "unknown words must ERR: {reply}");
    }

    #[test]
    fn v2_malformed_json_and_unknown_op() {
        let (mut conn, writer) = new_conn(fake_cls_engine());
        assert!(conn.handle_line("{nope"));
        assert!(conn.handle_line(r#"{"id":7,"op":"frobnicate"}"#));
        assert!(conn.handle_line(r#"{"id":8}"#));
        let ls = lines(&writer);
        assert_eq!(ls.len(), 3, "{ls:?}");
        assert!(ls[0].contains("bad_json"), "{}", ls[0]);
        assert!(ls[1].contains("bad_request") && ls[1].contains("\"id\":7"), "{}", ls[1]);
        assert!(ls[2].contains("missing 'op'"), "{}", ls[2]);
    }

    #[test]
    fn v2_classify_echoes_id_and_predicts() {
        let (mut conn, writer) = new_conn(fake_cls_engine());
        assert!(conn.handle_line(r#"{"id":"req-a","op":"classify","text":"t1 t2"}"#));
        let ls = wait_for_lines(&writer, 1);
        assert!(ls[0].contains("\"id\":\"req-a\""), "{}", ls[0]);
        assert!(ls[0].contains("\"ok\":true"), "{}", ls[0]);
        // [CLS]=1 t1=45 t2=46 [SEP]=2 + padding -> sum=94 -> 94 % 3 = 1
        assert!(ls[0].contains("\"pred\":1"), "{}", ls[0]);
    }

    #[test]
    fn v2_wrong_task_is_typed() {
        let (mut conn, writer) = new_conn(fake_cls_engine());
        assert!(conn.handle_line(r#"{"id":1,"op":"tag","text":"t1"}"#));
        let ls = lines(&writer);
        assert!(ls[0].contains("wrong_task"), "{}", ls[0]);
    }

    #[test]
    fn v2_batch_mixes_success_and_typed_errors() {
        let (mut conn, writer) = new_conn(fake_cls_engine());
        // item 0: valid framed ids; item 1: over the model max (9 > 8);
        // item 2: short unpadded ids are now *valid* (bucketed)
        assert!(conn.handle_line(
            r#"{"id":"b1","op":"batch","items":[
                {"op":"classify","ids":[1,45,46,2,0,0,0,0]},
                {"op":"classify","ids":[1,2,3,4,5,6,7,8,9]},
                {"op":"classify","ids":[1,45,46,2]}]}"#
                .replace('\n', " ")
                .trim()
        ));
        let ls = wait_for_lines(&writer, 1);
        assert_eq!(ls.len(), 1, "batch answers on one line: {ls:?}");
        assert!(ls[0].contains("\"id\":\"b1\""), "{}", ls[0]);
        // sum(1+45+46+2)=94 -> pred 1, for both the padded and the
        // unpadded form of the same content
        assert_eq!(ls[0].matches("\"pred\":1").count(), 2, "{}", ls[0]);
        assert!(ls[0].contains("too_long"), "{}", ls[0]);
        assert!(!ls[0].contains("bad_frame"), "{}", ls[0]);
    }

    #[test]
    fn v2_hostile_deadline_and_float_ids_are_handled() {
        let (mut conn, writer) = new_conn(fake_cls_engine());
        // a huge deadline must not panic Duration::from_secs_f64 — it is
        // clamped and the request completes normally
        assert!(conn.handle_line(
            r#"{"id":1,"op":"classify","text":"t1","deadline_ms":1e300}"#
        ));
        let ls = wait_for_lines(&writer, 1);
        assert!(ls[0].contains("\"ok\":true"), "{}", ls[0]);
        // non-integer ids are rejected, not silently truncated
        assert!(conn.handle_line(r#"{"id":2,"op":"classify","ids":[1.5,2,3,4,5,6,7,8]}"#));
        let ls = wait_for_lines(&writer, 2);
        assert!(ls[1].contains("bad_request"), "{}", ls[1]);
    }

    #[test]
    fn v2_stats_and_quit() {
        let (mut conn, writer) = new_conn(fake_cls_engine());
        assert!(conn.handle_line(r#"{"id":0,"op":"stats"}"#));
        assert!(!conn.handle_line(r#"{"op":"quit"}"#), "quit closes");
        let ls = lines(&writer);
        assert!(ls[0].contains("\"queue_depth\""), "{}", ls[0]);
        // a single coordinator reports itself as one healthy lane
        let v = Json::parse(&ls[0]).unwrap();
        let lanes = v
            .get("stats")
            .and_then(|s| s.get("lanes"))
            .and_then(Json::as_arr)
            .expect("stats carry per-lane health");
        assert_eq!(lanes.len(), 1, "{}", ls[0]);
        assert_eq!(lanes[0].get("alive").and_then(Json::as_bool), Some(true));
        assert_eq!(lanes[0].get("n_mux").and_then(Json::as_usize), Some(2));
    }

    #[test]
    fn v2_queue_full_is_reported_while_pipeline_continues() {
        let engine: Arc<dyn Submit> = Arc::new(
            EngineBuilder::new()
                .max_wait_ms(0)
                .queue_cap(1)
                .build_backend(Arc::new(
                    FakeBackend::new("cls", 2, 1, 8, 3).with_delay(Duration::from_millis(40)),
                ))
                .unwrap(),
        );
        let (mut conn, writer) = new_conn(engine);
        let n = 30;
        for i in 0..n {
            assert!(conn.handle_line(&format!(
                r#"{{"id":{i},"op":"classify","ids":[1,45,46,2,0,0,0,{i}]}}"#
            )));
        }
        // every submission eventually produces exactly one reply line:
        // queue_full synchronously, or a completion through the pump
        let ls = wait_for_lines(&writer, n);
        assert_eq!(ls.len(), n);
        let full = ls.iter().filter(|l| l.contains("queue_full")).count();
        let ok = ls.iter().filter(|l| l.contains("\"ok\":true")).count();
        assert!(full >= 1, "expected at least one queue_full: {ls:?}");
        assert!(ok >= 1, "expected at least one success: {ls:?}");
        assert_eq!(full + ok, n);
    }

    #[test]
    fn pump_writes_replies_in_completion_order_not_submission_order() {
        let cq: CompletionQueue = Channel::bounded(8);
        let pending = Mutex::new(HashMap::new());
        for (tag, id) in [(1u64, "first"), (2, "second")] {
            pending.lock().unwrap().insert(
                tag,
                Pending {
                    id: s(id),
                    kind: TaskKind::Classify,
                    want_logits: false,
                    batch: None,
                },
            );
        }
        let resp = |id: u64| Response {
            id,
            slot: 0,
            group: 0,
            logits: vec![0.0, 1.0].into(),
            n_classes: 2,
            latency: Duration::ZERO,
        };
        // completions land out of submission order: tag 2 first
        cq.send((2, Ok(resp(2)))).unwrap();
        cq.send((1, Err(EngineError::DeadlineExceeded))).unwrap();
        cq.close();
        let writer = Mutex::new(Vec::new());
        run_completion_pump(&cq, &pending, &writer);
        let ls = lines(&writer);
        assert_eq!(ls.len(), 2);
        assert!(ls[0].contains("\"id\":\"second\"") && ls[0].contains("\"ok\":true"), "{}", ls[0]);
        assert!(ls[1].contains("\"id\":\"first\"") && ls[1].contains("deadline"), "{}", ls[1]);
        assert!(pending.lock().unwrap().is_empty());
    }
}
