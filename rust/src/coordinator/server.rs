//! TCP front end: a line protocol over the coordinator.
//!
//! Protocol (one request per line):
//!   `CLS <token text>`                  -> `OK <pred> slot=<i> us=<latency>`
//!   `TOK <token text>`                  -> `OK <tag ids ...> slot=<i> us=<latency>`
//!   `STATS`                             -> one-line counters snapshot
//!   `QUIT`                              -> closes the connection
//! Errors: `ERR <message>`.
//!
//! One OS thread per connection, capped by a semaphore-ish counter — the
//! heavy lifting (batching, PJRT) happens on the coordinator's threads,
//! so connection threads only block on the completion handle.

use std::io::{BufRead, BufReader, Write};
use std::net::{TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, AtomicUsize, Ordering};
use std::sync::Arc;

use anyhow::Result;

use super::MuxCoordinator;

pub struct ServerConfig {
    pub addr: String,
    pub max_connections: usize,
}

impl Default for ServerConfig {
    fn default() -> Self {
        ServerConfig { addr: "127.0.0.1:7071".into(), max_connections: 64 }
    }
}

pub struct Server {
    pub local_addr: std::net::SocketAddr,
    stop: Arc<AtomicBool>,
    accept_thread: Option<std::thread::JoinHandle<()>>,
}

impl Server {
    /// Start serving `coord` on `cfg.addr`. Non-blocking; returns the
    /// bound address (use port 0 to pick a free port).
    pub fn start(coord: Arc<MuxCoordinator>, cfg: ServerConfig) -> Result<Server> {
        let listener = TcpListener::bind(&cfg.addr)?;
        let local_addr = listener.local_addr()?;
        listener.set_nonblocking(true)?;
        let stop = Arc::new(AtomicBool::new(false));
        let stop2 = stop.clone();
        let live = Arc::new(AtomicUsize::new(0));
        let accept_thread = std::thread::Builder::new()
            .name("datamux-accept".into())
            .spawn(move || {
                while !stop2.load(Ordering::Relaxed) {
                    match listener.accept() {
                        Ok((stream, _)) => {
                            if live.load(Ordering::Relaxed) >= cfg.max_connections {
                                let mut s = stream;
                                let _ = s.write_all(b"ERR too many connections\n");
                                continue;
                            }
                            live.fetch_add(1, Ordering::Relaxed);
                            let coord = coord.clone();
                            let live = live.clone();
                            let stop = stop2.clone();
                            std::thread::spawn(move || {
                                let _ = handle_conn(stream, &coord, &stop);
                                live.fetch_sub(1, Ordering::Relaxed);
                            });
                        }
                        Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => {
                            std::thread::sleep(std::time::Duration::from_millis(2));
                        }
                        Err(_) => break,
                    }
                }
            })?;
        Ok(Server { local_addr, stop, accept_thread: Some(accept_thread) })
    }

    pub fn stop(mut self) {
        self.stop.store(true, Ordering::Relaxed);
        if let Some(t) = self.accept_thread.take() {
            let _ = t.join();
        }
    }
}

impl Drop for Server {
    fn drop(&mut self) {
        self.stop.store(true, Ordering::Relaxed);
        if let Some(t) = self.accept_thread.take() {
            let _ = t.join();
        }
    }
}

fn handle_conn(stream: TcpStream, coord: &MuxCoordinator, stop: &AtomicBool) -> Result<()> {
    stream.set_nodelay(true).ok();
    let mut writer = stream.try_clone()?;
    let reader = BufReader::new(stream);
    for line in reader.lines() {
        if stop.load(Ordering::Relaxed) {
            break;
        }
        let line = line?;
        let reply = handle_line(line.trim(), coord);
        match reply {
            Some(r) => {
                writer.write_all(r.as_bytes())?;
                writer.write_all(b"\n")?;
            }
            None => break, // QUIT
        }
    }
    Ok(())
}

/// Protocol logic, factored for unit testing without sockets.
pub fn handle_line(line: &str, coord: &MuxCoordinator) -> Option<String> {
    let (cmd, rest) = match line.split_once(' ') {
        Some((c, r)) => (c, r),
        None => (line, ""),
    };
    match cmd {
        "QUIT" => None,
        "STATS" => {
            let c = coord.stats.counters.snapshot();
            Some(format!(
                "OK submitted={} completed={} rejected={} groups={} padded={}",
                c.submitted, c.completed, c.rejected, c.groups_executed, c.slots_padded
            ))
        }
        "CLS" => match coord.submit_text(&rest.split(" [SEP] ").collect::<Vec<_>>()) {
            Ok(h) => {
                let r = h.wait();
                Some(format!(
                    "OK {} slot={} us={}",
                    r.pred_class(),
                    r.slot,
                    r.latency.as_micros()
                ))
            }
            Err(e) => Some(format!("ERR {e}")),
        },
        "TOK" => match coord.submit_text(&[rest]) {
            Ok(h) => {
                let r = h.wait();
                let tags: Vec<String> =
                    r.pred_tokens().iter().map(|t| t.to_string()).collect();
                Some(format!(
                    "OK {} slot={} us={}",
                    tags.join(","),
                    r.slot,
                    r.latency.as_micros()
                ))
            }
            Err(e) => Some(format!("ERR {e}")),
        },
        _ => Some(format!("ERR unknown command '{cmd}'")),
    }
}
