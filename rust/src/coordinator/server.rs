//! TCP front end over any [`Submit`] engine (single coordinator or
//! adaptive-N router), served by one event-loop thread.
//!
//! Two wire protocols share every connection, dispatched per line:
//!
//! **v1 (legacy, lockstep)** — one request per line, one reply per line,
//! in order:
//! ```text
//!   CLS <token text>   -> OK <pred> slot=<i> us=<latency>
//!   TOK <token text>   -> OK <tag,tag,..> slot=<i> us=<latency>
//!   STATS              -> one-line counters snapshot
//!   QUIT               -> closes the connection
//!   errors             -> ERR <message>
//! ```
//!
//! **v2 (pipelined, typed)** — any line starting with `{` is a
//! line-delimited JSON request with a *client-chosen id*. Many requests
//! may be in flight per connection; replies are correlated by id and
//! written in completion order (not submission order):
//! ```text
//!   {"id":..,"op":"classify"|"tag","text":"t1 t2"|"ids":[..],
//!    "deadline_ms":N?,"priority":"high"|"normal"|"bulk"?,"logits":bool?}
//!   {"id":..,"op":"batch","items":[<op objects without id>..]}
//!   {"id":..,"op":"stats"} / {"op":"quit"}
//! -> {"id":..,"ok":true,"pred":N|"tags":[..],"slot":N,"group":N,"us":N}
//! -> {"id":..,"ok":true,"results":[..]}          (batch, one line)
//! -> {"id":..,"ok":false,"error":"<code>","message":".."}
//! ```
//! Error codes are the stable [`SubmitError::code`] /
//! [`EngineError::code`] strings plus `bad_json`, `bad_request`, and
//! `oversized_line`. `priority` feeds SLO-tiered admission: per-class
//! queue entries, deadline-aware shedding (`expired` / `overloaded`
//! rejections at submit time), and a per-class `classes` array in v2
//! STATS with queue-wait percentiles.
//!
//! **Threading**: one [`Reactor`] thread owns every socket (accept,
//! framing, writes, backpressure — see `reactor.rs`), and one
//! `datamux-completions` pump thread moves engine completions from the
//! shared [`CompletionQueue`] into a staging buffer and pokes the
//! reactor's waker. All protocol state lives on the reactor thread, so
//! it needs no locks. The v1 lockstep contract is kept by pausing a
//! connection's read interest while its one request is in flight —
//! no blocked thread, just a parked fd. `Server::stop()` drains and
//! joins both threads: no orphaned threads, no leaked sockets.

use std::collections::{HashMap, HashSet};
use std::net::TcpListener;
use std::sync::Arc;
use std::time::Duration;

use anyhow::Result;

use crate::util::json::{num, obj, s, Json};
use crate::util::sync::{rank, TrackedMutex};
use crate::util::threadpool::Channel;

use super::api::{
    CompletionItem, CompletionQueue, InferenceRequest, Payload, Priority, Submit, TaskKind,
};
use super::reactor::{ConnId, Handler, Outbox, Reactor, ReactorConfig};
use super::request::Response;

/// Engine completions in transit between the pump thread and the
/// reactor. Purely a hand-off buffer: per-connection backpressure is the
/// reactor's job (slow consumers are evicted when their write buffer
/// exceeds `write_buf_cap`), so this never accumulates per-client debt.
const COMPLETION_QUEUE_CAP: usize = 65536;

pub struct ServerConfig {
    pub addr: String,
    pub max_connections: usize,
    /// Drain grace: how long `Server::stop()` (and any flush-close) waits
    /// for a connection's buffered replies to reach the wire before
    /// force-closing it. (Name kept from the thread-per-connection
    /// server, where it was the blocking-read poll interval.)
    pub read_timeout: Duration,
    /// Longest accepted request line; beyond it the client gets a typed
    /// `oversized_line` error and a disconnect.
    pub max_line: usize,
    /// Per-connection write backlog allowed after a flush attempt; a
    /// consumer further behind than this is disconnected.
    pub write_buf_cap: usize,
}

impl Default for ServerConfig {
    fn default() -> Self {
        ServerConfig {
            addr: "127.0.0.1:7071".into(),
            max_connections: 64,
            read_timeout: Duration::from_millis(250),
            max_line: 64 * 1024,
            write_buf_cap: 256 * 1024,
        }
    }
}

pub struct Server {
    pub local_addr: std::net::SocketAddr,
    reactor: Option<Reactor>,
    cq: CompletionQueue,
    pump: Option<std::thread::JoinHandle<()>>,
}

impl Server {
    /// Start serving `engine` on `cfg.addr`. Non-blocking; returns the
    /// bound address (use port 0 to pick a free port).
    pub fn start(engine: Arc<dyn Submit>, cfg: ServerConfig) -> Result<Server> {
        let listener = TcpListener::bind(&cfg.addr)?;
        let local_addr = listener.local_addr()?;
        let cq: CompletionQueue = Channel::bounded(COMPLETION_QUEUE_CAP);
        let staging =
            Arc::new(TrackedMutex::new("server.staging", rank::SERVER_STAGING, Vec::new()));
        let handler = SessionHandler {
            engine,
            cq: cq.clone(),
            staging: staging.clone(),
            max_line: cfg.max_line,
            pending: HashMap::new(),
            conns: HashMap::new(),
            next_tag: 1,
        };
        let reactor = Reactor::start(
            listener,
            ReactorConfig {
                max_connections: cfg.max_connections,
                max_line: cfg.max_line,
                write_buf_cap: cfg.write_buf_cap,
                drain_grace: cfg.read_timeout,
            },
            handler,
        )?;
        let waker = reactor.waker();
        let pump_cq = cq.clone();
        let pump = std::thread::Builder::new()
            .name("datamux-completions".into())
            .spawn(move || {
                while let Some(item) = pump_cq.recv() {
                    staging.lock().push(item);
                    waker.wake();
                }
            })?;
        Ok(Server { local_addr, reactor: Some(reactor), cq, pump: Some(pump) })
    }

    /// Stop serving: the reactor drains and closes every live
    /// connection, then both the reactor and the completion pump join.
    pub fn stop(mut self) {
        self.shutdown();
    }

    fn shutdown(&mut self) {
        if let Some(mut r) = self.reactor.take() {
            r.stop();
        }
        self.cq.close();
        if let Some(p) = self.pump.take() {
            let _ = p.join();
        }
    }
}

impl Drop for Server {
    fn drop(&mut self) {
        self.shutdown();
    }
}

// ---------------------------------------------------------------------------
// protocol v1 (legacy, lockstep)
// ---------------------------------------------------------------------------

fn v1_stats(engine: &dyn Submit) -> String {
    let c = engine.counters();
    format!(
        "OK submitted={} completed={} rejected={} groups={} padded={} \
         tokens_padded={} expired={}",
        c.submitted,
        c.completed,
        c.rejected,
        c.groups_executed,
        c.slots_padded,
        c.tokens_padded,
        c.expired
    )
}

/// Build the task-agnostic v1 request for a CLS/TOK line. The command
/// only picks reply formatting; CLS splits sentence pairs on ` [SEP] `
/// and TOK treats the whole line as one part — exactly as the legacy
/// protocol did.
fn v1_request(cmd: &str, rest: &str, engine: &dyn Submit) -> Result<InferenceRequest, String> {
    let payload = if cmd == "CLS" {
        Payload::Text(rest.to_string())
    } else {
        // unpadded: the engine assigns the bucket and pads there
        match engine.tokenizer().encode_framed_unpadded(&[rest], engine.seq_len()) {
            Ok(ids) => Payload::Framed(ids),
            Err(e) => return Err(format!("tokenize: {e}")),
        }
    };
    Ok(InferenceRequest {
        task: engine.native_task(),
        payload,
        deadline: None,
        priority: Priority::Normal,
    })
}

fn v1_reply(kind: TaskKind, result: &Result<Response, super::request::EngineError>) -> String {
    match result {
        Ok(r) if kind == TaskKind::Classify => {
            format!("OK {} slot={} us={}", r.pred_class(), r.slot, r.latency.as_micros())
        }
        Ok(r) => {
            let tags: Vec<String> = r.pred_tokens().iter().map(|t| t.to_string()).collect();
            format!("OK {} slot={} us={}", tags.join(","), r.slot, r.latency.as_micros())
        }
        Err(e) => format!("ERR {e}"),
    }
}

/// v1 protocol logic, factored for unit testing without sockets. This is
/// the *blocking* form (submit + wait inline); the reactor path submits
/// tagged and parks the connection instead.
pub fn handle_line(line: &str, engine: &dyn Submit) -> Option<String> {
    let (cmd, rest) = match line.split_once(' ') {
        Some((c, r)) => (c, r),
        None => (line, ""),
    };
    match cmd {
        "QUIT" => None,
        "STATS" => Some(v1_stats(engine)),
        "CLS" | "TOK" => {
            let req = match v1_request(cmd, rest, engine) {
                Ok(req) => req,
                Err(msg) => return Some(format!("ERR {msg}")),
            };
            let kind =
                if cmd == "CLS" { TaskKind::Classify } else { TaskKind::TagTokens };
            match engine.submit(req) {
                Ok(h) => Some(v1_reply(kind, &h.wait())),
                Err(e) => Some(format!("ERR {e}")),
            }
        }
        _ => Some(format!("ERR unknown command '{cmd}'")),
    }
}

// ---------------------------------------------------------------------------
// reactor handler: all per-connection protocol state, single-threaded
// ---------------------------------------------------------------------------

struct BatchAcc {
    id: Json,
    remaining: usize,
    results: Vec<Json>,
}

enum ReplyKind {
    /// lockstep CLS/TOK: reply then resume the paused connection
    V1 { kind: TaskKind },
    V2 {
        /// client-chosen id, echoed verbatim (string, number, anything)
        id: Json,
        kind: TaskKind,
        want_logits: bool,
        /// set when this request is one item of a BATCH submit
        batch: Option<(Arc<TrackedMutex<BatchAcc>>, usize)>,
    },
}

struct Pending {
    conn: ConnId,
    reply: ReplyKind,
}

#[derive(Default)]
struct ConnState {
    /// in-flight tags, so a closing connection can drop its pendings
    tags: HashSet<u64>,
}

struct SessionHandler {
    engine: Arc<dyn Submit>,
    cq: CompletionQueue,
    /// completions parked by the pump thread until `on_wake` runs
    staging: Arc<TrackedMutex<Vec<CompletionItem>>>,
    max_line: usize,
    pending: HashMap<u64, Pending>,
    conns: HashMap<ConnId, ConnState>,
    next_tag: u64,
}

fn line_bytes(j: &Json) -> Vec<u8> {
    let mut b = j.to_string().into_bytes();
    b.push(b'\n');
    b
}

impl SessionHandler {
    fn alloc_tag(&mut self) -> u64 {
        let t = self.next_tag;
        self.next_tag += 1;
        t
    }

    fn track(&mut self, conn: ConnId, tag: u64, reply: ReplyKind) {
        self.conns.entry(conn).or_default().tags.insert(tag);
        self.pending.insert(tag, Pending { conn, reply });
    }

    fn untrack(&mut self, conn: ConnId, tag: u64) {
        self.pending.remove(&tag);
        if let Some(cs) = self.conns.get_mut(&conn) {
            cs.tags.remove(&tag);
        }
    }

    fn send_error(&self, out: &mut Outbox, conn: ConnId, id: &Json, code: &str, msg: &str) {
        out.send(conn, line_bytes(&attach_id(id.clone(), error_json(code, msg))));
    }

    fn v1_line(&mut self, conn: ConnId, l: &str, out: &mut Outbox) {
        let (cmd, rest) = match l.split_once(' ') {
            Some((c, r)) => (c, r),
            None => (l, ""),
        };
        match cmd {
            "QUIT" => out.close(conn),
            "STATS" => out.send(conn, format!("{}\n", v1_stats(self.engine.as_ref())).into_bytes()),
            "CLS" | "TOK" => {
                let req = match v1_request(cmd, rest, self.engine.as_ref()) {
                    Ok(req) => req,
                    Err(msg) => {
                        out.send(conn, format!("ERR {msg}\n").into_bytes());
                        return;
                    }
                };
                let kind =
                    if cmd == "CLS" { TaskKind::Classify } else { TaskKind::TagTokens };
                let tag = self.alloc_tag();
                // register before submitting: the completion may land
                // before submit_tagged even returns
                self.track(conn, tag, ReplyKind::V1 { kind });
                match self.engine.submit_tagged(req, tag, &self.cq) {
                    Ok(()) => out.pause(conn), // lockstep: park until the reply
                    Err(e) => {
                        self.untrack(conn, tag);
                        out.send(conn, format!("ERR {e}\n").into_bytes());
                    }
                }
            }
            _ => out.send(conn, format!("ERR unknown command '{cmd}'\n").into_bytes()),
        }
    }

    fn v2_line(&mut self, conn: ConnId, l: &str, out: &mut Outbox) {
        let v = match Json::parse(l) {
            Ok(v) => v,
            Err(e) => {
                self.send_error(out, conn, &Json::Null, "bad_json", &e.to_string());
                return;
            }
        };
        let id = v.get("id").cloned().unwrap_or(Json::Null);
        match v.get("op").and_then(Json::as_str) {
            Some("quit") => out.close(conn),
            Some("stats") => {
                out.send(conn, line_bytes(&attach_id(id, stats_json(self.engine.as_ref()))));
            }
            Some("batch") => self.v2_batch(conn, &id, &v, out),
            Some("classify") | Some("tag") => self.v2_single(conn, &id, &v, out),
            Some(other) => {
                self.send_error(out, conn, &id, "bad_request", &format!("unknown op '{other}'"));
            }
            None => self.send_error(out, conn, &id, "bad_request", "missing 'op'"),
        }
    }

    fn v2_single(&mut self, conn: ConnId, id: &Json, v: &Json, out: &mut Outbox) {
        match parse_task_item(v) {
            Err(msg) => self.send_error(out, conn, id, "bad_request", &msg),
            Ok((req, kind, want_logits)) => {
                let tag = self.alloc_tag();
                self.track(
                    conn,
                    tag,
                    ReplyKind::V2 { id: id.clone(), kind, want_logits, batch: None },
                );
                if let Err(e) = self.engine.submit_tagged(req, tag, &self.cq) {
                    self.untrack(conn, tag);
                    self.send_error(out, conn, id, e.code(), &e.to_string());
                }
            }
        }
    }

    fn v2_batch(&mut self, conn: ConnId, id: &Json, v: &Json, out: &mut Outbox) {
        let items = match v.get("items").and_then(Json::as_arr) {
            Some(items) => items,
            None => {
                self.send_error(out, conn, id, "bad_request", "batch needs an 'items' array");
                return;
            }
        };
        if items.is_empty() {
            let empty =
                obj(vec![("ok", Json::Bool(true)), ("results", Json::Arr(Vec::new()))]);
            out.send(conn, line_bytes(&attach_id(id.clone(), empty)));
            return;
        }
        let acc = Arc::new(TrackedMutex::new(
            "server.batch_acc",
            rank::SERVER_STAGING,
            BatchAcc {
                id: id.clone(),
                remaining: items.len(),
                results: vec![Json::Null; items.len()],
            },
        ));
        for (idx, item) in items.iter().enumerate() {
            match parse_task_item(item) {
                Err(msg) => {
                    if let Some(line) = batch_item_done(&acc, idx, error_json("bad_request", &msg))
                    {
                        out.send(conn, format!("{line}\n").into_bytes());
                    }
                }
                Ok((req, kind, want_logits)) => {
                    let tag = self.alloc_tag();
                    self.track(
                        conn,
                        tag,
                        ReplyKind::V2 {
                            id: Json::Null,
                            kind,
                            want_logits,
                            batch: Some((acc.clone(), idx)),
                        },
                    );
                    if let Err(e) = self.engine.submit_tagged(req, tag, &self.cq) {
                        self.untrack(conn, tag);
                        if let Some(line) =
                            batch_item_done(&acc, idx, error_json(e.code(), &e.to_string()))
                        {
                            out.send(conn, format!("{line}\n").into_bytes());
                        }
                    }
                }
            }
        }
    }
}

impl Handler for SessionHandler {
    fn on_line(&mut self, conn: ConnId, line: &str, out: &mut Outbox) {
        let l = line.trim();
        if l.is_empty() {
            return;
        }
        if l.starts_with('{') {
            self.v2_line(conn, l, out);
        } else {
            self.v1_line(conn, l, out);
        }
    }

    fn on_wake(&mut self, out: &mut Outbox) {
        let items = std::mem::take(&mut *self.staging.lock());
        for (tag, result) in items {
            let Some(p) = self.pending.remove(&tag) else {
                continue; // conn closed, or already answered synchronously
            };
            if let Some(cs) = self.conns.get_mut(&p.conn) {
                cs.tags.remove(&tag);
            }
            match p.reply {
                ReplyKind::V1 { kind } => {
                    out.send(p.conn, format!("{}\n", v1_reply(kind, &result)).into_bytes());
                    out.resume(p.conn); // release the lockstep pause
                }
                ReplyKind::V2 { id, kind, want_logits, batch } => {
                    let payload = match &result {
                        Ok(r) => success_json(kind, want_logits, r),
                        Err(e) => error_json(e.code(), &e.to_string()),
                    };
                    match batch {
                        None => out.send(p.conn, line_bytes(&attach_id(id, payload))),
                        Some((acc, idx)) => {
                            if let Some(line) = batch_item_done(&acc, idx, payload) {
                                out.send(p.conn, format!("{line}\n").into_bytes());
                            }
                        }
                    }
                }
            }
        }
    }

    fn on_oversize(&mut self, conn: ConnId, out: &mut Outbox) {
        self.send_error(
            out,
            conn,
            &Json::Null,
            "oversized_line",
            &format!("request line exceeds the {} byte limit", self.max_line),
        );
    }

    fn on_close(&mut self, conn: ConnId) {
        if let Some(cs) = self.conns.remove(&conn) {
            for tag in cs.tags {
                self.pending.remove(&tag);
            }
        }
    }
}

// ---------------------------------------------------------------------------
// protocol v2 parsing / formatting
// ---------------------------------------------------------------------------

/// Record one finished batch item; returns the reply line when the whole
/// batch is done.
fn batch_item_done(acc: &TrackedMutex<BatchAcc>, idx: usize, result: Json) -> Option<String> {
    let mut a = acc.lock();
    a.results[idx] = result;
    a.remaining -= 1;
    if a.remaining > 0 {
        return None;
    }
    let results = std::mem::take(&mut a.results);
    Some(
        attach_id(
            a.id.clone(),
            obj(vec![("ok", Json::Bool(true)), ("results", Json::Arr(results))]),
        )
        .to_string(),
    )
}

/// Parse one task object (`op`/`text`|`ids`/`deadline_ms`/`priority`/
/// `logits`) into a typed request.
fn parse_task_item(v: &Json) -> Result<(InferenceRequest, TaskKind, bool), String> {
    let kind = match v.get("op").and_then(Json::as_str) {
        Some("classify") | None => TaskKind::Classify,
        Some("tag") => TaskKind::TagTokens,
        Some(other) => return Err(format!("unknown op '{other}'")),
    };
    let payload = if let Some(ids) = v.get("ids").and_then(Json::as_arr) {
        let mut parsed = Vec::with_capacity(ids.len());
        for x in ids {
            // strict: reject floats and out-of-range values instead of
            // silently truncating/wrapping them into wrong token ids
            match x.as_f64() {
                Some(f)
                    if f.fract() == 0.0
                        && (i32::MIN as f64..=i32::MAX as f64).contains(&f) =>
                {
                    parsed.push(f as i32)
                }
                _ => return Err("'ids' must be an array of i32 integers".to_string()),
            }
        }
        Payload::Framed(parsed)
    } else if let Some(text) = v.get("text").and_then(Json::as_str) {
        Payload::Text(text.to_string())
    } else {
        return Err("missing 'text' or 'ids'".to_string());
    };
    // clamp to [0, 1 day]: Duration::from_secs_f64 panics on huge or
    // non-finite input, and a panic here would kill the reactor thread
    let deadline = v
        .get("deadline_ms")
        .and_then(Json::as_f64)
        .filter(|ms| ms.is_finite())
        .map(|ms| Duration::from_secs_f64(ms.clamp(0.0, 86_400_000.0) / 1e3));
    let priority = match v.get("priority") {
        None => Priority::Normal,
        Some(p) => match p.as_str().and_then(Priority::from_str) {
            Some(p) => p,
            None => {
                return Err(format!("unknown priority {p}; use \"high\"|\"normal\"|\"bulk\""))
            }
        },
    };
    let want_logits = v.get("logits").and_then(Json::as_bool).unwrap_or(false);
    Ok((InferenceRequest { task: kind, payload, deadline, priority }, kind, want_logits))
}

fn success_json(kind: TaskKind, want_logits: bool, r: &Response) -> Json {
    let mut fields = vec![
        ("ok", Json::Bool(true)),
        ("slot", num(r.slot as f64)),
        ("group", num(r.group as f64)),
        ("us", num(r.latency.as_micros() as f64)),
    ];
    match kind {
        TaskKind::Classify => fields.push(("pred", num(r.pred_class() as f64))),
        TaskKind::TagTokens => fields.push((
            "tags",
            Json::Arr(r.pred_tokens().into_iter().map(|t| num(t as f64)).collect()),
        )),
    }
    if want_logits {
        fields.push((
            "logits",
            Json::Arr(r.logits.iter().map(|&x| num(x as f64)).collect()),
        ));
    }
    obj(fields)
}

fn error_json(code: &str, msg: &str) -> Json {
    obj(vec![("ok", Json::Bool(false)), ("error", s(code)), ("message", s(msg))])
}

fn attach_id(id: Json, payload: Json) -> Json {
    match payload {
        Json::Obj(mut m) => {
            m.insert("id".to_string(), id);
            Json::Obj(m)
        }
        other => other,
    }
}

fn stats_json(engine: &dyn Submit) -> Json {
    let c = engine.counters();
    let l = engine.latency();
    let qw = engine.queue_wait();
    let status = engine.lane_status();
    // per-lane health: which Ns are alive, how many waves each pulled,
    // what a dead lane handed back to the shared queue, and the
    // per-bucket wave/entry split
    let lanes: Vec<Json> = status
        .iter()
        .map(|lane| {
            let lane_buckets: Vec<Json> = lane
                .buckets
                .iter()
                .map(|b| {
                    obj(vec![
                        ("seq_len", num(b.seq_len as f64)),
                        ("waves", num(b.waves as f64)),
                        ("entries", num(b.entries as f64)),
                    ])
                })
                .collect();
            obj(vec![
                ("n_mux", num(lane.n_mux as f64)),
                ("alive", Json::Bool(lane.alive)),
                ("pulls", num(lane.pulls as f64)),
                ("requeued", num(lane.requeued as f64)),
                ("completed", num(lane.completed as f64)),
                ("buckets", Json::Arr(lane_buckets)),
            ])
        })
        .collect();
    // engine-wide per-bucket aggregate (lanes share one registry)
    let mut agg: Vec<(usize, u64, u64)> = Vec::new();
    for lane in &status {
        for b in &lane.buckets {
            match agg.iter_mut().find(|(l, _, _)| *l == b.seq_len) {
                Some(slot) => {
                    slot.1 += b.waves;
                    slot.2 += b.entries;
                }
                None => agg.push((b.seq_len, b.waves, b.entries)),
            }
        }
    }
    agg.sort_unstable_by_key(|&(l, _, _)| l);
    let buckets: Vec<Json> = agg
        .into_iter()
        .map(|(seq_len, waves, entries)| {
            obj(vec![
                ("seq_len", num(seq_len as f64)),
                ("waves", num(waves as f64)),
                ("entries", num(entries as f64)),
            ])
        })
        .collect();
    // SLO tiers: admission/queue/completion accounting per priority class
    let classes: Vec<Json> = engine
        .class_status()
        .iter()
        .map(|cl| {
            obj(vec![
                ("priority", s(cl.priority.as_str())),
                ("depth", num(cl.depth as f64)),
                ("completed", num(cl.completed as f64)),
                ("shed_expired", num(cl.shed_expired as f64)),
                ("shed_overloaded", num(cl.shed_overloaded as f64)),
                ("queue_wait_p50_us", num(cl.queue_wait.p50_ns as f64 / 1e3)),
                ("queue_wait_p99_us", num(cl.queue_wait.p99_ns as f64 / 1e3)),
            ])
        })
        .collect();
    // model identity block: what this process serves. A sharding front
    // reads it during the handshake to verify every backend agrees on
    // task/shape before pooling them (see `coordinator::pool::ModelInfo`).
    let tok = engine.tokenizer();
    let vocab_size = tok.vocab.content_base as usize + tok.n_content;
    let model = obj(vec![
        ("task", s(engine.native_task().as_str())),
        ("seq_len", num(engine.seq_len() as f64)),
        ("n_classes", num(engine.n_classes() as f64)),
        ("vocab_size", num(vocab_size as f64)),
        (
            "buckets",
            Json::Arr(engine.buckets().iter().map(|&b| num(b as f64)).collect()),
        ),
    ]);
    // shard pool health (empty unless the engine is a ShardRouter)
    let shards: Vec<Json> = engine
        .shard_status()
        .iter()
        .map(|sh| {
            obj(vec![
                ("addr", s(&sh.addr)),
                ("state", s(sh.state.as_str())),
                ("probes", num(sh.probes as f64)),
                ("probe_failures", num(sh.probe_failures as f64)),
                ("failovers", num(sh.failovers as f64)),
                ("in_flight", num(sh.in_flight as f64)),
                ("completed", num(sh.completed as f64)),
                ("ewma_rtt_us", num(sh.ewma_rtt_us)),
            ])
        })
        .collect();
    obj(vec![
        ("ok", Json::Bool(true)),
        (
            "stats",
            obj(vec![
                ("model", model),
                ("shards", Json::Arr(shards)),
                ("submitted", num(c.submitted as f64)),
                ("completed", num(c.completed as f64)),
                ("rejected", num(c.rejected as f64)),
                ("expired", num(c.expired as f64)),
                ("groups", num(c.groups_executed as f64)),
                ("padded", num(c.slots_padded as f64)),
                ("tokens_padded", num(c.tokens_padded as f64)),
                ("intake_waves", num(c.intake_waves as f64)),
                ("scratch_reallocs", num(c.scratch_reallocs as f64)),
                ("queue_depth", num(engine.queue_depth() as f64)),
                ("p50_us", num(l.p50_ns as f64 / 1e3)),
                ("p99_us", num(l.p99_ns as f64 / 1e3)),
                ("queue_wait_p50_us", num(qw.p50_ns as f64 / 1e3)),
                ("queue_wait_p99_us", num(qw.p99_ns as f64 / 1e3)),
                ("buckets", Json::Arr(buckets)),
                ("classes", Json::Arr(classes)),
                ("lanes", Json::Arr(lanes)),
                // one entry per serving backend: the description line
                // (model, kernel arm, weight precision) plus, for
                // instrumented backends, cumulative per-stage ns
                (
                    "backends",
                    Json::Arr({
                        let stage_ns = engine.backend_stage_ns();
                        engine
                            .backend_info()
                            .iter()
                            .enumerate()
                            .map(|(i, d)| {
                                let mut fields = vec![("desc", s(d))];
                                if let Some(stages) =
                                    stage_ns.get(i).filter(|st| !st.is_empty())
                                {
                                    fields.push((
                                        "stage_ns",
                                        obj(stages
                                            .iter()
                                            .map(|&(k, v)| (k, num(v as f64)))
                                            .collect()),
                                    ));
                                }
                                obj(fields)
                            })
                            .collect()
                    }),
                ),
            ]),
        ),
    ])
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::EngineBuilder;
    use crate::runtime::FakeBackend;
    use std::io::{BufRead, BufReader, Read, Write};
    use std::net::TcpStream;
    use std::time::Instant;

    fn fake_cls_engine() -> Arc<dyn Submit> {
        Arc::new(
            EngineBuilder::new()
                .max_wait_ms(0)
                .build_backend(Arc::new(FakeBackend::new("cls", 2, 1, 8, 3)))
                .unwrap(),
        )
    }

    fn start(engine: Arc<dyn Submit>) -> Server {
        Server::start(engine, ServerConfig { addr: "127.0.0.1:0".into(), ..Default::default() })
            .expect("server starts")
    }

    fn client(srv: &Server) -> BufReader<TcpStream> {
        let s = TcpStream::connect(srv.local_addr).expect("connect");
        s.set_read_timeout(Some(Duration::from_secs(10))).unwrap();
        BufReader::new(s)
    }

    fn send(c: &mut BufReader<TcpStream>, line: &str) {
        c.get_mut().write_all(line.as_bytes()).unwrap();
        c.get_mut().write_all(b"\n").unwrap();
    }

    fn recv(c: &mut BufReader<TcpStream>) -> String {
        let mut line = String::new();
        c.read_line(&mut line).expect("read reply");
        line.trim_end().to_string()
    }

    #[test]
    fn v1_unknown_command_and_stats() {
        let engine = fake_cls_engine();
        let reply = handle_line("BOGUS x", engine.as_ref()).unwrap();
        assert!(reply.starts_with("ERR"), "{reply}");
        let stats = handle_line("STATS", engine.as_ref()).unwrap();
        assert!(stats.contains("submitted="), "{stats}");
        assert!(handle_line("QUIT", engine.as_ref()).is_none());
    }

    #[test]
    fn v1_cls_roundtrip_and_tokenize_error() {
        let engine = fake_cls_engine();
        let reply = handle_line("CLS t1 t2 t3", engine.as_ref()).unwrap();
        assert!(reply.starts_with("OK "), "{reply}");
        let reply = handle_line("CLS hello world", engine.as_ref()).unwrap();
        assert!(reply.starts_with("ERR"), "unknown words must ERR: {reply}");
    }

    #[test]
    fn v1_over_socket_is_lockstep_and_quits() {
        let srv = start(fake_cls_engine());
        let mut c = client(&srv);
        send(&mut c, "CLS t1 t2");
        assert!(recv(&mut c).starts_with("OK "), "CLS answers");
        send(&mut c, "BOGUS");
        assert!(recv(&mut c).starts_with("ERR unknown command"));
        send(&mut c, "STATS");
        assert!(recv(&mut c).contains("submitted="));
        send(&mut c, "QUIT");
        let mut rest = Vec::new();
        c.get_mut().read_to_end(&mut rest).expect("QUIT closes the conn");
        srv.stop();
    }

    #[test]
    fn v2_classify_echoes_id_and_predicts() {
        let srv = start(fake_cls_engine());
        let mut c = client(&srv);
        send(&mut c, r#"{"id":"req-a","op":"classify","text":"t1 t2"}"#);
        let reply = recv(&mut c);
        assert!(reply.contains("\"id\":\"req-a\""), "{reply}");
        assert!(reply.contains("\"ok\":true"), "{reply}");
        // [CLS]=1 t1=45 t2=46 [SEP]=2 + padding -> sum=94 -> 94 % 3 = 1
        assert!(reply.contains("\"pred\":1"), "{reply}");
        srv.stop();
    }

    #[test]
    fn v2_malformed_json_unknown_op_and_priority_typo() {
        let srv = start(fake_cls_engine());
        let mut c = client(&srv);
        send(&mut c, "{nope");
        assert!(recv(&mut c).contains("bad_json"));
        send(&mut c, r#"{"id":7,"op":"frobnicate"}"#);
        let reply = recv(&mut c);
        assert!(reply.contains("bad_request") && reply.contains("\"id\":7"), "{reply}");
        send(&mut c, r#"{"id":8}"#);
        assert!(recv(&mut c).contains("missing 'op'"));
        // a typo'd priority is a typed rejection, not a silent default
        send(&mut c, r#"{"id":9,"op":"classify","text":"t1","priority":"urgent"}"#);
        let reply = recv(&mut c);
        assert!(reply.contains("bad_request") && reply.contains("priority"), "{reply}");
        srv.stop();
    }

    #[test]
    fn v2_interleaved_pipelined_ids_all_answered() {
        let srv = start(fake_cls_engine());
        let mut c = client(&srv);
        // burst of pipelined requests in one write, varying content
        let mut burst = String::new();
        for i in 0..16 {
            burst.push_str(&format!(
                "{{\"id\":\"q{i}\",\"op\":\"classify\",\"ids\":[1,45,46,2,0,0,0,{i}]}}\n"
            ));
        }
        c.get_mut().write_all(burst.as_bytes()).unwrap();
        let mut seen = HashSet::new();
        for _ in 0..16 {
            let reply = recv(&mut c);
            assert!(reply.contains("\"ok\":true"), "{reply}");
            let id = Json::parse(&reply)
                .unwrap()
                .get("id")
                .and_then(Json::as_str)
                .unwrap()
                .to_string();
            assert!(seen.insert(id), "duplicate id in {reply}");
        }
        assert_eq!(seen.len(), 16, "every pipelined id answered exactly once");
        srv.stop();
    }

    #[test]
    fn v2_batch_mixes_success_and_typed_errors() {
        let srv = start(fake_cls_engine());
        let mut c = client(&srv);
        // item 0: valid framed ids; item 1: over the model max (9 > 8);
        // item 2: short unpadded ids are *valid* (bucketed)
        send(
            &mut c,
            &r#"{"id":"b1","op":"batch","items":[
                {"op":"classify","ids":[1,45,46,2,0,0,0,0]},
                {"op":"classify","ids":[1,2,3,4,5,6,7,8,9]},
                {"op":"classify","ids":[1,45,46,2]}]}"#
                .replace('\n', " "),
        );
        let reply = recv(&mut c);
        assert!(reply.contains("\"id\":\"b1\""), "{reply}");
        // sum(1+45+46+2)=94 -> pred 1, for both the padded and the
        // unpadded form of the same content
        assert_eq!(reply.matches("\"pred\":1").count(), 2, "{reply}");
        assert!(reply.contains("too_long"), "{reply}");
        assert!(!reply.contains("bad_frame"), "{reply}");
        srv.stop();
    }

    #[test]
    fn v2_hostile_deadline_and_float_ids_are_handled() {
        let srv = start(fake_cls_engine());
        let mut c = client(&srv);
        // a huge deadline must not panic Duration::from_secs_f64 — it is
        // clamped and the request completes normally
        send(&mut c, r#"{"id":1,"op":"classify","text":"t1","deadline_ms":1e300}"#);
        assert!(recv(&mut c).contains("\"ok\":true"));
        // non-integer ids are rejected, not silently truncated
        send(&mut c, r#"{"id":2,"op":"classify","ids":[1.5,2,3,4,5,6,7,8]}"#);
        assert!(recv(&mut c).contains("bad_request"));
        srv.stop();
    }

    #[test]
    fn v2_stats_carry_classes_and_lanes_then_quit() {
        let srv = start(fake_cls_engine());
        let mut c = client(&srv);
        send(&mut c, r#"{"id":"w","op":"classify","text":"t1 t2","priority":"high"}"#);
        assert!(recv(&mut c).contains("\"ok\":true"));
        send(&mut c, r#"{"id":0,"op":"stats"}"#);
        let reply = recv(&mut c);
        let v = Json::parse(&reply).unwrap();
        let stats = v.get("stats").expect("stats object");
        let lanes = stats.get("lanes").and_then(Json::as_arr).expect("lane health");
        assert_eq!(lanes.len(), 1, "{reply}");
        assert_eq!(lanes[0].get("alive").and_then(Json::as_bool), Some(true));
        let classes = stats.get("classes").and_then(Json::as_arr).expect("SLO classes");
        assert_eq!(classes.len(), 3, "one entry per priority class: {reply}");
        let names: Vec<&str> =
            classes.iter().filter_map(|c| c.get("priority").and_then(Json::as_str)).collect();
        assert_eq!(names, ["high", "normal", "bulk"], "{reply}");
        let high_done = classes[0].get("completed").and_then(Json::as_usize);
        assert_eq!(high_done, Some(1), "the high-priority classify is tallied: {reply}");
        // model identity block — the sharding front's handshake reads this
        let model = stats.get("model").expect("model block");
        assert_eq!(model.get("task").and_then(Json::as_str), Some("classify"), "{reply}");
        assert_eq!(model.get("seq_len").and_then(Json::as_usize), Some(8), "{reply}");
        assert_eq!(model.get("n_classes").and_then(Json::as_usize), Some(3), "{reply}");
        assert_eq!(model.get("vocab_size").and_then(Json::as_usize), Some(300), "{reply}");
        let mbuckets = model.get("buckets").and_then(Json::as_arr).expect("bucket list");
        assert!(!mbuckets.is_empty(), "{reply}");
        // single-process engine: the shard array exists and is empty
        let shards = stats.get("shards").and_then(Json::as_arr).expect("shards array");
        assert!(shards.is_empty(), "{reply}");
        send(&mut c, r#"{"op":"quit"}"#);
        let mut rest = Vec::new();
        c.get_mut().read_to_end(&mut rest).expect("quit closes the conn");
        srv.stop();
    }

    #[test]
    fn v2_queue_full_is_reported_while_pipeline_continues() {
        let engine: Arc<dyn Submit> = Arc::new(
            EngineBuilder::new()
                .max_wait_ms(0)
                .queue_cap(1)
                .build_backend(Arc::new(
                    FakeBackend::new("cls", 2, 1, 8, 3).with_delay(Duration::from_millis(40)),
                ))
                .unwrap(),
        );
        let srv = start(engine);
        let mut c = client(&srv);
        let n = 30;
        let mut burst = String::new();
        for i in 0..n {
            burst.push_str(&format!(
                "{{\"id\":{i},\"op\":\"classify\",\"ids\":[1,45,46,2,0,0,0,{i}]}}\n"
            ));
        }
        c.get_mut().write_all(burst.as_bytes()).unwrap();
        // every submission eventually produces exactly one reply line:
        // queue_full synchronously, or a completion through the pump
        let mut full = 0;
        let mut ok = 0;
        for _ in 0..n {
            let reply = recv(&mut c);
            if reply.contains("queue_full") {
                full += 1;
            } else if reply.contains("\"ok\":true") {
                ok += 1;
            } else {
                panic!("unexpected reply: {reply}");
            }
        }
        assert!(full >= 1, "expected at least one queue_full (got {ok} ok)");
        assert!(ok >= 1, "expected at least one success (got {full} queue_full)");
        srv.stop();
    }

    #[test]
    fn oversized_line_is_a_typed_error_then_disconnect() {
        let engine = fake_cls_engine();
        let srv = Server::start(
            engine,
            ServerConfig { addr: "127.0.0.1:0".into(), max_line: 256, ..Default::default() },
        )
        .unwrap();
        let mut c = client(&srv);
        let huge = format!("{{\"id\":1,\"op\":\"classify\",\"text\":\"{}\"", "t1 ".repeat(400));
        c.get_mut().write_all(huge.as_bytes()).unwrap(); // no newline, over the cap
        let reply = recv(&mut c);
        assert!(reply.contains("oversized_line"), "{reply}");
        let mut rest = Vec::new();
        c.get_mut().read_to_end(&mut rest).expect("server closes after the error");
        assert!(rest.is_empty());
        srv.stop();
    }

    #[test]
    fn stop_closes_live_connections_and_leaves_no_server_threads() {
        let srv = start(fake_cls_engine());
        let mut busy = client(&srv);
        send(&mut busy, r#"{"id":1,"op":"classify","text":"t1"}"#);
        assert!(recv(&mut busy).contains("\"ok\":true"));
        let mut idle = client(&srv);
        std::thread::sleep(Duration::from_millis(30)); // let the accept land
        let t0 = Instant::now();
        srv.stop();
        assert!(
            t0.elapsed() < Duration::from_secs(5),
            "stop() must not hang on live connections"
        );
        // both connections see EOF, not a hang: the old thread-per-conn
        // server orphaned its detached reader threads here
        let mut rest = Vec::new();
        busy.get_mut().read_to_end(&mut rest).expect("busy conn sees EOF");
        idle.get_mut().read_to_end(&mut rest).expect("idle conn sees EOF");
        // and the server's named threads are gone (joined, not detached)
        let mut names = String::new();
        for t in std::fs::read_dir("/proc/self/task").unwrap() {
            let p = t.unwrap().path().join("comm");
            names.push_str(&std::fs::read_to_string(p).unwrap_or_default());
        }
        assert!(!names.contains("datamux-reactor"), "orphaned reactor thread: {names}");
        assert!(!names.contains("datamux-completions"), "orphaned pump thread: {names}");
    }
}
