//! Sharded multi-process serving tier: [`ShardRouter`] implements
//! [`Submit`] over a pool of protocol-v2 TCP connections to N backend
//! `datamux serve` processes.
//!
//! Health model — one three-state breaker per shard:
//!
//! ```text
//!           probe/IO failure                 probe OK
//!  Closed ───────────────────▶ Open ─────▶ HalfOpen ─────▶ Closed
//!     ▲                         ▲  backoff     │
//!     └───── (traffic + probes) └──────────────┘ probe fails:
//!                                                re-open, delay doubles
//! ```
//!
//! A `Closed` shard takes traffic and is pinged with a periodic v2 STATS
//! probe; a probe timeout or any connection I/O failure opens the
//! breaker. An `Open` shard takes no traffic; after a seeded-jitter
//! exponential-backoff delay ([`crate::util::backoff::Backoff`]) the
//! monitor moves it to `HalfOpen` and attempts one reconnect+handshake —
//! success closes the breaker, failure re-opens it with a doubled delay.
//!
//! Failover is **loss-free** for admitted work, mirroring the in-process
//! lane-requeue guarantee across the process boundary: every in-flight
//! request is tracked in its connection's id map; when a shard dies, its
//! unanswered requests are resubmitted to surviving shards with their
//! *remaining* deadline budget (minus an RTT margin), and requests that
//! cannot be placed anywhere are parked and retried until a shard
//! returns or their deadline expires — nothing admitted is ever dropped
//! without a typed answer. When every breaker is open, new submissions
//! fail *fast* with [`SubmitError::Unavailable`] instead of queueing
//! behind dead connections.

use std::sync::atomic::{AtomicBool, AtomicU64, AtomicUsize, Ordering};
use std::sync::Arc;
use std::time::{Duration, Instant};

use anyhow::{anyhow, Result};

use crate::tokenizer::{default_vocab, Tokenizer};
use crate::util::backoff::Backoff;
use crate::util::metrics::{CounterSnapshot, LatencySummary};
use crate::util::sync::{rank, TrackedMutex};
use crate::util::threadpool::{Channel, OnceCellSync};

use super::api::{
    ClassStatus, CompletionQueue, InferenceRequest, ShardState, ShardStatus, Submit, SubmitError,
    TaskKind,
};
use super::buckets::Buckets;
use super::pool::{
    connect_handshake, probe_json, request_json, Entry, FaultInjector, FaultPlan, ModelInfo,
    PoolEvent, PoolRequest, ShardConn, ShardShared,
};
use super::request::{Completion, EngineError, RequestHandle};
use super::scheduler::Stats;
use super::{note_shed, prepare_request};

// ---------------------------------------------------------------------------
// breaker
// ---------------------------------------------------------------------------

/// Pure three-state breaker driven by the monitor thread. Time is always
/// passed in, never read, so the unit tests control the clock.
pub(crate) struct Breaker {
    state: ShardState,
    backoff: Backoff,
    /// when (in `Open`) the next half-open attempt may start
    next_probe_at: Option<Instant>,
}

impl Breaker {
    pub fn new(base: Duration, cap: Duration, seed: u64) -> Breaker {
        Breaker {
            state: ShardState::Closed,
            backoff: Backoff::new(base, cap, seed),
            next_probe_at: None,
        }
    }

    pub fn state(&self) -> ShardState {
        self.state
    }

    /// A probe answered / a reconnect handshake succeeded.
    pub fn on_success(&mut self) {
        self.state = ShardState::Closed;
        self.next_probe_at = None;
        self.backoff.reset();
    }

    /// A probe timed out / connection I/O failed / handshake failed.
    /// Schedules the next half-open attempt with exponential backoff.
    pub fn on_failure(&mut self, now: Instant) {
        self.state = ShardState::Open;
        self.next_probe_at = Some(now + self.backoff.next_delay());
    }

    /// `Open` and the backoff delay elapsed → `HalfOpen` (the caller
    /// owns the single reconnect attempt). Returns whether it moved.
    pub fn try_half_open(&mut self, now: Instant) -> bool {
        if self.state == ShardState::Open && self.next_probe_at.is_some_and(|t| t <= now) {
            self.state = ShardState::HalfOpen;
            self.next_probe_at = None;
            true
        } else {
            false
        }
    }
}

// ---------------------------------------------------------------------------
// configuration
// ---------------------------------------------------------------------------

/// How requests are placed onto healthy shards.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum Placement {
    /// `bucket_index % n_shards`, falling through to the next closed
    /// shard when the home shard is down — requests of one sequence-
    /// length bucket colocate, so each shard's batcher sees dense
    /// same-shape waves
    #[default]
    ByBucket,
    /// strict rotation over closed shards
    RoundRobin,
}

impl Placement {
    pub fn as_str(self) -> &'static str {
        match self {
            Placement::ByBucket => "by_bucket",
            Placement::RoundRobin => "round_robin",
        }
    }

    pub fn from_str(s: &str) -> Option<Placement> {
        match s {
            "by_bucket" => Some(Placement::ByBucket),
            "round_robin" => Some(Placement::RoundRobin),
            _ => None,
        }
    }
}

/// Shard-router configuration (see field docs for defaults).
#[derive(Debug, Clone)]
pub struct ShardConfig {
    /// backend `host:port` addresses, one per shard (non-empty)
    pub addrs: Vec<String>,
    pub placement: Placement,
    /// interval between health probes to closed shards (default 250ms)
    pub probe_interval: Duration,
    /// a probe unanswered for this long trips the breaker (default 1s)
    pub probe_timeout: Duration,
    /// half-open backoff: base delay (default 100ms)
    pub backoff_base: Duration,
    /// half-open backoff: cap (default 5s)
    pub backoff_cap: Duration,
    /// seed for backoff jitter (fault injection has its own seed in
    /// [`FaultPlan`])
    pub seed: u64,
    /// subtracted from the remaining deadline budget on every shard hop
    /// (covers the extra network round trip; default 2ms)
    pub rtt_margin: Duration,
    /// per-shard in-flight cap: `try_submit` sheds `QueueFull` beyond
    /// it, blocking `submit` waits (default 512)
    pub in_flight_cap: usize,
    /// a request bounced across more shard deaths than this fails typed
    /// (`WorkerFailed`) instead of cycling forever (default 3)
    pub max_resubmits: u32,
    /// per-connect-attempt timeout, also the handshake read timeout
    /// (default 1s)
    pub connect_timeout: Duration,
    /// how long `connect` waits for the *first* healthy shard before
    /// giving up entirely (default 10s)
    pub startup_timeout: Duration,
    /// an in-flight request older than this kills its connection — the
    /// belt-and-braces sweep that turns silent request loss (a wedged
    /// shard, a reply the pool could not correlate) into failover
    /// (default 10s)
    pub hop_timeout: Duration,
    /// chaos fault injection (default [`FaultPlan::from_env`])
    pub fault: FaultPlan,
}

impl ShardConfig {
    pub fn new<S: Into<String>, I: IntoIterator<Item = S>>(addrs: I) -> ShardConfig {
        ShardConfig {
            addrs: addrs.into_iter().map(Into::into).collect(),
            placement: Placement::default(),
            probe_interval: Duration::from_millis(250),
            probe_timeout: Duration::from_secs(1),
            backoff_base: Duration::from_millis(100),
            backoff_cap: Duration::from_secs(5),
            seed: 0,
            rtt_margin: Duration::from_millis(2),
            in_flight_cap: 512,
            max_resubmits: 3,
            connect_timeout: Duration::from_secs(1),
            startup_timeout: Duration::from_secs(10),
            hop_timeout: Duration::from_secs(10),
            fault: FaultPlan::from_env(),
        }
    }

    pub fn placement(mut self, p: Placement) -> Self {
        self.placement = p;
        self
    }

    pub fn probe_interval(mut self, d: Duration) -> Self {
        self.probe_interval = d;
        self
    }

    pub fn probe_timeout(mut self, d: Duration) -> Self {
        self.probe_timeout = d;
        self
    }

    pub fn backoff(mut self, base: Duration, cap: Duration) -> Self {
        self.backoff_base = base;
        self.backoff_cap = cap;
        self
    }

    pub fn seed(mut self, seed: u64) -> Self {
        self.seed = seed;
        self
    }

    pub fn rtt_margin(mut self, d: Duration) -> Self {
        self.rtt_margin = d;
        self
    }

    pub fn in_flight_cap(mut self, cap: usize) -> Self {
        self.in_flight_cap = cap.max(1);
        self
    }

    pub fn max_resubmits(mut self, n: u32) -> Self {
        self.max_resubmits = n;
        self
    }

    pub fn connect_timeout(mut self, d: Duration) -> Self {
        self.connect_timeout = d;
        self
    }

    pub fn startup_timeout(mut self, d: Duration) -> Self {
        self.startup_timeout = d;
        self
    }

    pub fn hop_timeout(mut self, d: Duration) -> Self {
        self.hop_timeout = d;
        self
    }

    pub fn fault(mut self, plan: FaultPlan) -> Self {
        self.fault = plan;
        self
    }
}

// ---------------------------------------------------------------------------
// shard bookkeeping
// ---------------------------------------------------------------------------

struct Shard {
    addr: String,
    breaker: TrackedMutex<Breaker>,
    conn: TrackedMutex<Option<Arc<ShardConn>>>,
    shared: Arc<ShardShared>,
}

impl Shard {
    fn state(&self) -> ShardState {
        self.breaker.lock().state()
    }

    /// Current connection if the breaker is closed and the reader alive.
    fn live_conn(&self) -> Option<Arc<ShardConn>> {
        if self.state() != ShardState::Closed {
            return None;
        }
        self.conn.lock().as_ref().filter(|c| !c.is_dead()).cloned()
    }
}

/// Why a placement attempt found no home for a request.
enum PlaceFailure {
    /// no shard has a closed breaker — the caller sheds `Unavailable`
    NoShard,
    /// at least one closed shard exists but all are at the in-flight
    /// cap — the caller sheds `QueueFull` or blocks
    AtCapacity,
}

/// State shared between the router's submit path and the monitor thread.
struct Core {
    shards: Vec<Arc<Shard>>,
    cfg: ShardConfig,
    fault: Arc<FaultInjector>,
    /// pool-global wire-id allocator: ids are never reused across shards
    /// or reconnects, so a late reply can never be mis-correlated after
    /// failover (also feeds connection generation numbers)
    next_id: AtomicU64,
    rr: AtomicUsize,
    /// requests that expired while parked with every shard down
    park_expired: AtomicU64,
}

impl Core {
    fn pick_start(&self, bucket: usize) -> usize {
        match self.cfg.placement {
            Placement::ByBucket => bucket % self.shards.len(),
            Placement::RoundRobin => self.rr.fetch_add(1, Ordering::Relaxed) % self.shards.len(),
        }
    }

    /// Write one request to a specific shard connection, registering it
    /// in-flight first (so a send failure can never lose it: either we
    /// reclaim it here or the dying reader drains it into failover).
    /// Returns the wire id, or the request back on connection failure.
    fn send_request(
        &self,
        shard: &Shard,
        conn: &Arc<ShardConn>,
        mut req: PoolRequest,
    ) -> Result<u64, PoolRequest> {
        let id = self.next_id.fetch_add(1, Ordering::Relaxed);
        // restamp the hop clock: staleness sweeps must measure how long
        // *this* connection has sat on the request, not how old the
        // request is overall (failover/unparking resets the hop, never
        // the deadline)
        req.sent_at = Instant::now();
        let deadline_ms = req.deadline.map(|dl| {
            dl.saturating_duration_since(Instant::now())
                .saturating_sub(self.cfg.rtt_margin)
                .as_secs_f64()
                * 1e3
        });
        let line = request_json(id, &req, deadline_ms);
        conn.map.lock().insert(id, Entry::Req(Box::new(req)));
        shard.shared.in_flight.fetch_add(1, Ordering::Relaxed);
        let sent = conn.send_line(&line, &self.fault).is_ok();
        if !sent {
            conn.shutdown_now(); // the reader drains + fails over the rest
        }
        // reclaim after a failed send, and after a send that raced the
        // reader's death (dead is set *before* the drain, so whoever
        // removes the entry from the map owns it — exactly once)
        if !sent || conn.is_dead() {
            if let Some(Entry::Req(r)) = conn.map.lock().remove(&id) {
                shard.shared.in_flight.fetch_sub(1, Ordering::Relaxed);
                return Err(*r);
            }
            // the reader already drained it into a ConnDown event
        }
        Ok(id)
    }

    /// Try every closed shard starting at `start`, falling through on
    /// dead connections and (when `capped`) on full shards.
    fn try_place(
        &self,
        start: usize,
        req: PoolRequest,
        capped: bool,
    ) -> Result<u64, (PoolRequest, PlaceFailure)> {
        let n = self.shards.len();
        let mut req = req;
        let mut saw_closed = false;
        for k in 0..n {
            let shard = &self.shards[(start + k) % n];
            let Some(conn) = shard.live_conn() else { continue };
            saw_closed = true;
            let depth = shard.shared.in_flight.load(Ordering::Relaxed);
            if capped && depth >= self.cfg.in_flight_cap as u64 {
                continue;
            }
            match self.send_request(shard, &conn, req) {
                Ok(id) => return Ok(id),
                Err(r) => req = r,
            }
        }
        let why = if saw_closed { PlaceFailure::AtCapacity } else { PlaceFailure::NoShard };
        Err((req, why))
    }
}

// ---------------------------------------------------------------------------
// the router
// ---------------------------------------------------------------------------

/// A [`Submit`] engine that forwards every request over TCP to a pool of
/// backend `datamux serve` shards, with per-shard breakers, health
/// probes, and loss-free failover. See the module docs for the model.
pub struct ShardRouter {
    core: Arc<Core>,
    tokenizer: Tokenizer,
    buckets: Buckets,
    task: TaskKind,
    seq_len: usize,
    n_classes: usize,
    stats: Arc<Stats>,
    events: Channel<PoolEvent>,
    shutdown: Arc<AtomicBool>,
    monitor: TrackedMutex<Option<std::thread::JoinHandle<()>>>,
}

impl ShardRouter {
    /// Connect to the configured shards. At least one shard must
    /// handshake within `startup_timeout`; unreachable shards start with
    /// their breaker open and are adopted by the monitor when they come
    /// up. Every reachable shard must serve the same model shape.
    pub fn connect(cfg: ShardConfig) -> Result<ShardRouter> {
        if cfg.addrs.is_empty() {
            return Err(anyhow!("shard router needs at least one backend address"));
        }
        let fault = Arc::new(FaultInjector::new(cfg.fault.clone()));
        let events: Channel<PoolEvent> = Channel::bounded(4096);
        let shards: Vec<Arc<Shard>> = cfg
            .addrs
            .iter()
            .enumerate()
            .map(|(i, addr)| {
                Arc::new(Shard {
                    addr: addr.clone(),
                    breaker: TrackedMutex::new(
                        "shards.breaker",
                        rank::SHARD_BREAKER,
                        Breaker::new(
                            cfg.backoff_base,
                            cfg.backoff_cap,
                            cfg.seed.wrapping_add(i as u64),
                        ),
                    ),
                    conn: TrackedMutex::new("shards.conn", rank::SHARD_CONN, None),
                    shared: Arc::default(),
                })
            })
            .collect();
        let core = Arc::new(Core {
            shards,
            cfg,
            fault,
            next_id: AtomicU64::new(1),
            rr: AtomicUsize::new(0),
            park_expired: AtomicU64::new(0),
        });

        // startup: handshake every shard; insist on >= 1 success before
        // the startup timeout, and on model agreement among successes
        let deadline = Instant::now() + core.cfg.startup_timeout;
        let mut model: Option<ModelInfo> = None;
        let mut last_err: Option<anyhow::Error> = None;
        loop {
            for (i, shard) in core.shards.iter().enumerate() {
                if shard.conn.lock().is_some() {
                    continue;
                }
                match connect_handshake(&shard.addr, core.cfg.connect_timeout, &core.fault) {
                    Ok((stream, info)) => {
                        match &model {
                            None => model = Some(info),
                            Some(m) if *m != info => {
                                return Err(anyhow!(
                                    "shard {} serves a different model shape than its peers",
                                    shard.addr
                                ));
                            }
                            Some(_) => {}
                        }
                        let n_classes = model.as_ref().map_or(0, |m| m.n_classes);
                        // a wedged shard must not block writers forever:
                        // a timed-out write reads as a dead connection
                        stream.set_write_timeout(Some(core.cfg.probe_timeout)).ok();
                        let generation = core.next_id.fetch_add(1, Ordering::Relaxed);
                        let conn = ShardConn::start(
                            i,
                            generation,
                            stream,
                            shard.shared.clone(),
                            events.clone(),
                            n_classes,
                        )?;
                        *shard.conn.lock() = Some(conn);
                        shard.breaker.lock().on_success();
                    }
                    Err(e) => last_err = Some(e),
                }
            }
            if model.is_some() || Instant::now() >= deadline {
                break;
            }
            std::thread::sleep(Duration::from_millis(50));
        }
        let model = model.ok_or_else(|| {
            anyhow!(
                "no shard reachable within {:?} ({})",
                core.cfg.startup_timeout,
                last_err.map_or_else(|| "no attempts".to_string(), |e| format!("{e:#}"))
            )
        })?;
        // open the breaker once per still-unreachable shard (the startup
        // loop itself must not compound the backoff while polling)
        for shard in &core.shards {
            if shard.conn.lock().is_none() {
                shard.breaker.lock().on_failure(Instant::now());
            }
        }

        let tokenizer = Tokenizer::new(default_vocab(), model.vocab_size);
        let buckets = Buckets::new(&model.buckets, model.seq_len);
        let stats = Arc::new(Stats::for_buckets(buckets.lens()));
        let shutdown = Arc::new(AtomicBool::new(false));
        let monitor = Monitor {
            core: core.clone(),
            events: events.clone(),
            shutdown: shutdown.clone(),
            model: model.clone(),
        };
        let handle = std::thread::Builder::new()
            .name("datamux-shardmon".into())
            .spawn(move || monitor.run())
            .map_err(|e| anyhow!("spawn shard monitor thread: {e}"))?;

        Ok(ShardRouter {
            core,
            tokenizer,
            buckets,
            task: model.task,
            seq_len: model.seq_len,
            n_classes: model.n_classes,
            stats,
            events,
            shutdown,
            monitor: TrackedMutex::new("shards.monitor", rank::THREAD_HANDLE, Some(handle)),
        })
    }

    /// Number of configured shards.
    pub fn n_shards(&self) -> usize {
        self.core.shards.len()
    }

    /// Shared admission: validate/frame the request, shed hopeless
    /// deadlines, then place it on a shard. Consumes `done` either into
    /// the in-flight map (success) or defused (typed error return).
    fn admit(
        &self,
        req: InferenceRequest,
        mut done: Completion,
        blocking: bool,
    ) -> Result<(u64, Option<Instant>), SubmitError> {
        let priority = req.priority;
        if self.shutdown.load(Ordering::Acquire) {
            done.defuse();
            self.stats.counters.rejected.fetch_add(1, Ordering::Relaxed);
            return Err(SubmitError::Shutdown);
        }
        let (content, bucket, deadline, priority) =
            match prepare_request(&self.tokenizer, &self.buckets, self.task, req) {
                Ok(t) => t,
                Err(e) => {
                    done.defuse();
                    return Err(note_shed(&self.stats, priority, e));
                }
            };
        let now = Instant::now();
        let mut preq = PoolRequest {
            content,
            task: self.task,
            priority,
            bucket,
            deadline,
            submitted: now,
            sent_at: now, // restamped on every wire write
            resubmits: 0,
            done,
        };
        let start = self.core.pick_start(bucket);
        // waiting at capacity backs off progressively (a fixed tight
        // spin burns CPU under sustained saturation)
        let mut wait = Duration::from_micros(200);
        loop {
            // re-checked every pass: the hop costs a round trip, so a
            // budget at or under the margin cannot be met behind the
            // wire — shed it typed and fast instead of blocking past the
            // deadline and shipping a zero remaining budget
            if let Some(dl) = preq.deadline {
                if dl.saturating_duration_since(Instant::now()) <= self.core.cfg.rtt_margin {
                    preq.done.defuse();
                    return Err(note_shed(&self.stats, priority, SubmitError::Overloaded));
                }
            }
            match self.core.try_place(start, preq, true) {
                Ok(id) => {
                    self.stats.counters.submitted.fetch_add(1, Ordering::Relaxed);
                    return Ok((id, deadline));
                }
                Err((mut r, PlaceFailure::NoShard)) => {
                    r.done.defuse();
                    self.stats.counters.rejected.fetch_add(1, Ordering::Relaxed);
                    return Err(SubmitError::Unavailable);
                }
                Err((r, PlaceFailure::AtCapacity)) => {
                    if !blocking {
                        let mut r = r;
                        r.done.defuse();
                        self.stats.counters.rejected.fetch_add(1, Ordering::Relaxed);
                        return Err(SubmitError::QueueFull);
                    }
                    preq = r;
                    // never sleep past the point where the budget dies:
                    // wake exactly when the deadline check above sheds
                    let mut nap = wait;
                    if let Some(dl) = preq.deadline {
                        nap = nap.min(
                            dl.saturating_duration_since(Instant::now())
                                .saturating_sub(self.core.cfg.rtt_margin),
                        );
                    }
                    std::thread::sleep(nap);
                    wait = (wait * 2).min(Duration::from_millis(5));
                    if self.shutdown.load(Ordering::Acquire) {
                        preq.done.defuse();
                        self.stats.counters.rejected.fetch_add(1, Ordering::Relaxed);
                        return Err(SubmitError::Shutdown);
                    }
                }
            }
        }
    }
}

impl Submit for ShardRouter {
    fn submit(&self, req: InferenceRequest) -> Result<RequestHandle, SubmitError> {
        let cell = OnceCellSync::new();
        let (id, deadline) = self.admit(req, Completion::cell(cell.clone()), true)?;
        Ok(RequestHandle { id, deadline, done: cell })
    }

    fn try_submit(&self, req: InferenceRequest) -> Result<RequestHandle, SubmitError> {
        let cell = OnceCellSync::new();
        let (id, deadline) = self.admit(req, Completion::cell(cell.clone()), false)?;
        Ok(RequestHandle { id, deadline, done: cell })
    }

    fn submit_tagged(
        &self,
        req: InferenceRequest,
        tag: u64,
        out: &CompletionQueue,
    ) -> Result<(), SubmitError> {
        self.admit(req, Completion::queue(tag, out.clone()), false).map(|_| ())
    }

    fn native_task(&self) -> TaskKind {
        self.task
    }

    fn tokenizer(&self) -> &Tokenizer {
        &self.tokenizer
    }

    fn seq_len(&self) -> usize {
        self.seq_len
    }

    fn n_classes(&self) -> usize {
        self.n_classes
    }

    fn buckets(&self) -> Vec<usize> {
        self.buckets.lens().to_vec()
    }

    fn queue_depth(&self) -> usize {
        self.core
            .shards
            .iter()
            .map(|s| s.shared.in_flight.load(Ordering::Relaxed) as usize)
            .sum()
    }

    fn counters(&self) -> CounterSnapshot {
        let mut completed = 0;
        let mut expired = self.core.park_expired.load(Ordering::Relaxed);
        for s in &self.core.shards {
            completed += s.shared.completed.load(Ordering::Relaxed);
            expired += s.shared.expired.load(Ordering::Relaxed);
        }
        CounterSnapshot {
            submitted: self.stats.counters.submitted.load(Ordering::Relaxed),
            completed,
            rejected: self.stats.counters.rejected.load(Ordering::Relaxed),
            expired,
            ..CounterSnapshot::default()
        }
    }

    fn latency(&self) -> LatencySummary {
        self.core
            .shards
            .iter()
            .map(|s| s.shared.e2e.summary())
            .fold(EMPTY_SUMMARY, LatencySummary::merge)
    }

    fn queue_wait(&self) -> LatencySummary {
        // the front has no visibility into shard-side queue waits
        EMPTY_SUMMARY
    }

    fn class_status(&self) -> Vec<ClassStatus> {
        // shed tallies are front-side; completion detail lives shard-side
        self.stats.class_snapshot()
    }

    fn shard_status(&self) -> Vec<ShardStatus> {
        self.core
            .shards
            .iter()
            .map(|s| ShardStatus {
                addr: s.addr.clone(),
                state: s.state(),
                probes: s.shared.probes.load(Ordering::Relaxed),
                probe_failures: s.shared.probe_failures.load(Ordering::Relaxed),
                failovers: s.shared.failovers.load(Ordering::Relaxed),
                in_flight: s.shared.in_flight.load(Ordering::Relaxed) as usize,
                completed: s.shared.completed.load(Ordering::Relaxed),
                ewma_rtt_us: s.shared.ewma_rtt_us(),
            })
            .collect()
    }

    fn backend_info(&self) -> Vec<String> {
        self.core
            .shards
            .iter()
            .map(|s| format!("shard {} [{}]", s.addr, s.state().as_str()))
            .collect()
    }
}

const EMPTY_SUMMARY: LatencySummary =
    LatencySummary { count: 0, mean_ns: 0.0, p50_ns: 0, p95_ns: 0, p99_ns: 0, max_ns: 0 };

impl Drop for ShardRouter {
    fn drop(&mut self) {
        self.shutdown.store(true, Ordering::Release);
        self.events.close();
        for s in &self.core.shards {
            if let Some(c) = s.conn.lock().as_ref() {
                c.shutdown_now();
            }
        }
        if let Some(h) = self.monitor.lock().take() {
            let _ = h.join();
        }
    }
}

// ---------------------------------------------------------------------------
// monitor thread
// ---------------------------------------------------------------------------

/// What [`Monitor::sweep_stale`] found wrong with an in-flight entry.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
enum Staleness {
    /// a health probe unanswered past `probe_timeout`
    Probe,
    /// a request whose *current hop* is older than `hop_timeout`
    Hop,
}

/// Staleness of one in-flight entry. Requests are judged by `sent_at` —
/// the current hop's write time — never by `submitted`: a request that
/// aged while parked or on a previous (dead) shard must not condemn the
/// healthy connection it was failed over onto.
fn entry_staleness(
    e: &Entry,
    now: Instant,
    probe_timeout: Duration,
    hop_timeout: Duration,
) -> Option<Staleness> {
    match e {
        Entry::Probe { sent } => {
            (now.duration_since(*sent) > probe_timeout).then_some(Staleness::Probe)
        }
        Entry::Req(r) => (now.duration_since(r.sent_at) > hop_timeout).then_some(Staleness::Hop),
    }
}

struct Monitor {
    core: Arc<Core>,
    events: Channel<PoolEvent>,
    shutdown: Arc<AtomicBool>,
    model: ModelInfo,
}

impl Monitor {
    fn run(self) {
        // requests that could not be placed anywhere (all shards down)
        // wait here; they are answered — placed, expired, or shut down —
        // never dropped
        let mut pending: Vec<PoolRequest> = Vec::new();
        let tick = (self.core.cfg.probe_interval / 4)
            .clamp(Duration::from_millis(5), Duration::from_millis(100));
        let mut last_probe = Instant::now();
        loop {
            if let Some(ev) = self.events.recv_timeout(tick) {
                self.handle_event(ev, &mut pending);
                while let Some(ev) = self.events.try_recv() {
                    self.handle_event(ev, &mut pending);
                }
            }
            if self.shutdown.load(Ordering::Acquire) {
                break;
            }
            let now = Instant::now();
            self.flush_pending(&mut pending, now);
            if now.duration_since(last_probe) >= self.core.cfg.probe_interval {
                last_probe = now;
                self.send_probes(now);
            }
            self.sweep_stale(now);
            self.reconnect_open(now);
        }
        // shutdown: tear down connections; their readers drain in-flight
        // maps, and every stranded Completion's drop guard answers typed
        // Shutdown — pending parked requests are dropped the same way
        for s in &self.core.shards {
            if let Some(c) = s.conn.lock().take() {
                c.shutdown_now();
                c.join();
            }
        }
    }

    fn handle_event(&self, ev: PoolEvent, pending: &mut Vec<PoolRequest>) {
        match ev {
            PoolEvent::ConnDown { shard, generation, orphans } => {
                let s = &self.core.shards[shard];
                let stale_conn = {
                    let mut conn = s.conn.lock();
                    if conn.as_ref().is_some_and(|c| c.generation == generation) {
                        s.breaker.lock().on_failure(Instant::now());
                        conn.take()
                    } else {
                        None // a newer connection already replaced it
                    }
                };
                if let Some(c) = stale_conn {
                    c.join(); // the reader just sent this event; reap it
                }
                s.shared.failovers.fetch_add(orphans.len() as u64, Ordering::Relaxed);
                for r in orphans {
                    self.resubmit(r, pending);
                }
            }
            PoolEvent::Retry { shard, req } => {
                self.core.shards[shard].shared.failovers.fetch_add(1, Ordering::Relaxed);
                self.resubmit(*req, pending);
            }
        }
    }

    /// Resubmit a failed-over request with its *remaining* deadline
    /// budget. An expired budget fails typed; a bounce-count overflow
    /// fails typed; no surviving shard parks it for retry.
    fn resubmit(&self, mut r: PoolRequest, pending: &mut Vec<PoolRequest>) {
        if let Some(dl) = r.deadline {
            if dl.saturating_duration_since(Instant::now()) <= self.core.cfg.rtt_margin {
                self.core.park_expired.fetch_add(1, Ordering::Relaxed);
                r.done.fulfill(Err(EngineError::DeadlineExceeded));
                return;
            }
        }
        r.resubmits += 1;
        if r.resubmits > self.core.cfg.max_resubmits {
            let n = r.resubmits - 1;
            r.done.fulfill(Err(EngineError::WorkerFailed(format!(
                "request failed over {n} times without an answer"
            ))));
            return;
        }
        let start = self.core.pick_start(r.bucket);
        // failover ignores the in-flight cap: an admitted request beats
        // backpressure — losing it is worse than a temporarily deep shard
        if let Err((r, _)) = self.core.try_place(start, r, false) {
            pending.push(r);
        }
    }

    /// Retry parked requests; expire the ones whose budget ran out.
    fn flush_pending(&self, pending: &mut Vec<PoolRequest>, now: Instant) {
        if pending.is_empty() {
            return;
        }
        for r in std::mem::take(pending) {
            if let Some(dl) = r.deadline {
                if dl.saturating_duration_since(now) <= self.core.cfg.rtt_margin {
                    self.core.park_expired.fetch_add(1, Ordering::Relaxed);
                    r.done.fulfill(Err(EngineError::DeadlineExceeded));
                    continue;
                }
            }
            let start = self.core.pick_start(r.bucket);
            if let Err((r, _)) = self.core.try_place(start, r, false) {
                pending.push(r);
            }
        }
    }

    /// Ping every closed shard with a v2 STATS probe. The reply updates
    /// the RTT EWMA; a missing reply is caught by [`Monitor::sweep_stale`].
    fn send_probes(&self, now: Instant) {
        for s in &self.core.shards {
            let Some(conn) = s.live_conn() else { continue };
            let id = self.core.next_id.fetch_add(1, Ordering::Relaxed);
            conn.map.lock().insert(id, Entry::Probe { sent: now });
            s.shared.probes.fetch_add(1, Ordering::Relaxed);
            if conn.send_line(&probe_json(id), &self.core.fault).is_err() {
                s.shared.probe_failures.fetch_add(1, Ordering::Relaxed);
                conn.map.lock().remove(&id);
                conn.shutdown_now();
            }
        }
    }

    /// Kill connections with an unanswered probe past `probe_timeout` or
    /// a request past `hop_timeout` — both mean the shard stopped
    /// answering without closing the socket; the reader's drain then
    /// fails the rest over.
    fn sweep_stale(&self, now: Instant) {
        for s in &self.core.shards {
            let Some(conn) = s.conn.lock().as_ref().cloned() else { continue };
            // backstop for a missed ConnDown event (closed channel): a
            // dead connection must still open the breaker or the shard
            // would never be probed for re-adoption. Deliberately no
            // join here: the reader may still be blocked delivering its
            // ConnDown orphans to this very thread's channel — dropping
            // the handle detaches it, and it exits right after the send.
            if conn.is_dead() {
                let mut slot = s.conn.lock();
                if slot.as_ref().is_some_and(|c| Arc::ptr_eq(c, &conn)) {
                    slot.take();
                    s.breaker.lock().on_failure(now);
                }
                continue;
            }
            let mut stale_probe = false;
            let mut stale_req = false;
            {
                let m = conn.map.lock();
                for e in m.values() {
                    match entry_staleness(e, now, self.core.cfg.probe_timeout, self.core.cfg.hop_timeout)
                    {
                        Some(Staleness::Probe) => stale_probe = true,
                        Some(Staleness::Hop) => stale_req = true,
                        None => {}
                    }
                }
            }
            if stale_probe {
                s.shared.probe_failures.fetch_add(1, Ordering::Relaxed);
            }
            if stale_probe || stale_req {
                conn.shutdown_now();
            }
        }
    }

    /// Move due `Open` breakers to `HalfOpen` and attempt one
    /// reconnect+handshake each; verify the returning shard still serves
    /// the same model before re-adopting it.
    fn reconnect_open(&self, now: Instant) {
        for (i, s) in self.core.shards.iter().enumerate() {
            if !s.breaker.lock().try_half_open(now) {
                continue;
            }
            s.shared.probes.fetch_add(1, Ordering::Relaxed);
            let timeout = self.core.cfg.connect_timeout;
            let outcome = connect_handshake(&s.addr, timeout, &self.core.fault)
                .and_then(|(stream, info)| {
                    if info != self.model {
                        return Err(anyhow!("shard {} changed model shape", s.addr));
                    }
                    stream.set_write_timeout(Some(self.core.cfg.probe_timeout)).ok();
                    let generation = self.core.next_id.fetch_add(1, Ordering::Relaxed);
                    ShardConn::start(
                        i,
                        generation,
                        stream,
                        s.shared.clone(),
                        self.events.clone(),
                        self.model.n_classes,
                    )
                });
            match outcome {
                Ok(conn) => {
                    *s.conn.lock() = Some(conn);
                    s.breaker.lock().on_success();
                }
                Err(_) => {
                    s.shared.probe_failures.fetch_add(1, Ordering::Relaxed);
                    s.breaker.lock().on_failure(Instant::now());
                }
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn mk_breaker() -> Breaker {
        Breaker::new(Duration::from_millis(100), Duration::from_secs(5), 7)
    }

    #[test]
    fn breaker_closed_to_open_to_half_open_to_closed() {
        let mut b = mk_breaker();
        let t0 = Instant::now();
        assert_eq!(b.state(), ShardState::Closed);
        assert!(!b.try_half_open(t0), "closed breakers never half-open");

        b.on_failure(t0);
        assert_eq!(b.state(), ShardState::Open);
        assert!(!b.try_half_open(t0), "the backoff delay must elapse first");
        // base 100ms, jitter in [0.5, 1.0): due strictly before 100ms
        assert!(b.try_half_open(t0 + Duration::from_millis(100)));
        assert_eq!(b.state(), ShardState::HalfOpen);
        assert!(!b.try_half_open(t0 + Duration::from_secs(9)), "only one attempt at a time");

        b.on_success();
        assert_eq!(b.state(), ShardState::Closed);
    }

    #[test]
    fn breaker_failures_double_the_delay_up_to_the_cap() {
        let mut b = mk_breaker();
        let t0 = Instant::now();
        let mut delays = Vec::new();
        for _ in 0..8 {
            b.on_failure(t0);
            let due = b.next_probe_at.expect("open breaker schedules a probe");
            delays.push(due.duration_since(t0));
            assert!(b.try_half_open(due), "due exactly at the scheduled time");
        }
        // nominal schedule 100ms * 2^k capped at 5s, jitter in [0.5, 1.0)
        for (k, d) in delays.iter().enumerate() {
            let nominal = Duration::from_millis(100)
                .saturating_mul(1 << k.min(10))
                .min(Duration::from_secs(5));
            assert!(*d <= nominal, "attempt {k}: {d:?} beyond nominal {nominal:?}");
            assert!(*d >= nominal.mul_f64(0.5), "attempt {k}: {d:?} under half of {nominal:?}");
            assert!(*d <= Duration::from_secs(5), "cap bounds every delay");
        }
        assert!(delays[7] >= Duration::from_secs(2), "late attempts sit near the cap");

        // success resets: the next failure starts from base again
        b.on_success();
        b.on_failure(t0);
        let due = b.next_probe_at.unwrap().duration_since(t0);
        assert!(due <= Duration::from_millis(100), "reset restarts from base, got {due:?}");
    }

    #[test]
    fn breaker_half_open_failure_reopens_with_longer_delay() {
        let mut b = mk_breaker();
        let t0 = Instant::now();
        b.on_failure(t0);
        let first = b.next_probe_at.unwrap().duration_since(t0);
        assert!(b.try_half_open(t0 + first));
        b.on_failure(t0);
        assert_eq!(b.state(), ShardState::Open);
        let second = b.next_probe_at.unwrap().duration_since(t0);
        // first is under base (jitter < 1.0); the doubled nominal with
        // jitter >= 0.5 puts the second at or above the full base
        assert!(first < Duration::from_millis(100), "{first:?}");
        assert!(second >= Duration::from_millis(100), "{second:?}");
        assert!(second > first, "backoff grows: {first:?} -> {second:?}");
    }

    #[test]
    fn shard_config_defaults_and_builders() {
        let cfg = ShardConfig::new(["a:1", "b:2"])
            .placement(Placement::RoundRobin)
            .probe_interval(Duration::from_millis(50))
            .probe_timeout(Duration::from_millis(200))
            .backoff(Duration::from_millis(10), Duration::from_millis(500))
            .seed(9)
            .rtt_margin(Duration::from_millis(1))
            .in_flight_cap(0)
            .max_resubmits(5)
            .connect_timeout(Duration::from_millis(300))
            .startup_timeout(Duration::from_secs(2))
            .hop_timeout(Duration::from_secs(3))
            .fault(FaultPlan::disabled());
        assert_eq!(cfg.addrs, vec!["a:1", "b:2"]);
        assert_eq!(cfg.placement, Placement::RoundRobin);
        assert_eq!(cfg.in_flight_cap, 1, "cap floors at 1");
        assert_eq!(cfg.max_resubmits, 5);
        assert!(!cfg.fault.enabled());
    }

    #[test]
    fn placement_wire_names_round_trip() {
        for p in [Placement::ByBucket, Placement::RoundRobin] {
            assert_eq!(Placement::from_str(p.as_str()), Some(p));
        }
        assert_eq!(Placement::from_str("sticky"), None);
        assert_eq!(Placement::default(), Placement::ByBucket);
    }

    #[test]
    fn staleness_is_judged_per_hop_not_per_request_lifetime() {
        use crate::coordinator::api::Priority;
        let probe_t = Duration::from_secs(1);
        let hop_t = Duration::from_secs(10);
        let t0 = Instant::now();

        let probe = Entry::Probe { sent: t0 };
        assert_eq!(entry_staleness(&probe, t0 + Duration::from_millis(500), probe_t, hop_t), None);
        assert_eq!(
            entry_staleness(&probe, t0 + Duration::from_secs(2), probe_t, hop_t),
            Some(Staleness::Probe)
        );

        // a request admitted 60s ago (far past hop_timeout) whose
        // current hop was written 1s ago: NOT stale. Failover/unparking
        // restamp sent_at, so one slow request can never serially
        // condemn every healthy connection it lands on.
        let req = Entry::Req(Box::new(PoolRequest {
            content: vec![1, 45, 2],
            task: TaskKind::Classify,
            priority: Priority::Normal,
            bucket: 0,
            deadline: None,
            submitted: t0,
            sent_at: t0 + Duration::from_secs(60),
            resubmits: 2,
            done: Completion::cell(OnceCellSync::new()),
        }));
        let now = t0 + Duration::from_secs(61);
        assert_eq!(entry_staleness(&req, now, probe_t, hop_t), None, "fresh hop, old request");
        // only once the *hop itself* exceeds hop_timeout is it stale
        let now = t0 + Duration::from_secs(75);
        assert_eq!(entry_staleness(&req, now, probe_t, hop_t), Some(Staleness::Hop));
        if let Entry::Req(mut r) = req {
            r.done.defuse(); // synchronous test teardown, not a drop-guard answer
        }
    }

    #[test]
    fn connect_refuses_empty_addrs_and_unreachable_shards() {
        assert!(ShardRouter::connect(ShardConfig::new(Vec::<String>::new())).is_err());
        // a port from the ephemeral range with nothing listening: the
        // startup loop must give up after the (short) startup timeout
        let cfg = ShardConfig::new(["127.0.0.1:1"])
            .connect_timeout(Duration::from_millis(100))
            .startup_timeout(Duration::from_millis(200))
            .fault(FaultPlan::disabled());
        let err = ShardRouter::connect(cfg).expect_err("nothing listening");
        assert!(format!("{err:#}").contains("no shard reachable"), "{err:#}");
    }
}
