//! # DataMUX serving stack
//!
//! Reproduction of *DataMUX: Data Multiplexing for Neural Networks*
//! (Murahari et al., NeurIPS 2022) as a three-layer serving system:
//!
//! - **Layer 1 (Pallas, build time)** — multiplex / demultiplex / attention
//!   kernels in `python/compile/kernels/`.
//! - **Layer 2 (JAX, build time)** — the T-MUX transformer (and MLP / CNN
//!   variants) in `python/compile/model.py`, AOT-lowered to HLO text.
//! - **Layer 3 (this crate, request path)** — a rust coordinator that loads
//!   the AOT artifacts via PJRT and serves *multiplexed* inference: it packs
//!   `N` user requests into a single model input row, executes once, and
//!   demultiplexes the outputs back to individual responses (paper Fig 1).
//!
//! Python never runs on the request path; after `make artifacts` the rust
//! binary is self-contained. See DESIGN.md for the system inventory, the
//! submission API ([`coordinator::Submit`]) and the wire protocol
//! grammar (v1 + v2).

pub mod analysis;
pub mod baseline;
pub mod coordinator;
pub mod runtime;
pub mod tokenizer;
pub mod util;
pub mod workload;

pub use coordinator::{
    BucketStatus, Buckets, ClassStatus, CoordinatorConfig, EngineBuilder, EngineError,
    FaultInjector, FaultPlan, InferenceRequest, LaneStatus, LogitsView, MuxCoordinator, MuxRouter,
    MuxTemplate, Payload, Placement, Priority, RequestHandle, Response, ShardConfig, ShardRouter,
    ShardState, ShardStatus, Submit, SubmitError, TaskKind,
};
pub use runtime::{ArtifactManifest, FakeBackend, InferenceBackend, ModelRuntime, NativeBackend};
