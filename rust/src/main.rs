//! `datamux` CLI: serve an artifact (or an adaptive-N router over
//! several) over TCP, or run one-shot inspection commands. Examples live
//! in examples/ — this binary is the long-running leader entrypoint.
//!
//! `--backend` picks the execution engine: `pjrt` compiles and runs the
//! artifact's HLO through the XLA CPU client; `native` runs the
//! pure-rust T-MUX forward (`runtime/native`) straight from the weights
//! blob, with no PJRT anywhere in the process.
use std::path::Path;
use std::sync::Arc;
use std::time::Duration;

use anyhow::Result;
use datamux::coordinator::{
    EngineBuilder, Placement, ShardConfig, ShardRouter, ShardState, SlotPolicy, Submit,
};
use datamux::runtime::native::Precision;
use datamux::runtime::{
    default_artifacts_dir, ArtifactManifest, ArtifactMeta, FakeBackend, InferenceBackend,
    ModelRuntime, NativeBackend,
};
use datamux::util::cli::Args;

fn main() -> Result<()> {
    let args = Args::parse_env()
        .describe("cmd", "serve", "serve | front | list | parity | lint")
        .describe("artifacts", "<auto>", "artifacts directory")
        .describe("artifact", "", "artifact name (default: first trained, else first)")
        .describe("backend", "pjrt", "pjrt | native (pure-rust forward) | fake (no artifacts)")
        .describe("precision", "f32", "f32 | int8 weight precision (native backend only)")
        .describe("addr", "127.0.0.1:7071", "TCP bind address for serve")
        .describe("max-connections", "64", "concurrent client connections served")
        .describe("max-wait-ms", "5", "batcher deadline")
        .describe("queue-cap", "1024", "admission queue capacity (per bucket)")
        .describe(
            "buckets",
            "",
            "sequence-length buckets, e.g. 32,64,128 (model max always included; \
             native backend only)",
        )
        .describe("rotate-slots", "false", "rotate slot assignment (paper A3)")
        .describe("adaptive", "false", "serve an adaptive-N router over every N of a profile")
        .describe("profile", "", "profile for --adaptive (default: first with most N lanes)")
        .describe("shards", "", "front: comma-separated backend host:port list")
        .describe("placement", "by_bucket", "front: by_bucket | round_robin")
        .describe("probe-interval-ms", "250", "front: health-probe interval")
        .describe("probe-timeout-ms", "1000", "front: unanswered probe trips the breaker")
        .describe("rtt-margin-ms", "2", "front: deadline budget reserved per shard hop")
        .describe("in-flight-cap", "512", "front: per-shard in-flight cap")
        .describe("seed", "0", "front: backoff jitter seed")
        .describe("fake-task", "cls", "fake backend: cls | token")
        .describe("fake-n", "2", "fake backend: mux width N")
        .describe("fake-seq-len", "8", "fake backend: model sequence length")
        .describe("fake-classes", "3", "fake backend: number of classes")
        .describe("fake-delay-ms", "0", "fake backend: per-execution delay")
        .describe("src", "<crate src/>", "lint: source root to scan");
    let cmd = args.str("cmd", "serve");
    let backend = args
        .choice("backend", "pjrt", &["pjrt", "native", "fake"])
        .map_err(|e| anyhow::anyhow!("{e}"))?;
    let precision = match args
        .choice("precision", "f32", &["f32", "int8"])
        .map_err(|e| anyhow::anyhow!("{e}"))?
        .as_str()
    {
        "int8" => Precision::Int8,
        _ => Precision::F32,
    };
    let dir = match args.str("artifacts", "") {
        s if s.is_empty() => default_artifacts_dir(),
        s => s.into(),
    };
    // loaded lazily: `front` and `serve --backend fake` run without any
    // artifacts directory at all
    match cmd.as_str() {
        // repo-native static analysis (src/analysis): unsafe-SAFETY
        // coverage, the pinned unsafe inventory, the serving-path panic
        // ban, hot-path allocation checks and the coordinator raw-lock
        // ban. Blocking in CI; run locally before sending a change.
        "lint" => {
            let root = match args.str("src", "") {
                s if s.is_empty() => Path::new(env!("CARGO_MANIFEST_DIR")).join("src"),
                s => s.into(),
            };
            let report = datamux::analysis::lint_dir(&root)?;
            for v in &report.violations {
                eprintln!("{v}");
            }
            if !report.violations.is_empty() {
                anyhow::bail!(
                    "datamux lint: {} violation(s) in {} file(s)",
                    report.violations.len(),
                    report.files_scanned
                );
            }
            println!("datamux lint: clean ({} files)", report.files_scanned);
            Ok(())
        }
        "list" => {
            let manifest = ArtifactManifest::load(&dir)?;
            println!("{} artifacts in {}", manifest.artifacts.len(), dir.display());
            for a in &manifest.artifacts {
                println!(
                    "  {:32} N={:<3} B={:<2} L={:<3} task={:<6} trained={}",
                    a.name, a.n_mux, a.batch, a.input_len, a.task, a.trained
                );
            }
            Ok(())
        }
        "parity" => {
            let manifest = ArtifactManifest::load(&dir)?;
            if backend == "native" {
                for meta in &manifest.artifacts {
                    if meta.parity.is_none() {
                        continue;
                    }
                    match NativeBackend::from_artifact(meta) {
                        Ok(model) => {
                            model.verify_parity()?;
                            println!("parity OK (native): {}", meta.name);
                        }
                        // ortho-mux / retrieval artifacts still need PJRT
                        Err(e) => println!("skipping {} (native: {e:#})", meta.name),
                    }
                }
            } else {
                let rt = ModelRuntime::cpu()?;
                for meta in &manifest.artifacts {
                    if meta.parity.is_some() {
                        rt.load(meta)?.verify_parity()?;
                        println!("parity OK: {}", meta.name);
                    }
                }
            }
            Ok(())
        }
        "serve" => {
            let builder = EngineBuilder::new()
                .max_wait_ms(args.u64("max-wait-ms", 5))
                .queue_cap(args.usize("queue-cap", 1024))
                .buckets(args.usize_list("buckets", &[]))
                .slot_policy(if args.bool("rotate-slots", false) {
                    SlotPolicy::RotateOffset
                } else {
                    SlotPolicy::Fill
                })
                .addr(args.str("addr", "127.0.0.1:7071"))
                .max_connections(args.usize("max-connections", 64))
                .precision(precision);

            // all branches produce the same trait object: the server is
            // generic over whichever engine shape (and backend) is behind it
            let engine: Arc<dyn Submit> = if backend == "fake" {
                let task = args
                    .choice("fake-task", "cls", &["cls", "token"])
                    .map_err(|e| anyhow::anyhow!("{e}"))?;
                let n_mux = args.usize("fake-n", 2);
                let seq_len = args.usize("fake-seq-len", 8);
                let n_classes = args.usize("fake-classes", 3);
                let mut fake = FakeBackend::new(&task, n_mux, 1, seq_len, n_classes);
                let delay = args.u64("fake-delay-ms", 0);
                if delay > 0 {
                    fake = fake.with_delay(Duration::from_millis(delay));
                }
                println!("loading fake {task} model (N={n_mux}, L={seq_len}, C={n_classes})");
                Arc::new(builder.build_backend(Arc::new(fake))?)
            } else if args.bool("adaptive", false) {
                let manifest = ArtifactManifest::load(&dir)?;
                let profile = match args.str("profile", "") {
                    p if !p.is_empty() => p,
                    _ => best_profile(&manifest)
                        .ok_or_else(|| anyhow::anyhow!("no timing artifacts for --adaptive"))?,
                };
                let mut ns: Vec<usize> = manifest
                    .artifacts
                    .iter()
                    .filter(|a| !a.trained && a.profile == profile)
                    .map(|a| a.n_mux)
                    .collect();
                ns.sort_unstable();
                ns.dedup();
                let mut metas: Vec<ArtifactMeta> = Vec::new();
                for n in &ns {
                    // `ns` came from this same filter, so a miss is
                    // impossible; skip defensively instead of panicking
                    let Some(meta) = manifest
                        .artifacts
                        .iter()
                        .filter(|a| !a.trained && a.profile == profile && a.n_mux == *n)
                        .min_by_key(|a| a.batch)
                    else {
                        continue;
                    };
                    println!(
                        "lane: {} (N={}, batch={}, backend={backend})",
                        meta.name, meta.n_mux, meta.batch
                    );
                    metas.push(meta.clone());
                }
                if backend == "native" {
                    let mut lanes: Vec<Arc<dyn InferenceBackend>> = Vec::new();
                    for meta in &metas {
                        lanes.push(Arc::new(NativeBackend::from_artifact_prec(meta, precision)?));
                    }
                    Arc::new(builder.build_router_backends(lanes)?)
                } else {
                    let rt = ModelRuntime::cpu()?;
                    let mut models = Vec::new();
                    for meta in &metas {
                        models.push(rt.load(meta)?);
                    }
                    Arc::new(builder.build_router(models)?)
                }
            } else {
                let manifest = ArtifactManifest::load(&dir)?;
                let name = args.str("artifact", "");
                let meta = if name.is_empty() {
                    manifest
                        .artifacts
                        .iter()
                        .find(|a| a.trained)
                        .or_else(|| manifest.artifacts.first())
                        .ok_or_else(|| anyhow::anyhow!("no artifacts"))?
                } else {
                    manifest
                        .find(&name)
                        .ok_or_else(|| anyhow::anyhow!("artifact '{name}' not found"))?
                };
                println!(
                    "loading {} (N={}, batch={}, backend={backend})",
                    meta.name, meta.n_mux, meta.batch
                );
                if backend == "native" {
                    Arc::new(builder.build_native(meta)?)
                } else {
                    let rt = ModelRuntime::cpu()?;
                    Arc::new(builder.build(rt.load(meta)?)?)
                }
            };

            let server = builder.serve(engine.clone())?;
            println!(
                "serving on {} — v1: CLS/TOK/STATS/QUIT, v2: line JSON \
                 (classify/tag/batch/stats, pipelined); seq-len buckets {:?}",
                server.local_addr,
                engine.buckets()
            );
            // native backends report their kernel arm + weight precision
            for line in engine.backend_info() {
                println!("backend: {line}");
            }
            // watch lane health: a dead lane stops pulling from the
            // shared queue and is reported once, loudly; the process
            // keeps serving on whatever lanes survive
            let mut dead_seen: std::collections::HashSet<usize> = Default::default();
            loop {
                std::thread::sleep(std::time::Duration::from_secs(5));
                for lane in engine.lane_status() {
                    if !lane.alive && dead_seen.insert(lane.n_mux) {
                        eprintln!(
                            "WARNING: lane N={} died after {} pulls; {} request(s) \
                             re-queued to surviving lanes",
                            lane.n_mux, lane.pulls, lane.requeued
                        );
                    }
                }
            }
        }
        // sharding front: a v2 server whose engine is a ShardRouter over
        // N backend `datamux serve` processes, with health-probed
        // breakers and loss-free failover (coordinator/shards.rs)
        "front" => {
            let shards_arg = args.str("shards", "");
            if shards_arg.is_empty() {
                anyhow::bail!("front requires --shards host:port,host:port,...");
            }
            let addrs: Vec<String> = shards_arg
                .split(',')
                .map(|a| a.trim().to_string())
                .filter(|a| !a.is_empty())
                .collect();
            let placement = args
                .choice("placement", "by_bucket", &["by_bucket", "round_robin"])
                .map_err(|e| anyhow::anyhow!("{e}"))?;
            let cfg = ShardConfig::new(addrs)
                .placement(Placement::from_str(&placement).unwrap_or_default())
                .probe_interval(Duration::from_millis(args.u64("probe-interval-ms", 250)))
                .probe_timeout(Duration::from_millis(args.u64("probe-timeout-ms", 1000)))
                .rtt_margin(Duration::from_millis(args.u64("rtt-margin-ms", 2)))
                .in_flight_cap(args.usize("in-flight-cap", 512))
                .seed(args.u64("seed", 0));
            let engine: Arc<dyn Submit> = Arc::new(ShardRouter::connect(cfg)?);
            let server = EngineBuilder::new()
                .addr(args.str("addr", "127.0.0.1:7071"))
                .max_connections(args.usize("max-connections", 64))
                .serve(engine.clone())?;
            let shards = engine.shard_status();
            println!(
                "front serving on {} over {} shard(s), placement={placement}; \
                 v2: line JSON (classify/tag/batch/stats, pipelined)",
                server.local_addr,
                shards.len()
            );
            for sh in &shards {
                println!("  shard {:<21} [{}]", sh.addr, sh.state.as_str());
            }
            // watch shard health: report every breaker transition —
            // loudly when a shard drops out, quietly when it returns;
            // the front keeps serving on whatever shards survive
            let mut last: Vec<ShardState> = shards.iter().map(|s| s.state).collect();
            loop {
                std::thread::sleep(std::time::Duration::from_secs(5));
                for (i, sh) in engine.shard_status().iter().enumerate() {
                    if sh.state == last[i] {
                        continue;
                    }
                    if sh.state == ShardState::Closed {
                        println!(
                            "shard {} recovered [{} -> {}]",
                            sh.addr,
                            last[i].as_str(),
                            sh.state.as_str()
                        );
                    } else {
                        eprintln!(
                            "WARNING: shard {} [{} -> {}]; {} failover(s), {} probe failure(s)",
                            sh.addr,
                            last[i].as_str(),
                            sh.state.as_str(),
                            sh.failovers,
                            sh.probe_failures
                        );
                    }
                    last[i] = sh.state;
                }
            }
        }
        other => {
            eprintln!("unknown command '{other}'\n{}", args.usage());
            std::process::exit(2);
        }
    }
}

/// The untrained profile with the most distinct N lanes (best router fit).
fn best_profile(manifest: &ArtifactManifest) -> Option<String> {
    let mut profiles: Vec<&str> = manifest
        .artifacts
        .iter()
        .filter(|a| !a.trained)
        .map(|a| a.profile.as_str())
        .collect();
    profiles.sort();
    profiles.dedup();
    profiles
        .into_iter()
        .max_by_key(|p| {
            manifest
                .artifacts
                .iter()
                .filter(|a| !a.trained && a.profile == *p)
                .map(|a| a.n_mux)
                .collect::<std::collections::HashSet<_>>()
                .len()
        })
        .map(|p| p.to_string())
}
