//! `datamux` CLI: serve an artifact over TCP or run one-shot inspection
//! commands. Examples live in examples/ — this binary is the long-running
//! leader entrypoint.
use std::sync::Arc;

use anyhow::Result;
use datamux::coordinator::server::{Server, ServerConfig};
use datamux::coordinator::{CoordinatorConfig, MuxCoordinator, SlotPolicy};
use datamux::runtime::{default_artifacts_dir, ArtifactManifest, ModelRuntime};
use datamux::util::cli::Args;

fn main() -> Result<()> {
    let args = Args::parse_env()
        .describe("cmd", "serve", "serve | list | parity")
        .describe("artifacts", "<auto>", "artifacts directory")
        .describe("artifact", "", "artifact name (default: first trained, else first)")
        .describe("addr", "127.0.0.1:7071", "TCP bind address for serve")
        .describe("max-wait-ms", "5", "batcher deadline")
        .describe("rotate-slots", "false", "rotate slot assignment (paper A3)");
    let cmd = args.str("cmd", "serve");
    let dir = match args.str("artifacts", "") {
        s if s.is_empty() => default_artifacts_dir(),
        s => s.into(),
    };
    let manifest = ArtifactManifest::load(&dir)?;

    match cmd.as_str() {
        "list" => {
            println!("{} artifacts in {}", manifest.artifacts.len(), dir.display());
            for a in &manifest.artifacts {
                println!(
                    "  {:32} N={:<3} B={:<2} L={:<3} task={:<6} trained={}",
                    a.name, a.n_mux, a.batch, a.input_len, a.task, a.trained
                );
            }
            Ok(())
        }
        "parity" => {
            let rt = ModelRuntime::cpu()?;
            for meta in &manifest.artifacts {
                if meta.parity.is_some() {
                    rt.load(meta)?.verify_parity()?;
                    println!("parity OK: {}", meta.name);
                }
            }
            Ok(())
        }
        "serve" => {
            let name = args.str("artifact", "");
            let meta = if name.is_empty() {
                manifest
                    .artifacts
                    .iter()
                    .find(|a| a.trained)
                    .or_else(|| manifest.artifacts.first())
                    .ok_or_else(|| anyhow::anyhow!("no artifacts"))?
            } else {
                manifest
                    .find(&name)
                    .ok_or_else(|| anyhow::anyhow!("artifact '{name}' not found"))?
            };
            let rt = ModelRuntime::cpu()?;
            println!("loading {} (N={}, batch={})", meta.name, meta.n_mux, meta.batch);
            let model = rt.load(meta)?;
            let cfg = CoordinatorConfig {
                max_wait: std::time::Duration::from_millis(args.u64("max-wait-ms", 5)),
                slot_policy: if args.bool("rotate-slots", false) {
                    SlotPolicy::RotateOffset
                } else {
                    SlotPolicy::Fill
                },
                ..Default::default()
            };
            let coord = Arc::new(MuxCoordinator::start(model, cfg)?);
            let server = Server::start(
                coord,
                ServerConfig { addr: args.str("addr", "127.0.0.1:7071"), max_connections: 64 },
            )?;
            println!("serving on {} — protocol: CLS/TOK/STATS/QUIT", server.local_addr);
            loop {
                std::thread::sleep(std::time::Duration::from_secs(60));
            }
        }
        other => {
            eprintln!("unknown command '{other}'\n{}", args.usage());
            std::process::exit(2);
        }
    }
}
