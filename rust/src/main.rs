//! `datamux` CLI: serve an artifact (or an adaptive-N router over
//! several) over TCP, or run one-shot inspection commands. Examples live
//! in examples/ — this binary is the long-running leader entrypoint.
//!
//! `--backend` picks the execution engine: `pjrt` compiles and runs the
//! artifact's HLO through the XLA CPU client; `native` runs the
//! pure-rust T-MUX forward (`runtime/native`) straight from the weights
//! blob, with no PJRT anywhere in the process.
use std::sync::Arc;

use anyhow::Result;
use datamux::coordinator::{EngineBuilder, SlotPolicy, Submit};
use datamux::runtime::native::Precision;
use datamux::runtime::{
    default_artifacts_dir, ArtifactManifest, ArtifactMeta, InferenceBackend, ModelRuntime,
    NativeBackend,
};
use datamux::util::cli::Args;

fn main() -> Result<()> {
    let args = Args::parse_env()
        .describe("cmd", "serve", "serve | list | parity")
        .describe("artifacts", "<auto>", "artifacts directory")
        .describe("artifact", "", "artifact name (default: first trained, else first)")
        .describe("backend", "pjrt", "pjrt | native (pure-rust forward, no PJRT)")
        .describe("precision", "f32", "f32 | int8 weight precision (native backend only)")
        .describe("addr", "127.0.0.1:7071", "TCP bind address for serve")
        .describe("max-connections", "64", "concurrent client connections served")
        .describe("max-wait-ms", "5", "batcher deadline")
        .describe("queue-cap", "1024", "admission queue capacity (per bucket)")
        .describe(
            "buckets",
            "",
            "sequence-length buckets, e.g. 32,64,128 (model max always included; \
             native backend only)",
        )
        .describe("rotate-slots", "false", "rotate slot assignment (paper A3)")
        .describe("adaptive", "false", "serve an adaptive-N router over every N of a profile")
        .describe("profile", "", "profile for --adaptive (default: first with most N lanes)");
    let cmd = args.str("cmd", "serve");
    let backend = args
        .choice("backend", "pjrt", &["pjrt", "native"])
        .map_err(|e| anyhow::anyhow!("{e}"))?;
    let precision = match args
        .choice("precision", "f32", &["f32", "int8"])
        .map_err(|e| anyhow::anyhow!("{e}"))?
        .as_str()
    {
        "int8" => Precision::Int8,
        _ => Precision::F32,
    };
    let dir = match args.str("artifacts", "") {
        s if s.is_empty() => default_artifacts_dir(),
        s => s.into(),
    };
    let manifest = ArtifactManifest::load(&dir)?;

    match cmd.as_str() {
        "list" => {
            println!("{} artifacts in {}", manifest.artifacts.len(), dir.display());
            for a in &manifest.artifacts {
                println!(
                    "  {:32} N={:<3} B={:<2} L={:<3} task={:<6} trained={}",
                    a.name, a.n_mux, a.batch, a.input_len, a.task, a.trained
                );
            }
            Ok(())
        }
        "parity" => {
            if backend == "native" {
                for meta in &manifest.artifacts {
                    if meta.parity.is_none() {
                        continue;
                    }
                    match NativeBackend::from_artifact(meta) {
                        Ok(model) => {
                            model.verify_parity()?;
                            println!("parity OK (native): {}", meta.name);
                        }
                        // ortho-mux / retrieval artifacts still need PJRT
                        Err(e) => println!("skipping {} (native: {e:#})", meta.name),
                    }
                }
            } else {
                let rt = ModelRuntime::cpu()?;
                for meta in &manifest.artifacts {
                    if meta.parity.is_some() {
                        rt.load(meta)?.verify_parity()?;
                        println!("parity OK: {}", meta.name);
                    }
                }
            }
            Ok(())
        }
        "serve" => {
            let builder = EngineBuilder::new()
                .max_wait_ms(args.u64("max-wait-ms", 5))
                .queue_cap(args.usize("queue-cap", 1024))
                .buckets(args.usize_list("buckets", &[]))
                .slot_policy(if args.bool("rotate-slots", false) {
                    SlotPolicy::RotateOffset
                } else {
                    SlotPolicy::Fill
                })
                .addr(args.str("addr", "127.0.0.1:7071"))
                .max_connections(args.usize("max-connections", 64))
                .precision(precision);

            // all branches produce the same trait object: the server is
            // generic over whichever engine shape (and backend) is behind it
            let engine: Arc<dyn Submit> = if args.bool("adaptive", false) {
                let profile = match args.str("profile", "") {
                    p if !p.is_empty() => p,
                    _ => best_profile(&manifest)
                        .ok_or_else(|| anyhow::anyhow!("no timing artifacts for --adaptive"))?,
                };
                let mut ns: Vec<usize> = manifest
                    .artifacts
                    .iter()
                    .filter(|a| !a.trained && a.profile == profile)
                    .map(|a| a.n_mux)
                    .collect();
                ns.sort_unstable();
                ns.dedup();
                let mut metas: Vec<ArtifactMeta> = Vec::new();
                for n in &ns {
                    let meta = manifest
                        .artifacts
                        .iter()
                        .filter(|a| !a.trained && a.profile == profile && a.n_mux == *n)
                        .min_by_key(|a| a.batch)
                        .unwrap();
                    println!(
                        "lane: {} (N={}, batch={}, backend={backend})",
                        meta.name, meta.n_mux, meta.batch
                    );
                    metas.push(meta.clone());
                }
                if backend == "native" {
                    let mut lanes: Vec<Arc<dyn InferenceBackend>> = Vec::new();
                    for meta in &metas {
                        lanes.push(Arc::new(NativeBackend::from_artifact_prec(meta, precision)?));
                    }
                    Arc::new(builder.build_router_backends(lanes)?)
                } else {
                    let rt = ModelRuntime::cpu()?;
                    let mut models = Vec::new();
                    for meta in &metas {
                        models.push(rt.load(meta)?);
                    }
                    Arc::new(builder.build_router(models)?)
                }
            } else {
                let name = args.str("artifact", "");
                let meta = if name.is_empty() {
                    manifest
                        .artifacts
                        .iter()
                        .find(|a| a.trained)
                        .or_else(|| manifest.artifacts.first())
                        .ok_or_else(|| anyhow::anyhow!("no artifacts"))?
                } else {
                    manifest
                        .find(&name)
                        .ok_or_else(|| anyhow::anyhow!("artifact '{name}' not found"))?
                };
                println!(
                    "loading {} (N={}, batch={}, backend={backend})",
                    meta.name, meta.n_mux, meta.batch
                );
                if backend == "native" {
                    Arc::new(builder.build_native(meta)?)
                } else {
                    let rt = ModelRuntime::cpu()?;
                    Arc::new(builder.build(rt.load(meta)?)?)
                }
            };

            let server = builder.serve(engine.clone())?;
            println!(
                "serving on {} — v1: CLS/TOK/STATS/QUIT, v2: line JSON \
                 (classify/tag/batch/stats, pipelined); seq-len buckets {:?}",
                server.local_addr,
                engine.buckets()
            );
            // native backends report their kernel arm + weight precision
            for line in engine.backend_info() {
                println!("backend: {line}");
            }
            // watch lane health: a dead lane stops pulling from the
            // shared queue and is reported once, loudly; the process
            // keeps serving on whatever lanes survive
            let mut dead_seen: std::collections::HashSet<usize> = Default::default();
            loop {
                std::thread::sleep(std::time::Duration::from_secs(5));
                for lane in engine.lane_status() {
                    if !lane.alive && dead_seen.insert(lane.n_mux) {
                        eprintln!(
                            "WARNING: lane N={} died after {} pulls; {} request(s) \
                             re-queued to surviving lanes",
                            lane.n_mux, lane.pulls, lane.requeued
                        );
                    }
                }
            }
        }
        other => {
            eprintln!("unknown command '{other}'\n{}", args.usage());
            std::process::exit(2);
        }
    }
}

/// The untrained profile with the most distinct N lanes (best router fit).
fn best_profile(manifest: &ArtifactManifest) -> Option<String> {
    let mut profiles: Vec<&str> = manifest
        .artifacts
        .iter()
        .filter(|a| !a.trained)
        .map(|a| a.profile.as_str())
        .collect();
    profiles.sort();
    profiles.dedup();
    profiles
        .into_iter()
        .max_by_key(|p| {
            manifest
                .artifacts
                .iter()
                .filter(|a| !a.trained && a.profile == *p)
                .map(|a| a.n_mux)
                .collect::<std::collections::HashSet<_>>()
                .len()
        })
        .map(|p| p.to_string())
}
