//! A deterministic, artifact-free [`InferenceBackend`].
//!
//! Lets the whole serving stack — batcher, scheduler, coordinator,
//! router, TCP server, both wire protocols — run end-to-end without PJRT
//! or `make artifacts`. The "model" is a pure function of the content
//! ids, so tests can verify that demultiplexed responses are routed back
//! to the right request (no crossed wires):
//!
//! * `cls`: the predicted class of a row is
//!   `sum(content ids) % n_classes` (slot prefix excluded, so the
//!   prediction is independent of which mux slot served the request).
//! * `token`: position `j` predicts `(id_j + j) % n_classes`.
//!
//! Knobs: a per-execution `delay` (to exercise queueing, deadlines and
//! backpressure) and `fail_after` (to exercise worker-death recovery).

use std::path::PathBuf;
use std::sync::atomic::{AtomicU64, Ordering};
use std::time::Duration;

use anyhow::bail;

use super::manifest::ArtifactMeta;
use super::InferenceBackend;

pub struct FakeBackend {
    meta: ArtifactMeta,
    delay: Duration,
    /// fail every run_ids call after this many successful ones
    fail_after: Option<u64>,
    calls: AtomicU64,
}

impl FakeBackend {
    /// A fake `task` ("cls" or "token") model: `input_len` is
    /// `seq_len + n_mux` (index-prefix layout, like the real artifacts).
    pub fn new(task: &str, n_mux: usize, batch: usize, seq_len: usize, n_classes: usize) -> Self {
        let meta = ArtifactMeta {
            name: format!("fake_{task}_n{n_mux}_b{batch}"),
            hlo: PathBuf::from("fake.hlo.txt"),
            weights: PathBuf::from("fake.weights.bin"),
            profile: "fake".to_string(),
            n_mux,
            seq_len,
            input_len: seq_len + n_mux,
            batch,
            d_model: 8,
            n_layers: 1,
            n_heads: 1,
            task: task.to_string(),
            n_classes,
            mux: "hadamard".to_string(),
            demux: "index_embed".to_string(),
            vocab_size: 300,
            n_weight_tensors: 0,
            trained: false,
            train_task: None,
            train_accuracy: None,
            parity: None,
        };
        FakeBackend { meta, delay: Duration::ZERO, fail_after: None, calls: AtomicU64::new(0) }
    }

    /// Sleep this long per execution (models a slow backbone).
    pub fn with_delay(mut self, delay: Duration) -> Self {
        self.delay = delay;
        self
    }

    /// Succeed `n` executions, then fail every subsequent one.
    pub fn failing_after(mut self, n: u64) -> Self {
        self.fail_after = Some(n);
        self
    }

    /// The class the fake predicts for a framed content row.
    pub fn expected_class(content: &[i32], n_classes: usize) -> usize {
        let sum: i64 = content.iter().map(|&t| t as i64).sum();
        (sum.rem_euclid(n_classes as i64)) as usize
    }

    /// The tag the fake predicts at `position` for content id `id`.
    pub fn expected_tag(id: i32, position: usize, n_classes: usize) -> usize {
        ((id as i64 + position as i64).rem_euclid(n_classes as i64)) as usize
    }
}

impl InferenceBackend for FakeBackend {
    fn meta(&self) -> &ArtifactMeta {
        &self.meta
    }

    fn run_ids(&self, ids: &[i32]) -> anyhow::Result<Vec<f32>> {
        self.run_ids_at(ids, self.meta.seq_len)
    }

    /// Shape-polymorphic: any content length up to the baked maximum.
    fn supports_seq_len(&self, seq_len: usize) -> bool {
        (1..=self.meta.seq_len).contains(&seq_len)
    }

    fn run_ids_at(&self, ids: &[i32], seq_len: usize) -> anyhow::Result<Vec<f32>> {
        let m = &self.meta;
        anyhow::ensure!(
            self.supports_seq_len(seq_len),
            "fake backend: seq_len {seq_len} outside 1..={}",
            m.seq_len
        );
        let prefix = m.input_len - m.seq_len;
        let input_len = prefix + seq_len;
        let rows = m.batch * m.n_mux;
        anyhow::ensure!(
            ids.len() == rows * input_len,
            "fake backend: ids length {} != expected {} at seq_len {seq_len}",
            ids.len(),
            rows * input_len
        );
        let n_calls = self.calls.fetch_add(1, Ordering::Relaxed);
        if let Some(limit) = self.fail_after {
            if n_calls >= limit {
                bail!("synthetic backend failure (call {} > limit {})", n_calls + 1, limit);
            }
        }
        if !self.delay.is_zero() {
            std::thread::sleep(self.delay);
        }
        let per_slot = match m.task.as_str() {
            "cls" => m.n_classes,
            "token" => seq_len * m.n_classes,
            other => bail!("fake backend: unsupported task {other}"),
        };
        let mut out = vec![0.0f32; rows * per_slot];
        for r in 0..rows {
            let content = &ids[r * input_len + prefix..(r + 1) * input_len];
            match m.task.as_str() {
                "cls" => {
                    let k = Self::expected_class(content, m.n_classes);
                    out[r * m.n_classes + k] = 1.0;
                }
                _ => {
                    let base = r * seq_len * m.n_classes;
                    for (j, &id) in content.iter().enumerate() {
                        let k = Self::expected_tag(id, j, m.n_classes);
                        out[base + j * m.n_classes + k] = 1.0;
                    }
                }
            }
        }
        Ok(out)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn cls_logits_are_content_deterministic_and_slot_independent() {
        let b = FakeBackend::new("cls", 2, 1, 4, 3);
        let m = b.meta().clone();
        // two slots with the same content but different prefixes
        let content = [1, 40, 7, 0];
        let mut ids = vec![0i32; m.ids_len()];
        for slot in 0..2 {
            let row = &mut ids[slot * m.input_len..(slot + 1) * m.input_len];
            row[0] = if slot == 0 { 4 } else { 3 };
            row[1] = if slot == 1 { 5 } else { 3 };
            row[2..].copy_from_slice(&content);
        }
        let out = b.run_ids(&ids).unwrap();
        let want = FakeBackend::expected_class(&content, 3);
        for slot in 0..2 {
            let logits = &out[slot * 3..(slot + 1) * 3];
            assert_eq!(crate::coordinator::request::argmax(logits), want, "slot {slot}");
        }
    }

    #[test]
    fn token_logits_follow_positions() {
        let b = FakeBackend::new("token", 1, 1, 3, 5);
        let m = b.meta().clone();
        let mut ids = vec![0i32; m.ids_len()];
        ids[1..].copy_from_slice(&[10, 11, 12]);
        let out = b.run_ids(&ids).unwrap();
        for j in 0..3 {
            let logits = &out[j * 5..(j + 1) * 5];
            assert_eq!(
                crate::coordinator::request::argmax(logits),
                FakeBackend::expected_tag(10 + j as i32, j, 5)
            );
        }
    }

    #[test]
    fn run_ids_at_serves_shorter_buckets_with_same_predictions() {
        // pad id is 0, so a padded-to-max row and its unpadded bucket row
        // sum identically: the prediction must not depend on the bucket
        let b = FakeBackend::new("cls", 2, 1, 8, 3);
        let m = b.meta().clone();
        let content = [1i32, 50, 7, 2]; // 4 tokens, bucket 4
        let make_ids = |seq: usize| {
            let li = m.n_mux + seq;
            let mut ids = vec![0i32; m.n_mux * li];
            for slot in 0..2 {
                let row = &mut ids[slot * li..(slot + 1) * li];
                row[..2].copy_from_slice(&[3, 3]);
                row[slot] = 4 + slot as i32;
                row[2..2 + content.len()].copy_from_slice(&content);
            }
            ids
        };
        let out_full = b.run_ids(&make_ids(8)).unwrap();
        let out_short = b.run_ids_at(&make_ids(4), 4).unwrap();
        assert_eq!(out_short.len(), 2 * 3, "cls output is bucket-independent");
        assert_eq!(out_full, out_short, "same logits at every bucket");
        // token task output shrinks with the bucket
        let t = FakeBackend::new("token", 1, 1, 8, 5);
        let ids: Vec<i32> = vec![3, 10, 11, 12];
        let out = t.run_ids_at(&ids, 3).unwrap();
        assert_eq!(out.len(), 3 * 5);
        assert!(t.run_ids_at(&ids, 9).is_err(), "beyond the baked max");
        assert!(t.run_ids_at(&ids, 0).is_err(), "zero-length bucket");
    }

    #[test]
    fn failing_after_trips() {
        let b = FakeBackend::new("cls", 1, 1, 2, 2).failing_after(1);
        let ids = vec![0i32; b.meta().ids_len()];
        assert!(b.run_ids(&ids).is_ok());
        assert!(b.run_ids(&ids).is_err());
        assert!(b.run_ids(&ids).is_err());
    }
}
