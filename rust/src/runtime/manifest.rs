//! Artifact manifest: the contract written by `python/compile/aot.py`.
//!
//! The manifest pins everything the request path must agree on with the
//! compile path: vocabulary layout, input shapes, parameter order, and
//! bit-level parity vectors for the integration tests.

use std::path::{Path, PathBuf};

use anyhow::{anyhow, bail, Context, Result};

use crate::util::json::Json;

#[derive(Debug, Clone, PartialEq)]
pub struct VocabLayout {
    pub pad: i32,
    pub cls: i32,
    pub sep: i32,
    pub eps_pad: i32,
    pub idx_base: i32,
    pub max_mux: usize,
    pub content_base: i32,
}

#[derive(Debug, Clone)]
pub struct Parity {
    /// flattened (batch, n_mux, input_len) ids
    pub ids: Vec<i32>,
    pub check_indices: Vec<usize>,
    pub check_values: Vec<f32>,
    pub output_shape: Vec<usize>,
    pub tol: f32,
}

impl Parity {
    /// Check `out` against the recorded spot values within
    /// `tol.max(tol_floor)`. Shared by every backend that claims to
    /// reproduce the compile path: PJRT uses floor 0 (bit parity),
    /// the native forward a small floor for its different summation
    /// order. `name` labels failures.
    pub fn check(&self, name: &str, out: &[f32], tol_floor: f32) -> Result<()> {
        let tol = self.tol.max(tol_floor);
        for (&i, &want) in self.check_indices.iter().zip(&self.check_values) {
            let got = *out
                .get(i)
                .ok_or_else(|| anyhow!("parity index {i} out of range {}", out.len()))?;
            if (got - want).abs() > tol {
                bail!(
                    "{name}: parity mismatch at flat index {i}: got {got}, want {want} (tol {tol})"
                );
            }
        }
        Ok(())
    }
}

#[derive(Debug, Clone)]
pub struct ArtifactMeta {
    pub name: String,
    pub hlo: PathBuf,
    pub weights: PathBuf,
    pub profile: String,
    pub n_mux: usize,
    pub seq_len: usize,
    pub input_len: usize,
    pub batch: usize,
    pub d_model: usize,
    pub n_layers: usize,
    pub n_heads: usize,
    pub task: String,
    pub n_classes: usize,
    pub mux: String,
    pub demux: String,
    pub vocab_size: usize,
    pub n_weight_tensors: usize,
    pub trained: bool,
    pub train_task: Option<String>,
    pub train_accuracy: Option<f64>,
    pub parity: Option<Parity>,
}

impl ArtifactMeta {
    /// total i32 elements in the ids input
    pub fn ids_len(&self) -> usize {
        self.batch * self.n_mux * self.input_len
    }

    /// number of logits the artifact produces
    pub fn output_len(&self) -> usize {
        match self.task.as_str() {
            "cls" => self.batch * self.n_mux * self.n_classes,
            "token" => self.batch * self.n_mux * self.seq_len * self.n_classes,
            "retrieval" => self.batch * self.n_mux * self.seq_len * self.vocab_size,
            other => panic!("unknown task {other}"),
        }
    }
}

#[derive(Debug)]
pub struct ArtifactManifest {
    pub dir: PathBuf,
    pub vocab: VocabLayout,
    pub artifacts: Vec<ArtifactMeta>,
}

fn req_usize(o: &Json, k: &str) -> Result<usize> {
    o.get(k).and_then(Json::as_usize).ok_or_else(|| anyhow!("manifest missing '{k}'"))
}

fn req_str(o: &Json, k: &str) -> Result<String> {
    Ok(o.get(k)
        .and_then(Json::as_str)
        .ok_or_else(|| anyhow!("manifest missing '{k}'"))?
        .to_string())
}

impl ArtifactManifest {
    pub fn load(dir: impl AsRef<Path>) -> Result<Self> {
        let dir = dir.as_ref().to_path_buf();
        let path = dir.join("manifest.json");
        let text = std::fs::read_to_string(&path)
            .with_context(|| format!("reading {} (run `make artifacts` first)", path.display()))?;
        Self::parse(&text, dir)
    }

    pub fn parse(text: &str, dir: PathBuf) -> Result<Self> {
        let root = Json::parse(text).map_err(|e| anyhow!("manifest: {e}"))?;
        let version = req_usize(&root, "version")?;
        if version != 1 {
            bail!("unsupported manifest version {version}");
        }
        let v = root.get("vocab").ok_or_else(|| anyhow!("manifest missing vocab"))?;
        let vocab = VocabLayout {
            pad: req_usize(v, "pad")? as i32,
            cls: req_usize(v, "cls")? as i32,
            sep: req_usize(v, "sep")? as i32,
            eps_pad: req_usize(v, "eps_pad")? as i32,
            idx_base: req_usize(v, "idx_base")? as i32,
            max_mux: req_usize(v, "max_mux")?,
            content_base: req_usize(v, "content_base")? as i32,
        };
        let mut artifacts = Vec::new();
        for a in root
            .get("artifacts")
            .and_then(Json::as_arr)
            .ok_or_else(|| anyhow!("manifest missing artifacts"))?
        {
            let parity = a.get("parity").map(|p| -> Result<Parity> {
                let ints = |k: &str| -> Result<Vec<i64>> {
                    Ok(p.get(k)
                        .and_then(Json::as_arr)
                        .ok_or_else(|| anyhow!("parity missing {k}"))?
                        .iter()
                        .filter_map(Json::as_i64)
                        .collect())
                };
                let floats = |k: &str| -> Result<Vec<f64>> {
                    Ok(p.get(k)
                        .and_then(Json::as_arr)
                        .ok_or_else(|| anyhow!("parity missing {k}"))?
                        .iter()
                        .filter_map(Json::as_f64)
                        .collect())
                };
                Ok(Parity {
                    ids: ints("ids")?.iter().map(|&x| x as i32).collect(),
                    check_indices: ints("check_indices")?.iter().map(|&x| x as usize).collect(),
                    check_values: floats("check_values")?.iter().map(|&x| x as f32).collect(),
                    output_shape: ints("output_shape")?.iter().map(|&x| x as usize).collect(),
                    tol: p.get("tol").and_then(Json::as_f64).unwrap_or(2e-4) as f32,
                })
            });
            let parity = match parity {
                Some(Ok(p)) => Some(p),
                Some(Err(e)) => return Err(e),
                None => None,
            };
            artifacts.push(ArtifactMeta {
                name: req_str(a, "name")?,
                hlo: dir.join(req_str(a, "hlo")?),
                weights: dir.join(req_str(a, "weights")?),
                profile: req_str(a, "profile")?,
                n_mux: req_usize(a, "n_mux")?,
                seq_len: req_usize(a, "seq_len")?,
                input_len: req_usize(a, "input_len")?,
                batch: req_usize(a, "batch")?,
                d_model: req_usize(a, "d_model")?,
                n_layers: req_usize(a, "n_layers")?,
                n_heads: req_usize(a, "n_heads")?,
                task: req_str(a, "task")?,
                n_classes: req_usize(a, "n_classes")?,
                mux: req_str(a, "mux")?,
                demux: req_str(a, "demux")?,
                vocab_size: req_usize(a, "vocab_size")?,
                n_weight_tensors: req_usize(a, "n_weight_tensors")?,
                trained: a.get("trained").and_then(Json::as_bool).unwrap_or(false),
                train_task: a.get("train_task").and_then(Json::as_str).map(String::from),
                train_accuracy: a.get("train_accuracy").and_then(Json::as_f64),
                parity,
            });
        }
        Ok(ArtifactManifest { dir, vocab, artifacts })
    }

    pub fn find(&self, name: &str) -> Option<&ArtifactMeta> {
        self.artifacts.iter().find(|a| a.name == name)
    }

    /// Select a timing artifact by (profile, n_mux, batch).
    pub fn timing(&self, profile: &str, n_mux: usize, batch: usize) -> Option<&ArtifactMeta> {
        self.artifacts
            .iter()
            .find(|a| !a.trained && a.profile == profile && a.n_mux == n_mux && a.batch == batch)
    }

    /// Select a trained artifact by task + n_mux.
    pub fn trained(&self, task: &str, n_mux: usize) -> Option<&ArtifactMeta> {
        self.artifacts
            .iter()
            .find(|a| a.trained && a.train_task.as_deref() == Some(task) && a.n_mux == n_mux)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const SAMPLE: &str = r#"{
      "version": 1,
      "vocab": {"pad":0,"cls":1,"sep":2,"eps_pad":3,"idx_base":4,"max_mux":40,"content_base":44},
      "artifacts": [{
        "name": "timing_tiny_n2_b1", "hlo": "t.hlo.txt", "weights": "t.weights.bin",
        "profile": "tiny", "n_mux": 2, "seq_len": 16, "input_len": 18, "batch": 1,
        "d_model": 128, "n_layers": 2, "n_heads": 4, "task": "cls", "n_classes": 3,
        "mux": "hadamard", "demux": "index_embed", "vocab_size": 300,
        "n_weight_tensors": 30, "trained": false,
        "parity": {"ids": [1,2,3], "check_indices": [0], "check_values": [0.5],
                   "output_shape": [1,2,3], "tol": 0.0002}
      }]
    }"#;

    #[test]
    fn parses_sample() {
        let m = ArtifactManifest::parse(SAMPLE, PathBuf::from("/tmp/x")).unwrap();
        assert_eq!(m.vocab.content_base, 44);
        let a = m.find("timing_tiny_n2_b1").unwrap();
        assert_eq!(a.n_mux, 2);
        assert_eq!(a.ids_len(), 18 * 2);
        assert_eq!(a.output_len(), 6);
        assert_eq!(a.parity.as_ref().unwrap().ids, vec![1, 2, 3]);
        assert!(m.timing("tiny", 2, 1).is_some());
        assert!(m.timing("tiny", 3, 1).is_none());
        assert!(m.trained("mnli", 2).is_none());
    }

    #[test]
    fn parity_check_spots_mismatches_and_honors_tol_floor() {
        let m = ArtifactManifest::parse(SAMPLE, PathBuf::from("/tmp")).unwrap();
        let p = m.artifacts[0].parity.as_ref().unwrap();
        // check index 0 expects 0.5 within tol 2e-4
        assert!(p.check("x", &[0.5001], 0.0).is_ok());
        assert!(p.check("x", &[0.501], 0.0).is_err());
        assert!(p.check("x", &[0.501], 1e-2).is_ok(), "floor widens tol");
        assert!(p.check("x", &[], 0.0).is_err(), "index out of range");
    }

    #[test]
    fn rejects_wrong_version() {
        let bad = SAMPLE.replace("\"version\": 1", "\"version\": 9");
        assert!(ArtifactManifest::parse(&bad, PathBuf::from("/tmp")).is_err());
    }

    #[test]
    fn token_output_len() {
        let m = ArtifactManifest::parse(SAMPLE, PathBuf::from("/tmp")).unwrap();
        let mut a = m.artifacts[0].clone();
        a.task = "token".into();
        assert_eq!(a.output_len(), 1 * 2 * 16 * 3);
    }
}
