//! L3 runtime: loading and execution of the AOT artifacts.
//!
//! `manifest` parses the registry written by `python/compile/aot.py`,
//! `weights` the binary tensor blobs, `model` wraps the `xla` crate
//! (PJRT CPU client) to compile HLO text and execute with device-resident
//! weights (see `/opt/xla-example/` for the reference wiring), and
//! `native` executes the T-MUX forward pass in pure rust directly from
//! the weights blob — real math with no PJRT dependency.

pub mod fake;
pub mod manifest;
pub mod model;
pub mod native;
pub mod weights;

pub use fake::FakeBackend;
pub use manifest::{ArtifactManifest, ArtifactMeta, Parity, VocabLayout};
pub use model::{default_artifacts_dir, LoadedModel, ModelRuntime};
pub use native::{NativeBackend, RawWeights};
pub use weights::WeightsFile;

/// Anything the coordinator can execute a mux group on.
///
/// Implemented by the PJRT-backed
/// [`SharedModel`](crate::coordinator::SharedModel), by the pure-rust
/// [`NativeBackend`] (real math, no PJRT), and by [`FakeBackend`]
/// (deterministic, artifact-free — used by tests and demos). The
/// coordinator only ever calls these two methods on the hot path.
pub trait InferenceBackend: Send + Sync {
    /// Shape / task metadata the engine must agree on with the model.
    /// `meta().seq_len` is the *maximum* sequence length; shape-
    /// polymorphic backends also execute shorter bucketed shapes (see
    /// [`InferenceBackend::run_ids_at`]).
    fn meta(&self) -> &ArtifactMeta;

    /// Execute on raw token ids (flattened `(batch, n_mux, input_len)`),
    /// returning flattened f32 logits of length `meta().output_len()`.
    fn run_ids(&self, ids: &[i32]) -> anyhow::Result<Vec<f32>>;

    /// One-line human description of this backend for startup output and
    /// stats endpoints. Backends with interesting execution detail (the
    /// native backend reports its GEMM kernel and weight precision)
    /// override this; the default just names the model.
    fn describe(&self) -> String {
        format!("{} (N={})", self.meta().name, self.meta().n_mux)
    }

    /// Can this backend execute a wave whose content rows are `seq_len`
    /// tokens long? Compiled backends (PJRT) bake one shape, so the
    /// default accepts only `meta().seq_len`; the native and fake
    /// backends accept any `1..=meta().seq_len` — that is what lets the
    /// scheduler run sequence-length buckets.
    fn supports_seq_len(&self, seq_len: usize) -> bool {
        seq_len == self.meta().seq_len
    }

    /// Execute at a runtime sequence length: `ids` is the flattened
    /// `(batch, n_mux, prefix_len + seq_len)` tensor and the result has
    /// `batch * n_mux * demux_len(seq_len) * n_classes` logits. The
    /// default only serves the baked shape and delegates to
    /// [`InferenceBackend::run_ids`].
    fn run_ids_at(&self, ids: &[i32], seq_len: usize) -> anyhow::Result<Vec<f32>> {
        anyhow::ensure!(
            seq_len == self.meta().seq_len,
            "{}: backend only executes its baked seq_len {} (asked for {seq_len})",
            self.meta().name,
            self.meta().seq_len
        );
        self.run_ids(ids)
    }

    /// Cumulative per-stage execution time as `(stage, ns)` pairs, for
    /// backends that instrument their forward (the native backend
    /// reports mux/qkv/attention/ffn/head). Stats endpoints and benches
    /// read this for Amdahl analysis; the default reports no detail.
    fn stage_ns(&self) -> Vec<(&'static str, u64)> {
        Vec::new()
    }
}
