//! L3 runtime: PJRT loading and execution of the AOT artifacts.
//!
//! `manifest` parses the registry written by `python/compile/aot.py`,
//! `weights` the binary tensor blobs, and `model` wraps the `xla` crate
//! (PJRT CPU client) to compile HLO text and execute with device-resident
//! weights. See `/opt/xla-example/` for the reference wiring this adapts.

pub mod manifest;
pub mod model;
pub mod weights;

pub use manifest::{ArtifactManifest, ArtifactMeta, Parity, VocabLayout};
pub use model::{default_artifacts_dir, LoadedModel, ModelRuntime};
pub use weights::WeightsFile;
