//! PJRT model runtime: load HLO-text artifacts, keep weights device-
//! resident, execute from the serving hot path.
//!
//! One `ModelRuntime` per process (owns the PJRT CPU client); one
//! `LoadedModel` per artifact (compiled executable + uploaded weight
//! buffers). `run_ids` is the only thing the coordinator calls per
//! request group — weights are never re-uploaded.

use std::path::Path;
use std::time::Instant;

use anyhow::{anyhow, bail, Context, Result};

use super::manifest::{ArtifactManifest, ArtifactMeta};
use super::weights::WeightsFile;

pub struct ModelRuntime {
    client: xla::PjRtClient,
}

impl ModelRuntime {
    /// Create the PJRT CPU client (the process-wide device handle).
    pub fn cpu() -> Result<Self> {
        let client = xla::PjRtClient::cpu().map_err(|e| anyhow!("pjrt cpu client: {e:?}"))?;
        Ok(ModelRuntime { client })
    }

    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    /// Load + compile one artifact and upload its weights.
    pub fn load(&self, meta: &ArtifactMeta) -> Result<LoadedModel> {
        let t0 = Instant::now();
        let proto = xla::HloModuleProto::from_text_file(
            meta.hlo.to_str().context("non-utf8 path")?,
        )
        .map_err(|e| anyhow!("parsing {}: {e:?}", meta.hlo.display()))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self
            .client
            .compile(&comp)
            .map_err(|e| anyhow!("compiling {}: {e:?}", meta.name))?;
        let compile_time = t0.elapsed();

        let t1 = Instant::now();
        let wf = WeightsFile::load(&meta.weights)?;
        if wf.tensors.len() != meta.n_weight_tensors {
            bail!(
                "{}: weights file has {} tensors, manifest says {}",
                meta.name,
                wf.tensors.len(),
                meta.n_weight_tensors
            );
        }
        let mut weight_bufs = Vec::with_capacity(wf.tensors.len());
        for i in 0..wf.tensors.len() {
            let data = wf.tensor_f32(i)?;
            let dims = wf.tensors[i].shape.clone();
            let buf = self
                .client
                .buffer_from_host_buffer::<f32>(&data, &dims, None)
                .map_err(|e| anyhow!("uploading {}: {e:?}", wf.tensors[i].name))?;
            weight_bufs.push(buf);
        }
        let upload_time = t1.elapsed();

        Ok(LoadedModel {
            meta: meta.clone(),
            exe,
            weight_bufs,
            client: self.client.clone(),
            weight_bytes: wf.total_bytes(),
            compile_time,
            upload_time,
        })
    }

    /// Load every artifact in a manifest (used by integration tests).
    pub fn load_all(&self, manifest: &ArtifactManifest) -> Result<Vec<LoadedModel>> {
        manifest.artifacts.iter().map(|m| self.load(m)).collect()
    }
}

/// A compiled model with device-resident weights.
pub struct LoadedModel {
    pub meta: ArtifactMeta,
    exe: xla::PjRtLoadedExecutable,
    weight_bufs: Vec<xla::PjRtBuffer>,
    client: xla::PjRtClient,
    pub weight_bytes: usize,
    pub compile_time: std::time::Duration,
    pub upload_time: std::time::Duration,
}

impl LoadedModel {
    /// Execute on raw token ids (flattened (batch, n_mux, input_len)).
    /// Returns the flattened f32 logits.
    pub fn run_ids(&self, ids: &[i32]) -> Result<Vec<f32>> {
        if ids.len() != self.meta.ids_len() {
            bail!(
                "{}: ids length {} != expected {} (batch {} x n_mux {} x input_len {})",
                self.meta.name,
                ids.len(),
                self.meta.ids_len(),
                self.meta.batch,
                self.meta.n_mux,
                self.meta.input_len
            );
        }
        let ids_buf = self
            .client
            .buffer_from_host_buffer::<i32>(
                ids,
                &[self.meta.batch, self.meta.n_mux, self.meta.input_len],
                None,
            )
            .map_err(|e| anyhow!("uploading ids: {e:?}"))?;
        let mut args: Vec<&xla::PjRtBuffer> = self.weight_bufs.iter().collect();
        args.push(&ids_buf);
        let outs = self
            .exe
            .execute_b(&args)
            .map_err(|e| anyhow!("executing {}: {e:?}", self.meta.name))?;
        let lit = outs[0][0]
            .to_literal_sync()
            .map_err(|e| anyhow!("fetching output: {e:?}"))?;
        // lowered with return_tuple=True -> unwrap the 1-tuple
        let out = lit.to_tuple1().map_err(|e| anyhow!("untuple: {e:?}"))?;
        let v = out.to_vec::<f32>().map_err(|e| anyhow!("to_vec: {e:?}"))?;
        if v.len() != self.meta.output_len() {
            bail!(
                "{}: output length {} != expected {}",
                self.meta.name,
                v.len(),
                self.meta.output_len()
            );
        }
        Ok(v)
    }

    /// Run the manifest's parity vector and verify bit-level agreement
    /// with the python compile path (within the blob's own tol).
    pub fn verify_parity(&self) -> Result<()> {
        let parity = self
            .meta
            .parity
            .as_ref()
            .ok_or_else(|| anyhow!("{} has no parity blob", self.meta.name))?;
        let out = self.run_ids(&parity.ids)?;
        parity.check(&self.meta.name, &out, 0.0)
    }

    /// Rough device-memory footprint of this model (weights + one io set),
    /// used by the fig12 memory bench.
    pub fn approx_device_bytes(&self) -> usize {
        self.weight_bytes + self.meta.ids_len() * 4 + self.meta.output_len() * 4
    }
}

/// Helper: find artifacts dir relative to the repo root (cwd or parents).
pub fn default_artifacts_dir() -> std::path::PathBuf {
    let mut dir = std::env::current_dir().unwrap_or_else(|_| Path::new(".").to_path_buf());
    loop {
        let cand = dir.join("artifacts");
        if cand.join("manifest.json").exists() {
            return cand;
        }
        if !dir.pop() {
            return Path::new("artifacts").to_path_buf();
        }
    }
}
