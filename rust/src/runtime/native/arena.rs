//! Per-worker tensor arenas for the native forward.
//!
//! Every intermediate of one forward — residual stream, fused QKV,
//! flash-attention tile scratch, FFN hidden, demux activations — lives
//! in a [`Workspace`]
//! whose buffers are sized from the *runtime* shape of the call: since
//! the forward became shape-polymorphic, the pool is keyed on the
//! sequence-length bucket, and a checkout only reuses a workspace built
//! for the same bucket (buffer sizes are exact, not sliced — `forward`
//! walks whole buffers with `chunks_exact`). Each concurrent caller
//! settles on one arena **per bucket it serves**, so a mixed-bucket
//! serving loop still allocates no tensors after per-bucket warmup. The
//! [`ArenaPool::reallocs`] counter is the native analogue of the
//! scheduler's `scratch_reallocs` invariant: it moves only while new
//! `(bucket, worker)` arenas are being materialized, and the
//! `native_forward` / `shape_buckets` benches gate on it staying flat
//! after warmup.

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Mutex, PoisonError};

use super::Dims;

/// All intermediate tensors of one forward, allocated once.
pub(crate) struct Workspace {
    /// residual stream, `(batch * input_len, d_model)`
    pub x: Vec<f32>,
    /// layer-normed input / final hidden states, same shape as `x`
    pub ln: Vec<f32>,
    /// fused QKV projections, `(batch * input_len, 3 * d_model)` with each
    /// row laid out `[q | k | v]` — one GEMM output, consumed in place by
    /// the flash-attention kernel
    pub qkv: Vec<f32>,
    /// attention context (heads merged), same shape as `x`
    pub ctx: Vec<f32>,
    /// projection output added back into the residual stream
    pub proj: Vec<f32>,
    /// per-(batch, head) flash-attention score tiles,
    /// `(batch * n_heads, ATTN_TILE)` — constant in `input_len`, replacing
    /// the old quadratic `(batch * n_heads, input_len, input_len)` scores
    pub attn_tile: Vec<f32>,
    /// FFN hidden, `(batch * input_len, d_ff)`
    pub ffh: Vec<f32>,
    /// demux prefix projections, `(batch * n_mux, d_demux)`
    pub pproj: Vec<f32>,
    /// demux content projections, `(batch * demux_len, d_demux)`
    pub hproj: Vec<f32>,
    /// demux MLP hidden, `(batch * n_mux * demux_len, d_demux)`
    pub z: Vec<f32>,
    /// demultiplexed hidden states, `(batch * n_mux * demux_len, d_model)`
    pub dem: Vec<f32>,
    /// int8 path: biased-u8 activation codes, sized for the largest
    /// quantized GEMM input (residual stream, FFN hidden, or demux z)
    pub aq: Vec<u8>,
    /// int8 path: per-row activation scales, one per row of `aq`
    pub ascale: Vec<f32>,
}

impl Workspace {
    fn new(d: &Dims) -> Workspace {
        let stream = d.rows() * d.d_model;
        let lp = d.demux_len();
        Workspace {
            x: vec![0.0; stream],
            ln: vec![0.0; stream],
            qkv: vec![0.0; 3 * stream],
            ctx: vec![0.0; stream],
            proj: vec![0.0; stream],
            attn_tile: vec![0.0; d.batch * d.n_heads * super::simd::ATTN_TILE],
            ffh: vec![0.0; d.rows() * d.d_ff],
            pproj: vec![0.0; d.batch * d.n_mux * d.d_demux],
            hproj: vec![0.0; d.batch * lp * d.d_demux],
            z: vec![0.0; d.batch * d.n_mux * lp * d.d_demux],
            dem: vec![0.0; d.batch * d.n_mux * lp * d.d_model],
            aq: vec![0; stream.max(d.rows() * d.d_ff).max(d.batch * d.n_mux * lp * d.d_demux)],
            ascale: vec![0.0; d.rows().max(d.batch * d.n_mux * lp)],
        }
    }

    /// Total heap bytes a workspace for `d` occupies, computed analytically
    /// (mirrors [`Workspace::new`] — kept in lockstep by
    /// `workspace_bytes_match_allocated_buffers`). The `native_forward`
    /// bench uses this to gate that attention memory scales *linearly* in
    /// `input_len` now that the quadratic scores block is gone.
    pub fn bytes_for(d: &Dims) -> usize {
        let stream = d.rows() * d.d_model;
        let lp = d.demux_len();
        let f32s = stream // x
            + stream // ln
            + 3 * stream // qkv
            + stream // ctx
            + stream // proj
            + d.batch * d.n_heads * super::simd::ATTN_TILE // attn_tile
            + d.rows() * d.d_ff // ffh
            + d.batch * d.n_mux * d.d_demux // pproj
            + d.batch * lp * d.d_demux // hproj
            + d.batch * d.n_mux * lp * d.d_demux // z
            + d.batch * d.n_mux * lp * d.d_model // dem
            + d.rows().max(d.batch * d.n_mux * lp); // ascale
        let aq = stream.max(d.rows() * d.d_ff).max(d.batch * d.n_mux * lp * d.d_demux);
        f32s * std::mem::size_of::<f32>() + aq
    }
}

/// Reusable [`Workspace`] pool keyed on the sequence-length bucket: one
/// workspace per (bucket, concurrent caller) after warmup.
pub(crate) struct ArenaPool {
    /// `(bucket seq_len, workspace)` — small linear scan; bucket counts
    /// are single digits
    free: Mutex<Vec<(usize, Workspace)>>,
    materializations: AtomicU64,
}

impl ArenaPool {
    #[allow(clippy::new_without_default)]
    pub fn new() -> ArenaPool {
        ArenaPool { free: Mutex::new(Vec::new()), materializations: AtomicU64::new(0) }
    }

    /// Pop a reusable workspace built for `dims.seq_len`, or materialize
    /// a new one (counted).
    pub fn checkout(&self, dims: &Dims) -> Workspace {
        {
            // a poisoning panic can only come from a forward that died
            // mid-flight; the freelist itself is always consistent
            let mut free = self.free.lock().unwrap_or_else(PoisonError::into_inner);
            if let Some(i) = free.iter().position(|(l, _)| *l == dims.seq_len) {
                return free.swap_remove(i).1;
            }
        }
        self.materializations.fetch_add(1, Ordering::Relaxed);
        Workspace::new(dims)
    }

    pub fn give_back(&self, seq_len: usize, ws: Workspace) {
        self.free.lock().unwrap_or_else(PoisonError::into_inner).push((seq_len, ws));
    }

    /// Arenas materialized so far. Flat after per-bucket warmup is the
    /// allocation-free steady-state invariant the benches enforce.
    pub fn reallocs(&self) -> u64 {
        self.materializations.load(Ordering::Relaxed)
    }
}

#[cfg(test)]
mod tests {
    use super::super::{Dims, NativeTask};
    use super::Workspace;

    fn dims(seq_len: usize) -> Dims {
        let n_mux = 4;
        Dims {
            batch: 2,
            n_mux,
            seq_len,
            prefix_len: n_mux,
            input_len: n_mux + seq_len,
            vocab_size: 300,
            d_model: 32,
            n_layers: 2,
            n_heads: 4,
            d_head: 8,
            d_ff: 128,
            d_demux: 64,
            n_classes: 3,
            task: NativeTask::Cls,
        }
    }

    #[test]
    fn workspace_bytes_match_allocated_buffers() {
        for seq_len in [1usize, 5, 16] {
            let d = dims(seq_len);
            let ws = Workspace::new(&d);
            let f32s = ws.x.len()
                + ws.ln.len()
                + ws.qkv.len()
                + ws.ctx.len()
                + ws.proj.len()
                + ws.attn_tile.len()
                + ws.ffh.len()
                + ws.pproj.len()
                + ws.hproj.len()
                + ws.z.len()
                + ws.dem.len()
                + ws.ascale.len();
            assert_eq!(Workspace::bytes_for(&d), f32s * 4 + ws.aq.len(), "seq_len={seq_len}");
        }
    }

    #[test]
    fn workspace_bytes_are_linear_in_input_len() {
        // three equally spaced seq lens: exactly collinear byte counts now
        // that the quadratic scores block is gone (cls task — every buffer
        // is degree-1 in input_len)
        let (b1, b2, b3) = (
            Workspace::bytes_for(&dims(4)),
            Workspace::bytes_for(&dims(10)),
            Workspace::bytes_for(&dims(16)),
        );
        assert_eq!(b2 - b1, b3 - b2, "workspace growth is not linear in li");
        assert!(b3 > b2 && b2 > b1);
    }
}
