//! The fused native T-MUX forward pass.
//!
//! Mirrors `python/compile/model.py::forward_task` exactly (pre-LN
//! encoder, tanh-approximate GELU, index-embedding demux, per-task
//! head), with the serving-side optimizations:
//!
//! * **Fused mux** — the per-slot transformed embeddings
//!   `phi^i(emb^i)` are never materialized. Each combined row is
//!   accumulated directly from the token gather:
//!   `x[b,l] = pos_mux[l] + Σ_s tok[ids[b,s,l]] ⊙ (vecs[s]/N)`, where
//!   `pos_mux` pre-folds the positional table with the mux mean (the
//!   shared positional add commutes with the mean over slots). The
//!   gather is row-banded across the thread pool and FMA-vectorized.
//! * **Fused QKV** — one `(d, 3d)` GEMM over the normed stream replaces
//!   three `(d, d)` projections; the activation row is quantized once
//!   and read once on the int8 path.
//! * **Flash-style attention** — per-(batch, head) jobs stream K/V
//!   tiles through an online-softmax accumulator
//!   ([`super::simd::flash_attn_row_scalar`] /
//!   [`super::simd::flash_attn_row_avx2`]); no `li×li` scores block is
//!   ever materialized, so attention scratch is linear in `input_len`.
//! * **Blocked GEMM** over pre-transposed weights for every projection
//!   ([`super::gemm`]), row-banded across the thread pool.
//! * **CLS-only demux** for classification (`demux_len = 1`), matching
//!   the compile path's `forward_task`.
//!
//! Every intermediate *tensor* lives in the caller's [`Workspace`] — no
//! tensor allocation happens per call beyond the returned logits vector
//! the [`InferenceBackend`](crate::runtime::InferenceBackend) API
//! mandates. (When the thread pool is active, each fork-join does a few
//! small bookkeeping allocations — latch + boxed jobs — which is what
//! the `arena_reallocs` gate deliberately does *not* count.)

#![allow(clippy::needless_range_loop)]

use std::time::Instant;

use anyhow::{bail, Result};

use super::arena::Workspace;
use super::gemm::{gemm_bt_pooled, gemm_bt_q8_pooled, parallel_for, SendMut};
use super::pack::{Mat, PackedWeights};
use super::{quant, Dims, StageTimers};
use crate::util::threadpool::ThreadPool;

/// sqrt(2/pi) — the tanh-approximate GELU constant jax.nn.gelu uses.
pub(crate) const GELU_C: f32 = 0.797_884_6;

/// Minimum gather mul-adds (`rows * n_mux * d_model`) before the fused
/// mux gather is worth a fork-join across the pool.
const GATHER_PAR_MIN_MACS: usize = 1 << 14;

#[inline]
pub(crate) fn gelu(x: f32) -> f32 {
    0.5 * x * (1.0 + (GELU_C * (x + 0.044_715 * x * x * x)).tanh())
}

/// GELU over a whole buffer, vectorized when the AVX2 kernel is active.
// lint: hot-path
pub(crate) fn gelu_buf(xs: &mut [f32]) {
    #[cfg(target_arch = "x86_64")]
    if super::simd::active_kernel() == super::simd::Kernel::Avx2Fma {
        // SAFETY: feature presence was verified by `active_kernel`.
        unsafe { super::simd::gelu_avx2(xs) };
        return;
    }
    for v in xs.iter_mut() {
        *v = gelu(*v);
    }
}

/// One projection at the weight's precision: f32 mats run the f32 GEMM
/// on `a`; int8 mats run the quantized GEMM on the codes `aq`/`ascale`
/// that [`quant_rows_if`] prepared from the same `a`. `aq`/`ascale` may
/// be oversized tails of the shared workspace scratch.
#[allow(clippy::too_many_arguments)]
fn run_mat(
    pool: Option<&ThreadPool>,
    w: &Mat,
    a: &[f32],
    aq: &[u8],
    ascale: &[f32],
    bias: Option<&[f32]>,
    c: &mut [f32],
    m: usize,
    k: usize,
    n: usize,
) {
    match w {
        Mat::F32(wt) => gemm_bt_pooled(pool, &a[..m * k], wt, bias, c, m, k, n),
        Mat::Q8(qm) => gemm_bt_q8_pooled(pool, &aq[..m * k], &ascale[..m], qm, bias, c, m, k, n),
    }
}

/// Quantize `m` rows of `a` into the workspace scratch iff the matrix
/// they will multiply is int8 (no-op on the f32 path).
fn quant_rows_if(w: &Mat, a: &[f32], m: usize, k: usize, aq: &mut [u8], ascale: &mut [f32]) {
    if matches!(w, Mat::Q8(_)) {
        quant::quantize_rows(&a[..m * k], m, k, aq, ascale);
    }
}

/// Row-wise layer norm (eps 1e-5, matching `model.py::_layer_norm`),
/// vectorized when the AVX2 kernel is active.
// lint: hot-path
pub(crate) fn layer_norm(src: &[f32], g: &[f32], b: &[f32], dst: &mut [f32], d: usize) {
    #[cfg(target_arch = "x86_64")]
    if super::simd::active_kernel() == super::simd::Kernel::Avx2Fma {
        // SAFETY: feature presence was verified by `active_kernel`;
        // src/dst are equal-length whole-row buffers and g/b hold d
        // floats by the callers' shapes.
        unsafe { super::simd::layer_norm_avx2(src, g, b, dst, d) };
        return;
    }
    layer_norm_scalar(src, g, b, dst, d);
}

/// Scalar layer-norm arm (also the reference the AVX2 arm is tested
/// against).
// lint: hot-path
pub(crate) fn layer_norm_scalar(src: &[f32], g: &[f32], b: &[f32], dst: &mut [f32], d: usize) {
    for (srow, drow) in src.chunks_exact(d).zip(dst.chunks_exact_mut(d)) {
        let mean = srow.iter().sum::<f32>() / d as f32;
        let mut var = 0.0f32;
        for &v in srow {
            var += (v - mean) * (v - mean);
        }
        var /= d as f32;
        let inv = 1.0 / (var + 1e-5).sqrt();
        for i in 0..d {
            drow[i] = (srow[i] - mean) * inv * g[i] + b[i];
        }
    }
}

/// Residual add `dst += src`, vectorized when the AVX2 kernel is active
/// (bitwise identical across arms — pure elementwise addition).
// lint: hot-path
fn add_assign_buf(dst: &mut [f32], src: &[f32]) {
    #[cfg(target_arch = "x86_64")]
    if super::simd::active_kernel() == super::simd::Kernel::Avx2Fma {
        // SAFETY: feature presence was verified by `active_kernel`;
        // src is at least as long as dst (same stream shape).
        unsafe { super::simd::add_assign_avx2(dst, src) };
        return;
    }
    for (x, p) in dst.iter_mut().zip(src) {
        *x += p;
    }
}

/// `dst[i] += a[i] * b[i]` over one row — the mux accumulate —
/// vectorized when the AVX2 kernel is active.
// lint: hot-path
fn fmadd_buf(dst: &mut [f32], a: &[f32], b: &[f32]) {
    #[cfg(target_arch = "x86_64")]
    if super::simd::active_kernel() == super::simd::Kernel::Avx2Fma {
        // SAFETY: feature presence was verified by `active_kernel`; the
        // callers slice a and b to exactly dst.len() elements.
        unsafe { super::simd::fmadd_buf_avx2(dst, a, b) };
        return;
    }
    for i in 0..dst.len() {
        dst[i] += a[i] * b[i];
    }
}

/// Nanoseconds since `mark`, advancing `mark` to now — the per-stage
/// lap counter.
#[inline]
fn lap(mark: &mut Instant) -> u64 {
    let now = Instant::now();
    let ns = now.duration_since(*mark).as_nanos() as u64;
    *mark = now;
    ns
}

/// One full forward: `ids` flattened `(batch, n_mux, input_len)` →
/// flattened logits (`(B, N, C)` for cls, `(B, N, L, C)` for token).
pub(crate) fn forward(
    w: &PackedWeights,
    tok: &[f32],
    dims: &Dims,
    pool: Option<&ThreadPool>,
    ids: &[i32],
    ws: &mut Workspace,
    timers: &StageTimers,
) -> Result<Vec<f32>> {
    let d = dims.d_model;
    let li = dims.input_len;
    let b = dims.batch;
    let n = dims.n_mux;
    let rows = dims.rows();
    for (i, &t) in ids.iter().enumerate() {
        if t < 0 || t as usize >= dims.vocab_size {
            bail!("token id {t} at flat index {i} out of range 0..{}", dims.vocab_size);
        }
    }
    let mut mark = Instant::now();

    // ---- fused mux + embedding gather -----------------------------------
    {
        let xptr = SendMut(ws.x.as_mut_ptr());
        let gather_rows = |r0: usize, r1: usize| {
            for row_i in r0..r1 {
                let (bb, l) = (row_i / li, row_i % li);
                // SAFETY: each band owns rows r0..r1 of `ws.x`
                // exclusively — the bands partition 0..rows — and the
                // dispatch below joins before the borrow of `ws.x`
                // resumes.
                let row = unsafe { std::slice::from_raw_parts_mut(xptr.0.add(row_i * d), d) };
                row.copy_from_slice(&w.pos_mux[l * d..(l + 1) * d]);
                for slot in 0..n {
                    let id = ids[(bb * n + slot) * li + l] as usize;
                    let emb = &tok[id * d..(id + 1) * d];
                    let vec = &w.mux_scaled[slot * d..(slot + 1) * d];
                    fmadd_buf(row, emb, vec);
                }
            }
        };
        match pool {
            Some(p) if rows > 1 && rows * n * d >= GATHER_PAR_MIN_MACS => {
                // balanced band split, same scheme as the pooled GEMMs —
                // banding never changes per-row arithmetic, so results
                // stay bitwise identical to the serial path
                let bands = p.n_workers().min(rows);
                let base = rows / bands;
                let extra = rows % bands;
                parallel_for(p, bands, |band| {
                    let r0 = band * base + band.min(extra);
                    let r1 = r0 + base + usize::from(band < extra);
                    gather_rows(r0, r1);
                });
            }
            _ => gather_rows(0, rows),
        }
    }
    let ns_mux = lap(&mut mark);

    // ---- pre-LN transformer encoder -------------------------------------
    let heads = dims.n_heads;
    let dh = dims.d_head;
    let d3 = 3 * d;
    let scale = 1.0 / (dh as f32).sqrt();
    #[cfg(target_arch = "x86_64")]
    let use_avx2 = super::simd::active_kernel() == super::simd::Kernel::Avx2Fma;
    let mut ns_qkv = 0u64;
    let mut ns_attn = 0u64;
    let mut ns_ffn = 0u64;
    for lp in &w.layers {
        layer_norm(&ws.x, &lp.ln1_g, &lp.ln1_b, &mut ws.ln, d);
        // one quantization of the normed stream, one fused GEMM for Q|K|V
        quant_rows_if(&lp.wqkv_t, &ws.ln, rows, d, &mut ws.aq, &mut ws.ascale);
        run_mat(
            pool,
            &lp.wqkv_t,
            &ws.ln,
            &ws.aq,
            &ws.ascale,
            Some(&lp.bqkv),
            &mut ws.qkv,
            rows,
            d,
            d3,
        );
        ns_qkv += lap(&mut mark);
        {
            // flash attention fans out over (batch, head): each pair owns
            // its score tile and a disjoint column stripe of ctx
            let tptr = SendMut(ws.attn_tile.as_mut_ptr());
            let cptr = SendMut(ws.ctx.as_mut_ptr());
            let qkv = &ws.qkv;
            let tile = super::simd::ATTN_TILE;
            let run = |bh: usize| {
                let (bb, hh) = (bh / heads, bh % heads);
                // SAFETY: each (batch, head) job owns score tile `bh`
                // exclusively, and the dispatch below joins before the
                // borrow of `ws.attn_tile` resumes.
                let stile = unsafe { std::slice::from_raw_parts_mut(tptr.0.add(bh * tile), tile) };
                let kbase = bb * li * d3 + d + hh * dh;
                let vbase = bb * li * d3 + 2 * d + hh * dh;
                for i in 0..li {
                    let qoff = (bb * li + i) * d3 + hh * dh;
                    // SAFETY: head `hh` writes only its own `dh`-wide
                    // column stripe of ctx row `bb*li + i` — disjoint
                    // across jobs, joined before the borrow resumes.
                    let crow = unsafe {
                        std::slice::from_raw_parts_mut(cptr.0.add((bb * li + i) * d + hh * dh), dh)
                    };
                    #[cfg(target_arch = "x86_64")]
                    if use_avx2 {
                        // SAFETY: AVX2+FMA presence was verified by
                        // `active_kernel`; qoff/kbase/vbase address head
                        // slices of qkv rows, all within `rows * 3d`.
                        unsafe {
                            super::simd::flash_attn_row_avx2(
                                qkv, qoff, kbase, vbase, d3, li, dh, scale, stile, crow,
                            )
                        };
                        continue;
                    }
                    super::simd::flash_attn_row_scalar(
                        qkv, qoff, kbase, vbase, d3, li, dh, scale, stile, crow,
                    );
                }
            };
            match pool {
                Some(p) if b * heads > 1 => parallel_for(p, b * heads, run),
                _ => {
                    for bh in 0..b * heads {
                        run(bh);
                    }
                }
            }
        }
        ns_attn += lap(&mut mark);
        quant_rows_if(&lp.wo_t, &ws.ctx, rows, d, &mut ws.aq, &mut ws.ascale);
        run_mat(pool, &lp.wo_t, &ws.ctx, &ws.aq, &ws.ascale, Some(&lp.bo), &mut ws.proj, rows, d, d);
        add_assign_buf(&mut ws.x, &ws.proj);
        layer_norm(&ws.x, &lp.ln2_g, &lp.ln2_b, &mut ws.ln, d);
        quant_rows_if(&lp.ff1_t, &ws.ln, rows, d, &mut ws.aq, &mut ws.ascale);
        run_mat(
            pool,
            &lp.ff1_t,
            &ws.ln,
            &ws.aq,
            &ws.ascale,
            Some(&lp.fb1),
            &mut ws.ffh,
            rows,
            d,
            dims.d_ff,
        );
        gelu_buf(&mut ws.ffh);
        quant_rows_if(&lp.ff2_t, &ws.ffh, rows, dims.d_ff, &mut ws.aq, &mut ws.ascale);
        run_mat(
            pool,
            &lp.ff2_t,
            &ws.ffh,
            &ws.aq,
            &ws.ascale,
            Some(&lp.fb2),
            &mut ws.proj,
            rows,
            dims.d_ff,
            d,
        );
        add_assign_buf(&mut ws.x, &ws.proj);
        ns_ffn += lap(&mut mark);
    }
    // final hidden states land in ws.ln
    layer_norm(&ws.x, &w.lnf_g, &w.lnf_b, &mut ws.ln, d);

    // ---- index-embedding demux + head -----------------------------------
    let fd = dims.d_demux;
    let lp_out = dims.demux_len();
    let prefix = dims.prefix_len;
    // one quantization of the full final-LN stream serves both the
    // prefix (w1p) and content (w1h) projections via row offsets
    quant_rows_if(&w.w1h_t, &ws.ln, rows, d, &mut ws.aq, &mut ws.ascale);
    for bb in 0..b {
        // prefix hidden rows are the first n positions of each batch row,
        // content rows follow — both contiguous, no gather copies
        let src = &ws.ln[bb * li * d..][..n * d];
        let dst = &mut ws.pproj[bb * n * fd..][..n * fd];
        run_mat(pool, &w.w1p_t, src, &ws.aq[bb * li * d..], &ws.ascale[bb * li..], None, dst, n, d, fd);
        let src = &ws.ln[(bb * li + prefix) * d..][..lp_out * d];
        let dst = &mut ws.hproj[bb * lp_out * fd..][..lp_out * fd];
        run_mat(
            pool,
            &w.w1h_t,
            src,
            &ws.aq[(bb * li + prefix) * d..],
            &ws.ascale[bb * li + prefix..],
            None,
            dst,
            lp_out,
            d,
            fd,
        );
    }
    for bb in 0..b {
        for slot in 0..n {
            let pp = &ws.pproj[(bb * n + slot) * fd..][..fd];
            for l in 0..lp_out {
                let hp = &ws.hproj[(bb * lp_out + l) * fd..][..fd];
                let z = &mut ws.z[((bb * n + slot) * lp_out + l) * fd..][..fd];
                for t in 0..fd {
                    z[t] = hp[t] + pp[t] + w.db1[t];
                }
            }
        }
    }
    gelu_buf(&mut ws.z);
    let zrows = b * n * lp_out;
    quant_rows_if(&w.w2_t, &ws.z, zrows, fd, &mut ws.aq, &mut ws.ascale);
    run_mat(pool, &w.w2_t, &ws.z, &ws.aq, &ws.ascale, Some(&w.db2), &mut ws.dem, zrows, fd, d);
    let mut out = vec![0.0f32; zrows * dims.n_classes];
    gemm_bt_pooled(pool, &ws.dem, &w.head_t, Some(&w.head_b), &mut out, zrows, d, dims.n_classes);
    let ns_head = lap(&mut mark);
    timers.record(ns_mux, ns_qkv, ns_attn, ns_ffn, ns_head);
    Ok(out)
}
