//! Cache-blocked GEMM microkernel over pre-transposed weights, plus the
//! fork-join helper that fans row bands across [`ThreadPool`].
//!
//! Layout contract: activations `a` are `(m, k)` row-major; weights are
//! stored **pre-transposed** at load time as `bt = W^T`, i.e. `(n, k)`
//! row-major. Every dot product then streams both operands contiguously
//! over `k`, which is what lets the compiler vectorize the inner loops —
//! the naive `(k, n)` layout walks the weight matrix with stride `n` and
//! defeats both SIMD and the cache.
//!
//! Blocking: output columns are processed in [`NC`]-wide tiles so one
//! tile of `bt` rows stays hot in L2 while every `a` row streams over
//! it, and the micro-kernel accumulates [`NR`] dot products per `a`-row
//! pass to amortize the activation loads.

// index-heavy kernels: explicit loops express the blocking structure
// more directly than iterator chains would
#![allow(clippy::needless_range_loop, clippy::too_many_arguments)]

use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{Arc, Condvar, Mutex, PoisonError};

use super::quant::QuantMat;
use super::simd;
use crate::util::threadpool::ThreadPool;

/// Output-column tile width: one tile of `bt` (`NC * k * 4` bytes) is
/// reused across all `m` activation rows before moving on.
const NC: usize = 64;

/// Micro-kernel width: dot products accumulated per `a`-row pass.
const NR: usize = 4;

/// Minimum multiply-accumulates before a GEMM is worth fanning out to
/// the pool; below this the fork-join latency exceeds the win.
const PAR_MIN_MACS: usize = 1 << 16;

/// Process-wide count of top-level GEMM dispatches (the f32 and int8
/// pooled entry points; per-band calls inside a fan-out are not
/// re-counted). The `native_forward` bench takes a delta across one
/// forward to pin the QKV-fusion invariant: one projection GEMM per
/// layer, not three.
static GEMM_DISPATCHES: AtomicU64 = AtomicU64::new(0);

/// Top-level GEMM dispatches so far (monotonic, process-wide).
pub fn gemm_dispatches() -> u64 {
    GEMM_DISPATCHES.load(Ordering::Relaxed)
}

/// `c = a @ bt^T (+ bias)`: `a` is `(m, k)`, `bt` is the pre-transposed
/// weight `(n, k)`, `c` is `(m, n)`, all row-major. Allocation-free.
///
/// Dispatches once per process: the AVX2+FMA microkernel in `simd.rs`
/// when the host supports it (and `DATAMUX_FORCE_SCALAR` is unset), the
/// blocked-scalar kernel below otherwise.
// lint: hot-path
pub fn gemm_bt(
    a: &[f32],
    bt: &[f32],
    bias: Option<&[f32]>,
    c: &mut [f32],
    m: usize,
    k: usize,
    n: usize,
) {
    assert_eq!(a.len(), m * k, "gemm: a is not (m, k)");
    assert_eq!(bt.len(), n * k, "gemm: bt is not (n, k)");
    assert_eq!(c.len(), m * n, "gemm: c is not (m, n)");
    if let Some(b) = bias {
        assert_eq!(b.len(), n, "gemm: bias is not (n,)");
    }
    #[cfg(target_arch = "x86_64")]
    if simd::active_kernel() == simd::Kernel::Avx2Fma {
        // SAFETY: feature presence was verified by `active_kernel`;
        // lengths were asserted above.
        unsafe { simd::gemm_bt_f32_avx2(a, bt, bias, c, m, k, n) };
        return;
    }
    gemm_bt_scalar(a, bt, bias, c, m, k, n);
}

/// The portable blocked-scalar arm (pre-SIMD kernel, kept as the
/// fallback and the reference the vectorized arm is tested against).
// lint: hot-path
pub(crate) fn gemm_bt_scalar(
    a: &[f32],
    bt: &[f32],
    bias: Option<&[f32]>,
    c: &mut [f32],
    m: usize,
    k: usize,
    n: usize,
) {
    let mut jb = 0;
    while jb < n {
        let je = (jb + NC).min(n);
        for i in 0..m {
            let ar = &a[i * k..(i + 1) * k];
            let cr = &mut c[i * n..(i + 1) * n];
            let mut j = jb;
            while j + NR <= je {
                let b0 = &bt[j * k..(j + 1) * k];
                let b1 = &bt[(j + 1) * k..(j + 2) * k];
                let b2 = &bt[(j + 2) * k..(j + 3) * k];
                let b3 = &bt[(j + 3) * k..(j + 4) * k];
                let mut s0 = 0.0f32;
                let mut s1 = 0.0f32;
                let mut s2 = 0.0f32;
                let mut s3 = 0.0f32;
                for kk in 0..k {
                    let av = ar[kk];
                    s0 += av * b0[kk];
                    s1 += av * b1[kk];
                    s2 += av * b2[kk];
                    s3 += av * b3[kk];
                }
                match bias {
                    Some(b) => {
                        cr[j] = s0 + b[j];
                        cr[j + 1] = s1 + b[j + 1];
                        cr[j + 2] = s2 + b[j + 2];
                        cr[j + 3] = s3 + b[j + 3];
                    }
                    None => {
                        cr[j] = s0;
                        cr[j + 1] = s1;
                        cr[j + 2] = s2;
                        cr[j + 3] = s3;
                    }
                }
                j += NR;
            }
            // bias is resolved once for the whole tail, like the NR-wide
            // body above — not re-matched per element
            let tail = j;
            while j < je {
                let br = &bt[j * k..(j + 1) * k];
                let mut s = 0.0f32;
                for kk in 0..k {
                    s += ar[kk] * br[kk];
                }
                cr[j] = s;
                j += 1;
            }
            if let Some(b) = bias {
                for j in tail..je {
                    cr[j] += b[j];
                }
            }
        }
        jb = je;
    }
}

/// Raw mutable base pointer smuggled into pool jobs. Each job writes a
/// disjoint element range and [`parallel_for`] joins before the borrow
/// ends, so no aliasing or escape is possible.
#[derive(Clone, Copy)]
pub(crate) struct SendMut(pub *mut f32);
// SAFETY: see type-level comment — strictly disjoint writes, joined
// before the underlying unique borrow resumes.
unsafe impl Send for SendMut {}
unsafe impl Sync for SendMut {}

/// [`gemm_bt`] with the `m` rows split into one band per pool worker.
/// Band boundaries never change per-element arithmetic, so the result is
/// bitwise identical to the serial kernel. Small problems (or no pool)
/// run serially.
pub fn gemm_bt_pooled(
    pool: Option<&ThreadPool>,
    a: &[f32],
    bt: &[f32],
    bias: Option<&[f32]>,
    c: &mut [f32],
    m: usize,
    k: usize,
    n: usize,
) {
    GEMM_DISPATCHES.fetch_add(1, Ordering::Relaxed);
    let pool = match pool {
        Some(p) if m >= 2 && m.saturating_mul(k).saturating_mul(n) >= PAR_MIN_MACS => p,
        _ => return gemm_bt(a, bt, bias, c, m, k, n),
    };
    let bands = pool.n_workers().min(m).max(1);
    // balanced split: the first `m % bands` bands get one extra row, so
    // band sizes differ by at most 1 and no trailing band is ever empty
    // (ceil(m/bands) strands whole bands when m % bands != 0).
    let base = m / bands;
    let extra = m % bands;
    let cptr = SendMut(c.as_mut_ptr());
    parallel_for(pool, bands, |band| {
        let r0 = band * base + band.min(extra);
        let r1 = r0 + base + usize::from(band < extra);
        // SAFETY: each band owns rows r0..r1 of `c` — disjoint across
        // bands — and `parallel_for` joins before the borrow of `c` ends.
        let cband = unsafe { std::slice::from_raw_parts_mut(cptr.0.add(r0 * n), (r1 - r0) * n) };
        gemm_bt(&a[r0 * k..r1 * k], bt, bias, cband, r1 - r0, k, n);
    });
}

/// Int8 sibling of [`gemm_bt`]: biased-u8 activations `aq` (m, k) with
/// per-row scales against a [`QuantMat`] (n output channels over k).
/// Both arms accumulate in exact i32 and share one f32 epilogue, so
/// dispatch never changes the result bitwise.
// lint: hot-path
pub(crate) fn gemm_bt_q8(
    aq: &[u8],
    ascale: &[f32],
    w: &QuantMat,
    bias: Option<&[f32]>,
    c: &mut [f32],
    m: usize,
    k: usize,
    n: usize,
) {
    assert_eq!(aq.len(), m * k, "q8 gemm: aq is not (m, k)");
    assert_eq!(ascale.len(), m, "q8 gemm: ascale is not (m,)");
    assert_eq!(w.q.len(), n * k, "q8 gemm: weights are not (n, k)");
    assert_eq!(c.len(), m * n, "q8 gemm: c is not (m, n)");
    if let Some(b) = bias {
        assert_eq!(b.len(), n, "q8 gemm: bias is not (n,)");
    }
    #[cfg(target_arch = "x86_64")]
    if simd::active_kernel() == simd::Kernel::Avx2Fma {
        // SAFETY: feature presence verified by `active_kernel`; lengths
        // asserted above.
        unsafe { simd::gemm_bt_q8_avx2(aq, ascale, w, bias, c, m, k, n) };
        return;
    }
    super::quant::gemm_bt_q8_scalar(aq, ascale, w, bias, c, m, k, n);
}

/// [`gemm_bt_q8`] with the same balanced row banding as
/// [`gemm_bt_pooled`]; bitwise identical to the serial call.
pub(crate) fn gemm_bt_q8_pooled(
    pool: Option<&ThreadPool>,
    aq: &[u8],
    ascale: &[f32],
    w: &QuantMat,
    bias: Option<&[f32]>,
    c: &mut [f32],
    m: usize,
    k: usize,
    n: usize,
) {
    GEMM_DISPATCHES.fetch_add(1, Ordering::Relaxed);
    let pool = match pool {
        Some(p) if m >= 2 && m.saturating_mul(k).saturating_mul(n) >= PAR_MIN_MACS => p,
        _ => return gemm_bt_q8(aq, ascale, w, bias, c, m, k, n),
    };
    let bands = pool.n_workers().min(m).max(1);
    let base = m / bands;
    let extra = m % bands;
    let cptr = SendMut(c.as_mut_ptr());
    parallel_for(pool, bands, |band| {
        let r0 = band * base + band.min(extra);
        let r1 = r0 + base + usize::from(band < extra);
        // SAFETY: as in `gemm_bt_pooled` — bands write disjoint row
        // ranges of `c` and are joined before the borrow ends.
        let cband = unsafe { std::slice::from_raw_parts_mut(cptr.0.add(r0 * n), (r1 - r0) * n) };
        gemm_bt_q8(&aq[r0 * k..r1 * k], &ascale[r0..r1], w, bias, cband, r1 - r0, k, n);
    });
}

struct Latch {
    left: Mutex<usize>,
    cv: Condvar,
    /// set when any job panicked — the caller re-raises after the join
    /// instead of silently returning partial output
    panicked: AtomicBool,
}

/// Decrements the latch on drop, so the caller is always released.
struct Done(Arc<Latch>);

impl Drop for Done {
    fn drop(&mut self) {
        // poison is survivable here: the count is the only state, and a
        // job panic is reported separately through `panicked`
        let mut left = self.0.left.lock().unwrap_or_else(PoisonError::into_inner);
        *left -= 1;
        if *left == 0 {
            self.0.cv.notify_all();
        }
    }
}

/// Run `f(0..n)` on the pool and block until every call has finished.
/// The closure may borrow locals: the latch wait below guarantees no job
/// (or its unwind) outlives this call, which is what makes the lifetime
/// extension sound.
///
/// A panic inside a job is caught (keeping the pool worker alive),
/// recorded on the latch, and re-raised here after all jobs drain — the
/// caller can never observe a partial result as success, and repeated
/// panics cannot bleed the pool dry.
pub fn parallel_for<F: Fn(usize) + Sync>(pool: &ThreadPool, n: usize, f: F) {
    if n == 0 {
        return;
    }
    if n == 1 {
        f(0);
        return;
    }
    let latch = Arc::new(Latch {
        left: Mutex::new(n),
        cv: Condvar::new(),
        panicked: AtomicBool::new(false),
    });
    let f_ref: &(dyn Fn(usize) + Sync) = &f;
    // SAFETY: every submitted job drops its `Done` before exiting, and
    // this function does not return until the latch reaches zero — the
    // forged 'static lifetime never outlives the borrow of `f`.
    let f_static: &'static (dyn Fn(usize) + Sync) = unsafe { std::mem::transmute(f_ref) };
    for i in 0..n {
        let done = Done(latch.clone());
        pool.submit(move || {
            // AssertUnwindSafe: on panic the caller re-panics below, so
            // any torn per-band state is never observed as a result
            let ok = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| f_static(i)));
            if ok.is_err() {
                done.0.panicked.store(true, Ordering::SeqCst);
            }
            drop(done);
        });
    }
    let mut left = latch.left.lock().unwrap_or_else(PoisonError::into_inner);
    while *left > 0 {
        left = latch.cv.wait(left).unwrap_or_else(PoisonError::into_inner);
    }
    drop(left);
    if latch.panicked.load(Ordering::SeqCst) {
        panic!("parallel_for: a pool job panicked (see stderr for the original message)");
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    /// The textbook ijk loop over the untransposed (k, n) layout.
    fn naive(a: &[f32], w: &[f32], bias: Option<&[f32]>, m: usize, k: usize, n: usize) -> Vec<f32> {
        let mut c = vec![0.0f32; m * n];
        for i in 0..m {
            for j in 0..n {
                let mut s = bias.map_or(0.0, |b| b[j]);
                for kk in 0..k {
                    s += a[i * k + kk] * w[kk * n + j];
                }
                c[i * n + j] = s;
            }
        }
        c
    }

    fn transpose(w: &[f32], k: usize, n: usize) -> Vec<f32> {
        let mut t = vec![0.0f32; n * k];
        for kk in 0..k {
            for j in 0..n {
                t[j * k + kk] = w[kk * n + j];
            }
        }
        t
    }

    #[test]
    fn blocked_kernel_matches_naive_across_shapes() {
        let mut rng = Rng::new(11);
        for &(m, k, n) in &[(1, 1, 1), (3, 5, 7), (8, 16, 64), (5, 33, 66), (17, 64, 130)] {
            let a: Vec<f32> = (0..m * k).map(|_| rng.normal() as f32).collect();
            let w: Vec<f32> = (0..k * n).map(|_| rng.normal() as f32).collect();
            let bias: Vec<f32> = (0..n).map(|_| rng.normal() as f32).collect();
            let bt = transpose(&w, k, n);
            let want = naive(&a, &w, Some(&bias), m, k, n);
            let mut got = vec![0.0f32; m * n];
            gemm_bt(&a, &bt, Some(&bias), &mut got, m, k, n);
            for i in 0..want.len() {
                assert!(
                    (got[i] - want[i]).abs() <= 1e-4 * (1.0 + want[i].abs()),
                    "({m},{k},{n})[{i}]: {} vs {}",
                    got[i],
                    want[i]
                );
            }
            let mut no_bias = vec![0.0f32; m * n];
            gemm_bt(&a, &bt, None, &mut no_bias, m, k, n);
            let want_nb = naive(&a, &w, None, m, k, n);
            for i in 0..want_nb.len() {
                assert!((no_bias[i] - want_nb[i]).abs() <= 1e-4 * (1.0 + want_nb[i].abs()));
            }
        }
    }

    /// Satellite: odd kernel shapes — `k` not a multiple of the SIMD
    /// width, `n < NR`, `m == 1` — must agree across the dispatch path,
    /// the scalar arm, and (where the host supports it) the explicit
    /// AVX2 arm.
    #[test]
    fn prop_gemm_bt_odd_shapes_agree_across_arms() {
        crate::util::proptest::check("gemm_bt_odd_shapes", 64, |g| {
            let m = if g.rng.bool(0.5) { 1 } else { g.sized(6) };
            let k = g.sized(69); // frequently not a multiple of 8 or 32
            let n = g.sized(11); // frequently < NR
            let a: Vec<f32> = (0..m * k).map(|_| g.rng.normal() as f32).collect();
            let bt: Vec<f32> = (0..n * k).map(|_| g.rng.normal() as f32).collect();
            let bias_vec: Vec<f32> = (0..n).map(|_| g.rng.normal() as f32).collect();
            let bias = if g.rng.bool(0.5) { Some(bias_vec.as_slice()) } else { None };
            let mut want = vec![0.0f32; m * n];
            gemm_bt_scalar(&a, &bt, bias, &mut want, m, k, n);
            let mut got = vec![0.0f32; m * n];
            gemm_bt(&a, &bt, bias, &mut got, m, k, n);
            for i in 0..want.len() {
                let tol = 1e-4 * (1.0 + want[i].abs());
                if (got[i] - want[i]).abs() > tol {
                    return Err(format!(
                        "dispatch vs scalar ({m},{k},{n})[{i}]: {} vs {}",
                        got[i], want[i]
                    ));
                }
            }
            #[cfg(target_arch = "x86_64")]
            if is_x86_feature_detected!("avx2") && is_x86_feature_detected!("fma") {
                let mut vec_arm = vec![0.0f32; m * n];
                unsafe { simd::gemm_bt_f32_avx2(&a, &bt, bias, &mut vec_arm, m, k, n) };
                for i in 0..want.len() {
                    let tol = 1e-4 * (1.0 + want[i].abs());
                    if (vec_arm[i] - want[i]).abs() > tol {
                        return Err(format!(
                            "avx2 vs scalar ({m},{k},{n})[{i}]: {} vs {}",
                            vec_arm[i], want[i]
                        ));
                    }
                }
            }
            Ok(())
        });
    }

    /// Int8 arms must be bitwise identical to each other (exact integer
    /// accumulation + shared epilogue) and track the f32 kernel within
    /// the analytic quantization-noise bound.
    #[test]
    fn prop_q8_gemm_arms_bitwise_identical_and_near_f32() {
        crate::util::proptest::check("gemm_bt_q8_arms", 48, |g| {
            let m = g.sized(5);
            let k = g.sized(80);
            let n = g.sized(10);
            let a: Vec<f32> = (0..m * k).map(|_| g.rng.normal() as f32).collect();
            let bt: Vec<f32> = (0..n * k).map(|_| g.rng.normal() as f32).collect();
            let w = QuantMat::from_bt(&bt, n, k);
            let mut aq = vec![0u8; m * k];
            let mut ascale = vec![0.0f32; m];
            super::super::quant::quantize_rows(&a, m, k, &mut aq, &mut ascale);
            let bias_vec: Vec<f32> = (0..n).map(|_| g.rng.normal() as f32).collect();
            let bias = if g.rng.bool(0.5) { Some(bias_vec.as_slice()) } else { None };
            let mut scalar = vec![0.0f32; m * n];
            super::super::quant::gemm_bt_q8_scalar(&aq, &ascale, &w, bias, &mut scalar, m, k, n);
            let mut dispatched = vec![0.0f32; m * n];
            gemm_bt_q8(&aq, &ascale, &w, bias, &mut dispatched, m, k, n);
            for i in 0..scalar.len() {
                if scalar[i].to_bits() != dispatched[i].to_bits() {
                    return Err(format!(
                        "q8 arms diverged at ({m},{k},{n})[{i}]: {} vs {}",
                        scalar[i], dispatched[i]
                    ));
                }
            }
            let mut f32_ref = vec![0.0f32; m * n];
            gemm_bt_scalar(&a, &bt, bias, &mut f32_ref, m, k, n);
            for i in 0..m {
                for j in 0..n {
                    let bound =
                        0.0125 * k as f32 * (ascale[i] * 127.0) * (w.scales[j] * 63.0) + 1e-5;
                    let err = (dispatched[i * n + j] - f32_ref[i * n + j]).abs();
                    if err > bound {
                        return Err(format!(
                            "q8 vs f32 ({m},{k},{n})[{i},{j}]: err {err} > bound {bound}"
                        ));
                    }
                }
            }
            Ok(())
        });
    }

    /// Satellite regression: when `m % bands != 0` the old ceil split
    /// left trailing bands empty; the balanced split must still be
    /// bitwise identical and must engage every worker's band.
    #[test]
    fn pooled_gemm_balanced_split_handles_uneven_rows() {
        let mut rng = Rng::new(13);
        let pool = ThreadPool::new(4, 32);
        // m = 5 with 4 workers: old split gave bands of 2,2,1,0; the
        // balanced split gives 2,1,1,1. k*n big enough to parallelize.
        for &(m, k, n) in &[(5usize, 64usize, 256usize), (7, 64, 256), (9, 64, 256)] {
            let a: Vec<f32> = (0..m * k).map(|_| rng.normal() as f32).collect();
            let bt: Vec<f32> = (0..n * k).map(|_| rng.normal() as f32).collect();
            let mut serial = vec![0.0f32; m * n];
            gemm_bt(&a, &bt, None, &mut serial, m, k, n);
            let mut pooled = vec![0.0f32; m * n];
            gemm_bt_pooled(Some(&pool), &a, &bt, None, &mut pooled, m, k, n);
            assert_eq!(serial, pooled, "uneven banding changed the math at m={m}");
        }
    }

    #[test]
    fn pooled_q8_gemm_is_bitwise_identical_to_serial() {
        let mut rng = Rng::new(14);
        let (m, k, n) = (37, 64, 48); // above PAR_MIN_MACS, m % 3 != 0
        let a: Vec<f32> = (0..m * k).map(|_| rng.normal() as f32).collect();
        let bt: Vec<f32> = (0..n * k).map(|_| rng.normal() as f32).collect();
        let w = QuantMat::from_bt(&bt, n, k);
        let mut aq = vec![0u8; m * k];
        let mut ascale = vec![0.0f32; m];
        super::super::quant::quantize_rows(&a, m, k, &mut aq, &mut ascale);
        let bias: Vec<f32> = (0..n).map(|_| rng.normal() as f32).collect();
        let mut serial = vec![0.0f32; m * n];
        gemm_bt_q8(&aq, &ascale, &w, Some(&bias), &mut serial, m, k, n);
        let pool = ThreadPool::new(3, 32);
        let mut pooled = vec![0.0f32; m * n];
        gemm_bt_q8_pooled(Some(&pool), &aq, &ascale, &w, Some(&bias), &mut pooled, m, k, n);
        assert_eq!(serial, pooled, "q8 row banding must not change the math");
    }

    #[test]
    fn pooled_gemm_is_bitwise_identical_to_serial() {
        let mut rng = Rng::new(12);
        let (m, k, n) = (37, 64, 48); // above PAR_MIN_MACS
        let a: Vec<f32> = (0..m * k).map(|_| rng.normal() as f32).collect();
        let bt: Vec<f32> = (0..n * k).map(|_| rng.normal() as f32).collect();
        let bias: Vec<f32> = (0..n).map(|_| rng.normal() as f32).collect();
        let mut serial = vec![0.0f32; m * n];
        gemm_bt(&a, &bt, Some(&bias), &mut serial, m, k, n);
        let pool = ThreadPool::new(3, 32);
        let mut pooled = vec![0.0f32; m * n];
        gemm_bt_pooled(Some(&pool), &a, &bt, Some(&bias), &mut pooled, m, k, n);
        assert_eq!(serial, pooled, "row banding must not change the math");
    }

    #[test]
    fn parallel_for_visits_every_index_once() {
        use std::sync::atomic::AtomicUsize;
        let pool = ThreadPool::new(4, 64);
        let hits: Vec<AtomicUsize> = (0..40).map(|_| AtomicUsize::new(0)).collect();
        parallel_for(&pool, hits.len(), |i| {
            hits[i].fetch_add(1, Ordering::SeqCst);
        });
        assert!(hits.iter().all(|h| h.load(Ordering::SeqCst) == 1));
    }

    #[test]
    fn parallel_for_repanics_on_job_panic_and_keeps_workers_alive() {
        use std::sync::atomic::AtomicUsize;
        let pool = ThreadPool::new(2, 16);
        let result = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            parallel_for(&pool, 4, |i| {
                if i == 2 {
                    panic!("synthetic job panic");
                }
            });
        }));
        assert!(result.is_err(), "caller must observe the job panic, not partial output");
        // the pool survives: a subsequent fan-out still completes fully
        let hits: Vec<AtomicUsize> = (0..8).map(|_| AtomicUsize::new(0)).collect();
        parallel_for(&pool, hits.len(), |i| {
            hits[i].fetch_add(1, Ordering::SeqCst);
        });
        assert!(hits.iter().all(|h| h.load(Ordering::SeqCst) == 1));
    }
}
