//! Native backend: the full T-MUX forward pass in pure rust, executed
//! straight from the `WeightsFile`/`ArtifactManifest` format — no PJRT,
//! no python, no network.
//!
//! This is the third [`InferenceBackend`](crate::runtime::InferenceBackend):
//! `SharedModel` (PJRT) runs the compiled HLO, `FakeBackend` does no
//! math, and [`NativeBackend`] does the *real* math at hardware speed:
//!
//! * `gemm` — cache-blocked dot-product GEMM over pre-transposed
//!   weights, row-banded across `util::threadpool`;
//! * `pack` — name-resolved weight loading (jax pytree paths), with the
//!   token-embedding table borrowed zero-copy from the blob and the mux
//!   vectors pre-scaled/pre-folded for the fused mux;
//! * `forward` — embedding + fused index-prefix mux combine, pre-LN
//!   multi-head self-attention, GELU FFN, final layer norm,
//!   index-embedding demux, task head;
//! * `arena` — per-worker tensor arenas so steady-state forwards
//!   allocate nothing beyond the API-mandated output vector;
//! * `reference` — the deliberately naive scalar twin, used as the
//!   proptest oracle and the live baseline the `native_forward` bench
//!   gates against (≥2x).
//!
//! Supported artifact space: `cls`/`token` tasks, `index_embed` demux,
//! vector mux strategies (hadamard / learned_hadamard / binary /
//! identity). `ortho` mux and `retrieval` artifacts still need PJRT and
//! are rejected at load with a clear error.

mod arena;
mod forward;
mod gemm;
mod pack;
mod quant;
pub mod reference;
mod simd;

use std::path::PathBuf;
use std::sync::atomic::{AtomicU64, Ordering};

use anyhow::{anyhow, ensure, Result};

use crate::runtime::manifest::ArtifactMeta;
use crate::runtime::weights::WeightsFile;
use crate::runtime::InferenceBackend;
use crate::util::threadpool::ThreadPool;

pub use gemm::gemm_dispatches;
pub use pack::RawWeights;
pub use simd::{active_kernel, Kernel};

/// Cumulative per-stage wall time (ns) across every forward a backend has
/// run — the Amdahl observability the v2 STATS `backends` block and the
/// `native_forward` bench `stage_ns` map read from. Stage boundaries:
/// `mux` = fused mux+embedding gather; `qkv` = ln1 + activation
/// quantization + the fused QKV GEMM; `attention` = the flash-attention
/// fan-out only; `ffn` = output projection + residuals + ln2 + FFN;
/// `head` = final LN + demux + task head. The forward accumulates laps
/// locally and lands one relaxed add per stage per call.
#[derive(Default)]
pub(crate) struct StageTimers {
    mux: AtomicU64,
    qkv: AtomicU64,
    attention: AtomicU64,
    ffn: AtomicU64,
    head: AtomicU64,
}

impl StageTimers {
    pub fn record(&self, mux: u64, qkv: u64, attention: u64, ffn: u64, head: u64) {
        self.mux.fetch_add(mux, Ordering::Relaxed);
        self.qkv.fetch_add(qkv, Ordering::Relaxed);
        self.attention.fetch_add(attention, Ordering::Relaxed);
        self.ffn.fetch_add(ffn, Ordering::Relaxed);
        self.head.fetch_add(head, Ordering::Relaxed);
    }

    pub fn snapshot(&self) -> [(&'static str, u64); 5] {
        [
            ("mux", self.mux.load(Ordering::Relaxed)),
            ("qkv", self.qkv.load(Ordering::Relaxed)),
            ("attention", self.attention.load(Ordering::Relaxed)),
            ("ffn", self.ffn.load(Ordering::Relaxed)),
            ("head", self.head.load(Ordering::Relaxed)),
        ]
    }
}

/// Weight precision the forward executes at. `F32` is the default;
/// `Int8` runs the projection GEMMs on per-output-channel symmetric int8
/// weights with dynamic per-row activation quantization (attention score
/// math, layer norms, and the task head stay f32).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum Precision {
    #[default]
    F32,
    Int8,
}

impl Precision {
    pub fn name(self) -> &'static str {
        match self {
            Precision::F32 => "f32",
            Precision::Int8 => "int8",
        }
    }
}

impl std::fmt::Display for Precision {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(self.name())
    }
}

/// Task the native forward serves (`retrieval` artifacts are rejected).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum NativeTask {
    Cls,
    Token,
}

/// Every static shape of one artifact, resolved once at load (`d_ff` and
/// `d_demux` live only in the weights blob, not the manifest).
#[derive(Debug, Clone)]
pub struct Dims {
    pub batch: usize,
    pub n_mux: usize,
    pub seq_len: usize,
    pub prefix_len: usize,
    pub input_len: usize,
    pub vocab_size: usize,
    pub d_model: usize,
    pub n_layers: usize,
    pub n_heads: usize,
    pub d_head: usize,
    pub d_ff: usize,
    pub d_demux: usize,
    pub n_classes: usize,
    pub task: NativeTask,
}

impl Dims {
    /// The same model at a shorter runtime sequence length (a bucket):
    /// only `seq_len`/`input_len` change — weights, heads and demux
    /// widths are shape-independent, and the positional table simply has
    /// unused tail rows. Attention cost drops quadratically in
    /// `input_len`, which is the whole point of bucketing.
    pub fn at_seq_len(&self, seq_len: usize) -> Dims {
        assert!(
            (1..=self.seq_len).contains(&seq_len),
            "runtime seq_len {seq_len} outside 1..={}",
            self.seq_len
        );
        Dims { seq_len, input_len: self.prefix_len + seq_len, ..self.clone() }
    }

    /// Rows of the residual stream: one per (batch, position).
    pub fn rows(&self) -> usize {
        self.batch * self.input_len
    }

    /// Positions demultiplexed: only \[CLS\] for cls (same logits,
    /// O(L) less demux work — the compile path's `demux_len=1`), every
    /// content position for token.
    pub fn demux_len(&self) -> usize {
        match self.task {
            NativeTask::Cls => 1,
            NativeTask::Token => self.seq_len,
        }
    }

    pub fn ids_len(&self) -> usize {
        self.batch * self.n_mux * self.input_len
    }

    pub fn output_len(&self) -> usize {
        self.batch * self.n_mux * self.demux_len() * self.n_classes
    }

    /// Approximate FLOPs of one forward (2 per multiply-accumulate;
    /// GEMM + attention + mux terms, elementwise/LN work excluded).
    pub fn flops(&self) -> f64 {
        let m = self.rows() as f64;
        let (d, f, fd) = (self.d_model as f64, self.d_ff as f64, self.d_demux as f64);
        let mux = 2.0 * m * (self.n_mux * self.d_model) as f64;
        let attn = 2.0
            * (self.batch * self.n_heads) as f64
            * (2 * self.input_len * self.input_len * self.d_head) as f64;
        let per_layer = 2.0 * m * (4.0 * d * d + 2.0 * d * f) + attn;
        let bn = (self.batch * self.n_mux) as f64;
        let lp = self.demux_len() as f64;
        let demux = 2.0 * bn * d * fd
            + 2.0 * (self.batch as f64) * lp * d * fd
            + 2.0 * bn * lp * fd * d
            + 2.0 * bn * lp * d * self.n_classes as f64;
        mux + self.n_layers as f64 * per_layer + demux
    }
}

/// Synthetic [`ArtifactMeta`] for artifact-free native models (tests,
/// benches, the zero-artifact e2e run) — index-prefix layout, same
/// conventions as [`FakeBackend`](crate::runtime::FakeBackend).
#[allow(clippy::too_many_arguments)]
pub fn synthetic_meta(
    task: &str,
    n_mux: usize,
    batch: usize,
    seq_len: usize,
    d_model: usize,
    n_layers: usize,
    n_heads: usize,
    n_classes: usize,
) -> ArtifactMeta {
    ArtifactMeta {
        name: format!("native_{task}_n{n_mux}_b{batch}_d{d_model}"),
        hlo: PathBuf::from("native.hlo.txt"),
        weights: PathBuf::from("native.weights.bin"),
        profile: "native".to_string(),
        n_mux,
        seq_len,
        input_len: seq_len + n_mux,
        batch,
        d_model,
        n_layers,
        n_heads,
        task: task.to_string(),
        n_classes,
        mux: "hadamard".to_string(),
        demux: "index_embed".to_string(),
        vocab_size: 300,
        // 7 model-level tensors + 1 head pair + 16 per layer (see
        // RawWeights::random); pack() cross-checks this against the blob
        n_weight_tensors: 12 + 16 * n_layers,
        trained: false,
        train_task: None,
        train_accuracy: None,
        parity: None,
    }
}

/// Pure-rust T-MUX inference over a weights blob.
pub struct NativeBackend {
    meta: ArtifactMeta,
    dims: Dims,
    /// owns the blob; the token table is gathered zero-copy out of it
    wf: WeightsFile,
    weights: pack::PackedWeights,
    precision: Precision,
    pool: Option<ThreadPool>,
    arenas: arena::ArenaPool,
    timers: StageTimers,
}

fn make_pool(threads: usize) -> Option<ThreadPool> {
    if threads <= 1 {
        None
    } else {
        Some(ThreadPool::new(threads, threads * 8))
    }
}

fn default_threads() -> usize {
    std::thread::available_parallelism()
        .map(|n| n.get())
        .unwrap_or(1)
        .saturating_sub(1)
        .clamp(1, 8)
}

impl NativeBackend {
    /// Load the artifact's weights blob from disk and pack it at f32.
    pub fn from_artifact(meta: &ArtifactMeta) -> Result<Self> {
        Self::from_artifact_prec(meta, Precision::F32)
    }

    /// Load the artifact's weights blob from disk and pack it at the
    /// requested precision (f32 blobs are quantized online for `Int8`;
    /// `DMUXW2` int8 blobs are dequantized for `F32`).
    pub fn from_artifact_prec(meta: &ArtifactMeta, precision: Precision) -> Result<Self> {
        let wf = WeightsFile::load(&meta.weights)?;
        Self::from_weights_prec(meta.clone(), wf, precision)
    }

    /// Build from an already-parsed blob (tests hand in synthetic ones).
    pub fn from_weights(meta: ArtifactMeta, wf: WeightsFile) -> Result<Self> {
        Self::from_weights_prec(meta, wf, Precision::F32)
    }

    /// [`from_weights`](Self::from_weights) at an explicit precision.
    pub fn from_weights_prec(
        meta: ArtifactMeta,
        wf: WeightsFile,
        precision: Precision,
    ) -> Result<Self> {
        let (dims, weights) = pack::pack(&meta, &wf, precision)?;
        // observability: one line per backend build so operators can see
        // which kernel arm and weight precision actually run
        eprintln!(
            "native backend {}: kernel={}, precision={precision}",
            meta.name,
            simd::active_kernel()
        );
        Ok(NativeBackend {
            meta,
            dims,
            wf,
            weights,
            precision,
            pool: make_pool(default_threads()),
            arenas: arena::ArenaPool::new(),
            timers: StageTimers::default(),
        })
    }

    /// A randomly-initialized model — real math, zero artifacts.
    #[allow(clippy::too_many_arguments)]
    pub fn random(
        task: &str,
        n_mux: usize,
        batch: usize,
        seq_len: usize,
        d_model: usize,
        n_layers: usize,
        n_heads: usize,
        n_classes: usize,
        seed: u64,
    ) -> Result<Self> {
        let meta =
            synthetic_meta(task, n_mux, batch, seq_len, d_model, n_layers, n_heads, n_classes);
        let raw = RawWeights::random(&meta, 2 * d_model, seed);
        let wf = WeightsFile::parse(raw.to_blob())?;
        Self::from_weights(meta, wf)
    }

    /// GEMM/attention worker threads (`<= 1` runs single-threaded).
    /// Banding never changes per-element arithmetic, so results are
    /// bitwise identical across thread counts.
    pub fn with_threads(mut self, threads: usize) -> Self {
        self.pool = make_pool(threads);
        self
    }

    pub fn dims(&self) -> &Dims {
        &self.dims
    }

    /// The weight precision this backend executes at.
    pub fn precision(&self) -> Precision {
        self.precision
    }

    /// GEMM worker threads actually in use (1 = single-threaded).
    pub fn n_threads(&self) -> usize {
        self.pool.as_ref().map_or(1, |p| p.n_workers())
    }

    /// Tensor-arena materializations so far; flat after warmup is the
    /// allocation-free steady-state invariant (bench-gated).
    pub fn arena_reallocs(&self) -> u64 {
        self.arenas.reallocs()
    }

    /// Heap bytes one workspace occupies at runtime bucket `seq_len`,
    /// computed analytically without allocating. The `native_forward`
    /// bench gates on this growing *linearly* in `input_len` now that
    /// flash attention removed the quadratic scores block.
    pub fn workspace_bytes_at(&self, seq_len: usize) -> Result<usize> {
        ensure!(
            self.supports_seq_len(seq_len),
            "{}: runtime seq_len {seq_len} outside 1..={}",
            self.meta.name,
            self.dims.seq_len
        );
        Ok(arena::Workspace::bytes_for(&self.dims.at_seq_len(seq_len)))
    }

    /// Run the manifest's parity vector against the native forward.
    /// Tolerance gets a floor of 1e-3: the fused path sums in a
    /// different order than the jax reduction, so bit-parity headroom
    /// beyond the blob's own `tol` is expected.
    pub fn verify_parity(&self) -> Result<()> {
        let parity = self
            .meta
            .parity
            .as_ref()
            .ok_or_else(|| anyhow!("{} has no parity blob", self.meta.name))?;
        let out = self.run_ids(&parity.ids)?;
        parity.check(&self.meta.name, &out, 1e-3)
    }
}

impl InferenceBackend for NativeBackend {
    fn meta(&self) -> &ArtifactMeta {
        &self.meta
    }

    fn run_ids(&self, ids: &[i32]) -> Result<Vec<f32>> {
        self.run_ids_at(ids, self.dims.seq_len)
    }

    fn describe(&self) -> String {
        format!(
            "{} (N={}, native, kernel={}, precision={}, threads={})",
            self.meta.name,
            self.dims.n_mux,
            simd::active_kernel(),
            self.precision,
            self.n_threads()
        )
    }

    /// Shape-polymorphic: the pure-rust forward takes its shapes at
    /// runtime, so every bucket `1..=seq_len` executes (the positional
    /// table just has unused tail rows).
    fn supports_seq_len(&self, seq_len: usize) -> bool {
        (1..=self.dims.seq_len).contains(&seq_len)
    }

    fn stage_ns(&self) -> Vec<(&'static str, u64)> {
        self.timers.snapshot().to_vec()
    }

    fn run_ids_at(&self, ids: &[i32], seq_len: usize) -> Result<Vec<f32>> {
        ensure!(
            self.supports_seq_len(seq_len),
            "{}: runtime seq_len {seq_len} outside 1..={}",
            self.meta.name,
            self.dims.seq_len
        );
        let dims = self.dims.at_seq_len(seq_len);
        ensure!(
            ids.len() == dims.ids_len(),
            "{}: ids length {} != expected {} (batch {} x n_mux {} x input_len {})",
            self.meta.name,
            ids.len(),
            dims.ids_len(),
            dims.batch,
            dims.n_mux,
            dims.input_len
        );
        let tok = self.wf.tensor_f32_view(self.weights.tok_idx)?;
        // arenas are keyed on the runtime shape: each bucket settles on
        // its own workspace set, so a mixed-bucket serving loop still
        // allocates nothing after per-bucket warmup
        let mut ws = self.arenas.checkout(&dims);
        let result = forward::forward(
            &self.weights,
            tok,
            &dims,
            self.pool.as_ref(),
            ids,
            &mut ws,
            &self.timers,
        );
        self.arenas.give_back(dims.seq_len, ws);
        let out = result?;
        debug_assert_eq!(out.len(), dims.output_len());
        Ok(out)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn backend(task: &str, threads: usize) -> NativeBackend {
        NativeBackend::random(task, 2, 1, 6, 8, 1, 2, 3, 21)
            .expect("random backend")
            .with_threads(threads)
    }

    #[test]
    fn native_backend_is_send_sync() {
        fn assert_send_sync<T: Send + Sync>() {}
        assert_send_sync::<NativeBackend>();
    }

    #[test]
    fn output_shapes_match_the_meta_contract() {
        for task in ["cls", "token"] {
            let b = backend(task, 1);
            let ids = vec![1i32; b.meta().ids_len()];
            let out = b.run_ids(&ids).expect("run");
            assert_eq!(out.len(), b.meta().output_len(), "{task}");
            assert_eq!(out.len(), b.dims().output_len(), "{task}");
        }
    }

    #[test]
    fn serial_and_pooled_forwards_are_bitwise_identical() {
        let serial = backend("cls", 1);
        let pooled = NativeBackend::random("cls", 2, 1, 6, 8, 1, 2, 3, 21)
            .unwrap()
            .with_threads(3);
        let ids: Vec<i32> = (0..serial.meta().ids_len() as i32).map(|i| i % 44).collect();
        assert_eq!(serial.run_ids(&ids).unwrap(), pooled.run_ids(&ids).unwrap());
    }

    #[test]
    fn rejects_bad_inputs() {
        let b = backend("cls", 1);
        assert!(b.run_ids(&[0i32; 3]).is_err(), "wrong ids length");
        let mut ids = vec![1i32; b.meta().ids_len()];
        ids[0] = 300; // == vocab_size, out of range
        assert!(b.run_ids(&ids).is_err(), "oob token id");
        ids[0] = -1;
        assert!(b.run_ids(&ids).is_err(), "negative token id");
    }

    #[test]
    fn arena_settles_after_warmup() {
        let b = backend("cls", 1);
        let ids = vec![2i32; b.meta().ids_len()];
        b.run_ids(&ids).unwrap();
        assert_eq!(b.arena_reallocs(), 1, "warmup materializes exactly one arena");
        for _ in 0..4 {
            b.run_ids(&ids).unwrap();
        }
        assert_eq!(b.arena_reallocs(), 1, "steady state must reuse the arena");
    }

    #[test]
    fn arena_settles_per_bucket_and_buckets_do_not_cross_contaminate() {
        // n_mux=2, seq_len max 6: run buckets 3 and 6 interleaved
        let b = backend("cls", 1);
        let ids_at = |seq: usize| vec![2i32; 2 * (2 + seq)];
        b.run_ids_at(&ids_at(6), 6).unwrap();
        b.run_ids_at(&ids_at(3), 3).unwrap();
        assert_eq!(b.arena_reallocs(), 2, "one arena per bucket");
        for _ in 0..4 {
            b.run_ids_at(&ids_at(6), 6).unwrap();
            b.run_ids_at(&ids_at(3), 3).unwrap();
        }
        assert_eq!(b.arena_reallocs(), 2, "mixed-bucket steady state reuses both");
    }

    #[test]
    fn bucketed_forward_matches_full_shape_on_padded_input() {
        // the same content padded to the max shape and run at the full
        // seq_len produces different hidden states only at pad positions;
        // for cls the demuxed [CLS]-anchored logits come from positions
        // that exist in both shapes, but attention mixes pad rows in, so
        // exact equality is NOT expected — instead pin the short shape
        // against the scalar reference (the real contract).
        let b = backend("cls", 1);
        let short = 4usize;
        let ids: Vec<i32> = (0..(2 * (2 + short)) as i32).map(|i| (i * 3) % 200).collect();
        let out = b.run_ids_at(&ids, short).unwrap();
        assert_eq!(out.len(), b.dims().at_seq_len(short).output_len());
        assert!(out.iter().all(|x| x.is_finite()));
        assert!(b.run_ids_at(&ids, 7).is_err(), "beyond the baked max");
    }

    #[test]
    fn int8_backend_runs_and_reports_its_precision() {
        let meta = synthetic_meta("cls", 2, 1, 6, 8, 1, 2, 3);
        let raw = RawWeights::random(&meta, 16, 21);
        let wf = WeightsFile::parse(raw.to_blob()).unwrap();
        let b = NativeBackend::from_weights_prec(meta, wf, Precision::Int8).unwrap();
        assert_eq!(b.precision(), Precision::Int8);
        assert!(b.describe().contains("precision=int8"), "{}", b.describe());
        assert!(b.describe().contains("kernel="), "{}", b.describe());
        let ids: Vec<i32> = (0..b.meta().ids_len() as i32).map(|i| i % 44).collect();
        let out = b.run_ids(&ids).expect("int8 forward");
        assert_eq!(out.len(), b.dims().output_len());
        assert!(out.iter().all(|x| x.is_finite()));
    }

    #[test]
    fn int8_stays_close_to_f32_on_a_small_model() {
        let meta = synthetic_meta("token", 2, 1, 5, 8, 1, 2, 3);
        let raw = RawWeights::random(&meta, 16, 9);
        let wf32 = WeightsFile::parse(raw.to_blob()).unwrap();
        let wq = WeightsFile::parse(raw.to_blob()).unwrap();
        let f = NativeBackend::from_weights(meta.clone(), wf32).unwrap();
        let q = NativeBackend::from_weights_prec(meta, wq, Precision::Int8).unwrap();
        let ids: Vec<i32> = (0..f.meta().ids_len() as i32).map(|i| (i * 7) % 200).collect();
        let of = f.run_ids(&ids).unwrap();
        let oq = q.run_ids(&ids).unwrap();
        assert_eq!(of.len(), oq.len());
        let scale = 1.0 + of.iter().fold(0.0f32, |m, x| m.max(x.abs()));
        for (i, (a, b)) in of.iter().zip(&oq).enumerate() {
            assert!(
                (a - b).abs() <= 0.08 * scale,
                "logit {i}: f32 {a} vs int8 {b} (allowed {})",
                0.08 * scale
            );
        }
    }

    #[test]
    fn flops_model_is_positive_and_grows_with_n() {
        let small = synthetic_meta("cls", 2, 1, 8, 16, 1, 2, 3);
        let large = synthetic_meta("cls", 8, 1, 8, 16, 1, 2, 3);
        let raw_s = RawWeights::random(&small, 32, 1);
        let raw_l = RawWeights::random(&large, 32, 1);
        let bs = NativeBackend::from_weights(small, WeightsFile::parse(raw_s.to_blob()).unwrap())
            .unwrap();
        let bl = NativeBackend::from_weights(large, WeightsFile::parse(raw_l.to_blob()).unwrap())
            .unwrap();
        assert!(bs.dims().flops() > 0.0);
        assert!(bl.dims().flops() > bs.dims().flops(), "longer mux input costs more");
    }
}
