//! Weight resolution and packing for the native forward.
//!
//! [`pack`] turns a [`WeightsFile`] (the `DMUXW1` blob `aot.py` writes)
//! into execution layout: projection matrices are pre-transposed to
//! `(out, in)` row-major for the dot-product GEMM, the mux vectors are
//! pre-scaled by `1/N` and their mean is folded into the positional
//! table (`pos_mux`), and the token-embedding gather table is *not*
//! copied at all — the backend borrows it from the blob through
//! [`WeightsFile::tensor_f32_view`]. Tensors are resolved by their jax
//! pytree path names (`layers/0/wq/w`, `demux/w1h`, ...), never by
//! position, so a reordered blob fails loudly instead of silently
//! mis-wiring.
//!
//! [`RawWeights`] is the artifact-free twin: tests and benches generate
//! a random model here, serialize it through the real `DMUXW1` format,
//! and hand `reference::forward` the same tensors the packed path loads.

#![allow(clippy::needless_range_loop)]

use std::collections::HashMap;

use anyhow::{anyhow, bail, ensure, Result};

use super::quant::QuantMat;
use super::{Dims, NativeTask, Precision};
use crate::runtime::manifest::ArtifactMeta;
use crate::runtime::weights::{Dtype, WeightsFile};
use crate::util::json::{arr, num, obj, s};
use crate::util::rng::Rng;

/// A projection matrix in execution layout: pre-transposed f32, or int8
/// codes with per-output-channel scales, per the backend's precision.
pub(crate) enum Mat {
    F32(Vec<f32>),
    Q8(QuantMat),
}

impl Mat {
    /// The f32 payload, if this matrix is f32 (tests and the f32-only
    /// paths use this).
    pub fn as_f32(&self) -> Option<&[f32]> {
        match self {
            Mat::F32(v) => Some(v),
            Mat::Q8(_) => None,
        }
    }
}

/// One encoder layer in execution layout (`*_t` = pre-transposed).
///
/// The Q/K/V projections are stored **fused**: `wqkv_t` stacks the three
/// transposed `(d, d)` matrices row-wise into one `(3d, d)` matrix whose
/// output channels are `[q(d) | k(d) | v(d)]`, so the forward runs one
/// GEMM over the normed stream instead of three (and, at int8, the
/// activation row is quantized once and read once).
pub(crate) struct LayerPack {
    pub ln1_g: Vec<f32>,
    pub ln1_b: Vec<f32>,
    pub wqkv_t: Mat,
    pub bqkv: Vec<f32>,
    pub wo_t: Mat,
    pub bo: Vec<f32>,
    pub ln2_g: Vec<f32>,
    pub ln2_b: Vec<f32>,
    pub ff1_t: Mat,
    pub fb1: Vec<f32>,
    pub ff2_t: Mat,
    pub fb2: Vec<f32>,
}

/// Everything the forward needs besides the borrowed token table.
pub(crate) struct PackedWeights {
    /// index of `tok_emb` in the blob — gathered zero-copy per forward
    pub tok_idx: usize,
    /// `pos_mux[l] = pos_emb[l] ⊙ mean_n vecs[n]`: the position term of
    /// the fused mux (the shared positional add commutes with the mean
    /// over slots, so it is applied once, pre-multiplied)
    pub pos_mux: Vec<f32>,
    /// `vecs[n] / N` — per-slot Hadamard vector with the mux mean folded in
    pub mux_scaled: Vec<f32>,
    pub layers: Vec<LayerPack>,
    pub lnf_g: Vec<f32>,
    pub lnf_b: Vec<f32>,
    pub w1h_t: Mat,
    pub w1p_t: Mat,
    pub db1: Vec<f32>,
    pub w2_t: Mat,
    pub db2: Vec<f32>,
    pub head_t: Vec<f32>,
    pub head_b: Vec<f32>,
}

/// Stack three same-shape projections into one fused matrix: output
/// channels (rows of the `(n, k)` dot layout) are concatenated, so a
/// single GEMM produces `[a | b | c]` per activation row. All parts come
/// from the same `Resolver::mat` precision, so a mix is a packing bug.
fn fuse3(a: Mat, b: Mat, c: Mat) -> Result<Mat> {
    match (a, b, c) {
        (Mat::F32(mut x), Mat::F32(y), Mat::F32(z)) => {
            x.extend_from_slice(&y);
            x.extend_from_slice(&z);
            Ok(Mat::F32(x))
        }
        (Mat::Q8(x), Mat::Q8(y), Mat::Q8(z)) => Ok(Mat::Q8(QuantMat::concat(&[&x, &y, &z]))),
        _ => bail!("qkv fusion: projection precisions diverged within one layer"),
    }
}

/// Name-indexed access to a weights blob with shape validation.
struct Resolver<'a> {
    wf: &'a WeightsFile,
    by_name: HashMap<&'a str, usize>,
}

impl<'a> Resolver<'a> {
    fn new(wf: &'a WeightsFile) -> Resolver<'a> {
        let by_name = wf.tensors.iter().enumerate().map(|(i, t)| (t.name.as_str(), i)).collect();
        Resolver { wf, by_name }
    }

    fn idx(&self, name: &str) -> Result<usize> {
        self.by_name
            .get(name)
            .copied()
            .ok_or_else(|| anyhow!("weights blob missing tensor '{name}'"))
    }

    fn shape_of(&self, name: &str) -> Result<&'a [usize]> {
        Ok(&self.wf.tensors[self.idx(name)?].shape)
    }

    fn view(&self, name: &str, shape: &[usize]) -> Result<&'a [f32]> {
        let i = self.idx(name)?;
        let t = &self.wf.tensors[i];
        ensure!(
            t.shape.as_slice() == shape,
            "tensor '{name}' shape {:?} != expected {:?}",
            t.shape,
            shape
        );
        self.wf.tensor_f32_view(i)
    }

    fn vec(&self, name: &str, shape: &[usize]) -> Result<Vec<f32>> {
        Ok(self.view(name, shape)?.to_vec())
    }

    /// `(rows, cols)` tensor copied transposed to `(cols, rows)`.
    fn transposed(&self, name: &str, rows: usize, cols: usize) -> Result<Vec<f32>> {
        let src = self.view(name, &[rows, cols])?;
        let mut out = vec![0.0f32; rows * cols];
        for r in 0..rows {
            for c in 0..cols {
                out[c * rows + r] = src[r * cols + c];
            }
        }
        Ok(out)
    }

    /// A `(rows, cols)` projection resolved into execution layout at the
    /// requested precision, converting across the blob's storage dtype:
    /// f32 blobs are quantized online for `Precision::Int8` (bitwise the
    /// same codes a `DMUXW2` writer would store), int8 blobs are
    /// dequantized for `Precision::F32`.
    fn mat(&self, name: &str, rows: usize, cols: usize, precision: Precision) -> Result<Mat> {
        let i = self.idx(name)?;
        let t = &self.wf.tensors[i];
        ensure!(
            t.shape.as_slice() == [rows, cols],
            "tensor '{name}' shape {:?} != expected {:?}",
            t.shape,
            [rows, cols]
        );
        match t.dtype {
            Dtype::F32 => {
                let bt = self.transposed(name, rows, cols)?;
                Ok(match precision {
                    Precision::F32 => Mat::F32(bt),
                    Precision::Int8 => Mat::Q8(QuantMat::from_bt(&bt, cols, rows)),
                })
            }
            Dtype::I8 => {
                let data = self.wf.tensor_i8_view(i)?;
                let scales = self.wf.tensor_scales(i)?;
                ensure!(
                    scales.len() == cols,
                    "tensor '{name}' has {} scales for {cols} output channels",
                    scales.len()
                );
                let qm = QuantMat::from_parts(data, scales, rows, cols);
                Ok(match precision {
                    Precision::F32 => Mat::F32(qm.dequantize(cols, rows)),
                    Precision::Int8 => Mat::Q8(qm),
                })
            }
        }
    }
}

/// Validate the artifact against the blob and build execution layout at
/// the requested weight precision.
pub(crate) fn pack(
    meta: &ArtifactMeta,
    wf: &WeightsFile,
    precision: Precision,
) -> Result<(Dims, PackedWeights)> {
    match meta.mux.as_str() {
        "hadamard" | "learned_hadamard" | "binary" | "identity" => {}
        other => bail!(
            "native backend: unsupported mux strategy '{other}' \
             (vector strategies only; ortho needs per-slot matrices)"
        ),
    }
    ensure!(
        meta.demux == "index_embed",
        "native backend: unsupported demux strategy '{}'",
        meta.demux
    );
    let task = match meta.task.as_str() {
        "cls" => NativeTask::Cls,
        "token" => NativeTask::Token,
        other => bail!("native backend: unsupported task '{other}'"),
    };
    ensure!(meta.n_layers >= 1, "native backend: model needs at least one layer");
    ensure!(
        meta.input_len == meta.seq_len + meta.n_mux,
        "native backend: expected index-prefix layout input_len = seq_len + n_mux, \
         got {} != {} + {}",
        meta.input_len,
        meta.seq_len,
        meta.n_mux
    );
    ensure!(
        meta.n_heads >= 1 && meta.d_model % meta.n_heads == 0,
        "native backend: d_model {} not divisible by n_heads {}",
        meta.d_model,
        meta.n_heads
    );
    if meta.n_weight_tensors != 0 {
        ensure!(
            wf.tensors.len() == meta.n_weight_tensors,
            "{}: weights file has {} tensors, manifest says {}",
            meta.name,
            wf.tensors.len(),
            meta.n_weight_tensors
        );
    }

    let d = meta.d_model;
    let head_name = match task {
        NativeTask::Cls => "head_cls",
        NativeTask::Token => "head_token",
    };
    let r = Resolver::new(wf);

    // hidden widths live only in the blob, not the manifest
    let ff1_shape = r.shape_of("layers/0/ff1/w")?;
    ensure!(
        ff1_shape.len() == 2 && ff1_shape[0] == d,
        "layers/0/ff1/w must be (d_model, d_ff), got {ff1_shape:?}"
    );
    let d_ff = ff1_shape[1];
    let w1h_shape = r.shape_of("demux/w1h")?;
    ensure!(
        w1h_shape.len() == 2 && w1h_shape[0] == d,
        "demux/w1h must be (d_model, d_demux), got {w1h_shape:?}"
    );
    let d_demux = w1h_shape[1];

    let dims = Dims {
        batch: meta.batch,
        n_mux: meta.n_mux,
        seq_len: meta.seq_len,
        prefix_len: meta.n_mux,
        input_len: meta.input_len,
        vocab_size: meta.vocab_size,
        d_model: d,
        n_layers: meta.n_layers,
        n_heads: meta.n_heads,
        d_head: d / meta.n_heads,
        d_ff,
        d_demux,
        n_classes: meta.n_classes,
        task,
    };

    let mut layers = Vec::with_capacity(meta.n_layers);
    for li in 0..meta.n_layers {
        let p = |stem: &str| format!("layers/{li}/{stem}");
        let wq = r.mat(&p("wq/w"), d, d, precision)?;
        let wk = r.mat(&p("wk/w"), d, d, precision)?;
        let wv = r.mat(&p("wv/w"), d, d, precision)?;
        let mut bqkv = r.vec(&p("wq/b"), &[d])?;
        bqkv.extend(r.vec(&p("wk/b"), &[d])?);
        bqkv.extend(r.vec(&p("wv/b"), &[d])?);
        layers.push(LayerPack {
            ln1_g: r.vec(&p("ln1/g"), &[d])?,
            ln1_b: r.vec(&p("ln1/b"), &[d])?,
            wqkv_t: fuse3(wq, wk, wv)?,
            bqkv,
            wo_t: r.mat(&p("wo/w"), d, d, precision)?,
            bo: r.vec(&p("wo/b"), &[d])?,
            ln2_g: r.vec(&p("ln2/g"), &[d])?,
            ln2_b: r.vec(&p("ln2/b"), &[d])?,
            ff1_t: r.mat(&p("ff1/w"), d, d_ff, precision)?,
            fb1: r.vec(&p("ff1/b"), &[d_ff])?,
            ff2_t: r.mat(&p("ff2/w"), d_ff, d, precision)?,
            fb2: r.vec(&p("ff2/b"), &[d])?,
        });
    }

    let vecs = r.view("mux/vecs", &[meta.n_mux, d])?;
    let inv_n = 1.0 / meta.n_mux as f32;
    let mux_scaled: Vec<f32> = vecs.iter().map(|v| v * inv_n).collect();
    let mut mean = vec![0.0f32; d];
    for n in 0..meta.n_mux {
        for dd in 0..d {
            mean[dd] += vecs[n * d + dd] * inv_n;
        }
    }
    let pos = r.view("pos_emb", &[meta.input_len, d])?;
    let mut pos_mux = vec![0.0f32; meta.input_len * d];
    for l in 0..meta.input_len {
        for dd in 0..d {
            pos_mux[l * d + dd] = pos[l * d + dd] * mean[dd];
        }
    }
    // shape + alignment validated once here; the forward gathers from the
    // blob without copying
    r.view("tok_emb", &[meta.vocab_size, d])?;
    let tok_idx = r.idx("tok_emb")?;

    let packed = PackedWeights {
        tok_idx,
        pos_mux,
        mux_scaled,
        layers,
        lnf_g: r.vec("ln_f/g", &[d])?,
        lnf_b: r.vec("ln_f/b", &[d])?,
        w1h_t: r.mat("demux/w1h", d, d_demux, precision)?,
        w1p_t: r.mat("demux/w1p", d, d_demux, precision)?,
        db1: r.vec("demux/b1", &[d_demux])?,
        w2_t: r.mat("demux/w2", d_demux, d, precision)?,
        db2: r.vec("demux/b2", &[d])?,
        head_t: r.transposed(&format!("{head_name}/w"), d, meta.n_classes)?,
        head_b: r.vec(&format!("{head_name}/b"), &[meta.n_classes])?,
    };
    Ok((dims, packed))
}

/// Named tensors in the exact jax pytree flatten order `aot.py` writes —
/// an artifact-free stand-in for a trained weights blob.
pub struct RawWeights {
    /// `(pytree path, shape, row-major data)`
    pub tensors: Vec<(String, Vec<usize>, Vec<f32>)>,
}

impl RawWeights {
    pub fn get(&self, name: &str) -> Option<(&[usize], &[f32])> {
        self.tensors
            .iter()
            .find(|(n, _, _)| n == name)
            .map(|(_, shape, data)| (shape.as_slice(), data.as_slice()))
    }

    /// A randomly-initialized T-MUX model for `meta`'s shapes, in the
    /// init scales `python/compile/model.py::init_params` uses.
    /// Deterministic in `(meta shapes, seed)`.
    pub fn random(meta: &ArtifactMeta, d_ff: usize, seed: u64) -> RawWeights {
        let d = meta.d_model;
        let fd = 2 * d; // demux MLP hidden width (model.py: fd = 2 * d)
        let n_cls = meta.n_classes;
        let mut rng = Rng::new(seed);
        let mut tensors: Vec<(String, Vec<usize>, Vec<f32>)> = Vec::new();
        fn gauss(rng: &mut Rng, len: usize, scale: f64) -> Vec<f32> {
            (0..len).map(|_| (rng.normal() * scale) as f32).collect()
        }
        fn dense_scale(d_in: usize, d_out: usize) -> f64 {
            (2.0 / (d_in + d_out) as f64).sqrt()
        }
        let head = match meta.task.as_str() {
            "token" => "head_token",
            _ => "head_cls",
        };
        // jax flattens dicts alphabetically; this order mirrors aot.py
        tensors.push(("demux/b1".into(), vec![fd], vec![0.0; fd]));
        tensors.push(("demux/b2".into(), vec![d], vec![0.0; d]));
        let demux_scale = 1.0 / (d as f64).sqrt();
        tensors.push(("demux/w1h".into(), vec![d, fd], gauss(&mut rng, d * fd, demux_scale)));
        tensors.push(("demux/w1p".into(), vec![d, fd], gauss(&mut rng, d * fd, demux_scale)));
        let w2_scale = 1.0 / (fd as f64).sqrt();
        tensors.push(("demux/w2".into(), vec![fd, d], gauss(&mut rng, fd * d, w2_scale)));
        tensors.push((format!("{head}/b"), vec![n_cls], vec![0.0; n_cls]));
        tensors.push((
            format!("{head}/w"),
            vec![d, n_cls],
            gauss(&mut rng, d * n_cls, dense_scale(d, n_cls)),
        ));
        for li in 0..meta.n_layers {
            let p = |stem: &str| format!("layers/{li}/{stem}");
            let ff_scale = dense_scale(d, d_ff);
            tensors.push((p("ff1/b"), vec![d_ff], vec![0.0; d_ff]));
            tensors.push((p("ff1/w"), vec![d, d_ff], gauss(&mut rng, d * d_ff, ff_scale)));
            tensors.push((p("ff2/b"), vec![d], vec![0.0; d]));
            tensors.push((p("ff2/w"), vec![d_ff, d], gauss(&mut rng, d_ff * d, ff_scale)));
            tensors.push((p("ln1/b"), vec![d], vec![0.0; d]));
            tensors.push((p("ln1/g"), vec![d], vec![1.0; d]));
            tensors.push((p("ln2/b"), vec![d], vec![0.0; d]));
            tensors.push((p("ln2/g"), vec![d], vec![1.0; d]));
            for w in ["wk", "wo", "wq", "wv"] {
                tensors.push((p(&format!("{w}/b")), vec![d], vec![0.0; d]));
                tensors.push((
                    p(&format!("{w}/w")),
                    vec![d, d],
                    gauss(&mut rng, d * d, dense_scale(d, d)),
                ));
            }
        }
        tensors.push(("ln_f/b".into(), vec![d], vec![0.0; d]));
        tensors.push(("ln_f/g".into(), vec![d], vec![1.0; d]));
        tensors.push((
            "mux/vecs".into(),
            vec![meta.n_mux, d],
            gauss(&mut rng, meta.n_mux * d, 1.0),
        ));
        tensors.push((
            "pos_emb".into(),
            vec![meta.input_len, d],
            gauss(&mut rng, meta.input_len * d, 0.02),
        ));
        tensors.push((
            "tok_emb".into(),
            vec![meta.vocab_size, d],
            gauss(&mut rng, meta.vocab_size * d, 0.02),
        ));
        RawWeights { tensors }
    }

    /// Serialize as a `DMUXW1` blob — byte-compatible with
    /// `aot.py::write_weights`, so loading goes through the real
    /// [`WeightsFile`] parser.
    pub fn to_blob(&self) -> Vec<u8> {
        let mut entries = Vec::new();
        let mut offset = 0usize;
        for (name, shape, data) in &self.tensors {
            let nbytes = data.len() * 4;
            entries.push(obj(vec![
                ("name", s(name)),
                ("shape", arr(shape.iter().map(|&x| num(x as f64)))),
                ("dtype", s("f32")),
                ("offset", num(offset as f64)),
                ("nbytes", num(nbytes as f64)),
            ]));
            offset += nbytes;
        }
        let header = obj(vec![("tensors", arr(entries))]).to_string();
        let mut out = Vec::with_capacity(11 + header.len() + offset);
        out.extend_from_slice(b"DMUXW1\n");
        out.extend_from_slice(&(header.len() as u32).to_le_bytes());
        out.extend_from_slice(header.as_bytes());
        for (_, _, data) in &self.tensors {
            for &v in data {
                out.extend_from_slice(&v.to_le_bytes());
            }
        }
        out
    }

    /// Serialize as a `DMUXW2` blob with the projection matrices stored
    /// int8 (per-output-channel symmetric scales) and everything else
    /// f32. Uses the same fold order and ties-to-even rounding as the
    /// online quantizer (`QuantMat::from_bt`), so a backend loaded from
    /// this blob is bitwise identical to one quantized at load time.
    pub fn to_blob_q8(&self) -> Vec<u8> {
        let mut entries = Vec::new();
        let mut payload: Vec<u8> = Vec::new();
        for (name, shape, data) in &self.tensors {
            if quantized_in_blob(name, shape) {
                let (rows, cols) = (shape[0], shape[1]);
                let mut scales = vec![0.0f32; cols];
                let mut codes = vec![0i8; rows * cols];
                for o in 0..cols {
                    let mut amax = 0.0f32;
                    for r in 0..rows {
                        amax = amax.max(data[r * cols + o].abs());
                    }
                    if amax <= 0.0 {
                        continue;
                    }
                    let inv = 63.0 / amax;
                    scales[o] = amax / 63.0;
                    for r in 0..rows {
                        codes[r * cols + o] = (data[r * cols + o] * inv).round_ties_even() as i32 as i8;
                    }
                }
                let offset = payload.len();
                let nbytes = codes.len();
                payload.extend(codes.iter().map(|&q| q as u8));
                while payload.len() % 4 != 0 {
                    payload.push(0); // pad so the scales stay 4-aligned
                }
                let scales_offset = payload.len();
                for &sc in &scales {
                    payload.extend_from_slice(&sc.to_le_bytes());
                }
                entries.push(obj(vec![
                    ("name", s(name)),
                    ("shape", arr(shape.iter().map(|&x| num(x as f64)))),
                    ("dtype", s("i8")),
                    ("offset", num(offset as f64)),
                    ("nbytes", num(nbytes as f64)),
                    ("scales_offset", num(scales_offset as f64)),
                    ("scales_nbytes", num((scales.len() * 4) as f64)),
                ]));
            } else {
                let offset = payload.len();
                let nbytes = data.len() * 4;
                for &v in data {
                    payload.extend_from_slice(&v.to_le_bytes());
                }
                entries.push(obj(vec![
                    ("name", s(name)),
                    ("shape", arr(shape.iter().map(|&x| num(x as f64)))),
                    ("dtype", s("f32")),
                    ("offset", num(offset as f64)),
                    ("nbytes", num(nbytes as f64)),
                ]));
            }
        }
        let header = obj(vec![("tensors", arr(entries))]).to_string();
        let mut out = Vec::with_capacity(11 + header.len() + payload.len());
        out.extend_from_slice(b"DMUXW2\n");
        out.extend_from_slice(&(header.len() as u32).to_le_bytes());
        out.extend_from_slice(header.as_bytes());
        out.extend_from_slice(&payload);
        out
    }

    /// Total tensor count (what the manifest's `n_weight_tensors` pins).
    pub fn len(&self) -> usize {
        self.tensors.len()
    }

    pub fn is_empty(&self) -> bool {
        self.tensors.is_empty()
    }
}

/// Which tensors the `DMUXW2` writer stores int8: the 2-D projection
/// matrices the forward multiplies by (encoder projections + demux MLP).
/// Embeddings, biases, layer-norm params, and the task head stay f32.
fn quantized_in_blob(name: &str, shape: &[usize]) -> bool {
    shape.len() == 2
        && ((name.starts_with("layers/") && name.ends_with("/w"))
            || matches!(name, "demux/w1h" | "demux/w1p" | "demux/w2"))
}

#[cfg(test)]
mod tests {
    use super::*;

    fn meta() -> ArtifactMeta {
        super::super::synthetic_meta("cls", 2, 1, 6, 8, 1, 2, 3)
    }

    #[test]
    fn random_blob_roundtrips_through_the_weights_parser() {
        let m = meta();
        let raw = RawWeights::random(&m, 16, 5);
        let wf = WeightsFile::parse(raw.to_blob()).expect("parse");
        assert_eq!(wf.tensors.len(), raw.len());
        for (i, (name, shape, data)) in raw.tensors.iter().enumerate() {
            assert_eq!(&wf.tensors[i].name, name);
            assert_eq!(&wf.tensors[i].shape, shape);
            assert_eq!(wf.tensor_f32_view(i).expect("view"), data.as_slice());
        }
    }

    #[test]
    fn pack_resolves_by_name_and_transposes() {
        let m = meta();
        let raw = RawWeights::random(&m, 16, 6);
        let wf = WeightsFile::parse(raw.to_blob()).unwrap();
        let (dims, packed) = pack(&m, &wf, Precision::F32).expect("pack");
        assert_eq!(dims.d_ff, 16);
        assert_eq!(dims.d_demux, 16);
        assert_eq!(dims.d_head, 4);
        let (shape, wq) = raw.get("layers/0/wq/w").unwrap();
        let (_, wk) = raw.get("layers/0/wk/w").unwrap();
        let (_, wv) = raw.get("layers/0/wv/w").unwrap();
        let d = shape[0];
        // fused QKV: rows 0..d are wq^T, d..2d are wk^T, 2d..3d are wv^T
        let qkv = packed.layers[0].wqkv_t.as_f32().expect("f32 precision packs f32 mats");
        assert_eq!(qkv.len(), 3 * d * d);
        for (block, w) in [wq, wk, wv].into_iter().enumerate() {
            for r in 0..d {
                for c in 0..d {
                    assert_eq!(qkv[(block * d + c) * d + r], w[r * d + c]);
                }
            }
        }
        let (_, bq) = raw.get("layers/0/wq/b").unwrap();
        let (_, bv) = raw.get("layers/0/wv/b").unwrap();
        assert_eq!(&packed.layers[0].bqkv[..d], bq);
        assert_eq!(&packed.layers[0].bqkv[2 * d..], bv);
        // fused mux precomputation: vecs/N and pos ⊙ mean(vecs)
        let (_, vecs) = raw.get("mux/vecs").unwrap();
        let (_, pos) = raw.get("pos_emb").unwrap();
        let n = m.n_mux;
        for dd in 0..d {
            let mean: f32 = (0..n).map(|s| vecs[s * d + dd]).sum::<f32>() / n as f32;
            assert!((packed.pos_mux[dd] - pos[dd] * mean).abs() < 1e-6);
            assert!((packed.mux_scaled[dd] - vecs[dd] / n as f32).abs() < 1e-6);
        }
    }

    #[test]
    fn pack_rejects_unsupported_configs() {
        let mut m = meta();
        m.mux = "ortho".into();
        let raw = RawWeights::random(&meta(), 16, 7);
        let wf = WeightsFile::parse(raw.to_blob()).unwrap();
        assert!(pack(&m, &wf, Precision::F32).is_err(), "ortho mux must be rejected");
        let mut m = meta();
        m.demux = "mlp".into();
        let wf = WeightsFile::parse(raw.to_blob()).unwrap();
        assert!(pack(&m, &wf, Precision::F32).is_err(), "mlp demux must be rejected");
        let mut m = meta();
        m.task = "retrieval".into();
        let wf = WeightsFile::parse(raw.to_blob()).unwrap();
        assert!(pack(&m, &wf, Precision::F32).is_err(), "retrieval must be rejected");
    }

    #[test]
    fn pack_reports_missing_tensors_by_name() {
        let m = meta();
        let mut raw = RawWeights::random(&m, 16, 8);
        raw.tensors.retain(|(n, _, _)| n != "demux/w1h");
        let wf = WeightsFile::parse(raw.to_blob()).unwrap();
        let mut m2 = m.clone();
        m2.n_weight_tensors = raw.len();
        let err = pack(&m2, &wf, Precision::F32).unwrap_err().to_string();
        assert!(err.contains("demux/w1h"), "{err}");
    }

    #[test]
    fn q8_blob_roundtrips_and_keeps_nonprojection_tensors_f32() {
        let m = meta();
        let raw = RawWeights::random(&m, 16, 9);
        let wf = WeightsFile::parse(raw.to_blob_q8()).expect("parse DMUXW2");
        assert_eq!(wf.tensors.len(), raw.len());
        for (i, (name, shape, data)) in raw.tensors.iter().enumerate() {
            assert_eq!(&wf.tensors[i].name, name);
            assert_eq!(&wf.tensors[i].shape, shape);
            if quantized_in_blob(name, shape) {
                assert_eq!(wf.tensors[i].dtype, crate::runtime::weights::Dtype::I8);
                assert_eq!(wf.tensor_scales(i).unwrap().len(), shape[1]);
            } else {
                assert_eq!(wf.tensors[i].dtype, crate::runtime::weights::Dtype::F32);
                assert_eq!(wf.tensor_f32_view(i).unwrap(), data.as_slice());
            }
        }
        // both precisions pack from the quantized blob
        assert!(pack(&m, &wf, Precision::Int8).is_ok());
        assert!(pack(&m, &wf, Precision::F32).is_ok());
    }

    /// The writer's per-column quantization and the online `from_bt`
    /// quantization of the same f32 tensor must agree bitwise — this is
    /// what makes a `DMUXW2`-loaded backend identical to an
    /// online-quantized one.
    #[test]
    fn blob_quantization_matches_online_quantization_bitwise() {
        let m = meta();
        let raw = RawWeights::random(&m, 16, 10);
        let wf_f32 = WeightsFile::parse(raw.to_blob()).unwrap();
        let wf_q8 = WeightsFile::parse(raw.to_blob_q8()).unwrap();
        let (_, from_f32) = pack(&m, &wf_f32, Precision::Int8).unwrap();
        let (_, from_q8) = pack(&m, &wf_q8, Precision::Int8).unwrap();
        let pairs = [
            (&from_f32.layers[0].wqkv_t, &from_q8.layers[0].wqkv_t),
            (&from_f32.layers[0].ff1_t, &from_q8.layers[0].ff1_t),
            (&from_f32.w1h_t, &from_q8.w1h_t),
            (&from_f32.w2_t, &from_q8.w2_t),
        ];
        for (a, b) in pairs {
            match (a, b) {
                (Mat::Q8(x), Mat::Q8(y)) => {
                    assert_eq!(x.q, y.q);
                    assert_eq!(x.wsum, y.wsum);
                    assert_eq!(
                        x.scales.iter().map(|v| v.to_bits()).collect::<Vec<_>>(),
                        y.scales.iter().map(|v| v.to_bits()).collect::<Vec<_>>()
                    );
                }
                _ => panic!("Int8 precision must pack Q8 mats"),
            }
        }
    }
}
