//! Int8 quantization for the native backend: symmetric per-output-channel
//! weight quantization, dynamic symmetric per-row activation quantization,
//! and the scalar int8 GEMM arm.
//!
//! Scheme (matches the `DMUXW2` on-disk format, see DESIGN.md):
//! - Weights: per output channel o, `s_w[o] = max|w[·,o]| / 63`; codes are
//!   `round_ties_even(w / s_w)` clamped by construction to ±63. 7-bit
//!   codes keep the AVX2 `maddubs` pair-sums inside i16 (2·255·63 < 2^15).
//! - Activations: per row, `s_a = max|x| / 127`, stored biased as
//!   `u8 = q + 128` so the unsigned×signed `maddubs` path applies; the
//!   bias is removed exactly in the epilogue via the precomputed per-
//!   channel weight sums (`acc - 128·Σq_w`).
//! - `dequant` is the single f32 epilogue shared by the scalar and AVX2
//!   arms, which keeps the two bitwise-identical.
#![allow(clippy::needless_range_loop)]

const W_QMAX: f32 = 63.0;
const A_QMAX: f32 = 127.0;

/// A weight matrix quantized to int8, stored (n, k) row-major — row o is
/// output channel o, i.e. the same transposed-for-dot layout the f32
/// `*_t` matrices use.
pub(crate) struct QuantMat {
    /// int8 codes, `q[o*k + p]`.
    pub q: Vec<i8>,
    /// Per-output-channel scale: `w ≈ q * scales[o]`.
    pub scales: Vec<f32>,
    /// Per-output-channel code sum `Σ_p q[o*k+p]`, used to cancel the
    /// +128 activation bias exactly in the epilogue.
    pub wsum: Vec<i32>,
}

impl QuantMat {
    /// Quantize an already-transposed (n, k) f32 matrix. This is the
    /// same fold order and rounding the `DMUXW2` writer uses, so a
    /// blob-quantized tensor loads to bitwise-identical codes.
    pub fn from_bt(bt: &[f32], n: usize, k: usize) -> QuantMat {
        assert_eq!(bt.len(), n * k);
        let mut q = vec![0i8; n * k];
        let mut scales = vec![0.0f32; n];
        let mut wsum = vec![0i32; n];
        for o in 0..n {
            let row = &bt[o * k..(o + 1) * k];
            let mut amax = 0.0f32;
            for &v in row {
                amax = amax.max(v.abs());
            }
            if amax <= 0.0 {
                continue; // scale stays 0.0, codes stay 0
            }
            let inv = W_QMAX / amax;
            scales[o] = amax / W_QMAX;
            let dst = &mut q[o * k..(o + 1) * k];
            let mut s = 0i32;
            for (d, &v) in dst.iter_mut().zip(row) {
                let qi = (v * inv).round_ties_even() as i32;
                *d = qi as i8;
                s += qi;
            }
            wsum[o] = s;
        }
        QuantMat { q, scales, wsum }
    }

    /// Assemble from a `DMUXW2` tensor: `data` is the blob's (k, n)
    /// row-major int8 payload, `scales` its per-column scales. Transposes
    /// to the (n, k) dot layout and recomputes the code sums.
    pub fn from_parts(data: &[i8], scales: &[f32], k: usize, n: usize) -> QuantMat {
        assert_eq!(data.len(), k * n);
        assert_eq!(scales.len(), n);
        let mut q = vec![0i8; n * k];
        let mut wsum = vec![0i32; n];
        for p in 0..k {
            for o in 0..n {
                let v = data[p * n + o];
                q[o * k + p] = v;
                wsum[o] += v as i32;
            }
        }
        QuantMat { q, scales: scales.to_vec(), wsum }
    }

    /// Concatenate the output channels of several quantized matrices
    /// that share the same `k` (row-wise in the (n, k) dot layout).
    /// Per-channel scales and code sums are channel-local, so the fused
    /// matrix is exactly the stack of its parts — this is what backs the
    /// fused-QKV packing at int8 precision.
    pub fn concat(parts: &[&QuantMat]) -> QuantMat {
        let total_codes: usize = parts.iter().map(|p| p.q.len()).sum();
        let total_n: usize = parts.iter().map(|p| p.scales.len()).sum();
        let mut q = Vec::with_capacity(total_codes);
        let mut scales = Vec::with_capacity(total_n);
        let mut wsum = Vec::with_capacity(total_n);
        for p in parts {
            q.extend_from_slice(&p.q);
            scales.extend_from_slice(&p.scales);
            wsum.extend_from_slice(&p.wsum);
        }
        QuantMat { q, scales, wsum }
    }

    /// Expand back to the (n, k) f32 dot layout (used when `--precision
    /// f32` is requested against an int8 blob).
    pub fn dequantize(&self, n: usize, k: usize) -> Vec<f32> {
        assert_eq!(self.q.len(), n * k);
        let mut out = vec![0.0f32; n * k];
        for o in 0..n {
            let s = self.scales[o];
            for p in 0..k {
                out[o * k + p] = self.q[o * k + p] as f32 * s;
            }
        }
        out
    }
}

/// The one f32 epilogue both int8 GEMM arms share: remove the +128
/// activation bias exactly, apply both scales, add the f32 bias.
#[inline]
// lint: hot-path
pub(crate) fn dequant(acc: i32, wsum: i32, sa: f32, sw: f32, bias: f32) -> f32 {
    (acc - 128 * wsum) as f32 * (sa * sw) + bias
}

/// Scalar arm of the per-row activation quantizer. `round_ties_even`
/// matches `_mm256_cvtps_epi32` under the default MXCSR, so the AVX2 arm
/// produces identical codes. Returns the row scale (`amax/127`), or 0.0
/// for an all-zero row (codes all 128 = bias).
// lint: hot-path
pub(crate) fn quantize_row_scalar(x: &[f32], out: &mut [u8]) -> f32 {
    let k = x.len();
    let mut amax = 0.0f32;
    for &v in x {
        amax = amax.max(v.abs());
    }
    if amax <= 0.0 {
        out[..k].fill(128);
        return 0.0;
    }
    let inv = A_QMAX / amax;
    for (o, &v) in out[..k].iter_mut().zip(x) {
        *o = ((v * inv).round_ties_even() as i32 + 128) as u8;
    }
    amax / A_QMAX
}

/// Quantize m rows of activations, dispatching to the AVX2 arm when it
/// is the active kernel. Scales land in `ascale[..m]`, codes in
/// `aq[..m*k]`.
pub(crate) fn quantize_rows(a: &[f32], m: usize, k: usize, aq: &mut [u8], ascale: &mut [f32]) {
    assert!(a.len() >= m * k && aq.len() >= m * k && ascale.len() >= m);
    #[cfg(target_arch = "x86_64")]
    if super::simd::active_kernel() == super::simd::Kernel::Avx2Fma {
        for i in 0..m {
            // SAFETY: feature presence verified by `active_kernel`; the
            // row slices are length-checked by the assert above.
            ascale[i] =
                unsafe { super::simd::quantize_row_avx2(&a[i * k..(i + 1) * k], &mut aq[i * k..(i + 1) * k]) };
        }
        return;
    }
    for i in 0..m {
        ascale[i] = quantize_row_scalar(&a[i * k..(i + 1) * k], &mut aq[i * k..(i + 1) * k]);
    }
}

/// Scalar int8 GEMM arm: exact i32 accumulation, shared `dequant`
/// epilogue. Same contract as `simd::gemm_bt_q8_avx2`.
#[allow(clippy::too_many_arguments)]
// lint: hot-path
pub(crate) fn gemm_bt_q8_scalar(
    aq: &[u8],
    ascale: &[f32],
    w: &QuantMat,
    bias: Option<&[f32]>,
    c: &mut [f32],
    m: usize,
    k: usize,
    n: usize,
) {
    for i in 0..m {
        let ar = &aq[i * k..(i + 1) * k];
        let cr = &mut c[i * n..(i + 1) * n];
        let sa = ascale[i];
        for j in 0..n {
            let wr = &w.q[j * k..(j + 1) * k];
            let mut acc = 0i32;
            for p in 0..k {
                acc += ar[p] as i32 * wr[p] as i32;
            }
            let b = match bias {
                Some(b) => b[j],
                None => 0.0,
            };
            cr[j] = dequant(acc, w.wsum[j], sa, w.scales[j], b);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    #[test]
    fn from_bt_codes_stay_within_seven_bits_and_sums_match() {
        let mut rng = Rng::new(7);
        let (n, k) = (9, 33);
        let bt: Vec<f32> = (0..n * k).map(|_| rng.normal() as f32 * 2.0).collect();
        let w = QuantMat::from_bt(&bt, n, k);
        for o in 0..n {
            let mut s = 0i32;
            for p in 0..k {
                let q = w.q[o * k + p] as i32;
                assert!((-63..=63).contains(&q), "code {q} out of 7-bit range");
                s += q;
            }
            assert_eq!(s, w.wsum[o]);
        }
    }

    #[test]
    fn dequantize_roundtrip_error_is_bounded_by_half_step() {
        let mut rng = Rng::new(8);
        let (n, k) = (5, 17);
        let bt: Vec<f32> = (0..n * k).map(|_| rng.normal() as f32).collect();
        let w = QuantMat::from_bt(&bt, n, k);
        let back = w.dequantize(n, k);
        for o in 0..n {
            // half a quantization step per element, plus f32 slack
            let tol = 0.5 * w.scales[o] + 1e-6;
            for p in 0..k {
                let err = (back[o * k + p] - bt[o * k + p]).abs();
                assert!(err <= tol, "err {err} > tol {tol}");
            }
        }
    }

    #[test]
    fn from_parts_transposes_to_from_bt_layout() {
        let mut rng = Rng::new(9);
        let (k, n) = (6, 4);
        let bt: Vec<f32> = (0..n * k).map(|_| rng.normal() as f32).collect();
        let w = QuantMat::from_bt(&bt, n, k);
        // serialize the codes the way the blob stores them: (k, n)
        let mut blob = vec![0i8; k * n];
        for o in 0..n {
            for p in 0..k {
                blob[p * n + o] = w.q[o * k + p];
            }
        }
        let w2 = QuantMat::from_parts(&blob, &w.scales, k, n);
        assert_eq!(w.q, w2.q);
        assert_eq!(w.wsum, w2.wsum);
        assert_eq!(
            w.scales.iter().map(|s| s.to_bits()).collect::<Vec<_>>(),
            w2.scales.iter().map(|s| s.to_bits()).collect::<Vec<_>>()
        );
    }

    #[test]
    fn zero_row_quantizes_to_bias_code_and_zero_scale() {
        let x = vec![0.0f32; 11];
        let mut q = vec![0u8; 11];
        assert_eq!(quantize_row_scalar(&x, &mut q), 0.0);
        assert!(q.iter().all(|&v| v == 128));
        // and the zero scale kills the row in dequant
        assert_eq!(dequant(12345, 678, 0.0, 0.5, 1.5), 1.5);
    }

    #[test]
    fn scalar_q8_gemm_tracks_f32_within_quantization_noise() {
        let mut rng = Rng::new(10);
        let (m, k, n) = (3, 40, 6);
        let a: Vec<f32> = (0..m * k).map(|_| rng.normal() as f32).collect();
        let bt: Vec<f32> = (0..n * k).map(|_| rng.normal() as f32).collect();
        let w = QuantMat::from_bt(&bt, n, k);
        let mut aq = vec![0u8; m * k];
        let mut ascale = vec![0.0f32; m];
        quantize_rows(&a, m, k, &mut aq, &mut ascale);
        let mut got = vec![0.0f32; m * n];
        gemm_bt_q8_scalar(&aq, &ascale, &w, None, &mut got, m, k, n);
        let mut want = vec![0.0f32; m * n];
        super::super::gemm::gemm_bt(&a, &bt, None, &mut want, m, k, n);
        for i in 0..m {
            for j in 0..n {
                let bound = 0.0125 * k as f32 * (ascale[i] * 127.0) * (w.scales[j] * 63.0) + 1e-5;
                let err = (got[i * n + j] - want[i * n + j]).abs();
                assert!(err <= bound, "({i},{j}): err {err} > bound {bound}");
            }
        }
    }
}
