//! Straightforward scalar T-MUX forward from raw tensors — the oracle
//! for the optimized native path and the live "naive unfused" baseline
//! in `benches/native_forward.rs` (same pattern as `engine_hotpath`'s
//! inline legacy path: the baseline is measured on the same machine,
//! never a stale constant).
//!
//! Deliberately unoptimized: the per-slot transformed embeddings
//! `phi^i(x^i)` are fully materialized before the mux mean, every
//! projection is a textbook ijk triple loop over the blob's untransposed
//! `(in, out)` layout (stride-`n` weight walks), nothing is blocked,
//! pre-transposed, fused, arena-reused, or threaded, and every
//! intermediate allocates. Keep it that way — its slowness is the point.
//!
//! The reference is **f32-only by design**: it is the single scalar
//! oracle both execution arms answer to. The f32 path must match it
//! within float-reassociation tolerance; the int8 path is pinned against
//! the f32 path separately (`tests/native.rs`) with a quantization-noise
//! bound, so it inherits this oracle transitively.

#![allow(clippy::needless_range_loop)]

use anyhow::{anyhow, bail, ensure, Result};

use super::pack::RawWeights;
use crate::runtime::manifest::ArtifactMeta;

fn tensor<'a>(raw: &'a RawWeights, name: &str) -> Result<(&'a [usize], &'a [f32])> {
    raw.get(name).ok_or_else(|| anyhow!("reference: missing tensor '{name}'"))
}

/// Naive `(m, k) @ (k, n) + bias` over the untransposed weight layout.
fn matmul(a: &[f32], w: &[f32], bias: Option<&[f32]>, m: usize, k: usize, n: usize) -> Vec<f32> {
    let mut c = vec![0.0f32; m * n];
    for i in 0..m {
        for j in 0..n {
            let mut s = bias.map_or(0.0, |b| b[j]);
            for kk in 0..k {
                s += a[i * k + kk] * w[kk * n + j];
            }
            c[i * n + j] = s;
        }
    }
    c
}

fn layer_norm(x: &[f32], g: &[f32], b: &[f32], d: usize) -> Vec<f32> {
    let mut out = vec![0.0f32; x.len()];
    for (row, orow) in x.chunks_exact(d).zip(out.chunks_exact_mut(d)) {
        let mean = row.iter().sum::<f32>() / d as f32;
        let var = row.iter().map(|&v| (v - mean) * (v - mean)).sum::<f32>() / d as f32;
        let inv = 1.0 / (var + 1e-5).sqrt();
        for i in 0..d {
            orow[i] = (row[i] - mean) * inv * g[i] + b[i];
        }
    }
    out
}

fn gelu(x: f32) -> f32 {
    0.5 * x * (1.0 + (0.797_884_6 * (x + 0.044_715 * x * x * x)).tanh())
}

/// One unfused scalar forward over `ids` (flattened `(B, N, input_len)`)
/// at the artifact's full sequence length.
pub fn forward(raw: &RawWeights, meta: &ArtifactMeta, ids: &[i32]) -> Result<Vec<f32>> {
    forward_at(raw, meta, meta.seq_len, ids)
}

/// The scalar forward at a runtime sequence length `seq_len <=
/// meta.seq_len` (a bucket): `ids` is flattened `(B, N, n_mux +
/// seq_len)`. Parameterized exactly like the fused native path so the
/// bucketed parity proptest can pin every bucket against this oracle.
pub fn forward_at(
    raw: &RawWeights,
    meta: &ArtifactMeta,
    seq_len: usize,
    ids: &[i32],
) -> Result<Vec<f32>> {
    let b = meta.batch;
    let n = meta.n_mux;
    ensure!(
        (1..=meta.seq_len).contains(&seq_len),
        "reference: seq_len {seq_len} outside 1..={}",
        meta.seq_len
    );
    ensure!(
        meta.input_len == meta.seq_len + n,
        "reference: prefix layout {} != {} + {n}",
        meta.input_len,
        meta.seq_len
    );
    let li = n + seq_len;
    let d = meta.d_model;
    ensure!(ids.len() == b * n * li, "reference: ids length {}", ids.len());
    ensure!(meta.demux == "index_embed", "reference: demux {}", meta.demux);
    let (tok_shape, tok) = tensor(raw, "tok_emb")?;
    let vocab = tok_shape[0];
    let (_, pos) = tensor(raw, "pos_emb")?;
    let (_, vecs) = tensor(raw, "mux/vecs")?;
    let (ff1_shape, _) = tensor(raw, "layers/0/ff1/w")?;
    let d_ff = ff1_shape[1];
    let (w1h_shape, _) = tensor(raw, "demux/w1h")?;
    let fd = w1h_shape[1];

    // ---- embeddings, per-slot transforms, mux mean (all materialized) ---
    let mut emb = vec![0.0f32; b * n * li * d];
    for bb in 0..b {
        for slot in 0..n {
            for l in 0..li {
                let id = ids[(bb * n + slot) * li + l];
                ensure!(id >= 0 && (id as usize) < vocab, "reference: token id {id} oob");
                let base = ((bb * n + slot) * li + l) * d;
                for dd in 0..d {
                    emb[base + dd] = tok[id as usize * d + dd] + pos[l * d + dd];
                }
            }
        }
    }
    // phi^i(x^i), materialized per slot before summing — the unfused path
    let mut slotted = vec![0.0f32; b * n * li * d];
    for bb in 0..b {
        for slot in 0..n {
            for l in 0..li {
                let base = ((bb * n + slot) * li + l) * d;
                for dd in 0..d {
                    slotted[base + dd] = emb[base + dd] * vecs[slot * d + dd];
                }
            }
        }
    }
    let rows = b * li;
    let mut x = vec![0.0f32; rows * d];
    for bb in 0..b {
        for l in 0..li {
            for dd in 0..d {
                let mut acc = 0.0f32;
                for slot in 0..n {
                    acc += slotted[((bb * n + slot) * li + l) * d + dd];
                }
                x[(bb * li + l) * d + dd] = acc / n as f32;
            }
        }
    }

    // ---- encoder ---------------------------------------------------------
    let heads = meta.n_heads;
    let dh = d / heads;
    let scale = 1.0 / (dh as f32).sqrt();
    for layer in 0..meta.n_layers {
        let p = |stem: &str| format!("layers/{layer}/{stem}");
        let ln1 = layer_norm(
            &x,
            tensor(raw, &p("ln1/g"))?.1,
            tensor(raw, &p("ln1/b"))?.1,
            d,
        );
        let q = matmul(
            &ln1,
            tensor(raw, &p("wq/w"))?.1,
            Some(tensor(raw, &p("wq/b"))?.1),
            rows,
            d,
            d,
        );
        let k = matmul(
            &ln1,
            tensor(raw, &p("wk/w"))?.1,
            Some(tensor(raw, &p("wk/b"))?.1),
            rows,
            d,
            d,
        );
        let v = matmul(
            &ln1,
            tensor(raw, &p("wv/w"))?.1,
            Some(tensor(raw, &p("wv/b"))?.1),
            rows,
            d,
            d,
        );
        let mut ctx = vec![0.0f32; rows * d];
        for bb in 0..b {
            for hh in 0..heads {
                for i in 0..li {
                    let mut scores = vec![0.0f32; li];
                    for j in 0..li {
                        let mut s = 0.0f32;
                        for t in 0..dh {
                            s += q[(bb * li + i) * d + hh * dh + t]
                                * k[(bb * li + j) * d + hh * dh + t];
                        }
                        scores[j] = s * scale;
                    }
                    let max = scores.iter().cloned().fold(f32::NEG_INFINITY, f32::max);
                    let mut sum = 0.0f32;
                    for sv in scores.iter_mut() {
                        *sv = (*sv - max).exp();
                        sum += *sv;
                    }
                    for sv in scores.iter_mut() {
                        *sv /= sum;
                    }
                    for j in 0..li {
                        for t in 0..dh {
                            ctx[(bb * li + i) * d + hh * dh + t] +=
                                scores[j] * v[(bb * li + j) * d + hh * dh + t];
                        }
                    }
                }
            }
        }
        let attn = matmul(
            &ctx,
            tensor(raw, &p("wo/w"))?.1,
            Some(tensor(raw, &p("wo/b"))?.1),
            rows,
            d,
            d,
        );
        for i in 0..x.len() {
            x[i] += attn[i];
        }
        let ln2 = layer_norm(
            &x,
            tensor(raw, &p("ln2/g"))?.1,
            tensor(raw, &p("ln2/b"))?.1,
            d,
        );
        let mut h = matmul(
            &ln2,
            tensor(raw, &p("ff1/w"))?.1,
            Some(tensor(raw, &p("ff1/b"))?.1),
            rows,
            d,
            d_ff,
        );
        for v in h.iter_mut() {
            *v = gelu(*v);
        }
        let ff = matmul(
            &h,
            tensor(raw, &p("ff2/w"))?.1,
            Some(tensor(raw, &p("ff2/b"))?.1),
            rows,
            d_ff,
            d,
        );
        for i in 0..x.len() {
            x[i] += ff[i];
        }
    }
    let hfinal = layer_norm(&x, tensor(raw, "ln_f/g")?.1, tensor(raw, "ln_f/b")?.1, d);

    // ---- index-embedding demux + head ------------------------------------
    let prefix = n;
    let lp = match meta.task.as_str() {
        "cls" => 1,
        "token" => seq_len,
        other => bail!("reference: unsupported task '{other}'"),
    };
    let w1h = tensor(raw, "demux/w1h")?.1;
    let w1p = tensor(raw, "demux/w1p")?.1;
    let b1 = tensor(raw, "demux/b1")?.1;
    let w2 = tensor(raw, "demux/w2")?.1;
    let b2 = tensor(raw, "demux/b2")?.1;
    let head = match meta.task.as_str() {
        "token" => "head_token",
        _ => "head_cls",
    };
    let hw = tensor(raw, &format!("{head}/w"))?.1;
    let hb = tensor(raw, &format!("{head}/b"))?.1;
    let n_cls = meta.n_classes;
    let mut out = vec![0.0f32; b * n * lp * n_cls];
    for bb in 0..b {
        // prefix hidden states (index embeddings) and content positions
        let mut pproj = vec![0.0f32; n * fd];
        for slot in 0..n {
            let row = &hfinal[(bb * li + slot) * d..(bb * li + slot + 1) * d];
            let dst = matmul(row, w1p, None, 1, d, fd);
            pproj[slot * fd..(slot + 1) * fd].copy_from_slice(&dst);
        }
        for l in 0..lp {
            let row = &hfinal[(bb * li + prefix + l) * d..(bb * li + prefix + l + 1) * d];
            let hproj = matmul(row, w1h, None, 1, d, fd);
            for slot in 0..n {
                let mut z = vec![0.0f32; fd];
                for t in 0..fd {
                    z[t] = gelu(hproj[t] + pproj[slot * fd + t] + b1[t]);
                }
                let dem = matmul(&z, w2, Some(b2), 1, fd, d);
                let logits = matmul(&dem, hw, Some(hb), 1, d, n_cls);
                let base = ((bb * n + slot) * lp + l) * n_cls;
                out[base..base + n_cls].copy_from_slice(&logits);
            }
        }
    }
    Ok(out)
}
